"""Micro-benchmark: device-resident chunked decode vs the per-token host
serving loop.

    PYTHONPATH=src python benchmarks/bench_serve.py [--repeats 2]
    PYTHONPATH=src python benchmarks/bench_serve.py --smoke   # CI

Writes results/benchmarks/BENCH_serve.json. Both engines serve the same
greedy request wave (mixed prompt lengths, continuous slot turnover);
each engine is warmed with one throwaway wave (compile caches), then
timed waves reuse the SAME engine instance — exactly how a long-lived
server amortizes compiled programs. The host loop pays one blocking
device->host logits sync + python sampling + token re-upload per decode
STEP; the chunked engine dispatches one fused `decode_loop` scan per K
tokens per slot and syncs once per chunk, with admission fused into a
single prefill+insert dispatch.

The model is a deliberately tiny serving config (2 layers, d_model 32):
the point of this bench is the SERVING-LOOP overhead — per-token
dispatch + sync latency, which bounds decode throughput whenever the
accelerator is fast relative to the host (the GainSight regime this
repo models) — not matmul time. Per-step model compute shrinks the
measured gap; it does not change the per-token overhead being removed.

Sync accounting is per slot-stream (decode syncs x slots / tokens): the
host loop pays ~1 sync per generated token of every stream, the chunked
engine ~1/K.

Checks recorded (the PR's acceptance bar):
  * speedup_ge_3x     — chunked device decode >= 3x tokens/sec over the
                        per-token host loop (asserted on smoke too)
  * host_sync_per_tok — host mode ~1 per token (per-slot accounting)
  * dev_sync_per_tok  — device mode ~1/K per token
  * greedy_parity     — identical greedy token streams across modes
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

LENGTHS = [4, 8, 12, 16]


def _requests(cfg, n, max_new):
    from repro.serving.engine import Request
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        LENGTHS[i % len(LENGTHS)])
                    .astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def _wave(eng, cfg, n, max_new):
    """Serve one request wave on a warm engine; returns
    (tokens, wall_s, decode_syncs, streams)."""
    for r in _requests(cfg, n, max_new):
        eng.submit(r)
    eng.done = []
    eng.host_syncs = eng.admit_syncs = 0
    t0 = time.time()
    done, _ = eng.run()
    wall = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    return (toks, wall, eng.host_syncs - eng.admit_syncs,
            {r.rid: r.out_tokens for r in done})


def collect(repeats: int = 2, smoke: bool = False, chunk: int = 8,
            n_requests: int = 16, max_new: int = 48) -> dict:
    import dataclasses

    import jax
    from repro.configs import get_config
    from repro.models.model import Model
    from repro.serving import ServeEngine

    if smoke:
        n_requests, max_new = 12, 32

    n_slots, window = 4, 80
    cfg = dataclasses.replace(get_config("llama3.2-1b").reduced(),
                              dtype="float32", n_layers=2, d_model=32,
                              n_heads=4, n_kv_heads=4, head_dim=8, d_ff=64)
    params = Model(cfg).init(jax.random.key(0))

    out = {}
    streams = {}
    for mode in ("host", "device"):
        eng = ServeEngine(cfg, params, n_slots=n_slots, window=window,
                          mode=mode, decode_chunk=chunk)
        _, cold, _, _ = _wave(eng, cfg, n_requests, max_new)   # warm-up
        best = None
        for _ in range(repeats + 1):
            toks, wall, syncs, st = _wave(eng, cfg, n_requests, max_new)
            if best is None or wall < best[1]:
                best = (toks, wall, syncs, st)
        toks, wall, syncs, st = best
        streams[mode] = st
        out[mode] = {"tokens": toks, "wall_s": round(wall, 4),
                     "cold_s": round(cold, 3),
                     "tok_per_s": round(toks / max(wall, 1e-9), 1),
                     "decode_syncs": syncs,
                     "sync_per_tok": round(syncs * n_slots / max(toks, 1),
                                           4)}

    speedup = out["device"]["tok_per_s"] / max(out["host"]["tok_per_s"],
                                               1e-9)
    parity = streams["device"] == streams["host"]
    return {
        "config": cfg.name,
        "n_slots": n_slots,
        "n_requests": n_requests,
        "max_new": max_new,
        "decode_chunk": chunk,
        "host": out["host"],
        "device": out["device"],
        "speedup": round(speedup, 1),
        "checks": {
            "speedup_ge_3x": speedup >= 3.0,
            "host_sync_per_tok": out["host"]["sync_per_tok"] >= 0.8,
            "dev_sync_per_tok":
                out["device"]["sync_per_tok"] <= 1.5 / chunk,
            "greedy_parity": parity,
        },
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--smoke", action="store_true",
                    help="small wave for CI (speedup bar still applies)")
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--out", default="results/benchmarks")
    args = ap.parse_args()
    res = collect(args.repeats, smoke=args.smoke, chunk=args.chunk)
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "BENCH_serve.json"), "w") as f:
        json.dump(res, f, indent=1)
    print(f"bench_serve: {res['n_requests']} reqs x {res['max_new']} new "
          f"(K={res['decode_chunk']}, {res['n_slots']} slots)  "
          f"host {res['host']['tok_per_s']} tok/s "
          f"({res['host']['sync_per_tok']} sync/tok)  "
          f"device {res['device']['tok_per_s']} tok/s "
          f"({res['device']['sync_per_tok']} sync/tok)  "
          f"speedup {res['speedup']}x  parity "
          f"{res['checks']['greedy_parity']}")
    return 0 if all(res["checks"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
