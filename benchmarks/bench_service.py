"""Micro-benchmark: coalesced multi-tenant query execution vs the
eager single-caller path, on a mixed sweep/match/codesign workload.

    PYTHONPATH=src python benchmarks/bench_service.py [--smoke] [--repeats 1]

Writes results/benchmarks/bench_service.json. The sequential baseline
is what N tenants get today: each runs its own Session and executes its
query eagerly, re-evaluating every lattice its neighbours already
evaluated. The coalesced path queues the same queries on ONE session
and drains them in a single admission wave (`Session.run_many`): plan
nodes dedupe by content hash, distinct lattice evaluations union into
one padded device batch, and the shmoo/codesign grids run once each.
Results must match the sequential path BIT-FOR-BIT (the executor's
core invariant); the recorded speedup and the device-call counts gate
CI. The same workload is also pushed through the JSON compile service
(`repro.launch.compile_service`) as an end-to-end check of the
process-level front door.
"""
from __future__ import annotations

import argparse
import json
import os
import time

SHAPE = "decode_32k"


def _workload(smoke: bool):
    """One mixed wave: per tenant a (distinct but overlapping) sweep, a
    match with tenant-specific demands over the shared lattice, and a
    co-design run for the tenant's model — distinct queries that share
    almost all of their lattice evaluation."""
    from repro.api import CoDesignQuery, MatchQuery, SweepQuery
    from repro.core.dse import Demand
    from repro.workloads.profiler import profile_arch

    archs = ["qwen2-0.5b", "llama3.2-1b", "minicpm-2b"] if smoke else \
        ["qwen2-0.5b", "llama3.2-1b", "llama3.2-3b", "minicpm-2b",
         "zamba2-2.7b", "xlstm-1.3b"]
    nw = (16, 32, 64) if smoke else (16, 32, 64, 128)
    shared = SweepQuery(cells=("gc2t_nn", "gc2t_osos"),
                        word_sizes=(16, 32), num_words=nw)
    queries, kinds = [], []
    for i, arch in enumerate(archs):
        # tenant sweeps are PROPER prefixes of the shared lattice's
        # num_words — never equal to it — so the shared sweep (behind
        # every match/codesign) must union the remaining configs into
        # the tenant sweeps' device batch rather than pure-dedupe
        queries.append(SweepQuery(cells=("gc2t_nn", "gc2t_osos"),
                                  word_sizes=(16, 32),
                                  num_words=nw[:2 + i % max(1, len(nw) - 2)]))
        kinds.append("sweep")
        queries.append(MatchQuery(
            (Demand(f"{arch}-act", "L1", 2.0e8 * (1 + i), 2.0e-6),
             Demand(f"{arch}-kv", "L2", 4.0e8 * (1 + i), 1.0e-3,
                    capacity_bits=1 << 20)), shared))
        kinds.append("match")
        queries.append(CoDesignQuery(
            profiles=(profile_arch(arch, SHAPE),), sweep=shared,
            vdd_scales=(0.85, 1.0)))
        kinds.append("codesign")
    return queries, kinds


def _counted(fn, counter, key):
    def wrapper(*a, **kw):
        counter[key] += 1
        return fn(*a, **kw)
    return wrapper


def collect(repeats: int = 1, smoke: bool = False) -> dict:
    from repro.api import Session
    from repro.core import dse_batch

    queries, kinds = _workload(smoke)
    calls = {"eval_batch": 0, "vdd": 0}
    orig_eb, orig_vl = dse_batch.evaluate_batch, \
        dse_batch.evaluate_vdd_lattice
    dse_batch.evaluate_batch = _counted(orig_eb, calls, "eval_batch")
    dse_batch.evaluate_vdd_lattice = _counted(orig_vl, calls, "vdd")
    try:
        # warm the jitted kernels (power-of-two buckets make these the
        # same compiled programs both measured paths reuse)
        Session().run_many(queries)

        def best_of(fn):
            walls, res = [], None
            for _ in range(max(1, repeats)):
                t0 = time.time()
                res = fn()
                walls.append(time.time() - t0)
            return res, min(walls)

        def sequential():
            marks = dict(calls)
            out = [Session().run(q) for q in queries]   # isolated tenants
            return out, {k: calls[k] - marks[k] for k in calls}

        def coalesced():
            marks = dict(calls)
            out = Session().run_many(queries)           # one wave
            return out, {k: calls[k] - marks[k] for k in calls}

        (seq_res, seq_calls), seq_s = best_of(sequential)
        (co_res, co_calls), co_s = best_of(coalesced)
    finally:
        dse_batch.evaluate_batch = orig_eb
        dse_batch.evaluate_vdd_lattice = orig_vl

    def canon(r):
        return json.dumps(r.as_dict(), sort_keys=True, default=str)

    identical = all(canon(a) == canon(b) for a, b in zip(seq_res, co_res))

    # end-to-end through the JSON front door (sweep/match only — the
    # service resolves codesign profiles itself from {arch, shape})
    from repro.launch.compile_service import CompileService
    svc = CompileService(wave_size=len(queries))
    reqs = []
    for i, (q, kind) in enumerate(zip(queries, kinds)):
        if kind == "sweep":
            spec = {"type": "sweep", "cells": list(q.cells),
                    "word_sizes": list(q.word_sizes),
                    "num_words": list(q.num_words)}
        elif kind == "match":
            spec = {"type": "match",
                    "demands": [{"name": d.name, "level": d.level,
                                 "read_freq_hz": d.read_freq_hz,
                                 "lifetime_s": d.lifetime_s,
                                 "capacity_bits": d.capacity_bits}
                                for d in q.demands],
                    "sweep": {"cells": list(q.sweep.cells),
                              "word_sizes": list(q.sweep.word_sizes),
                              "num_words": list(q.sweep.num_words)}}
        else:
            spec = {"type": "codesign",
                    "profiles": [{"arch": p.arch, "shape": SHAPE}
                                 for p in q.profiles],
                    "vdd_scales": list(q.vdd_scales),
                    "sweep": {"cells": list(q.sweep.cells),
                              "word_sizes": list(q.sweep.word_sizes),
                              "num_words": list(q.sweep.num_words)}}
        reqs.append(json.dumps({"id": f"r{i}", "tenant": f"t{i % 3}",
                                "query": spec}))
    responses = [json.loads(line) for line in svc.serve_lines(reqs)]
    service_ok = len(responses) == len(queries) and \
        all(r["ok"] for r in responses)

    speedup = seq_s / max(co_s, 1e-9)
    n = len(queries)
    return {
        "n_queries": n, "mix": dict((k, kinds.count(k)) for k in set(kinds)),
        "sequential_wall_s": round(seq_s, 3),
        "coalesced_wall_s": round(co_s, 3),
        "sequential_qps": round(n / max(seq_s, 1e-9), 1),
        "coalesced_qps": round(n / max(co_s, 1e-9), 1),
        "speedup": round(speedup, 2),
        "sequential_calls": seq_calls, "coalesced_calls": co_calls,
        "service_waves": svc.waves,
        "checks": {
            "results_bit_identical": identical,
            # the coalescing claim, in device-call counts: one union
            # batch + one vdd lattice for the whole wave (evaluate_batch
            # is itself a thin wrapper over evaluate_vdd_lattice, so its
            # inner call is subtracted from the direct-vdd count)
            "coalesced_one_eval_batch": co_calls["eval_batch"] == 1,
            "coalesced_one_vdd_eval":
                co_calls["vdd"] - co_calls["eval_batch"] == 1,
            "coalescing_reduces_calls":
                sum(co_calls.values()) < sum(seq_calls.values()),
            "concurrency_speedup": speedup >= 1.2,
            "service_all_ok": service_ok,
        },
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="small workload for CI")
    ap.add_argument("--out", default="results/benchmarks")
    args = ap.parse_args()
    res = collect(args.repeats, args.smoke)
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "bench_service.json"), "w") as f:
        json.dump(res, f, indent=1)
    print(f"bench_service: {res['n_queries']} queries  "
          f"sequential {res['sequential_wall_s']}s "
          f"({res['sequential_qps']} q/s)  coalesced "
          f"{res['coalesced_wall_s']}s ({res['coalesced_qps']} q/s)  "
          f"speedup {res['speedup']}x  identical "
          f"{res['checks']['results_bit_identical']}  calls "
          f"{res['sequential_calls']} -> {res['coalesced_calls']}")
    return 0 if all(res["checks"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
