"""One benchmark per paper table/figure (DESIGN.md §7). Each returns a
dict of rows and asserts its paper-fidelity claim(s); benchmarks/run.py
prints them as CSV and writes results/benchmarks/*.json."""
from __future__ import annotations

import time

import numpy as np

from repro.api import Session, SweepQuery
from repro.core import dse, layout, power, retention, timing
from repro.core.bank import BankConfig, build_bank
from repro.core.cells import CELLS, with_write_vt
from repro.core.spice import devices as dv
from repro.core.techfile import SYN40


def fig3_cell_area():
    """Cell layouts: Si-Si GC = 69% of 6T, OS-OS = 11% (C1)."""
    a6 = layout.cell_area_um2(SYN40, "sram6t")
    rows = []
    for key, paper in [("sram6t", 1.0), ("gc2t_nn", 0.69), ("gc2t_osos", 0.11),
                       ("gc2t_np", None), ("gc3t", None), ("gc2t_hyb", None)]:
        a = layout.cell_area_um2(SYN40, key)
        rows.append({"cell": key, "area_um2": round(a, 4),
                     "ratio_vs_6t": round(a / a6, 3), "paper_ratio": paper})
    checks = {"c1_sisi": abs(rows[1]["ratio_vs_6t"] - 0.69) < 0.03,
              "c1_osos": abs(rows[2]["ratio_vs_6t"] - 0.11) < 0.02}
    return {"rows": rows, "checks": checks}


def fig6_bank_area():
    """Bank/array area + efficiency + crossover (C2, C3, C5-area)."""
    rows = []
    for bits in (1024, 4096, 16384, 65536, 262144):
        ws = int(np.sqrt(bits))
        bs = build_bank(BankConfig(ws, ws, cell="sram6t"))
        bg = build_bank(BankConfig(ws, ws, cell="gc2t_nn"))
        bl = build_bank(BankConfig(ws, ws, cell="gc2t_nn", wwlls=True))
        bo = build_bank(BankConfig(ws, ws, cell="gc2t_osos"))
        rows.append({
            "bits": bits,
            "sram_bank_um2": round(bs.area_um2), "gc_bank_um2": round(bg.area_um2),
            "gc_ls_bank_um2": round(bl.area_um2), "osos_bank_um2": round(bo.area_um2),
            "sram_array_um2": round(bs.array_area_um2),
            "gc_array_um2": round(bg.array_area_um2),
            "sram_arr_eff": round(bs.plan.array_efficiency, 3),
            "gc_arr_eff": round(bg.plan.array_efficiency, 3),
            "gc_over_sram": round(bg.area_um2 / bs.area_um2, 3),
        })
    # paper's method: polynomial trendline on the 1-16Kb ratios,
    # extrapolated to 64/256Kb
    x = np.log2([r["bits"] for r in rows[:3]])
    y = [r["gc_over_sram"] for r in rows[:3]]
    fit = np.polyfit(x, y, 2)
    extrap = {int(2 ** b): round(float(np.polyval(fit, b)), 3)
              for b in (16, 18)}
    checks = {
        "c2_gc_larger_1to16k": all(r["gc_over_sram"] > 1 for r in rows[:3]),
        "c2_gc_array_smaller": all(r["gc_array_um2"] < r["sram_array_um2"]
                                   for r in rows),
        "c2_crossover_at_scale": rows[-1]["gc_over_sram"] < 1,
        "c3_osos_smaller_everywhere": all(
            r["osos_bank_um2"] < r["sram_bank_um2"] for r in rows),
        "c5_wwlls_area_penalty": all(
            r["gc_ls_bank_um2"] > r["gc_bank_um2"] for r in rows),
    }
    return {"rows": rows, "trendline_extrapolation": extrap, "checks": checks}


def fig7_frequency():
    """Operating frequency (C4, C5)."""
    rows = []
    for bits in (1024, 4096, 16384):
        ws = int(np.sqrt(bits))
        recs = {}
        for name, cfg in [
            ("sram", BankConfig(ws, ws, "sram6t")),
            ("gc_1to1", BankConfig(16, bits // 16, "gc2t_nn")),
            ("gc_sq", BankConfig(ws, ws, "gc2t_nn")),
            ("gc_sq_ls", BankConfig(ws, ws, "gc2t_nn", wwlls=True)),
            ("gc_np", BankConfig(ws, ws, "gc2t_np")),
            ("gc_osos", BankConfig(ws, ws, "gc2t_osos")),
        ]:
            b = build_bank(cfg)
            t = timing.analyze(b)
            recs[name + "_mhz"] = round(t.f_max_hz / 1e6, 1)
            if name == "gc_sq":
                recs["gc_stages"] = t.delay_stages
                recs["gc_mux"] = build_bank(
                    BankConfig(16, bits // 16, "gc2t_nn")).has_colmux
        rows.append({"bits": bits, **recs})
    checks = {
        "c4_gc_slower_than_sram": all(r["gc_sq_mhz"] < r["sram_mhz"]
                                      for r in rows),
        "c4_mux_config_slower": all(r["gc_1to1_mhz"] <= r["gc_sq_mhz"]
                                    for r in rows),
        "c4_freq_falls_with_size": rows[-1]["gc_sq_mhz"] < rows[0]["gc_sq_mhz"],
        "c5_wwlls_faster": all(r["gc_sq_ls_mhz"] >= r["gc_sq_mhz"]
                               for r in rows),
    }
    return {"rows": rows, "checks": checks}


def fig7_bandwidth():
    """Effective bandwidth: dual-port GC vs shared-port SRAM (C6)."""
    rows = []
    for bits in (1024, 4096, 16384):
        ws = int(np.sqrt(bits))
        pg = dse.evaluate(BankConfig(ws, ws, "gc2t_nn"))
        ps = dse.evaluate(BankConfig(ws, ws, "sram6t"))
        rows.append({
            "bits": bits,
            "gc_eff_bw_gbps": round(pg.eff_bw_bps / 8e9, 2),
            "sram_eff_bw_gbps": round(ps.eff_bw_bps / 8e9, 2),
            "gc_words_per_cycle": round(pg.eff_bw_bps / pg.f_max_hz / ws, 2),
            "sram_words_per_cycle": round(ps.eff_bw_bps / ps.f_max_hz / ws, 2),
        })
    checks = {
        "c6_sram_halved": all(r["sram_words_per_cycle"] == 1.0 for r in rows),
        "c6_gc_dual": all(r["gc_words_per_cycle"] == 2.0 for r in rows),
    }
    return {"rows": rows, "checks": checks}


def fig7_leakage():
    """Leakage power (C7)."""
    rows = []
    for bits in (1024, 4096, 16384):
        ws = int(np.sqrt(bits))
        bs = build_bank(BankConfig(ws, ws, "sram6t"))
        bg = build_bank(BankConfig(ws, ws, "gc2t_nn"))
        ts = timing.analyze(bs)
        tg = timing.analyze(bg)
        r = retention.analyze(bg.cell, SYN40)
        p_s = power.analyze(bs, ts.f_max_hz)
        p_g = power.analyze(bg, tg.f_max_hz, t_ret_s=r.t_ret_s)
        rows.append({
            "bits": bits,
            "sram_cell_leak_uw": round(p_s.cell_leakage_w * 1e6, 4),
            "gc_cell_leak_uw": round(p_g.cell_leakage_w * 1e6, 6),
            "sram_total_leak_uw": round(p_s.leakage_w * 1e6, 3),
            "gc_total_leak_uw": round(p_g.leakage_w * 1e6, 3),
            "gc_refresh_uw": round(p_g.refresh_w * 1e6, 3),
        })
    checks = {
        "c7_cell_leak_negligible": all(r["gc_cell_leak_uw"] == 0 for r in rows),
        # bank-level: GC wins once cell leakage amortizes over periphery
        # (>= 4 Kb here; at 1 Kb the dual-port periphery leak dominates —
        # noted in EXPERIMENTS.md)
        "c7_bank_leak_lower_ge4kb": all(
            r["gc_total_leak_uw"] < r["sram_total_leak_uw"]
            for r in rows if r["bits"] >= 4096),
    }
    return {"rows": rows, "checks": checks}


def fig8_retention():
    """Retention modulation (C8, C9) + Id-Vg curves (Fig 8a/d)."""
    rows = []
    for label, cell, ls in [
        ("sisi_nn_lvt", with_write_vt(CELLS["gc2t_nn"], "nmos_lvt"), False),
        ("sisi_nn_svt", CELLS["gc2t_nn"], False),
        ("sisi_nn_hvt", with_write_vt(CELLS["gc2t_nn"], "nmos_hvt"), False),
        ("sisi_nn_svt_ls", CELLS["gc2t_nn"], True),
        ("sisi_np", CELLS["gc2t_np"], False),
        ("osos", CELLS["gc2t_osos"], False),
        ("osos_hvt_ls", with_write_vt(CELLS["gc2t_osos"], "os_n_hvt"), True),
        ("hybrid", CELLS["gc2t_hyb"], False),
    ]:
        r = retention.analyze(cell, SYN40, wwlls=ls)
        rows.append({"config": label, "t_ret_s": float(f"{r.t_ret_s:.4g}"),
                     "v_sn0": round(r.v_sn0, 3),
                     "i_leak0_a": float(f"{r.i_leak0_a:.3g}")})
    # sweep up to 0.54 V: beyond that the un-boosted write degrades the
    # '1' below the read margin (v0 < v_m; retention -> 0, a real cliff)
    vt_sweep = retention.retention_vs_vt(
        CELLS["gc2t_nn"], SYN40, np.linspace(0.32, 0.54, 8))
    ioff_os = dv.i_off(SYN40.flavor("os_n_hvt"), 1.0, 0.04, 1.1)
    by = {r["config"]: r["t_ret_s"] for r in rows}
    checks = {
        "c8_si_us_range": 1e-7 < by["sisi_nn_svt"] < 1e-4,
        "c8_vt_monotone": bool(np.all(np.diff(vt_sweep) > 0)),
        "c8_wwlls_helps": by["sisi_nn_svt_ls"] > by["sisi_nn_svt"],
        "c9_os_ms_range": 1e-3 < by["osos"] < 1.0,
        "c9_os_engineered_gt_10s": by["osos_hvt_ls"] > 10.0,
        "c9_ioff_lt_1e18_per_um": ioff_os < 1e-18,
        "hybrid_between": by["sisi_nn_svt"] < by["hybrid"],
    }
    return {"rows": rows, "vt_sweep_s": [float(f"{x:.4g}") for x in vt_sweep],
            "checks": checks}


def table1_fig9_workloads(dryrun_dir="results/dryrun"):
    """Workload demands for our 10 assigned archs (Table I + Fig 9)."""
    import glob
    import os
    from repro.workloads.profiler import profile_arch, profile_from_dryrun
    if glob.glob(os.path.join(dryrun_dir, "*pod256.json")):
        profiles = profile_from_dryrun(dryrun_dir)
    else:  # analytic fallback if the dry-run sweep hasn't run
        from repro.configs import ARCH_IDS, get_config
        profiles = [profile_arch(a, s.name) for a in ARCH_IDS
                    for s in get_config(a).shapes()]
    rows = []
    for p in profiles:
        rows.append({
            "task": f"{p.arch}:{p.shape}", "kind": p.kind,
            "step_s": float(f"{p.step_time_s:.3g}"),
            "l1_read_mhz_per_bank": round(p.l1_read_hz / 1e6, 2),
            "l2_read_mhz_per_bank": round(p.l2_read_hz / 1e6, 2),
            "act_lifetime_s": float(f"{p.act_lifetime_s:.3g}"),
            "kv_lifetime_s": float(f"{p.kv_lifetime_s:.3g}"),
        })
    l1 = [r["l1_read_mhz_per_bank"] for r in rows]
    l2 = [r["l2_read_mhz_per_bank"] for r in rows]
    checks = {"fig9_l2_freq_exceeds_l1_for_most": float(np.mean(
        [b > a for a, b in zip(l1, l2)])) >= 0.5}
    return {"rows": rows, "checks": checks, "n_profiles": len(rows)}


def fig10_shmoo(dryrun_dir="results/dryrun"):
    """Design-choice shmoo: GCRAM configs x workload demands."""
    from repro.workloads.profiler import demands_table, profile_arch, \
        profile_from_dryrun
    import glob
    import os
    if glob.glob(os.path.join(dryrun_dir, "*pod256.json")):
        profiles = profile_from_dryrun(dryrun_dir)
    else:
        from repro.configs import ARCH_IDS, get_config
        profiles = [profile_arch(a, s.name) for a in ARCH_IDS
                    for s in get_config(a).shapes()]
    points = list(Session().sweep(
        SweepQuery(cells=("gc2t_nn",), wwlls=(False, True))).points)
    demands = demands_table(profiles)
    grid = dse.shmoo(points, demands)
    # aggregates the paper reads off the plot:
    small = [k for k in next(iter(grid.values()))
             if "/16x16" in k or "/16x32" in k or "/32x16" in k or "/32x32" in k]
    l1_rows = {k: v for k, v in grid.items() if k.startswith("L1")}
    l1_small_pass = float(np.mean([any(v[c] for c in small)
                                   for v in l1_rows.values()]))
    pass_rate = float(np.mean([[v for v in row.values()]
                               for row in grid.values()]))
    # multibank rescue (paper: "employ a multi-banked GCRAM design to
    # accommodate multiple parallel read and write requests"): L2 demands
    # no single bank can serve become feasible with N interleaved banks
    from repro.core.multibank import banks_needed
    best = max((p for p in points if p.swing_ok), key=lambda p: p.f_max_hz)
    l2_hard = [d for d in demands if d.level == "L2"
               and not any(dse.feasible(p, d) for p in points)]
    rescued = {d.name: banks_needed(best, d) for d in l2_hard}
    rescue_ok = all(1 < n <= 1024 for n in rescued.values()) if rescued \
        else True
    checks = {
        "fig10_small_banks_serve_most_l1": l1_small_pass >= 0.6,
        "fig10_grid_nontrivial": 0.05 < pass_rate < 0.95,
        "fig10_multibank_rescues_l2": rescue_ok,
    }
    return {"grid_rows": len(grid), "grid_cols": len(next(iter(grid.values()))),
            "pass_rate": round(pass_rate, 3),
            "l1_small_bank_pass": round(l1_small_pass, 3),
            "l2_multibank_counts": rescued, "checks": checks,
            "sample": {k: dict(list(v.items())[:4])
                       for k, v in list(grid.items())[:3]}}


def beyond_dse_gradopt():
    """Paper §VI future work realized: gradient co-optimization."""
    t0 = time.time()
    out = {}
    for tgt in (1e-6, 1e-4, 1e-2):
        res = dse.grad_optimize(target_ret_s=tgt, steps=200)
        out[f"target_{tgt:g}s"] = {
            k: (float(f"{v:.4g}") if isinstance(v, float) else v)
            for k, v in res.items() if k != "loss_history"}
    out["wall_s"] = round(time.time() - t0, 1)
    out["checks"] = {"all_targets_met": all(
        v["met"] for k, v in out.items() if k.startswith("target"))}
    return out


def beyond_batched_spice_throughput():
    """Batched-JAX SPICE vs serial solve: design points/second on this
    host (the TPU-native reformulation of the paper's HSPICE loop)."""
    import jax
    import jax.numpy as jnp
    from repro.core.spice.transient import Transient
    from repro.core.timing import read_netlist
    b = build_bank(BankConfig(32, 32, "gc2t_nn"))
    ckt, meta = read_netlist(b)
    sys = ckt.build()
    tr = Transient(sys)
    waves = [([0.0, 1e-10, 1.2e-10], [1.1, 1.1, 0.0]),
             ([0.0, 8e-11, 1e-10], [0.0, 0.0, 1.1]),
             ([0.0, 1.0], [meta["v_sn"], meta["v_sn"]]),
             ([0.0, 1.0], [1.1, 1.1])]
    B = 64
    vts = {"vt0": jnp.tile(jnp.linspace(0.30, 0.60, B)[:, None],
                           (1, len(sys.dev["vt0"])))}
    # warm (compile)
    r = tr.run_batch(waves, 1e-9, 120, vts)
    jax.block_until_ready(r["all"])
    t0 = time.time()
    r = tr.run_batch(waves, 1e-9, 120, vts)
    jax.block_until_ready(r["all"])
    dt_batch = time.time() - t0
    t0 = time.time()
    r1 = tr.run(waves, 1e-9, 120)
    jax.block_until_ready(r1["all"])
    dt_one = time.time() - t0
    speedup = dt_one * B / max(dt_batch, 1e-9)
    return {"batch": B, "batched_wall_s": round(dt_batch, 3),
            "serial_wall_s_per_point": round(dt_one, 4),
            "throughput_points_per_s": round(B / dt_batch, 1),
            "batch_speedup_vs_serial": round(speedup, 1),
            "checks": {"batching_pays": speedup > 4}}


def beyond_batched_sweep():
    """Unified-API lattice sweep: batched (vmapped) vs per-point loop,
    parity + wall-clock (see benchmarks/bench_sweep.py)."""
    from benchmarks.bench_sweep import collect
    return collect(repeats=1)


ALL = {
    "fig3_cell_area": fig3_cell_area,
    "fig6_bank_area": fig6_bank_area,
    "fig7_frequency": fig7_frequency,
    "fig7_bandwidth": fig7_bandwidth,
    "fig7_leakage": fig7_leakage,
    "fig8_retention": fig8_retention,
    "table1_fig9_workloads": table1_fig9_workloads,
    "fig10_shmoo": fig10_shmoo,
    "beyond_dse_gradopt": beyond_dse_gradopt,
    "beyond_batched_spice_throughput": beyond_batched_spice_throughput,
    "beyond_batched_sweep": beyond_batched_sweep,
}
