"""Benchmark: gradient-based design optimization vs a dense vdd grid.

    PYTHONPATH=src python benchmarks/bench_optimize.py
    PYTHONPATH=src python benchmarks/bench_optimize.py --smoke   # CI

The question the differentiable path has to answer: does seeding from a
COARSE voltage ladder and descending the implicit-function gradients
reach the dense grid's optimum at a fraction of its lattice
evaluations?  Both flows minimize standby power over the same config
lattice under the same (read frequency, retention lifetime) demand:

  dense — evaluate every config at `--dense-rungs` voltage rungs
          spanning the operating window, take the feasible argmin
          (the pre-PR OptimizeQuery strategy: sweep and pick).
  grad  — evaluate every config at the 4-rung COARSE ladder only, pick
          the winning config, then refine its continuous vdd knob with
          projected Adam on `repro.core.dse_grad` + exact quantized
          verification (`repro.optim.dse_opt`). Gradient steps are
          counted as full evaluations (conservative: a VJP step costs
          ~2 forward evals of the smooth surrogate, but none of the
          exact model).

Checks recorded (the PR's acceptance bar):
  * objective_le_grid — the gradient flow's EXACT verified objective
                        <= the dense grid's optimum (never worse)
  * evals_lt_25pct    — total gradient-flow evaluations < 25% of the
                        dense grid's (full mode; smoke lattices are too
                        small for the ratio to be meaningful)
  * met               — the returned point passes exact dse.feasible

Writes results/bench_optimize.json and mirrors it to
results/benchmarks/BENCH_optimize.json for the benchmark index.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

COARSE = (0.7, 0.85, 1.0, 1.15)
DEMAND = {"target_freq_hz": 2e8, "target_ret_s": 5e-5}
OBJECTIVE = "standby_w"


def _lattice(smoke: bool):
    from repro.core.dse import lattice_configs
    if smoke:
        return lattice_configs(cells=("gc2t_nn", "gc2t_np"),
                               word_sizes=(32,), num_words=(32, 64),
                               wwlls=(False,))
    return lattice_configs(cells=("gc2t_nn", "gc2t_np", "gc2t_osos"),
                           word_sizes=(16, 32), num_words=(32, 64, 128),
                           wwlls=(False, True))


def _grid_optimum(cfgs, vdd_scales):
    """Feasible argmin of the objective over the (rungs x configs) grid.
    Returns (best objective, (rung, config index), lattice)."""
    from repro.core import dse_batch
    lat = dse_batch.evaluate_vdd_lattice(cfgs, list(vdd_scales))
    feas = dse_batch.feasible_grid(
        lat.f_max_hz, lat.retention_s, lat.swing_ok, lat.num_words,
        np.array([DEMAND["target_freq_hz"]]),
        np.array([DEMAND["target_ret_s"]]))[:, :, 0]
    obj = np.where(feas, np.asarray(getattr(lat, OBJECTIVE)), np.inf)
    v, p = np.unravel_index(int(np.argmin(obj)), obj.shape)
    return float(obj[v, p]), (int(v), int(p)), lat


def collect(smoke: bool, dense_rungs: int, steps: int) -> dict:
    from repro.optim import dse_opt

    cfgs = _lattice(smoke)
    dense_ladder = np.linspace(0.62, 1.25, dense_rungs)

    t0 = time.time()
    dense_best, (dv, dp), _ = _grid_optimum(cfgs, dense_ladder)
    dense_wall = time.time() - t0
    dense_evals = dense_rungs * len(cfgs)

    t0 = time.time()
    coarse_best, (cv, cp), _ = _grid_optimum(cfgs, COARSE)
    r = dse_opt.optimize(cfgs[cp], objective=OBJECTIVE,
                         knobs=("vdd_scale",), steps=steps,
                         seed_vdd_scales=COARSE, **DEMAND)
    grad_wall = time.time() - t0
    grad_evals = (len(COARSE) * len(cfgs)      # coarse config screen
                  + r.evals["grid"]            # optimize() re-seeds cfg*
                  + r.evals["grad_steps"]      # conservative: 1 step = 1
                  + r.evals["verify"])         # exact verification

    ratio = grad_evals / dense_evals
    return {
        "n_configs": len(cfgs),
        "dense_rungs": dense_rungs,
        "demand": DEMAND,
        "objective": OBJECTIVE,
        "dense": {"best": dense_best, "vdd_scale": float(dense_ladder[dv]),
                  "config": cfgs[dp].cell, "evals": dense_evals,
                  "wall_s": round(dense_wall, 3)},
        "grad": {"best": r.objective_value,
                 "knobs": dict(r.knobs), "config": cfgs[cp].cell,
                 "met": r.met, "improved_vs_seed": r.improved,
                 "coarse_seed_best": coarse_best,
                 "evals": grad_evals, "evals_detail": dict(r.evals),
                 "wall_s": round(grad_wall, 3)},
        "eval_ratio": round(ratio, 4),
        "objective_ratio": round(r.objective_value / dense_best, 6)
        if np.isfinite(dense_best) else None,
        "checks": {
            "objective_le_grid": bool(
                r.objective_value <= dense_best * (1 + 1e-9)),
            "evals_lt_25pct": bool(ratio < 0.25),
            "met": bool(r.met),
        },
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small lattice for CI (skips the 25% evals bar)")
    ap.add_argument("--dense-rungs", type=int, default=24)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--out", default="results")
    args = ap.parse_args()
    if args.smoke:
        args.steps = min(args.steps, 12)
    res = collect(args.smoke, args.dense_rungs, args.steps)
    os.makedirs(os.path.join(args.out, "benchmarks"), exist_ok=True)
    for path in (os.path.join(args.out, "bench_optimize.json"),
                 os.path.join(args.out, "benchmarks",
                              "BENCH_optimize.json")):
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
    print(f"bench_optimize: dense {res['dense']['best']:.4g} W in "
          f"{res['dense']['evals']} evals | grad "
          f"{res['grad']['best']:.4g} W in {res['grad']['evals']} evals "
          f"(ratio {res['eval_ratio']})  met={res['grad']['met']}")
    checks = dict(res["checks"])
    if args.smoke:
        # tiny lattice: the fixed gradient-step cost dominates the ratio
        checks.pop("evals_lt_25pct")
    return 0 if all(checks.values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
