"""Micro-benchmark: the device-batched (vdd x lattice x demand) co-design
cube vs the scalar per-(point, voltage, demand) Python loop, with parity
checks against the scalar references `dse.evaluate` / `dse.feasible` /
`multibank.banks_needed`.

    PYTHONPATH=src python benchmarks/bench_codesign.py [--smoke] [--repeats 1]

Writes results/benchmarks/bench_codesign.json. The scalar loop is what
the shmoo flow used to be: re-evaluate every config at every operating
voltage, then test every demand pair-by-pair. The batched path shares
per-(topology, voltage) electricals, vmaps the timing/power algebra over
(vdd x lattice) and evaluates all three demand grids (feasibility,
banks_needed, energy) in one device program each. Feasibility and bank
counts must match BIT-FOR-BIT; the recorded speedup gates CI at >= 10x.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

VDD_SCALES = (0.7, 0.85, 1.0, 1.15)


def _demands():
    from repro.core.dse import Demand
    # span the interesting corners: native-retention passes, refresh-only
    # passes, frequency-infeasible, capacity-driven sizing
    ds = [
        Demand("act-l1", "L1", 3.0e8, 2.0e-6),
        Demand("act-l1-fast", "L1", 1.2e9, 5.0e-7),
        Demand("kv-l2", "L2", 8.0e8, 1.0e-3, capacity_bits=1 << 20),
        Demand("stream-l2", "L2", 2.5e9, 1.0e-5),
        Demand("weights-l2", "L2", 2.0e8, 3600.0, capacity_bits=1 << 22),
        Demand("hopeless", "L2", 5.0e10, 1.0),
    ]
    steps = [2.0e-3, 2.0e-3, 5.0e-3, 5.0e-3, 5.0e-3, 5.0e-3]
    return ds, steps


def collect(repeats: int = 1, smoke: bool = False) -> dict:
    from repro.core import dse
    from repro.core import power as power_mod
    from repro.core.dse import lattice_configs
    from repro.core.dse_batch import codesign_metrics, evaluate_vdd_lattice
    from repro.core.multibank import banks_needed

    if smoke:
        cfgs = lattice_configs(cells=("gc2t_nn", "gc2t_osos"),
                               word_sizes=(16, 32), num_words=(16, 32, 64))
    else:
        cfgs = lattice_configs()
    demands, steps = _demands()
    V, P, D = len(VDD_SCALES), len(cfgs), len(demands)

    def best_of(fn):
        cold, walls = None, []
        for _ in range(repeats + 1):
            t0 = time.time()
            res = fn()
            walls.append(time.time() - t0)
            cold = cold if cold is not None else walls[0]
        return res, min(walls[1:]) if len(walls) > 1 else walls[0], cold

    def scalar_loop():
        feas = np.zeros((V, P, D), bool)
        banks = np.zeros((V, P, D), np.int64)
        points = []
        for vi, v in enumerate(VDD_SCALES):
            row = [dse.evaluate(c, vdd_scale=v) for c in cfgs]
            points.append(row)
            for pi, dp in enumerate(row):
                for di, d in enumerate(demands):
                    feas[vi, pi, di] = dse.feasible(dp, d)
                    banks[vi, pi, di] = banks_needed(
                        dp, d, capacity_bits=d.capacity_bits)
        return feas, banks, points

    def batched():
        lat = evaluate_vdd_lattice(cfgs, VDD_SCALES)
        feas, banks, energy, macro_ok = codesign_metrics(lat, demands, steps)
        return lat, feas, banks, energy, macro_ok

    (lat, bfeas, bbanks, benergy, _), batch_s, batch_cold = best_of(batched)
    (sfeas, sbanks, spoints), loop_s, loop_cold = best_of(scalar_loop)

    feas_exact = bool((bfeas == sfeas).all())
    banks_exact = bool((bbanks == sbanks).all())
    # energy parity vs the scalar power model: e_read per access recovered
    # from power.analyze's dynamic read power at f_max
    worst_e = 0.0
    for vi in range(V):
        for pi, dp in enumerate(spoints[vi]):
            from repro.core.bank import build_bank
            bank = build_bank(dp.cfg)
            pw = power_mod.analyze(bank, dp.f_max_hz,
                                   t_ret_s=dp.retention_s
                                   if np.isfinite(dp.retention_s) else None,
                                   vdd_scale=VDD_SCALES[vi])
            e_read = pw.dynamic_read_w_at_fmax \
                / (dp.f_max_hz * power_mod.ACTIVITY)
            for di, d in enumerate(demands):
                ref = d.read_freq_hz * steps[di] * e_read \
                    + sbanks[vi, pi, di] * (dp.leakage_w + dp.refresh_w) \
                    * steps[di]
                got = benergy[vi, pi, di]
                worst_e = max(worst_e,
                              abs(got - ref) / max(abs(ref), 1e-30))
    speedup = loop_s / max(batch_s, 1e-9)
    return {
        "n_configs": P, "n_vdd": V, "n_demands": D,
        "n_scalar_evals": V * P, "grid_entries": V * P * D,
        "loop_wall_s": round(loop_s, 3),
        "batched_wall_s": round(batch_s, 3),
        "loop_cold_s": round(loop_cold, 3),
        "batched_cold_s": round(batch_cold, 3),
        "speedup": round(speedup, 1),
        "energy_max_rel_dev": float(f"{worst_e:.3g}"),
        "checks": {"feasible_bit_exact": feas_exact,
                   "banks_bit_exact": banks_exact,
                   "energy_within_1e-9": bool(worst_e <= 1e-9),
                   "speedup_ge_10x": speedup >= 10.0},
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="small lattice for CI")
    ap.add_argument("--out", default="results/benchmarks")
    args = ap.parse_args()
    res = collect(args.repeats, args.smoke)
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "bench_codesign.json"), "w") as f:
        json.dump(res, f, indent=1)
    print(f"bench_codesign: {res['n_vdd']}x{res['n_configs']}x"
          f"{res['n_demands']} grid  loop {res['loop_wall_s']}s  "
          f"batched {res['batched_wall_s']}s  speedup {res['speedup']}x  "
          f"feas_exact {res['checks']['feasible_bit_exact']}  "
          f"banks_exact {res['checks']['banks_bit_exact']}")
    return 0 if all(res["checks"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
