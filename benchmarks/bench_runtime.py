"""Micro-benchmark: adaptive voltage governor vs every fixed operating
point, on MEASURED serving telemetry.

    PYTHONPATH=src python benchmarks/bench_runtime.py
    PYTHONPATH=src python benchmarks/bench_runtime.py --smoke   # CI

Writes results/benchmarks/BENCH_runtime.json. Three deterministic
traffic scenarios replay on a warm device-mode ServeEngine:

  chat_burst    bursts of parallel chats separated by near-idle windows
                — the governor's home turf (ride the rail down when
                quiet, jump up for bursts)
  batch_offline sustained full-batch decode — a constant-rate stress
                where the governor should park at one rung and match
                (not beat by switching) the best fixed point
  long_context  few long-prompt requests, high KV residency per token
                — retention/refresh bookkeeping dominates

Each scenario runs TWICE: a plain engine and a telemetry-instrumented
one (same seed). The instrumented run must produce BIT-IDENTICAL greedy
streams and the SAME host-sync counts — the tentpole's zero-overhead
claim, checked here on real traffic, not a mock.

Per scenario the telemetry windows become macro `Traffic` (the governed
macro is the L2 KV-cache store; its rate is the measured KV byte
stream), a fresh `VddGovernor` walks the gc2t_np voltage ladder, and
every fixed rung replays the same windows under the SAME headroom
admission rule (an inadmissible window prices a fixed rung at +inf: a
pinned deployment would have dropped requests or lost data there —
see repro/runtime/governor.py). The governor must strictly beat every
fixed rung on TOTAL energy across all three scenarios.

Time is virtual (1 model step = 1 us) so measured KV read rates land
inside the gc2t_np f_max span and replays are deterministic.

Checks recorded (the PR's acceptance bar):
  * greedy_parity        — instrumented streams == plain streams
  * zero_extra_syncs     — instrumented host/admit sync counts == plain
  * governor_beats_fixed — governor total energy < every fixed rung's
  * measured_codesign    — measured windows flow through
                           Session.codesign_measured end to end
"""
from __future__ import annotations

import argparse
import json
import math
import os

LADDER = (0.5, 0.6, 0.7, 0.8, 0.9, 1.0, 1.1)
STEP_TIME_S = 1e-6


def _scenarios(smoke: bool):
    from repro.runtime import Phase, Scenario
    chat_cycles = 1 if smoke else 2
    chat = []
    for c in range(chat_cycles):
        chat += [Phase(f"burst{c}", 4, 24, 24, 7),
                 Phase(f"quiet{c}a", 1, 6, 8, 8),
                 Phase(f"quiet{c}b", 0, 0, 0, 8)]
    return [
        Scenario("chat_burst", tuple(chat)),
        Scenario("batch_offline", (Phase("fill", 8, 32, 28, 7),
                                   Phase("steady", 0, 0, 0, 7),
                                   Phase("drain", 0, 0, 0, 4),
                                   Phase("drain2", 0, 0, 0, 4))),
        Scenario("long_context", (Phase("admit", 2, 40, 20, 6),
                                  Phase("steady", 2, 40, 20, 6),
                                  Phase("tail", 1, 40, 12, 6))),
    ]


def _drain_counters(eng):
    """(host_syncs, admit_syncs) deltas work because engines are reused
    across scenarios: record absolutes, diff per scenario."""
    return eng.host_syncs, eng.admit_syncs


def collect(smoke: bool = False) -> dict:
    import dataclasses

    import jax
    from repro.api import Session
    from repro.api.queries import SweepQuery
    from repro.configs import get_config
    from repro.models.model import Model
    from repro.runtime import (GovernorPolicy, TelemetryCollector,
                               VddGovernor, replay_fixed, run_scenario,
                               traffic_from_window)
    from repro.serving import ServeEngine

    cfg = dataclasses.replace(get_config("llama3.2-1b").reduced(),
                              dtype="float32", n_layers=2, d_model=32,
                              n_heads=4, n_kv_heads=4, head_dim=8, d_ff=64)
    params = Model(cfg).init(jax.random.key(0))
    kw = dict(n_slots=4, window=64, mode="device", decode_chunk=4)
    plain = ServeEngine(cfg, params, **kw)
    col = TelemetryCollector(step_time_s=STEP_TIME_S)
    inst = ServeEngine(cfg, params, telemetry=col, **kw)

    scen_windows = {}
    parity = True
    zero_extra = True
    rid = 0
    for sc in _scenarios(smoke):
        p0, i0 = _drain_counters(plain), _drain_counters(inst)
        plain.done, inst.done = [], []
        run_scenario(plain, sc, seed=17, rid_base=rid)
        wins = run_scenario(inst, sc, seed=17, collector=col, rid_base=rid)
        rid += sum(ph.n_requests for ph in sc.phases)
        ps = {r.rid: list(r.out_tokens) for r in plain.done}
        ws = {r.rid: list(r.out_tokens) for r in inst.done}
        parity &= ps == ws and len(ps) > 0
        dp = tuple(a - b for a, b in zip(_drain_counters(plain), p0))
        di = tuple(a - b for a, b in zip(_drain_counters(inst), i0))
        zero_extra &= dp == di
        scen_windows[sc.name] = wins

    # the governed macro: one gc2t_np 64x64 config across the vdd ladder
    sess = Session()
    lat = sess.vdd_lattice(
        SweepQuery(cells=("gc2t_np",), word_sizes=(64,), num_words=(64,),
                   wwlls=(False,)), LADDER)
    policy = GovernorPolicy()
    traffics = {name: [traffic_from_window(w, cfg) for w in wins]
                for name, wins in scen_windows.items()}
    peak = max(t.read_hz for ts in traffics.values() for t in ts)
    n_banks = math.ceil(policy.headroom * peak / float(lat.f_max_hz[-1, 0]))

    per_scenario = {}
    gov_total = 0.0
    fixed_totals = {v: 0.0 for v in LADDER}
    for name, ts in traffics.items():
        gov = VddGovernor(lat, 0, n_banks, policy)
        for t in ts:
            gov.observe(t)
        fixed = {v: replay_fixed(lat, 0, n_banks, ts, vi, policy)
                 for vi, v in enumerate(LADDER)}
        gov_total += gov.total_energy_j
        for v in LADDER:
            fixed_totals[v] += fixed[v]
        adm_fixed = {v: e for v, e in fixed.items() if math.isfinite(e)}
        best_v, best_e = min(adm_fixed.items(), key=lambda kv: kv[1]) \
            if adm_fixed else (None, float("inf"))
        per_scenario[name] = {
            "windows": len(ts),
            "peak_read_hz": max(t.read_hz for t in ts),
            "rungs": [d.vdd_scale for d in gov.decisions],
            "switches": sum(d.switched for d in gov.decisions),
            "governor_j": gov.total_energy_j,
            "fixed_j": {str(v): (e if math.isfinite(e) else "inadmissible")
                        for v, e in fixed.items()},
            "best_fixed": {"vdd": best_v, "energy_j": best_e},
            "saved_vs_best_fixed":
                1.0 - gov.total_energy_j / best_e
                if math.isfinite(best_e) and best_e > 0 else None,
        }

    beats = all(gov_total < fixed_totals[v] for v in LADDER)
    finite_fixed = [e for e in fixed_totals.values() if math.isfinite(e)]
    best_fixed_total = min(finite_fixed) if finite_fixed else float("inf")

    # close the loop: measured windows -> CoDesignQuery -> report
    all_wins = [w for wins in scen_windows.values() for w in wins
                if w.decode_steps > 0]
    report = sess.codesign_measured(
        all_wins, cfg, sweep=SweepQuery(cells=("gc2t_np", "gc2t_nn")),
        vdd_scales=LADDER, step_time_s=STEP_TIME_S)
    codesign_ok = len(report.plans) == len(all_wins) and report.all_feasible

    return {
        "config": cfg.name,
        "smoke": smoke,
        "step_time_s": STEP_TIME_S,
        "vdd_ladder": list(LADDER),
        "n_banks": n_banks,
        "scenarios": per_scenario,
        "governor_total_j": gov_total,
        "fixed_totals_j": {str(v): (e if math.isfinite(e) else "inadmissible")
                           for v, e in fixed_totals.items()},
        "saved_vs_best_fixed_total":
            round(1.0 - gov_total / best_fixed_total, 4)
            if math.isfinite(best_fixed_total) else None,
        "codesign_workloads": len(report.plans),
        "checks": {
            "greedy_parity": parity,
            "zero_extra_syncs": zero_extra,
            "governor_beats_fixed": beats,
            "measured_codesign": codesign_ok,
        },
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one chat cycle for CI (all checks still apply)")
    ap.add_argument("--out", default="results/benchmarks")
    args = ap.parse_args()
    res = collect(smoke=args.smoke)
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "BENCH_runtime.json"), "w") as f:
        json.dump(res, f, indent=1)
    saved = res["saved_vs_best_fixed_total"]
    print(f"bench_runtime: {len(res['scenarios'])} scenarios, "
          f"{res['n_banks']} banks, governor {res['governor_total_j']:.3e} J"
          f" vs best fixed "
          f"{min(e for e in res['fixed_totals_j'].values() if isinstance(e, float)):.3e} J"
          f" ({saved:.1%} saved)" if saved is not None else
          "bench_runtime: no admissible fixed point")
    for name, s in res["scenarios"].items():
        print(f"  {name:>13}: rungs {s['rungs']} "
              f"({s['switches']} switches), gov {s['governor_j']:.3e} J, "
              f"best fixed vdd={s['best_fixed']['vdd']}")
    print(f"  checks: {res['checks']}")
    return 0 if all(res["checks"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
