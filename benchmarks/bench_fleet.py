"""Chaos benchmark: the compile fleet under injected faults.

    PYTHONPATH=src python benchmarks/bench_fleet.py [--smoke]

Writes results/benchmarks/bench_fleet.json. A mixed multi-tenant
workload (overlapping sweeps, matches and co-designs that share most of
their lattice evaluations, plus a few tenant-unique lattices) runs
three ways:

  1. **baseline** — one in-process fault-free `CompileService`: the
     reference responses.
  2. **chaos fleet** — N worker subprocesses over a fresh shared store
     with the deterministic fault harness armed (`repro.testing.faults`):
     one worker hard-killed mid-wave after its second artifact publish,
     the rest tearing writes, corrupting reads and failing evaluations,
     plus one poison request that fails on every attempt everywhere.
  3. **clean fleet** (full mode only) — the same fleet with no faults,
     as the control.

The checks gate CI on the fleet's whole contract: every real request's
response is BIT-IDENTICAL to the baseline despite the chaos, the poison
request is quarantined with a structured error after exactly
`max_attempts`, the chaos actually happened (a worker died, retries
fired), and the shared lease log proves ZERO duplicate lattice
evaluations — every node key was fresh-evaluated at most once across
all workers, with steals and heals reported separately as the
sanctioned recovery paths.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

SHAPE = "decode_32k"


def _workload(smoke: bool):
    """Request dicts for the JSON front door. Tenant sweeps overlap
    (prefixes of a shared num_words ladder) so leases have real
    cross-worker contention; the `unique` sweeps give each shard some
    work nobody else can publish for it."""
    nw = (16, 32, 64) if smoke else (16, 32, 64, 128)
    archs = ["qwen2-0.5b", "llama3.2-1b"] if smoke else \
        ["qwen2-0.5b", "llama3.2-1b", "llama3.2-3b", "minicpm-2b"]
    shared = {"cells": ["gc2t_nn", "gc2t_osos"], "word_sizes": [16, 32],
              "num_words": list(nw)}
    reqs = []
    n_tenants = 4 if smoke else 9
    for i in range(n_tenants):
        t = f"t{i}"
        reqs.append({"id": f"{t}-sweep", "tenant": t, "query": {
            "type": "sweep", "cells": shared["cells"],
            "word_sizes": shared["word_sizes"],
            "num_words": list(nw[:2 + i % max(1, len(nw) - 2)])}})
        reqs.append({"id": f"{t}-match", "tenant": t, "query": {
            "type": "match",
            "demands": [
                {"name": f"{t}-act", "level": "L1",
                 "read_freq_hz": 2.0e8 * (1 + i), "lifetime_s": 2.0e-6},
                {"name": f"{t}-kv", "level": "L2",
                 "read_freq_hz": 4.0e8 * (1 + i), "lifetime_s": 1.0e-3,
                 "capacity_bits": 1 << 20}],
            "sweep": shared}})
        reqs.append({"id": f"{t}-codesign", "tenant": t, "query": {
            "type": "codesign",
            "profiles": [{"arch": archs[i % len(archs)], "shape": SHAPE}],
            "vdd_scales": [0.85, 1.0], "sweep": shared}})
        # a lattice only this tenant asks for, at varying shard
        # positions — exercises publish-before-wait with no other
        # worker able to produce the artifact
        reqs.append({"id": f"{t}-unique", "tenant": t, "query": {
            "type": "sweep", "cells": ["gc2t_nn"], "word_sizes": [8],
            "num_words": [nw[i % len(nw)]], "write_vts": [None],
            "wwlls": [i % 2 == 1]}})
    reqs.append({"id": "POISON-req", "tenant": "chaos", "query": {
        "type": "sweep", "cells": ["gc2t_nn"], "word_sizes": [8],
        "num_words": [16]}})
    return reqs


def _normalize(resp: dict) -> str:
    """The bit-identity canon: id + ok + result, with transport
    bookkeeping (wave, attempts, worker timings) stripped."""
    return json.dumps({"id": resp.get("id"), "ok": resp.get("ok"),
                       "result": resp.get("result")},
                      sort_keys=True, default=str)


def _run_fleet(reqs, n_workers, max_attempts, fault_specs, smoke):
    from repro.api.leases import LeaseManager
    from repro.launch.fleet import Fleet

    spool = tempfile.mkdtemp(prefix="gcram-fleet-spool-")
    store = tempfile.mkdtemp(prefix="gcram-fleet-store-")
    t0 = time.time()
    with Fleet(spool, store, n_workers=n_workers,
               wave_size=max(8, len(reqs) // n_workers + 1),
               deadline_s=120.0 if smoke else 240.0,
               max_attempts=max_attempts, backoff_s=0.2,
               lease_ttl_s=2.0, fault_specs=fault_specs) as fleet:
        responses = fleet.run(reqs, timeout_s=300 if smoke else 900)
        stats = fleet.stats()
    wall = time.time() - t0
    log = LeaseManager.read_eval_log(store)
    fresh = {k: c.get("fresh", 0) for k, c in log.items()}
    return {"responses": responses, "stats": stats, "wall_s": wall,
            "fresh_counts": fresh,
            "duplicates": LeaseManager.duplicate_evals(store)}


def collect(smoke: bool = False) -> dict:
    from repro.launch.compile_service import CompileService

    reqs = _workload(smoke)
    real = [r for r in reqs if "POISON" not in r["id"]]
    n_workers = 2 if smoke else 3
    max_attempts = n_workers + 3

    # 1. baseline: fault-free in-process service, fresh session
    t0 = time.time()
    svc = CompileService(wave_size=len(real))
    lines = svc.serve_lines(json.dumps(r) for r in real)
    baseline = {r["id"]: r for r in map(json.loads, lines)}
    baseline_wall = time.time() - t0

    # 2. chaos fleet: one worker suicides mid-wave after its 2nd
    # publish; the rest tear writes, corrupt reads, fail and stall
    # evaluations; poison fails everywhere, every attempt
    chaos_faults = {"w0": "seed=7,salt=w0,die_after_puts=2,poison=POISON",
                    "inline": "poison=POISON"}
    for i in range(1, n_workers):
        chaos_faults[f"w{i}"] = (
            f"seed=7,salt=w{i},tear_rate=0.4,corrupt_rate=0.3,"
            f"eval_fail_rate=0.3,eval_slow_rate=0.3,slow_s=0.05,"
            f"poison=POISON")
    chaos = _run_fleet(reqs, n_workers, max_attempts, chaos_faults, smoke)

    by_id = {r["id"]: r for r in chaos["responses"]}
    poison = by_id["POISON-req"]
    real_identical = all(
        _normalize(by_id[r["id"]]) == _normalize(baseline[r["id"]])
        for r in real)

    checks = {
        "fleet_all_real_ok": all(by_id[r["id"]]["ok"] for r in real),
        "chaos_bit_identical_to_baseline": real_identical,
        "zero_duplicate_evals": chaos["duplicates"] == {},
        "poison_quarantined": (not poison["ok"]
                               and bool(poison.get("quarantined"))
                               and poison.get("attempts") == max_attempts),
        "worker_died_mid_wave":
            chaos["stats"].get("worker_deaths", 0) >= 1,
        "retries_fired": chaos["stats"].get("retries", 0) > 0,
    }
    out = {
        "n_requests": len(reqs), "n_workers": n_workers,
        "max_attempts": max_attempts,
        "baseline_wall_s": round(baseline_wall, 2),
        "chaos_wall_s": round(chaos["wall_s"], 2),
        "chaos_stats": {k: v for k, v in chaos["stats"].items()
                        if k != "workers"},
        "chaos_fresh_evals": sum(chaos["fresh_counts"].values()),
        "chaos_unique_keys": len(chaos["fresh_counts"]),
        "duplicates": chaos["duplicates"],
    }

    if not smoke:
        # 3. control: same fleet, no faults — every key evaluated fresh
        # exactly once, nothing stolen, nothing retried
        clean = _run_fleet(reqs, n_workers, max_attempts,
                           {"w0": "poison=POISON", "inline":
                            "poison=POISON"}, smoke)
        clean_by_id = {r["id"]: r for r in clean["responses"]}
        checks["clean_bit_identical_to_baseline"] = all(
            _normalize(clean_by_id[r["id"]]) == _normalize(
                baseline[r["id"]]) for r in real)
        checks["clean_single_fresh_eval_per_key"] = (
            clean["duplicates"] == {} and
            all(n <= 1 for n in clean["fresh_counts"].values()))
        checks["clean_no_steals"] = \
            clean["stats"]["evals"]["by_reason"].get("steal", 0) == 0
        out["clean_wall_s"] = round(clean["wall_s"], 2)
        out["clean_stats"] = {k: v for k, v in clean["stats"].items()
                              if k != "workers"}

    out["checks"] = checks
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small workload for CI")
    ap.add_argument("--out", default="results/benchmarks")
    args = ap.parse_args()
    res = collect(args.smoke)
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "bench_fleet.json"), "w") as f:
        json.dump(res, f, indent=1)
    s = res["chaos_stats"]
    print(f"bench_fleet: {res['n_requests']} requests  "
          f"{res['n_workers']} workers  baseline {res['baseline_wall_s']}s  "
          f"chaos {res['chaos_wall_s']}s  deaths {s.get('worker_deaths', 0)}  "
          f"retries {s.get('retries', 0)}  quarantined "
          f"{s.get('quarantined', 0)}  fresh evals "
          f"{res['chaos_fresh_evals']}/{res['chaos_unique_keys']} keys  "
          f"duplicates {res['duplicates']}")
    print("checks:", json.dumps(res["checks"]))
    return 0 if all(res["checks"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
