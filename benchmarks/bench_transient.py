"""Benchmark: fused sparse-Newton transient engines vs the PR 2 dense
batched baseline, plus the scalar-parity and Newton-parity contracts.

    PYTHONPATH=src python benchmarks/bench_transient.py [--repeats 1]
    PYTHONPATH=src python benchmarks/bench_transient.py --smoke   # CI

Three sections:

  engine   — one topology (gc2t_nn 32x32), B lanes with jittered ladder
             R/C and stop times, identical inputs into
             `Transient.run_lattice` per (solver, precision) mode:
             "jnp"/f64 (the PR 2 dense batched baseline), "pallas"/f64,
             "pallas"/mixed, "sparse"/f64. Reports warm wall time,
             speedup over the dense baseline, max trace deviation and
             t_cell relative deviation vs the dense reference.
  scalar   — whole-lattice `characterize` (default solver) vs the
             per-point `timing.simulate_read` loop; per-point t_cell
             must agree within 1% (the parity contract).
  newton   — analytic-stamp Newton trace vs the jacfwd Newton trace.

Checks recorded (the PR's acceptance bar):
  * engine_speedup_ge_5x — fused "pallas"/f64 >= 5x over the dense
                           batched baseline at B >= 64 (full mode only;
                           smoke batches are too small to time)
  * engine_parity_1pct   — every fused mode's t_cell within 1% of the
                           dense engine on the jittered batch
  * parity_within_1pct   — batched t_cell within 1% of scalar
                           simulate_read
  * newton_parity_1e-6   — analytic vs jacfwd trace gap <= 1e-6 (f64)

Writes results/bench_transient.json (machine-readable: speedups, parity,
solver modes — uploaded by CI) and mirrors it to
results/benchmarks/BENCH_transient.json for the benchmark index.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

ENGINE_MODES = (("jnp", "f64"), ("pallas", "f64"), ("pallas", "mixed"),
                ("sparse", "f64"))


def _lattice(smoke: bool):
    from repro.core.dse import lattice_configs
    if smoke:
        return lattice_configs(cells=("gc2t_nn", "gc2t_np"),
                               word_sizes=(16, 32), num_words=(16, 32),
                               wwlls=(False,))
    return lattice_configs(cells=("gc2t_nn", "gc2t_np", "gc2t_osos"),
                           word_sizes=(16, 32, 64),
                           num_words=(16, 32, 64, 128),
                           wwlls=(False, True))


def _best_of(fn, repeats: int):
    cold = None
    walls = []
    res = None
    for _ in range(repeats + 1):
        t0 = time.time()
        res = fn()
        walls.append(time.time() - t0)
        cold = cold if cold is not None else walls[0]
    return res, min(walls[1:]) if len(walls) > 1 else walls[0], cold


def _engine_inputs(B: int):
    """One topology's run_lattice inputs with per-lane jitter: the same
    assembly path as char_batch._characterize_group, but B independent
    lanes from a single netlist template (jittered ladder R/C and stop
    times stand in for a real parameter lattice)."""
    from repro.core import timing
    from repro.core.bank import BankConfig, build_bank
    bank = build_bank(BankConfig(32, 32, "gc2t_nn"))
    ckt, meta = timing.read_netlist(bank)
    res_stamps, cap_stamps, src_G = ckt.build_stamps()
    system = ckt.build()

    rng = np.random.default_rng(0)
    g_vals = np.asarray([g for _, _, g in ckt.res])
    c_vals = np.asarray([c for _, _, c in ckt.caps])
    g_b = g_vals[None] * (1.0 + 0.1 * rng.uniform(-1, 1, (B, len(g_vals))))
    c_b = c_vals[None] * (1.0 + 0.1 * rng.uniform(-1, 1, (B, len(c_vals))))
    G_b = src_G[None] + np.einsum("br,rij->bij", g_b, res_stamps)
    C_b = np.einsum("bc,cij->bij", c_b, cap_stamps)

    t_an, _ = timing.cell_read_time(bank)
    t_end1 = max(timing.T_END_OVER_ANALYTIC * t_an, timing.T_END_MIN_S)
    t_end = t_end1 * (1.0 + 0.1 * rng.uniform(-1, 1, B))
    t0 = timing.T0_FRACTION * t_end

    wt = wv = None
    v_pre = 0.0
    for p in range(B):
        waves_p, v_pre = timing.read_stimulus(bank.cell, bank.cfg.tech,
                                              meta["v_sn"], t0[p])
        if wt is None:
            k = max(len(t) for t, _ in waves_p)
            wt = np.zeros((B, len(waves_p), k))
            wv = np.zeros((B, len(waves_p), k))
        for w, (t, v) in enumerate(waves_p):
            wt[p, w] = t + [t[-1]] * (k - len(t))
            wv[p, w] = v + [v[-1]] * (k - len(v))
    return system, bank, dict(wt=wt, wv=wv, t_end=t_end, G_b=G_b, C_b=C_b,
                              v_pre=v_pre, t0=t0)


def _bench_engines(B: int, n_steps: int, repeats: int) -> dict:
    """Identical lattice inputs through every (solver, precision) engine."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from repro.core.spice.transient import Transient, crossing_time

    with enable_x64():
        system, bank, inp = _engine_inputs(B)
        v0 = jnp.full((system.n,), inp["v_pre"])

        def run(tr):
            res = tr.run_lattice(inp["wt"], inp["wv"], inp["t_end"],
                                 n_steps,
                                 over_batches={"G": inp["G_b"],
                                               "C": inp["C_b"]}, v0=v0)
            return {k: np.asarray(v) for k, v in res.items()}

        out = {}
        ref = None
        for solver, precision in ENGINE_MODES:
            tr = Transient(system, solver=solver, precision=precision)
            res, warm, cold = _best_of(lambda: run(tr), repeats)
            swing = bank.cfg.tech.v_sense_se
            target = inp["v_pre"] + (swing if bank.cell.predischarge
                                     else -swing)
            tc, valid = crossing_time(res["t"], res["rbl_near"], target,
                                      rising=bank.cell.predischarge)
            t_cell = np.where(np.asarray(valid),
                              np.asarray(tc) - inp["t0"], np.inf)
            entry = {"solver": solver, "precision": precision,
                     "warm_s": round(warm, 4), "cold_s": round(cold, 3)}
            if ref is None:
                ref = {"all": res["all"], "t_cell": t_cell, "warm": warm}
            else:
                trace_dev = float(np.max(np.abs(
                    res["all"].astype(np.float64) - ref["all"])))
                both = np.isfinite(t_cell) & np.isfinite(ref["t_cell"])
                tc_dev = float(np.max(
                    np.abs(t_cell[both] - ref["t_cell"][both])
                    / ref["t_cell"][both])) if both.any() else float("inf")
                if not np.array_equal(np.isfinite(t_cell),
                                      np.isfinite(ref["t_cell"])):
                    tc_dev = float("inf")
                entry.update(
                    speedup=round(ref["warm"] / max(warm, 1e-9), 1),
                    trace_dev=float(f"{trace_dev:.3g}"),
                    t_cell_rel_dev=float(f"{tc_dev:.3g}"))
            out[f"{solver}/{precision}"] = entry
    return out


def _newton_parity() -> float:
    """Max |trace| gap between analytic-stamp Newton and jacfwd Newton."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from repro.core import timing
    from repro.core.bank import BankConfig, build_bank
    from repro.core.spice.transient import Transient
    with enable_x64():
        bank = build_bank(BankConfig(32, 32, "gc2t_nn"))
        ckt, meta = timing.read_netlist(bank)
        sys = ckt.build()
        t_an, _ = timing.cell_read_time(bank)
        t_end = max(timing.T_END_OVER_ANALYTIC * t_an, timing.T_END_MIN_S)
        waves, v_pre = timing.read_stimulus(
            bank.cell, bank.cfg.tech, meta["v_sn"],
            timing.T0_FRACTION * t_end)
        v0 = jnp.full((sys.n,), v_pre)
        ref = Transient(sys, newton="jacfwd").run(waves, t_end,
                                                  n_steps=300, v0=v0)
        got = Transient(sys, newton="full", tol=1e-9).run(waves, t_end,
                                                          n_steps=300, v0=v0)
        return float(jnp.max(jnp.abs(ref["all"] - got["all"])))


def collect(repeats: int = 1, smoke: bool = False, n_steps: int = 300
            ) -> dict:
    from repro.core import timing
    from repro.core.bank import build_bank
    from repro.core.spice.char_batch import characterize

    # -- engine section: fused modes vs the PR 2 dense batched baseline
    B = 16 if smoke else 64
    engines = _bench_engines(B, n_steps, repeats)
    pallas = engines["pallas/f64"]
    engine_speedup = pallas.get("speedup", 0.0)
    engine_parity = max(e.get("t_cell_rel_dev", 0.0)
                        for e in engines.values())

    # -- scalar-parity section: batched characterize vs simulate_read
    cfgs = _lattice(smoke)
    batch, batch_s, batch_cold = _best_of(
        lambda: characterize(cfgs, n_steps=n_steps), repeats)
    ref, loop_s, loop_cold = _best_of(
        lambda: [timing.simulate_read(build_bank(c), n_steps=n_steps)[0]
                 for c in cfgs], repeats)

    worst = 0.0
    for ch, t_ref in zip(batch, ref):
        if np.isinf(t_ref) or np.isinf(ch.t_cell_s):
            if t_ref != ch.t_cell_s:
                worst = float("inf")
            continue
        worst = max(worst, abs(ch.t_cell_s - t_ref) / t_ref)

    newton_dev = _newton_parity()
    speedup = loop_s / max(batch_s, 1e-9)
    n_topologies = len({(c.cell, c.write_vt, c.wwlls) for c in cfgs})
    return {
        "engine_batch": B,
        "engines": engines,
        "engine_speedup": engine_speedup,
        "n_points": len(cfgs),
        "n_topologies": n_topologies,
        "n_steps": n_steps,
        "loop_wall_s": round(loop_s, 3),
        "batched_wall_s": round(batch_s, 3),
        "loop_cold_s": round(loop_cold, 3),
        "batched_cold_s": round(batch_cold, 3),
        "speedup": round(speedup, 1),
        "max_rel_dev_t_cell": float(f"{worst:.3g}"),
        "newton_trace_dev": float(f"{newton_dev:.3g}"),
        "checks": {
            "engine_speedup_ge_5x": engine_speedup >= 5.0,
            "engine_parity_1pct": engine_parity <= 0.01,
            "parity_within_1pct": worst <= 0.01,
            "newton_parity_1e-6": newton_dev <= 1e-6,
        },
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="small lattice for CI (skips the 5x bars)")
    ap.add_argument("--n-steps", type=int, default=300)
    ap.add_argument("--out", default="results")
    args = ap.parse_args()
    res = collect(args.repeats, smoke=args.smoke, n_steps=args.n_steps)
    os.makedirs(os.path.join(args.out, "benchmarks"), exist_ok=True)
    for path in (os.path.join(args.out, "bench_transient.json"),
                 os.path.join(args.out, "benchmarks",
                              "BENCH_transient.json")):
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
    eng = "  ".join(
        f"{k} {v['warm_s']}s" + (f" ({v['speedup']}x)" if "speedup" in v
                                 else "")
        for k, v in res["engines"].items())
    print(f"bench_transient: engines[B={res['engine_batch']}] {eng}")
    print(f"  lattice {res['n_points']} pts ({res['n_topologies']} topo)  "
          f"loop {res['loop_wall_s']}s  batched {res['batched_wall_s']}s  "
          f"({res['speedup']}x)  t_cell dev {res['max_rel_dev_t_cell']}  "
          f"newton dev {res['newton_trace_dev']}")
    checks = dict(res["checks"])
    if args.smoke:
        # tiny batches: wall-clock ratios are compile/dispatch noise
        checks.pop("engine_speedup_ge_5x")
        checks.pop("speedup_ge_5x", None)
    return 0 if all(checks.values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
