"""Micro-benchmark: whole-lattice batched transient characterization vs
the per-point `timing.simulate_read` loop, plus the analytic-vs-autodiff
Newton parity check.

    PYTHONPATH=src python benchmarks/bench_transient.py [--repeats 1]
    PYTHONPATH=src python benchmarks/bench_transient.py --smoke   # CI

Writes results/benchmarks/BENCH_transient.json. Each path runs
`repeats+1` times and the best post-warmup wall time is reported. The
batched pipeline amortizes one compiled program per cell topology
(memoized across calls); the scalar loop re-traces a fresh integrator
per point — which is exactly the cost the pipeline removes, so the warm
speedup is dominated by (points / topologies) * retrace cost.

Checks recorded (the PR's acceptance bar):
  * speedup_ge_5x        — batched >= 5x faster (warm) on a >= 64-point
                           lattice (full mode)
  * parity_within_1pct   — per-point t_cell within 1% of the scalar
                           simulate_read reference
  * newton_parity_1e-6   — analytic-Jacobian Newton trace matches the
                           jacfwd Newton trace to 1e-6 (float64)
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def _lattice(smoke: bool):
    from repro.core.dse import lattice_configs
    if smoke:
        return lattice_configs(cells=("gc2t_nn", "gc2t_np"),
                               word_sizes=(16, 32), num_words=(16, 32),
                               wwlls=(False,))
    return lattice_configs(cells=("gc2t_nn", "gc2t_np", "gc2t_osos"),
                           word_sizes=(16, 32, 64),
                           num_words=(16, 32, 64, 128),
                           wwlls=(False, True))


def _newton_parity() -> float:
    """Max |trace| gap between analytic-stamp Newton and jacfwd Newton."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from repro.core import timing
    from repro.core.bank import BankConfig, build_bank
    from repro.core.spice.transient import Transient
    with enable_x64():
        bank = build_bank(BankConfig(32, 32, "gc2t_nn"))
        ckt, meta = timing.read_netlist(bank)
        sys = ckt.build()
        t_an, _ = timing.cell_read_time(bank)
        t_end = max(timing.T_END_OVER_ANALYTIC * t_an, timing.T_END_MIN_S)
        waves, v_pre = timing.read_stimulus(
            bank.cell, bank.cfg.tech, meta["v_sn"],
            timing.T0_FRACTION * t_end)
        v0 = jnp.full((sys.n,), v_pre)
        ref = Transient(sys, newton="jacfwd").run(waves, t_end,
                                                  n_steps=300, v0=v0)
        got = Transient(sys, newton="full", tol=1e-9).run(waves, t_end,
                                                          n_steps=300, v0=v0)
        return float(jnp.max(jnp.abs(ref["all"] - got["all"])))


def collect(repeats: int = 1, smoke: bool = False, n_steps: int = 300
            ) -> dict:
    from repro.core import timing
    from repro.core.bank import build_bank
    from repro.core.spice.char_batch import characterize

    cfgs = _lattice(smoke)

    def best_of(fn):
        cold = None
        walls = []
        res = None
        for _ in range(repeats + 1):
            t0 = time.time()
            res = fn()
            walls.append(time.time() - t0)
            cold = cold if cold is not None else walls[0]
        return res, min(walls[1:]) if len(walls) > 1 else walls[0], cold

    batch, batch_s, batch_cold = best_of(
        lambda: characterize(cfgs, n_steps=n_steps))
    ref, loop_s, loop_cold = best_of(
        lambda: [timing.simulate_read(build_bank(c), n_steps=n_steps)[0]
                 for c in cfgs])

    worst = 0.0
    for ch, t_ref in zip(batch, ref):
        if np.isinf(t_ref) or np.isinf(ch.t_cell_s):
            if t_ref != ch.t_cell_s:
                worst = float("inf")
            continue
        worst = max(worst, abs(ch.t_cell_s - t_ref) / t_ref)

    newton_dev = _newton_parity()
    speedup = loop_s / max(batch_s, 1e-9)
    n_topologies = len({(c.cell, c.write_vt, c.wwlls) for c in cfgs})
    return {
        "n_points": len(cfgs),
        "n_topologies": n_topologies,
        "n_steps": n_steps,
        "loop_wall_s": round(loop_s, 3),
        "batched_wall_s": round(batch_s, 3),
        "loop_cold_s": round(loop_cold, 3),
        "batched_cold_s": round(batch_cold, 3),
        "speedup": round(speedup, 1),
        "max_rel_dev_t_cell": float(f"{worst:.3g}"),
        "newton_trace_dev": float(f"{newton_dev:.3g}"),
        "checks": {
            "speedup_ge_5x": speedup >= 5.0,
            "parity_within_1pct": worst <= 0.01,
            "newton_parity_1e-6": newton_dev <= 1e-6,
        },
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="small lattice for CI (skips the 64-point bar)")
    ap.add_argument("--n-steps", type=int, default=300)
    ap.add_argument("--out", default="results/benchmarks")
    args = ap.parse_args()
    res = collect(args.repeats, smoke=args.smoke, n_steps=args.n_steps)
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "BENCH_transient.json"), "w") as f:
        json.dump(res, f, indent=1)
    print(f"bench_transient: {res['n_points']} points "
          f"({res['n_topologies']} topologies)  "
          f"loop {res['loop_wall_s']}s  batched {res['batched_wall_s']}s  "
          f"speedup {res['speedup']}x  "
          f"t_cell dev {res['max_rel_dev_t_cell']}  "
          f"newton dev {res['newton_trace_dev']}")
    checks = dict(res["checks"])
    if args.smoke:
        checks.pop("speedup_ge_5x")   # tiny lattice: timing not meaningful
    return 0 if all(checks.values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
