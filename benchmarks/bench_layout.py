"""Benchmark: batched layout extraction vs the per-point geometry
reference, plus the analytic-vs-extracted fidelity scorecard.

    PYTHONPATH=src python benchmarks/bench_layout.py [--repeats 1]
    PYTHONPATH=src python benchmarks/bench_layout.py --smoke   # CI

Three sections:

  extract  — a design lattice (64 points full, 16 smoke) through BOTH
             extraction paths: the per-point reference (place + route +
             `extract_point` over routed geometry) and the closed-form
             struct-of-arrays `extract_lattice` (no geometry built).
             Reports wall times, speedup, and asserts every point
             BIT-identical between the two paths.
  scorecard— per gain-cell topology at 16x64: hand-modeled vs extracted
             read-column R/C, the analytic t_cell correction, and the
             TRANSIENT t_cell gap (characterize with parasitics=
             "modeled" vs "extracted", same solver/steps) — the number
             the layout tier exists to produce.
  verify   — full verify_bank (DRC + LVS-lite + bit-parity) over the
             scorecard configs; everything must come back clean.

Checks recorded (the PR's acceptance bar):
  * extract_bit_identical   — batched == per-point on every lattice point
  * transient_gap_le_10pct  — extracted-parasitic transient t_cell
                              within 10% of the hand-modeled ladder
  * geometry_all_clean      — DRC clean + LVS ok on every verified bank

Writes results/bench_layout.json (uploaded by CI) and mirrors it to
results/benchmarks/BENCH_layout.json for the benchmark index.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def _lattice(smoke: bool):
    from repro.core.dse import lattice_configs
    if smoke:
        return lattice_configs(cells=("gc2t_nn", "gc2t_osos"),
                               word_sizes=(8, 16), num_words=(32, 64),
                               wwlls=(False,))
    return lattice_configs(cells=("gc2t_nn", "gc2t_np", "gc2t_osos",
                                  "gc3t"),
                           word_sizes=(8, 16, 32, 64),
                           num_words=(32, 64),
                           wwlls=(False, True))


def _bench_extract(cfgs, repeats: int) -> dict:
    from repro.core.bank import build_bank
    from repro.geom import extract_lattice, extract_point, place_bank, \
        route_bank

    banks = [build_bank(c) for c in cfgs]

    def point_path():
        return [extract_point(route_bank(place_bank(b))) for b in banks]

    def lattice_path():
        return extract_lattice(banks)

    walls_p, walls_l = [], []
    points = lat = None
    for _ in range(repeats + 1):
        t0 = time.time()
        points = point_path()
        walls_p.append(time.time() - t0)
        t0 = time.time()
        lat = lattice_path()
        walls_l.append(time.time() - t0)
    wall_p = min(walls_p[1:]) if len(walls_p) > 1 else walls_p[0]
    wall_l = min(walls_l[1:]) if len(walls_l) > 1 else walls_l[0]

    mismatches = sum(
        1 for i, pt in enumerate(points)
        if any(v != float(lat[k][i]) for k, v in pt.items()))
    return {
        "n_points": len(cfgs),
        "point_wall_s": round(wall_p, 4),
        "lattice_wall_s": round(wall_l, 5),
        "speedup": round(wall_p / max(wall_l, 1e-9), 1),
        "bit_mismatches": mismatches,
    }


def _scorecard(n_steps: int) -> list:
    from repro.core import bank as bank_mod
    from repro.core import timing
    from repro.core.bank import BankConfig, build_bank
    from repro.core.spice.char_batch import characterize
    from repro.geom import extract as gx

    cfgs = [BankConfig(16, 64, cell=c)
            for c in ("gc2t_nn", "gc2t_np", "gc2t_osos", "gc3t",
                      "gc2t_hyb")]
    modeled = characterize(cfgs, n_steps=n_steps)
    extracted = characterize(cfgs, n_steps=n_steps,
                             parasitics="extracted")
    rows = []
    for cfg, cm, ce in zip(cfgs, modeled, extracted):
        bank = build_bank(cfg)
        rc = gx.read_column_rc(bank)
        r_hand, c_hand = bank_mod.bitline_rc(bank)
        t_hand = timing.cell_read_time(bank)[0]
        t_ext = timing.cell_read_time(
            bank, rc=(rc["bl_r_ohm"], rc["bl_c_f"]))[0]
        gap = abs(ce.t_cell_s - cm.t_cell_s) / cm.t_cell_s
        rows.append({
            "cell": cfg.cell, "rows": bank.rows,
            "bl_r_ratio": round(rc["bl_r_ohm"] / r_hand, 3),
            "bl_c_ratio": round(rc["bl_c_f"] / c_hand, 3),
            "bl_length_nm": round(rc["bl_length_nm"], 1),
            "n_vias": int(rc["n_vias"]),
            "t_cell_analytic_modeled_s": float(f"{t_hand:.4g}"),
            "t_cell_analytic_extracted_s": float(f"{t_ext:.4g}"),
            "analytic_correction": round((t_ext - t_hand) / t_hand, 4),
            "t_cell_sim_modeled_s": float(f"{cm.t_cell_s:.4g}"),
            "t_cell_sim_extracted_s": float(f"{ce.t_cell_s:.4g}"),
            "transient_gap": round(gap, 4),
            "swing_ok": bool(cm.swing_ok and ce.swing_ok),
        })
    return rows


def _verify(rows) -> dict:
    from repro.core.bank import BankConfig
    from repro.geom import verify_bank

    reports = [verify_bank(BankConfig(16, 64, cell=r["cell"]))
               for r in rows]
    return {
        "n_verified": len(reports),
        "n_drc_clean": sum(r["drc_clean"] for r in reports),
        "n_lvs_ok": sum(r["lvs_ok"] for r in reports),
        "n_bit_identical": sum(r["extract_bit_identical"]
                               for r in reports),
        "all_clean": all(r["drc_clean"] and r["lvs_ok"]
                         and r["extract_bit_identical"]
                         for r in reports),
    }


def collect(repeats: int = 1, smoke: bool = False, n_steps: int = 300
            ) -> dict:
    cfgs = _lattice(smoke)
    extract = _bench_extract(cfgs, repeats)
    scorecard = _scorecard(n_steps)
    verify = _verify(scorecard)
    worst_gap = max(r["transient_gap"] for r in scorecard)
    return {
        "extract": extract,
        "scorecard": scorecard,
        "verify": verify,
        "n_steps": n_steps,
        "worst_transient_gap": worst_gap,
        "checks": {
            "extract_bit_identical": extract["bit_mismatches"] == 0,
            "transient_gap_le_10pct": worst_gap <= 0.10,
            "geometry_all_clean": verify["all_clean"],
        },
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="small lattice for CI")
    ap.add_argument("--n-steps", type=int, default=300)
    ap.add_argument("--out", default="results")
    args = ap.parse_args()
    res = collect(args.repeats, smoke=args.smoke, n_steps=args.n_steps)
    os.makedirs(os.path.join(args.out, "benchmarks"), exist_ok=True)
    for path in (os.path.join(args.out, "bench_layout.json"),
                 os.path.join(args.out, "benchmarks",
                              "BENCH_layout.json")):
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
    ex = res["extract"]
    print(f"bench_layout: extraction {ex['n_points']} pts  "
          f"geometry {ex['point_wall_s']}s  batched {ex['lattice_wall_s']}s "
          f"({ex['speedup']}x)  bit mismatches {ex['bit_mismatches']}")
    for r in res["scorecard"]:
        print(f"  {r['cell']:10s} R x{r['bl_r_ratio']:<5} "
              f"C x{r['bl_c_ratio']:<5} analytic {r['analytic_correction']:+.1%}"
              f"  transient gap {r['transient_gap']:.2%}")
    print(f"  verify: {res['verify']}  worst transient gap "
          f"{res['worst_transient_gap']:.2%}")
    return 0 if all(res["checks"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
