"""Micro-benchmark: batched (vmapped) lattice sweep vs the per-point
Python loop, with parity checks against the scalar reference.

    PYTHONPATH=src python benchmarks/bench_sweep.py [--repeats 2]

Writes results/benchmarks/bench_sweep.json. Each path is run `repeats+1`
times and the best post-warmup wall time is reported, so the number
measures steady-state evaluation (JAX op compilation amortizes across a
session; the cold-start cost is reported separately as *_cold_s).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

_FIELDS = ("area_um2", "f_max_hz", "read_bw_bps", "write_bw_bps",
           "eff_bw_bps", "leakage_w", "refresh_w", "retention_s",
           "t_read_s", "t_write_s")


def _max_rel_dev(batch, ref):
    worst = 0.0
    for p, r in zip(batch, ref):
        if p.swing_ok != r.swing_ok:
            return float("inf")
        for f in _FIELDS:
            a, b = getattr(p, f), getattr(r, f)
            if np.isinf(b) or np.isinf(a):
                if a != b:
                    return float("inf")
                continue
            worst = max(worst, abs(a - b) / max(abs(b), 1e-30))
    return worst


def collect(repeats: int = 2) -> dict:
    from repro.api import Session
    from repro.api.queries import SweepQuery
    from repro.core import dse
    from repro.core.dse_batch import evaluate_batch

    cfgs = SweepQuery().configs(Session().tech)

    def best_of(fn):
        cold = None
        walls = []
        for _ in range(repeats + 1):
            t0 = time.time()
            res = fn()
            walls.append(time.time() - t0)
            cold = cold if cold is not None else walls[0]
        return res, min(walls[1:]) if len(walls) > 1 else walls[0], cold

    batch, batch_s, batch_cold = best_of(lambda: evaluate_batch(cfgs))
    ref, loop_s, loop_cold = best_of(
        lambda: [dse.evaluate(c) for c in cfgs])
    dev = _max_rel_dev(batch, ref)
    speedup = loop_s / max(batch_s, 1e-9)
    return {
        "n_points": len(cfgs),
        "loop_wall_s": round(loop_s, 3),
        "batched_wall_s": round(batch_s, 3),
        "loop_cold_s": round(loop_cold, 3),
        "batched_cold_s": round(batch_cold, 3),
        "speedup": round(speedup, 1),
        "max_rel_dev": float(f"{dev:.3g}"),
        "checks": {"speedup_ge_3x": speedup >= 3.0,
                   "parity_within_1e-6": dev <= 1e-6},
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--out", default="results/benchmarks")
    args = ap.parse_args()
    res = collect(args.repeats)
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "bench_sweep.json"), "w") as f:
        json.dump(res, f, indent=1)
    print(f"bench_sweep: {res['n_points']} points  "
          f"loop {res['loop_wall_s']}s  batched {res['batched_wall_s']}s  "
          f"speedup {res['speedup']}x  max_rel_dev {res['max_rel_dev']}")
    return 0 if all(res["checks"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
