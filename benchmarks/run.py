"""Benchmark orchestrator: one entry per paper table/figure + beyond-paper.

    PYTHONPATH=src python -m benchmarks.run [--only fig8_retention]

Prints `name,wall_s,checks_passed,detail` CSV lines and writes full JSON
to results/benchmarks/<name>.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> int:
    from benchmarks.figures import ALL
    ap = argparse.ArgumentParser()
    ap.add_argument("--only")
    ap.add_argument("--out", default="results/benchmarks")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    names = [args.only] if args.only else list(ALL)
    print("name,wall_s,checks_passed,detail")
    n_fail = 0
    for name in names:
        t0 = time.time()
        try:
            res = ALL[name]()
            checks = res.get("checks", {})
            ok = all(checks.values())
            bad = [k for k, v in checks.items() if not v]
            if not ok:
                n_fail += 1
            with open(os.path.join(args.out, f"{name}.json"), "w") as f:
                json.dump(res, f, indent=1, default=str)
            print(f"{name},{time.time()-t0:.2f},"
                  f"{sum(checks.values())}/{len(checks)},"
                  f"{'OK' if ok else 'FAILED:' + ';'.join(bad)}")
        except Exception as e:  # pragma: no cover
            n_fail += 1
            print(f"{name},{time.time()-t0:.2f},0/0,ERROR:{e}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
