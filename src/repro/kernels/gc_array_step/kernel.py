"""Pallas TPU kernel: one implicit step of an R x C gain-cell bitcell
array with bitline-rail coupling (structured "fast-SPICE").

Why this exists (DESIGN.md §6): a 16 Kb array is ~10^4 nonlinear storage
nodes. A flat MNA solve is O((RC)^3); but the circuit GRAPH is special —
cells couple only through the bitline rails. Exploiting that structure:
per-cell pointwise-implicit Newton (VPU elementwise over the (R, bC)
tile) + per-column rail KCL via column-sum reductions, Gauss-Seidel
between the two. This is the TPU re-expression of hierarchical fast-SPICE
partitioning (the paper's HSPICE bottleneck for full-array disturb /
retention sweeps).

Tiling: grid over column blocks (columns are independent given their own
rail); each tile holds (R, bC) SN states + (bC,) rail states in VMEM.
R x bC x 4 B with R <= 512, bC = 128 -> 256 KiB: fits with headroom.
The device model (EKV) is inlined elementwise jnp — VPU-friendly
(softplus/exp), no MXU needed except the column reductions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.spice.mna import channel_current_raw

NEWTON = 3
GS_SWEEPS = 2

_PKEYS = ("vtw", "nw", "kpw", "lamw", "ww", "lw",
          "vtr", "nr", "kpr", "lamr", "wr", "lr",
          "c_sn", "c_bl", "g_bl", "v_bl_drv")


def _step_math(v_sn, v_bl, wwl, wbl, rwl, h, p):
    """Shared tile math (identical to ref.py on a full tile)."""
    def i_write(vs):
        return channel_current_raw(1.0, p["vtw"], p["nw"], p["kpw"],
                                   p["lamw"], p["ww"], p["lw"],
                                   wwl[:, None], vs, wbl[None, :])

    def i_read(vs, vb):
        return channel_current_raw(1.0, p["vtr"], p["nr"], p["kpr"],
                                   p["lamr"], p["wr"], p["lr"],
                                   vs, vb[None, :], rwl[:, None])

    v_sn_new, v_bl_new = v_sn, v_bl
    dv = 1e-4
    for _ in range(GS_SWEEPS):
        def res(vs):
            return p["c_sn"] * (vs - v_sn) / h + i_write(vs)

        vs = v_sn_new
        for _ in range(NEWTON):
            r = res(vs)
            dr = (res(vs + dv) - r) / dv
            vs = vs - r / jnp.maximum(dr, 1e-18)
        v_sn_new = vs

        i_col = jnp.sum(i_read(v_sn_new, v_bl_new), axis=0)
        g_cells = (jnp.sum(i_read(v_sn_new, v_bl_new + dv), axis=0)
                   - i_col) / dv
        num = (p["c_bl"] / h) * v_bl + p["g_bl"] * p["v_bl_drv"] \
            - (i_col - g_cells * v_bl_new)
        den = p["c_bl"] / h + p["g_bl"] + g_cells
        v_bl_new = num / den
    return v_sn_new, v_bl_new


def _kernel(p_ref, vsn_ref, vbl_ref, wwl_ref, wbl_ref, rwl_ref, h_ref,
            out_sn_ref, out_bl_ref):
    p = {k: p_ref[i] for i, k in enumerate(_PKEYS)}
    v_sn = vsn_ref[...]
    v_bl = vbl_ref[...]
    sn, bl = _step_math(v_sn, v_bl, wwl_ref[...], wbl_ref[...], rwl_ref[...],
                        h_ref[0], p)
    out_sn_ref[...] = sn
    out_bl_ref[...] = bl


@functools.partial(jax.jit,
                   static_argnames=("block_c", "interpret"))
def gc_array_step(v_sn, v_bl, wwl, wbl, rwl, h, p, *, block_c: int = 128,
                  interpret: bool = False):
    """See ref.gc_array_step_ref. Tiles over column blocks."""
    R, C = v_sn.shape
    bC = min(block_c, C)
    Cp = -(-C // bC) * bC
    pad_c = [(0, 0), (0, Cp - C)]
    v_sn_p = jnp.pad(v_sn, pad_c)
    v_bl_p = jnp.pad(v_bl, ((0, Cp - C),))
    wbl_p = jnp.pad(wbl, ((0, Cp - C),))
    pvec = jnp.stack([jnp.asarray(p[k], jnp.float32) for k in _PKEYS])
    harr = jnp.asarray([h], jnp.float32)

    out_sn, out_bl = pl.pallas_call(
        _kernel,
        grid=(Cp // bC,),
        in_specs=[
            pl.BlockSpec((len(_PKEYS),), lambda i: (0,)),
            pl.BlockSpec((R, bC), lambda i: (0, i)),
            pl.BlockSpec((bC,), lambda i: (i,)),
            pl.BlockSpec((R,), lambda i: (0,)),
            pl.BlockSpec((bC,), lambda i: (i,)),
            pl.BlockSpec((R,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((R, bC), lambda i: (0, i)),
            pl.BlockSpec((bC,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, Cp), jnp.float32),
            jax.ShapeDtypeStruct((Cp,), jnp.float32),
        ],
        interpret=interpret,
    )(pvec, v_sn_p, v_bl_p, wwl, wbl_p, rwl, harr)
    return out_sn[:, :C], out_bl[:C]
