"""Public wrappers for the array-step kernel + convenience param packing."""
from __future__ import annotations

import jax

from repro.core.cells import CELLS, Bitcell
from repro.core.techfile import TechFile, SYN40
from repro.kernels.gc_array_step.kernel import gc_array_step as _kernel
from repro.kernels.gc_array_step.ref import gc_array_step_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def cell_params(cell_name: str = "gc2t_nn", tech: TechFile = SYN40,
                c_bl: float = 20e-15, g_bl: float = 1e-4,
                v_bl_drv: float = 0.0) -> dict:
    cell: Bitcell = CELLS[cell_name]
    wf, rf = cell.wf(tech), cell.rf(tech)
    return {
        "vtw": wf.vt0, "nw": wf.n_slope, "kpw": wf.k_prime,
        "lamw": wf.lambda_, "ww": cell.w_write, "lw": cell.l_write,
        "vtr": rf.vt0, "nr": rf.n_slope, "kpr": rf.k_prime,
        "lamr": rf.lambda_, "wr": cell.w_read, "lr": cell.l_read,
        "c_sn": cell.sn_cap(tech), "c_bl": c_bl, "g_bl": g_bl,
        "v_bl_drv": v_bl_drv,
    }


def gc_array_step(v_sn, v_bl, wwl, wbl, rwl, h, p, block_c: int = 128):
    return _kernel(v_sn, v_bl, wwl, wbl, rwl, h, p,
                   block_c=block_c, interpret=_interpret())
