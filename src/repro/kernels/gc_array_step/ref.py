"""Pure-jnp oracle for the structured bitcell-array implicit step."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.spice.mna import channel_current_raw

NEWTON = 3
GS_SWEEPS = 2


def gc_array_step_ref(v_sn, v_bl, wwl, wbl, rwl, h, p):
    """One backward-Euler step of an R x C gain-cell array.

    v_sn: (R, C) storage nodes;  v_bl: (C,) read bitlines
    wwl:  (R,) write wordline voltages;  wbl: (C,) write bitlines
    rwl:  (R,) read wordline voltages (source terminal of read devices)
    h: timestep; p: dict of scalars {vtw, nw, kpw, lamw, ww, lw,
       vtr, nr, kpr, lamr, wr, lr, c_sn, c_bl, g_bl} (g_bl: BL driver
       conductance to its target v_bl_drv).

    Returns (v_sn', v_bl'). Cells couple ONLY through the bitline rails:
    per-cell pointwise-implicit Newton for SN, column-sum KCL for rails,
    Gauss-Seidel between the two (the fast-SPICE partitioning).
    """
    R, C = v_sn.shape

    def i_write(vsn, row_wwl, col_wbl):
        # write device: gate=WWL, channel WBL <-> SN
        return channel_current_raw(1.0, p["vtw"], p["nw"], p["kpw"],
                                   p["lamw"], p["ww"], p["lw"],
                                   row_wwl, vsn, col_wbl)

    def i_read(vsn, vbl, row_rwl):
        # read device: gate=SN, channel RBL <-> RWL
        return channel_current_raw(1.0, p["vtr"], p["nr"], p["kpr"],
                                   p["lamr"], p["wr"], p["lr"],
                                   vsn, vbl, row_rwl)

    v_sn_new, v_bl_new = v_sn, v_bl
    for _ in range(GS_SWEEPS):
        # --- per-cell implicit SN update (rails frozen) ---
        def res_sn(vs):
            return (p["c_sn"] * (vs - v_sn) / h
                    + i_write(vs, wwl[:, None], wbl[None, :]))

        vs = v_sn_new
        dv = 1e-4
        for _ in range(NEWTON):
            r = res_sn(vs)
            dr = (res_sn(vs + dv) - r) / dv
            vs = vs - r / jnp.maximum(dr, 1e-18)
        v_sn_new = vs

        # --- rail update: linearized KCL with column-summed currents ---
        i_cells = i_read(v_sn_new, v_bl_new[None, :], rwl[:, None])
        i_col = jnp.sum(i_cells, axis=0)              # (C,) leaving BL
        # conductance of cells wrt BL (numerical, for implicit rail)
        dv = 1e-3
        g_cells = (jnp.sum(i_read(v_sn_new, (v_bl_new + dv)[None, :],
                                  rwl[:, None]), axis=0) - i_col) / dv
        num = (p["c_bl"] / h) * v_bl + p["g_bl"] * p["v_bl_drv"] \
            - (i_col - g_cells * v_bl_new)
        den = p["c_bl"] / h + p["g_bl"] + g_cells
        v_bl_new = num / den
    return v_sn_new, v_bl_new
