"""Pure-jnp oracle for the batched MNA solve."""
import jax.numpy as jnp


def batched_solve_ref(J, r):
    """J: (B, N, N), r: (B, N) -> x with J @ x = r."""
    return jnp.linalg.solve(J, r[..., None])[..., 0]
