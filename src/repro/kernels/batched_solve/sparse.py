"""Fixed-pattern sparse-Newton engine: symbolic LU + the fused
re-stamp / factor / solve / update iteration body.

The MNA Newton system J dv = F(v) of one topology group has a FIXED
sparsity pattern across the whole design lattice (`MNASparsity`,
exported by core.spice.mna): the incidence stamps pin where G/C/device
conductances land, only the values vary per point. This module turns
that pattern into a compiled solver:

  * `lu_schedule` runs the SYMBOLIC factorization once on the host —
    natural pivot order (the gmin + C/h + G_BIG diagonal stamps make J
    strictly diagonally dominant, the same argument the dense
    Gauss-Jordan kernel relies on), fill-in positions appended after
    the pattern entries. The RBL-ladder netlists factor with zero fill.
  * `factor` / `solve_factored` replay that schedule numerically on
    (B, nnz) value vectors — every step is a static-index gather /
    fused-multiply / scatter over the batch axis, so the whole lattice
    factors as a handful of vectorized ops instead of B serial dense
    LAPACK calls on (n, n) matrices.
  * `make_newton_iter` builds the fused per-iteration body the Pallas
    kernel (kernel.sparse_newton) and the XLA fallback (`newton_solve`)
    BOTH trace: gather device terminal voltages, evaluate the channel
    model once for current + 3x3 stamps (`channel_current_and_grads`),
    scatter the nine entries onto the constant part of the pattern,
    factor, triangular-solve, apply the masked update. Interpret-mode
    parity tests hold the two in lockstep.

Precision policy (the mixed-precision contract, see
docs/fidelity-tiers.md): `compute_dtype` is the dtype of the residual
accumulation, Jacobian stamps and the factor/solve; `store_dtype` is
the dtype of the carried state. "mixed" = f32 storage with every
per-iteration accumulation in f64 — safe because Newton re-evaluates
the residual from the stored state each iteration (self-correcting),
while a pure-f32 solve through the cond(J)~1e6 MNA Jacobian is not.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spice.mna import (G_MIN, MNASparsity,
                                  channel_current_and_grads)

#: storage/compute dtypes per precision mode
PRECISIONS: Dict[str, tuple] = {
    "f64": (jnp.float64, jnp.float64),
    "mixed": (jnp.float32, jnp.float64),
    "f32": (jnp.float32, jnp.float32),
}

#: device parameter pack order (gg = gate-leak conductance ig*w/1.1 is
#: appended as the 8th row by `pack_params`)
PARAM_FIELDS = ("pol", "vt0", "n", "kp", "lam", "w", "l")
N_PARAMS = len(PARAM_FIELDS) + 1


# ---------------------------------------------------------------------------
# symbolic factorization
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class _Step:
    """Elimination step of pivot k: static index maps into the filled
    value vector."""
    k: int
    dpos: int                  # position of (k, k)
    colk: np.ndarray           # positions of (i, k), i in rows (L column)
    rowk: np.ndarray           # positions of (k, j), j in cols (U row)
    upd: np.ndarray            # (len(rows), len(cols)) positions of (i, j)
    rows: np.ndarray           # row indices i > k with (i, k) present
    cols: np.ndarray           # col indices j > k with (k, j) present


@dataclass(frozen=True)
class LUSchedule:
    """Host-side symbolic LU of one sparsity pattern. `nnz` counts the
    pattern entries, `nnz_f` includes fill-in appended after them (the
    numeric kernels zero-pad their value vectors to nnz_f)."""
    n: int
    nnz: int
    nnz_f: int
    steps: Tuple[_Step, ...]


def lu_schedule(sp: MNASparsity) -> LUSchedule:
    """Symbolic Gaussian elimination in natural order (unpivoted — J is
    strictly diagonally dominant, asserted against jnp.linalg.solve in
    tests). Deterministic: fill entries append in discovery order."""
    n = sp.n
    entries = [(int(i), int(j)) for i, j in zip(sp.rows, sp.cols)]
    patf = set(entries)
    for k in range(n):
        rows_k = [i for i in range(k + 1, n) if (i, k) in patf]
        cols_k = [j for j in range(k + 1, n) if (k, j) in patf]
        for i in rows_k:
            for j in cols_k:
                if (i, j) not in patf:
                    patf.add((i, j))
                    entries.append((i, j))
    pos = {e: p for p, e in enumerate(entries)}
    steps = []
    for k in range(n):
        rows_k = [i for i in range(k + 1, n) if (i, k) in patf]
        cols_k = [j for j in range(k + 1, n) if (k, j) in patf]
        steps.append(_Step(
            k=k, dpos=pos[(k, k)],
            colk=np.array([pos[(i, k)] for i in rows_k], np.int32),
            rowk=np.array([pos[(k, j)] for j in cols_k], np.int32),
            upd=np.array([[pos[(i, j)] for j in cols_k] for i in rows_k],
                         np.int32).reshape(len(rows_k), len(cols_k)),
            rows=np.array(rows_k, np.int32),
            cols=np.array(cols_k, np.int32)))
    return LUSchedule(n=n, nnz=sp.nnz, nnz_f=len(entries),
                      steps=tuple(steps))


# ---------------------------------------------------------------------------
# numeric kernels over (B, nnz) value vectors
# ---------------------------------------------------------------------------

def factor(sched: LUSchedule, vals):
    """In-pattern LU of (B, nnz_f) values (unrolled static schedule).
    L factors overwrite the (i, k) entries, U stays in place."""
    for st in sched.steps:
        if not len(st.rows):
            continue
        f = vals[:, st.colk] / vals[:, st.dpos][:, None]
        vals = vals.at[:, st.colk].set(f)
        if len(st.cols):
            vals = vals.at[:, st.upd].add(
                -f[:, :, None] * vals[:, st.rowk][:, None, :])
    return vals


def solve_factored(sched: LUSchedule, lu, r):
    """Forward + back substitution: lu (B, nnz_f), r (B, n) -> x."""
    y = r
    for st in sched.steps:
        if len(st.rows):
            y = y.at[:, st.rows].add(-lu[:, st.colk] * y[:, st.k:st.k + 1])
    x = y
    for st in reversed(sched.steps):
        s = x[:, st.k]
        if len(st.cols):
            s = s - jnp.sum(lu[:, st.rowk] * x[:, st.cols], axis=1)
        x = x.at[:, st.k].set(s / lu[:, st.dpos])
    return x


def factor_solve(sched: LUSchedule, vals, r):
    return solve_factored(sched, factor(sched, vals), r)


def coo_matvec(sp: MNASparsity, vals, v):
    """y = A @ v with A given as (B, nnz) pattern values, v (B, n)."""
    prod = vals[:, :sp.nnz] * v[:, sp.cols]
    return jnp.zeros_like(v).at[:, sp.rows].add(prod)


# ---------------------------------------------------------------------------
# the fused Newton iteration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class NewtonSpec:
    """Everything static the fused iteration needs: the pattern, its
    symbolic LU, the device terminal index maps and the precision
    policy. Built once per (topology, precision) by `build_spec`."""
    sp: MNASparsity
    sched: LUSchedule
    didx_g: np.ndarray
    didx_a: np.ndarray
    didx_b: np.ndarray
    precision: str = "f64"

    @property
    def n_dev(self) -> int:
        return len(self.didx_g)

    @property
    def dtypes(self) -> tuple:
        return PRECISIONS[self.precision]


def build_spec(system, sparsity: Optional[MNASparsity] = None,
               precision: str = "f64") -> NewtonSpec:
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r} "
                         f"({' | '.join(PRECISIONS)})")
    sp = sparsity if sparsity is not None \
        else MNASparsity.from_system(system)
    return NewtonSpec(sp, lu_schedule(sp), np.asarray(system.didx["g"]),
                      np.asarray(system.didx["a"]),
                      np.asarray(system.didx["b"]), precision)


def pack_params(dev: dict, B: int, dtype) -> jnp.ndarray:
    """Device parameter dict -> (B, N_PARAMS, n_dev) operand block
    (PARAM_FIELDS rows + the gate-leak conductance gg as the last row),
    broadcast over the batch. One array keeps the Pallas kernel's ref
    list flat."""
    n_dev = int(np.shape(dev["pol"])[-1])
    cols = [jnp.asarray(dev[k], dtype) for k in PARAM_FIELDS]
    cols.append(jnp.asarray(dev["ig"] * dev["w"] / 1.1, dtype))
    out = jnp.stack([jnp.broadcast_to(c, (B, n_dev)) for c in cols],
                    axis=1)
    return out


def make_newton_iter(spec: NewtonSpec, tol: float):
    """Returns iter_fn(j_const, rhs, params, v, done) -> (v, done): one
    fused re-stamp + factor + solve + masked-update step, shared by the
    XLA while_loop fallback and the Pallas kernel body.

      j_const  (B, nnz)   G + G_BIG + gmin + C/h pattern values
                          (constant across a timestep's iterations)
      rhs      (B, n)     (C/h) @ v_prev + Norton source injections
      params   (B, N_PARAMS, n_dev)  from `pack_params`
      v        (B, n)     state (store dtype)
      done     (B,)       per-lane convergence mask; converged lanes
                          freeze (bit-exact across backends/iteration
                          counts — what the interpret-vs-XLA parity
                          tests key on)
    """
    sdt, cdt = spec.dtypes
    sp, sched = spec.sp, spec.sched
    n_dev = spec.n_dev
    # ground (-1) terminal reads as v=0 via a padded gather; scatters
    # mask ground rows/entries out
    g_safe = np.where(spec.didx_g >= 0, spec.didx_g, sp.n)
    a_safe = np.where(spec.didx_a >= 0, spec.didx_a, sp.n)
    b_safe = np.where(spec.didx_b >= 0, spec.didx_b, sp.n)
    dev_ok = (spec.sp.dev_pos >= 0)                     # (9, n_dev)
    dev_safe = np.where(dev_ok, sp.dev_pos, 0).ravel()
    row_idx = {"a": spec.didx_a, "b": spec.didx_b, "g": spec.didx_g}
    row_ok = {k: (idx >= 0) for k, idx in row_idx.items()}
    row_safe = {k: np.where(ok, row_idx[k], 0)
                for k, ok in row_ok.items()}

    def iter_fn(j_const, rhs, params, v, done):
        B = v.shape[0]
        vc = v.astype(cdt)
        jc = j_const.astype(cdt)
        r = coo_matvec(sp, jc, vc) - rhs.astype(cdt)
        if n_dev:
            vpad = jnp.concatenate(
                [vc, jnp.zeros((B, 1), cdt)], axis=1)
            vg, va, vb = vpad[:, g_safe], vpad[:, a_safe], vpad[:, b_safe]
            p = params.astype(cdt)
            i_ab, di_dvg, di_dva, di_dvb = channel_current_and_grads(
                *(p[:, i] for i in range(len(PARAM_FIELDS))), vg, va, vb)
            gg = p[:, len(PARAM_FIELDS)]
            i_g = gg * (vg - 0.5 * (va + vb))
            cur = {"a": i_ab - 0.5 * i_g, "b": -i_ab - 0.5 * i_g,
                   "g": i_g}
            for kk in ("a", "b", "g"):
                r = r.at[:, row_safe[kk]].add(
                    jnp.where(row_ok[kk][None, :], cur[kk], 0.0))
            # the nine stamp entries, `device_jacobian` order
            jac9 = jnp.stack([
                di_dvg - 0.5 * gg, di_dva + 0.25 * gg, di_dvb + 0.25 * gg,
                -di_dvg - 0.5 * gg, -di_dva + 0.25 * gg,
                -di_dvb + 0.25 * gg,
                gg, -0.5 * gg, -0.5 * gg], axis=1)     # (B, 9, n_dev)
            jvals = jc.at[:, dev_safe].add(
                jnp.where(dev_ok.ravel()[None, :],
                          jac9.reshape(B, 9 * n_dev), 0.0))
        else:
            jvals = jc
        if sched.nnz_f > sched.nnz:   # zero-pad for fill-in entries
            jvals = jnp.concatenate(
                [jvals, jnp.zeros((B, sched.nnz_f - sched.nnz), cdt)],
                axis=1)
        dv = factor_solve(sched, jvals, r)
        conv = jnp.max(jnp.abs(dv), axis=1) < tol
        v_next = jnp.where(done[:, None], v, (vc - dv).astype(sdt))
        return v_next, done | conv

    return iter_fn


def newton_solve(spec: NewtonSpec, j_const, rhs, params, v0,
                 iters: int, tol: float):
    """XLA fallback: run the fused iteration under a while_loop with a
    whole-batch early exit (every lane frozen individually, the loop
    ends when all are). This is what `solver="sparse"` — and
    `solver="pallas"` on backends without a native Pallas lowering —
    executes."""
    it = make_newton_iter(spec, tol)

    def cond(state):
        _, done, i = state
        return (i < iters) & jnp.logical_not(jnp.all(done))

    def body(state):
        v, done, i = state
        v, done = it(j_const, rhs, params, v, done)
        return v, done, i + 1

    B = v0.shape[0]
    v, _, n_it = jax.lax.while_loop(
        cond, body, (v0, jnp.zeros((B,), bool), jnp.asarray(0)))
    return v, n_it


def j_constant(spec: NewtonSpec, gn, cn, h):
    """The iteration-constant pattern values G + gmin + C/h for a run:
    gn/cn (B, nnz) linear-element values (sources folded into gn),
    h (B,) per-point step size. Kept in the COMPUTE dtype: under the
    mixed contract only the carried state/traces drop to f32 — the
    Jacobian operands and the residual accumulation stay f64."""
    _, cdt = spec.dtypes
    j = gn + cn / h[:, None]
    return j.at[:, spec.sp.diag_pos].add(G_MIN).astype(cdt)
