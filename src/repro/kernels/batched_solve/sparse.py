"""Fixed-pattern sparse-Newton engine: symbolic LU + the fused
re-stamp / factor / solve / update iteration body.

The MNA Newton system J dv = F(v) of one topology group has a FIXED
sparsity pattern across the whole design lattice (`MNASparsity`,
exported by core.spice.mna): the incidence stamps pin where G/C/device
conductances land, only the values vary per point. This module turns
that pattern into a compiled solver:

  * `lu_schedule` runs the SYMBOLIC factorization once on the host —
    natural pivot order (the gmin + C/h + G_BIG diagonal stamps make J
    strictly diagonally dominant, the same argument the dense
    Gauss-Jordan kernel relies on), fill-in positions appended after
    the pattern entries. The RBL-ladder netlists factor with zero fill.
  * `factor` / `solve_factored` replay that schedule numerically on
    (B, nnz) value vectors — every step is a static-index gather /
    fused-multiply / scatter over the batch axis, so the whole lattice
    factors as a handful of vectorized ops instead of B serial dense
    LAPACK calls on (n, n) matrices.
  * `make_newton_iter` builds the fused per-iteration body the Pallas
    kernel (kernel.sparse_newton) and the XLA fallback (`newton_solve`)
    BOTH trace: gather device terminal voltages, evaluate the channel
    model once for current + 3x3 stamps (`channel_current_and_grads`),
    scatter the nine entries onto the constant part of the pattern,
    factor, triangular-solve, apply the masked update. Interpret-mode
    parity tests hold the two in lockstep.

Precision policy (the mixed-precision contract, see
docs/fidelity-tiers.md): `compute_dtype` is the dtype of the residual
accumulation, Jacobian stamps and the factor/solve; `store_dtype` is
the dtype of the carried state. "mixed" = f32 storage with every
per-iteration accumulation in f64 — safe because Newton re-evaluates
the residual from the stored state each iteration (self-correcting),
while a pure-f32 solve through the cond(J)~1e6 MNA Jacobian is not.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spice.mna import (G_MIN, MNASparsity,
                                  channel_current_and_grads,
                                  channel_current_raw)

#: storage/compute dtypes per precision mode
PRECISIONS: Dict[str, tuple] = {
    "f64": (jnp.float64, jnp.float64),
    "mixed": (jnp.float32, jnp.float64),
    "f32": (jnp.float32, jnp.float32),
}

#: device parameter pack order (gg = gate-leak conductance ig*w/1.1 is
#: appended as the 8th row by `pack_params`)
PARAM_FIELDS = ("pol", "vt0", "n", "kp", "lam", "w", "l")
N_PARAMS = len(PARAM_FIELDS) + 1


# ---------------------------------------------------------------------------
# symbolic factorization
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class _Step:
    """Elimination step of pivot k: static index maps into the filled
    value vector."""
    k: int
    dpos: int                  # position of (k, k)
    colk: np.ndarray           # positions of (i, k), i in rows (L column)
    rowk: np.ndarray           # positions of (k, j), j in cols (U row)
    upd: np.ndarray            # (len(rows), len(cols)) positions of (i, j)
    rows: np.ndarray           # row indices i > k with (i, k) present
    cols: np.ndarray           # col indices j > k with (k, j) present


@dataclass(frozen=True, eq=False)
class LUSchedule:
    """Host-side symbolic LU of one sparsity pattern. `nnz` counts the
    pattern entries, `nnz_f` includes fill-in appended after them (the
    numeric kernels zero-pad their value vectors to nnz_f). `entries` is
    the (nnz_f, 2) list of (row, col) coordinates in value-vector order
    — what `transpose_perm` maps to solve against J^T on the adjoint
    path. eq=False: identity hashing, so schedules key host-side caches
    directly."""
    n: int
    nnz: int
    nnz_f: int
    steps: Tuple[_Step, ...]
    entries: Optional[np.ndarray] = None


def lu_schedule(sp: MNASparsity) -> LUSchedule:
    """Symbolic Gaussian elimination in natural order (unpivoted — J is
    strictly diagonally dominant, asserted against jnp.linalg.solve in
    tests). Deterministic: fill entries append in discovery order."""
    n = sp.n
    entries = [(int(i), int(j)) for i, j in zip(sp.rows, sp.cols)]
    patf = set(entries)
    for k in range(n):
        rows_k = [i for i in range(k + 1, n) if (i, k) in patf]
        cols_k = [j for j in range(k + 1, n) if (k, j) in patf]
        for i in rows_k:
            for j in cols_k:
                if (i, j) not in patf:
                    patf.add((i, j))
                    entries.append((i, j))
    pos = {e: p for p, e in enumerate(entries)}
    steps = []
    for k in range(n):
        rows_k = [i for i in range(k + 1, n) if (i, k) in patf]
        cols_k = [j for j in range(k + 1, n) if (k, j) in patf]
        steps.append(_Step(
            k=k, dpos=pos[(k, k)],
            colk=np.array([pos[(i, k)] for i in rows_k], np.int32),
            rowk=np.array([pos[(k, j)] for j in cols_k], np.int32),
            upd=np.array([[pos[(i, j)] for j in cols_k] for i in rows_k],
                         np.int32).reshape(len(rows_k), len(cols_k)),
            rows=np.array(rows_k, np.int32),
            cols=np.array(cols_k, np.int32)))
    return LUSchedule(n=n, nnz=sp.nnz, nnz_f=len(entries),
                      steps=tuple(steps),
                      entries=np.array(entries, np.int32).reshape(-1, 2))


_TPERM_CACHE: Dict[int, tuple] = {}


def transpose_perm(sched: LUSchedule) -> np.ndarray:
    """Entry permutation mapping a (B, nnz_f) value vector of J onto the
    value vector of J^T over the SAME schedule: perm[p] = position of
    (j, i) for entry p = (i, j). Valid because MNA patterns are
    structurally symmetric (full 3x3 device blocks, symmetric linear
    stamps, symmetric ground removal), which elimination preserves — so
    `factor(sched, jvals[:, perm])` is a legitimate LU of J^T and one
    `solve_factored` yields the adjoint lam = J^-T vbar. Cached per
    schedule identity (schedules are built once per topology)."""
    got = _TPERM_CACHE.get(id(sched))
    if got is not None and got[0] is sched:
        return got[1]
    if sched.entries is None:
        raise ValueError("schedule lacks entry coordinates "
                         "(rebuild via lu_schedule)")
    pos = {(int(i), int(j)): p
           for p, (i, j) in enumerate(sched.entries)}
    perm = np.empty(sched.nnz_f, np.int32)
    for p, (i, j) in enumerate(sched.entries):
        q = pos.get((int(j), int(i)))
        if q is None:
            raise ValueError(
                f"sparsity pattern is not structurally symmetric at "
                f"({int(i)}, {int(j)}): transpose solve unavailable")
        perm[p] = q
    _TPERM_CACHE[id(sched)] = (sched, perm)
    return perm


# ---------------------------------------------------------------------------
# numeric kernels over (B, nnz) value vectors
# ---------------------------------------------------------------------------

def factor(sched: LUSchedule, vals):
    """In-pattern LU of (B, nnz_f) values (unrolled static schedule).
    L factors overwrite the (i, k) entries, U stays in place."""
    for st in sched.steps:
        if not len(st.rows):
            continue
        f = vals[:, st.colk] / vals[:, st.dpos][:, None]
        vals = vals.at[:, st.colk].set(f)
        if len(st.cols):
            vals = vals.at[:, st.upd].add(
                -f[:, :, None] * vals[:, st.rowk][:, None, :])
    return vals


def solve_factored(sched: LUSchedule, lu, r):
    """Forward + back substitution: lu (B, nnz_f), r (B, n) -> x."""
    y = r
    for st in sched.steps:
        if len(st.rows):
            y = y.at[:, st.rows].add(-lu[:, st.colk] * y[:, st.k:st.k + 1])
    x = y
    for st in reversed(sched.steps):
        s = x[:, st.k]
        if len(st.cols):
            s = s - jnp.sum(lu[:, st.rowk] * x[:, st.cols], axis=1)
        x = x.at[:, st.k].set(s / lu[:, st.dpos])
    return x


def factor_solve(sched: LUSchedule, vals, r):
    return solve_factored(sched, factor(sched, vals), r)


def coo_matvec(sp: MNASparsity, vals, v):
    """y = A @ v with A given as (B, nnz) pattern values, v (B, n)."""
    prod = vals[:, :sp.nnz] * v[:, sp.cols]
    return jnp.zeros_like(v).at[:, sp.rows].add(prod)


# ---------------------------------------------------------------------------
# the fused Newton iteration
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=False)
class NewtonSpec:
    """Everything static the fused iteration needs: the pattern, its
    symbolic LU, the device terminal index maps and the precision
    policy. Built once per (topology, precision) by `build_spec`.
    eq=False: identity hashing, so the spec is valid as a custom_vjp
    nondiff argument / cache key."""
    sp: MNASparsity
    sched: LUSchedule
    didx_g: np.ndarray
    didx_a: np.ndarray
    didx_b: np.ndarray
    precision: str = "f64"

    @property
    def n_dev(self) -> int:
        return len(self.didx_g)

    @property
    def dtypes(self) -> tuple:
        return PRECISIONS[self.precision]


def build_spec(system, sparsity: Optional[MNASparsity] = None,
               precision: str = "f64") -> NewtonSpec:
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r} "
                         f"({' | '.join(PRECISIONS)})")
    sp = sparsity if sparsity is not None \
        else MNASparsity.from_system(system)
    return NewtonSpec(sp, lu_schedule(sp), np.asarray(system.didx["g"]),
                      np.asarray(system.didx["a"]),
                      np.asarray(system.didx["b"]), precision)


def pack_params(dev: dict, B: int, dtype, overrides=None) -> jnp.ndarray:
    """Device parameter dict -> (B, N_PARAMS, n_dev) operand block
    (PARAM_FIELDS rows + the gate-leak conductance gg as the last row),
    broadcast over the batch. One array keeps the Pallas kernel's ref
    list flat.

    `overrides` maps PARAM_FIELDS names (plus "ig") to per-point values
    — scalar, (B, 1) or (B, n_dev), broadcastable over the batch — and
    is the per-lattice-point device-parameter hook the differentiable
    DSE path threads knobs (device widths, VT) through: gg is recomputed
    from the possibly-overridden w/ig so a width cotangent reaches the
    gate-leak row too."""
    n_dev = int(np.shape(dev["pol"])[-1])
    over = dict(overrides or {})
    bad = set(over) - set(PARAM_FIELDS) - {"ig"}
    if bad:
        raise ValueError(f"unknown device-param overrides {sorted(bad)} "
                         f"(allowed: {PARAM_FIELDS + ('ig',)})")

    def val(k):
        return jnp.asarray(over[k] if k in over else dev[k], dtype)

    cols = [val(k) for k in PARAM_FIELDS]
    cols.append(val("ig") * val("w") / 1.1)
    out = jnp.stack([jnp.broadcast_to(c, (B, n_dev)) for c in cols],
                    axis=1)
    return out


def make_newton_iter(spec: NewtonSpec, tol: float):
    """Returns iter_fn(j_const, rhs, params, v, done) -> (v, done): one
    fused re-stamp + factor + solve + masked-update step, shared by the
    XLA while_loop fallback and the Pallas kernel body.

      j_const  (B, nnz)   G + G_BIG + gmin + C/h pattern values
                          (constant across a timestep's iterations)
      rhs      (B, n)     (C/h) @ v_prev + Norton source injections
      params   (B, N_PARAMS, n_dev)  from `pack_params`
      v        (B, n)     state (store dtype)
      done     (B,)       per-lane convergence mask; converged lanes
                          freeze (bit-exact across backends/iteration
                          counts — what the interpret-vs-XLA parity
                          tests key on)
    """
    sdt, cdt = spec.dtypes
    sp, sched = spec.sp, spec.sched
    n_dev = spec.n_dev
    # ground (-1) terminal reads as v=0 via a padded gather; scatters
    # mask ground rows/entries out
    g_safe = np.where(spec.didx_g >= 0, spec.didx_g, sp.n)
    a_safe = np.where(spec.didx_a >= 0, spec.didx_a, sp.n)
    b_safe = np.where(spec.didx_b >= 0, spec.didx_b, sp.n)
    dev_ok = (spec.sp.dev_pos >= 0)                     # (9, n_dev)
    dev_safe = np.where(dev_ok, sp.dev_pos, 0).ravel()
    row_idx = {"a": spec.didx_a, "b": spec.didx_b, "g": spec.didx_g}
    row_ok = {k: (idx >= 0) for k, idx in row_idx.items()}
    row_safe = {k: np.where(ok, row_idx[k], 0)
                for k, ok in row_ok.items()}

    def iter_fn(j_const, rhs, params, v, done):
        B = v.shape[0]
        vc = v.astype(cdt)
        jc = j_const.astype(cdt)
        r = coo_matvec(sp, jc, vc) - rhs.astype(cdt)
        if n_dev:
            vpad = jnp.concatenate(
                [vc, jnp.zeros((B, 1), cdt)], axis=1)
            vg, va, vb = vpad[:, g_safe], vpad[:, a_safe], vpad[:, b_safe]
            p = params.astype(cdt)
            i_ab, di_dvg, di_dva, di_dvb = channel_current_and_grads(
                *(p[:, i] for i in range(len(PARAM_FIELDS))), vg, va, vb)
            gg = p[:, len(PARAM_FIELDS)]
            i_g = gg * (vg - 0.5 * (va + vb))
            cur = {"a": i_ab - 0.5 * i_g, "b": -i_ab - 0.5 * i_g,
                   "g": i_g}
            for kk in ("a", "b", "g"):
                r = r.at[:, row_safe[kk]].add(
                    jnp.where(row_ok[kk][None, :], cur[kk], 0.0))
            # the nine stamp entries, `device_jacobian` order
            jac9 = jnp.stack([
                di_dvg - 0.5 * gg, di_dva + 0.25 * gg, di_dvb + 0.25 * gg,
                -di_dvg - 0.5 * gg, -di_dva + 0.25 * gg,
                -di_dvb + 0.25 * gg,
                gg, -0.5 * gg, -0.5 * gg], axis=1)     # (B, 9, n_dev)
            jvals = jc.at[:, dev_safe].add(
                jnp.where(dev_ok.ravel()[None, :],
                          jac9.reshape(B, 9 * n_dev), 0.0))
        else:
            jvals = jc
        if sched.nnz_f > sched.nnz:   # zero-pad for fill-in entries
            jvals = jnp.concatenate(
                [jvals, jnp.zeros((B, sched.nnz_f - sched.nnz), cdt)],
                axis=1)
        dv = factor_solve(sched, jvals, r)
        conv = jnp.max(jnp.abs(dv), axis=1) < tol
        v_next = jnp.where(done[:, None], v, (vc - dv).astype(sdt))
        return v_next, done | conv

    return iter_fn


def newton_solve(spec: NewtonSpec, j_const, rhs, params, v0,
                 iters: int, tol: float):
    """XLA fallback: run the fused iteration under a while_loop with a
    whole-batch early exit (every lane frozen individually, the loop
    ends when all are). This is what `solver="sparse"` — and
    `solver="pallas"` on backends without a native Pallas lowering —
    executes."""
    it = make_newton_iter(spec, tol)

    def cond(state):
        _, done, i = state
        return (i < iters) & jnp.logical_not(jnp.all(done))

    def body(state):
        v, done, i = state
        v, done = it(j_const, rhs, params, v, done)
        return v, done, i + 1

    B = v0.shape[0]
    v, _, n_it = jax.lax.while_loop(
        cond, body, (v0, jnp.zeros((B,), bool), jnp.asarray(0)))
    return v, n_it


def _safe_maps(spec: NewtonSpec):
    """Ground-padded terminal gather indices + KCL scatter maps (host
    numpy, derived once per spec — identity-cached)."""
    got = _SAFE_MAPS_CACHE.get(id(spec))
    if got is not None and got[0] is spec:
        return got[1]
    sp = spec.sp
    g_safe = np.where(spec.didx_g >= 0, spec.didx_g, sp.n)
    a_safe = np.where(spec.didx_a >= 0, spec.didx_a, sp.n)
    b_safe = np.where(spec.didx_b >= 0, spec.didx_b, sp.n)
    row_idx = {"a": spec.didx_a, "b": spec.didx_b, "g": spec.didx_g}
    row_ok = {k: (idx >= 0) for k, idx in row_idx.items()}
    row_safe = {k: np.where(ok, row_idx[k], 0)
                for k, ok in row_ok.items()}
    maps = (g_safe, a_safe, b_safe, row_ok, row_safe)
    _SAFE_MAPS_CACHE[id(spec)] = (spec, maps)
    return maps


_SAFE_MAPS_CACHE: Dict[int, tuple] = {}


def sparse_residual(spec: NewtonSpec, j_const, rhs, params, v):
    """BE residual r(v) = J0 v - rhs + device KCL currents, whose root
    is the converged Newton state. Pure differentiable jnp (no freeze
    masks / loops): the implicit-function adjoint differentiates THIS,
    never the while_loop. Casts happen inside so jax.vjp hands back
    cotangents in the caller's input dtypes."""
    _, cdt = spec.dtypes
    sp = spec.sp
    vc = v.astype(cdt)
    r = coo_matvec(sp, j_const.astype(cdt), vc) - rhs.astype(cdt)
    if not spec.n_dev:
        return r
    g_safe, a_safe, b_safe, row_ok, row_safe = _safe_maps(spec)
    B = vc.shape[0]
    vpad = jnp.concatenate([vc, jnp.zeros((B, 1), cdt)], axis=1)
    vg, va, vb = vpad[:, g_safe], vpad[:, a_safe], vpad[:, b_safe]
    p = params.astype(cdt)
    i_ab = channel_current_raw(
        *(p[:, i] for i in range(len(PARAM_FIELDS))), vg, va, vb)
    gg = p[:, len(PARAM_FIELDS)]
    i_g = gg * (vg - 0.5 * (va + vb))
    cur = {"a": i_ab - 0.5 * i_g, "b": -i_ab - 0.5 * i_g, "g": i_g}
    for kk in ("a", "b", "g"):
        r = r.at[:, row_safe[kk]].add(
            jnp.where(row_ok[kk][None, :], cur[kk], 0.0))
    return r


def _jac_vals(spec: NewtonSpec, j_const, params, v):
    """Assemble the (B, nnz_f) Newton Jacobian values J(v) — constant
    part + device stamps at v, fill entries zero-padded. The adjoint
    path factors the transpose-permuted copy of exactly these values."""
    sdt, cdt = spec.dtypes
    sp, sched = spec.sp, spec.sched
    n_dev = spec.n_dev
    jc = j_const.astype(cdt)
    B = v.shape[0]
    if n_dev:
        g_safe, a_safe, b_safe, _, _ = _safe_maps(spec)
        vc = v.astype(cdt)
        vpad = jnp.concatenate([vc, jnp.zeros((B, 1), cdt)], axis=1)
        vg, va, vb = vpad[:, g_safe], vpad[:, a_safe], vpad[:, b_safe]
        p = params.astype(cdt)
        _, di_dvg, di_dva, di_dvb = channel_current_and_grads(
            *(p[:, i] for i in range(len(PARAM_FIELDS))), vg, va, vb)
        gg = p[:, len(PARAM_FIELDS)]
        dev_ok = (sp.dev_pos >= 0)
        dev_safe = np.where(dev_ok, sp.dev_pos, 0).ravel()
        jac9 = jnp.stack([
            di_dvg - 0.5 * gg, di_dva + 0.25 * gg, di_dvb + 0.25 * gg,
            -di_dvg - 0.5 * gg, -di_dva + 0.25 * gg, -di_dvb + 0.25 * gg,
            gg, -0.5 * gg, -0.5 * gg], axis=1)
        jc = jc.at[:, dev_safe].add(
            jnp.where(dev_ok.ravel()[None, :],
                      jac9.reshape(B, 9 * n_dev), 0.0))
    if sched.nnz_f > sched.nnz:
        jc = jnp.concatenate(
            [jc, jnp.zeros((B, sched.nnz_f - sched.nnz), jc.dtype)],
            axis=1)
    return jc


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def newton_solve_implicit(spec: NewtonSpec, iters: int, tol: float,
                          j_const, rhs, params, v0):
    """Differentiable sparse-Newton solve: the primal is the ordinary
    `newton_solve` while_loop; the backward pass is ONE transposed
    symbolic-LU solve at the root (implicit function theorem) —

        lam = J(v*)^-T vbar,   theta_bar = -(dF/dtheta)^T lam

    — via `transpose_perm` on the same schedule, so gradients cost one
    extra factor+solve instead of a differentiated unroll. The v0
    cotangent is zero: the root does not depend on the initial guess,
    making the VJP independent of iteration count past convergence."""
    v, _ = newton_solve(spec, j_const, rhs, params, v0, iters, tol)
    return v


def _nsi_fwd(spec, iters, tol, j_const, rhs, params, v0):
    v = newton_solve_implicit(spec, iters, tol, j_const, rhs, params, v0)
    return v, (j_const, rhs, params, v)


def _nsi_bwd(spec, iters, tol, res, v_bar):
    j_const, rhs, params, v_star = res
    _, cdt = spec.dtypes
    jvals = _jac_vals(spec, j_const, params, v_star)
    perm = transpose_perm(spec.sched)
    lam = factor_solve(spec.sched, jvals[:, perm], v_bar.astype(cdt))
    _, vjp_fn = jax.vjp(
        lambda jc, r_, p_: sparse_residual(spec, jc, r_, p_, v_star),
        j_const, rhs, params)
    jc_bar, rhs_bar, p_bar = vjp_fn(-lam)
    return jc_bar, rhs_bar, p_bar, jnp.zeros_like(v_star)


newton_solve_implicit.defvjp(_nsi_fwd, _nsi_bwd)


def j_constant(spec: NewtonSpec, gn, cn, h):
    """The iteration-constant pattern values G + gmin + C/h for a run:
    gn/cn (B, nnz) linear-element values (sources folded into gn),
    h (B,) per-point step size. Kept in the COMPUTE dtype: under the
    mixed contract only the carried state/traces drop to f32 — the
    Jacobian operands and the residual accumulation stay f64."""
    _, cdt = spec.dtypes
    j = gn + cn / h[:, None]
    return j.at[:, spec.sp.diag_pos].add(G_MIN).astype(cdt)
