"""Pallas kernel: the WHOLE Newton solve of one backward-Euler timestep
fused into a single kernel over the lattice batch axis.

One `pallas_call` program handles a tile of `block_b` lattice points and
runs the complete fixed-length Newton loop in registers/VMEM: gather the
device terminal voltages, evaluate the channel model once for current
AND 3x3 stamp partials (`channel_current_and_grads`), assemble the
rank-2-per-device Woodbury capacitance matrix, solve the (3 n_dev)^2
system in closed form, apply the masked update — no HBM round-trip
between Newton iterations, no (B, n, n) operand anywhere (the constant
part of the Jacobian enters only through its prefactored inverse, see
`newton.py`).

The kernel body calls the SAME traced iteration (`make_fused_iter`) as
the XLA while_loop fallback; per-lane freeze makes fixed-length
fori_loop (here) and early-exit while_loop (fallback) bit-identical, so
the CPU interpret-mode parity tests pin the kernel to the production
path exactly.

Dtype note: on TPU the kernel computes in the input dtype, and f64 is
not natively available — use precision="mixed"/"f32" specs there (the
mixed contract keeps carried state f32; see docs/fidelity-tiers.md).
On CPU (interpret mode) f64 runs fine, which is what the parity suite
exercises.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.batched_solve.newton import FusedSpec, make_fused_iter


def _newton_kernel(krhs_ref, v_ref, params_ref, ku_ref, sb_ref, kpa_ref,
                   kpg_ref, vout_ref, *, spec: FusedSpec, iters: int,
                   tol: float):
    it = make_fused_iter(spec, tol)
    pre = {"KU": ku_ref[...], "Sb": sb_ref[...],
           "KPa": kpa_ref[...], "KPg": kpg_ref[...]}
    krhs = krhs_ref[...]
    params = params_ref[...]
    v0 = v_ref[...]
    bB = v0.shape[0]

    def body(_, state):
        v, done = state
        return it(pre, krhs, params, v, done)

    v, _ = jax.lax.fori_loop(0, iters, body,
                             (v0, jnp.zeros((bB,), bool)))
    vout_ref[...] = v


@functools.partial(jax.jit, static_argnames=("spec", "iters", "tol",
                                             "block_b", "interpret"))
def fused_newton(spec: FusedSpec, pre, Krhs, params, v0, *,
                 iters: int, tol: float, block_b: int = 8,
                 interpret: bool = False):
    """One timestep's Newton solve through the Pallas kernel.

    pre: dict from `newton.precompute` (only KU/Sb/KPa/KPg enter the
    kernel; K/KCoh are per-step hoists handled by the caller).
    Krhs (B, n), params (B, N_PARAMS, n_dev), v0 (B, n) -> v (B, n).
    The batch pads to a multiple of block_b (edge lanes repeat lane 0,
    which is always a valid system)."""
    B, n = v0.shape
    n_dev, k = spec.n_dev, spec.k
    Bp = -(-B // block_b) * block_b

    def padb(x):
        if Bp == B:
            return x
        reps = jnp.broadcast_to(x[:1], (Bp - B,) + x.shape[1:])
        return jnp.concatenate([x, reps], axis=0)

    operands = [padb(Krhs), padb(v0), padb(params), padb(pre["KU"]),
                padb(pre["Sb"]), padb(pre["KPa"]), padb(pre["KPg"])]
    in_specs = [
        pl.BlockSpec((block_b, n), lambda i: (i, 0)),
        pl.BlockSpec((block_b, n), lambda i: (i, 0)),
        pl.BlockSpec((block_b,) + operands[2].shape[1:],
                     lambda i: (i, 0, 0)),
        pl.BlockSpec((block_b, n, k), lambda i: (i, 0, 0)),
        pl.BlockSpec((block_b, n_dev, 3, k), lambda i: (i, 0, 0, 0)),
        pl.BlockSpec((block_b, n, n_dev), lambda i: (i, 0, 0)),
        pl.BlockSpec((block_b, n, n_dev), lambda i: (i, 0, 0)),
    ]
    out = pl.pallas_call(
        functools.partial(_newton_kernel, spec=spec, iters=iters, tol=tol),
        grid=(Bp // block_b,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, n), v0.dtype),
        interpret=interpret,
    )(*operands)
    return out[:B]
