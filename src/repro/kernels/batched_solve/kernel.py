"""Pallas TPU kernel: batched dense Gauss-Jordan solve of MNA Newton
systems J x = r over a (B, N, N) batch.

Why this exists (DESIGN.md §6): the SPICE inner loop of the paper's
compiler is one small dense solve per Newton iteration per design point.
HSPICE runs them serially on CPU; on TPU the batch dimension maps onto
VPU lanes — hundreds of design-space corners solve in one fused kernel
with every operand resident in VMEM.

Algorithm: Gauss-Jordan WITHOUT pivoting — valid because the MNA Jacobian
carries gmin + C/h + G_BIG diagonal stamps (strictly dominant diagonal;
asserted in tests against jnp.linalg.solve). Jordan elimination (zeroing
the whole column each step) trades ~1.5x flops vs LU for a branch-free,
mask-only inner body — the right trade on the VPU where the (B, N) row
update is a single fused multiply-add wavefront.

Tiling: grid over batch tiles of bB systems; each block holds
(bB, Np, Np) + (bB, Np) in VMEM with Np padded to the 128-lane boundary
(identity rows in the pad region keep the math exact). VMEM footprint
bB*Np*(Np+1)*4 B — e.g. 8 x 128 x 129 x 4 = 528 KiB < 1 MiB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gauss_jordan_kernel(j_ref, r_ref, x_ref):
    J = j_ref[...].astype(jnp.float32)       # (bB, Np, Np)
    r = r_ref[...].astype(jnp.float32)       # (bB, Np)
    bB, Np, _ = J.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (Np,), 0)

    def body(k, carry):
        J, r = carry
        piv_row = jax.lax.dynamic_slice_in_dim(J, k, 1, axis=1)   # (bB,1,Np)
        piv_r = jax.lax.dynamic_slice_in_dim(r, k, 1, axis=1)     # (bB,1)
        piv = jax.lax.dynamic_slice_in_dim(piv_row, k, 1, axis=2) # (bB,1,1)
        inv = 1.0 / piv[:, :, 0]                                  # (bB,1)
        col = jax.lax.dynamic_slice_in_dim(J, k, 1, axis=2)[..., 0]  # (bB,Np)
        factor = col * inv                                        # (bB,Np)
        mask = (rows != k).astype(jnp.float32)                    # (Np,)
        factor = factor * mask[None, :]
        # rank-1 update: rows i != k across the whole column block
        J = J - factor[:, :, None] * piv_row
        r = r - factor * piv_r
        return J, r

    J, r = jax.lax.fori_loop(0, Np, body, (J, r))
    diag = jnp.diagonal(J, axis1=1, axis2=2)                      # (bB,Np)
    x_ref[...] = (r / diag).astype(x_ref.dtype)


def _pad_to(x, n, axis):
    """Zero-pad `x` to length `n` along `axis` (no-op when already
    there). Callers that need non-singular pad blocks add identity rows
    themselves — see `batched_solve`."""
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def batched_solve(J, r, *, block_b: int = 8, interpret: bool = False):
    """J: (B, N, N), r: (B, N) -> x: (B, N). fp32 compute.

    N is padded to a multiple of 128 (TPU lanes) with identity rows;
    B is padded to a multiple of block_b.
    """
    B, N = r.shape
    Np = max(128, -(-N // 128) * 128)
    Bp = -(-B // block_b) * block_b

    Jp = _pad_to(_pad_to(J, Np, 1), Np, 2)
    if Np > N:  # identity in the pad block keeps the system solvable
        eye = jnp.zeros((Np, Np), J.dtype).at[
            jnp.arange(N, Np), jnp.arange(N, Np)].set(1.0)
        Jp = Jp + eye[None]
    rp = _pad_to(r, Np, 1)
    Jp = _pad_to(Jp, Bp, 0)
    rp = _pad_to(rp, Bp, 0)
    if Bp > B:  # pad systems must stay non-singular
        eyeb = jnp.broadcast_to(jnp.eye(Np, dtype=J.dtype), (Bp - B, Np, Np))
        Jp = Jp.at[B:].set(eyeb)

    out = pl.pallas_call(
        _gauss_jordan_kernel,
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, Np, Np), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, Np), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, Np), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, Np), jnp.float32),
        interpret=interpret,
    )(Jp, rp)
    return out[:B, :N].astype(r.dtype)
