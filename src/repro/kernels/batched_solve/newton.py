"""Fused low-rank Newton engine: the per-iteration body behind
`solver="pallas"`.

The sparse-LU engine (`sparse.py`) already beats B serial dense solves
by replaying a symbolic factorization over the batch axis — but it still
refactors the full pattern every Newton iteration. This module goes one
step further using two structural facts of the batched transient runs:

  1. the timestep h = t_end / n_steps is CONSTANT per lattice point, so
     the linear part of the Jacobian J0 = G + C/h + gmin is constant
     across the whole run and can be factored ONCE per characterization
     (we keep K = J0^-1 explicitly — one (B, n, n) inverse per run, never
     per step);
  2. the only entries that change between iterations are the per-device
     3x3 conductance stamps, i.e. J = J0 + Um @ D @ Vm with Um/Vm
     CONSTANT 0/1 incidence matrices of the device terminals and D the
     block-diagonal (3 n_dev x 3 n_dev) matrix of channel partials — a
     rank 3*n_dev update.

The Newton step then collapses via the Woodbury identity

    dv = J^-1 F = t - KU @ (I + D S)^-1 D (Vm @ t),
    t  = K F = v - K rhs + (K Pa) i_ab + (K Pg) i_g

where S = Vm K Um, KU = K Um, K Pa / K Pg (terminal incidence columns of
K) are all hoisted out of the iteration, and K rhs is hoisted out to
once per TIMESTEP (K C/h and the K @ source-injection sequence are
per-run precomputes). Note K J0 = I kills the residual matvec entirely:
the iteration touches no (B, n, n) operand at all — just the channel
model on (B, n_dev) and a (3 n_dev)^2 solve. This is an inexact-Newton
scheme in the round-off sense only: the fixed point satisfies F(v) = 0
exactly regardless of the error in K, so parity with the dense reference
holds to integration tolerance (asserted at 1e-6 on whole traces).

D itself is rank-2 per device: D_d = s_a (x) d3 + s_g (x) gg*e_g with
s_a = (1,-1,0), s_g = (-1/2,-1/2,1) over KCL rows (a,b,g), d3 the
channel partials and e_g = (1,-1/2,-1/2) the gate-leak row — so (I+DS)
assembles from two outer products per device, no 3x3 stamps are ever
materialized.

The same traced body runs three ways: under `jax.lax.while_loop` with a
whole-batch early exit (the XLA fallback, production path on CPU), under
a fixed-length `fori_loop` inside the Pallas kernel (`fused.py`), and in
interpret mode for the CPU parity tests. Per-lane freeze (`done` mask)
makes all three bit-identical: a converged lane stops changing, so an
early-exited while_loop and a run-to-the-cap fori_loop agree exactly.

Precision policy (docs/fidelity-tiers.md): `store_dtype` is the dtype of
the carried state/traces, `compute_dtype` the dtype of the model
evaluation and the Woodbury solve. "mixed" = f32 storage, f64 compute —
safe because Newton re-evaluates the residual from the stored state each
iteration; "f32" is screening-only (cond(J0) ~ 1e6 amplifies solve
round-off into the traces).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spice.mna import (G_MIN, channel_current_and_grads,
                                  channel_current_raw)
from repro.kernels.batched_solve.sparse import (PARAM_FIELDS, PRECISIONS,
                                                pack_params)

__all__ = ["FusedSpec", "build_fused_spec", "precompute", "make_fused_iter",
           "newton_solve", "newton_solve_fixed", "pack_params",
           "residual", "fixed_point_adjoint"]

#: KCL row signs of the channel current (rows a, b, g)
S_A = np.array([1.0, -1.0, 0.0])
#: KCL row signs of the gate-leak current
S_G = np.array([-0.5, -0.5, 1.0])
#: gate-leak voltage row: i_g = gg * (vg - (va+vb)/2), columns (g, a, b)
E_G = np.array([1.0, -0.5, -0.5])


@dataclass(frozen=True, eq=False)
class FusedSpec:
    """Static structure of one topology group for the fused engine:
    terminal incidence matrices and gather maps (host numpy — they bake
    into the jitted programs / Pallas kernel as constants). eq=False:
    identity hashing, so the spec can be a jit static argument (specs
    are built once per topology group and cached)."""
    n: int
    n_dev: int
    um: np.ndarray          # (n, k) KCL row incidence, cols per device (a,b,g)
    vm: np.ndarray          # (k, n) terminal voltage rows, per device (g,a,b)
    pa: np.ndarray          # (n, n_dev) channel-current KCL incidence
    pg: np.ndarray          # (n, n_dev) gate-leak KCL incidence
    g_safe: np.ndarray      # terminal gather indices, ground -> n (pad row)
    a_safe: np.ndarray
    b_safe: np.ndarray
    precision: str = "f64"

    @property
    def k(self) -> int:
        return 3 * self.n_dev

    @property
    def dtypes(self) -> tuple:
        return PRECISIONS[self.precision]


def build_fused_spec(system, precision: str = "f64") -> FusedSpec:
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r} "
                         f"({' | '.join(PRECISIONS)})")
    n = system.n
    didx_g = np.asarray(system.didx["g"])
    didx_a = np.asarray(system.didx["a"])
    didx_b = np.asarray(system.didx["b"])
    n_dev = len(didx_g)
    k = 3 * n_dev
    um = np.zeros((n, k))
    vm = np.zeros((k, n))
    pa = np.zeros((n, n_dev))
    pg = np.zeros((n, n_dev))
    for d in range(n_dev):
        a, b, g = int(didx_a[d]), int(didx_b[d]), int(didx_g[d])
        if a >= 0:
            pa[a, d] += 1.0
            pg[a, d] -= 0.5
        if b >= 0:
            pa[b, d] -= 1.0
            pg[b, d] -= 0.5
        if g >= 0:
            pg[g, d] += 1.0
        for j, node in enumerate((a, b, g)):    # Um columns: rows of D
            if node >= 0:
                um[node, 3 * d + j] = 1.0
        for j, node in enumerate((g, a, b)):    # Vm rows: cols of D
            if node >= 0:
                vm[3 * d + j, node] = 1.0
    return FusedSpec(
        n=n, n_dev=n_dev, um=um, vm=vm, pa=pa, pg=pg,
        g_safe=np.where(didx_g >= 0, didx_g, n),
        a_safe=np.where(didx_a >= 0, didx_a, n),
        b_safe=np.where(didx_b >= 0, didx_b, n),
        precision=precision)


def precompute(spec: FusedSpec, G_b, C_b, h):
    """Per-run constants of the Woodbury iteration. G_b/C_b (B, n, n)
    dense linear stamps (built once per lattice), h (B,) per-point step.

    Returns a dict pytree: K (B,n,n) inverse of the constant Jacobian
    part, KU (B,n,k), Sb (B,n_dev,3,k) = Vm K Um in device blocks,
    KPa/KPg (B,n,n_dev) = K @ terminal incidence, KCoh (B,n,n) = K C / h
    (for the per-step rhs hoist K rhs = KCoh @ v_prev + K src)."""
    _, cdt = spec.dtypes
    n = spec.n
    G_b = jnp.asarray(G_b, cdt)
    C_b = jnp.asarray(C_b, cdt)
    h = jnp.asarray(h, cdt)
    J0 = G_b + C_b / h[:, None, None] + G_MIN * jnp.eye(n, dtype=cdt)
    K = jnp.linalg.inv(J0)
    KU = jnp.einsum("bij,jk->bik", K, jnp.asarray(spec.um, cdt))
    Sb = jnp.einsum("ki,bij->bkj", jnp.asarray(spec.vm, cdt), KU)
    if spec.n_dev:
        Sb = Sb.reshape(-1, spec.n_dev, 3, spec.k)
    return {
        "K": K,
        "KU": KU,
        "Sb": Sb,
        "KPa": jnp.einsum("bij,jd->bid", K, jnp.asarray(spec.pa, cdt)),
        "KPg": jnp.einsum("bij,jd->bid", K, jnp.asarray(spec.pg, cdt)),
        "KCoh": jnp.einsum("bij,bjk->bik", K, C_b) / h[:, None, None],
    }


def _inv3(M):
    """Closed-form batched 3x3 inverse (adjugate via cross products) —
    branch-free, no per-pivot unrolling."""
    r0 = jnp.cross(M[..., 1, :], M[..., 2, :])
    r1 = jnp.cross(M[..., 2, :], M[..., 0, :])
    r2 = jnp.cross(M[..., 0, :], M[..., 1, :])
    det = jnp.sum(M[..., 0, :] * r0, axis=-1)
    return jnp.stack([r0, r1, r2], axis=-1) / det[..., None, None]


def _solve_small(A, b, n_dev: int):
    """w = A^-1 b for the (B, k, k) Woodbury capacitance matrix
    A = I + D S. k = 3 n_dev is tiny; specialize the common shapes
    (closed-form 3x3 blocks) and fall back to unrolled unpivoted
    elimination for larger device counts (A is a small perturbation of
    the identity in the circuits this engine targets)."""
    if n_dev == 1:
        return jnp.einsum("bij,bj->bi", _inv3(A), b)
    if n_dev == 2:
        P, Q = A[:, :3, :3], A[:, :3, 3:]
        R, T = A[:, 3:, :3], A[:, 3:, 3:]
        Pi = _inv3(P)
        X = jnp.einsum("bij,bjk->bik", Pi, Q)
        y1 = jnp.einsum("bij,bj->bi", Pi, b[:, :3])
        x2 = jnp.einsum(
            "bij,bj->bi",
            _inv3(T - jnp.einsum("bij,bjk->bik", R, X)),
            b[:, 3:] - jnp.einsum("bij,bj->bi", R, y1))
        x1 = y1 - jnp.einsum("bij,bj->bi", X, x2)
        return jnp.concatenate([x1, x2], axis=1)
    k = 3 * n_dev
    for i in range(k):
        f = A[:, i + 1:, i] / A[:, i, i:i + 1]
        A = A.at[:, i + 1:, i:].add(-f[:, :, None] * A[:, i:i + 1, i:])
        b = b.at[:, i + 1:].add(-f * b[:, i:i + 1])
    x = jnp.zeros_like(b)
    for i in range(k - 1, -1, -1):
        s = b[:, i] - jnp.sum(A[:, i, i + 1:] * x[:, i + 1:], axis=1)
        x = x.at[:, i].set(s / A[:, i, i])
    return x


def make_fused_iter(spec: FusedSpec, tol: float):
    """Returns iter_fn(pre, Krhs, params, v, done) -> (v, done): one
    fused Woodbury-Newton step. `pre` from `precompute`, Krhs (B, n) the
    per-timestep hoist K @ rhs, params (B, N_PARAMS, n_dev) from
    `pack_params`, v (B, n) store-dtype state, done (B,) freeze mask.

    The body is deliberately CONSTANT-FREE: Pallas rejects kernels that
    capture array literals, so the terminal gathers unroll over the
    (static, tiny) device list instead of index arrays, the S_A/S_G/E_G
    sign vectors enter as python scalar coefficients in explicit row
    stacks, and the Woodbury identity comes from broadcasted_iota. The
    values are bit-compatible with the einsum formulation (the sign
    entries are exact binary fractions)."""
    sdt, cdt = spec.dtypes
    n_dev, k = spec.n_dev, spec.k
    # host-side static node indices per device terminal (-1 = ground)
    g_idx = [int(i) if i < spec.n else -1 for i in spec.g_safe]
    a_idx = [int(i) if i < spec.n else -1 for i in spec.a_safe]
    b_idx = [int(i) if i < spec.n else -1 for i in spec.b_safe]

    def gather(x, idx):
        """(B, n) -> (B, n_dev) terminal values; ground reads 0."""
        cols = [x[:, i] if i >= 0 else jnp.zeros_like(x[:, 0])
                for i in idx]
        return jnp.stack(cols, axis=1)

    def iter_fn(pre, Krhs, params, v, done):
        B = v.shape[0]
        vc = v.astype(cdt)
        if n_dev == 0:      # linear circuit: one exact solve
            dv = vc - Krhs.astype(cdt)
            v_next = jnp.where(done[:, None], v, (vc - dv).astype(sdt))
            return v_next, done | jnp.ones((B,), bool)
        vg = gather(vc, g_idx)
        va = gather(vc, a_idx)
        vb = gather(vc, b_idx)
        p = params.astype(cdt)
        i_ab, di_dvg, di_dva, di_dvb = channel_current_and_grads(
            *(p[:, i] for i in range(len(PARAM_FIELDS))), vg, va, vb)
        gg = p[:, len(PARAM_FIELDS)]
        i_g = gg * (vg - 0.5 * (va + vb))
        d3 = jnp.stack([di_dvg, di_dva, di_dvb], axis=2)  # (B, n_dev, 3)
        Sb = pre["Sb"].astype(cdt)
        t = (vc - Krhs.astype(cdt)
             + jnp.einsum("bid,bd->bi", pre["KPa"].astype(cdt), i_ab)
             + jnp.einsum("bid,bd->bi", pre["KPg"].astype(cdt), i_g))
        # Vm @ t rows are one-hot terminal picks (g, a, b) per device
        g3 = jnp.stack([gather(t, g_idx), gather(t, a_idx),
                        gather(t, b_idx)], axis=2)        # (B, n_dev, 3)
        # D = s_a (x) d3 + s_g (x) gg*e_g per device block (rank 2);
        # e_g = (1, -1/2, -1/2) over Sb's terminal axis (g, a, b)
        d3S = jnp.einsum("bdj,bdjk->bdk", d3, Sb)         # (B, n_dev, k)
        egS = (Sb[:, :, 0] - 0.5 * Sb[:, :, 1] - 0.5 * Sb[:, :, 2]) \
            * gg[:, :, None]
        # rows (a, b, g): s_a = (1, -1, 0), s_g = (-1/2, -1/2, 1)
        DS = jnp.stack([d3S - 0.5 * egS,
                        -d3S - 0.5 * egS,
                        egS], axis=2).reshape(B, k, k)
        rows = jax.lax.broadcasted_iota(jnp.int32, (k, k), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (k, k), 1)
        A = (rows == cols).astype(cdt)[None] + DS
        d3g = jnp.einsum("bdj,bdj->bd", d3, g3)
        egg = (g3[:, :, 0] - 0.5 * g3[:, :, 1] - 0.5 * g3[:, :, 2]) * gg
        b_k = jnp.stack([d3g - 0.5 * egg,
                         -d3g - 0.5 * egg,
                         egg], axis=2).reshape(B, k)
        w = _solve_small(A, b_k, n_dev)
        dv = t - jnp.einsum("bnk,bk->bn", pre["KU"].astype(cdt), w)
        conv = jnp.max(jnp.abs(dv), axis=1) < tol
        v_next = jnp.where(done[:, None], v, (vc - dv).astype(sdt))
        return v_next, done | conv

    return iter_fn


def newton_solve(spec: FusedSpec, pre, Krhs, params, v0,
                 iters: int, tol: float):
    """XLA fallback: fused iteration under a while_loop with whole-batch
    early exit. Per-lane freeze makes the result bit-identical to the
    fixed-length variant the Pallas kernel runs."""
    it = make_fused_iter(spec, tol)

    def cond(state):
        _, done, i = state
        return (i < iters) & jnp.logical_not(jnp.all(done))

    def body(state):
        v, done, i = state
        v, done = it(pre, Krhs, params, v, done)
        return v, done, i + 1

    B = v0.shape[0]
    v, _, n_it = jax.lax.while_loop(
        cond, body, (v0, jnp.zeros((B,), bool), jnp.asarray(0)))
    return v, n_it


def newton_solve_fixed(spec: FusedSpec, pre, Krhs, params, v0,
                       iters: int, tol: float):
    """Fixed-iteration variant (fori_loop, no early exit) — the exact
    control flow the Pallas kernel uses; parity tests run this against
    the kernel in interpret mode."""
    it = make_fused_iter(spec, tol)
    B = v0.shape[0]

    def body(_, state):
        v, done = state
        return it(pre, Krhs, params, v, done)

    v, _ = jax.lax.fori_loop(0, iters, body,
                             (v0, jnp.zeros((B,), bool)))
    return v


def _gather_safe(x, idx):
    """(B, n) -> (B, n_dev) terminal values via padded gather; ground
    terminals (index n) read the zero pad column. Index-array twin of
    `make_fused_iter`'s statically-unrolled gather — the adjoint path
    never runs inside Pallas, so dynamic gathers are fine here."""
    xp = jnp.concatenate([x, jnp.zeros_like(x[:, :1])], axis=1)
    return xp[:, idx]


def residual(spec: FusedSpec, pre, Krhs, params, v):
    """Preconditioned BE residual F(v) = v - K rhs + (K Pa) i_ab(v)
    + (K Pg) i_g(v), whose root is the converged Newton state (the
    iteration's update is dv = M^-1 F, so dv = 0 iff F = 0). Pure
    elementwise jnp with no freeze masks or loops: the implicit-function
    adjoint differentiates THIS function w.r.t. the data inputs, never
    the while_loop that located the root. Casts to compute dtype happen
    inside so `jax.vjp` hands back cotangents matching the caller's
    input dtypes (params stays in store dtype on the mixed path)."""
    _, cdt = spec.dtypes
    out = v.astype(cdt) - Krhs.astype(cdt)
    if spec.n_dev == 0:
        return out
    vc = v.astype(cdt)
    vg = _gather_safe(vc, spec.g_safe)
    va = _gather_safe(vc, spec.a_safe)
    vb = _gather_safe(vc, spec.b_safe)
    p = params.astype(cdt)
    i_ab = channel_current_raw(
        *(p[:, i] for i in range(len(PARAM_FIELDS))), vg, va, vb)
    gg = p[:, len(PARAM_FIELDS)]
    i_g = gg * (vg - 0.5 * (va + vb))
    return (out
            + jnp.einsum("bid,bd->bi", pre["KPa"].astype(cdt), i_ab)
            + jnp.einsum("bid,bd->bi", pre["KPg"].astype(cdt), i_g))


def fixed_point_adjoint(spec: FusedSpec, pre, Krhs, params, v_star, v_bar):
    """Implicit-function VJP through the converged Newton solve.

    At the fixed point F(v*, theta) = 0 (theta = the data inputs pre /
    Krhs / params), the implicit function theorem gives
    dv*/dtheta = -M^-1 dF/dtheta with M = dF/dv = I + KU D Vm — the
    SAME rank-k structure the forward iteration inverts. The adjoint
    lam = M^-T vbar therefore costs ONE extra Woodbury solve against the
    transposed capacitance matrix,

        M^-T = I - Vm^T D^T A^-T KU^T,        A = I + D S,

    where A is the identical (B, k, k) matrix `make_fused_iter` builds
    (assembled here at v*), and theta_bar = -(dF/dtheta)^T lam is one
    VJP of `residual` at the root. Returns (pre_bar, Krhs_bar,
    params_bar). The v0 cotangent is zero — the root does not depend on
    the initial guess, which is what makes the VJP independent of the
    iteration count past convergence (pinned by a regression test)."""
    _, cdt = spec.dtypes
    n_dev, k = spec.n_dev, spec.k
    vb_c = v_bar.astype(cdt)
    if n_dev == 0:
        lam = vb_c
    else:
        B = v_star.shape[0]
        vc = v_star.astype(cdt)
        vg = _gather_safe(vc, spec.g_safe)
        va = _gather_safe(vc, spec.a_safe)
        vb = _gather_safe(vc, spec.b_safe)
        p = params.astype(cdt)
        _, di_dvg, di_dva, di_dvb = channel_current_and_grads(
            *(p[:, i] for i in range(len(PARAM_FIELDS))), vg, va, vb)
        gg = p[:, len(PARAM_FIELDS)]
        d3 = jnp.stack([di_dvg, di_dva, di_dvb], axis=2)  # (B, n_dev, 3)
        Sb = pre["Sb"].astype(cdt)
        d3S = jnp.einsum("bdj,bdjk->bdk", d3, Sb)
        egS = (Sb[:, :, 0] - 0.5 * Sb[:, :, 1] - 0.5 * Sb[:, :, 2]) \
            * gg[:, :, None]
        DS = jnp.stack([d3S - 0.5 * egS,
                        -d3S - 0.5 * egS,
                        egS], axis=2).reshape(B, k, k)
        A = jnp.eye(k, dtype=cdt)[None] + DS
        # lam = vbar - Vm^T D^T (A^T)^-1 KU^T vbar
        y = jnp.einsum("bnk,bn->bk", pre["KU"].astype(cdt), vb_c)
        u = _solve_small(jnp.swapaxes(A, 1, 2), y, n_dev)
        u3 = u.reshape(B, n_dev, 3)           # rows (a, b, g) of Um cols
        sau = u3[:, :, 0] - u3[:, :, 1]                        # s_a . u
        sgu = u3[:, :, 2] - 0.5 * (u3[:, :, 0] + u3[:, :, 1])  # s_g . u
        # D^T u over D's column order (g, a, b):
        #   d3 * (s_a . u) + gg * e_g * (s_g . u)
        ggs = gg * sgu
        dtu = d3 * sau[:, :, None] \
            + jnp.stack([ggs, -0.5 * ggs, -0.5 * ggs], axis=2)
        corr = jnp.zeros((B, spec.n + 1), cdt)
        corr = corr.at[:, spec.g_safe].add(dtu[:, :, 0])
        corr = corr.at[:, spec.a_safe].add(dtu[:, :, 1])
        corr = corr.at[:, spec.b_safe].add(dtu[:, :, 2])
        lam = vb_c - corr[:, : spec.n]
    _, vjp_fn = jax.vjp(
        lambda pre_, krhs_, params_:
            residual(spec, pre_, krhs_, params_, v_star),
        pre, Krhs, params)
    return vjp_fn(-lam)
