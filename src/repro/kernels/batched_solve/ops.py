"""Jit'd public wrappers for the batched MNA solvers.

Dense Gauss-Jordan (`solve`/`batched_solve`): the PR 2 kernel, f32
per-iteration dense solves for screening sweeps. On CPU the Pallas
kernel runs in interpret mode; on TPU it compiles natively. `solve1`
adapts it to the single-system signature the Newton stepper uses —
under vmap the batch dimension folds back into the kernel's grid via
jax's batching rule for pallas_call.

Fused Newton (`fused_newton_step`): the sparse-Newton engine's
whole-timestep solve (newton.py / fused.py). Backend dispatch: the
native Pallas kernel on TPU, the identical-result XLA while_loop on
CPU (interpret-mode Pallas is an emulation — orders of magnitude slower
than compiled XLA, so it is reserved for the parity tests).

The dispatching solve is wrapped in a `jax.custom_vjp`: neither the
while_loop fallback nor the Pallas kernel is reverse-differentiable,
but the converged root is an implicit function of the data inputs, so
the backward pass is ONE extra Woodbury solve with the transposed
capacitance matrix (`newton.fixed_point_adjoint`) instead of a
differentiated unroll. This is what lets energy/delay gradients flow
through whole transient characterizations (core/dse_grad.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.batched_solve import newton as _newton
from repro.kernels.batched_solve.fused import fused_newton as _fused_kernel
from repro.kernels.batched_solve.kernel import batched_solve as _kernel
from repro.kernels.batched_solve.ref import batched_solve_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _fused_solve(spec, iters, tol, pre, Krhs, params, v0):
    """Differentiable fused Newton solve (backend-dispatching primal).
    spec/iters/tol are static (FusedSpec hashes by identity)."""
    if jax.default_backend() == "tpu":
        return _fused_kernel(spec, pre, Krhs, params, v0,
                             iters=iters, tol=tol, interpret=False)
    v, _ = _newton.newton_solve(spec, pre, Krhs, params, v0, iters, tol)
    return v


def _fused_solve_fwd(spec, iters, tol, pre, Krhs, params, v0):
    v = _fused_solve(spec, iters, tol, pre, Krhs, params, v0)
    return v, (pre, Krhs, params, v)


def _fused_solve_bwd(spec, iters, tol, res, v_bar):
    pre, Krhs, params, v_star = res
    pre_bar, krhs_bar, params_bar = _newton.fixed_point_adjoint(
        spec, pre, Krhs, params, v_star, v_bar)
    return pre_bar, krhs_bar, params_bar, jnp.zeros_like(v_star)


_fused_solve.defvjp(_fused_solve_fwd, _fused_solve_bwd)


def fused_newton_step(spec, pre, Krhs, params, v0, *, iters, tol,
                      force_kernel: bool = False):
    """One timestep's fused Newton solve -> v (B, n). Routes to the
    Pallas kernel on TPU (or when forced, in interpret mode — the parity
    tests), else to the bit-identical XLA while_loop fallback. Except on
    the forced-interpret parity path, the result carries the
    implicit-function VJP, so whole characterizations built on this step
    are reverse-differentiable."""
    if force_kernel and jax.default_backend() != "tpu":
        return _fused_kernel(spec, pre, Krhs, params, v0,
                             iters=iters, tol=tol, interpret=True)
    return _fused_solve(spec, iters, tol, pre, Krhs, params, v0)


def batched_solve(J, r, block_b: int = 8):
    return _kernel(J, r, block_b=block_b, interpret=_interpret())


def solve1(J, r):
    """Single system (N, N) @ x = (N,)."""
    return batched_solve(J[None], r[None], block_b=1)[0]


def solve(J, r, block_b: int = 8):
    """Shape-dispatching entry: (N, N) or (B, N, N) systems. NOTE the
    kernel computes in float32 regardless of input dtype — fine for DSE
    screening sweeps, but the float64 characterization anchor
    (repro.core.spice.char_batch) should use the "jnp" solver."""
    if J.ndim == 2:
        return solve1(J, r)
    return batched_solve(J, r, block_b=block_b)
