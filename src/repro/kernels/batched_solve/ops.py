"""Jit'd public wrappers for the batched MNA solvers.

Dense Gauss-Jordan (`solve`/`batched_solve`): the PR 2 kernel, f32
per-iteration dense solves for screening sweeps. On CPU the Pallas
kernel runs in interpret mode; on TPU it compiles natively. `solve1`
adapts it to the single-system signature the Newton stepper uses —
under vmap the batch dimension folds back into the kernel's grid via
jax's batching rule for pallas_call.

Fused Newton (`fused_newton_step`): the sparse-Newton engine's
whole-timestep solve (newton.py / fused.py). Backend dispatch: the
native Pallas kernel on TPU, the identical-result XLA while_loop on
CPU (interpret-mode Pallas is an emulation — orders of magnitude slower
than compiled XLA, so it is reserved for the parity tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.batched_solve import newton as _newton
from repro.kernels.batched_solve.fused import fused_newton as _fused_kernel
from repro.kernels.batched_solve.kernel import batched_solve as _kernel
from repro.kernels.batched_solve.ref import batched_solve_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_newton_step(spec, pre, Krhs, params, v0, *, iters, tol,
                      force_kernel: bool = False):
    """One timestep's fused Newton solve -> v (B, n). Routes to the
    Pallas kernel on TPU (or when forced, in interpret mode — the parity
    tests), else to the bit-identical XLA while_loop fallback."""
    if jax.default_backend() == "tpu":
        return _fused_kernel(spec, pre, Krhs, params, v0,
                             iters=iters, tol=tol, interpret=False)
    if force_kernel:
        return _fused_kernel(spec, pre, Krhs, params, v0,
                             iters=iters, tol=tol, interpret=True)
    v, _ = _newton.newton_solve(spec, pre, Krhs, params, v0, iters, tol)
    return v


def batched_solve(J, r, block_b: int = 8):
    return _kernel(J, r, block_b=block_b, interpret=_interpret())


def solve1(J, r):
    """Single system (N, N) @ x = (N,)."""
    return batched_solve(J[None], r[None], block_b=1)[0]


def solve(J, r, block_b: int = 8):
    """Shape-dispatching entry: (N, N) or (B, N, N) systems. NOTE the
    kernel computes in float32 regardless of input dtype — fine for DSE
    screening sweeps, but the float64 characterization anchor
    (repro.core.spice.char_batch) should use the "jnp" solver."""
    if J.ndim == 2:
        return solve1(J, r)
    return batched_solve(J, r, block_b=block_b)
