"""Jit'd public wrappers for the batched MNA solve.

On CPU (this container / unit tests) the Pallas kernel runs in
interpret mode; on TPU it compiles natively. `solve1` adapts the kernel
to the single-system signature the Newton stepper uses — under vmap
(design-space batches) the batch dimension folds back into the kernel's
grid via jax's batching rule for pallas_call.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.batched_solve.kernel import batched_solve as _kernel
from repro.kernels.batched_solve.ref import batched_solve_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def batched_solve(J, r, block_b: int = 8):
    return _kernel(J, r, block_b=block_b, interpret=_interpret())


def solve1(J, r):
    """Single system (N, N) @ x = (N,)."""
    return batched_solve(J[None], r[None], block_b=1)[0]


def solve(J, r, block_b: int = 8):
    """Shape-dispatching entry: (N, N) or (B, N, N) systems. NOTE the
    kernel computes in float32 regardless of input dtype — fine for DSE
    screening sweeps, but the float64 characterization anchor
    (repro.core.spice.char_batch) should use the "jnp" solver."""
    if J.ndim == 2:
        return solve1(J, r)
    return batched_solve(J, r, block_b=block_b)
