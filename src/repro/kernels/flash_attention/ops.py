"""Public wrapper: pads to block multiples, interpret on CPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, q_offset=0, *, bq=256, bkv=512, causal=True):
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    bq_ = min(bq, Sq)
    bkv_ = min(bkv, Skv)
    Sqp = -(-Sq // bq_) * bq_
    Skvp = -(-Skv // bkv_) * bkv_
    if Sqp != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sqp - Sq), (0, 0), (0, 0)))
    if Skvp != Skv:
        # padded keys masked out via causal positions only when causal;
        # for non-causal, mask by writing NEG-biased keys is avoided by
        # requiring divisible Skv in the non-causal path.
        assert causal, "non-causal path requires Skv % bkv == 0"
        k = jnp.pad(k, ((0, 0), (0, Skvp - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skvp - Skv), (0, 0), (0, 0)))
    out = flash_attention_fwd(q, k, v, q_offset, bq=bq_, bkv=bkv_,
                              causal=causal, interpret=_interpret())
    return out[:, :Sq]
