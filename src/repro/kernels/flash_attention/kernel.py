"""Pallas TPU kernel: flash attention forward (causal, GQA).

Why this exists (§Perf hillclimb #1, EXPERIMENTS.md): the pure-JAX flash
path materializes every (cq, ckv) score/probability block in HBM — the
dominant roofline term for every attention-heavy cell. In this kernel the
whole online-softmax tile pipeline (scores -> max -> exp -> accumulate)
lives in VMEM; HBM traffic collapses to Q + K + V + O.

Grid: (B, K_heads, nq) — one program per (batch, kv-head, q-block),
looping over kv blocks with lax.fori_loop. Per-program VMEM footprint:
  q block   (G, bq, hd)            e.g. 4 x 256 x 128 x 4 B = 0.5 MiB
  k/v SEQ   2 x (Skv, hd) bf16     e.g. 2 x 32768 x 128 x 2 B = 16 MiB*
  scores    (G, bq, bkv) f32       e.g. 4 x 256 x 512 x 4 B = 2 MiB
(*) for Skv > ~8k at hd=128 the full-KV block exceeds v5e VMEM; callers
split KV externally (seq-parallel shard_map does this for free: each
model rank holds Skv/16). MXU alignment: bq, bkv, hd multiples of 128
preferred; smaller shapes run (padded lanes) but underfill the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, qoff_ref, o_ref, *, bkv, causal):
    # q: (1, bq, 1, G, hd) ; k/v: (1, Skv, 1, hd) ; o like q
    q = q_ref[0, :, 0].astype(jnp.float32)           # (bq, G, hd)
    bq, G, hd = q.shape
    Skv = k_ref.shape[1]
    nkv = Skv // bkv
    qi = pl.program_id(2)
    qpos = qoff_ref[0] + qi * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq,), 0)
    scale = 1.0 / np.sqrt(hd)

    def body(j, carry):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k_ref[0, :, 0], j * bkv, bkv, 0)
        vb = jax.lax.dynamic_slice_in_dim(v_ref[0, :, 0], j * bkv, bkv, 0)
        s = jax.lax.dot_general(
            q.reshape(bq * G, hd), kb.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(bq, G, bkv) * scale
        if causal:
            kpos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bkv,), 0)
            mask = kpos[None, :] <= qpos[:, None]
            s = jnp.where(mask[:, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.reshape(bq * G, bkv), vb.astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(bq, G, hd)
        acc_new = acc * corr[..., None] + pv
        return m_new, l_new, acc_new

    m0 = jnp.full((bq, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, G), jnp.float32)
    a0 = jnp.zeros((bq, G, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nkv, body, (m0, l0, a0))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    o_ref[0, :, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bkv", "causal",
                                             "interpret"))
def flash_attention_fwd(q, k, v, q_offset=0, *, bq=256, bkv=512,
                        causal=True, interpret=False):
    """q: (B, Sq, H, hd); k/v: (B, Skv, K, hd); H = K*G. Returns like q.
    Sq % bq == 0 and Skv % bkv == 0 required (callers pad)."""
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    bq = min(bq, Sq)
    bkv = min(bkv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0, (Sq, bq, Skv, bkv)
    qg = q.reshape(B, Sq, K, G, hd)
    qoff = jnp.asarray([q_offset], jnp.int32)

    out = pl.pallas_call(
        functools.partial(_kernel, bkv=bkv, causal=causal),
        grid=(B, K, Sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, 1, G, hd), lambda b, h, i: (b, i, h, 0, 0)),
            pl.BlockSpec((1, Skv, 1, hd), lambda b, h, i: (b, 0, h, 0)),
            pl.BlockSpec((1, Skv, 1, hd), lambda b, h, i: (b, 0, h, 0)),
            pl.BlockSpec((1,), lambda b, h, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, G, hd), lambda b, h, i: (b, i, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, K, G, hd), q.dtype),
        interpret=interpret,
    )(qg, k, v, qoff)
    return out.reshape(B, Sq, H, hd)
