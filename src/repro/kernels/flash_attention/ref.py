"""Pure-jnp oracle: naive causal GQA attention."""
import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal=True, q_offset=0):
    """q: (B, Sq, H, hd); k, v: (B, Skv, K, hd) -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    s = s / np.sqrt(hd)
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        mask = jnp.arange(Skv)[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", w, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)
