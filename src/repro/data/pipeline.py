"""Deterministic synthetic LM data pipeline.

Production shape: an infinite, SHARDED, RESUMABLE stream. Every batch is a
pure function of (seed, step, shard) — identical across restarts and
host counts, which is what makes checkpoint-restart and elastic rescale
exactly reproducible (the cursor is just the step int).

Sequences are Zipf-distributed token ids with short Markov-ish structure
(token t+1 = f(t) with noise) so the model has learnable signal and the
loss visibly decreases in examples/quickstart.py; labels are next-token.

For the audio/vlm stubs the pipeline also fabricates frame/patch
embeddings (deterministic per step).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0
    family: str = "dense"
    d_model: int = 0
    enc_frames: int = 0
    n_patches: int = 0

    def local_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards

    def batch_at(self, step: int) -> dict:
        """The (step, shard) batch — pure function, O(1) random access."""
        lb = self.local_batch()
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        V = self.vocab_size
        # Zipf-ish marginal + deterministic successor structure:
        base = rng.zipf(1.3, size=(lb, self.seq_len + 1)) % V
        succ = (base[:, :-1] * 31 + 7) % V
        mix = rng.random((lb, self.seq_len)) < 0.7
        toks = np.where(mix, succ, base[:, 1:]).astype(np.int32)
        first = base[:, :1].astype(np.int32)
        seq = np.concatenate([first, toks], axis=1)  # (lb, S+1)
        out = {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
        if self.family == "audio":
            out["frames"] = rng.standard_normal(
                (lb, self.enc_frames, self.d_model)).astype(np.float32)
        if self.family == "vlm":
            np_ = self.n_patches
            out["tokens"] = out["tokens"][:, : self.seq_len - np_]
            out["labels"] = out["labels"][:, : self.seq_len - np_]
            out["patches"] = rng.standard_normal(
                (lb, np_, self.d_model)).astype(np.float32)
        return out

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch_iterator(cfg, shape, *, seed=0, n_shards=1, shard=0,
                        start_step=0):
    ds = SyntheticLMData(
        vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
        global_batch=shape.global_batch, seed=seed, n_shards=n_shards,
        shard=shard, family=cfg.family, d_model=cfg.d_model,
        enc_frames=cfg.enc_frames, n_patches=cfg.n_patches)
    return ds, ds.iterate(start_step)
