"""Render the §Dry-run / §Roofline tables in EXPERIMENTS.md from
results/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report results/dryrun
"""
from __future__ import annotations

import glob
import json
import sys


def load(results_dir: str):
    recs = []
    for p in sorted(glob.glob(f"{results_dir}/*.json")):
        recs.append(json.load(open(p)))
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def roofline_table(recs, mesh="16x16") -> str:
    rows = [
        "| arch | shape | kind | compute s | memory s | collective s | "
        "bottleneck | MODEL_FLOPS | useful/HLO | MFU bound | peak GiB/dev | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        rl = r["roofline"]
        an = r["hlo_analysis"]
        useful = rl["model_flops"] / max(rl["hlo_flops_global"], 1.0)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {rl['compute_s']:.4g} | {rl['memory_s']:.4g} "
            f"| {rl['collective_s']:.4g} | **{rl['bottleneck']}** "
            f"| {rl['model_flops']:.3g} | {useful:.3f} "
            f"| {rl['mfu']:.4f} | {fmt_bytes(r['peak_bytes_per_device'])} "
            f"| {'Y' if r['fits_16g_hbm'] else 'N'} |")
    return "\n".join(rows)


def dryrun_table(recs) -> str:
    rows = [
        "| arch | shape | mesh | compile s | flops/dev | HLO bytes/dev | "
        "wire bytes/dev | collectives (AR/AG/RS/A2A/CP) | args GiB | temp GiB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        an = r["hlo_analysis"]
        bt = an["collective_by_type"]
        coll = "/".join(f"{bt.get(k, 0)/2**20:.0f}M" for k in (
            "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
            "collective-permute"))
        ma = r["memory_analysis"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']} "
            f"| {an['flops']:.3g} | {an['mem_bytes']:.3g} "
            f"| {an['collective_wire_bytes']:.3g} | {coll} "
            f"| {fmt_bytes(ma['argument_bytes_per_device'])} "
            f"| {fmt_bytes(ma['temp_bytes_per_device'])} |")
    return "\n".join(rows)


def summary(recs) -> str:
    n256 = sum(1 for r in recs if r["mesh"] == "16x16")
    n512 = sum(1 for r in recs if r["mesh"] == "2x16x16")
    worst = sorted((r for r in recs if r["mesh"] == "16x16"),
                   key=lambda r: r["roofline"]["mfu"])[:5]
    coll = sorted((r for r in recs if r["mesh"] == "16x16"),
                  key=lambda r: -r["roofline"]["collective_s"])[:5]
    out = [f"cells compiled: {n256} single-pod + {n512} multi-pod",
           "worst MFU bound: " + ", ".join(
               f"{r['arch']}:{r['shape']}={r['roofline']['mfu']:.4f}"
               for r in worst),
           "most collective-bound: " + ", ".join(
               f"{r['arch']}:{r['shape']}={r['roofline']['collective_s']:.3g}s"
               for r in coll)]
    return "\n".join(out)


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(d)
    print("## summary\n" + summary(recs))
    print("\n## §Roofline (single-pod 16x16)\n" + roofline_table(recs))
    print("\n## §Roofline (multi-pod 2x16x16)\n" +
          roofline_table(recs, mesh="2x16x16"))
    print("\n## §Dry-run\n" + dryrun_table(recs))
