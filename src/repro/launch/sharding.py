"""Logical-axis -> mesh-axis rule tables and sharding-tree builders.

The scheme (MaxText-style, DESIGN.md §5):
  * batch            -> all data axes ("pod","data")
  * embed_fsdp       -> "data"   (ZeRO/FSDP shard of the big tables)
  * embed            -> "data"   (param d_model dim: FSDP; activations fall
                                  back to replicated because 'data' is taken
                                  by 'batch' in any activation spec)
  * heads/kv_heads   -> "model"  (TP), fallback head_dim -> "model" when the
                        head count does not divide the axis (GSPMD needs
                        divisibility; logical_to_pspec replicates otherwise)
  * mlp/inner/...    -> "model"
  * experts          -> "model"  (EP; moe.py switches to d_ff TP when E < axis)
  * vocab            -> "model"
  * kv_seq           -> "model", or ("data","model") when the decode batch is
                        too small to occupy the data axes (long_500k B=1)
  * layers/seq/state -> replicated

Divisibility fallback (models/common.logical_to_pspec) replicates any dim
whose size does not divide the assigned axes, so one rule table serves all
10 architectures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import logical_to_pspec
from repro.launch.mesh import data_axis_names


def _sanitize(rules: dict, mesh) -> dict:
    """Drop mesh axes the rule table names but this mesh doesn't have
    (e.g. a data-only bring-up mesh has no 'model' axis)."""
    have = set(mesh.axis_names)

    def fix(v):
        if v is None:
            return None
        axes = v if isinstance(v, tuple) else (v,)
        axes = tuple(a for a in axes if a in have)
        if not axes:
            return None
        # preserve tuple-ness: consumers iterate rules["batch"] as a tuple
        return axes if isinstance(v, tuple) else axes[0]

    return {k: fix(v) for k, v in rules.items()}


def make_rules(mesh, *, batch_size: int = None, kind: str = "train") -> dict:
    data_axes = data_axis_names(mesh)
    n_data = int(np.prod([mesh.shape[a] for a in data_axes]))
    small_batch = batch_size is not None and batch_size < n_data
    if kind == "decode":
        # Serving layout (§Perf hillclimb #2): no gradients -> no reason to
        # FSDP-shard weights over 'data' (that put a 58 GB/step expert
        # all-gather on arctic's decode path). Instead: experts stay EP
        # over 'model', expert d_ff shards 2D over the data axes (weights
        # live exactly where they are consumed; MoE psums tiny activations
        # instead of gathering weights), everything else replicates over
        # 'data' and keeps TP over 'model'.
        return _sanitize({
            "batch": data_axes,
            "seq": None,
            "layers": None,
            # non-expert weights keep the FSDP shard: their per-step
            # all-gather is ~15 MB/layer (cheap) and replicating them
            # would blow HBM on archs whose heads don't divide 'model'
            "embed": "data",
            "embed_fsdp": "data",
            "vocab": "model",
            "heads": "model",
            "kv_heads": "model",
            "head_dim": None,
            "mlp": "model",
            "expert_mlp": data_axes,
            "experts": "model",
            "inner": "model",
            "inner_all": "model",
            "conv_dim": "model",
            "ssm_heads": "model",
            "kv_seq": ("data", "model") if small_batch else "model",
        }, mesh)
    return _sanitize({
        "batch": data_axes,
        "seq": None,
        "layers": None,
        "embed": "data",
        "embed_fsdp": "data",
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        # head_dim is NEVER sharded: contracting over a sharded head_dim
        # puts an all-reduce inside every flash-attention KV chunk (measured:
        # ~4.5 TB/step wire for qwen2 train_4k). Archs whose head count does
        # not divide the model axis replicate attention instead (EXPERIMENTS
        # §Perf hillclimbs attack this with seq-parallel attention).
        "head_dim": None,
        "mlp": "model",
        "expert_mlp": None,
        "experts": "model",
        "inner": "model",
        "inner_all": "model",
        "conv_dim": "model",
        "ssm_heads": "model",
        "kv_seq": ("data", "model") if small_batch else "model",
    }, mesh)


def spec_tree(logical_tree, shape_tree, rules, mesh):
    """Map a tree of logical-axis tuples + matching ShapeDtypeStructs to
    PartitionSpecs (with divisibility fallback)."""
    return jax.tree.map(
        lambda axes, s: logical_to_pspec(axes, rules, shape=s.shape, mesh=mesh),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def sharding_tree(logical_tree, shape_tree, rules, mesh):
    specs = spec_tree(logical_tree, shape_tree, rules, mesh)
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_sharding(mesh, rules):
    """Sharding for (B, ...) host-data arrays: batch over data axes."""
    return NamedSharding(mesh, P(rules["batch"]))


def sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype), sharding=sharding)
