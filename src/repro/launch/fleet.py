"""Fault-tolerant multi-worker compile fleet.

`repro.launch.compile_service` is one process draining admission waves;
this module is the FLEET around it: a dispatcher shards queued JSON
query requests across N `CompileService` worker subprocesses over a
spool directory, and survives the failures a fleet actually has —
crashed workers, hung waves, torn artifacts, poison requests.

Topology (everything is plain files, so any worker on any host sharing
the filesystem can join):

    spool/
      w0/inbox/<rid>.json    per-worker request shards (atomic writes)
      outbox/<rid>.json      responses, any worker -> dispatcher
      stats/<wid>.json       terminal worker stats (graceful exit)
      stop                   global shutdown flag
    store/                   shared content-addressed ArtifactStore
      _leases/               claim files + the evaluation log

Failure handling, layer by layer:

  * **no duplicate work**: every worker session runs with a
    `repro.api.leases.LeaseManager` over the shared store, so a lattice
    evaluation is computed by exactly one worker no matter how requests
    shard; the rest read the published artifact. A crashed worker's
    claims expire after one lease TTL and are STOLEN — in-flight nodes
    are reclaimed, not lost.
  * **deadlines**: a request with no response within `deadline_s` is
    re-dispatched to another worker (the slow worker's eventual
    response is still accepted if it arrives first).
  * **bounded retry**: worker death and retryable (node-evaluation)
    failures re-queue the request with exponential backoff; after
    `max_attempts` dispatches the request is QUARANTINED — it resolves
    with a structured ``{"ok": false, "error": ..., "attempts": K,
    "quarantined": true}`` response instead of wedging the fleet.
    Deterministic failures (bad JSON, invalid queries) are returned
    immediately, as the single service would.
  * **graceful degradation**: if no worker subprocess can start — or
    every worker dies mid-run — the dispatcher finishes the workload
    through an in-process `CompileService` with the same retry and
    quarantine semantics.

CLI (dispatcher):

    PYTHONPATH=src python -m repro.launch.fleet \
        --input requests.jsonl --workers 3 \
        --spool /tmp/gcram-spool --store /tmp/gcram-store

Workers are spawned as `python -m repro.launch.fleet --worker ...`;
`--faults "seed=7,tear_rate=0.3,..."` arms the deterministic chaos
harness (`repro.testing.faults`) inside a worker — used by the chaos
tests and `benchmarks/bench_fleet.py`.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import time
from collections import Counter
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.techfile import SYN40

__all__ = ["Fleet", "worker_main"]

_RID_RE = re.compile(r"^r(\d+)-(\d+)\.(\d+)$")


def _atomic_json(path: str, obj) -> None:
    tmp = f"{path}.tmp{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(obj, f, default=str)
    os.replace(tmp, path)


def _src_pythonpath() -> str:
    """PYTHONPATH entry that makes `repro` importable in workers,
    regardless of the dispatcher's cwd."""
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    current = os.environ.get("PYTHONPATH", "")
    return src + (os.pathsep + current if current else "")


# ---------------------------------------------------------------------------
# worker subprocess
# ---------------------------------------------------------------------------

def worker_main(spool: str, wid: str, store_dir: str,
                wave_size: int = 16, lease_ttl_s: float = 10.0,
                faults: str = "", poll_s: float = 0.02,
                tech=SYN40) -> int:
    """One fleet worker: scan the inbox shard, drain each batch as one
    `CompileService` admission wave against the SHARED leased store,
    publish responses atomically to the outbox. Exits on the spool's
    `stop` flag."""
    from repro.api import Session
    from repro.api.leases import LeaseManager
    from repro.api.store import ArtifactStore
    from repro.launch.compile_service import CompileService
    from repro.testing.faults import (FaultInjector, FaultSpec,
                                      InjectedFault)

    inbox = os.path.join(spool, wid, "inbox")
    outbox = os.path.join(spool, "outbox")
    stats_dir = os.path.join(spool, "stats")
    stop_flag = os.path.join(spool, "stop")
    for d in (inbox, outbox, stats_dir):
        os.makedirs(d, exist_ok=True)

    store = ArtifactStore(store_dir)
    store.sweep_tmp()                 # droppings of previously killed writers
    leases = LeaseManager(store_dir, owner=wid, ttl_s=lease_ttl_s)
    session = Session(tech, store=store, leases=leases)
    svc = CompileService(session=session, wave_size=wave_size)
    injector = None
    if faults:
        spec = FaultSpec.parse(faults)
        if spec.any_faults():
            injector = FaultInjector(spec).install(store=store, evals=True)

    while not os.path.exists(stop_flag):
        names = sorted(f for f in os.listdir(inbox)
                       if f.endswith(".json"))[:wave_size]
        if not names:
            time.sleep(poll_s)
            continue
        batch = []
        for name in names:
            try:
                with open(os.path.join(inbox, name)) as f:
                    batch.append((name, json.load(f)))
            except (OSError, ValueError):
                continue              # vanished mid-scan; re-listed next loop
        ready, responses = [], []
        for name, req in batch:
            if injector is not None:
                try:
                    injector.check_request(req)
                except InjectedFault as e:
                    responses.append((name, {
                        "id": req.get("id"),
                        "tenant": req.get("tenant", "anonymous"),
                        "ok": False, "error": f"InjectedFault: {e}",
                        "retryable": True}))
                    continue
            svc.submit(req)
            ready.append(name)
        if ready:                      # one admission wave for the shard
            responses.extend(zip(ready, svc.drain()))
        for name, resp in responses:
            _atomic_json(os.path.join(outbox, name), resp)
            try:
                os.unlink(os.path.join(inbox, name))
            except OSError:
                pass
    _atomic_json(os.path.join(stats_dir, f"{wid}.json"), {
        "worker": wid, "service": svc.stats(), "leases": leases.stats(),
        "faults": dict(injector.counts) if injector is not None else {}})
    return 0


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

@dataclass
class _Worker:
    wid: str
    inbox: str
    proc: Optional[subprocess.Popen] = None
    alive: bool = False


@dataclass
class _Req:
    idx: int
    req: dict
    status: str = "queued"            # queued | inflight | done
    attempts: int = 0                 # dispatches tried so far
    worker: Optional[_Worker] = None
    rid: str = ""
    due: float = 0.0                  # monotonic: earliest (re)dispatch
    dispatched: float = 0.0           # monotonic: last dispatch time
    last_error: str = ""
    response: Optional[dict] = None


class Fleet:
    """Dispatcher for N compile-service worker subprocesses.

    `run(requests)` returns one response per request, in request order,
    every one resolved — success, deterministic error, or structured
    quarantine. Use as a context manager (`with Fleet(...) as f:`) so
    workers are always stopped and their stats collected."""

    def __init__(self, spool: str, store: Optional[str],
                 n_workers: int = 2, wave_size: int = 16,
                 deadline_s: float = 120.0, max_attempts: int = 5,
                 backoff_s: float = 0.25, lease_ttl_s: float = 5.0,
                 poll_s: float = 0.02,
                 fault_specs: Optional[Dict[str, str]] = None,
                 python: Optional[str] = None, tech=SYN40):
        self.spool = os.fspath(spool)
        self.store_dir = os.fspath(store) if store is not None else None
        self.n_workers = int(n_workers)
        self.wave_size = int(wave_size)
        self.deadline_s = float(deadline_s)
        self.max_attempts = max(1, int(max_attempts))
        self.backoff_s = float(backoff_s)
        self.lease_ttl_s = float(lease_ttl_s)
        self.poll_s = float(poll_s)
        self.fault_specs = dict(fault_specs or {})
        self.python = python or sys.executable
        self.tech = tech
        self.workers: List[_Worker] = []
        self.degraded = False
        self.counters: Counter = Counter()
        self.worker_stats: Dict[str, dict] = {}
        self._started = False
        self._rr = 0
        self._run_seq = 0
        self._inline_svc = None
        self._inline_injector = None
        self.outbox = os.path.join(self.spool, "outbox")

    # -- lifecycle -----------------------------------------------------
    def start(self) -> "Fleet":
        if self._started:
            return self
        self._started = True
        os.makedirs(self.outbox, exist_ok=True)
        os.makedirs(os.path.join(self.spool, "stats"), exist_ok=True)
        env = dict(os.environ, PYTHONPATH=_src_pythonpath())
        logs = os.path.join(self.spool, "logs")
        os.makedirs(logs, exist_ok=True)
        for i in range(self.n_workers):
            wid = f"w{i}"
            inbox = os.path.join(self.spool, wid, "inbox")
            os.makedirs(inbox, exist_ok=True)
            cmd = [self.python, "-m", "repro.launch.fleet", "--worker",
                   "--spool", self.spool, "--worker-id", wid,
                   "--store", self.store_dir or "",
                   "--wave-size", str(self.wave_size),
                   "--lease-ttl", str(self.lease_ttl_s)]
            spec = self.fault_specs.get(wid)
            if spec:
                cmd += ["--faults", spec]
            w = _Worker(wid, inbox)
            try:
                log = open(os.path.join(logs, f"{wid}.log"), "w")
                w.proc = subprocess.Popen(
                    cmd, env=env, stdout=log, stderr=log,
                    stdin=subprocess.DEVNULL)
                w.alive = True
            except OSError as e:
                self.counters["spawn_failures"] += 1
                w.alive = False
                w.proc = None
                self.counters[f"spawn_error_{type(e).__name__}"] += 1
            self.workers.append(w)
        if not any(w.alive for w in self.workers):
            # no subprocess could start: single-worker in-process mode
            self.degraded = True
        return self

    def stop(self) -> None:
        if not self._started:
            return
        try:
            with open(os.path.join(self.spool, "stop"), "w") as f:
                f.write("stop\n")
        except OSError:
            pass
        for w in self.workers:
            if w.proc is None:
                continue
            try:
                w.proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                w.proc.kill()
                w.proc.wait()
            w.alive = False
        stats_dir = os.path.join(self.spool, "stats")
        for w in self.workers:
            path = os.path.join(stats_dir, f"{w.wid}.json")
            try:
                with open(path) as f:
                    self.worker_stats[w.wid] = json.load(f)
            except (OSError, ValueError):
                pass                  # killed workers leave no stats

    def __enter__(self) -> "Fleet":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def kill_worker(self, i: int) -> None:
        """SIGKILL worker i (chaos testing: a crash, not a shutdown)."""
        w = self.workers[i]
        if w.proc is not None and w.proc.poll() is None:
            w.proc.kill()

    def _live(self) -> List[_Worker]:
        return [w for w in self.workers if w.alive]

    # -- the run loop --------------------------------------------------
    def run(self, requests, timeout_s: float = 600.0) -> List[dict]:
        self.start()
        self._run_seq += 1
        states = [_Req(i, dict(r)) for i, r in enumerate(requests)]
        if self.degraded:
            self.counters["degraded_runs"] += 1
            self._run_inline(states)
            return [st.response for st in states]
        now = time.monotonic()
        for st in states:
            st.due = now
        t_end = now + float(timeout_s)
        seen: set = set()
        while any(st.status != "done" for st in states):
            now = time.monotonic()
            if now > t_end:
                self.counters["run_timeouts"] += 1
                for st in states:
                    if st.status != "done":
                        self._quarantine(
                            st, f"fleet run timed out after {timeout_s}s")
                break
            self._collect(states, seen)
            self._check_liveness(states)
            if not self._live():
                # every worker died: finish in-process
                self.degraded = True
                self.counters["degraded_runs"] += 1
                self._run_inline([st for st in states
                                  if st.status != "done"])
                continue
            self._check_deadlines(states)
            self._dispatch_due(states)
            time.sleep(self.poll_s)
        return [st.response for st in states]

    # -- run-loop pieces -----------------------------------------------
    def _collect(self, states: List[_Req], seen: set) -> None:
        try:
            names = os.listdir(self.outbox)
        except OSError:
            return
        for name in names:
            if not name.endswith(".json") or name in seen:
                continue
            seen.add(name)
            m = _RID_RE.match(name[:-5])
            if not m or int(m.group(1)) != self._run_seq:
                continue              # stale response from an earlier run
            idx, attempt = int(m.group(2)), int(m.group(3))
            if idx >= len(states):
                continue
            st = states[idx]
            if st.status == "done":
                continue
            try:
                with open(os.path.join(self.outbox, name)) as f:
                    resp = json.load(f)
            except (OSError, ValueError):
                seen.discard(name)    # mid-write; retry next poll
                continue
            if resp.get("ok") or not resp.get("retryable"):
                # success and deterministic errors resolve from ANY
                # attempt (results are content-addressed: a late
                # duplicate is bit-identical)
                st.response = {**resp, "attempts": st.attempts}
                st.status = "done"
                self.counters["resolved"] += 1
            elif attempt == st.attempts and st.status == "inflight":
                self._fail(st, resp.get("error", "worker error"))

    def _check_liveness(self, states: List[_Req]) -> None:
        for w in self.workers:
            if not w.alive or w.proc is None:
                continue
            if w.proc.poll() is None:
                continue
            w.alive = False
            self.counters["worker_deaths"] += 1
            for st in states:
                if st.status == "inflight" and st.worker is w:
                    self._fail(st, f"worker {w.wid} died "
                                   f"(exit {w.proc.returncode})")

    def _check_deadlines(self, states: List[_Req]) -> None:
        now = time.monotonic()
        for st in states:
            if st.status == "inflight" and \
                    now - st.dispatched > self.deadline_s:
                self.counters["deadline_expiries"] += 1
                self._fail(st, f"deadline {self.deadline_s}s exceeded "
                               f"on {st.worker.wid if st.worker else '?'}")

    def _dispatch_due(self, states: List[_Req]) -> None:
        now = time.monotonic()
        live = self._live()
        if not live:
            return
        for st in states:
            if st.status != "queued" or st.due > now:
                continue
            w = live[self._rr % len(live)]
            self._rr += 1
            st.attempts += 1
            st.rid = f"r{self._run_seq}-{st.idx:05d}.{st.attempts}"
            _atomic_json(os.path.join(w.inbox, st.rid + ".json"), st.req)
            st.worker = w
            st.status = "inflight"
            st.dispatched = time.monotonic()
            self.counters["dispatched"] += 1

    def _fail(self, st: _Req, error: str) -> None:
        st.last_error = error
        if st.attempts >= self.max_attempts:
            self._quarantine(st, error)
            return
        self.counters["retries"] += 1
        st.status = "queued"
        st.worker = None
        st.due = time.monotonic() + \
            self.backoff_s * (2 ** max(0, st.attempts - 1))

    def _quarantine(self, st: _Req, error: str) -> None:
        """A request that keeps failing gets a structured terminal
        response — the fleet never wedges on a poison request."""
        st.response = {"id": st.req.get("id"),
                       "tenant": st.req.get("tenant", "anonymous"),
                       "ok": False, "error": error,
                       "attempts": st.attempts, "quarantined": True}
        st.status = "done"
        self.counters["quarantined"] += 1

    # -- degraded in-process mode --------------------------------------
    def _inline(self):
        if self._inline_svc is None:
            from repro.api import Session
            from repro.api.leases import LeaseManager
            from repro.launch.compile_service import CompileService
            from repro.testing.faults import FaultInjector, FaultSpec
            leases = LeaseManager(self.store_dir, owner="inline",
                                  ttl_s=self.lease_ttl_s) \
                if self.store_dir else None
            session = Session(self.tech, store=self.store_dir,
                              leases=leases)
            self._inline_svc = CompileService(session=session,
                                              wave_size=self.wave_size)
            spec_str = self.fault_specs.get("inline", "")
            if spec_str:
                spec = FaultSpec.parse(spec_str)
                if spec.any_faults():
                    self._inline_injector = FaultInjector(spec).install(
                        store=session.store, evals=True)
        return self._inline_svc

    def _run_inline(self, states: List[_Req]) -> None:
        """Single-worker in-process fallback with the same bounded
        retry + quarantine semantics as the subprocess path."""
        from repro.testing.faults import InjectedFault
        svc = self._inline()
        for st in states:
            if st.status == "done":
                continue
            while True:
                st.attempts += 1
                self.counters["dispatched"] += 1
                resp = None
                if self._inline_injector is not None:
                    try:
                        self._inline_injector.check_request(st.req)
                    except InjectedFault as e:
                        resp = {"id": st.req.get("id"),
                                "tenant": st.req.get("tenant",
                                                     "anonymous"),
                                "ok": False,
                                "error": f"InjectedFault: {e}",
                                "retryable": True}
                if resp is None:
                    svc.submit(st.req)
                    resp = svc.drain()[0]
                if resp.get("ok") or not resp.get("retryable"):
                    st.response = {**resp, "attempts": st.attempts}
                    st.status = "done"
                    self.counters["resolved"] += 1
                    break
                if st.attempts >= self.max_attempts:
                    self._quarantine(st, resp.get("error", "error"))
                    break
                self.counters["retries"] += 1
                time.sleep(min(
                    self.backoff_s * (2 ** max(0, st.attempts - 1)),
                    2.0))

    # -- accounting ----------------------------------------------------
    def eval_summary(self) -> dict:
        """Evaluation accounting across ALL workers, from the shared
        lease log: unique keys, evaluations by reason, and any key
        fresh-evaluated more than once (the fleet invariant is that
        `duplicates` is empty)."""
        from repro.api.leases import LeaseManager
        if not self.store_dir:
            return {"unique_keys": 0, "by_reason": {}, "duplicates": {}}
        counts = LeaseManager.read_eval_log(self.store_dir)
        by_reason: Counter = Counter()
        for c in counts.values():
            by_reason.update(c)
        return {"unique_keys": len(counts),
                "by_reason": dict(by_reason),
                "duplicates": LeaseManager.duplicate_evals(
                    self.store_dir)}

    def stats(self) -> dict:
        return {"n_workers": self.n_workers, "degraded": self.degraded,
                **{k: self.counters[k] for k in sorted(self.counters)},
                "evals": self.eval_summary(),
                "workers": self.worker_stats}


# ---------------------------------------------------------------------------
# CLI: dispatcher by default, --worker for the subprocess entry
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--worker", action="store_true",
                    help="run as a fleet worker (internal)")
    ap.add_argument("--spool", required=True)
    ap.add_argument("--store", default=None)
    ap.add_argument("--worker-id", default="w0")
    ap.add_argument("--wave-size", type=int, default=16)
    ap.add_argument("--lease-ttl", type=float, default=10.0)
    ap.add_argument("--faults", default="",
                    help="FaultSpec string, e.g. seed=7,tear_rate=0.3")
    ap.add_argument("--input", default="-",
                    help="JSONL request file, or - for stdin")
    ap.add_argument("--output", default="-")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--deadline", type=float, default=120.0)
    ap.add_argument("--max-attempts", type=int, default=5)
    args = ap.parse_args(argv)
    if args.worker:
        if not args.store:
            ap.error("--worker requires --store")
        return worker_main(args.spool, args.worker_id, args.store,
                           wave_size=args.wave_size,
                           lease_ttl_s=args.lease_ttl,
                           faults=args.faults)
    src = sys.stdin if args.input == "-" else open(args.input)
    try:
        requests = [json.loads(line) for line in src if line.strip()]
    finally:
        if src is not sys.stdin:
            src.close()
    with Fleet(args.spool, args.store, n_workers=args.workers,
               wave_size=args.wave_size, deadline_s=args.deadline,
               max_attempts=args.max_attempts,
               lease_ttl_s=args.lease_ttl) as fleet:
        responses = fleet.run(requests)
        stats = fleet.stats()
    dst = sys.stdout if args.output == "-" else open(args.output, "w")
    try:
        for resp in responses:
            dst.write(json.dumps(resp, default=str) + "\n")
    finally:
        if dst is not sys.stdout:
            dst.close()
    print(json.dumps(stats, default=str), file=sys.stderr)
    return 0 if all(r.get("ok") for r in responses) else 1


if __name__ == "__main__":
    raise SystemExit(main())
