"""Roofline terms from dry-run artifacts (TPU v5e target constants).

  compute term    = HLO_FLOPs / (chips x peak)      [per-device flops / peak]
  memory term     = HLO_bytes / (chips x HBM bw)
  collective term = wire bytes / (chips x link bw)

HLO_FLOPs / bytes / wire bytes come from hlo_analysis.analyze() which is
already PER-DEVICE, so terms divide by per-chip rates directly.
"""
from __future__ import annotations

from dataclasses import dataclass

PEAK_FLOPS = 197e12      # bf16 FLOP/s per v5e chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link (~per-chip effective)


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float          # 6*N*D useful flops (global)
    hlo_flops_global: float
    bottleneck: str
    step_time_s: float          # max of the three (no-overlap bound)
    mfu: float                  # model_flops / (chips*peak*step_time)
    roofline_frac: float        # dominant-term utilization vs its peak

    def as_dict(self):
        return self.__dict__.copy()


def derive(analysis: dict, *, n_chips: int, model_flops: float) -> Roofline:
    f = analysis["flops"]                 # per-device
    b = analysis["mem_bytes"]
    w = analysis["collective_wire_bytes"]
    ct = f / PEAK_FLOPS
    mt = b / HBM_BW
    lt = w / LINK_BW
    terms = {"compute": ct, "memory": mt, "collective": lt}
    bottleneck = max(terms, key=terms.get)
    step = max(ct, mt, lt)
    hlo_global = f * n_chips
    mfu = model_flops / (n_chips * PEAK_FLOPS * step) if step > 0 else 0.0
    # fraction of roofline: time the dominant resource is busy doing the
    # dominant term's work vs the whole step (1.0 = perfectly bound)
    frac = terms[bottleneck] / step if step > 0 else 0.0
    return Roofline(ct, mt, lt, model_flops, hlo_global, bottleneck, step,
                    mfu, frac)


def model_flops_for(cfg, shape) -> float:
    """6*N*D (dense) or 6*N_active*D (MoE) per step; decode D = batch tokens."""
    from repro.models.model import Model
    n = Model(cfg).param_count(active_only=True)
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks  # forward only
    return 2.0 * n * shape.global_batch  # decode: 1 token per sequence
