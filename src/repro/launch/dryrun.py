import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST run before any jax import: jax locks the device count on first init.
"""Multi-pod dry-run driver (deliverable e).

For every (architecture x live input shape) cell, on the single-pod
(16,16) mesh and the multi-pod (2,16,16) mesh:

    lowered  = jit(step).lower(*sharded ShapeDtypeStructs)
    compiled = lowered.compile()
    memory_analysis / cost_analysis / hlo_analysis -> JSON record

No arrays are ever allocated: inputs are ShapeDtypeStructs; the products
are the compiled per-device program and its analyses.

Usage:
    python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    python -m repro.launch.dryrun --all --out results/dryrun
    python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k --multi-pod
"""
import argparse
import json
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             block_skip=False, microbatches=1, moment_dtype="float32",
             baseline=False, kv_dtype=None, extra_tags=None) -> dict:
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, SHAPES
    from repro.launch.mesh import make_production_mesh, n_chips
    from repro.launch import steps as steps_mod
    from repro.launch import hlo_analysis, roofline

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rules_kind = None
    if baseline:
        # paper-faithful baseline: plain GSPMD layouts, no beyond-paper
        # optimizations (seq-parallel attention, 2D serving MoE, serve rules)
        cfg = dataclasses.replace(cfg, attn_seqpar=False)
        os.environ["REPRO_MOE_SMALL_T"] = "0"
        rules_kind = "train"
    else:
        os.environ.pop("REPRO_MOE_SMALL_T", None)
    if kv_dtype:
        cfg = dataclasses.replace(cfg, kv_dtype=kv_dtype)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(str(s) for s in mesh.devices.shape),
           "multi_pod": multi_pod, "kind": shape.kind,
           "baseline": baseline,
           "block_skip": block_skip, "microbatches": microbatches}
    if extra_tags:
        rec.update(extra_tags)
    t0 = time.time()
    bundle = steps_mod.build(cfg, mesh, shape, block_skip=block_skip,
                             microbatches=microbatches,
                             moment_dtype=jnp.dtype(moment_dtype),
                             rules_kind=rules_kind)
    with mesh:
        lowered = bundle.lower()
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    an = hlo_analysis.analyze(txt, n_chips(mesh))
    mf = roofline.model_flops_for(cfg, shape)
    rl = roofline.derive(an, n_chips=n_chips(mesh), model_flops=mf)

    rec.update({
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory_analysis": {
            "argument_bytes_per_device": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes_per_device": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes_per_device": getattr(ma, "temp_size_in_bytes", None),
            "alias_bytes_per_device": getattr(ma, "alias_size_in_bytes", None),
        },
        "xla_cost_analysis": {k: ca.get(k) for k in ("flops", "bytes accessed")},
        "hlo_analysis": an,
        "roofline": rl.as_dict(),
        "hlo_bytes": len(txt),
    })
    # peak per-device bytes: args + temp (aliased buffers counted once)
    args_b = rec["memory_analysis"]["argument_bytes_per_device"] or 0
    temp_b = rec["memory_analysis"]["temp_bytes_per_device"] or 0
    alias_b = rec["memory_analysis"]["alias_bytes_per_device"] or 0
    rec["peak_bytes_per_device"] = args_b + temp_b - alias_b
    rec["fits_16g_hbm"] = rec["peak_bytes_per_device"] < 16 * 1024 ** 3
    return rec


def live_cells():
    from repro.configs import ARCH_IDS, get_config
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in cfg.shapes():
            yield arch, shape.name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--block-skip", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--moment-dtype", default="float32")
    ap.add_argument("--baseline", action="store_true",
                    help="paper-faithful layouts; no beyond-paper opts")
    args = ap.parse_args()

    cells = list(live_cells()) if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    ok = fail = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'pod512' if mp else 'pod256'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag} (exists)")
                ok += 1
                continue
            try:
                rec = run_cell(arch, shape, mp,
                               block_skip=args.block_skip,
                               microbatches=args.microbatches,
                               moment_dtype=args.moment_dtype,
                               baseline=args.baseline)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                rl = rec["roofline"]
                print(f"[ok] {tag}: compile={rec['compile_s']}s "
                      f"bottleneck={rl['bottleneck']} step={rl['step_time_s']:.4f}s "
                      f"mfu={rl['mfu']:.3f} peak_dev_gb="
                      f"{rec['peak_bytes_per_device']/2**30:.2f}")
                ok += 1
            except Exception as e:
                fail += 1
                print(f"[FAIL] {tag}: {e}")
                traceback.print_exc()
    print(f"dryrun: {ok} ok, {fail} failed")
    return 1 if fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
