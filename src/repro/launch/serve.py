"""Production serving launcher: loads (or initializes) params, starts the
slot-based continuous-batching engine, and serves a synthetic request
stream (or stdin token prompts). Decode is device-resident by default:
`--decode-chunk K` fuses K decode+sample steps per host dispatch (one
host sync per K tokens); `--host-loop` falls back to the per-token
reference loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \\
        --slots 4 --window 1024 --decode-chunk 8 [--host-loop] \\
        [--reduced] [--ckpt-dir /ckpt/run1]
"""
from __future__ import annotations

import argparse
import dataclasses
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--window", type=int, default=1024)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--decode-chunk", type=int, default=8,
                    help="tokens generated per host dispatch (device mode)")
    ap.add_argument("--host-loop", action="store_true",
                    help="per-token host sampling loop (parity reference)")
    ap.add_argument("--kv-dtype", default=None, choices=[None, "int8"])
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore trained params from a checkpoint dir")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--stats", action="store_true",
                    help="attach the runtime telemetry collector and print "
                         "the window summary + per-request log")
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models.model import Model
    from repro.serving import ServeEngine
    from repro.serving.engine import Request

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), name=cfg.name,
                                  dtype="float32")
    if args.kv_dtype:
        cfg = dataclasses.replace(cfg, kv_dtype=args.kv_dtype)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    if args.ckpt_dir:
        from repro.checkpoint import CheckpointManager
        cm = CheckpointManager(args.ckpt_dir)
        step, restored = cm.restore_latest(
            jax.eval_shape(model.init, jax.random.key(0)))
        if restored is not None:
            # serving uses the master params cast to the compute dtype
            params = jax.tree.map(lambda a, s: a.astype(s.dtype), restored,
                                  jax.eval_shape(model.init,
                                                 jax.random.key(0)))
            print(f"restored params from step {step}")

    collector = None
    if args.stats:
        from repro.runtime import TelemetryCollector
        collector = TelemetryCollector()        # wall clock
    eng = ServeEngine(cfg, params, n_slots=args.slots, window=args.window,
                      mode="host" if args.host_loop else "device",
                      decode_chunk=args.decode_chunk, telemetry=collector)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                rng.integers(4, 32)).astype(np.int32),
            max_new_tokens=args.max_new, temperature=0.7 if i % 2 else 0.0))
    t0 = time.time()
    done, steps = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    mode = "host-loop" if args.host_loop else \
        f"device chunk={eng.decode_chunk}"
    print(f"served {len(done)} requests / {toks} tokens in {steps} engine "
          f"steps / {dt:.2f}s ({toks/max(dt,1e-9):.1f} tok/s, "
          f"{eng.host_syncs} host syncs = "
          f"{toks/max(eng.host_syncs,1):.1f} tok/sync, {mode})")
    if collector is not None:
        win = collector.snapshot()
        print(f"[telemetry] {win.decode_steps} decode steps, "
              f"mean batch {win.mean_batch:.2f}, "
              f"mean KV rows {win.mean_kv_rows:.1f}, "
              f"mean queue depth {win.mean_queue_depth:.2f}, "
              f"{win.prefill_tokens} prefill + {win.decode_tokens} decode "
              f"tokens over {win.duration_s:.2f}s")
        print(f"{'rid':>5} {'prompt':>7} {'emitted':>8} "
              f"{'queue_wait_s':>13} {'service_s':>10}")
        for st in sorted(eng.request_log, key=lambda s: s.rid):
            print(f"{st.rid:>5} {st.prompt_len:>7} {st.emitted:>8} "
                  f"{st.queue_wait_s:>13.4f} {st.service_s:>10.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
