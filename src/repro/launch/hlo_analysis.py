"""Trip-count-aware analysis of post-SPMD optimized HLO text.

Why this exists (EXPERIMENTS.md §Roofline methodology): XLA's built-in
``compiled.cost_analysis()`` visits each while-loop body ONCE, so any
program built around lax.scan (scan-over-layers, flash-attention KV scan,
microbatching) under-counts FLOPs/bytes by the trip count, and it reports
no per-collective breakdown at all. This module re-derives:

  * flops            - 2*M*N*K for every dot, multiplied through nested
                       while trip counts (parsed from loop conditions)
  * mem_bytes        - HBM-traffic proxy: OUTPUT bytes of every
                       materializing top-level op (each buffer written
                       once), x 1.5 for read-back by consumers. pred-dtype
                       buffers (masks) and broadcast/iota outputs are
                       excluded — on TPU those fuse into consumers.
                       CPU-fusion granularity makes this an upper-bound
                       flavored estimate; it is CONSISTENT across
                       configurations, which is what §Perf optimization
                       deltas require.
  * collectives      - wire bytes per op type with ring-algorithm
                       multipliers: all-reduce 2(g-1)/g, all-gather /
                       reduce-scatter / all-to-all (g-1)/g, permute 1

All numbers are PER-DEVICE (the HLO is the per-device SPMD program).
Conditional branches are counted at the max over branches (upper bound).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_SKIP_MEM = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "while", "conditional", "call", "after-all", "partition-id",
             "replica-id", "reshape", "broadcast", "iota"}
MEM_READBACK = 1.5
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast",
                "all-reduce-start", "all-gather-start",
                "collective-permute-start"}


def shape_bytes(type_str: str) -> float:
    """Bytes of an HLO type expression (handles tuples)."""
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    args: str = ""


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)  # value name -> type


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\((.*)\)\s*->.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\((?:[^()]|\([^()]*\))*\))|(?:[a-z]\w*\[[\d,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\((.*)$"
)
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*((?:\([^()]*\))|(?:[a-z]\w*\[[\d,]*\](?:\{[^}]*\})?))")


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _logical_lines(text: str):
    """Join wrapped statements: HLO pretty-printing breaks long tuple types
    and operand lists across physical lines; a new statement starts only at
    '%name =', a computation header, ENTRY, or '}'."""
    out: List[str] = []
    for raw in text.splitlines():
        s = _COMMENT_RE.sub("", raw).rstrip()
        if not s.strip():
            continue
        st = s.strip()
        new_stmt = (st.startswith("%") or st.startswith("ROOT ")
                    or st.startswith("ENTRY ") or st.startswith("HloModule")
                    or st == "}")
        if new_stmt or not out:
            out.append(s)
        else:
            out[-1] += " " + st
    return out


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for line in _logical_lines(text):
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
                for pm in _PARAM_RE.finditer(m.group(3)):
                    cur.types[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        _, name, type_str, opcode, rest = m.groups()
        # operand names: %foo refs inside the first balanced paren group
        depth, i = 1, 0
        while i < len(rest) and depth > 0:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        arg_str, attrs = rest[: i - 1], rest[i:]
        operands = re.findall(r"%([\w\.\-]+)", arg_str)
        cur.ops.append(Op(name, type_str, opcode, operands, attrs, arg_str))
        cur.types[name] = type_str
    if entry:
        comps["__entry__"] = comps[entry]
    return comps


def _attr_comp(attrs: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w\.\-]+)", attrs)
    return m.group(1) if m else None


def _attr_comps(attrs: str, key: str) -> List[str]:
    m = re.search(key + r"=\{([^}]*)\}", attrs)
    if not m:
        return []
    return re.findall(r"%?([\w\.\-]+)", m.group(1))


def _dims_attr(attrs: str, key: str) -> List[int]:
    m = re.search(key + r"=\{([\d,]*)\}", attrs)
    if not m or not m.group(1):
        return []
    return [int(x) for x in m.group(1).split(",")]


def group_size(attrs: str, default: int) -> int:
    # iota format: replica_groups=[G,S]<=[N]  (last dim = group size)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+", attrs)
    if m:
        return int(m.group(2))
    # explicit: replica_groups={{0,1,2},{3,4,5}}
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return default


def trip_count_from_backend_config(attrs: str) -> Optional[int]:
    """XLA records loop trip counts: backend_config={"known_trip_count":
    {"n":"4"},...} — the authoritative source."""
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', attrs)
    return int(m.group(1)) if m else None


def trip_count(comp: Computation) -> Tuple[int, bool]:
    """Fallback heuristic from a loop condition computation: the largest
    integer constant (jax scan/fori compare induction < constant)."""
    best = None
    for op in comp.ops:
        if op.opcode == "constant":
            m = re.search(r"^\s*(-?\d+)\s*$", op.args or "")
            if m:
                v = int(m.group(1))
                best = v if best is None else max(best, v)
        for m in re.finditer(r"constant\((-?\d+)\)", op.args + " " + op.attrs):
            v = int(m.group(1))
            best = v if best is None else max(best, v)
    if best is None or best <= 0:
        return 1, False
    return best, True


@dataclass
class Cost:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_wire: float = 0.0
    coll_by_type: Dict[str, float] = field(default_factory=dict)
    mem_by_shape: Dict[str, float] = field(default_factory=dict)
    coll_count: int = 0
    dot_count: int = 0
    unknown_trips: int = 0

    def add(self, other: "Cost", mult: float = 1.0, with_mem: bool = True):
        self.flops += other.flops * mult
        if with_mem:
            self.mem_bytes += other.mem_bytes * mult
            for k, v in other.mem_by_shape.items():
                self.mem_by_shape[k] = self.mem_by_shape.get(k, 0.0) + v * mult
        self.coll_wire += other.coll_wire * mult
        for k, v in other.coll_by_type.items():
            self.coll_by_type[k] = self.coll_by_type.get(k, 0.0) + v * mult
        self.coll_count += int(other.coll_count * mult)
        self.dot_count += int(other.dot_count * mult)
        self.unknown_trips += other.unknown_trips


def _dot_flops(op: Op, comp: Computation) -> float:
    if len(op.operands) < 2:
        return 0.0
    lhs_t = comp.types.get(op.operands[0])
    rhs_t = comp.types.get(op.operands[1])
    if lhs_t is None or rhs_t is None:
        return 0.0
    lhs, rhs = shape_dims(lhs_t), shape_dims(rhs_t)
    if lhs is None or rhs is None:
        return 0.0
    lc = _dims_attr(op.attrs, "lhs_contracting_dims")
    lb = _dims_attr(op.attrs, "lhs_batch_dims")
    rc = _dims_attr(op.attrs, "rhs_contracting_dims")
    rb = _dims_attr(op.attrs, "rhs_batch_dims")
    import numpy as np
    pl = float(np.prod(lhs)) if lhs else 1.0
    contract = 1.0
    for d in rc:
        contract *= rhs[d] if d < len(rhs) else 1
    batch = 1.0
    for d in rb:
        batch *= rhs[d] if d < len(rhs) else 1
    pr = float(np.prod(rhs)) if rhs else 1.0
    n_free_rhs = pr / max(contract * batch, 1.0)
    return 2.0 * pl * n_free_rhs


_WIRE_MULT = {
    "all-reduce": lambda g: 2.0 * (g - 1) / g,
    "all-reduce-start": lambda g: 2.0 * (g - 1) / g,
    "all-gather": lambda g: (g - 1) / g,
    "all-gather-start": lambda g: (g - 1) / g,
    "reduce-scatter": lambda g: (g - 1) / g,
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
    "collective-permute-start": lambda g: 1.0,
    "collective-broadcast": lambda g: 1.0,
}


def analyze(text: str, n_devices: int) -> dict:
    comps = parse_hlo(text)
    memo: Dict[str, Cost] = {}

    def cost_of(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()  # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        c = Cost()
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                body = _attr_comp(op.attrs, "body")
                cond = _attr_comp(op.attrs, "condition")
                tc = trip_count_from_backend_config(op.attrs)
                known = tc is not None
                if not known and cond and cond in comps:
                    tc, known = trip_count(comps[cond])
                tc = tc or 1
                if body:
                    c.add(cost_of(body), mult=tc)
                if not known:
                    c.unknown_trips += 1
                continue
            if oc == "conditional":
                branches = _attr_comps(op.attrs, "branch_computations")
                if not branches:
                    t = _attr_comp(op.attrs, "true_computation")
                    f = _attr_comp(op.attrs, "false_computation")
                    branches = [b for b in (t, f) if b]
                if branches:
                    subs = [cost_of(b) for b in branches]
                    best = max(subs, key=lambda s: s.flops + s.mem_bytes)
                    c.add(best)
                continue
            if oc in ("call", "fusion", "map", "reduce", "reduce-window",
                      "scatter", "sort", "select-and-scatter"):
                sub = _attr_comp(op.attrs, "to_apply") or _attr_comp(
                    op.attrs, "calls")
                if sub:
                    # inner ops of a fusion don't touch HBM: flops only
                    c.add(cost_of(sub), with_mem=False)
            if oc == "dot":
                c.flops += _dot_flops(op, comp)
                c.dot_count += 1
            if oc in _COLLECTIVES:
                g = group_size(op.attrs, n_devices)
                in_bytes = sum(shape_bytes(comp.types.get(o, ""))
                               for o in op.operands)
                base = shape_bytes(op.type_str) if "gather" in oc else in_bytes
                wire = _WIRE_MULT.get(oc, lambda g: 1.0)(max(g, 1)) * base
                c.coll_wire += wire
                c.coll_by_type[oc.replace("-start", "")] = \
                    c.coll_by_type.get(oc.replace("-start", ""), 0.0) + wire
                c.coll_count += 1
            if oc not in _SKIP_MEM and not oc.endswith("-done"):
                if op.type_str.startswith("pred"):
                    continue  # masks fuse into consumers on TPU
                if oc == "dynamic-update-slice" and len(op.operands) >= 2:
                    # in-place cache write: traffic = the UPDATE slice, not
                    # the whole (layer-stacked) buffer the op returns
                    b = MEM_READBACK * shape_bytes(
                        comp.types.get(op.operands[1], ""))
                else:
                    b = MEM_READBACK * shape_bytes(op.type_str)
                c.mem_bytes += b
                m = _SHAPE_RE.search(op.type_str)
                key = m.group(0) if m else "?"
                c.mem_by_shape[key] = c.mem_by_shape.get(key, 0.0) + b
        memo[name] = c
        return c

    entry = cost_of("__entry__")
    top_shapes = dict(sorted(entry.mem_by_shape.items(),
                             key=lambda kv: -kv[1])[:32])
    return {
        "flops": entry.flops,
        "mem_bytes": entry.mem_bytes,
        "collective_wire_bytes": entry.coll_wire,
        "collective_by_type": entry.coll_by_type,
        "mem_by_shape_top": top_shapes,
        "collective_count": entry.coll_count,
        "dot_count": entry.dot_count,
        "unknown_trip_counts": entry.unknown_trips,
    }
