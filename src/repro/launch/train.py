"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \\
        --shape train_4k --steps 1000 --ckpt-dir /ckpt/run1 \\
        [--mesh 16x16 | --mesh 2x16x16] [--microbatches 4] [--reduced]

On real hardware the mesh axes map onto the fleet via jax.distributed
(initialize() is called when JAX_COORDINATOR is set); on this CPU
container use --reduced --mesh 1x1 for a functional end-to-end run.
Restarting the same command resumes from the newest committed checkpoint
(elastic: the mesh may differ between runs).
"""
from __future__ import annotations

import argparse
import dataclasses
import os


def parse_mesh(spec: str):
    import jax
    dims = tuple(int(x) for x in spec.split("x"))
    if len(dims) == 3:
        return jax.make_mesh(dims, ("pod", "data", "model"))
    if len(dims) == 2:
        return jax.make_mesh(dims, ("data", "model"))
    return jax.make_mesh(dims, ("data",))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default=None,
                    help="e.g. 16x16 or 2x16x16; default: all devices as data")
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU bring-up)")
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):
        import jax
        jax.distributed.initialize()

    import jax
    from repro.configs import get_config, SHAPES, ShapeConfig
    from repro.training import Trainer, TrainConfig

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), name=cfg.name,
                                  dtype="float32")
        shape = ShapeConfig(shape.name, min(shape.seq_len, 128),
                            min(shape.global_batch, 8), shape.kind)
    mesh = parse_mesh(args.mesh) if args.mesh else \
        jax.make_mesh((len(jax.devices()),), ("data",))

    tr = Trainer(cfg, mesh, shape,
                 TrainConfig(total_steps=args.steps,
                             ckpt_every=args.ckpt_every,
                             ckpt_dir=args.ckpt_dir, seed=args.seed,
                             microbatches=args.microbatches))
    state, hist = tr.run()
    if hist:
        print(f"done: step {hist[-1]['step']} loss {hist[-1]['loss']:.4f}; "
              f"stats {tr.stats}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
