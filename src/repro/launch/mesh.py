"""Production mesh definitions (functions, never module-level constants, so
importing this module never touches jax device state).

Single pod:  (16, 16)      ("data", "model")   = 256 chips (TPU v5e pod)
Multi pod:   (2, 16, 16)   ("pod", "data", "model") = 512 chips

The dry-run (launch/dryrun.py) sets XLA_FLAGS host-device-count=512 before
any jax import; tests use make_test_mesh() over however many devices exist.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 1):
    """Small mesh over available (or forced) host devices for tests."""
    n = len(jax.devices())
    need = data * model * pod
    if n < need:
        raise RuntimeError(f"need {need} devices, have {n}")
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def data_axis_names(mesh) -> tuple:
    """Mesh axes that shard the batch (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_chips(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
