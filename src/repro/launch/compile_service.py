"""Multi-tenant GCRAM compile service over the coalescing executor.

The query API (`repro.api`) is in-process; this module is the
PROCESS-LEVEL front end the ROADMAP's production story needs: many
tenants (DSE jobs, co-design agents, CI) post JSON query requests onto
one queue, and a single session drains them in ADMISSION WAVES through
`Session.run_many` — so concurrently submitted queries coalesce
(identical plan nodes execute once, distinct lattice evaluations union
into one padded device batch) and, with `--store`, every artifact
lands in the shared content-addressed on-disk cache where the next
service process (or any other session) finds it.

Request (one JSON object per line; `id` echoes back, `tenant` is
accounting only — isolation is by content, not by tenant):

    {"id": "r1", "tenant": "teamA",
     "query": {"type": "sweep", "cells": ["gc2t_nn"],
               "word_sizes": [16, 32], "num_words": [16, 32]}}

`type` is one of compile | sweep | match | codesign | optimize, with
fields mirroring the Query dataclasses (demands as dicts, codesign
profiles as {"arch", "shape"} pairs resolved via the workload
profiler). Responses stream back one JSON line per request, in request
order per wave:

    {"id": "r1", "tenant": "teamA", "ok": true, "wave": 0,
     "result": {...Result.as_dict()...}}

Errors (bad JSON, unknown type, invalid query construction, node
failures) resolve ONLY the offending request — the rest of the wave
completes: {"ok": false, "error": "..."}.

CLI (used by CI and benchmarks/bench_service.py):

    PYTHONPATH=src python -m repro.launch.compile_service \
        --input requests.jsonl --wave-size 64 --store /tmp/gcram-store
"""
from __future__ import annotations

import argparse
import json
import queue
import sys
import threading
import time
from typing import Iterable, Iterator, List, Optional

from repro.api import Session
from repro.api.queries import (CoDesignQuery, CompileQuery, MatchQuery,
                               OptimizeQuery, Query, SweepQuery)
from repro.core.bank import BankConfig
from repro.core.dse import Demand
from repro.core.techfile import SYN40

__all__ = ["CompileService", "parse_query"]


# ---------------------------------------------------------------------------
# JSON -> Query
# ---------------------------------------------------------------------------

_SWEEP_TUPLES = ("cells", "word_sizes", "num_words", "write_vts", "wwlls")
_SWEEP_SCALARS = ("batched", "fidelity", "sim_steps", "solver",
                  "precision")


def _parse_sweep(spec: dict) -> SweepQuery:
    kw = {}
    for f in _SWEEP_TUPLES:
        if f in spec:
            kw[f] = tuple(spec[f])
    for f in _SWEEP_SCALARS:
        if f in spec:
            kw[f] = spec[f]
    return SweepQuery(**kw)


def _parse_demand(spec: dict) -> Demand:
    return Demand(spec["name"], spec["level"],
                  float(spec["read_freq_hz"]), float(spec["lifetime_s"]),
                  int(spec.get("capacity_bits", 0)))


def _parse_cfg(spec: dict, tech) -> BankConfig:
    kw = {k: spec[k] for k in ("word_size", "num_words", "cell",
                               "write_vt", "wwlls", "wwl_boost")
          if k in spec}
    return BankConfig(tech=tech, **kw)


def parse_query(spec: dict, tech=SYN40) -> Query:
    """One request's `query` object -> the matching frozen Query.
    Validation happens in the Query constructors themselves, so an
    invalid request fails here — before it is queued or coalesced."""
    kind = spec.get("type")
    if kind == "sweep":
        return _parse_sweep(spec)
    if kind == "compile":
        return CompileQuery(_parse_cfg(spec.get("cfg", {}), tech),
                            simulate=bool(spec.get("simulate", False)),
                            solver=spec.get("solver", "jnp"))
    if kind == "match":
        return MatchQuery(
            tuple(_parse_demand(d) for d in spec.get("demands", ())),
            _parse_sweep(spec.get("sweep", {})),
            allow_refresh=bool(spec.get("allow_refresh", True)),
            max_banks=int(spec.get("max_banks", 1024)))
    if kind == "codesign":
        from repro.workloads.profiler import profile_arch
        profiles = tuple(profile_arch(p["arch"], p["shape"])
                         for p in spec.get("profiles", ()))
        kw = {}
        if "vdd_scales" in spec:
            kw["vdd_scales"] = tuple(spec["vdd_scales"])
        return CoDesignQuery(
            profiles, _parse_sweep(spec.get("sweep", {})),
            allow_refresh=bool(spec.get("allow_refresh", True)),
            max_banks=int(spec.get("max_banks", 1024)),
            objective=spec.get("objective", "energy"), **kw)
    if kind == "optimize":
        kw = {k: spec[k] for k in ("cell", "target_ret_s",
                                   "target_freq_hz", "steps", "lr")
              if k in spec}
        return OptimizeQuery(**kw)
    raise ValueError(f"unknown query type {kind!r} (compile | sweep | "
                     "match | codesign | optimize)")


# ---------------------------------------------------------------------------
# the service
# ---------------------------------------------------------------------------

class CompileService:
    """One coalescing Session behind a thread-safe request queue.

    `submit(request)` enqueues (any thread); `drain()` pops everything
    available — up to `wave_size` requests — and runs it as ONE
    admission wave, returning the JSON-able responses in request order.
    Tenants share all artifact caches by content, which is safe because
    node keys hash the full evaluation payload (tech + lattice-shaping
    fields): a tenant can only ever hit cache entries it would have
    computed identically itself."""

    def __init__(self, session: Optional[Session] = None, tech=SYN40,
                 store=None, wave_size: int = 64):
        self.session = session if session is not None \
            else Session(tech, store=store)
        self.wave_size = int(wave_size)
        self.queue: "queue.Queue[dict]" = queue.Queue()
        self.waves = 0
        self.tenants: dict = {}
        # notified on every submit (and at EOF) so serve_stream can
        # wait event-driven instead of busy-polling an idle queue
        self._arrival = threading.Condition()

    # -- request intake ------------------------------------------------
    def submit(self, request: dict) -> None:
        self.queue.put(dict(request))
        with self._arrival:
            self._arrival.notify_all()

    def submit_line(self, line: str) -> None:
        try:
            req = json.loads(line)
            if not isinstance(req, dict):
                raise ValueError("request must be a JSON object")
        except ValueError as e:
            req = {"_parse_error": f"bad request line: {e}"}
        self.submit(req)

    # -- wave processing ----------------------------------------------
    def _account(self, tenant: str, ok: bool) -> None:
        t = self.tenants.setdefault(tenant, {"requests": 0, "errors": 0})
        t["requests"] += 1
        t["errors"] += 0 if ok else 1

    def drain(self) -> List[dict]:
        """Process one admission wave; returns [] when the queue is
        empty."""
        reqs: List[dict] = []
        while len(reqs) < self.wave_size:
            try:
                reqs.append(self.queue.get_nowait())
            except queue.Empty:
                break
        if not reqs:
            return []
        wave = self.waves
        self.waves += 1
        pending = []                      # (request, future-or-None, err)
        for req in reqs:
            err = req.get("_parse_error")
            if err is None:
                try:
                    q = parse_query(req.get("query") or {},
                                    self.session.tech)
                    pending.append((req, self.session.submit(q), None))
                    continue
                except Exception as e:               # noqa: BLE001
                    err = f"{type(e).__name__}: {e}"
            pending.append((req, None, err))
        t0 = time.time()
        self.session.flush()
        wall = time.time() - t0
        out = []
        for req, fut, err in pending:
            tenant = req.get("tenant", "anonymous")
            resp = {"id": req.get("id"), "tenant": tenant, "wave": wave}
            retryable = False
            if err is None:
                e = fut.exception()
                if e is None:
                    # result extraction and serialization can raise too
                    # (a Result whose as_dict trips, a value json can't
                    # encode): that failure must resolve ONLY this
                    # request, like every other per-request error path
                    try:
                        result = fut.result().as_dict()
                        json.dumps(result, default=str)
                        resp["ok"] = True
                        resp["result"] = result
                    except Exception as e2:              # noqa: BLE001
                        err = ("response serialization failed: "
                               f"{type(e2).__name__}: {e2}")
                else:
                    # node/evaluation failures may be transient (a fleet
                    # dispatcher retries them); parse and serialization
                    # failures are deterministic and are not
                    err = f"{type(e).__name__}: {e}"
                    retryable = True
            if err is not None:
                resp["ok"] = False
                resp["error"] = err
                resp["retryable"] = retryable
            self._account(tenant, resp["ok"])
            out.append(resp)
        if out:
            out[-1]["wave_wall_s"] = round(wall, 4)
        return out

    def serve_lines(self, lines: Iterable[str]) -> Iterator[str]:
        """Stream request lines -> response lines, draining a wave every
        `wave_size` requests and at end of input. Suits finite inputs
        (files, closed pipes); for a long-lived producer that may hold
        the stream open use `serve_stream`, which drains partial waves
        after an idle window instead of waiting for EOF."""
        for line in lines:
            if not line.strip():
                continue
            self.submit_line(line)
            if self.queue.qsize() >= self.wave_size:
                for resp in self.drain():
                    yield json.dumps(resp, default=str)
        while True:
            wave = self.drain()
            if not wave:
                break
            for resp in wave:
                yield json.dumps(resp, default=str)

    def serve_stream(self, lines: Iterable[str],
                     max_wait_s: float = 0.05) -> Iterator[str]:
        """Like serve_lines, but for LIVE streams (stdin from a
        long-running tenant, a FIFO): a background reader feeds the
        queue while waves drain as soon as `wave_size` accumulates OR
        the stream goes quiet for `max_wait_s` — a small tenant batch
        gets its responses without waiting for EOF or a full wave.

        Fully event-driven: an idle service BLOCKS on the arrival
        condition (zero wake-ups, no busy-poll); once a request lands,
        the admission window is a timed condition wait that ends the
        moment the wave fills or the stream hits EOF, and is bounded by
        `max_wait_s` so partial waves still drain on time."""
        eof = threading.Event()

        def reader():
            try:
                for line in lines:
                    if line.strip():
                        self.submit_line(line)
            finally:
                eof.set()
                with self._arrival:
                    self._arrival.notify_all()

        threading.Thread(target=reader, daemon=True).start()
        while True:
            with self._arrival:
                # idle: sleep until a request (or EOF) arrives
                while self.queue.empty() and not eof.is_set():
                    self._arrival.wait()
                if self.queue.empty() and eof.is_set():
                    break
                # admission window: gather arrivals until the wave is
                # full, the producer closes, or max_wait_s elapses
                deadline = time.monotonic() + max_wait_s
                while (self.queue.qsize() < self.wave_size
                       and not eof.is_set()):
                    remaining = deadline - time.monotonic()
                    if remaining <= 0 or not self._arrival.wait(remaining):
                        break
            for resp in self.drain():
                yield json.dumps(resp, default=str)

    def stats(self) -> dict:
        ex = self.session.executor
        out = {"waves": self.waves, "tenants": self.tenants,
               "executor": dict(ex.stats)}
        if self.session.store is not None:
            out["store"] = self.session.store.stats()
        return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--input", default="-",
                    help="JSONL request file, or - for stdin")
    ap.add_argument("--output", default="-",
                    help="JSONL response file, or - for stdout")
    ap.add_argument("--wave-size", type=int, default=64)
    ap.add_argument("--wait", type=float, default=0.05,
                    help="stdin mode: idle window (s) before draining "
                         "a partial wave")
    ap.add_argument("--store", default=None,
                    help="artifact-store directory shared across runs")
    ap.add_argument("--leases", action="store_true",
                    help="claim evaluations via file leases on the "
                         "store directory (run N services against one "
                         "store without duplicating work)")
    args = ap.parse_args(argv)
    session = Session(store=args.store, leases=args.leases or None)
    svc = CompileService(session=session, wave_size=args.wave_size)
    src = sys.stdin if args.input == "-" else open(args.input)
    # stdin may be a long-lived pipe: drain partial waves after an idle
    # window so small batches are answered without waiting for EOF
    serve = (lambda s: svc.serve_stream(s, max_wait_s=args.wait)) \
        if src is sys.stdin else svc.serve_lines
    dst = sys.stdout if args.output == "-" else open(args.output, "w")
    try:
        n_err = 0
        for line in serve(src):
            dst.write(line + "\n")
            dst.flush()
            n_err += not json.loads(line)["ok"]
    finally:
        if src is not sys.stdin:
            src.close()
        if dst is not sys.stdout:
            dst.close()
    print(json.dumps(svc.stats(), default=str), file=sys.stderr)
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
