"""Step builders: compose Model x mesh x optimizer into jit-able
train_step / prefill / decode_step functions together with fully-sharded
input ShapeDtypeStructs (what the dry-run lowers and what train.py runs).

Shape kinds (configs/base.SHAPES):
  train    -> train_step(state, batch)  [fp32 master params + opt state]
  prefill  -> prefill(params, batch)    [bf16 serving params]
  decode   -> decode_step(params, cache, token, pos)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import sharding_ctx
from repro.models.model import Model
from repro.optim import make_optimizer, make_schedule
from repro.launch.sharding import make_rules, sharding_tree, sds


@dataclass
class StepBundle:
    kind: str
    fn: Callable                 # python fn (enter sharding ctx at trace)
    in_specs: tuple              # ShapeDtypeStructs with shardings
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple
    model: Model
    rules: dict
    meta: dict

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jitted().lower(*self.in_specs)


def _cast_like(tree, shape_tree):
    return jax.tree.map(lambda x, s: x.astype(s.dtype), tree, shape_tree)


def make_schedule_for(cfg, total_steps=10000):
    return make_schedule(cfg.schedule, peak_lr=3e-4,
                         warmup_steps=max(1, total_steps // 100),
                         total_steps=total_steps)


def batch_specs(cfg, shape, mesh=None, rules=None):
    """ShapeDtypeStructs for the host data batch of this (arch, shape)."""
    GB, S = shape.global_batch, shape.seq_len
    sh = None
    if mesh is not None:
        n_data = int(np.prod([mesh.shape[a] for a in rules["batch"]]))
        spec = P(rules["batch"]) if GB % max(n_data, 1) == 0 else P()
        sh = NamedSharding(mesh, spec)
    out = {}
    if cfg.family == "vlm":
        st = S - cfg.n_patches
        out["tokens"] = sds((GB, st), jnp.int32, sh)
        out["labels"] = sds((GB, st), jnp.int32, sh)
        out["patches"] = sds((GB, cfg.n_patches, cfg.d_model), jnp.bfloat16, sh)
    else:
        out["tokens"] = sds((GB, S), jnp.int32, sh)
        out["labels"] = sds((GB, S), jnp.int32, sh)
        if cfg.family == "audio":
            out["frames"] = sds((GB, cfg.enc_frames, cfg.d_model), jnp.bfloat16, sh)
    return out


def build(cfg, mesh, shape, *, block_skip=False, microbatches=1,
          total_steps=10000, moment_dtype=jnp.float32, rules_kind=None):
    rules = make_rules(mesh, batch_size=shape.global_batch,
                       kind=rules_kind or shape.kind)
    model = Model(cfg, mesh=mesh, block_skip=block_skip)
    pspecs = model.param_specs()
    pshapes = jax.eval_shape(model.init, jax.random.key(0))

    if shape.kind == "train":
        return _build_train(cfg, mesh, shape, model, rules, pspecs, pshapes,
                            microbatches, total_steps, moment_dtype)
    if shape.kind == "prefill":
        return _build_prefill(cfg, mesh, shape, model, rules, pspecs, pshapes)
    return _build_decode(cfg, mesh, shape, model, rules, pspecs, pshapes)


# ---------------------------------------------------------------------------

def _build_train(cfg, mesh, shape, model, rules, pspecs, pshapes,
                 microbatches, total_steps, moment_dtype):
    opt = make_optimizer(cfg, make_schedule_for(cfg, total_steps),
                         moment_dtype=moment_dtype)
    master_shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), pshapes)
    opt_shapes = jax.eval_shape(opt.init, master_shapes)
    opt_specs = opt.state_specs(pspecs, master_shapes)

    p_sh = sharding_tree(pspecs, master_shapes, rules, mesh)
    o_sh = sharding_tree(opt_specs, opt_shapes, rules, mesh)
    rep = NamedSharding(mesh, P())

    bspecs = batch_specs(cfg, shape, mesh, rules)
    state_specs_in = {
        "params": jax.tree.map(lambda s, sh: sds(s.shape, s.dtype, sh),
                               master_shapes, p_sh),
        "opt": jax.tree.map(lambda s, sh: sds(s.shape, s.dtype, sh),
                            opt_shapes, o_sh),
        "step": sds((), jnp.int32, rep),
    }
    state_shardings = {"params": p_sh, "opt": o_sh, "step": rep}
    metrics_shardings = {"loss": rep, "ce": rep, "aux": rep,
                         "grad_norm": rep, "lr": rep}

    def train_step(state, batch):
        with sharding_ctx(mesh, rules):
            def lossfn(master):
                p = _cast_like(master, pshapes)  # fp32 master -> compute dtype
                l, m = model.loss(p, batch)
                return l, m

            if microbatches > 1:
                mb = jax.tree.map(
                    lambda x: x.reshape(microbatches,
                                        x.shape[0] // microbatches,
                                        *x.shape[1:]), batch)

                def acc_body(carry, mbatch):
                    gsum, lsum, msum = carry
                    def lf(master):
                        p = _cast_like(master, pshapes)
                        return model.loss(p, mbatch)
                    (l, m), g = jax.value_and_grad(lf, has_aux=True)(
                        state["params"])
                    gsum = jax.tree.map(jnp.add, gsum, g)
                    return (gsum, lsum + l, {k: msum[k] + m[k] for k in m}), None

                g0 = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32),
                                  master_shapes)
                (grads, loss, met), _ = jax.lax.scan(
                    acc_body,
                    (g0, jnp.float32(0), {"ce": jnp.float32(0), "aux": jnp.float32(0)}),
                    mb)
                grads = jax.tree.map(lambda g: g / microbatches, grads)
                loss = loss / microbatches
                met = {k: v / microbatches for k, v in met.items()}
            else:
                (loss, met), grads = jax.value_and_grad(lossfn, has_aux=True)(
                    state["params"])

            newp, newopt, stats = opt.update(grads, state["opt"],
                                             state["params"], state["step"])
            # NaN/overflow guard: a non-finite loss or grad norm turns the
            # update into a no-op (state buffers are donated, so the guard
            # must live inside the step, not in the host loop).
            good = jnp.isfinite(loss) & jnp.isfinite(stats["grad_norm"])
            sel = lambda a, b: jax.tree.map(
                lambda x, y: jnp.where(good, x, y), a, b)
            newp = sel(newp, state["params"])
            newopt = sel(newopt, state["opt"])
            metrics = {"loss": loss, "ce": met["ce"], "aux": met["aux"],
                       "grad_norm": stats["grad_norm"], "lr": stats["lr"]}
            return {"params": newp, "opt": newopt,
                    "step": state["step"] + 1}, metrics

    in_specs = (state_specs_in, bspecs)
    in_shardings = (state_shardings,
                    jax.tree.map(lambda s: s.sharding, bspecs))
    out_shardings = (state_shardings, metrics_shardings)
    return StepBundle("train", train_step, in_specs, in_shardings,
                      out_shardings, (0,), model, rules,
                      {"opt": opt, "pshapes": pshapes,
                       "master_shapes": master_shapes, "opt_shapes": opt_shapes,
                       "p_sh": p_sh, "o_sh": o_sh})


def _build_prefill(cfg, mesh, shape, model, rules, pspecs, pshapes):
    p_sh = sharding_tree(pspecs, pshapes, rules, mesh)
    param_specs_in = jax.tree.map(lambda s, sh: sds(s.shape, s.dtype, sh),
                                  pshapes, p_sh)
    bspecs = batch_specs(cfg, shape, mesh, rules)
    rep = NamedSharding(mesh, P())

    def prefill(params, batch):
        with sharding_ctx(mesh, rules):
            logits, cache, pos = model.prefill(params, batch)
            return logits, cache, pos

    # cache out shardings: infer from cache specs
    W = model.kv_window(shape.seq_len)
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, W))
    c_sh = sharding_tree(model.cache_specs(), cache_shapes, rules, mesh)
    out_shardings = (rep, c_sh, rep)
    in_specs = (param_specs_in, bspecs)
    in_shardings = (p_sh, jax.tree.map(lambda s: s.sharding, bspecs))
    return StepBundle("prefill", prefill, in_specs, in_shardings,
                      out_shardings, (), model, rules,
                      {"p_sh": p_sh, "cache_shapes": cache_shapes,
                       "c_sh": c_sh})


def _build_decode(cfg, mesh, shape, model, rules, pspecs, pshapes):
    p_sh = sharding_tree(pspecs, pshapes, rules, mesh)
    param_specs_in = jax.tree.map(lambda s, sh: sds(s.shape, s.dtype, sh),
                                  pshapes, p_sh)
    GB = shape.global_batch
    W = model.kv_window(shape.seq_len)
    cache_shapes = jax.eval_shape(lambda: model.init_cache(GB, W))
    c_sh = sharding_tree(model.cache_specs(), cache_shapes, rules, mesh)
    cache_specs_in = jax.tree.map(lambda s, sh: sds(s.shape, s.dtype, sh),
                                  cache_shapes, c_sh)
    n_data = int(np.prod([mesh.shape[a] for a in rules["batch"]]))
    bspec = P(rules["batch"]) if GB % max(n_data, 1) == 0 else P()
    bsh = NamedSharding(mesh, bspec)
    rep = NamedSharding(mesh, P())

    def decode_step(params, cache, token, pos):
        with sharding_ctx(mesh, rules):
            logits, cache = model.decode_step(params, cache, token, pos)
            return logits, cache

    in_specs = (param_specs_in, cache_specs_in,
                sds((GB, 1), jnp.int32, bsh), sds((GB,), jnp.int32, bsh))
    in_shardings = (p_sh, c_sh, bsh, bsh)
    out_shardings = (bsh, c_sh)
    return StepBundle("decode", decode_step, in_specs, in_shardings,
                      out_shardings, (1,), model, rules,
                      {"p_sh": p_sh, "cache_shapes": cache_shapes, "c_sh": c_sh})
