"""GainSight-analogue workload profiler (paper Table I + Fig 9).

The paper profiles NVIDIA L1/L2 cache demands per AI task and matches
them against GCRAM configs. Here the workloads are OUR ten assigned
architectures x shapes, profiled on the TPU-v5e-target memory hierarchy
from the compiled dry-run artifacts (DESIGN.md §2 assumption 4):

  per (arch, shape):
    step_time        roofline step bound (launch/roofline.py)
    traffic classes  weights / kv-state / activations bytes per step
                     (analytic from the config; cross-checked against the
                     dry-run's HLO bytes)
    "L1" demand      per-CORE working-buffer request rate: the chip's
                     operand feed split over n_cores x banks_per_core
                     L1 instances; lifetime ~ one layer
    "L2" demand      the SHARED level: aggregate L1 misses (AI workloads
                     stream — low L1 reuse, miss ratio ~0.6) plus the
                     weight/KV stream, split over the few wide L2 banks.
                     This is the paper's "counterintuitive" Fig 9 finding:
                     L2 per-bank read frequency EXCEEDS L1's because L2 is
                     shared by all cores; lifetime = class reuse interval

Demands feed core/dse.shmoo — the Fig 10 reproduction.
"""
from __future__ import annotations

import glob
import json
import math
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core.dse import Demand
from repro.launch import roofline as rl

# hierarchy shape (H100-class, matching GainSight's profiling target);
# L1_MISS=0.25: tiled GEMMs reuse operands in L1, attention/streams miss.
# Module-level so measured profiles (repro.runtime.profile) split their
# traffic over the SAME hierarchy as the analytic ones.
N_CORES = 128
BANKS_PER_CORE = 8
L2_BANKS = 128
L1_MISS = 0.25
REUSE_DEPTH = 64          # operand-reuse window amortizing the L1 feed
WORD_BYTES = 4.0          # bytes per cache request


def hierarchy_split(flops_per_s: float, stream_bytes_per_s: float):
    """Split one device's compute + HBM-stream rates into PER-INSTANCE
    L1/L2 read Hz on the profiled hierarchy — the single source of truth
    for both analytic (`profile_config`) and measured
    (`repro.runtime.profile.measured_profile`) profiles.

    Operand feed: ~2 words/MAC amortized over a REUSE_DEPTH-deep reuse
    window; L2 sees the L1 miss stream plus the class (weight/KV/act)
    stream, divided over the few wide L2 banks — the paper's Fig 9
    "shared L2 exceeds L1 per-bank rate" effect."""
    l1_bw = flops_per_s * 2 * 2 / REUSE_DEPTH      # bytes/s on-chip feed
    l1_per_bank = l1_bw / (N_CORES * BANKS_PER_CORE) / WORD_BYTES
    l2_per_bank = (L1_MISS * l1_bw + stream_bytes_per_s) / L2_BANKS \
        / WORD_BYTES
    return l1_per_bank, l2_per_bank


@dataclass(frozen=True)
class Profile:
    """One (arch, shape) workload's memory-demand profile.

    Units: times/lifetimes in seconds, traffic in bytes per step,
    `l1_read_hz` / `l2_read_hz` in PER-INSTANCE request Hz — the
    aggregate on-chip feed is already split over the profiled
    hierarchy's (cores x banks) memory instances. Single-bank
    feasibility compares these directly against a bank's `f_max_hz`;
    when one bank falls short, multibanking covers the same rate in
    aggregate (the `core.dse.Demand` convention).
    Frozen (hashable) so `repro.api.CoDesignQuery` tuples of Profiles can
    key session memoization."""
    arch: str
    shape: str
    kind: str
    step_time_s: float
    weights_bytes: float
    kv_bytes: float
    act_bytes_per_layer: float
    weight_reuse_s: float        # lifetime demand for weight memory (s)
    kv_lifetime_s: float
    act_lifetime_s: float
    l1_read_hz: float
    l2_read_hz: float

    def demands(self) -> List[Demand]:
        """The profile's two cache-level Demands. Frequencies are
        per-instance Hz (already split over the hierarchy's banks — see
        class docstring), lifetimes seconds."""
        return [
            Demand(f"{self.arch}:{self.shape}", "L1",
                   self.l1_read_hz, self.act_lifetime_s),
            Demand(f"{self.arch}:{self.shape}", "L2",
                   self.l2_read_hz,
                   max(self.kv_lifetime_s, self.act_lifetime_s)),
        ]


def _bytes_classes(cfg, shape):
    """Analytic per-step traffic per class (bf16)."""
    from repro.models.model import Model
    m = Model(cfg)
    n_params = m.param_count()
    n_active = m.param_count(active_only=True)
    wb = 2.0 * n_active                       # one stream of active weights
    if shape.kind == "train":
        wb *= 3.0                             # fwd + bwd(dgrad+wgrad)
    toks = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    act = 2.0 * toks * cfg.d_model * 12       # ~12 materialized tensors/layer
    kv = 0.0
    if shape.kind != "train":
        W = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
        kv = (2.0 * cfg.n_layers * shape.global_batch * W
              * cfg.n_kv_heads * cfg.hd() * 2)
        if cfg.ssm_state:
            kv += (cfg.n_layers * shape.global_batch * 4
                   * (cfg.d_model * cfg.ssm_expand // max(cfg.ssm_headdim, 1))
                   * cfg.ssm_headdim * cfg.ssm_state)
    return wb, kv, act


def profile_config(cfg, shape, *, arch_name: Optional[str] = None,
                   shape_name: Optional[str] = None, n_devices: int = 256,
                   step_time_s: Optional[float] = None) -> Profile:
    """Analytic profile of an explicit (config, shape) on an
    `n_devices`-way pod. Demands are derived at TARGET efficiency — 50%
    MFU for train/prefill, HBM-stream-bound for decode — so the memory
    system is sized for what the accelerator is SUPPOSED to sustain, not
    for the current software baseline. `step_time_s` overrides the
    roofline step (used when diffing against MEASURED profiles, which
    observe a real per-step time)."""
    wb, kvb, act = _bytes_classes(cfg, shape)
    mf = rl.model_flops_for(cfg, shape)
    if step_time_s is not None:
        step = float(step_time_s)
    elif shape.kind == "decode":
        step = max((wb + kvb) / n_devices / rl.HBM_BW,
                   mf / (n_devices * rl.PEAK_FLOPS))
    else:
        step = mf / (n_devices * rl.PEAK_FLOPS) / 0.5
    L = cfg.n_layers + cfg.n_enc_layers

    layer_t = step / max(L, 1)
    decode_session = shape.seq_len * step if shape.kind == "decode" else step
    flops_dev = rl.model_flops_for(cfg, shape) / n_devices
    stream_bw = (wb + kvb + act) / n_devices / step  # HBM-side class stream
    l1_per_bank, l2_per_bank = hierarchy_split(flops_dev / step, stream_bw)
    return Profile(
        arch_name or cfg.name, shape_name or shape.name, shape.kind, step,
        wb, kvb, act / max(L, 1),
        weight_reuse_s=3600.0 * 24,                # weights live for the job
        kv_lifetime_s=decode_session,
        act_lifetime_s=layer_t,
        l1_read_hz=l1_per_bank,
        l2_read_hz=l2_per_bank,
    )


def profile_arch(arch: str, shape_name: str,
                 dryrun_record: Optional[dict] = None) -> Profile:
    """Profile a registered (arch, shape) pair on the 256-device pod
    (dryrun_record's own step is recorded for reference only)."""
    from repro.configs import get_config, SHAPES
    return profile_config(get_config(arch), SHAPES[shape_name],
                          arch_name=arch, shape_name=shape_name)


def profile_from_dryrun(results_dir: str) -> List[Profile]:
    out = []
    for path in sorted(glob.glob(f"{results_dir}/*pod256.json")):
        rec = json.load(open(path))
        out.append(profile_arch(rec["arch"], rec["shape"], rec))
    return out


def demands_table(profiles: List[Profile], **kw) -> List[Demand]:
    ds = []
    for p in profiles:
        ds.extend(p.demands(**kw))
    return ds


# ---------------------------------------------------------------------------
# memory-system planner: pick a GCRAM config per buffer class (the paper's
# "activation caches need us lifetimes; weight memory needs hours" §V-D)
# ---------------------------------------------------------------------------

def plan_memory(profile: Profile, points=None) -> Dict[str, dict]:
    """For each buffer class pick the smallest-area feasible GCRAM bank."""
    from repro.core import dse
    if points is None:
        from repro.api import Session
        points = Session().sweep().points
    classes = {
        "activation_cache": Demand("act", "L1", profile.l1_read_hz,
                                   profile.act_lifetime_s),
        "kv_state": Demand("kv", "L2", profile.l2_read_hz,
                           profile.kv_lifetime_s),
        "weight_memory": Demand("w", "L2", profile.l2_read_hz,
                                profile.weight_reuse_s),
    }
    plan = {}
    for name, d in classes.items():
        feas = [p for p in points if dse.feasible(p, d)]
        if feas:
            # prefer density: max bits/area among feasible
            best = max(feas, key=lambda p: p.cfg.bits / p.area_um2)
            plan[name] = {"feasible": True, **best.as_dict()}
        else:
            plan[name] = {"feasible": False,
                          "demand_hz": d.read_freq_hz,
                          "lifetime_s": d.lifetime_s}
    return plan
