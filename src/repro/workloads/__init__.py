from repro.workloads.profiler import (demands_table, hierarchy_split,
                                      profile_arch, profile_config,
                                      profile_from_dryrun)

__all__ = ["demands_table", "hierarchy_split", "profile_arch",
           "profile_config", "profile_from_dryrun"]
