from repro.workloads.profiler import profile_arch, profile_from_dryrun, demands_table
