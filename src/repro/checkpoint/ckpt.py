"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step):
    <dir>/step_000042/
        manifest.json     # tree structure, shapes, dtypes, mesh, step
        shard_00000.npz   # this host's param shards (addressable data only)
        ...
        COMMIT            # written LAST -> step-atomic visibility

Design points mirrored from production systems (Orbax/MaxText-style):
  * every host writes only its ADDRESSABLE shards; single-host CPU runs
    degrade to "host 0 writes everything" transparently;
  * a checkpoint is valid iff COMMIT exists (crash mid-write is invisible);
  * ASYNC save: arrays are device_get'd synchronously (cheap, sharded)
    then written on a background thread so the train loop keeps stepping;
  * ELASTIC restore: arrays are re-sharded to the CURRENT mesh at load
    (jax.make_array_from_callback against the saved global array), so an
    N-host checkpoint restores onto an M-host job (N != M) — rescale and
    failed-node-replacement both reduce to this;
  * retention: keep_last K steps are retained, older ones deleted.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flat_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
                os.path.join(directory, name, "COMMIT")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def save_checkpoint(directory: str, step: int, tree: Any, *,
                    host_id: int = 0) -> str:
    """Synchronous core: write this host's shards + manifest + COMMIT."""
    path = os.path.join(directory, f"step_{step:09d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    paths, leaves, _ = _flat_with_paths(tree)

    manifest = {"step": step, "leaves": [], "version": 1}
    arrays = {}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        manifest["leaves"].append({
            "path": p, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "index": i})
        # npz keys cannot contain '/': use leaf index
        arrays[f"a{i}"] = arr
    np.savez(os.path.join(tmp, f"shard_{host_id:05d}.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write(str(time.time()))
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)
    return path


def restore_checkpoint(directory: str, step: int, like: Any, *,
                       mesh=None, shardings=None) -> Any:
    """Restore into the structure/shardings of `like` (a tree of arrays or
    ShapeDtypeStructs). Elastic: target mesh/shardings may differ from the
    saving job's."""
    path = os.path.join(directory, f"step_{step:09d}")
    if not os.path.exists(os.path.join(path, "COMMIT")):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = {}
    for name in sorted(os.listdir(path)):
        if name.startswith("shard_") and name.endswith(".npz"):
            with np.load(os.path.join(path, name)) as z:
                for k in z.files:
                    data[k] = z[k]

    paths, leaves, treedef = _flat_with_paths(like)
    by_path = {l["path"]: l for l in manifest["leaves"]}
    out = []
    sh_flat = None
    if shardings is not None:
        sh_flat = jax.tree_util.tree_leaves(shardings)
    for j, (p, leaf) in enumerate(zip(paths, leaves)):
        meta = by_path.get(p)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {p}")
        arr = data[f"a{meta['index']}"]
        want_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        arr = arr.astype(want_dtype)
        if sh_flat is not None:
            out.append(jax.device_put(arr, sh_flat[j]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Async, retention-managed checkpointing for the train loop."""

    def __init__(self, directory: str, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree: Any):
        self.wait()  # one in flight at a time (bounded memory)
        # device_get NOW (cheap: sharded host copy) so the step can mutate
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save(self, step: int, tree: Any):
        self.wait()
        save_checkpoint(self.directory, step, tree)
        self._gc()

    def restore_latest(self, like: Any, *, mesh=None, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, restore_checkpoint(self.directory, step, like,
                                        mesh=mesh, shardings=shardings)

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and os.path.exists(
                os.path.join(self.directory, n, "COMMIT")))
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)
