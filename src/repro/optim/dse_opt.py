"""Projected-Adam design optimizer behind `OptimizeQuery`.

Turns "sweep and pick" into "optimize": the discrete vdd ladder
(`dse_batch.evaluate_vdd_lattice`) is demoted to a GLOBAL SEED, and the
continuous knobs (operating voltage, device widths, bitline wire width)
are refined by Adam (`repro.optim.optimizers.adamw`) on the
differentiable evaluator (`core.dse_grad`) — gradients flow through the
retention integral, the EKV read/leak currents and (when a transient
knob is involved) the implicit-function VJP of the Newton engine.

Constraint handling: the `dse.feasible` demand rule is expressed as
smooth normalized margins g_i (>= 0 feasible) and enters the loss as
relu(-g)^2 penalties on top of a log objective; box bounds are enforced
by projection (clip after every Adam update — the moments live in the
clipped space, standard projected-gradient practice).

Never-regress guarantee: the final candidate is re-evaluated with the
EXACT quantized algebra (`evaluate_grad_fn(quantized=True)`, bit-exact
vs `dse.evaluate`) and the EXACT feasibility rule; if it does not beat
the best grid rung, the grid rung is returned. The optimizer can only
improve on the sweep it replaced.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bank import BankConfig
from repro.core.dse_grad import KNOBS, evaluate_grad_fn
from repro.core import dse_batch
from repro.optim.optimizers import adamw

#: Box bounds of each knob (multipliers around the nominal design).
DEFAULT_BOUNDS: Dict[str, Tuple[float, float]] = {
    "vdd_scale": (0.6, 1.25),
    "w_read_scale": (0.5, 2.0),
    "w_write_scale": (0.5, 2.0),
    "bl_wire_scale": (0.5, 2.0),
}

#: Objectives (minimized). Any OUTPUTS key works; these are the
#: physically sensible ones.
OBJECTIVES = ("standby_w", "t_read_s", "e_read_j", "e_write_j")

PENALTY_WEIGHT = 25.0


@dataclass
class OptResult:
    """Outcome of one projected-Adam design optimization."""
    cfg: BankConfig
    knobs: Dict[str, float]           # optimized knob multipliers
    objective: str
    objective_value: float            # EXACT (quantized) value at `knobs`
    met: bool                         # exact dse.feasible at `knobs`
    outputs: Dict[str, float]         # exact quantized outputs at `knobs`
    seed_knobs: Dict[str, float]      # best grid rung the loop started at
    seed_objective_value: float
    seed_met: bool
    improved: bool                    # strictly beat the grid seed
    fell_back: bool                   # candidate regressed -> grid returned
    evals: Dict[str, int]             # lattice evals vs gradient steps
    history: List[Tuple[float, float]] = field(repr=False,
                                               default_factory=list)

    def as_dict(self) -> dict:
        d = {"cell": self.cfg.cell, "word_size": self.cfg.word_size,
             "num_words": self.cfg.num_words, "wwlls": self.cfg.wwlls,
             "write_vt": self.cfg.write_vt,
             "knobs": dict(self.knobs), "objective": self.objective,
             "objective_value": self.objective_value, "met": self.met,
             "seed_knobs": dict(self.seed_knobs),
             "seed_objective_value": self.seed_objective_value,
             "seed_met": self.seed_met, "improved": self.improved,
             "fell_back": self.fell_back, "evals": dict(self.evals),
             "outputs": dict(self.outputs),
             "loss_history": [float(l) for l, _ in self.history]}
        return d


def _margins(out, idx, *, target_freq_hz, target_ret_s, allow_refresh,
             num_words):
    """Normalized feasibility margins (>= 0 feasible), traced. Mirrors
    dse.feasible: sense swing, read frequency, and retention met either
    natively or through the < 10%-bandwidth refresh rule."""
    f = out["f_max_hz"][idx]
    ret = out["retention_s"][idx]
    g_swing = out["swing_margin_rel"][idx]
    g_freq = f / target_freq_hz - 1.0
    g_native = ret / target_ret_s - 1.0
    if allow_refresh:
        # num_words/ret < 0.1*f  <=>  0.1*f*ret/num_words > 1
        g_refresh = 0.1 * f * ret / num_words - 1.0
        g_ret = jnp.maximum(g_native, g_refresh)
    else:
        g_ret = g_native
    return (g_swing, g_freq, g_ret)


def _exact_check(out, idx, *, target_freq_hz, target_ret_s, allow_refresh,
                 num_words) -> bool:
    """EXACT dse.feasible on quantized traced outputs (float64 compares,
    same rule text: strict swing, f >= target, native-or-refresh)."""
    f = float(out["f_max_hz"][idx])
    ret = float(out["retention_s"][idx])
    ok = float(out["swing_margin_a"][idx]) > 0.0
    if not ok or f < target_freq_hz:
        return False
    if ret >= target_ret_s:
        return True
    if not allow_refresh or ret <= 0.0:
        return False
    return num_words / ret < 0.1 * f


def grid_seed(cfg: BankConfig, vdd_scales: Sequence[float], *,
              objective: str, target_freq_hz: float, target_ret_s: float,
              allow_refresh: bool = True, lat=None):
    """Coarse-ladder global seed: evaluate the EXACT model at each rung,
    pick the best feasible one (fallback: least-infeasible by penalty).
    Returns (seed_knobs, seed_objective_value, seed_met, n_evals).

    `lat` short-circuits evaluation with a precomputed single-config
    VddLattice over `vdd_scales` (the planner's shared vdd_lattice node
    — session-cached and store-persisted)."""
    if lat is None:
        lat = dse_batch.evaluate_vdd_lattice([cfg], list(vdd_scales))
    if len(lat.cfgs) != 1 or tuple(lat.vdd_scales) != \
            tuple(float(v) for v in vdd_scales):
        raise ValueError("seed lattice does not match (cfg, vdd_scales)")
    obj = np.asarray(getattr(lat, objective))[:, 0]
    feas = dse_batch.feasible_grid(
        lat.f_max_hz, lat.retention_s, lat.swing_ok, lat.num_words,
        np.array([target_freq_hz]), np.array([target_ret_s]),
        allow_refresh=allow_refresh)[:, 0, 0]
    if feas.any():
        cand = np.where(feas, obj, np.inf)
        v = int(np.argmin(cand))
        met = True
    else:
        # least-violated rung: penalize missing frequency and retention
        f, ret = lat.f_max_hz[:, 0], lat.retention_s[:, 0]
        viol = (np.maximum(1.0 - f / target_freq_hz, 0.0) ** 2
                + np.maximum(1.0 - ret / max(target_ret_s, 1e-30), 0.0) ** 2
                + np.where(lat.swing_ok[:, 0], 0.0, 1.0))
        v = int(np.argmin(viol))
        met = False
    seed = {"vdd_scale": float(lat.vdd_scales[v])}
    return seed, float(obj[v]), met, len(lat.vdd_scales)


def optimize(cfg: BankConfig, *, target_freq_hz: float,
             target_ret_s: float, objective: str = "standby_w",
             knobs: Sequence[str] = ("vdd_scale",),
             steps: int = 60, lr: float = 0.05,
             bounds: Optional[Dict[str, Tuple[float, float]]] = None,
             seed_vdd_scales: Sequence[float] = (0.7, 0.85, 1.0, 1.15),
             allow_refresh: bool = True,
             penalty_weight: float = PENALTY_WEIGHT,
             constraint_margin: float = 0.04,
             max_verify: int = 6,
             seed_lattice=None) -> OptResult:
    """Gradient-refine the continuous knobs of one gain-cell config.

    Runs under float64 internally. `knobs` picks which multipliers move
    (the rest stay 1.0); `bounds` overrides DEFAULT_BOUNDS entries. The
    result's metrics are the EXACT quantized model's — directly
    comparable to `dse.evaluate` numbers — and never regress vs the
    grid seed.

    `constraint_margin` keeps the smooth-model optimum a few percent
    inside the feasible region: the surrogate drops the delay-chain
    staircase, so its frequency margin overestimates the exact model's
    by up to one stage unit — optimizing to the exact boundary would
    land infeasible on verification. The `max_verify` best trajectory
    points are then checked with the exact quantized algebra (each check
    is one lattice eval, counted in `evals["verify"]`) and the best
    exact-feasible one wins.
    """
    knobs = tuple(knobs)
    bad = set(knobs) - set(KNOBS)
    if bad:
        raise ValueError(f"unknown knobs {sorted(bad)} (allowed: {KNOBS})")
    if not knobs:
        raise ValueError("need at least one knob to optimize")
    bnds = dict(DEFAULT_BOUNDS)
    bnds.update(bounds or {})
    lo = np.array([bnds[k][0] for k in knobs])
    hi = np.array([bnds[k][1] for k in knobs])
    num_words = cfg.num_words
    targs = dict(target_freq_hz=target_freq_hz, target_ret_s=target_ret_s,
                 allow_refresh=allow_refresh, num_words=num_words)

    from jax.experimental import enable_x64
    with enable_x64():
        seed, seed_obj, seed_met, n_grid = grid_seed(
            cfg, seed_vdd_scales, objective=objective, lat=seed_lattice,
            **{k: targs[k] for k in ("target_freq_hz", "target_ret_s",
                                     "allow_refresh")})

        fn_smooth = evaluate_grad_fn(cfg)          # smooth chain surrogate
        fn_exact = evaluate_grad_fn(cfg, quantized=True)

        def loss_fn(vec):
            kn = {k: vec[i:i + 1] for i, k in enumerate(knobs)}
            out = fn_smooth(kn)
            g = _margins(out, 0, **targs)
            pen = sum(jnp.maximum(constraint_margin - gi, 0.0) ** 2
                      for gi in g)
            return (jnp.log(jnp.maximum(out[objective][0], 1e-300))
                    + penalty_weight * pen)

        vg = jax.jit(jax.value_and_grad(loss_fn))

        x = np.clip(np.array([seed.get(k, 1.0) for k in knobs]), lo, hi)
        vec = jnp.asarray(x, jnp.float64)
        opt = adamw(lambda step: lr, weight_decay=0.0, max_grad_norm=1.0)
        # dict param tree: adamw's tuple-leaf detection reserves tuples
        state = opt.init({"x": vec})
        history: List[Tuple[float, float]] = []
        traj: List[Tuple[float, np.ndarray]] = []
        for s in range(steps):
            loss, g = vg(vec)
            loss = float(loss)
            if math.isfinite(loss):
                traj.append((loss, np.asarray(vec)))
            new, state, stats = opt.update(
                {"x": g}, state, {"x": vec}, jnp.asarray(s))
            vec = new["x"]
            vec = jnp.clip(vec.astype(jnp.float64), lo, hi)  # projection
            history.append((loss, float(stats["grad_norm"])))
        loss = float(vg(vec)[0])
        if math.isfinite(loss):
            traj.append((loss, np.asarray(vec)))

        # -- exact verification: check the best trajectory points (by
        # surrogate loss, deduplicated) with the quantized algebra and
        # the exact feasibility rule; keep the best exact-feasible one
        traj.sort(key=lambda lv: lv[0])
        seen: List[np.ndarray] = []
        cand_best = None   # (obj, met, knobs-dict)
        n_verify = 0
        for _, xv in traj:
            if any(np.allclose(xv, s_, rtol=0, atol=1e-4) for s_ in seen):
                continue
            seen.append(xv)
            cand = {k: float(xv[i]) for i, k in enumerate(knobs)}
            kn = {k: jnp.asarray([v], jnp.float64) for k, v in cand.items()}
            out_c = fn_exact(kn)
            n_verify += 1
            c = (float(out_c[objective][0]), _exact_check(out_c, 0, **targs),
                 cand)
            # feasible beats infeasible; then lower objective wins
            if cand_best is None or (c[1], -c[0]) > (cand_best[1],
                                                     -cand_best[0]):
                cand_best = c
            if n_verify >= max_verify:
                break
        cand_obj, cand_met, cand = cand_best

        # -- never-regress: fall back to the grid rung when the refined
        # point is infeasible-while-the-seed-was-feasible or worse
        regressed = (seed_met and not cand_met) or \
            (cand_met == seed_met and cand_obj > seed_obj)
        if regressed:
            final, final_obj, final_met = dict(seed), seed_obj, seed_met
        else:
            final, final_obj, final_met = cand, cand_obj, cand_met
        kn_f = {k: jnp.asarray([v], jnp.float64) for k, v in final.items()}
        out_f = fn_exact(kn_f)
        outputs = {k: float(v[0]) for k, v in out_f.items()}

    for k in KNOBS:
        final.setdefault(k, 1.0)
    return OptResult(
        cfg=cfg, knobs=final, objective=objective,
        objective_value=final_obj, met=final_met, outputs=outputs,
        seed_knobs=dict(seed), seed_objective_value=seed_obj,
        seed_met=seed_met,
        improved=bool((final_met or not seed_met)
                      and final_obj < seed_obj),
        fell_back=bool(regressed),
        evals={"grid": n_grid, "grad_steps": steps, "verify": n_verify},
        history=history)
