"""Optimizers with sharding-aware state (no optax dependency).

AdamW: ZeRO-style — moments inherit the parameter's sharding (params are
already FSDP+TP sharded by the rule table, so optimizer state is too);
moments optionally bf16 (distributed-optimization memory trick).

Adafactor: factored second moment (row/col statistics) for the 480B MoE —
state is ~2/max(d_row,d_col) of AdamW's.

Each optimizer exposes:
  init(params)                 -> state tree
  update(grads, state, params, step) -> (new_params, new_state, stats)
  state_specs(param_specs)     -> logical-axis tree matching state
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                        for g in jax.tree.leaves(tree)))


def _clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable
    state_specs: Callable


def adamw(schedule, *, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          max_grad_norm=1.0, moment_dtype=jnp.float32):
    """AdamW over fp32 master params. moment_dtype=bf16 halves state memory
    (documented accuracy tradeoff; used at >100B scale)."""

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        grads, gn = _clip_by_global_norm(grads, max_grad_norm)
        lr = schedule(step)
        t = (step + 1).astype(jnp.float32)
        c1 = 1 - b1 ** t
        c2 = 1 - b2 ** t

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
            nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g * g
            step_ = (mu32 / c1) / (jnp.sqrt(nu32 / c2) + eps)
            wd = weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
            newp = p.astype(jnp.float32) - lr * (step_ + wd)
            return (newp.astype(p.dtype), mu32.astype(moment_dtype),
                    nu32.astype(moment_dtype))

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        newp = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return newp, {"mu": mu, "nu": nu}, {"grad_norm": gn, "lr": lr}

    def state_specs(param_specs, param_shapes=None):
        return {"mu": param_specs, "nu": param_specs}

    return Optimizer(init, update, state_specs)


def adafactor(schedule, *, eps=1e-30, clip_threshold=1.0, decay=0.8,
              max_grad_norm=1.0, min_dim_size_to_factor=128):
    """Adafactor (Shazeer & Stern) without first moment: row/col-factored
    second-moment statistics; memory ~ O(d_row + d_col) per matrix."""

    def _factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim_size_to_factor and \
            p.shape[-2] >= min_dim_size_to_factor

    def init(params):
        def one(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(one, params)}

    def update(grads, state, params, step):
        grads, gn = _clip_by_global_norm(grads, max_grad_norm)
        lr = schedule(step)
        t = (step + 1).astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(v, g, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                upd_ = g * jax.lax.rsqrt(r)[..., None] * jax.lax.rsqrt(vc)[..., None, :]
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta * v["v"] + (1 - beta) * g2}
                upd_ = g * jax.lax.rsqrt(nv["v"])
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(upd_ * upd_) + 1e-30)
            upd_ = upd_ / jnp.maximum(1.0, rms / clip_threshold)
            newp = p.astype(jnp.float32) - lr * upd_
            return newp.astype(p.dtype), nv

        # state leaves are {"vr","vc"} or {"v"} dicts: treat them as leaves
        # and walk the STATE tree first so structures line up.
        leaf = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        out = jax.tree.map(upd, state["v"], grads, params, is_leaf=leaf)
        newp = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        nv = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return newp, {"v": nv}, {"grad_norm": gn, "lr": lr}

    def state_specs(param_specs, param_shapes):
        # factored leaves drop the last / second-to-last logical axis
        def one(axes, p):
            if _factored(p):
                return {"vr": tuple(axes[:-1]),
                        "vc": tuple(axes[:-2]) + tuple(axes[-1:])}
            return {"v": tuple(axes)}
        return {"v": jax.tree.map(one, param_specs, param_shapes,
                                  is_leaf=lambda x: isinstance(x, tuple) and all(
                                      isinstance(e, (str, type(None))) for e in x))}

    return Optimizer(init, update, state_specs)


def make_optimizer(cfg, schedule, moment_dtype=jnp.float32):
    if cfg.optimizer == "adafactor":
        return adafactor(schedule)
    return adamw(schedule, moment_dtype=moment_dtype)
