"""LR schedules: cosine (llama-style) and WSD (minicpm's Warmup-Stable-Decay).

All schedules are pure functions of the int32 step -> fp32 lr, safe inside
jit (no python branching on traced values).
"""
from __future__ import annotations

import jax.numpy as jnp


def cosine(step, *, peak_lr, warmup_steps, total_steps, min_ratio=0.1):
    step = step.astype(jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    t = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
                 0.0, 1.0)
    cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup_steps, warm, cos)


def wsd(step, *, peak_lr, warmup_steps, total_steps, decay_frac=0.1,
        min_ratio=0.01):
    """Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395): linear warmup,
    long stable plateau at peak, exponential-ish decay over the last
    `decay_frac` of training."""
    step = step.astype(jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    decay_start = total_steps * (1 - decay_frac)
    t = jnp.clip((step - decay_start) / jnp.maximum(total_steps - decay_start, 1),
                 0.0, 1.0)
    decay = peak_lr * jnp.exp(jnp.log(min_ratio) * t)
    lr = jnp.where(step < warmup_steps, warm,
                   jnp.where(step < decay_start, peak_lr, decay))
    return lr


def make_schedule(name, **kw):
    base = {"cosine": cosine, "wsd": wsd}[name]
    def fn(step):
        return base(step, **kw)
    return fn
