"""Gradient compression: int8 + error feedback (beyond-paper
distributed-optimization trick, DESIGN.md §5).

At 1000+ node scale the cross-pod (DCI) gradient all-reduce is the
bandwidth wall. `compress_grads`/`decompress_grads` implement symmetric
per-tensor-block int8 quantization with an ERROR-FEEDBACK residual (the
quantization error is carried into the next step's gradient, so the
compressed-SGD fixed point matches the uncompressed one — Seide et al. /
EF-SGD). Wire cost: 8 bits + one fp32 scale per block of 1024 vs 32 bits:
~3.97x less gradient traffic.

Usage (training/loop or steps):
    cgrads, new_err = compress_grads(grads, err)
    # all-reduce cgrads.q (int8) and cgrads.scale instead of fp32 grads
    grads = decompress_grads(cgrads)

The dry-run path keeps fp32 all-reduce by default; enable with
steps.build(..., grad_compression=True) to lower the compressed variant
(the int8 all-reduce shows up in §Roofline's wire bytes at ~1/4 size).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 1024


class Compressed(NamedTuple):
    q: jnp.ndarray        # int8 flat blocks
    scale: jnp.ndarray    # fp32 per block
    shape: tuple
    n: int


def _compress_one(g, e):
    g32 = g.astype(jnp.float32) + (e if e is not None else 0.0)
    flat = g32.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    fb = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(fb), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(fb / scale[:, None]), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    err = (flat - deq).reshape(g.shape)         # error feedback residual
    return Compressed(q, scale, g.shape, n), err


def compress_grads(grads, err_tree=None):
    if err_tree is None:
        err_tree = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                                grads)
    out = jax.tree.map(_compress_one, grads, err_tree)
    comp = jax.tree.map(lambda o: o[0], out,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        isinstance(x[0], Compressed))
    err = jax.tree.map(lambda o: o[1], out,
                       is_leaf=lambda x: isinstance(x, tuple) and
                       isinstance(x[0], Compressed))
    return comp, err


def decompress_grads(comp):
    def one(c: Compressed):
        deq = (c.q.astype(jnp.float32) * c.scale[:, None]).reshape(-1)[:c.n]
        return deq.reshape(c.shape)
    return jax.tree.map(one, comp, is_leaf=lambda x: isinstance(x, Compressed))


def wire_bytes_ratio() -> float:
    """fp32 bytes / compressed bytes per element."""
    return 4.0 / (1.0 + 4.0 / BLOCK)
