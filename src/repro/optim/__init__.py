from repro.optim.optimizers import adamw, adafactor, make_optimizer, global_norm
from repro.optim.schedules import make_schedule
