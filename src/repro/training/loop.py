"""Fault-tolerant training loop.

Production behaviors implemented (and unit-tested in tests/test_training.py):
  * checkpoint/restart: async step-atomic checkpoints every `ckpt_every`
    steps; on (re)start the loop restores the latest committed step and the
    data pipeline resumes from the same cursor (batch = f(seed, step)), so
    a killed-and-relaunched run produces bit-identical training curves;
  * preemption handling: SIGTERM (and a test hook `preempt_at`) triggers a
    final synchronous checkpoint before exit (graceful eviction);
  * elastic rescale: restore re-shards every array onto the CURRENT mesh
    (checkpoint/ckpt.py), so the same run can continue on a different
    device count;
  * straggler mitigation: per-step wall-time EWMA; steps slower than
    `straggler_factor` x EWMA are counted and logged. In a real multi-host
    job the SPMD collectives make stragglers a cluster-level concern —
    the deployed mechanism is (a) this detection signal exported to the
    job controller and (b) restart-from-checkpoint with the slow host
    replaced, which is exactly restore+rescale above;
  * NaN/overflow guard: non-finite loss skips the update (state is only
    replaced on finite metrics) and counts toward `max_bad_steps`.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import make_batch_iterator
from repro.launch import steps as steps_mod


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_last: int = 3
    log_every: int = 10
    seed: int = 0
    microbatches: int = 1
    straggler_factor: float = 3.0
    max_bad_steps: int = 10
    preempt_at: Optional[int] = None     # test hook: simulate SIGTERM
    log_fn: Callable = print
    telemetry: Optional[object] = None   # repro.runtime TelemetryCollector


class Trainer:
    def __init__(self, cfg, mesh, shape, tcfg: TrainConfig):
        self.cfg, self.mesh, self.shape, self.tcfg = cfg, mesh, shape, tcfg
        self.bundle = steps_mod.build(cfg, mesh, shape,
                                      microbatches=tcfg.microbatches,
                                      total_steps=tcfg.total_steps)
        self.step_fn = self.bundle.jitted()
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep_last=tcfg.keep_last)
        self._preempted = False
        self.stats = {"straggler_steps": 0, "bad_steps": 0, "restored_step": None}

    # -- state ------------------------------------------------------------
    def init_state(self):
        meta = self.bundle.meta
        model = self.bundle.model
        key = jax.random.key(self.tcfg.seed)

        def init():
            p = model.init(key)
            master = jax.tree.map(lambda x: x.astype(jnp.float32), p)
            return {"params": master, "opt": meta["opt"].init(master),
                    "step": jnp.int32(0)}

        shardings = {"params": meta["p_sh"], "opt": meta["o_sh"],
                     "step": jax.sharding.NamedSharding(
                         self.mesh, jax.sharding.PartitionSpec())}
        with self.mesh:
            state = jax.jit(init, out_shardings=shardings)()
        return state

    def restore_or_init(self):
        like = jax.tree.map(lambda s: s, self.bundle.in_specs[0])
        shardings = self.bundle.in_shardings[0]
        step, state = self.ckpt.restore_latest(like, mesh=self.mesh,
                                               shardings=shardings)
        if state is None:
            return self.init_state(), 0
        self.stats["restored_step"] = step
        return state, step

    # -- loop -------------------------------------------------------------
    def _install_sigterm(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not main thread (tests)

    def run(self):
        tc = self.tcfg
        self._install_sigterm()
        state, start = self.restore_or_init()
        ds, it = make_batch_iterator(self.cfg, self.shape, seed=tc.seed,
                                     start_step=start)
        ewma = None
        history = []
        step = start
        while step < tc.total_steps:
            if tc.preempt_at is not None and step == tc.preempt_at:
                self._preempted = True
            if self._preempted:
                self.ckpt.save(step, state)
                tc.log_fn(f"[preempt] checkpointed at step {step}, exiting")
                return state, history

            batch = next(it)
            t0 = time.time()
            with self.mesh:
                new_state, metrics = self.step_fn(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0

            state = new_state  # in-step NaN guard made a bad update a no-op
            if not np.isfinite(metrics["loss"]):
                self.stats["bad_steps"] += 1
                tc.log_fn(f"[warn] non-finite loss at step {step}; update skipped")
                if self.stats["bad_steps"] > tc.max_bad_steps:
                    raise RuntimeError("too many bad steps")

            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > tc.straggler_factor * ewma and step > start + 5:
                self.stats["straggler_steps"] += 1
                tc.log_fn(f"[straggler] step {step} took {dt:.3f}s "
                          f"(ewma {ewma:.3f}s)")
            history.append({"step": step, **metrics, "time_s": dt})
            if tc.telemetry is not None:
                tc.telemetry.on_train_step(
                    step, self.shape.global_batch * self.shape.seq_len, dt,
                    metrics["loss"])
            if step % tc.log_every == 0:
                tc.log_fn(f"step {step}: loss={metrics['loss']:.4f} "
                          f"lr={metrics['lr']:.2e} "
                          f"gnorm={metrics['grad_norm']:.3f} {dt:.2f}s")
            step += 1
            if step % tc.ckpt_every == 0:
                self.ckpt.save_async(step, state)

        self.ckpt.save(step, state)
        return state, history
