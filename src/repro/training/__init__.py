from repro.training.loop import Trainer, TrainConfig
