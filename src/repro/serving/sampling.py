"""Samplers for the serving engine.

`sample_tokens` is the device sampler used inside the fused decode scan:
greedy where temperature <= 0, otherwise top-k temperature sampling via
`jax.lax.top_k` + `jax.random.categorical`, batched over slots so the
whole decode batch samples in one fused op with zero host syncs.

`sample_host` is the original per-request host sampler, kept as the
parity reference (and as the sampling path of the engine's
``mode="host"`` per-token loop). The two are exactly equal under greedy
decoding; under temperature sampling they draw from the same top-k
support but from DIFFERENT random streams — `sample_host` consumes a
`np.random.Generator`, `sample_tokens` a `jax.random` key — so
stochastic token streams are not expected to match across modes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sample_tokens(logits, key, temperature, top_k, *, k_max: int):
    """Sample one token per row, fully on device.

    logits: (B, V) float32; temperature: (B,) float32; top_k: (B,) int32.
    `k_max` is the static top-k width compiled into the program; per-row
    `top_k` is clipped into [1, k_max] by masking the tail of the top-k
    candidates, so one compiled sampler serves every request mix.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    k_max = min(int(k_max), logits.shape[-1])
    vals, idx = jax.lax.top_k(logits, k_max)            # (B, k_max)
    t = jnp.maximum(temperature, 1e-6)[:, None]
    keep = jnp.arange(k_max)[None, :] < jnp.clip(top_k, 1, k_max)[:, None]
    scaled = jnp.where(keep, vals / t, -jnp.inf)
    choice = jax.random.categorical(key, scaled, axis=-1)
    sampled = jnp.take_along_axis(idx, choice[:, None], axis=-1)[:, 0]
    return jnp.where(temperature > 0.0, sampled.astype(jnp.int32), greedy)


def sample_host(logits: np.ndarray, temperature: float, top_k: int,
                rng: np.random.Generator) -> int:
    """Host reference sampler: one token from one row of logits."""
    if temperature <= 0:
        return int(np.argmax(logits))
    l = logits / temperature
    idx = np.argpartition(l, -top_k)[-top_k:]
    p = np.exp(l[idx] - l[idx].max())
    p /= p.sum()
    return int(rng.choice(idx, p=p))
