"""Batched serving engine: prefill + decode with slot-based continuous
batching (vLLM-style at the granularity JAX's static shapes allow).

The engine owns a fixed decode batch of `n_slots` sequences and a KV cache
sized (slots, window). Requests are queued; whenever a slot frees (EOS or
max tokens), the next request is prefilled into that slot (single-sequence
prefill, cache row swapped in) — decode steps always run the full static
batch, masking empty slots. Under SWA the cache is a ring buffer.

All compute paths are the same Model.prefill / Model.decode_step used by
the dry-run; sampling is greedy or top-k temperature.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 -> greedy
    top_k: int = 40
    out_tokens: Optional[list] = None


class ServeEngine:
    def __init__(self, cfg, params, *, n_slots=4, window=512, mesh=None,
                 seed=0):
        self.cfg = cfg
        self.model = Model(cfg, mesh=mesh)
        self.params = params
        self.n_slots = n_slots
        self.window = self.model.kv_window(window)
        self.mesh = mesh
        self.rng = np.random.default_rng(seed)

        self.cache = self.model.init_cache(n_slots, self.window)
        self.pos = jnp.zeros((n_slots,), jnp.int32)
        self.active: List[Optional[Request]] = [None] * n_slots
        # host-side mirror of the per-slot feedback tokens: sampling
        # happens on host anyway, so slots accumulate here and a SINGLE
        # device update per step refreshes the copy (instead of one
        # .at[slot].set() dispatch per slot per token). The mirror is
        # snapshotted (np.array copy) on upload: jnp.asarray may alias
        # host memory on CPU, and mutating an aliased buffer is UB.
        self._last_tok_np = np.zeros((n_slots, 1), np.int32)
        self.last_tok = jnp.asarray(np.array(self._last_tok_np))
        self.queue: List[Request] = []
        self.done: List[Request] = []

        self._prefill1 = jax.jit(
            lambda p, b: self.model.prefill(p, b, W=self.window))
        self._decode = jax.jit(self.model.decode_step)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.out_tokens = []
        self.queue.append(req)

    def _free_slots(self):
        return [i for i, r in enumerate(self.active) if r is None]

    def _insert_cache_row(self, slot, row_cache, row_pos):
        def put(c, rc):
            return c.at[:, slot].set(rc[:, 0].astype(c.dtype))
        self.cache = jax.tree.map(put, self.cache, row_cache)
        self.pos = self.pos.at[slot].set(row_pos)

    def _admit(self):
        admitted = False
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            P = len(req.prompt)
            batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
            if self.cfg.family == "audio":
                batch["frames"] = jnp.zeros(
                    (1, self.cfg.enc_frames, self.cfg.d_model), jnp.bfloat16)
            if self.cfg.family == "vlm":
                batch["patches"] = jnp.zeros(
                    (1, self.cfg.n_patches, self.cfg.d_model), jnp.bfloat16)
            logits, cache1, pos1 = self._prefill1(self.params, batch)
            self._insert_cache_row(slot, cache1, int(pos1[0]))
            tok = self._sample(np.asarray(logits)[0], req)
            req.out_tokens.append(int(tok))
            self.active[slot] = req
            self._last_tok_np[slot, 0] = tok
            admitted = True
        if admitted:
            self.last_tok = jnp.asarray(np.array(self._last_tok_np))

    def _sample(self, logits: np.ndarray, req: Request) -> int:
        if req.temperature <= 0:
            return int(np.argmax(logits))
        l = logits / req.temperature
        idx = np.argpartition(l, -req.top_k)[-req.top_k:]
        p = np.exp(l[idx] - l[idx].max())
        p /= p.sum()
        return int(self.rng.choice(idx, p=p))

    def _retire(self, slot):
        req = self.active[slot]
        self.active[slot] = None
        self.done.append(req)

    # ------------------------------------------------------------------
    def step(self):
        """One engine iteration: admit waiting requests, one decode step."""
        self._admit()
        if all(r is None for r in self.active):
            return False
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.last_tok, self.pos)
        self.pos = self.pos + 1
        logits_np = np.asarray(logits, np.float32)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = self._sample(logits_np[slot], req)
            req.out_tokens.append(tok)
            self._last_tok_np[slot, 0] = tok
            if len(req.out_tokens) >= req.max_new_tokens:
                self._retire(slot)
        self.last_tok = jnp.asarray(np.array(self._last_tok_np))
        return True

    def run(self, max_steps=10000):
        steps = 0
        while (self.queue or any(r is not None for r in self.active)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.done, steps
