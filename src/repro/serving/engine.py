"""Batched serving engine: prefill + decode with slot-based continuous
batching (vLLM-style at the granularity JAX's static shapes allow).

The engine owns a fixed decode batch of `n_slots` sequences and a KV
cache sized (slots, window). Requests are queued (deque, O(1) FIFO);
whenever slots free (EOS or max tokens) waiting requests are admitted in
prompt-length groups: equal-length prompts prefill in ONE batched
dispatch, with the batch dim padded to a power-of-two bucket so the
compiled program is reused across admission waves of different sizes
(mirroring `char_batch`'s lattice bucketing; the prompt length itself is
never padded — right-padding would corrupt recurrent-state caches and
ring seeding, so buckets are keyed (prompt_len, batch_bucket)).

Decode is fully device-resident: `Model.decode_loop` fuses
`decode_chunk` steps of decode_step + sampling (greedy and top-k
temperature via `jax.lax.top_k` + `jax.random.categorical`) into one
jitted lax.scan whose carry (cache, feedback token, pos, emitted
counter, done mask, PRNG key) is donated, so the KV cache updates in
place and the host syncs ONCE per `decode_chunk` tokens instead of once
per token. Finished slots (tokens-emitted >= max_new_tokens, or EOS
hit) freeze inside the chunk via the carried done mask, so a slot that
stops mid-chunk emits exactly its budget.

All host->device slot updates (admission) are surgical `.at[idx].set`
scatters rather than whole-array uploads, so they compose correctly
with an in-flight chunk under JAX async dispatch. `run()` exploits
that: it dispatches chunk N+1 BEFORE reconciling chunk N's tokens, so
host-side bookkeeping (retire, admit, prefill dispatch) overlaps device
compute; a freed slot rejoins one chunk later, which is the
K-vs-latency tradeoff documented in the README. `step()` stays fully
synchronous (admit -> one chunk -> reconcile) for lifecycle tests.

mode="host" keeps the original per-token loop (device->host logits sync
+ np-rng host sampling every token) as the parity and throughput
reference: greedy token streams are exactly equal across modes;
stochastic streams draw from the same top-k support but different rngs
(see serving/sampling.py). `host_syncs` counts blocking device->host
transfers in both modes for the bench_serve scoreboard.

Observability: the engine keeps a completed-request log (`request_log`,
RequestStats entries stamped by the engine clock) and accepts an
optional duck-typed `telemetry` collector (repro.runtime). Telemetry
hooks consume ONLY host-side values the engine already reconciled —
the np token/live arrays pulled once per chunk, host-tracked per-slot
context lengths, python queue depths — so an attached collector adds
ZERO device syncs and cannot perturb token streams (asserted in
tests/test_runtime.py).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serving.sampling import sample_host, sample_tokens


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 -> greedy
    top_k: int = 40
    eos_id: Optional[int] = None  # emitting this token stops the request
    out_tokens: Optional[list] = None
    # engine-stamped lifecycle times (engine clock, seconds)
    t_submit_s: Optional[float] = None
    t_admit_s: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class RequestStats:
    """Lifecycle record of one COMPLETED request, appended to
    `ServeEngine.request_log` at retire (the engine previously forgot
    everything but the token stream). Timestamps come from the engine
    clock — `time.monotonic` by default, or an attached telemetry
    collector's virtual clock, so deterministic replays yield
    deterministic stats. In this engine the first token is sampled
    INSIDE the prefill dispatch, so `t_first_s == t_admit_s`; both are
    kept because the schema outlives that implementation detail."""
    rid: int
    prompt_len: int
    emitted: int
    t_submit_s: float
    t_admit_s: float
    t_first_s: float
    t_retire_s: float

    @property
    def queue_wait_s(self) -> float:
        return self.t_admit_s - self.t_submit_s

    @property
    def service_s(self) -> float:
        """Admission-to-retire residency — the observed data lifetime of
        the request's KV-cache rows."""
        return self.t_retire_s - self.t_admit_s


class ServeEngine:
    def __init__(self, cfg, params, *, n_slots=4, window=512, mesh=None,
                 seed=0, mode="device", decode_chunk=8, top_k_max=64,
                 telemetry=None, clock=None):
        if mode not in ("device", "host"):
            raise ValueError(f"mode must be 'device' or 'host': {mode!r}")
        self.cfg = cfg
        self.model = Model(cfg, mesh=mesh)
        self.params = params
        self.n_slots = n_slots
        self.window = self.model.kv_window(window)
        self.mesh = mesh
        self.mode = mode
        self.decode_chunk = max(1, int(decode_chunk)) if mode == "device" \
            else 1
        self.top_k_max = top_k_max
        # device sampling key (carried through the jitted chunk, split on
        # device); the np rng only feeds the host-mode reference sampler
        # — the two streams intentionally differ (see serving/sampling).
        self.key = jax.random.key(seed)
        self.rng = np.random.default_rng(seed)

        self.cache = self.model.init_cache(n_slots, self.window)
        self.pos = jnp.zeros((n_slots,), jnp.int32)
        self.active: List[Optional[Request]] = [None] * n_slots
        self.queue: Deque[Request] = deque()
        self.done: List[Request] = []
        self.host_syncs = 0       # all blocking device->host transfers
        self.admit_syncs = 0      # ...of which admission (prefill) syncs
        # host-side prediction of per-slot emitted counts INCLUDING
        # in-flight chunks: the device emits exactly min(K, max_new -
        # emitted) tokens per chunk for a live slot, so this is exact
        # (EOS only shortens it), and run() can skip dispatching chunks
        # in which every slot would sit frozen.
        self._pred = [0] * n_slots

        # --- runtime observability (repro.runtime) ------------------
        # `telemetry` is duck-typed (TelemetryCollector-shaped); its
        # hooks receive only host-side data — see module docstring.
        # The clock defaults to the collector's (virtual clocks make
        # replays deterministic), else wall time.
        self.telemetry = telemetry
        self.clock = clock if clock is not None else \
            (getattr(telemetry, "clock", None) or time.monotonic)
        self.request_log: List[RequestStats] = []
        # host-tracked per-slot context length (KV-cache rows in use),
        # advanced at admission/reconcile — never read from device
        self._ctx = [0] * n_slots

        # per-slot decode-scan state, device resident. Admission touches
        # only the admitted slots via .at[idx].set so updates queue
        # behind any in-flight chunk instead of overwriting its outputs.
        self.last_tok = jnp.zeros((n_slots, 1), jnp.int32)
        self.emitted = jnp.zeros((n_slots,), jnp.int32)
        self.done_mask = jnp.ones((n_slots,), bool)
        self._temp_d = jnp.zeros((n_slots,), jnp.float32)
        self._topk_d = jnp.ones((n_slots,), jnp.int32)
        self._maxnew_d = jnp.zeros((n_slots,), jnp.int32)
        self._eos_d = jnp.full((n_slots,), -1, jnp.int32)
        # host-mode mirror of the feedback tokens: host sampling fills it
        # slot by slot, then ONE upload per step refreshes the device
        # copy. Snapshotted (np.array copy) on upload: jnp.asarray may
        # alias host memory on CPU, and mutating an aliased buffer is UB.
        self._tok_np = np.zeros((n_slots, 1), np.int32)

        # --- compiled programs --------------------------------------
        self._prefill_logits = jax.jit(
            lambda p, b: self.model.prefill(p, b, W=self.window))
        self._decode = jax.jit(self.model.decode_step)      # host mode

        def _admit_kernel(p, batch, cache, pos, last_tok, emitted, done,
                          temp, topk, max_new, eos, meta_i, r_temp, key):
            """Fused admission: batched prefill + first-token sampling +
            cache-row insertion + slot-state scatter, ONE dispatch per
            prompt-length group. meta_i is (4, Bp) int32 rows (slot idx,
            top_k, max_new, eos); pad rows carry idx == n_slots, which is
            out of bounds and therefore DROPPED by JAX scatter semantics,
            so bucket padding never touches a live slot."""
            idx, r_topk, r_maxnew, r_eos = meta_i
            key, sub = jax.random.split(key)
            logits, rows, rpos = self.model.prefill(p, batch, W=self.window)
            tok = sample_tokens(logits, sub, r_temp, r_topk,
                                k_max=self.top_k_max)
            fin = (r_maxnew <= 1) | ((r_eos >= 0) & (tok == r_eos))
            cache = jax.tree.map(
                lambda c, rc: c.at[:, idx].set(rc.astype(c.dtype)),
                cache, rows)
            pos = pos.at[idx].set(rpos)
            last_tok = last_tok.at[idx, 0].set(tok)
            emitted = emitted.at[idx].set(1)
            done = done.at[idx].set(fin)
            temp = temp.at[idx].set(r_temp)
            topk = topk.at[idx].set(r_topk)
            max_new = max_new.at[idx].set(r_maxnew)
            eos = eos.at[idx].set(r_eos)
            return (tok, cache, pos, last_tok, emitted, done, temp, topk,
                    max_new, eos, key)

        self._admit_device = jax.jit(
            _admit_kernel, donate_argnums=tuple(range(2, 11)) + (13,))

        def _chunk(p, cache, token, pos, emitted, done, temp, topk,
                   max_new, eos, key):
            samp = lambda lg, k: sample_tokens(lg, k, temp, topk,
                                               k_max=self.top_k_max)
            keys = jax.random.split(key, self.decode_chunk + 1)
            out = self.model.decode_loop(
                p, cache, token, pos, emitted, max_new, done, eos, samp,
                keys[1:], n_tokens=self.decode_chunk)
            return out + (keys[0],)

        # donate the scan carry: cache/token/pos/emitted/done and the
        # PRNG key are replaced by the returned arrays every chunk, so
        # their buffers are reused in place (no KV-cache round-trip).
        self._decode_chunk = jax.jit(_chunk,
                                     donate_argnums=(1, 2, 3, 4, 5, 10))

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        if (self.mode == "device" and req.temperature > 0
                and req.top_k > self.top_k_max):
            warnings.warn(
                f"request {req.rid}: top_k={req.top_k} exceeds the "
                f"engine's static top_k_max={self.top_k_max}; device "
                f"sampling draws from the top {self.top_k_max} candidates "
                f"only (host mode would use the full top_k) — raise "
                f"ServeEngine(top_k_max=...) for wider sampling")
        req.out_tokens = []
        req.t_submit_s = self.clock()
        self.queue.append(req)
        if self.telemetry is not None:
            self.telemetry.on_submit(req.rid, len(req.prompt),
                                     len(self.queue))

    def _free_slots(self):
        return [i for i, r in enumerate(self.active) if r is None]

    # ------------------------------------------------------------------
    # admission: length-grouped, batch-bucketed prefill
    # ------------------------------------------------------------------
    def _admit(self):
        free = self._free_slots()
        if not free or not self.queue:
            return
        take = []
        for slot in free:
            if not self.queue:
                break
            take.append((slot, self.queue.popleft()))
        groups = {}
        for slot, req in take:
            groups.setdefault(len(req.prompt), []).append((slot, req))
        for items in groups.values():
            self._admit_group(items)
        if self.mode == "host":
            self.last_tok = jnp.asarray(np.array(self._tok_np))

    def _prefill_batch(self, toks):
        Bp = toks.shape[0]
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (Bp, self.cfg.enc_frames, self.cfg.d_model), jnp.bfloat16)
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (Bp, self.cfg.n_patches, self.cfg.d_model), jnp.bfloat16)
        return batch

    def _admit_group(self, items):
        """One prefill dispatch for equal-length prompts, batch padded to
        a power-of-two bucket (edge-repeat) for program reuse."""
        B = len(items)
        toks = np.stack([r.prompt for _, r in items]).astype(np.int32)
        Bp = 1 << (B - 1).bit_length()
        if Bp > B:
            toks = np.concatenate(
                [toks, np.repeat(toks[-1:], Bp - B, axis=0)])
        batch = self._prefill_batch(toks)

        if self.mode == "device":
            # (idx, top_k, max_new, eos) packed into one int32 upload;
            # pad rows get idx == n_slots (out of bounds -> dropped)
            meta_i = np.full((4, Bp), -1, np.int32)
            meta_i[0] = self.n_slots
            meta_i[2] = 1
            temp = np.zeros((Bp,), np.float32)
            for i, (s, r) in enumerate(items):
                meta_i[0, i] = s
                meta_i[1, i] = r.top_k
                meta_i[2, i] = r.max_new_tokens
                meta_i[3, i] = -1 if r.eos_id is None else r.eos_id
                temp[i] = r.temperature
            (tok_d, self.cache, self.pos, self.last_tok, self.emitted,
             self.done_mask, self._temp_d, self._topk_d, self._maxnew_d,
             self._eos_d, self.key) = self._admit_device(
                self.params, batch, self.cache, self.pos, self.last_tok,
                self.emitted, self.done_mask, self._temp_d, self._topk_d,
                self._maxnew_d, self._eos_d, jnp.asarray(meta_i),
                jnp.asarray(temp), self.key)
            first = np.asarray(jax.device_get(tok_d))[:B]
            self.host_syncs += 1
            self.admit_syncs += 1
            self._record_first_tokens(items, first)
            return

        logits, cache_g, _ = self._prefill_logits(self.params, batch)
        logits_np = np.asarray(jax.device_get(logits), np.float32)
        self.host_syncs += 1
        self.admit_syncs += 1
        first = np.array(
            [sample_host(logits_np[i], r.temperature, r.top_k, self.rng)
             for i, (_, r) in enumerate(items)], np.int32)

        idx = jnp.asarray(np.array([s for s, _ in items], np.int32))

        def put(c, rc):
            return c.at[:, idx].set(rc[:, :B].astype(c.dtype))
        self.cache = jax.tree.map(put, self.cache, cache_g)
        # prefill pos == sequence length fed to the backbone (vlm
        # prepends patch embeds) — computed host-side to avoid a sync
        S = toks.shape[1] + (self.cfg.n_patches
                             if self.cfg.family == "vlm" else 0)
        self.pos = self.pos.at[idx].set(S)
        self._record_first_tokens(items, first)

    def _record_first_tokens(self, items, first):
        """Shared admission bookkeeping: record each request's prefill
        token, retire requests that finish at prefill (max_new <= 1 or
        EOS — the device kernel computes the matching `fin` flag), and
        activate the rest. Both modes MUST run this identically for the
        cross-mode greedy-parity contract to hold."""
        now = self.clock()
        for i, (slot, req) in enumerate(items):
            t = int(first[i])
            req.out_tokens.append(t)
            req.t_admit_s = now
            if (len(req.out_tokens) >= req.max_new_tokens
                    or (req.eos_id is not None and t == req.eos_id)):
                self.done.append(req)      # finished at prefill
                self._log_done(req, now)
                continue
            self.active[slot] = req
            # prefill writes one cache row per backbone position (vlm
            # prepends patch embeds) — same formula as the host-mode pos
            self._ctx[slot] = len(req.prompt) + \
                (self.cfg.n_patches if self.cfg.family == "vlm" else 0)
            self._tok_np[slot, 0] = t
            self._pred[slot] = 1
        if self.telemetry is not None:
            self.telemetry.on_admit(
                len(items), sum(len(r.prompt) for _, r in items),
                len(self.queue))

    def _log_done(self, req, now):
        fallback = lambda t: t if t is not None else now
        st = RequestStats(req.rid, len(req.prompt), len(req.out_tokens),
                          fallback(req.t_submit_s), fallback(req.t_admit_s),
                          fallback(req.t_admit_s), now)
        self.request_log.append(st)
        if self.telemetry is not None:
            self.telemetry.on_retire(st)

    def _retire(self, slot):
        req = self.active[slot]
        self.active[slot] = None
        self._ctx[slot] = 0
        self.done.append(req)
        self._log_done(req, self.clock())

    # ------------------------------------------------------------------
    # stepping
    # ------------------------------------------------------------------
    def _dispatch_chunk(self):
        """Launch one fused K-token decode; returns the (K, slots) token
        and live-mask device arrays WITHOUT syncing."""
        (self.cache, self.last_tok, self.pos, self.emitted, self.done_mask,
         toks, live, self.key) = self._decode_chunk(
            self.params, self.cache, self.last_tok, self.pos, self.emitted,
            self.done_mask, self._temp_d, self._topk_d, self._maxnew_d,
            self._eos_d, self.key)
        for slot, req in enumerate(self.active):
            if req is not None:
                self._pred[slot] = min(self._pred[slot] + self.decode_chunk,
                                       req.max_new_tokens)
        return toks, live, list(self.active)

    def _reconcile(self, toks, live, snapshot):
        """Fold a (K, slots) chunk back into the request streams recorded
        at dispatch time and retire finished slots (one blocking sync)."""
        toks, live = jax.device_get((toks, live))
        self.host_syncs += 1
        toks, live = np.asarray(toks), np.asarray(live)
        if self.telemetry is not None:
            # the done mask freezes monotonically inside a chunk, so
            # per-slot emitted counts are the live-mask column sums —
            # already on host, no extra sync. The hook runs BEFORE the
            # retire loop so a virtual clock has advanced past this
            # chunk when retire timestamps are stamped.
            em = live.sum(axis=0)
            rows = [min(self._ctx[s]
                        + (int(em[s]) if self.active[s] is r else 0),
                        self.window)
                    for s, r in enumerate(snapshot) if r is not None]
            self.telemetry.on_chunk(
                toks.shape[0],
                int(sum(int(em[s]) for s, r in enumerate(snapshot)
                        if r is not None)),
                rows, len(self.queue))
        for slot, req in enumerate(snapshot):
            if req is None:
                continue
            n_app = 0
            for k in range(toks.shape[0]):
                if not live[k, slot]:
                    break                 # slot froze earlier in the chunk
                req.out_tokens.append(int(toks[k, slot]))
                n_app += 1
            if self.active[slot] is not req:
                continue                  # slot re-admitted since dispatch
            self._ctx[slot] = min(self._ctx[slot] + n_app, self.window)
            self._tok_np[slot, 0] = req.out_tokens[-1]
            if (len(req.out_tokens) >= req.max_new_tokens
                    or (req.eos_id is not None
                        and req.out_tokens[-1] == req.eos_id)):
                self._retire(slot)

    def _may_emit(self):
        """Host-side prediction of whether any slot can still produce
        tokens (EOS hits are only discovered at reconcile)."""
        return any(r is not None and self._pred[s] < r.max_new_tokens
                   for s, r in enumerate(self.active))

    def step(self):
        """One synchronous engine iteration: admit waiting requests, then
        one decode dispatch — `decode_chunk` fused tokens (device mode)
        or a single token (host mode) — and reconcile."""
        self._admit()
        # a whole admission wave can finish at prefill (max_new <= 1 /
        # instant EOS) without occupying a slot — keep draining the
        # queue rather than stranding it behind an idle engine
        while all(r is None for r in self.active) and self.queue:
            self._admit()
        if all(r is None for r in self.active):
            return False
        if self.mode == "host":
            return self._step_host()
        self._reconcile(*self._dispatch_chunk())
        return True

    def _step_host(self):
        """The pre-device-resident loop: one decode_step, logits pulled
        to host, np-rng sampling per slot. Kept as parity reference."""
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.last_tok, self.pos)
        self.pos = self.pos + 1
        logits_np = np.asarray(jax.device_get(logits), np.float32)
        self.host_syncs += 1
        if self.telemetry is not None:
            rows = [min(self._ctx[s] + 1, self.window)
                    for s, r in enumerate(self.active) if r is not None]
            self.telemetry.on_chunk(1, len(rows), rows, len(self.queue))
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = sample_host(logits_np[slot], req.temperature, req.top_k,
                              self.rng)
            req.out_tokens.append(tok)
            self._ctx[slot] = min(self._ctx[slot] + 1, self.window)
            self._tok_np[slot, 0] = tok
            if (len(req.out_tokens) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id)):
                self._retire(slot)
        self.last_tok = jnp.asarray(np.array(self._tok_np))
        return True

    def run(self, max_steps=10000):
        """Serve until queue and slots drain. Device mode pipelines: the
        next chunk is dispatched before the previous chunk's tokens are
        pulled, so reconcile/admit/prefill run while the device decodes
        (a freed slot rejoins one chunk later)."""
        steps = 0
        if self.mode == "host":
            while (self.queue or any(r is not None for r in self.active)) \
                    and steps < max_steps:
                self.step()
                steps += 1
            return self.done, steps
        pending = None
        while steps < max_steps:
            if pending is None:
                self._admit()   # nothing in flight: admit synchronously
                # requests can finish AT prefill without occupying a
                # slot; keep admitting so the queue is never stranded
                while not self._may_emit() and self.queue:
                    self._admit()
                if not self._may_emit():
                    break
            nxt = self._dispatch_chunk() if self._may_emit() else None
            if pending is not None:
                self._reconcile(*pending)
            self._admit()       # freed slots rejoin at the NEXT chunk
            pending = nxt
            steps += 1
        if pending is not None:
            self._reconcile(*pending)
        return self.done, steps
