from repro.serving.engine import ServeEngine
