from repro.serving.engine import Request, RequestStats, ServeEngine
from repro.serving.sampling import sample_host, sample_tokens

__all__ = ["Request", "RequestStats", "ServeEngine", "sample_host",
           "sample_tokens"]
