from repro.serving.engine import Request, ServeEngine
from repro.serving.sampling import sample_host, sample_tokens

__all__ = ["Request", "ServeEngine", "sample_host", "sample_tokens"]
