"""Design-space exploration primitives (paper §V-E + the §VI "future
work" gradient-based co-optimization, realized here).

The user-facing entry point is now the unified query API in `repro.api`
(`Session` + `SweepQuery`/`MatchQuery`/`OptimizeQuery`); this module
keeps the underlying models and reference implementations:

  * evaluate():   the SCALAR reference evaluator for one BankConfig —
                  the batched lattice evaluator (repro.core.dse_batch)
                  asserts parity against it
  * sweep():      DEPRECATED shim over Session().sweep(SweepQuery(...))
  * shmoo():      Fig 10 — feasibility of each bank config against each
                  workload's (read-frequency, lifetime) demand
  * pareto():     non-dominated set over caller-chosen metric keys
                  (sort-based skyline filter)
  * grad_optimize(): continuous co-optimization of (write VT, device
                  widths, WWL boost) by gradient descent through the
                  differentiable retention/timing models — possible
                  because the whole model stack is jnp (beyond-paper).
"""
from __future__ import annotations

import itertools
import warnings
from dataclasses import dataclass
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import power as power_mod
from repro.core import retention as ret_mod
from repro.core import timing as timing_mod
from repro.core.bank import Bank, BankConfig, build_bank
from repro.core.cells import CELLS, Bitcell, with_write_vt
from repro.core.techfile import SYN40, PHI_T


@dataclass
class DesignPoint:
    """One evaluated bank at one operating point.

    Units: `area_um2` um^2; `f_max_hz` Hz; bandwidths bits/s; powers
    watts; `retention_s` / `t_read_s` / `t_write_s` seconds. `vdd_scale`
    is the operating-voltage multiplier the point was evaluated at
    (tech.vdd * vdd_scale; 1.0 = the deck's nominal rail)."""
    cfg: BankConfig
    area_um2: float
    f_max_hz: float
    read_bw_bps: float
    write_bw_bps: float
    eff_bw_bps: float
    leakage_w: float
    refresh_w: float
    retention_s: float
    swing_ok: bool
    t_read_s: float = 0.0
    t_write_s: float = 0.0
    vdd_scale: float = 1.0

    @property
    def standby_w(self) -> float:
        """Total standby power (W): leakage + refresh (the paper's idle
        cost)."""
        return self.leakage_w + self.refresh_w

    def as_dict(self):
        d = {"cell": self.cfg.cell, "word_size": self.cfg.word_size,
             "num_words": self.cfg.num_words, "wwlls": self.cfg.wwlls,
             "write_vt": self.cfg.write_vt}
        for k in ("area_um2", "f_max_hz", "eff_bw_bps", "leakage_w",
                  "refresh_w", "retention_s", "swing_ok", "t_read_s",
                  "t_write_s", "standby_w", "vdd_scale"):
            d[k] = getattr(self, k)
        return d


def evaluate(cfg: BankConfig, vdd_scale: float = 1.0) -> DesignPoint:
    """Scalar reference evaluation of one config at one operating voltage
    (`vdd_scale` multiplies tech.vdd; geometry/floorplan are voltage-
    independent). The batched evaluators in `repro.core.dse_batch` assert
    parity against this function."""
    bank = build_bank(cfg)
    t = timing_mod.analyze(bank, vdd_scale=vdd_scale)
    if bank.is_gc:
        cell = bank.cell
        r = ret_mod.analyze(cell, cfg.tech, wwlls=cfg.wwlls,
                            wwl_boost=cfg.wwl_boost, vdd_scale=vdd_scale)
        ret = r.t_ret_s
    else:
        ret = float("inf")
    p = power_mod.analyze(bank, t.f_max_hz, t_ret_s=ret if bank.is_gc else None,
                          vdd_scale=vdd_scale)
    ws = cfg.word_size
    if bank.is_gc:
        # dual port: concurrent read + write at f_max
        rbw = t.f_max_hz * ws
        wbw = t.f_max_hz * ws
        ebw = rbw + wbw
    else:
        # shared port: effective bandwidth halves (paper C6)
        rbw = t.f_max_hz * ws / 2
        wbw = t.f_max_hz * ws / 2
        ebw = rbw + wbw
    return DesignPoint(cfg, bank.area_um2, t.f_max_hz, rbw, wbw, ebw,
                       p.leakage_w, p.refresh_w, ret, t.read_swing_ok,
                       t.t_read_s, t.t_write_s, vdd_scale)


def lattice_configs(cells=("gc2t_nn", "gc2t_np", "gc2t_osos"),
                    word_sizes=(16, 32, 64, 128),
                    num_words=(16, 32, 64, 128),
                    write_vts=(None,), wwlls=(False, True),
                    tech=SYN40) -> List[BankConfig]:
    """Expand a config lattice, skipping write-VT flavors that don't match
    the cell's device family (Si VT overrides on OS cells and vice versa)."""
    out = []
    for c, ws, nw, vt, ls in itertools.product(cells, word_sizes, num_words,
                                               write_vts, wwlls):
        wf = getattr(CELLS[c], "write_flavor", None)
        if vt is not None and (wf is None
                               or wf.startswith("os") != vt.startswith("os")):
            continue
        out.append(BankConfig(ws, nw, cell=c, write_vt=vt, wwlls=ls,
                              tech=tech))
    return out


def sweep(cells=("gc2t_nn", "gc2t_np", "gc2t_osos"),
          word_sizes=(16, 32, 64, 128), num_words=(16, 32, 64, 128),
          write_vts=(None,), wwlls=(False, True)) -> List[DesignPoint]:
    """DEPRECATED: use repro.api.Session().sweep(SweepQuery(...)). This
    shim routes through the session so old call sites get the batched
    (vmapped) evaluator for free."""
    warnings.warn(
        "dse.sweep() is deprecated; use repro.api.Session().sweep("
        "SweepQuery(...))", DeprecationWarning, stacklevel=2)
    from repro.api import Session, SweepQuery
    q = SweepQuery(cells=tuple(cells), word_sizes=tuple(word_sizes),
                   num_words=tuple(num_words), write_vts=tuple(write_vts),
                   wwlls=tuple(wwlls))
    return list(Session().sweep(q).points)


# ---------------------------------------------------------------------------
# shmoo (Fig 10)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Demand:
    """One workload's cache demand (GainSight analogue).

    Units — read carefully, these are the contract of the whole matching
    flow:
      read_freq_hz   read-request rate in Hz arriving at ONE memory
                     instance of the profiled hierarchy (the workload
                     profiler has already split the chip's aggregate
                     traffic over its cores x banks instances — it is
                     NOT the whole-chip feed). Single-bank feasibility
                     (`feasible`) compares it directly against a bank's
                     `f_max_hz`; when one bank falls short,
                     `multibank.banks_needed` sizes an interleaved macro
                     whose AGGREGATE n * f_bank covers this same rate.
      lifetime_s     how long a datum must stay readable, in seconds.
      capacity_bits  macro capacity the demand needs (bits; 0 = don't
                     size for capacity).

    Frozen (hashable) so queries carrying Demands can key session caches.
    """
    name: str
    level: str                 # "L1" | "L2"
    read_freq_hz: float
    lifetime_s: float
    capacity_bits: int = 0


def feasible(dp: DesignPoint, d: Demand, *, allow_refresh=True) -> bool:
    """A bank works for a demand if it meets the read frequency and either
    natively retains data for the lifetime or (if allowed) refreshes at
    <10% bandwidth overhead (multi-banked designs absorb capacity).

    The refresh rule, exactly: with `allow_refresh=True` a bank whose
    `retention_s` falls short of `d.lifetime_s` still passes when
    `refresh_rate < 0.1 * f_max_hz`, where `refresh_rate = num_words /
    retention_s` is the row-rewrite rate (rows/s) needed to keep the
    array alive. `retention_s <= 0` (the cell cannot hold the margin at
    all, e.g. at a collapsed operating voltage) never passes, refresh or
    not. This is the SCALAR reference; `repro.core.dse_batch.
    feasible_grid` evaluates the same rule over a whole
    (vdd x lattice x demand) grid on device, bit-for-bit."""
    if not dp.swing_ok or dp.f_max_hz < d.read_freq_hz:
        return False
    if dp.retention_s >= d.lifetime_s:
        return True
    if not allow_refresh or dp.retention_s <= 0:
        return False
    refresh_rate = dp.cfg.num_words / dp.retention_s  # rows/s to rewrite
    return refresh_rate < 0.1 * dp.f_max_hz


def shmoo_key(cfg: BankConfig) -> str:
    """Grid-column label of one config — single source of truth for the
    scalar `shmoo` and the batched `dse_batch.shmoo_batch`."""
    return f"{cfg.cell}/{cfg.word_size}x{cfg.num_words}" + \
        ("+ls" if cfg.wwlls else "")


def shmoo(points: List[DesignPoint], demands: List[Demand], *,
          allow_refresh: bool = True) -> dict:
    """Fig 10 grid: demand x bank-config -> pass/fail."""
    grid = {}
    for d in demands:
        row = {}
        for dp in points:
            row[shmoo_key(dp.cfg)] = feasible(dp, d,
                                              allow_refresh=allow_refresh)
        grid[f"{d.level}:{d.name}"] = row
    return grid


# metrics where bigger is better; everything else is minimized
PARETO_MAXIMIZE = frozenset({"f_max_hz", "read_bw_bps", "write_bw_bps",
                             "eff_bw_bps", "retention_s"})


def pareto(points: List[DesignPoint],
           keys: Sequence[str] = ("area_um2", "f_max_hz", "standby_w"),
           ) -> List[DesignPoint]:
    """Non-dominated set over the chosen metric `keys` (DesignPoint
    attribute names). Metrics in PARETO_MAXIMIZE are maximized, the rest
    minimized. Sort-based skyline filter: after a lexicographic sort any
    dominator of a point precedes it, so each candidate is compared only
    against the current front — O(n log n + n * |front|) instead of the
    old all-pairs O(n^2) scan (which also ignored `keys` entirely).
    Returns the front sorted by the first key; infeasible (swing-fail)
    points are excluded."""
    def metric(dp):
        return tuple(-getattr(dp, k) if k in PARETO_MAXIMIZE
                     else getattr(dp, k) for k in keys)

    def dominates(a, b):
        return all(x <= y for x, y in zip(a, b)) and \
            any(x < y for x, y in zip(a, b))

    ranked = sorted(((metric(dp), i, dp) for i, dp in enumerate(points)
                     if dp.swing_ok), key=lambda t: (t[0], t[1]))
    front, front_vals = [], []
    for m, _, dp in ranked:
        if not any(dominates(fv, m) for fv in front_vals):
            front.append(dp)
            front_vals.append(m)
    return front


# ---------------------------------------------------------------------------
# gradient-based co-optimization (paper §VI future work, realized)
# ---------------------------------------------------------------------------

# re-export: the differentiable twin of evaluate() lives in dse_grad (it
# carries the traced algebra); callers conventionally reach it as
# dse.evaluate_grad. The projected-Adam optimizer over it is
# repro.optim.dse_opt (the OptimizeQuery engine).
from repro.core.dse_grad import evaluate_grad, evaluate_grad_fn  # noqa: E402


def grad_optimize(cell_name="gc2t_nn", *, target_ret_s=1e-4,
                  target_freq_hz=None, steps=300, lr=0.02, tech=SYN40,
                  verbose=False) -> dict:
    """Continuously optimize (write-VT, write width, WWL boost) to MEET a
    retention target while maximizing read current (speed) and minimizing
    cell area — gradient descent through the differentiable retention
    integral and device model. Returns the optimized design and its
    discrete-model validation."""
    cell = CELLS[cell_name]
    wf = cell.wf(tech)
    rf = cell.rf(tech)
    c_sn_base = cell.sn_cap(tech)
    v_m = ret_mod._margin_voltage(cell, tech)
    vdd = tech.vdd

    def unpack(theta):
        vt = 0.25 + 0.62 * jax.nn.sigmoid(theta[0])       # 0.25..0.87 V
        w_w = 0.06 + 0.32 * jax.nn.sigmoid(theta[1])      # 0.06..0.38 um
        boost = 0.8 * jax.nn.sigmoid(theta[2])            # 0..0.8 V
        return vt, w_w, boost

    def retention_of(vt, w_w, boost):
        c_sn = c_sn_base + wf.cj_f_per_um * (w_w - cell.w_write)
        v0 = jnp.minimum(vdd, vdd + boost - vt + 0.12) \
            - cell.wwl_couple_ratio * vdd
        fn = ret_mod.leak_fn(cell, tech)
        vs = jnp.linspace(v_m, jnp.maximum(v0, v_m + 1e-3), 512)
        inv = 1.0 / jnp.maximum(
            jax.vmap(lambda v: fn(v, vt0=vt, w=w_w))(vs), 1e-30)
        return c_sn * jnp.trapezoid(inv, vs)

    def speed_of(vt, w_w, boost):
        # write-limited component: on-current into SN at boosted gate
        from repro.core.spice.mna import channel_current_raw
        i_on = channel_current_raw(
            jnp.float32(wf.polarity), vt, wf.n_slope, wf.k_prime, wf.lambda_,
            w_w, cell.l_write, vdd + boost, vdd, vdd * 0.45)
        return jnp.abs(i_on)

    def loss(theta):
        vt, w_w, boost = unpack(theta)
        ret = retention_of(vt, w_w, boost)
        spd = speed_of(vt, w_w, boost)
        area = w_w + 0.35 * boost            # normalized area proxy (ring)
        pen = jax.nn.relu(jnp.log(target_ret_s) - jnp.log(ret)) ** 2
        return 8.0 * pen - 0.5 * jnp.log(spd) + 0.3 * area

    theta = jnp.zeros((3,))
    val_grad = jax.jit(jax.value_and_grad(loss))
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    hist = []
    for i in range(steps):
        l, g = val_grad(theta)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        theta = theta - lr * m / (jnp.sqrt(v) + 1e-8)
        if verbose and i % 50 == 0:
            hist.append(float(l))
    vt, w_w, boost = (float(x) for x in unpack(theta))
    ret = float(retention_of(vt, w_w, boost))
    return {"write_vt": vt, "w_write_um": w_w, "wwl_boost": boost,
            "retention_s": ret, "target_ret_s": target_ret_s,
            "met": ret >= target_ret_s * 0.95, "loss_history": hist}
