"""OpenGCRAM core — the paper's contribution as a composable JAX library.

Entry point: repro.core.compiler.GCRAMCompiler (config -> netlists,
floorplan, timing/power/retention reports); design-space exploration in
repro.core.dse; multibank macros in repro.core.multibank.
"""
