"""OpenGCRAM core — the paper's contribution as a composable JAX library.

User entry point: the unified query API in `repro.api` (`Session` +
`CompileQuery`/`SweepQuery`/`MatchQuery`/`OptimizeQuery`). This package
holds the underlying models: bank generation (`bank`), analytic +
transient timing (`timing`), power (`power`), retention (`retention`),
the scalar/batched design-space evaluators (`dse`, `dse_batch`),
compilation to netlists + floorplans (`compiler`), and multibank macro
composition (`multibank`). `GCRAMCompiler`, `dse.sweep` and
`build_multibank` remain as deprecated shims over `repro.api`.
"""
