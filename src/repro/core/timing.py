"""Timing: analytical (logical effort + Elmore, GEMTOO-class) AND
transient-simulated (HSPICE-class) read/write paths.

Read critical path (paper §V-C: read limits frequency):
  clk->addr DFF -> decoder (logical-effort chain over row fanout)
  -> WL RC (Elmore) -> cell drives RBL swing (I_read into C_RBL)
  -> sense amp -> out DFF, plus the control delay-chain quantization:
  the chain must cover the analog path with margin; its stage count
  jumps at array-size thresholds — reproducing the 1 Kb -> 4 Kb
  frequency cliff of Fig 7(a).

The transient path builds the RBL column netlist (driver, wordline RC
ladder, active cell, leaker cells lumped, SA load) and integrates it with
the batched Newton engine; tests assert analytic-vs-transient deviation
<= 15% X claim (the GEMTOO gap the paper cites). `simulate_read` is the
SCALAR reference; `repro.core.spice.char_batch.characterize` runs the
same netlist/integrator/extraction over a whole design lattice in one
compiled program per cell topology and asserts 1% parity against it.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

import numpy as np

from repro.core import bank as bank_mod
from repro.core.cells import Sram6T
from repro.core.spice import devices as dv
from repro.core.techfile import TechFile, with_vdd_scale

FO4_S = 18e-12      # fanout-4 inverter delay in syn40
LE_BRANCH = 2.0     # logical-effort branching per decode stage
REF_SETTLE_S = 40e-12  # GC single-ended read: reference settle adder
WL_DRIVER_R_OHM = 2.5e3 / 4.0   # sized wordline driver
WBL_DRIVER_R_OHM = 800.0        # write-bitline driver
SA_INPUT_C_F = 2e-15            # SA input + mux junction on the RBL
CHAIN_MARGIN = 1.3              # control chain covers analog path by 30%
CHAIN_MAX_STAGES = 64           # before switching to a coarser unit
CHAIN_UNIT_GROWTH = 4.0


# -- pure formula kernels, shared with the batched lattice evaluator
#    (repro.core.dse_batch); elementwise, so they accept scalars or arrays

def elmore_delay(r_drv, r, c):
    """Driver-R + distributed-RC Elmore delay of one wire."""
    return 0.69 * (r_drv * c + 0.5 * r * c)


def cell_swing_time(dv_sense, c_bl, i_net, r_bl):
    """Sense-swing time: current derating (Vds droop over the swing) +
    distributed-RC Elmore of the bitline ladder; calibrated against the
    transient engine to <= 15% (the GEMTOO-class gap, asserted in tests)."""
    return dv_sense * c_bl / (0.75 * i_net) + 0.35 * r_bl * c_bl + 9e-12


def chain_unit(analog_s, unit_s):
    """Delay-chain stage granularity: very slow paths (OS reads) switch to
    a coarser unit, capping the chain at CHAIN_MAX_STAGES (a real
    controller would divide the clock instead). Scalar reference; the
    batched evaluator vectorizes the same recurrence."""
    while analog_s * CHAIN_MARGIN / unit_s > CHAIN_MAX_STAGES:
        unit_s *= CHAIN_UNIT_GROWTH
    return unit_s


def bank_at_vdd(bank, vdd_scale: float):
    """A shallow view of `bank` whose config carries the vdd-scaled deck.
    Geometry, floorplan and wire RC are voltage-independent, so the copy
    shares them; only the electrical algebra sees the scaled rail."""
    if vdd_scale == 1.0:
        return bank
    cfg = dataclasses.replace(bank.cfg,
                              tech=with_vdd_scale(bank.cfg.tech, vdd_scale))
    return dataclasses.replace(bank, cfg=cfg)


@dataclass
class Timing:
    """All delays in seconds, `f_max_hz` in hertz."""
    t_read_s: float
    t_write_s: float
    t_wl_s: float
    t_cell_s: float
    t_dec_s: float
    delay_stages: int
    f_max_hz: float
    read_swing_ok: bool

    def as_dict(self):
        return self.__dict__.copy()


def decoder_delay(rows: int) -> float:
    """Logical-effort sized decode chain: delay ~ FO4 * stages, stages ~
    ln(fanout) with branching."""
    n_bits = max(1, int(math.ceil(math.log2(max(rows, 2)))))
    path_effort = rows * LE_BRANCH
    stages = max(2, int(round(math.log(max(path_effort, 2), 4))) + n_bits // 3)
    return stages * FO4_S


def wordline_delay(bank, rc=None) -> float:
    """`rc` (r_ohm, c_f) overrides the hand-modeled wordline RC — the
    hook the layout tier uses to drive this with EXTRACTED parasitics."""
    r, c = rc if rc is not None else bank_mod.wordline_rc(bank)
    return elmore_delay(WL_DRIVER_R_OHM, r, c)


def cell_read_time(bank, *, v_sn=None, rc=None) -> tuple:
    """Time for the cell to move RBL by the sense swing; returns
    (seconds, swing_ok). `rc` (r_ohm, c_f) overrides the hand-modeled
    read-bitline RC (extracted-parasitics hook, via totals included)."""
    tech = bank.cfg.tech
    r_bl, c_bl = rc if rc is not None else bank_mod.bitline_rc(bank)
    c_bl += SA_INPUT_C_F
    if isinstance(bank.cell, Sram6T):
        i = bank.cell.i_read(tech)
        dv_sense = tech.v_sense_diff
        leak = 0.0
    else:
        cell = bank.cell
        if v_sn is None:
            bit = 0 if cell.read_on_sn_low else 1
            v_sn = cell.v_sn_written(tech, bit, wwlls=bank.cfg.wwlls,
                                     wwl_boost=bank.cfg.wwl_boost)
        v_rbl0 = 0.0 if cell.predischarge else tech.vdd
        swing = tech.v_sense_se
        v_rbl_mid = v_rbl0 + (0.5 * swing if cell.predischarge else -0.5 * swing)
        i = cell.i_read(tech, v_sn, v_rbl_mid)
        # unselected leakers fight the read current
        off_sn = cell.v_sn_written(tech, 1 if cell.read_on_sn_low else 0)
        leak = (bank.rows - 1) * cell.i_leak_rbl(tech, off_sn)
        dv_sense = swing
    i_net = max(i - leak, 1e-12)
    ok = i > 3.0 * leak
    return cell_swing_time(dv_sense, c_bl, i_net, r_bl), ok


def write_time(bank) -> float:
    """WBL drive + WL + SN settle through the write device."""
    tech = bank.cfg.tech
    t_wl = wordline_delay(bank)
    r_bl, c_bl = bank_mod.bitline_rc(bank)
    t_bl = elmore_delay(WBL_DRIVER_R_OHM, r_bl, c_bl)
    if isinstance(bank.cell, Sram6T):
        return t_wl + t_bl + 2 * FO4_S
    cell = bank.cell
    wf = cell.wf(tech)
    v_gate = tech.vdd + (bank.cfg.wwl_boost if bank.cfg.wwlls else 0.0)
    i_on = abs(float(dv.channel_current(
        wf, cell.w_write, cell.l_write, v_gate, tech.vdd, tech.vdd * 0.45)))
    t_sn = cell.sn_cap(tech) * 0.9 * tech.vdd / max(i_on, 1e-12)
    return t_wl + t_bl + t_sn


def size_delay_chain(analog_s: float, tech) -> tuple:
    """Control delay-chain sizing: the chain must cover the analog read
    path with >= 30% margin, quantized to stages (the Fig 7a staircase).
    Returns (stages, unit_s); chain delay is stages * unit_s."""
    unit = chain_unit(analog_s, tech.stage_delay_s)
    return int(math.ceil(analog_s * CHAIN_MARGIN / unit)), unit


def analyze(bank, *, vdd_scale: float = 1.0,
            parasitics: str = "modeled") -> Timing:
    """Analytic read/write timing closure of one bank.

    parasitics="modeled" (default) uses the hand RC models in
    `core.bank`; "extracted" drives the read critical path — wordline
    Elmore, cell sense-swing, and through them the control delay-chain
    stage count — with the layout-extracted read-column RC from
    `repro.geom.extract` (rail-row overhead, strip jogs, via stacks).
    The write path stays hand-modeled either way: the extractor models
    the READ column (see docs/layout.md)."""
    if parasitics not in ("modeled", "extracted"):
        raise ValueError(f"parasitics must be 'modeled' or 'extracted', "
                         f"got {parasitics!r}")
    bank = bank_at_vdd(bank, vdd_scale)
    tech = bank.cfg.tech
    wl_rc = bl_rc = None
    if parasitics == "extracted":
        from repro.geom import extract as geom_extract
        rcs = geom_extract.read_column_rc(bank)
        wl_rc = (rcs["wl_r_ohm"], rcs["wl_c_f"])
        bl_rc = (rcs["bl_r_ohm"], rcs["bl_c_f"])
    t_dec = decoder_delay(bank.rows)
    t_wl = wordline_delay(bank, rc=wl_rc)
    t_cell, ok = cell_read_time(bank, rc=bl_rc)
    t_colmux = 2 * FO4_S if bank.has_colmux else 0.0
    analog = t_wl + t_cell + t_colmux + tech.sa_delay_s
    if bank.is_gc:
        analog += REF_SETTLE_S  # single-ended sensing reference settle
    stages, unit = size_delay_chain(analog, tech)
    t_chain = stages * unit
    t_read = tech.dff_delay_s + t_dec + t_chain + tech.dff_delay_s
    t_wr = tech.dff_delay_s + t_dec + max(write_time(bank), t_chain * 0.6)
    bank.delay_stages = stages
    f = 1.0 / max(t_read, t_wr)
    return Timing(t_read, t_wr, t_wl, t_cell, t_dec, stages, f, ok)


# ---------------------------------------------------------------------------
# transient-simulated read path (HSPICE-analogue)
# ---------------------------------------------------------------------------

T_END_MIN_S = 0.5e-9        # stop-time floor for the read transient
T_END_OVER_ANALYTIC = 6.0   # stop time as a multiple of the analytic t_cell
T0_FRACTION = 0.05          # precharge-release instant as fraction of t_end


def read_stimulus(cell, tech, v_sn: float, t0: float):
    """The four read-path drive waveforms (rwl activation, precharge/
    predischarge release, SN level, VDD rail) and the RBL idle level.

    SINGLE source of truth for the stimulus recipe: the scalar
    `simulate_read` and the batched `char_batch` pipeline both build
    their waves here, which is what anchors their 1% parity contract —
    edit timings/levels in one place only."""
    vdd = tech.vdd
    rwl_idle = vdd if not cell.rwl_active_high else 0.0
    rwl_act = 0.0 if not cell.rwl_active_high else vdd
    v_pre = 0.0 if cell.predischarge else vdd
    en_idle = 0.0 if not cell.predischarge else vdd
    en_off = vdd if not cell.predischarge else 0.0
    waves = [
        ([0.0, t0, t0 * 1.2], [rwl_idle, rwl_idle, rwl_act]),
        ([0.0, t0 * 0.8, t0], [en_idle, en_idle, en_off]),
        ([0.0, 1.0], [v_sn, v_sn]),
        ([0.0, 1.0], [vdd, vdd]),
    ]
    return waves, v_pre


def read_netlist(bank, n_seg: int = 8, rc=None):
    """RBL column: WL driver -> RC ladder -> active cell + lumped leakers
    -> SA cap. Returns (Circuit, metadata). `rc` (r_ohm, c_f) overrides
    the hand-modeled ladder totals with extracted ones; the element
    STRUCTURE is identical either way (via R/C folds uniformly into the
    ladder segments), so topology-grouped batching is unaffected."""
    from repro.core.spice.mna import Circuit
    tech = bank.cfg.tech
    cell = bank.cell
    r_bl, c_bl = rc if rc is not None else bank_mod.bitline_rc(bank)
    ckt = Circuit()
    # RWL driver as a voltage source on the cell gate path; RBL ladder:
    ckt.vsrc("rwl", 0)
    pre_high = not cell.predischarge
    # precharge PMOS / predischarge NMOS gated by EN (wave 1) — the
    # paper's Read_Port_Data modification (§V-A): released at t0.
    ckt.vsrc("pre_en", 1)
    if pre_high:
        ckt.vsrc("vdd", 3)
        ckt.dev(tech.flavor("pmos_svt"), 1.2, 0.04, "pre_en", "vdd",
                "rbl_0", name="precharge")
    else:
        ckt.dev(tech.flavor("nmos_svt"), 1.2, 0.04, "pre_en", "rbl_0",
                "0", name="predischarge")
    for i in range(n_seg):
        a, b = f"rbl_{i}", f"rbl_{i+1}"
        ckt.r(a, b, r_bl / n_seg)
        ckt.c(b, "0", c_bl / n_seg)
    ckt.c("rbl_0", "0", 2e-15)  # SA input
    # active cell at the far end: read device gate=SN (source), RBL drain
    bit = 0 if cell.read_on_sn_low else 1
    v_sn = cell.v_sn_written(tech, bit, wwlls=bank.cfg.wwlls,
                             wwl_boost=bank.cfg.wwl_boost)
    ckt.vsrc("sn", 2)
    rf = cell.rf(tech)
    far = f"rbl_{n_seg}"
    ckt.dev(rf, cell.w_read, cell.l_read, "sn", far, "rwl", name="read_dev")
    ckt.probe("rbl_near", "rbl_0")
    ckt.probe("rbl_far", far)
    meta = {"v_sn": v_sn, "pre_high": pre_high, "vdd": tech.vdd}
    return ckt, meta


def simulate_read(bank, n_steps=300, t_end=None, solver="jnp"):
    """Transient RBL swing; returns (t_cell_sim_seconds, traces).

    Integrates in float64 (enable_x64): the MNA Jacobian's G_BIG Norton
    rows put cond(J) around 1e6, so float32 Newton solves carry ~1e-1
    relative noise into the traces — double precision is what makes this
    path the accuracy ANCHOR the analytic model calibrates against (and
    what the batched lattice pipeline asserts 1% parity with)."""
    from jax.experimental import enable_x64
    with enable_x64():
        return _simulate_read_x64(bank, n_steps, t_end, solver)


def _simulate_read_x64(bank, n_steps, t_end, solver):
    from repro.core.spice.transient import Transient
    import jax.numpy as jnp
    tech = bank.cfg.tech
    cell = bank.cell
    ckt, meta = read_netlist(bank)
    sys = ckt.build()
    tr = Transient(sys, solver=solver)
    t_an, _ = cell_read_time(bank)
    t_end = t_end or max(T_END_OVER_ANALYTIC * t_an, T_END_MIN_S)
    t0 = T0_FRACTION * t_end
    waves, v_pre = read_stimulus(cell, tech, meta["v_sn"], t0)
    res = tr.run(waves, t_end, n_steps=n_steps,
                 v0=jnp.full((sys.n,), v_pre))
    swing = tech.v_sense_se
    target = v_pre + (swing if cell.predischarge else -swing)
    from repro.core.spice.transient import crossing_time
    tc, valid = crossing_time(res["t"], res["rbl_near"], target,
                              rising=cell.predischarge)
    t_cell = float(tc) - t0 if bool(valid) else float("inf")
    return float(t_cell), res
