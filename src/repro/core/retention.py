"""Retention (paper Fig 8): SN decay through write-device subthreshold +
read-gate leakage, until the read margin is lost.

Two paths, cross-validated in tests:
  * closed-form-ish ODE integration in jnp (fast, differentiable — feeds
    the DSE gradient co-optimizer);
  * the transient engine on the retention netlist (the "HSPICE" path).

Retention is defined as t(V_SN crosses V_margin) for the worst-case
state — the decaying '1' for NMOS-read cells (paper: "primarily
constrained by the decay of state 1"), the rising '0' for PMOS-read.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cells import Bitcell
from repro.core.spice.mna import channel_current_raw
from repro.core.techfile import TechFile, with_vdd_scale


@dataclass
class Retention:
    """Retention analysis result. Units: `t_ret_s` seconds, voltages in
    volts, `i_leak0_a` (the SN leak at the freshly-written level) in
    amperes."""
    t_ret_s: float
    v_sn0: float
    v_margin: float
    i_leak0_a: float

    def as_dict(self):
        return self.__dict__.copy()


def _margin_voltage(cell: Bitcell, tech: TechFile) -> float:
    """SN level at which the '1' state is lost (paper: retention is
    "primarily constrained by the decay of state 1"):
      NMOS read — below VT_read + 0.15 V the cell can no longer meet the
      sense swing;
      PMOS read — below VDD - |VT_read| - 0.15 V the read device starts
      conducting and a stored '1' mis-reads as '0'."""
    rf = cell.rf(tech)
    if cell.read_on_sn_low:
        return tech.vdd - rf.vt0 - 0.15
    return rf.vt0 + 0.15


def leak_fn(cell: Bitcell, tech: TechFile):
    """Returns i_leak(v_sn) (A, discharging positive) as a jnp function of
    the raw write-device params — differentiable for DSE."""
    wf, rf = cell.wf(tech), cell.rf(tech)

    def fn(v_sn, vt0=wf.vt0, w=cell.w_write):
        # write device off: gate at 0 (NMOS) with WBL at 0 -> discharges SN
        i_w = channel_current_raw(
            jnp.float32(wf.polarity), vt0, wf.n_slope, wf.k_prime,
            wf.lambda_, w, cell.l_write,
            jnp.float32(0.0 if wf.polarity > 0 else tech.vdd),
            v_sn, jnp.float32(0.0))
        i_g = rf.i_gate_a_per_um * cell.w_read * v_sn / 1.1
        return jnp.abs(i_w) + i_g

    return fn


def analyze(cell: Bitcell, tech: TechFile, *, wwlls=False, wwl_boost=0.55,
            n_steps=4000, vdd_scale: float = 1.0) -> Retention:
    """Log-time ODE integration of dV/dt = -I(V)/C_SN (decaying '1').

    `vdd_scale` evaluates the cell at a scaled operating voltage (the
    paper's on-the-fly retention knob): the written SN level, the margin
    and the write-device leak all follow the scaled rail."""
    tech = with_vdd_scale(tech, vdd_scale)
    c_sn = cell.sn_cap(tech)
    v0 = cell.v_sn_written(tech, 1, wwlls=wwlls, wwl_boost=wwl_boost)
    v_m = _margin_voltage(cell, tech)
    fn = leak_fn(cell, tech)
    t = _cross_time(fn, c_sn, v0, v_m, n_steps)
    return Retention(float(t), v0, v_m, float(fn(jnp.float32(v0))))


def _cross_time(i_of_v, c_sn, v0, v_margin, n_steps):
    """t = C * integral_{v_m}^{v0} dV / I(V)  (exact for dV/dt=-I/C)."""
    if v0 <= v_margin:
        return 0.0
    vs = jnp.linspace(v_margin, v0, n_steps)
    inv_i = 1.0 / jnp.maximum(jax.vmap(i_of_v)(vs), 1e-30)
    return float(c_sn * jnp.trapezoid(inv_i, vs))


def retention_vs_vt(cell: Bitcell, tech: TechFile, vt_values, *,
                    wwlls=False) -> np.ndarray:
    """Fig 8(c): differentiable retention as a function of write-VT."""
    c_sn = cell.sn_cap(tech)
    v_m = _margin_voltage(cell, tech)
    wf = cell.wf(tech)

    def one(vt0):
        v0 = jnp.minimum(
            tech.vdd,
            tech.vdd + (0.55 if wwlls else 0.0) - vt0 + 0.12) \
            - cell.wwl_couple_ratio * tech.vdd
        fn = leak_fn(cell, tech)
        vs = jnp.linspace(v_m, jnp.maximum(v0, v_m + 1e-3), 2000)
        inv_i = 1.0 / jnp.maximum(jax.vmap(lambda v: fn(v, vt0=vt0))(vs), 1e-30)
        return c_sn * jnp.trapezoid(inv_i, vs)

    return np.asarray(jax.vmap(one)(jnp.asarray(vt_values, jnp.float32)))


def sn_decay_trace(cell: Bitcell, tech: TechFile, t_end, n=400, *,
                   wwlls=False):
    """Fig 8(b)/(e): V_SN(t) by direct integration (log-spaced)."""
    c_sn = cell.sn_cap(tech)
    v0 = cell.v_sn_written(tech, 1, wwlls=wwlls)
    fn = leak_fn(cell, tech)
    ts = jnp.concatenate([jnp.zeros((1,)),
                          jnp.logspace(math.log10(t_end) - 6,
                                       math.log10(t_end), n - 1)])

    def body(v, dt):
        v = jnp.maximum(v - fn(v) / c_sn * dt, 0.0)
        return v, v

    dts = jnp.diff(ts)
    _, vs = jax.lax.scan(body, jnp.float32(v0), dts)
    return np.asarray(ts[1:]), np.asarray(vs)
