"""Bank compilation: config -> Report (the paper's §III-A output set).

The user entry point is now the unified query API:

    from repro.api import Session, CompileQuery
    rep = Session().compile(word_size=32, num_words=32, cell="gc2t_nn")
    rep.write("out/gc32x32")

This module keeps the core implementation (`compile_bank`) plus the
DEPRECATED `GCRAMCompiler` facade, now a thin shim over the Session.

Produces (the paper's output set, §III-A, minus NDA'd GDS):
  * bank organization + module inventory + floorplan manifest (JSON —
    our layout stand-in; bounding boxes + power rings)
  * critical-path SPICE netlists (.sp text: read column, write path,
    retention cell) — simulate with the built-in batched engine or any
    external SPICE
  * timing (analytic + transient-simulated), power, retention reports
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings
from dataclasses import dataclass
from typing import Optional

from repro.core import power as power_mod
from repro.core import retention as ret_mod
from repro.core import timing as timing_mod
from repro.core.bank import Bank, BankConfig, build_bank
from repro.core.spice.mna import Circuit


def circuit_to_spice(ckt: Circuit, title: str) -> str:
    """Emit a SPICE netlist text for a built Circuit."""
    lines = [f"* {title} (OpenGCRAM-JAX syn40)", ".option post"]
    for i, (a, b, g) in enumerate(ckt.res):
        lines.append(f"R{i} {ckt.names[a]} {ckt.names[b]} {1.0/g:.6g}")
    for i, (a, b, c) in enumerate(ckt.caps):
        lines.append(f"C{i} {ckt.names[a]} {ckt.names[b]} {c:.6g}")
    for i, d in enumerate(ckt.devs):
        model = "nch" if d["pol"] > 0 else "pch"
        lines.append(
            f"M{i} {ckt.names[d['a']]} {ckt.names[d['g']]} "
            f"{ckt.names[d['b']]} 0 {model} w={d['w']:.3g}u l={d['l']:.3g}u "
            f"* vt0={d['vt0']:.3g}")
    for i, (node, wid) in enumerate(ckt.vsrcs):
        lines.append(f"V{i} {ckt.names[node]} 0 PWL_WAVE_{wid}")
    lines.append(".end")
    return "\n".join(lines)


@dataclass
class Report:
    cfg: BankConfig
    bank: Bank
    timing: timing_mod.Timing
    power: power_mod.Power
    retention: Optional[ret_mod.Retention]
    t_cell_sim_s: Optional[float]
    netlists: dict          # name -> spice text

    def summary(self) -> dict:
        out = {"config": {
            "word_size": self.cfg.word_size, "num_words": self.cfg.num_words,
            "cell": self.cfg.cell, "wwlls": self.cfg.wwlls,
            "write_vt": self.cfg.write_vt},
            "bank": self.bank.summary(),
            "timing": self.timing.as_dict(),
            "power": self.power.as_dict()}
        if self.retention:
            out["retention"] = self.retention.as_dict()
        if self.t_cell_sim_s is not None:
            out["t_cell_sim_s"] = self.t_cell_sim_s
            out["analytic_vs_sim_dev"] = abs(
                self.timing.t_cell_s - self.t_cell_sim_s) / max(
                self.t_cell_sim_s, 1e-15)
        return out

    # uniform Result interface (repro.api.results registers this class)
    def as_dict(self) -> dict:
        return self.summary()

    def write(self, outdir: str):
        os.makedirs(outdir, exist_ok=True)
        with open(os.path.join(outdir, "report.json"), "w") as f:
            json.dump(self.summary(), f, indent=1)
        with open(os.path.join(outdir, "floorplan.json"), "w") as f:
            json.dump(self.bank.plan.manifest(), f, indent=1)
        for name, text in self.netlists.items():
            with open(os.path.join(outdir, f"{name}.sp"), "w") as f:
                f.write(text)
        return outdir


def compile_bank(cfg: BankConfig, *, simulate: bool = False,
                 solver: str = "jnp") -> Report:
    """Core compile flow (used by repro.api.Session.compile)."""
    bank = build_bank(cfg)
    t = timing_mod.analyze(bank)
    ret = None
    t_sim = None
    netlists = {}
    if bank.is_gc:
        ret = ret_mod.analyze(bank.cell, cfg.tech, wwlls=cfg.wwlls,
                              wwl_boost=cfg.wwl_boost)
        ckt, _ = timing_mod.read_netlist(bank)
        netlists["read_column"] = circuit_to_spice(
            ckt, f"{cfg.cell} {bank.rows}x{bank.cols} read column")
        if simulate:
            t_sim, _ = timing_mod.simulate_read(bank, solver=solver)
    p = power_mod.analyze(bank, t.f_max_hz,
                          t_ret_s=ret.t_ret_s if ret else None)
    return Report(cfg, bank, t, p, ret, t_sim, netlists)


class GCRAMCompiler:
    """DEPRECATED facade; use repro.api.Session().compile(...)."""

    def __init__(self, cfg: BankConfig):
        self.cfg = cfg

    def compile(self, *, simulate: bool = False, solver: str = "jnp") -> Report:
        warnings.warn(
            "GCRAMCompiler is deprecated; use repro.api.Session().compile("
            "cfg) or Session().run(CompileQuery(cfg))",
            DeprecationWarning, stacklevel=2)
        from repro.api import Session
        return Session(self.cfg.tech).compile(self.cfg, simulate=simulate,
                                              solver=solver)
