"""Differentiable twin of `dse.evaluate` (the §VI gradient-based DSE).

`dse.evaluate` is the scalar reference: plain-Python float algebra,
`float()` casts, data-dependent branches — exact, but opaque to
autodiff. This module re-derives the SAME electrical algebra as a traced
jnp program over the CONTINUOUS design knobs so that
energy/delay/retention gradients flow into the projected-Adam optimizer
(`repro.optim.dse_opt`) behind `OptimizeQuery`:

  vdd_scale      array operating voltage multiplier (the paper's
                 on-the-fly retention knob; `with_vdd_scale` semantics)
  w_read_scale   read-device width multiplier
  w_write_scale  write-device width multiplier
  bl_wire_scale  bitline wire WIDTH multiplier (r ~ 1/s, c_wire ~ s)

Discrete structure (cell topology, array geometry, decoder stages,
wwlls) stays frozen per config — those axes belong to the grid seed.

Chain quantization: the control delay chain of `timing.analyze`
(ceil to stage units, unit coarsening) is piecewise-CONSTANT in the
knobs — its gradient is zero almost everywhere, which would blind the
optimizer to the dominant t_read term. The default here is the smooth
surrogate t_chain = analog * CHAIN_MARGIN (the chain's lower envelope;
the true chain is within one stage unit above it). `quantized=True`
replicates the exact staircase for parity testing against
`dse.evaluate` — use it for verification, not for gradients.

Everything here calls the shared formula kernels (`timing.elmore_delay`,
`timing.cell_swing_time`, the EKV `channel_current` family) and the
traced cell primitives (`cells.v_sn_written_t` &c): one algebra, two
evaluation modes. Run under `jax.experimental.enable_x64` for
gradient-grade accuracy; the finite-difference harness in
tests/test_grad_dse.py pins every output's derivative to < 1e-4.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax.numpy as jnp

from repro.core import bank as bank_mod
from repro.core import cells as cells_mod
from repro.core import power as power_mod
from repro.core import timing as timing_mod
from repro.core.bank import BankConfig, build_bank
from repro.core.retention import _margin_voltage
from repro.core.spice import devices as dv
from repro.core.spice.mna import channel_current_raw

KNOBS = ("vdd_scale", "w_read_scale", "w_write_scale", "bl_wire_scale")

#: Traced outputs of `evaluate_grad_fn` (all (B,) arrays). `swing_margin_a`
#: is the read-current margin i_read - 3*i_leak_total whose sign is the
#: `swing_ok` feasibility bit of the scalar evaluator.
OUTPUTS = ("t_read_s", "t_write_s", "t_cell_s", "t_wl_s", "f_max_hz",
           "retention_s", "leakage_w", "refresh_w", "standby_w",
           "e_read_j", "e_write_j", "read_bw_bps", "eff_bw_bps",
           "swing_margin_a", "swing_margin_rel")


def evaluate_grad_fn(cfg: BankConfig, *, quantized: bool = False,
                     n_ret_steps: int = 4000
                     ) -> Callable[[Dict[str, jnp.ndarray]],
                                   Dict[str, jnp.ndarray]]:
    """Build the traced evaluator for one gain-cell config.

    Returns `fn(knobs) -> outputs`: `knobs` maps any subset of KNOBS to
    (B,) arrays (missing knobs default to 1.0 — the nominal design), and
    `outputs` maps every name in OUTPUTS to a (B,) array. The closure is
    pure jnp end-to-end: `jax.grad`/`jax.jacfwd` of any reduction of any
    output flows back to every knob.
    """
    bank = build_bank(cfg)
    if not bank.is_gc:
        raise ValueError(f"cell {cfg.cell!r}: the differentiable evaluator "
                         "models gain cells (SRAM has no retention/width "
                         "knobs on this path)")
    tech = cfg.tech
    cell = bank.cell
    wf, rf = cell.wf(tech), cell.rf(tech)
    rows, cols, ws = bank.rows, bank.cols, cfg.word_size

    # -- static geometry decomposed into knob-scaling classes
    r_wl0, c_wl0 = bank_mod.wordline_rc(bank)
    c_wl_gate0 = cols * wf.cg_f_per_um * cell.w_write   # ~ w_write
    c_wl_wire = c_wl0 - c_wl_gate0                      # static (M2 wire)
    r_bl0, c_bl0 = bank_mod.bitline_rc(bank)
    c_bl_junc0 = rows * rf.cj_f_per_um * cell.w_read    # ~ w_read
    c_bl_wire0 = c_bl0 - c_bl_junc0                     # ~ bl wire width

    # -- static timing skeleton
    t_dec = timing_mod.decoder_delay(rows)
    t_colmux = 2 * timing_mod.FO4_S if bank.has_colmux else 0.0
    t_fixed = t_colmux + tech.sa_delay_s + timing_mod.REF_SETTLE_S
    swing = tech.v_sense_se
    bit = 0 if cell.read_on_sn_low else 1

    # -- static power skeleton (periphery area is geometry, not a knob)
    periph_leak = sum(bank.modules.values()) * power_mod.PERIPH_LEAK_W_PER_UM2
    n_bits = cfg.bits

    vdd0 = tech.vdd
    w_r0, w_w0 = cell.w_read, cell.w_write

    def fn(knobs: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
        bad = set(knobs) - set(KNOBS)
        if bad:
            raise ValueError(f"unknown knobs {sorted(bad)} "
                             f"(allowed: {KNOBS})")
        some = next(iter(knobs.values()))
        one = jnp.ones_like(jnp.asarray(some))
        s_v = jnp.asarray(knobs.get("vdd_scale", one))
        s_wr = jnp.asarray(knobs.get("w_read_scale", one))
        s_ww = jnp.asarray(knobs.get("w_write_scale", one))
        s_bl = jnp.asarray(knobs.get("bl_wire_scale", one))

        vdd = vdd0 * s_v
        w_read = w_r0 * s_wr
        w_write = w_w0 * s_ww
        r_bl = r_bl0 / s_bl
        c_bl = c_bl_wire0 * s_bl + c_bl_junc0 * s_wr
        c_wl = c_wl_wire + c_wl_gate0 * s_ww

        # ---- timing (traced mirror of timing.analyze) ----
        t_wl = timing_mod.elmore_delay(timing_mod.WL_DRIVER_R_OHM,
                                       r_wl0, c_wl)
        v_sn = cells_mod.v_sn_written_t(cell, tech, bit, vdd,
                                        wwlls=cfg.wwlls,
                                        wwl_boost=cfg.wwl_boost)
        v_rbl0 = jnp.zeros_like(vdd) if cell.predischarge else vdd
        v_rbl_mid = v_rbl0 + (0.5 * swing if cell.predischarge
                              else -0.5 * swing)
        i_rd = cells_mod.i_read_t(cell, tech, v_sn, v_rbl_mid, vdd, w_read)
        off_sn = cells_mod.v_sn_written_t(
            cell, tech, 1 if cell.read_on_sn_low else 0, vdd)
        leak = (rows - 1) * cells_mod.i_leak_rbl_t(cell, tech, off_sn,
                                                   vdd, w_read)
        i_net = jnp.maximum(i_rd - leak, 1e-12)
        swing_margin = i_rd - 3.0 * leak
        # scale-free variant in (-inf, 1]; > 0 iff the scalar swing_ok bit
        swing_margin_rel = 1.0 - 3.0 * leak / jnp.maximum(i_rd, 1e-30)
        t_cell = timing_mod.cell_swing_time(
            swing, c_bl + timing_mod.SA_INPUT_C_F, i_net, r_bl)

        analog = t_wl + t_cell + t_fixed
        covered = analog * timing_mod.CHAIN_MARGIN
        if quantized:
            u0, cap = tech.stage_delay_s, timing_mod.CHAIN_MAX_STAGES
            gr = timing_mod.CHAIN_UNIT_GROWTH
            k = jnp.maximum(jnp.ceil(
                jnp.log(covered / (u0 * cap)) / jnp.log(gr)), 0.0)
            unit = u0 * gr ** k
            t_chain = jnp.ceil(covered / unit) * unit
        else:
            t_chain = covered  # smooth lower envelope of the staircase

        # write path: WBL elmore + SN settle through the write device
        t_bl_wr = timing_mod.elmore_delay(timing_mod.WBL_DRIVER_R_OHM,
                                          r_bl, c_bl)
        v_gate = vdd + (cfg.wwl_boost if cfg.wwlls else 0.0)
        i_on = jnp.abs(dv.channel_current(wf, w_write, cell.l_write,
                                          v_gate, vdd, vdd * 0.45))
        c_sn = cells_mod.sn_cap_t(cell, tech, w_read, w_write)
        t_sn = c_sn * 0.9 * vdd / jnp.maximum(i_on, 1e-12)
        t_write_raw = t_wl + t_bl_wr + t_sn

        dff = tech.dff_delay_s
        t_read = dff + t_dec + t_chain + dff
        t_wr = dff + t_dec + jnp.maximum(t_write_raw, 0.6 * t_chain)
        f = 1.0 / jnp.maximum(t_read, t_wr)

        # ---- retention (traced mirror of retention.analyze) ----
        v0w = cells_mod.v_sn_written_t(cell, tech, 1, vdd,
                                       wwlls=cfg.wwlls,
                                       wwl_boost=cfg.wwl_boost)
        if cell.read_on_sn_low:
            v_m = vdd - rf.vt0 - 0.15
        else:
            v_m = jnp.full_like(vdd, _margin_voltage(cell, tech))
        vs = jnp.linspace(v_m, jnp.maximum(v0w, v_m + 1e-3), n_ret_steps,
                          axis=-1)
        vg_w = jnp.zeros(()) if wf.polarity > 0 else vdd[..., None]
        i_w = jnp.abs(channel_current_raw(
            wf.polarity, wf.vt0, wf.n_slope, wf.k_prime, wf.lambda_,
            w_write[..., None], cell.l_write, vg_w, vs, jnp.zeros(())))
        i_g = rf.i_gate_a_per_um * w_read[..., None] * vs / 1.1
        inv_i = 1.0 / jnp.maximum(i_w + i_g, 1e-30)
        t_ret = jnp.where(v0w > v_m,
                          c_sn * jnp.trapezoid(inv_i, vs, axis=-1), 0.0)

        # ---- power (traced mirror of power.analyze, GC branch) ----
        bl_swing = 3.0 * swing
        e_read = (c_wl * vdd ** 2 + ws * c_bl * vdd * bl_swing
                  + ws * 8e-15 * vdd ** 2)
        e_write = (c_wl * vdd ** 2 + ws * c_bl * vdd ** 2
                   + ws * 6e-15 * vdd ** 2)
        if cfg.wwlls:
            e_write = e_write * 1.25
        # dead cell (t_ret == 0): refresh pinned to 0 like the scalar
        # evaluator — such points are infeasible regardless (dse.feasible
        # rejects retention_s <= 0), so the optimizer must exclude them
        # via the retention constraint, not this term
        refresh = jnp.where(t_ret > 0,
                            n_bits * (e_write / ws)
                            / jnp.maximum(t_ret, 1e-30), 0.0)
        leakage = jnp.full_like(vdd, periph_leak)  # GC: no cell static path

        return {
            "t_read_s": t_read, "t_write_s": t_wr, "t_cell_s": t_cell,
            "t_wl_s": t_wl, "f_max_hz": f, "retention_s": t_ret,
            "leakage_w": leakage, "refresh_w": refresh,
            "standby_w": leakage + refresh,
            "e_read_j": e_read, "e_write_j": e_write,
            "read_bw_bps": f * ws, "eff_bw_bps": 2.0 * f * ws,
            "swing_margin_a": swing_margin,
            "swing_margin_rel": swing_margin_rel,
        }

    return fn


def evaluate_grad(cfg: BankConfig, knobs: Dict[str, jnp.ndarray], *,
                  quantized: bool = False) -> Dict[str, jnp.ndarray]:
    """One-shot convenience over `evaluate_grad_fn` (builds the closure
    and applies it — use the _fn form inside jit/grad loops)."""
    return evaluate_grad_fn(cfg, quantized=quantized)(knobs)
