"""Constructive layout/floorplan model: areas EMERGE from the rule deck
(poly pitches x routing tracks + explicit DRC margins + power rings) —
the thing GEMTOO's analytical model omits (paper §III-C).

Outputs: cell area, array area (with rail overhead), per-module
peripheral areas, and the bank floorplan (Fig 4/5): Write_Port_Address
left, Read_Port_Address right, Write_Port_Data bottom, Read_Port_Data
top, control corners, power ring(s) around everything.
A JSON-able manifest of module bounding boxes stands in for GDS (foundry
layers are NDA'd; DESIGN.md §2 assumption 3).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

from repro.core.techfile import TechFile

UM2_PER_NM2 = 1e-6

# peripheral-module footprints in (poly pitches, tracks) per instance.
# Calibrated against the paper's Fig 6 bank/array ratios (OpenRAM-class
# modules are routing-dominated and large; tests/test_core assert the
# resulting ratios).
MODULE_GEOM = {
    "wl_driver":     (5.0, 8.0),    # per row, logical-effort sized chain
    "decoder_unit":  (7.0, 8.0),    # per row (pre+final NAND)
    "precharge":     (2.0, 6.0),    # per column
    "predischarge":  (2.5, 6.0),    # per column (+EN inverter shared)
    "colmux_unit":   (2.0, 6.0),    # per column
    "sense_amp":     (11.0, 8.0),   # SRAM differential SA per data bit
    "sense_amp_se":  (22.0, 10.0),  # GC single-ended SA + reference rail
    "write_driver":  (9.0, 8.0),    # GC single-ended write driver
    "write_driver_diff": (11.0, 8.0),  # SRAM differential write driver
    "dff":           (8.0, 8.0),    # per bit (addr/data/control)
    "refgen":        (120.0, 16.0), # one per bank (GC single-ended read)
    "ctrl_base":     (90.0, 16.0),  # control FSM + clk gating
    "delay_stage":   (4.0, 8.0),    # per delay-chain stage
    "wwl_ls":        (7.0, 8.0),    # per-row WWL level shifter
}

RING_W_NM = 1200          # one power ring width (supply pair)
BLOCK_MARGIN_NM = 400     # DRC spacing between placed blocks
ROUTING_FACTOR = 2.2      # placed-module to routed-strip area overhead
GC_PORT_FACTOR = 1.2      # dual-port bus routing overhead on GC strips
PACK_FACTOR = 1.6         # packed (BEOL-under-array) floorplan: routing
                          # overhead without the strip whitespace


def cell_wh_nm(tech: TechFile, geom_key: str):
    """Drawn cell width/height in nm. The DRC margin is isotropic —
    sqrt(1+margin) on each dimension — so the w/h aspect ratio stays the
    drawn (poly pitches x tracks) ratio; the old form lumped the whole
    margin onto the width, which skewed wordline-vs-bitline lengths."""
    g = tech.cell_geoms[geom_key]
    s = (1.0 + g["margin"]) ** 0.5
    return (g["poly_pitches"] * tech.cpp * s, g["tracks"] * tech.track * s)


def cell_area_um2(tech: TechFile, geom_key: str) -> float:
    """Defined as the EXACT product of cell_wh_nm (tests assert
    w * h == area bitwise — one source of truth for cell footprint)."""
    w, h = cell_wh_nm(tech, geom_key)
    return w * h * UM2_PER_NM2


def module_area_um2(tech: TechFile, kind: str, n: int = 1) -> float:
    pp, tr = MODULE_GEOM[kind]
    return n * pp * tech.cpp * tr * tech.track * UM2_PER_NM2


@dataclass
class Floorplan:
    bank_w_um: float
    bank_h_um: float
    array_w_um: float
    array_h_um: float
    modules: List[dict] = field(default_factory=list)

    @property
    def bank_area_um2(self):
        return self.bank_w_um * self.bank_h_um

    @property
    def array_area_um2(self):
        return self.array_w_um * self.array_h_um

    @property
    def array_efficiency(self):
        return self.array_area_um2 / self.bank_area_um2

    def manifest(self) -> dict:
        return {"bank_w_um": self.bank_w_um, "bank_h_um": self.bank_h_um,
                "array_w_um": self.array_w_um, "array_h_um": self.array_h_um,
                "array_efficiency": self.array_efficiency,
                "modules": self.modules}


def packed_floorplan(tech: TechFile, *, geom_key: str, rows: int, cols: int,
                     periph_um2: float, n_rings: int) -> "Floorplan":
    """Monolithic-3D floorplan for BEOL cells (OS-OS): the bitcell array is
    fabricated between upper metal layers ON TOP of the Si periphery
    (paper §V-A/§V-B: "taking no Si area budget"), so the bank footprint
    is max(array, packed periphery) + power ring."""
    import math as _m
    cw, ch = cell_wh_nm(tech, geom_key)
    aw = cols * cw * 1e-3
    ah = (rows * ch + (rows // 16 + 1) * 2 * tech.track) * 1e-3
    core = max(aw * ah, periph_um2 * PACK_FACTOR)
    side = _m.sqrt(core)
    ring = n_rings * RING_W_NM * 1e-3
    bw = side + 2 * ring
    bh = side + 2 * ring
    mods = [
        {"name": "bitcell_array(BEOL, stacked)", "x": ring, "y": ring,
         "w": aw, "h": ah},
        {"name": "periphery(under array)", "x": ring, "y": ring,
         "w": side, "h": side},
        {"name": "power_rings", "x": 0, "y": 0, "w": bw, "h": bh,
         "rings": n_rings},
    ]
    return Floorplan(bw, bh, aw, ah, mods)


def floorplan(tech: TechFile, *, geom_key: str, rows: int, cols: int,
              left_um2: float, right_um2: float, top_um2: float,
              bottom_um2: float, corner_um2: float, n_rings: int,
              rail_rows_per: int = 16) -> Floorplan:
    """Place array + four peripheral strips + corner control + rings.

    rail_rows_per: a horizontal power-rail row is inserted every N cell
    rows (array overhead that shrinks RELATIVELY as banks grow — drives
    the paper's Fig 6(b,c) trend).
    """
    cw, ch = cell_wh_nm(tech, geom_key)
    rail_rows = rows // rail_rows_per + 1
    aw = cols * cw * 1e-3                                # um
    ah = (rows * ch + rail_rows * 2 * tech.track) * 1e-3
    m = BLOCK_MARGIN_NM * 1e-3

    rf = ROUTING_FACTOR
    lw = rf * left_um2 / ah if ah > 0 else 0.0           # strip widths
    rw = rf * right_um2 / ah if ah > 0 else 0.0
    th = rf * top_um2 / aw if aw > 0 else 0.0
    bh = rf * bottom_um2 / aw if aw > 0 else 0.0
    corner_um2 = rf * corner_um2

    core_w = lw + m + aw + m + rw
    core_h = th + m + ah + m + bh
    # corner blocks (control/refgen) fold into the larger dimension
    core_w += corner_um2 / max(core_h, 1e-9)
    ring = n_rings * RING_W_NM * 1e-3
    bw = core_w + 2 * ring
    bhgt = core_h + 2 * ring

    mods = [
        {"name": "bitcell_array", "x": ring + lw + m, "y": ring + bh + m,
         "w": aw, "h": ah},
        {"name": "left_port_address", "x": ring, "y": ring + bh + m,
         "w": lw, "h": ah},
        {"name": "right_port_address", "x": ring + lw + 2 * m + aw,
         "y": ring + bh + m, "w": rw, "h": ah},
        {"name": "top_port_data", "x": ring + lw + m, "y": ring + bh + 2 * m + ah,
         "w": aw, "h": th},
        {"name": "bottom_port_data", "x": ring + lw + m, "y": ring,
         "w": aw, "h": bh},
        {"name": "power_rings", "x": 0, "y": 0, "w": bw, "h": bhgt,
         "rings": n_rings},
    ]
    return Floorplan(bw, bhgt, aw, ah, mods)
