"""Modified nodal analysis: circuit build (python) -> dense arrays (jnp).

Circuits here are the CRITICAL-PATH netlists of a memory bank (wordline
RC ladder + write transistor + SN; RBL column with one active cell and
R-1 leakers; retention cell) — tens of nodes after rail segmentation, so
dense (N, N) MNA is exact and maps onto the batched Pallas solver.

Nonlinear devices are stored as per-instance PARAMETER ARRAYS (vt0, n,
k', lambda, W, L, polarity), not flavor objects, so a whole design-space
batch — and gradients through VT / sizing for the DSE co-optimizer — are
just vmap/grad over those arrays.

Voltage sources are Norton equivalents (G_BIG to a piecewise-linear
waveform), keeping the system pure nodal.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.techfile import PHI_T, DeviceFlavor

G_BIG = 1e2     # Norton conductance for sources (S)
G_MIN = 1e-10   # diagonal gmin


def channel_current_raw(pol, vt0, n, kp, lam, w, l, vg, va, vb):
    """Vectorized signed current a->b; raw-parameter version of
    devices.channel_current (kept in lockstep; tested against it)."""
    def mag(v_hi, v_lo):
        vds = v_hi - v_lo
        vgs_on = jnp.where(pol > 0, vg - v_lo, v_hi - vg)
        i_s = 2.0 * n * kp * (1.0 / jnp.maximum(l, 1e-3)) * PHI_T ** 2
        a_ = (vgs_on - vt0) / (2.0 * n * PHI_T)
        b_ = (vgs_on - vt0 - n * vds) / (2.0 * n * PHI_T)
        l2 = lambda x: jax.nn.softplus(x) ** 2
        return i_s * (l2(a_) - l2(b_)) * (1.0 + lam * vds)

    return w * jnp.where(va >= vb, mag(va, vb), -mag(vb, va))


def channel_current_grads(pol, vt0, n, kp, lam, w, l, vg, va, vb):
    """Closed-form (di/dvg, di/dva, di/dvb) of `channel_current_raw`,
    vectorized over device arrays — one pass computes every device's 3x3
    conductance stamp, replacing n forward-mode Jacobian passes per
    Newton iteration.

    With L2(x) = softplus(x)^2 and L2'(x) = 2 softplus(x) sigmoid(x):

        m(v_hi, v_lo) = I_S [L2(a) - L2(b)] (1 + lam vds)
        a = (vgs_on - vt0) / (2 n phi_t)
        b = (vgs_on - vt0 - n vds) / (2 n phi_t)

    so each partial is the chain rule through (a, b, vds) with the branch
    (va >= vb picks which terminal is the source) selected exactly like
    the forward evaluation — matching jacfwd of channel_current_raw to
    float roundoff."""
    den = 2.0 * n * PHI_T
    i_s = 2.0 * n * kp * (1.0 / jnp.maximum(l, 1e-3)) * PHI_T ** 2
    is_n = pol > 0

    def mag_grads(v_hi, v_lo):
        vds = v_hi - v_lo
        vgs_on = jnp.where(is_n, vg - v_lo, v_hi - vg)
        a_ = (vgs_on - vt0) / den
        b_ = (vgs_on - vt0 - n * vds) / den
        sp_a, sp_b = jax.nn.softplus(a_), jax.nn.softplus(b_)
        dl2a = 2.0 * sp_a * jax.nn.sigmoid(a_)
        dl2b = 2.0 * sp_b * jax.nn.sigmoid(b_)
        core = sp_a ** 2 - sp_b ** 2
        lam_f = 1.0 + lam * vds
        # d(vgs_on)/d{vg, v_hi, v_lo}
        dvgs_dvg = jnp.where(is_n, 1.0, -1.0)
        dvgs_dhi = jnp.where(is_n, 0.0, 1.0)
        dvgs_dlo = jnp.where(is_n, -1.0, 0.0)
        dm_dvg = i_s * (dl2a - dl2b) * dvgs_dvg / den * lam_f
        dm_dhi = i_s * ((dl2a * dvgs_dhi - dl2b * (dvgs_dhi - n)) / den
                        * lam_f + core * lam)
        dm_dlo = i_s * ((dl2a * dvgs_dlo - dl2b * (dvgs_dlo + n)) / den
                        * lam_f - core * lam)
        return dm_dvg, dm_dhi, dm_dlo

    f_dvg, f_dhi, f_dlo = mag_grads(va, vb)     # forward: hi=va, lo=vb
    r_dvg, r_dhi, r_dlo = mag_grads(vb, va)     # reverse: hi=vb, lo=va
    fwd = va >= vb
    di_dvg = w * jnp.where(fwd, f_dvg, -r_dvg)
    di_dva = w * jnp.where(fwd, f_dhi, -r_dlo)
    di_dvb = w * jnp.where(fwd, f_dlo, -r_dhi)
    return di_dvg, di_dva, di_dvb


def channel_current_and_grads(pol, vt0, n, kp, lam, w, l, vg, va, vb):
    """Fused (i, di/dvg, di/dva, di/dvb): the current AND its 3x3 stamp
    row in ONE pass over the device arrays, sharing the softplus/sigmoid
    evaluations between the value and the partials. This is the hot body
    of the fused sparse-Newton kernels, where residual and Jacobian are
    produced together per iteration — the separate `channel_current_raw`
    + `channel_current_grads` pair (kept as the tested reference) would
    evaluate the channel model twice."""
    den = 2.0 * n * PHI_T
    i_s = 2.0 * n * kp * (1.0 / jnp.maximum(l, 1e-3)) * PHI_T ** 2
    is_n = pol > 0

    def mag_all(v_hi, v_lo):
        vds = v_hi - v_lo
        vgs_on = jnp.where(is_n, vg - v_lo, v_hi - vg)
        a_ = (vgs_on - vt0) / den
        b_ = (vgs_on - vt0 - n * vds) / den
        sp_a, sp_b = jax.nn.softplus(a_), jax.nn.softplus(b_)
        dl2a = 2.0 * sp_a * jax.nn.sigmoid(a_)
        dl2b = 2.0 * sp_b * jax.nn.sigmoid(b_)
        core = sp_a ** 2 - sp_b ** 2
        lam_f = 1.0 + lam * vds
        m = i_s * core * lam_f
        dvgs_dvg = jnp.where(is_n, 1.0, -1.0)
        dvgs_dhi = jnp.where(is_n, 0.0, 1.0)
        dvgs_dlo = jnp.where(is_n, -1.0, 0.0)
        dm_dvg = i_s * (dl2a - dl2b) * dvgs_dvg / den * lam_f
        dm_dhi = i_s * ((dl2a * dvgs_dhi - dl2b * (dvgs_dhi - n)) / den
                        * lam_f + core * lam)
        dm_dlo = i_s * ((dl2a * dvgs_dlo - dl2b * (dvgs_dlo + n)) / den
                        * lam_f - core * lam)
        return m, dm_dvg, dm_dhi, dm_dlo

    f_m, f_dvg, f_dhi, f_dlo = mag_all(va, vb)
    r_m, r_dvg, r_dhi, r_dlo = mag_all(vb, va)
    fwd = va >= vb
    i = w * jnp.where(fwd, f_m, -r_m)
    di_dvg = w * jnp.where(fwd, f_dvg, -r_dvg)
    di_dva = w * jnp.where(fwd, f_dhi, -r_dlo)
    di_dvb = w * jnp.where(fwd, f_dlo, -r_dhi)
    return i, di_dvg, di_dva, di_dvb


@dataclass
class Circuit:
    """Builder. Node 0 is ground."""
    names: List[str] = field(default_factory=lambda: ["0"])
    res: List[tuple] = field(default_factory=list)    # (a, b, G)
    caps: List[tuple] = field(default_factory=list)   # (a, b, C)
    devs: List[dict] = field(default_factory=list)
    vsrcs: List[tuple] = field(default_factory=list)  # (node, wave_idx)
    probes: Dict[str, int] = field(default_factory=dict)

    def __post_init__(self):
        self._index = {n: i for i, n in enumerate(self.names)}

    def node(self, name: str) -> int:
        i = self._index.get(name)
        if i is None:
            i = len(self.names)
            self.names.append(name)
            self._index[name] = i
        return i

    def r(self, a, b, ohms):
        self.res.append((self.node(a), self.node(b), 1.0 / ohms))

    def c(self, a, b, farads):
        self.caps.append((self.node(a), self.node(b), farads))

    def dev(self, flavor: DeviceFlavor, w_um, l_um, g, a, b, name=""):
        self.devs.append({
            "pol": float(flavor.polarity), "vt0": flavor.vt0,
            "n": flavor.n_slope, "kp": flavor.k_prime,
            "lam": flavor.lambda_, "w": w_um, "l": l_um,
            "ig": flavor.i_gate_a_per_um,
            "g": self.node(g), "a": self.node(a), "b": self.node(b),
            "name": name,
        })
        # gate + junction caps as fixed linear caps
        cg = flavor.cg_f_per_um * w_um
        cj = flavor.cj_f_per_um * w_um
        self.caps.append((self.node(g), self.node(a), cg / 2))
        self.caps.append((self.node(g), self.node(b), cg / 2))
        self.caps.append((self.node(a), 0, cj))
        self.caps.append((self.node(b), 0, cj))

    def vsrc(self, node, wave_idx):
        self.vsrcs.append((self.node(node), wave_idx))

    def probe(self, label, node):
        self.probes[label] = self.node(node)

    # ---- assembly ----
    def build(self) -> "MNASystem":
        n = len(self.names) - 1  # exclude ground

        def idx(i):
            return i - 1  # ground dropped

        G = np.zeros((n, n))
        C = np.zeros((n, n))
        for a, b, g in self.res:
            for (i, j) in ((a, a), (b, b)):
                if i > 0:
                    G[idx(i), idx(j)] += g
            if a > 0 and b > 0:
                G[idx(a), idx(b)] -= g
                G[idx(b), idx(a)] -= g
        for a, b, c in self.caps:
            if a > 0:
                C[idx(a), idx(a)] += c
            if b > 0:
                C[idx(b), idx(b)] += c
            if a > 0 and b > 0:
                C[idx(a), idx(b)] -= c
                C[idx(b), idx(a)] -= c
        src_node = np.array([idx(nd) for nd, _ in self.vsrcs], np.int32)
        src_wave = np.array([w for _, w in self.vsrcs], np.int32)
        for nd in src_node:
            G[nd, nd] += G_BIG

        d = self.devs
        dev_arr = {k: jnp.array([x[k] for x in d]) if d else jnp.zeros((0,))
                   for k in ("pol", "vt0", "n", "kp", "lam", "w", "l", "ig")}
        dev_idx = {k: np.array([idx(x[k]) for x in d], np.int32) if d
                   else np.zeros((0,), np.int32) for k in ("g", "a", "b")}
        return MNASystem(jnp.array(G), jnp.array(C), dev_arr, dev_idx,
                         src_node, src_wave, n, dict(self.probes),
                         list(self.names))

    def build_stamps(self):
        """Unit-value incidence stamps of the LINEAR elements, so a whole
        lattice of structurally-identical circuits assembles as one einsum:

            G(g) = src_G + einsum('(b)r,rij->(b)ij', g, res_stamps)
            C(c) =         einsum('(b)c,cij->(b)ij', c, cap_stamps)

        where g/c are the per-point element-value vectors (in list order).
        Returns (res_stamps (nR,n,n), cap_stamps (nC,n,n), src_G (n,n)),
        float64 numpy — the einsum reproduces the scalar `build()`
        accumulation to f64 roundoff, and the batched characterization
        pipeline keeps the matrices in f64 end-to-end (it runs under
        enable_x64; see char_batch)."""
        n = len(self.names) - 1

        def stamp(a, b):
            s = np.zeros((n, n))
            if a > 0:
                s[a - 1, a - 1] += 1.0
            if b > 0:
                s[b - 1, b - 1] += 1.0
            if a > 0 and b > 0:
                s[a - 1, b - 1] -= 1.0
                s[b - 1, a - 1] -= 1.0
            return s

        res_stamps = np.stack([stamp(a, b) for a, b, _ in self.res]) \
            if self.res else np.zeros((0, n, n))
        cap_stamps = np.stack([stamp(a, b) for a, b, _ in self.caps]) \
            if self.caps else np.zeros((0, n, n))
        src_G = np.zeros((n, n))
        for nd, _ in self.vsrcs:
            src_G[nd - 1, nd - 1] += G_BIG
        return res_stamps, cap_stamps, src_G

    def build_sparsity(self) -> MNASparsity:
        """Full structural export for the fused sparse-Newton engine:
        the union Jacobian pattern PLUS the element-value projections,
        so a lattice group assembles its per-point pattern values as

            Gn = g_elems @ res_proj + src_nnz     # (B, nnz)
            Cn = c_elems @ cap_proj               # (B, nnz)

        (g_elems/c_elems in `res`/`caps` list order — the same vectors
        the incidence-stamp einsum consumed) without ever forming the
        dense (B, n, n) matrices `build_stamps` implies."""
        n = len(self.names) - 1
        pairs = set()

        def add(a, b):
            for i, j in ((a, a), (b, b), (a, b), (b, a)):
                if i > 0 and j > 0:
                    pairs.add((i - 1, j - 1))

        for a, b, _ in self.res:
            add(a, b)
        for a, b, _ in self.caps:
            add(a, b)
        d = self.devs
        didx = {k: np.array([x[k] - 1 for x in d], np.int32) if d
                else np.zeros((0,), np.int32) for k in ("g", "a", "b")}
        entries, pos, rows, cols, diag_pos, dev_pos = MNASparsity._build(
            n, pairs, didx, len(d))
        nnz = len(entries)

        def proj(elems):
            P = np.zeros((len(elems), nnz))
            for e, (a, b, _) in enumerate(elems):
                if a > 0:
                    P[e, pos[(a - 1, a - 1)]] += 1.0
                if b > 0:
                    P[e, pos[(b - 1, b - 1)]] += 1.0
                if a > 0 and b > 0:
                    P[e, pos[(a - 1, b - 1)]] -= 1.0
                    P[e, pos[(b - 1, a - 1)]] -= 1.0
            return P

        src_nnz = np.zeros((nnz,))
        for nd, _ in self.vsrcs:
            src_nnz[pos[(nd - 1, nd - 1)]] += G_BIG
        return MNASparsity(n, rows, cols, diag_pos, dev_pos,
                           res_proj=proj(self.res),
                           cap_proj=proj(self.caps), src_nnz=src_nnz)


@dataclass(frozen=True)
class MNASparsity:
    """Fixed sparsity structure of one topology's MNA Newton system.

    Within a topology group the circuit STRUCTURE is identical across a
    whole design lattice — only element values vary — so the union
    nonzero pattern of J = C/h + G + dI/dv + gmin is a per-topology
    constant. This object exports that pattern plus the index maps the
    fused sparse-Newton kernels (repro.kernels.batched_solve) need to
    re-stamp, factor and solve WITHOUT ever materializing dense
    (B, n, n) matrices:

      rows/cols    COO pattern of the nnz stored entries (row-major
                   sorted, so the diagonal of row i sits between its
                   off-diagonals — the LU schedule relies on the order
                   being deterministic, not on any particular sort)
      diag_pos     position of (i, i) for each node i
      dev_pos      (9, n_dev) positions of each device's 3x3 stamp
                   entries in `device_jacobian` row/col order
                   [(a,g),(a,a),(a,b),(b,g),(b,a),(b,b),(g,g),(g,a),
                   (g,b)]; -1 where the row or column is ground
      res_proj     (n_res, nnz) unit-stamp projection: Gn = g @ res_proj
                   reproduces build()'s resistor accumulation on the
                   pattern (None when built from_system: dense G/C are
                   projected directly instead)
      cap_proj     (n_cap, nnz) likewise for capacitor values
      src_nnz      (nnz,) Norton G_BIG source conductances on the
                   pattern (already folded into dense G by build())

    gmin is NOT included in any map — the solver adds G_MIN at diag_pos
    so the pattern stays a pure structural export."""
    n: int
    rows: np.ndarray
    cols: np.ndarray
    diag_pos: np.ndarray
    dev_pos: np.ndarray
    res_proj: Optional[np.ndarray] = None
    cap_proj: Optional[np.ndarray] = None
    src_nnz: Optional[np.ndarray] = None

    @property
    def nnz(self) -> int:
        return len(self.rows)

    def pos(self) -> Dict[tuple, int]:
        return {(int(i), int(j)): p
                for p, (i, j) in enumerate(zip(self.rows, self.cols))}

    def project_dense(self, M) -> jnp.ndarray:
        """Dense (..., n, n) matrix -> (..., nnz) pattern values."""
        return jnp.asarray(M)[..., self.rows, self.cols]

    @staticmethod
    def _build(n, pairs, didx, n_dev):
        pairs = set(pairs) | {(i, i) for i in range(n)}
        na, nb, ng = didx["a"], didx["b"], didx["g"]
        for d in range(n_dev):
            nodes = [int(x[d]) for x in (ng, na, nb)]
            pairs |= {(i, j) for i in nodes for j in nodes
                      if i >= 0 and j >= 0}
        entries = sorted(pairs)
        pos = {e: p for p, e in enumerate(entries)}
        rows = np.array([i for i, _ in entries], np.int32)
        cols = np.array([j for _, j in entries], np.int32)
        diag_pos = np.array([pos[(i, i)] for i in range(n)], np.int32)
        dev_pos = np.full((9, n_dev), -1, np.int32)
        combos = ((na, ng), (na, na), (na, nb), (nb, ng), (nb, na),
                  (nb, nb), (ng, ng), (ng, na), (ng, nb))
        for e, (ri, ci) in enumerate(combos):
            for d in range(n_dev):
                i, j = int(ri[d]), int(ci[d])
                if i >= 0 and j >= 0:
                    dev_pos[e, d] = pos[(i, j)]
        return entries, pos, rows, cols, diag_pos, dev_pos

    @staticmethod
    def from_system(system: "MNASystem") -> "MNASparsity":
        """Pattern-only structure from a built system: nonzeros of the
        numeric G/C (structural by construction — conductance stamps
        cannot cancel) plus the device stamps and the diagonal. Callers
        project dense G/C through `project_dense`; no element-value
        projections are available on this path."""
        G = np.asarray(system.G)
        C = np.asarray(system.C)
        pairs = {(int(i), int(j))
                 for i, j in zip(*np.nonzero((G != 0.0) | (C != 0.0)))}
        n_dev = int(system.dev["pol"].shape[0])
        _, _, rows, cols, diag_pos, dev_pos = MNASparsity._build(
            system.n, pairs, system.didx, n_dev)
        return MNASparsity(system.n, rows, cols, diag_pos, dev_pos)


@dataclass
class MNASystem:
    G: jnp.ndarray            # (n, n)
    C: jnp.ndarray            # (n, n)
    dev: dict                 # per-instance param arrays
    didx: dict                # g/a/b node indices (ground = -1)
    src_node: np.ndarray
    src_wave: np.ndarray
    n: int
    probes: dict
    names: list

    def with_params(self, **over):
        """Functional override of device parameter arrays (vt0, w, ...) —
        the hook for DSE batching/gradients. The special keys "G" and "C"
        override the LINEAR matrices, which is how the batched
        characterization pipeline threads per-design-point wire parasitics
        (bitline ladder RC, SA load, ...) through one compiled program."""
        over = dict(over)
        G = jnp.asarray(over.pop("G")) if "G" in over else self.G
        C = jnp.asarray(over.pop("C")) if "C" in over else self.C
        dev = dict(self.dev)
        dev.update({k: jnp.asarray(v) for k, v in over.items()})
        return MNASystem(G, C, dev, self.didx, self.src_node,
                         self.src_wave, self.n, self.probes, self.names)

    def _v_of(self, v, node_idx):
        # ground (-1) reads as 0.0
        vg = jnp.concatenate([v, jnp.zeros((1,), v.dtype)])
        return vg[node_idx]

    def device_currents(self, v):
        """KCL residual contribution of all devices: (n,) currents
        LEAVING each node."""
        if self.dev["pol"].shape[0] == 0:
            return jnp.zeros((self.n,))
        vg = self._v_of(v, self.didx["g"])
        va = self._v_of(v, self.didx["a"])
        vb = self._v_of(v, self.didx["b"])
        i_ab = channel_current_raw(self.dev["pol"], self.dev["vt0"],
                                   self.dev["n"], self.dev["kp"],
                                   self.dev["lam"], self.dev["w"],
                                   self.dev["l"], vg, va, vb)
        # gate leakage: gate -> (a+b)/2
        i_g = self.dev["ig"] * self.dev["w"] * (vg - 0.5 * (va + vb)) / 1.1
        out = jnp.zeros((self.n,))
        def acc(out, idxs, cur):
            ok = idxs >= 0
            return out.at[jnp.where(ok, idxs, 0)].add(jnp.where(ok, cur, 0.0))
        out = acc(out, self.didx["a"], i_ab - 0.5 * i_g)
        out = acc(out, self.didx["b"], -i_ab - 0.5 * i_g)
        out = acc(out, self.didx["g"], i_g)
        return out

    def source_currents(self, wave_v):
        """Norton injections for sources; wave_v: (n_waves,) values now."""
        out = jnp.zeros((self.n,))
        if len(self.src_node) == 0:
            return out
        return out.at[self.src_node].add(G_BIG * wave_v[self.src_wave])

    def device_jacobian(self, v):
        """d(device_currents)/dv as a dense (n, n) matrix, assembled from
        per-device 3x3 analytic stamps in ONE vectorized pass.

        For each device, with channel partials (di/dvg, di/dva, di/dvb)
        from `channel_current_grads` and gate-leak conductance
        gg = ig*w/1.1 (i_g = gg*(vg - (va+vb)/2)), the KCL rows stamp as

            row a (+i_ab - i_g/2):  [di_dvg - gg/2, di_dva + gg/4, di_dvb + gg/4]
            row b (-i_ab - i_g/2):  [-di_dvg - gg/2, -di_dva + gg/4, -di_dvb + gg/4]
            row g (+i_g):           [gg, -gg/2, -gg/2]

        (columns ordered g, a, b), scatter-added with ground (-1) rows and
        columns dropped."""
        if self.dev["pol"].shape[0] == 0:
            return jnp.zeros((self.n, self.n))
        vg = self._v_of(v, self.didx["g"])
        va = self._v_of(v, self.didx["a"])
        vb = self._v_of(v, self.didx["b"])
        di_dvg, di_dva, di_dvb = channel_current_grads(
            self.dev["pol"], self.dev["vt0"], self.dev["n"], self.dev["kp"],
            self.dev["lam"], self.dev["w"], self.dev["l"], vg, va, vb)
        gg = self.dev["ig"] * self.dev["w"] / 1.1
        na, nb, ng = self.didx["a"], self.didx["b"], self.didx["g"]
        entries = (
            (na, ng, di_dvg - 0.5 * gg),
            (na, na, di_dva + 0.25 * gg),
            (na, nb, di_dvb + 0.25 * gg),
            (nb, ng, -di_dvg - 0.5 * gg),
            (nb, na, -di_dva + 0.25 * gg),
            (nb, nb, -di_dvb + 0.25 * gg),
            (ng, ng, gg + jnp.zeros_like(di_dvg)),
            (ng, na, -0.5 * gg + jnp.zeros_like(di_dvg)),
            (ng, nb, -0.5 * gg + jnp.zeros_like(di_dvg)),
        )
        rows = jnp.concatenate([jnp.asarray(r) for r, _, _ in entries])
        cols = jnp.concatenate([jnp.asarray(c) for _, c, _ in entries])
        vals = jnp.concatenate([x for _, _, x in entries])
        ok = (rows >= 0) & (cols >= 0)
        flat = jnp.where(ok, rows * self.n + cols, 0)
        J = jnp.zeros((self.n * self.n,)).at[flat].add(
            jnp.where(ok, vals, 0.0))
        return J.reshape(self.n, self.n)

    def jacobian(self, v, h):
        """Analytic MNA Newton Jacobian J = C/h + G + dI/dv + gmin."""
        return (self.C / h + self.G + self.device_jacobian(v)
                + G_MIN * jnp.eye(self.n))

    def residual(self, v, v_prev, h, wave_v):
        """Backward-Euler KCL residual (n,)."""
        return (self.C @ ((v - v_prev) / h) + self.G @ v
                + self.device_currents(v) - self.source_currents(wave_v)
                + G_MIN * v)
