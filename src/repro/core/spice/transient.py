"""Transient solver: backward-Euler + Newton, lax.scan over time steps,
vmap over design-point batches.

The Newton linear solve goes through repro.kernels.batched_solve.ops
(Pallas TPU kernel; interpret mode on CPU) or jnp.linalg.solve. The MNA
Jacobian J = C/h + G + dI/dv has gmin + C/h diagonal dominance, so
unpivoted elimination is stable (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.spice.mna import MNASystem

NEWTON_ITERS = 6


def wave_value(times, values, t):
    """Piecewise-linear waveform lookup. times/values: (k,)."""
    return jnp.interp(t, times, values)


def make_stepper(system: MNASystem, solver_name: str = "jnp",
                 newton: str = "full", iters: int = NEWTON_ITERS):
    """Returns step(v, t, h, wave_t, wave_v, dev_over) -> v_next.
    Pure function of arrays: vmap/grad-safe over dev_over batches.

    newton="full":     re-linearize + solve every iteration (HSPICE-like)
    newton="modified": linearize ONCE per timestep, invert, iterate with
                       mat-vecs — trades 1 O(n^3) factorization + k O(n^2)
                       applies against k factorization (§Perf GCRAM-sim
                       hillclimb; valid because BE steps start near the
                       solution so the Jacobian barely moves within a step)
    """
    if solver_name == "pallas":
        from repro.kernels.batched_solve import ops as solve_ops
        solver = solve_ops.solve1
    else:
        solver = lambda J, r: jnp.linalg.solve(J, r)

    def step(v, t, h, wave_times, wave_values, dev_over):
        sys = system.with_params(**dev_over) if dev_over else system
        wv = jax.vmap(lambda tt, vv: wave_value(tt, vv, t))(wave_times,
                                                            wave_values)

        def res(vv):
            return sys.residual(vv, v, h, wv)

        if newton == "modified":
            J = jax.jacfwd(res)(v)
            Jinv = jnp.linalg.inv(J)

            def it(vv, _):
                return vv - Jinv @ res(vv), None

            v2, _ = jax.lax.scan(it, v, None, length=iters)
            return v2

        def it(vv, _):
            r = res(vv)
            J = jax.jacfwd(res)(vv)
            return vv - solver(J, r), None

        v2, _ = jax.lax.scan(it, v, None, length=iters)
        return v2

    return step


class Transient:
    """run(waves, t_end, n_steps) -> probe traces. jit cached per n_steps."""

    def __init__(self, system: MNASystem, solver: str = "jnp",
                 newton: str = "full", iters: int = NEWTON_ITERS):
        self.system = system
        self.solver = solver
        self._step = make_stepper(system, solver, newton=newton, iters=iters)
        self._jit_cache = {}

    def _fn(self, n_steps: int, keys: tuple):
        if (n_steps, keys) not in self._jit_cache:
            step = self._step

            def run(t_end, wt, wv, v0, dev_vals):
                dev_over = dict(zip(keys, dev_vals))
                h = t_end / n_steps

                def body(v, i):
                    v = step(v, (i + 1.0) * h, h, wt, wv, dev_over)
                    return v, v

                _, vs = jax.lax.scan(body, v0, jnp.arange(n_steps))
                return vs

            self._jit_cache[(n_steps, keys)] = jax.jit(run)
        return self._jit_cache[(n_steps, keys)]

    def pack_waves(self, waves):
        k = max(len(t) for t, _ in waves)

        def pad(a):
            a = jnp.asarray(a, jnp.float32)
            return jnp.pad(a, (0, k - len(a)), mode="edge")

        wt = jnp.stack([pad(t) for t, _ in waves])
        wv = jnp.stack([pad(v) for _, v in waves])
        return wt, wv

    def run(self, waves, t_end, n_steps=400, v0=None, dev_over=None):
        wt, wv = self.pack_waves(waves)
        if v0 is None:
            v0 = jnp.zeros((self.system.n,))
        dev_over = dev_over or {}
        keys = tuple(sorted(dev_over))
        vals = tuple(jnp.asarray(dev_over[k]) for k in keys)
        vs = self._fn(int(n_steps), keys)(jnp.float32(t_end), wt, wv, v0, vals)
        out = {"all": vs,
               "t": (jnp.arange(n_steps) + 1) * (t_end / n_steps)}
        for label, node in self.system.probes.items():
            out[label] = vs[:, node - 1]
        return out

    def run_batch(self, waves, t_end, n_steps, dev_batches: dict, v0=None):
        """vmap over a batch of device-parameter overrides: dev_batches is
        {param: (B, n_dev)} — the whole design-space sweep in one program."""
        wt, wv = self.pack_waves(waves)
        if v0 is None:
            v0 = jnp.zeros((self.system.n,))
        keys = tuple(sorted(dev_batches))
        vals = tuple(jnp.asarray(dev_batches[k]) for k in keys)
        fn = self._fn(int(n_steps), keys)
        bfn = jax.vmap(lambda dv: fn(jnp.float32(t_end), wt, wv, v0, dv))
        vs = bfn(vals)  # (B, n_steps, n)
        out = {"all": vs,
               "t": (jnp.arange(n_steps) + 1) * (t_end / n_steps)}
        for label, node in self.system.probes.items():
            out[label] = vs[:, :, node - 1]
        return out
