"""Transient solver: backward-Euler + Newton, lax.scan over time steps,
vmap over design-point batches.

The Newton linear solve goes through repro.kernels.batched_solve.ops
(Pallas TPU kernel; interpret mode on CPU) or jnp.linalg.solve. The MNA
Jacobian J = C/h + G + dI/dv has gmin + C/h diagonal dominance, so
unpivoted elimination is stable (DESIGN.md §6).

Newton uses the ANALYTIC Jacobian (`MNASystem.jacobian`: per-device 3x3
conductance stamps assembled in one vectorized pass) instead of n
forward-mode `jacfwd` passes, and exits early once the update norm drops
under `tol` (a `lax.while_loop`; under vmap JAX's batching rule freezes
converged lanes, so per-point results match the scalar path). The
`jacfwd` mode keeps the autodiff Jacobian as the parity reference — and
as the reverse-differentiable path, since while_loop has no VJP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spice.mna import MNASystem

NEWTON_ITERS = 6
NEWTON_TOL = 1e-6       # volts; max|dv| under this ends the Newton loop


def wave_value(times, values, t):
    """Piecewise-linear waveform lookup. times/values: (k,)."""
    return jnp.interp(t, times, values)


def crossing_time(t, v, target, rising: bool):
    """First threshold crossing of a trace, linearly interpolated between
    the bracketing time steps. t, v: (..., T) — vectorized over leading
    batch dims, so a whole lattice extracts on-device in one pass.

    Returns (t_cross, valid): t_cross is +inf where the trace never
    reaches the target (valid False), matching the scalar simulate_read
    convention (final sample must be past the target and the crossing
    must not be at step 0)."""
    t = jnp.asarray(t)
    v = jnp.asarray(v)
    mask = (v >= target) if rising else (v <= target)
    ok = mask[..., -1]
    hit = jnp.argmax(mask, axis=-1)
    pos = jnp.maximum(hit, 1)[..., None]
    v1 = jnp.take_along_axis(v, pos, axis=-1)[..., 0]
    v0 = jnp.take_along_axis(v, pos - 1, axis=-1)[..., 0]
    t1 = jnp.take_along_axis(jnp.broadcast_to(t, v.shape), pos,
                             axis=-1)[..., 0]
    t0 = jnp.take_along_axis(jnp.broadcast_to(t, v.shape), pos - 1,
                             axis=-1)[..., 0]
    dv = v1 - v0
    frac = jnp.clip((target - v0) / jnp.where(dv == 0.0, 1.0, dv), 0.0, 1.0)
    valid = ok & (hit > 0)
    return jnp.where(valid, t0 + frac * (t1 - t0), jnp.inf), valid


def make_stepper(system: MNASystem, solver_name: str = "jnp",
                 newton: str = "full", iters: int = NEWTON_ITERS,
                 tol: float = NEWTON_TOL, with_aux: bool = False):
    """Returns step(v, t, h, wave_t, wave_v, dev_over) -> v_next.
    Pure function of arrays: vmap-safe over dev_over batches (which may
    also carry per-point "G"/"C" matrix overrides).

    newton="full":     analytic-Jacobian Newton (re-stamp + solve every
                       iteration, HSPICE-like) with tolerance early-exit:
                       stops as soon as max|dv| < tol instead of burning
                       the fixed `iters` budget (BE steps start near the
                       solution, so 2-3 iterations usually suffice)
    newton="jacfwd":   the legacy fixed-iteration loop with the autodiff
                       (jax.jacfwd) Jacobian — the reference the analytic
                       stamps are tested against, and the grad-safe path
    newton="modified": linearize ONCE per timestep (analytic stamps),
                       invert, iterate with mat-vecs — trades 1 O(n^3)
                       factorization + k O(n^2) applies against k
                       factorizations (§Perf GCRAM-sim hillclimb)

    with_aux=True (full mode only) makes step return (v_next, n_iters)
    so tests can observe the early exit.
    """
    if with_aux and newton != "full":
        raise ValueError("with_aux is only supported for newton='full'")
    if solver_name == "pallas":
        from repro.kernels.batched_solve import ops as solve_ops
        solver = solve_ops.solve
    else:
        solver = lambda J, r: jnp.linalg.solve(J, r)

    def step(v, t, h, wave_times, wave_values, dev_over):
        sys = system.with_params(**dev_over) if dev_over else system
        wv = jax.vmap(lambda tt, vv: wave_value(tt, vv, t))(wave_times,
                                                            wave_values)

        def res(vv):
            return sys.residual(vv, v, h, wv)

        if newton == "modified":
            # one LU factorization, k triangular-solve applies — same
            # chord iteration as the old explicit-inverse path but
            # O(n^3/3) + k O(n^2) instead of O(n^3) for the inverse,
            # and partial pivoting instead of inv's full Gauss-Jordan
            lu_piv = jax.scipy.linalg.lu_factor(sys.jacobian(v, h))

            def it(vv, _):
                return vv - jax.scipy.linalg.lu_solve(lu_piv, res(vv)), None

            v2, _ = jax.lax.scan(it, v, None, length=iters)
            return v2

        if newton == "jacfwd":
            def it(vv, _):
                r = res(vv)
                J = jax.jacfwd(res)(vv)
                return vv - solver(J, r), None

            v2, _ = jax.lax.scan(it, v, None, length=iters)
            return v2

        # newton == "full": analytic stamps + early exit
        def cond(state):
            _, done, i = state
            return (i < iters) & jnp.logical_not(done)

        def body(state):
            vv, _, i = state
            dv = solver(sys.jacobian(vv, h), res(vv))
            done = jnp.max(jnp.abs(dv)) < tol
            return vv - dv, done, i + 1

        v2, _, n_it = jax.lax.while_loop(
            cond, body, (v, jnp.asarray(False), jnp.asarray(0)))
        if with_aux:
            return v2, n_it
        return v2

    return step


class Transient:
    """run(waves, t_end, n_steps) -> probe traces. jit cached per n_steps.

    solver: "jnp" (dense reference, vmap per-point Newton);
    "pallas" — the fused sparse-Newton engine for `run_lattice`
    (prefactored-K Woodbury iteration: Pallas kernel on TPU, bit-
    identical XLA fallback on CPU; see kernels.batched_solve.newton);
    "sparse" — the fixed-pattern symbolic-LU engine (re-factors the
    pattern each iteration; the general path when the fused engine's
    constant-J0 assumption is off the table). Scalar run()/run_batch()
    always use the dense per-point stepper ("pallas" there keeps its PR 2
    meaning: the dense Gauss-Jordan kernel inside the Newton loop).

    precision (lattice engines only): "f64" | "mixed" (f32 carried
    state/traces, f64 model + solve) | "f32" (screening only) — the
    mixed-precision contract is documented in docs/fidelity-tiers.md.
    """

    def __init__(self, system: MNASystem, solver: str = "jnp",
                 newton: str = "full", iters: int = NEWTON_ITERS,
                 tol: float = NEWTON_TOL, precision: str = "f64"):
        self.system = system
        self.solver = solver
        self.precision = precision
        self.iters = iters
        self.tol = tol
        self._step = make_stepper(system, solver, newton=newton,
                                  iters=iters, tol=tol)
        self._jit_cache = {}
        self._wave_cache = {}
        self._fused_cache = {}

    def _fn(self, n_steps: int, keys: tuple):
        if (n_steps, keys) not in self._jit_cache:
            step = self._step

            def run(t_end, wt, wv, v0, dev_vals):
                dev_over = dict(zip(keys, dev_vals))
                h = t_end / n_steps

                def body(v, i):
                    v = step(v, (i + 1.0) * h, h, wt, wv, dev_over)
                    return v, v

                _, vs = jax.lax.scan(body, v0, jnp.arange(n_steps))
                return vs

            self._jit_cache[(n_steps, keys)] = jax.jit(run)
        return self._jit_cache[(n_steps, keys)]

    def pack_waves(self, waves):
        """Pad + stack piecewise-linear waveforms; memoized by content (and
        the ambient float width) so repeated run()/run_batch() calls with
        identical waveforms skip the re-padding and host->device
        transfer."""
        dtype = jnp.result_type(float)
        key = (dtype.name,) + tuple(
            (tuple(float(x) for x in t), tuple(float(x) for x in v))
            for t, v in waves)
        hit = self._wave_cache.get(key)
        if hit is not None:
            return hit
        k = max(len(t) for t, _ in waves)

        def pad(a):
            a = jnp.asarray(a, dtype)
            return jnp.pad(a, (0, k - len(a)), mode="edge")

        wt = jnp.stack([pad(t) for t, _ in waves])
        wv = jnp.stack([pad(v) for _, v in waves])
        self._wave_cache[key] = (wt, wv)
        return wt, wv

    def run(self, waves, t_end, n_steps=400, v0=None, dev_over=None):
        wt, wv = self.pack_waves(waves)
        if v0 is None:
            v0 = jnp.zeros((self.system.n,))
        dev_over = dev_over or {}
        keys = tuple(sorted(dev_over))
        vals = tuple(jnp.asarray(dev_over[k]) for k in keys)
        t_end = jnp.asarray(t_end, jnp.result_type(float))
        vs = self._fn(int(n_steps), keys)(t_end, wt, wv, v0, vals)
        out = {"all": vs,
               "t": (jnp.arange(n_steps) + 1) * (t_end / n_steps)}
        for label, node in self.system.probes.items():
            out[label] = vs[:, node - 1]
        return out

    def run_batch(self, waves, t_end, n_steps, dev_batches: dict, v0=None):
        """vmap over a batch of device-parameter overrides: dev_batches is
        {param: (B, n_dev)} — the whole design-space sweep in one program."""
        wt, wv = self.pack_waves(waves)
        if v0 is None:
            v0 = jnp.zeros((self.system.n,))
        keys = tuple(sorted(dev_batches))
        vals = tuple(jnp.asarray(dev_batches[k]) for k in keys)
        t_end = jnp.asarray(t_end, jnp.result_type(float))
        fn = self._fn(int(n_steps), keys)
        bfn = jax.vmap(lambda dv: fn(t_end, wt, wv, v0, dv))
        vs = bfn(vals)  # (B, n_steps, n)
        out = {"all": vs,
               "t": (jnp.arange(n_steps) + 1) * (t_end / n_steps)}
        for label, node in self.system.probes.items():
            out[label] = vs[:, :, node - 1]
        return out

    def run_lattice(self, wt, wv, t_end, n_steps, over_batches=None,
                    v0=None):
        """Whole-lattice transient: vmap over per-point waveforms AND stop
        times AND parameter overrides in one compiled program.

        wt/wv: (B, n_waves, k) packed waveforms; t_end: (B,) stop times
        (h varies per point); over_batches: {param: (B, ...)}, which may
        include "G"/"C" (B, n, n) linear-matrix overrides carrying the
        per-point wire parasitics. v0: (n,) shared initial state.
        Returns {"all": (B, T, n), "t": (B, T), probes: (B, T)}.

        With solver="pallas"/"sparse" the lattice routes to the fused
        explicit-batch engines: "G"/"C" (B, n, n) matrix overrides plus
        per-point DEVICE-parameter batches (PARAM_FIELDS names + "ig",
        each (B, 1) or (B, n_dev)) — the latter feed `pack_params`
        overrides, which is how the differentiable DSE path threads
        device-width knobs through a whole characterization.
        """
        if v0 is None:
            v0 = jnp.zeros((self.system.n,))
        over_batches = over_batches or {}
        if self.solver in ("pallas", "sparse"):
            from repro.kernels.batched_solve.sparse import PARAM_FIELDS
            dev_allowed = set(PARAM_FIELDS) | {"ig"}
            bad = set(over_batches) - {"G", "C"} - dev_allowed
            if bad:
                raise ValueError(
                    f"solver={self.solver!r} lattice runs support only "
                    "G/C and device-parameter overrides, got "
                    f"{sorted(bad)}")
            G_b = jnp.asarray(over_batches.get(
                "G", jnp.broadcast_to(self.system.G,
                                      (len(t_end),) + self.system.G.shape)))
            C_b = jnp.asarray(over_batches.get(
                "C", jnp.broadcast_to(self.system.C,
                                      (len(t_end),) + self.system.C.shape)))
            dev_over = {k: jnp.asarray(v) for k, v in over_batches.items()
                        if k in dev_allowed}
            return self._run_lattice_fused(wt, wv, t_end, n_steps,
                                           G_b, C_b, v0, dev_over)
        keys = tuple(sorted(over_batches))
        vals = tuple(jnp.asarray(over_batches[k]) for k in keys)
        t_end = jnp.asarray(t_end, jnp.result_type(float))
        fn = self._fn(int(n_steps), keys)
        bfn = jax.vmap(lambda te, wtt, wvv, dv: fn(te, wtt, wvv, v0, dv))
        vs = bfn(t_end, jnp.asarray(wt), jnp.asarray(wv), vals)
        out = {"all": vs,
               "t": (jnp.arange(n_steps) + 1)[None, :]
               * (t_end[:, None] / n_steps)}
        for label, node in self.system.probes.items():
            out[label] = vs[:, :, node - 1]
        return out

    def _fused_fn(self, n_steps: int, dev_keys: tuple = ()):
        """Compiled whole-lattice program for the explicit-batch engines:
        precompute everything iteration-constant (and step-constant —
        h is fixed per point, so the linear Jacobian part never changes
        across the scan), then scan the per-step fused Newton solve.
        `dev_keys` names the per-point device-parameter overrides
        (static — part of the jit cache key); the whole program is
        reverse-differentiable w.r.t. G/C/waveforms/t_end/v0 and the
        device overrides via the implicit-function VJP of the solves."""
        key = (self.solver, self.precision, int(n_steps), dev_keys)
        hit = self._fused_cache.get(key)
        if hit is not None:
            return hit
        from repro.core.spice.mna import G_BIG, MNASparsity
        from repro.kernels.batched_solve import newton as nwt
        from repro.kernels.batched_solve import ops as solve_ops
        from repro.kernels.batched_solve import sparse as sps

        system = self.system
        n = system.n
        iters, tol = self.iters, self.tol
        src_node = np.asarray(system.src_node)
        src_wave = np.asarray(system.src_wave)
        if self.solver == "sparse":
            spec = sps.build_spec(system, MNASparsity.from_system(system),
                                  self.precision)
        else:
            spec = nwt.build_fused_spec(system, self.precision)
        sdt, cdt = spec.dtypes

        def src_sequence(te, wt, wv):
            """Norton source injections for every step up front: the
            waveforms are known for the whole run, so the (B, T, n)
            sequence assembles in one pass outside the scan."""
            B = te.shape[0]
            h = te / n_steps
            ts = (jnp.arange(n_steps, dtype=te.dtype) + 1.0)[None, :] \
                * h[:, None]
            wvals = jax.vmap(
                lambda tt, a, b: jax.vmap(
                    lambda x, y: jnp.interp(tt, x, y))(a, b)
            )(ts, wt, wv)                                 # (B, n_waves, T)
            return jnp.zeros((B, n_steps, n), cdt).at[
                :, :, src_node].add(
                (G_BIG * wvals[:, src_wave, :]).transpose(0, 2, 1)
                .astype(cdt))

        if self.solver == "sparse":
            sp = spec.sp

            def run(te, wt, wv, v0, G_b, C_b, dev_vals):
                B = te.shape[0]
                h = te / n_steps
                gn = sp.project_dense(jnp.asarray(G_b, cdt))
                cn = sp.project_dense(jnp.asarray(C_b, cdt))
                j_const = sps.j_constant(spec, gn, cn, h)
                coh = (cn / h[:, None]).astype(cdt)
                src_seq = src_sequence(te, wt, wv)
                params = sps.pack_params(system.dev, B, cdt,
                                         dict(zip(dev_keys, dev_vals)))

                def body(v, src_t):
                    rhs = sps.coo_matvec(sp, coh, v.astype(cdt)) + src_t
                    v2 = sps.newton_solve_implicit(
                        spec, iters, tol, j_const, rhs, params, v)
                    return v2, v2

                v00 = jnp.broadcast_to(v0.astype(sdt), (B, n))
                _, vs = jax.lax.scan(body, v00,
                                     jnp.swapaxes(src_seq, 0, 1))
                return jnp.swapaxes(vs, 0, 1)
        else:

            def run(te, wt, wv, v0, G_b, C_b, dev_vals):
                B = te.shape[0]
                h = te / n_steps
                pre = nwt.precompute(spec, G_b, C_b, h)
                src_seq = src_sequence(te, wt, wv)
                # K @ rhs hoist: rhs = (C/h) v_prev + src, so
                # K rhs = KCoh @ v_prev + (K @ src) — the source term
                # for ALL steps in one einsum outside the scan
                Ksrc = jnp.einsum("bij,btj->bti", pre["K"], src_seq)
                params = sps.pack_params(system.dev, B, sdt,
                                         dict(zip(dev_keys, dev_vals)))

                def body(v, Ksrc_t):
                    Krhs = jnp.einsum("bij,bj->bi", pre["KCoh"],
                                      v.astype(cdt)) + Ksrc_t
                    v2 = solve_ops.fused_newton_step(
                        spec, pre, Krhs, params, v, iters=iters, tol=tol)
                    return v2, v2

                v00 = jnp.broadcast_to(v0.astype(sdt), (B, n))
                _, vs = jax.lax.scan(body, v00,
                                     jnp.swapaxes(Ksrc, 0, 1))
                return jnp.swapaxes(vs, 0, 1)

        fn = jax.jit(run)
        self._fused_cache[key] = fn
        return fn

    def _run_lattice_fused(self, wt, wv, t_end, n_steps, G_b, C_b, v0,
                           dev_over=None):
        dev_over = dev_over or {}
        dev_keys = tuple(sorted(dev_over))
        t_end = jnp.asarray(t_end, jnp.result_type(float))
        fn = self._fused_fn(int(n_steps), dev_keys)
        vs = fn(t_end, jnp.asarray(wt), jnp.asarray(wv),
                jnp.asarray(v0), G_b, C_b,
                tuple(dev_over[k] for k in dev_keys))
        out = {"all": vs,
               "t": (jnp.arange(n_steps) + 1)[None, :]
               * (t_end[:, None] / n_steps)}
        for label, node in self.system.probes.items():
            out[label] = vs[:, :, node - 1]
        return out
