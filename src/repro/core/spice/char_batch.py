"""Topology-grouped batched transient characterization of the read path.

`timing.simulate_read` is the scalar (HSPICE-class) reference: per design
point it rebuilds the RBL-column netlist, re-jits a fresh Newton
integrator and extracts the sense-swing crossing on host — O(lattice)
compilations and O(lattice * n_steps * newton) small dense solves issued
one program at a time. This module characterizes a whole design lattice
in a handful of compiled programs:

  1. group configs by cell topology (`dse_batch.topology_key`): within a
     group the critical-path netlist STRUCTURE (nodes, devices, sources)
     is identical — only the wire parasitics, stop time and wave timings
     differ with the array geometry;
  2. build ONE parametric netlist per group and lift the per-point
     structural quantities into parameter arrays:
       * the linear elements assemble via unit-value incidence stamps
         (`Circuit.build_stamps`): G_b = src_G + g_b @ R_stamps and
         C_b = c_b @ C_stamps, where g_b/c_b (B, n_elem) hold each
         point's bitline-ladder segment conductances, wire/SA/junction
         capacitances — an einsum instead of B python assemblies;
       * per-point stop times t_end (from the analytic swing estimate)
         and the precharge/wordline wave timings enter as (B, ...) arrays;
  3. integrate the whole group in a single `Transient.run_lattice`
     program. solver="pallas" (default) routes to the fused sparse-
     Newton engine (kernels.batched_solve.newton): the constant part of
     the Jacobian G + C/h + gmin is inverted ONCE per run (h is fixed
     per point) and each Newton iteration applies a rank-3*n_dev
     Woodbury correction from the analytic device stamps — a Pallas
     kernel on TPU, a bit-identical XLA while_loop on CPU. "sparse"
     replays a symbolic LU over the fixed nonzero pattern instead;
     "jnp" keeps the dense `jax.vmap` + `jnp.linalg.solve` reference
     path of PR 2;
  4. extract the sense-swing threshold crossing vectorized on-device
     (`transient.crossing_time`), interpolated between bracketing steps
     exactly like the scalar reference.

Compiled programs are memoized per (topology, n_seg, n_steps, solver,
precision), so
repeated characterizations of overlapping lattices (Session sweeps,
benchmarks) pay tracing once.

Newton Jacobian stamp math (the per-iteration hot path): the MNA Newton
system is J dv = F(v) with J = C/h + G + dI/dv + gmin. dI/dv is built
from per-device 3x3 analytic stamps — `channel_current_grads` gives
(di/dvg, di/dva, di/dvb) of the EKV channel current in closed form, one
vectorized pass over the device parameter arrays, and
`MNASystem.device_jacobian` scatter-adds the nine KCL entries per device
into the dense matrix. See those docstrings for the row/column algebra.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core import timing as timing_mod
from repro.core.bank import BankConfig, build_bank
from repro.core.dse_batch import (group_by_topology, pad_bucket,
                                  pow2_bucket, topology_key)
from repro.core.spice.transient import Transient, crossing_time

_PIPE_CACHE_MAX = 32     # compiled-pipeline entries kept (FIFO eviction)


@dataclass
class TransientChar:
    """Transient read characterization of one design point."""
    cfg: BankConfig
    t_cell_s: float            # simulated sense-swing time (inf: no cross)
    t_cell_analytic_s: float   # analytic estimate (timing.cell_read_time)
    rel_dev: float             # |analytic - sim| / sim (the GEMTOO gap)
    swing_ok: bool             # trace reached the sense target
    t_end_s: float
    n_steps: int

    def as_dict(self) -> dict:
        return {"cell": self.cfg.cell, "word_size": self.cfg.word_size,
                "num_words": self.cfg.num_words, "wwlls": self.cfg.wwlls,
                "write_vt": self.cfg.write_vt,
                "t_cell_sim_s": self.t_cell_s,
                "t_cell_analytic_s": self.t_cell_analytic_s,
                "rel_dev": self.rel_dev, "swing_ok": self.swing_ok,
                "t_end_s": self.t_end_s, "n_steps": self.n_steps}


# (topology_key, n_seg, n_steps, solver) -> (system, Transient, stamps)
_PIPE_CACHE: Dict[tuple, tuple] = {}


def _pipeline(bank0, key: tuple):
    """Template netlist + jitted Transient + incidence stamps for one
    topology group (memoized: repeat characterizations re-trace nothing).

    The key embeds id(tech) (via topology_key), so each entry also PINS
    the TechFile object: without the strong reference, a collected tech's
    id could be reused by a different TechFile and silently hit the stale
    template."""
    hit = _PIPE_CACHE.get(key)
    if hit is not None:
        return hit[:-1]
    n_seg, n_steps, solver, precision = key[-4:]
    ckt, meta = timing_mod.read_netlist(bank0, n_seg=n_seg)
    res_stamps, cap_stamps, src_G = ckt.build_stamps()
    system = ckt.build()
    tr = Transient(system, solver=solver, precision=precision)
    out = (system, tr, res_stamps, cap_stamps, src_G, meta)
    while len(_PIPE_CACHE) >= _PIPE_CACHE_MAX:   # bound pinned programs
        del _PIPE_CACHE[next(iter(_PIPE_CACHE))]
    _PIPE_CACHE[key] = out + (bank0.cfg.tech,)
    return out


def _characterize_group(cfgs: List[BankConfig], banks, *, n_seg: int,
                        n_steps: int, solver: str,
                        precision: str = "f64",
                        parasitics: str = "modeled") -> List[TransientChar]:
    bank0 = banks[0]
    tech = cfgs[0].tech
    cell = bank0.cell
    key = topology_key(cfgs[0]) + (n_seg, n_steps, solver, precision)
    system, tr, res_stamps, cap_stamps, src_G, meta = _pipeline(bank0, key)

    # parasitics="extracted" (the layout tier): ONE batched extraction
    # over the group replaces the hand-modeled bitline ladder totals.
    # Via R/C folds uniformly into the n_seg segments, so the element
    # structure — and with it the compiled pipeline — is unchanged.
    ext = None
    if parasitics == "extracted":
        from repro.geom import extract as geom_extract
        ext = geom_extract.extract_lattice(banks)

    # -- lift structural values into per-point parameter arrays. The
    # per-point netlist builder is the single source of truth for element
    # VALUES (ladder R/C, device caps, SA load); structure is asserted
    # identical to the template.
    g_vals = np.zeros((len(banks), len(res_stamps)))
    c_vals = np.zeros((len(banks), len(cap_stamps)))
    t_an = np.zeros((len(banks),))
    for p, bank in enumerate(banks):
        rc_p = (float(ext["bl_r_ohm"][p]), float(ext["bl_c_f"][p])) \
            if ext is not None else None
        ckt_p, _ = timing_mod.read_netlist(bank, n_seg=n_seg, rc=rc_p)
        assert len(ckt_p.names) == len(system.names) and \
            len(ckt_p.res) == len(res_stamps) and \
            len(ckt_p.caps) == len(cap_stamps), "topology group mismatch"
        g_vals[p] = [g for _, _, g in ckt_p.res]
        c_vals[p] = [c for _, _, c in ckt_p.caps]
        t_an[p] = timing_mod.cell_read_time(bank, rc=rc_p)[0]

    # float64 assembly, float64 all the way down (the group runs under
    # enable_x64 — see characterize; no f32 cast happens or should)
    G_b = src_G[None] + np.einsum("br,rij->bij", g_vals, res_stamps)
    C_b = np.einsum("bc,cij->bij", c_vals, cap_stamps)

    # -- per-point stop time + waves, from the SAME stimulus recipe as
    # the scalar simulate_read (timing.read_stimulus), edge-padded to the
    # longest waveform exactly like Transient.pack_waves
    t_end = np.maximum(timing_mod.T_END_OVER_ANALYTIC * t_an,
                       timing_mod.T_END_MIN_S)
    t0 = timing_mod.T0_FRACTION * t_end
    B = len(banks)
    wt = wv = None
    v_pre = 0.0
    for p in range(B):
        waves_p, v_pre = timing_mod.read_stimulus(cell, tech,
                                                  meta["v_sn"], t0[p])
        if wt is None:   # buffer dims derived from the stimulus itself
            k = max(len(t) for t, _ in waves_p)
            wt = np.zeros((B, len(waves_p), k))
            wv = np.zeros((B, len(waves_p), k))
        for w, (t, v) in enumerate(waves_p):
            wt[p, w] = t + [t[-1]] * (k - len(t))
            wv[p, w] = v + [v[-1]] * (k - len(v))

    # pad the batch to a power-of-two bucket (edge-repeat) so the jitted
    # lattice program is reused across characterizations of different
    # sizes — vmap shapes are static, and session sweeps routinely hand
    # this pipeline varying-size "missing" subsets
    Bp = pow2_bucket(B)
    if Bp > B:
        pad = lambda a: pad_bucket(a, Bp)
        G_b, C_b, wt, wv = map(pad, (G_b, C_b, wt, np.asarray(wv)))
        t_end_p = pad(t_end)
    else:
        t_end_p = t_end

    res = tr.run_lattice(wt, wv, t_end_p, n_steps,
                         over_batches={"G": G_b, "C": C_b},
                         v0=jnp.full((system.n,), v_pre))

    swing = tech.v_sense_se
    target = v_pre + (swing if cell.predischarge else -swing)
    tc, valid = crossing_time(res["t"], res["rbl_near"], target,
                              rising=cell.predischarge)
    tc = np.asarray(tc)[:B]
    valid = np.asarray(valid)[:B]
    t_cell = np.where(valid, tc - t0, np.inf)

    out = []
    for p, cfg in enumerate(cfgs):
        sim = float(t_cell[p])
        dev = abs(t_an[p] - sim) / sim if np.isfinite(sim) and sim > 0 \
            else float("inf")
        out.append(TransientChar(cfg, sim, float(t_an[p]), float(dev),
                                 bool(valid[p]), float(t_end[p]), n_steps))
    return out


def t_cell_grad_fn(cfg: BankConfig, *, n_seg: int = 8, n_steps: int = 300,
                   solver: str = "pallas", precision: str = "f64"):
    """Differentiable transient read characterization of ONE topology.

    Returns `fn(knobs) -> (t_cell_s (B,), valid (B,))` where `knobs` maps
    any subset of the continuous design knobs to (B,) arrays:

      vdd_scale     array operating voltage multiplier (techfile
                    `with_vdd_scale` semantics: rails, written SN level
                    and stimulus levels scale; sense swing does not)
      w_read_scale  read-device width multiplier (device current + its
                    gate/junction caps + the bitline junction load)
      bl_wire_scale bitline wire WIDTH multiplier (ladder conductance
                    scales up, wire capacitance scales up)

    The returned fn is traced end-to-end: every knob flows through the
    MNA assembly, the stimulus waves and the implicit-function VJP of the
    fused Newton solve (kernels.batched_solve), so `jax.grad` of any
    reduction of t_cell_s is ONE extra adjoint solve per timestep — not a
    differentiated unroll. Discretization constants (t0, t_end, step
    count) are pinned at the NOMINAL design point: they are solver
    settings, not physics, and freezing them keeps the objective smooth.

    Call under `jax.experimental.enable_x64` (gradients of interpolated
    crossings through a cond(J)~1e6 system need f64). Gain cells only;
    solver must be "pallas" or "sparse" (the dense "jnp" path takes no
    device-parameter overrides).
    """
    if solver not in ("pallas", "sparse"):
        raise ValueError(f"solver {solver!r} not differentiable here "
                         "(use 'pallas' or 'sparse')")
    bank0 = build_bank(cfg)
    if not bank0.is_gc:
        raise ValueError(f"cell {cfg.cell!r} has no single-ended read "
                         "column to characterize")
    tech = cfg.tech
    cell = bank0.cell
    key = topology_key(cfg) + (n_seg, n_steps, solver, precision)
    system, tr, res_stamps, cap_stamps, src_G, meta = _pipeline(bank0, key)

    # -- nominal element values + cap-class decomposition. read_netlist
    # appends, in order: 4 precharge-device caps (fixed w=1.2), n_seg
    # ladder caps (c_bl/n_seg each), the SA input cap, 4 read-device caps
    # (each proportional to w_read). Assert that layout before relying
    # on it.
    ckt0, _ = timing_mod.read_netlist(bank0, n_seg=n_seg)
    g0 = np.array([g for _, _, g in ckt0.res])          # conductances
    c0 = np.array([c for _, _, c in ckt0.caps])
    assert len(g0) == n_seg and len(c0) == n_seg + 9, \
        "read_netlist element layout changed; update t_cell_grad_fn"
    from repro.core import bank as bank_mod
    r_bl0, c_bl0 = bank_mod.bitline_rc(bank0)
    rf = cell.rf(tech)
    c_junc0 = bank0.rows * rf.cj_f_per_um * cell.w_read  # scales w_read
    c_wire0 = c_bl0 - c_junc0                            # scales bl width
    np.testing.assert_allclose(g0, n_seg / r_bl0, rtol=1e-9)
    np.testing.assert_allclose(c0[4:4 + n_seg], c_bl0 / n_seg, rtol=1e-9)

    d_rd = next(i for i, d in enumerate(ckt0.devs) if d["name"] == "read_dev")
    w0 = np.array([d["w"] for d in ckt0.devs])
    n_dev = len(w0)

    # -- static discretization (from the nominal analytic estimate)
    t_an0 = timing_mod.cell_read_time(bank0)[0]
    t_end = max(timing_mod.T_END_OVER_ANALYTIC * t_an0,
                timing_mod.T_END_MIN_S)
    t0 = timing_mod.T0_FRACTION * t_end
    # wave TIME grids are static (the stimulus recipe of read_stimulus,
    # edge-padded to 3 knots); LEVELS are rebuilt traced per point below
    wt1 = np.array([[0.0, t0, t0 * 1.2],
                    [0.0, t0 * 0.8, t0],
                    [0.0, 1.0, 1.0],
                    [0.0, 1.0, 1.0]])
    bit = 0 if cell.read_on_sn_low else 1
    swing = tech.v_sense_se
    n = system.n

    from repro.core import cells as cells_mod

    def fn(knobs):
        some = next(iter(knobs.values()))
        B = some.shape[0]
        one = jnp.ones((B,), dtype=some.dtype)
        s_v = jnp.asarray(knobs.get("vdd_scale", one))
        s_w = jnp.asarray(knobs.get("w_read_scale", one))
        s_bl = jnp.asarray(knobs.get("bl_wire_scale", one))

        # linear elements: ladder conductance ~ wire width; ladder cap =
        # wire part ~ width + junction part ~ w_read; device caps of the
        # read transistor ~ w_read; precharge-device + SA caps fixed
        g_vals = g0[None, :] * s_bl[:, None]
        c_lad = (c_wire0 * s_bl + c_junc0 * s_w)[:, None] / n_seg
        c_vals = jnp.concatenate([
            jnp.broadcast_to(c0[:4], (B, 4)),
            jnp.broadcast_to(c_lad, (B, n_seg)),
            jnp.broadcast_to(c0[4 + n_seg], (B, 1)),
            c0[None, 4 + n_seg + 1:] * s_w[:, None],
        ], axis=1)
        G_b = jnp.asarray(src_G)[None] + jnp.einsum(
            "br,rij->bij", g_vals, jnp.asarray(res_stamps))
        C_b = jnp.einsum("bc,cij->bij", c_vals, jnp.asarray(cap_stamps))

        # stimulus levels, traced (same recipe as timing.read_stimulus)
        vdd = tech.vdd * s_v
        zero = jnp.zeros_like(vdd)
        v_sn = cells_mod.v_sn_written_t(cell, tech, bit, vdd,
                                        wwlls=cfg.wwlls,
                                        wwl_boost=cfg.wwl_boost)
        rwl_idle = zero if cell.rwl_active_high else vdd
        rwl_act = vdd if cell.rwl_active_high else zero
        v_pre = zero if cell.predischarge else vdd
        en_idle = vdd if cell.predischarge else zero
        en_off = zero if cell.predischarge else vdd
        wv = jnp.stack([
            jnp.stack([rwl_idle, rwl_idle, rwl_act], axis=1),
            jnp.stack([en_idle, en_idle, en_off], axis=1),
            jnp.stack([v_sn, v_sn, v_sn], axis=1),
            jnp.stack([vdd, vdd, vdd], axis=1),
        ], axis=1)
        wt = jnp.broadcast_to(wt1[None], (B, 4, 3))

        w_b = jnp.broadcast_to(w0, (B, n_dev)).at[:, d_rd].set(
            w0[d_rd] * s_w)
        v0 = jnp.broadcast_to(v_pre[:, None], (B, n))
        res = tr.run_lattice(wt, wv, jnp.full((B,), t_end), n_steps,
                             over_batches={"G": G_b, "C": C_b, "w": w_b},
                             v0=v0)
        # per-point sense target via trace shift (crossing_time takes a
        # scalar target)
        target = v_pre + (swing if cell.predischarge else -swing)
        tc, valid = crossing_time(res["t"], res["rbl_near"] - target[:, None],
                                  0.0, rising=cell.predischarge)
        return tc - t0, valid

    return fn


def characterize(cfgs: Sequence[BankConfig], *, n_steps: int = 300,
                 solver: str = "pallas", n_seg: int = 8,
                 precision: str = "f64", parasitics: str = "modeled"
                 ) -> List[Optional[TransientChar]]:
    """Batched transient read characterization of a config lattice.

    Returns one TransientChar per config, in input order; non-gain-cell
    configs (no single-ended read column to simulate) get None. Matches
    the scalar `timing.simulate_read` per point — same netlist builder,
    same integrator, same interpolated crossing extraction — but runs one
    compiled program per cell topology instead of one per point.

    parasitics="extracted" (fidelity="layout") swaps the hand-modeled
    read-bitline ladder for the batched layout extraction
    (`repro.geom.extract.extract_lattice`) — one struct-of-arrays
    extraction per topology group, same compiled transient pipeline.
    """
    if parasitics not in ("modeled", "extracted"):
        raise ValueError(f"parasitics must be 'modeled' or 'extracted', "
                         f"got {parasitics!r}")
    cfgs = list(cfgs)
    out: List[Optional[TransientChar]] = [None] * len(cfgs)
    # float64 throughout (see timing.simulate_read: cond(J) ~ 1e6 makes
    # f32 Newton noise dominate the traces). solver="pallas" (default) is
    # the fused sparse-Newton engine — f64 or mixed-precision per the
    # `precision` knob; "jnp" stays the dense accuracy anchor and
    # precision="f32" is screening-only.
    with enable_x64():
        for idx in group_by_topology(cfgs).values():
            group = [cfgs[i] for i in idx]
            banks = [build_bank(c) for c in group]
            if not banks[0].is_gc:
                continue
            chars = _characterize_group(group, banks, n_seg=n_seg,
                                        n_steps=n_steps, solver=solver,
                                        precision=precision,
                                        parasitics=parasitics)
            for i, ch in zip(idx, chars):
                out[i] = ch
    return out
