"""EKV-style compact transistor model (JAX-differentiable).

    i = I_S * [ L2((Vgs_on - VT)/(2 n phi_t)) - L2((Vgs_on - VT - n Vds)/(2 n phi_t)) ]
        * (1 + lambda * Vds),       L2(x) = ln^2(1 + e^x)
    I_S = 2 n k' (W/L) phi_t^2

One smooth expression covers subthreshold (slope == the deck's SS:
n phi_t ln10) through strong inversion (square law /2n) and saturation —
exactly what the retention problem needs (the write transistor sits deep
in subthreshold while the SN discharges). Both polarities share the same
magnitude function: conventional current always flows high->low terminal;
NMOS gates on with vg above the LOW terminal, PMOS with vg below the HIGH
terminal. All functions are elementwise jnp, so circuits vmap over
design-point batches (the "HSPICE -> batched JAX" adaptation, DESIGN §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.techfile import PHI_T, DeviceFlavor


def _l2(x):
    return jax.nn.softplus(x) ** 2  # ln^2(1+e^x)


def _i_mag_per_um(fl: DeviceFlavor, vg, v_hi, v_lo, l_um):
    """|I| per um width for current flowing v_hi -> v_lo (>= 0)."""
    vds = v_hi - v_lo
    if fl.polarity > 0:
        vgs_on = vg - v_lo          # NMOS: source = low terminal
    else:
        vgs_on = v_hi - vg          # PMOS: source = high terminal
    n = fl.n_slope
    i_s = 2.0 * n * fl.k_prime * (1.0 / max(l_um, 1e-3)) * PHI_T ** 2
    a = (vgs_on - fl.vt0) / (2.0 * n * PHI_T)
    b = (vgs_on - fl.vt0 - n * vds) / (2.0 * n * PHI_T)
    return i_s * (_l2(a) - _l2(b)) * (1.0 + fl.lambda_ * vds)


def channel_current(fl: DeviceFlavor, w_um, l_um, vg, va, vb):
    """Signed conventional current a -> b through the channel (A)."""
    fwd = _i_mag_per_um(fl, vg, va, vb, l_um)
    rev = _i_mag_per_um(fl, vg, vb, va, l_um)
    return w_um * jnp.where(va >= vb, fwd, -rev)


def i_gate(fl: DeviceFlavor, w_um, vg, vch):
    """Gate leakage (A), linear-in-bias toy model (sign: gate -> channel)."""
    return fl.i_gate_a_per_um * w_um * (vg - vch) / 1.1


def i_off(fl: DeviceFlavor, w_um, l_um, vdd):
    """Off-state leakage magnitude at Vgs_on=0, |Vds|=vdd (A)."""
    if fl.polarity > 0:
        return float(w_um * _i_mag_per_um(fl, 0.0, vdd, 0.0, l_um))
    return float(w_um * _i_mag_per_um(fl, vdd, vdd, 0.0, l_um))


def on_current_per_um(fl: DeviceFlavor, vdd, l_um=0.04):
    """|Id_sat| per um at Vgs_on = Vds = vdd."""
    if fl.polarity > 0:
        return float(_i_mag_per_um(fl, vdd, vdd, 0.0, l_um))
    return float(_i_mag_per_um(fl, 0.0, vdd, 0.0, l_um))


def id_vg_curve(fl: DeviceFlavor, vds: float, l_um=0.04, w_um=1.0, n=121):
    """Fig 8(a)/(d): |Id|-Vgs_on sweep at fixed |Vds|."""
    vgs = jnp.linspace(0.0, 1.1, n)
    if fl.polarity > 0:
        i = jax.vmap(lambda v: channel_current(fl, w_um, l_um, v, vds, 0.0))(vgs)
    else:
        i = jax.vmap(lambda v: channel_current(fl, w_um, l_um, vds - v, vds, 0.0))(vgs)
    return vgs, jnp.abs(i)
