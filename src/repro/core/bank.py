"""Bank generator: user config -> organization + modules + floorplan +
critical-path netlists (the compiler's structural core, paper Fig 4).

Organization: cols = word_size * words_per_row; rows = num_words /
words_per_row. words_per_row is chosen to square the array (paper §V-C:
at word_size:num_words = 1:1 a column mux is required; at 4:1 the array
is naturally square and faster).

GCRAM banks are dual-port: Write_Port_Address (left), Read_Port_Address
(right), Write_Port_Data (bottom: write drivers + data DFFs),
Read_Port_Data (top: precharge OR predischarge + SA + out DFFs), two
control blocks + reference generator (single-ended sensing) and an
optional WWL level shifter column (second supply ring, paper Fig 6a/7a).
SRAM banks are single-port with differential sensing.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core import layout
from repro.core.cells import CELLS, Bitcell, Sram6T, with_write_vt
from repro.core.techfile import TechFile, SYN40


@dataclass(frozen=True)
class BankConfig:
    word_size: int = 32
    num_words: int = 32
    cell: str = "gc2t_nn"             # cells.CELLS key
    write_vt: Optional[str] = None    # override write flavor (Fig 8c)
    wwlls: bool = False               # WWL level shifter + 2nd ring
    wwl_boost: float = 0.55
    tech: TechFile = SYN40

    @property
    def bits(self) -> int:
        return self.word_size * self.num_words


@dataclass
class Bank:
    cfg: BankConfig
    rows: int
    cols: int
    words_per_row: int
    has_colmux: bool
    is_gc: bool
    cell: object
    modules: Dict[str, float]         # name -> area um2
    plan: layout.Floorplan
    delay_stages: int = 0             # filled by timing

    @property
    def area_um2(self):
        return self.plan.bank_area_um2

    @property
    def array_area_um2(self):
        return self.plan.array_area_um2

    def summary(self) -> dict:
        return {
            "cell": self.cfg.cell, "word_size": self.cfg.word_size,
            "num_words": self.cfg.num_words, "bits": self.cfg.bits,
            "rows": self.rows, "cols": self.cols,
            "words_per_row": self.words_per_row,
            "wwlls": self.cfg.wwlls,
            "bank_area_um2": self.area_um2,
            "array_area_um2": self.array_area_um2,
            "array_efficiency": self.plan.array_efficiency,
            "modules_um2": dict(self.modules),
        }


def organize(word_size: int, num_words: int):
    """Square-ish array: pick words_per_row (power of two, <= 8).
    Ties break toward FEWER rows: per-row periphery (decoders, drivers)
    is the expensive direction for a dual-port bank."""
    best, best_key = 1, (float("inf"), float("inf"))
    for wpr in (1, 2, 4, 8):
        if num_words % wpr:
            continue
        rows = num_words // wpr
        cols = word_size * wpr
        ratio = max(rows, cols) / min(rows, cols)
        key = (ratio, rows)
        if key < best_key:
            best, best_key = wpr, key
    return best


def build_bank(cfg: BankConfig) -> Bank:
    tech = cfg.tech
    cell = CELLS[cfg.cell]
    if cfg.write_vt and isinstance(cell, Bitcell):
        cell = with_write_vt(cell, cfg.write_vt)
    is_gc = not isinstance(cell, Sram6T)

    wpr = organize(cfg.word_size, cfg.num_words)
    rows = cfg.num_words // wpr
    cols = cfg.word_size * wpr
    has_colmux = wpr > 1

    ma = lambda kind, n=1: layout.module_area_um2(tech, kind, n)
    n_addr_bits = max(1, int(math.log2(cfg.num_words)))
    mods: Dict[str, float] = {}

    if is_gc:
        # dual port: independent write/read address paths
        mods["w_decoder"] = ma("decoder_unit", rows)
        mods["w_wl_driver"] = ma("wl_driver", rows)
        mods["r_decoder"] = ma("decoder_unit", rows)
        mods["r_wl_driver"] = ma("wl_driver", rows)
        mods["addr_dff"] = ma("dff", 2 * n_addr_bits)
        if cfg.wwlls:
            mods["wwl_ls"] = ma("wwl_ls", rows)
        pre = "predischarge" if getattr(cell, "predischarge", False) \
            else "precharge"
        mods[pre] = ma(pre, cols)
        if has_colmux:
            mods["r_colmux"] = ma("colmux_unit", cols)
            mods["w_colmux"] = ma("colmux_unit", cols)
        mods["sense_amp"] = ma("sense_amp_se", cfg.word_size)
        mods["write_driver"] = ma("write_driver", cfg.word_size)
        mods["data_dff"] = ma("dff", 2 * cfg.word_size)  # in + out latches
        mods["refgen"] = ma("refgen")
        # two control FSMs + both delay chains (stage count from timing;
        # estimated here from array size, refined after timing.analyze)
        est_stages = 8 + rows // 16
        mods["ctrl"] = 2 * (ma("ctrl_base") + ma("delay_stage", est_stages))
        n_rings = 2 if cfg.wwlls else 1
        pf = layout.GC_PORT_FACTOR
        left = pf * (mods["w_decoder"] + mods["w_wl_driver"]
                     + mods.get("wwl_ls", 0.0))
        right = pf * (mods["r_decoder"] + mods["r_wl_driver"])
        top = pf * (mods[pre] + mods.get("r_colmux", 0.0)
                    + mods["sense_amp"] + ma("dff", cfg.word_size))
        bottom = pf * (mods["write_driver"] + mods.get("w_colmux", 0.0)
                       + ma("dff", cfg.word_size))
        corner = mods["refgen"] + mods["ctrl"] + pf * mods["addr_dff"]
    else:
        mods["decoder"] = ma("decoder_unit", rows)
        mods["wl_driver"] = ma("wl_driver", rows)
        mods["addr_dff"] = ma("dff", n_addr_bits)
        mods["precharge"] = ma("precharge", cols)
        if has_colmux:
            mods["colmux"] = ma("colmux_unit", cols)
        mods["sense_amp"] = ma("sense_amp", cfg.word_size)
        mods["write_driver"] = ma("write_driver_diff", cfg.word_size)
        mods["data_dff"] = ma("dff", 2 * cfg.word_size)
        mods["ctrl"] = ma("ctrl_base") + ma("delay_stage", 6 + rows // 32)
        n_rings = 1
        left = mods["decoder"] + mods["wl_driver"]
        right = 0.0
        top = mods["precharge"] + mods.get("colmux", 0.0) + \
            mods["sense_amp"] + ma("dff", cfg.word_size)
        bottom = mods["write_driver"] + ma("dff", cfg.word_size)
        corner = mods["ctrl"] + mods["addr_dff"]

    geom = cell.geom_key
    if is_gc and getattr(cell, "is_beol", False):
        plan = layout.packed_floorplan(
            tech, geom_key=geom, rows=rows, cols=cols,
            periph_um2=left + right + top + bottom + corner,
            n_rings=n_rings)
    else:
        plan = layout.floorplan(tech, geom_key=geom, rows=rows, cols=cols,
                                left_um2=left, right_um2=right, top_um2=top,
                                bottom_um2=bottom, corner_um2=corner,
                                n_rings=n_rings)
    return Bank(cfg, rows, cols, wpr, has_colmux, is_gc, cell, mods, plan)


# ---------------------------------------------------------------------------
# wire parasitics of the array (for timing + critical-path netlists)
# ---------------------------------------------------------------------------

def wordline_rc(bank: Bank):
    """Total R (ohm), C (F) of one wordline across all columns (M2) +
    gate loads."""
    tech = bank.cfg.tech
    cw, _ = layout.cell_wh_nm(tech, bank.cell.geom_key)
    length_um = bank.cols * cw * 1e-3
    r = tech.r_ohm_per_um["m2"] * length_um
    c_wire = tech.c_f_per_um["m2"] * length_um
    if bank.is_gc:
        wf = bank.cell.wf(tech)
        c_gates = bank.cols * wf.cg_f_per_um * bank.cell.w_write
    else:
        c_gates = bank.cols * tech.flavor("nmos_svt").cg_f_per_um * 0.14
    return r, c_wire + c_gates


def bitline_rc(bank: Bank):
    """Total R, C of one bitline across all rows (M3) + junction loads."""
    tech = bank.cfg.tech
    _, ch = layout.cell_wh_nm(tech, bank.cell.geom_key)
    length_um = bank.rows * ch * 1e-3
    r = tech.r_ohm_per_um["m3"] * length_um
    c_wire = tech.c_f_per_um["m3"] * length_um
    if bank.is_gc:
        rf = bank.cell.rf(tech)
        c_j = bank.rows * rf.cj_f_per_um * bank.cell.w_read
    else:
        c_j = bank.rows * tech.flavor("nmos_svt").cj_f_per_um * 0.14
    return r, c_wire + c_j
