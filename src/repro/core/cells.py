"""Bitcell library: 6T SRAM baseline + gain-cell variants.

Topology conventions (documented deviation from the paper noted in
DESIGN.md §2: the paper describes predischarge for all Si-Si reads; here
each config gets the electrically coherent scheme for its read device):

  gc2t_nn   write NMOS; read NMOS (gate=SN, source=RWL, drain=RBL).
            RWL idles at VDD and falls on read (ACTIVE-LOW — its falling
            edge couples SN down, the paper's §V-A problem). RBL
            precharged HIGH; SN='1' discharges it.
  gc2t_np   write NMOS; read PMOS. RWL idles 0, rises on read
            (ACTIVE-HIGH — rising edge boosts SN, recovering WWL-coupling
            droop). RBL PREDISCHARGED to 0; SN='0' charges it up
            (paper's predischarge + EN-inverter modification).
  gc2t_osos both OS NMOS (p-type OS too slow — paper §V-A); BEOL cell,
            precharge scheme like nn.
  gc3t      write NMOS + 2-NMOS read stack (decoupled read, better sense
            margin, more area).
  gc2t_hyb  OS write + Si PMOS read (paper §VI / ref [15]).
  sram6t    baseline: differential BL/BLb, shared-port.

Every cell exposes: device list (for leakage/netlists), SN capacitance,
post-write SN level, read current into/out of the RBL, coupling deltas.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

import jax.numpy as jnp

from repro.core.techfile import TechFile, DeviceFlavor
from repro.core.spice import devices as dv


@dataclass(frozen=True)
class Bitcell:
    name: str
    geom_key: str
    write_flavor: str
    read_flavor: str
    w_write: float = 0.12          # um
    w_read: float = 0.16
    l_write: float = 0.06          # longer L on the write device: retention
    l_read: float = 0.04
    rwl_active_high: bool = False  # np: True
    predischarge: bool = False     # np/hyb: RBL starts low, '0' charges it
    is_beol: bool = False          # OS cells take no FEOL area
    read_on_sn_low: bool = False   # PMOS read: conducts when SN low
    wwl_couple_ratio: float = 0.06 # C_couple/C_SN of WWL falling edge
    rwl_couple_ratio: float = 0.05

    # ---- derived electrical quantities ----
    def wf(self, tech: TechFile) -> DeviceFlavor:
        return tech.flavor(self.write_flavor)

    def rf(self, tech: TechFile) -> DeviceFlavor:
        return tech.flavor(self.read_flavor)

    def sn_cap(self, tech: TechFile) -> float:
        rf, wf = self.rf(tech), self.wf(tech)
        return (rf.cg_f_per_um * self.w_read + wf.cj_f_per_um * self.w_write
                + tech.sn_wire_cap_f)

    def v_sn_written(self, tech: TechFile, bit: int, *, wwlls=False,
                     wwl_boost=0.55, creep=0.12) -> float:
        """Post-write SN voltage incl. source-follower creep, WWL-coupling
        droop at WWL falloff and RWL-edge coupling at read idle level."""
        wf = self.wf(tech)
        vdd = tech.vdd
        if bit == 0:
            v = 0.0
        else:
            v_wwl = vdd + (wwl_boost if wwlls else 0.0)
            v = min(vdd, v_wwl - wf.vt0 + creep)
        v -= self.wwl_couple_ratio * vdd            # WWL falling edge
        if self.rwl_active_high:
            v += self.rwl_couple_ratio * vdd        # NP: RWL rise boosts SN
        return max(v, 0.0)

    def i_read(self, tech: TechFile, v_sn: float, v_rbl: float) -> float:
        """|I| the cell drives on the RBL at SN=v_sn, RBL=v_rbl (A)."""
        rf = self.rf(tech)
        vdd = tech.vdd
        if rf.polarity > 0:
            # NMOS read: active RWL=0; discharges RBL (precharged high)
            i = dv.channel_current(rf, self.w_read, self.l_read,
                                   v_sn, v_rbl, 0.0)
        else:
            # PMOS read: active RWL=vdd; charges RBL (predischarged low)
            i = dv.channel_current(rf, self.w_read, self.l_read,
                                   v_sn, vdd, v_rbl)
        return abs(float(i))

    def i_leak_rbl(self, tech: TechFile, unselected_v_sn: float) -> float:
        """Off-state RBL leakage of ONE unselected cell (A): limits rows
        per bitline (sense-margin erosion)."""
        rf = self.rf(tech)
        vdd = tech.vdd
        if rf.polarity > 0:
            # unselected: RWL=vdd -> vgs_on = v_sn - vdd < 0
            i = dv.channel_current(rf, self.w_read, self.l_read,
                                   unselected_v_sn, vdd * 0.9, vdd)
        else:
            i = dv.channel_current(rf, self.w_read, self.l_read,
                                   vdd, vdd * 0.1, 0.0)
        return abs(float(i))

    def i_sn_leak(self, tech: TechFile, v_sn: float) -> float:
        """Total SN leakage at v_sn: write-device subthreshold + read-gate
        leakage (paper §V-D: the retention mechanism)."""
        wf, rf = self.wf(tech), self.rf(tech)
        i_w = abs(float(dv.channel_current(wf, self.w_write, self.l_write,
                                           0.0 if wf.polarity > 0 else tech.vdd,
                                           v_sn, 0.0)))
        i_g = abs(float(dv.i_gate(rf, self.w_read, v_sn, tech.vdd / 2)))
        return i_w + i_g

    def cell_leakage(self, tech: TechFile) -> float:
        """Static VDD->GND leakage power of an idle cell (W). Gain cells
        have NO static path (paper C7) — only SRAM burns static power."""
        return 0.0


@dataclass(frozen=True)
class Sram6T:
    name: str = "sram6t"
    geom_key: str = "sram6t"
    w_pd: float = 0.20
    w_pu: float = 0.10
    w_ax: float = 0.14
    l: float = 0.04

    def sn_cap(self, tech):  # not used (static cell)
        return 0.0

    def i_read(self, tech: TechFile, v_sn=None, v_rbl=None) -> float:
        """Differential read current through access+pulldown at read onset."""
        nm = tech.flavor("nmos_svt")
        i_ax = dv.channel_current(nm, self.w_ax, self.l, tech.vdd,
                                  tech.vdd * 0.9, 0.0)
        return abs(float(i_ax)) * 0.7  # series pulldown derating

    def cell_leakage(self, tech: TechFile) -> float:
        """Idle VDD->GND leakage (W): one off pull-up + one off pull-down +
        access junctions; classic 6T three-path approximation."""
        nm, pm = tech.flavor("nmos_svt"), tech.flavor("pmos_svt")
        i = (dv.i_off(nm, self.w_pd, self.l, tech.vdd)
             + dv.i_off(pm, self.w_pu, self.l, tech.vdd)
             + dv.i_off(nm, self.w_ax, self.l, tech.vdd) * 0.5)
        return i * tech.vdd


CELLS = {
    "sram6t": Sram6T(),
    "gc2t_nn": Bitcell("gc2t_nn", "gc2t_nn", "nmos_svt", "nmos_svt"),
    "gc2t_np": Bitcell("gc2t_np", "gc2t_np", "nmos_svt", "pmos_svt",
                       rwl_active_high=True, predischarge=True,
                       read_on_sn_low=True),
    "gc2t_osos": Bitcell("gc2t_osos", "gc2t_osos", "os_n", "os_n",
                         w_write=0.10, w_read=0.20, is_beol=True,
                         wwl_couple_ratio=0.04),
    "gc3t": Bitcell("gc3t", "gc3t", "nmos_svt", "nmos_svt", w_read=0.20,
                    wwl_couple_ratio=0.03, rwl_couple_ratio=0.01),
    "gc2t_hyb": Bitcell("gc2t_hyb", "gc2t_hyb", "os_n", "pmos_svt",
                        rwl_active_high=True, predischarge=True,
                        read_on_sn_low=True),
}


def with_write_vt(cell: Bitcell, flavor: str) -> Bitcell:
    """VT-modulated variant (paper Fig 8c)."""
    return replace(cell, write_flavor=flavor,
                   name=f"{cell.name}:{flavor}")


# ---------------------------------------------------------------------------
# traced variants of the electrical primitives (core/dse_grad.py)
#
# The Bitcell methods above return Python floats (`abs(float(i))`) and
# branch on scalar comparisons — fine for the scalar reference path, but
# they sever autodiff. These module-level twins mirror the SAME algebra
# with jnp primitives, taking the continuous knobs (vdd, device widths)
# as traced arrays so gradients flow; the discrete cell attributes stay
# Python-level branches (they are static per cell).
# ---------------------------------------------------------------------------

def v_sn_written_t(cell: Bitcell, tech: TechFile, bit: int, vdd, *,
                   wwlls=False, wwl_boost=0.55, creep=0.12):
    """Traced twin of Bitcell.v_sn_written: post-write SN level with the
    operating voltage `vdd` as a traced array."""
    wf = cell.wf(tech)
    vdd = jnp.asarray(vdd)
    if bit == 0:
        v = jnp.zeros_like(vdd)
    else:
        v_wwl = vdd + (wwl_boost if wwlls else 0.0)
        v = jnp.minimum(vdd, v_wwl - wf.vt0 + creep)
    v = v - cell.wwl_couple_ratio * vdd
    if cell.rwl_active_high:
        v = v + cell.rwl_couple_ratio * vdd
    return jnp.maximum(v, 0.0)


def i_read_t(cell: Bitcell, tech: TechFile, v_sn, v_rbl, vdd, w_read):
    """Traced twin of Bitcell.i_read: |I| onto the RBL, with vdd and the
    read-device width traced."""
    rf = cell.rf(tech)
    if rf.polarity > 0:
        i = dv.channel_current(rf, w_read, cell.l_read,
                               v_sn, v_rbl, jnp.zeros_like(v_rbl))
    else:
        i = dv.channel_current(rf, w_read, cell.l_read, v_sn, vdd, v_rbl)
    return jnp.abs(i)


def i_leak_rbl_t(cell: Bitcell, tech: TechFile, unselected_v_sn, vdd,
                 w_read):
    """Traced twin of Bitcell.i_leak_rbl (one unselected cell's off-state
    RBL leakage)."""
    rf = cell.rf(tech)
    if rf.polarity > 0:
        i = dv.channel_current(rf, w_read, cell.l_read,
                               unselected_v_sn, vdd * 0.9, vdd)
    else:
        i = dv.channel_current(rf, w_read, cell.l_read,
                               vdd, vdd * 0.1, jnp.zeros_like(vdd))
    return jnp.abs(i)


def sn_cap_t(cell: Bitcell, tech: TechFile, w_read, w_write):
    """Traced twin of Bitcell.sn_cap with both device widths traced."""
    rf, wf = cell.rf(tech), cell.wf(tech)
    return (rf.cg_f_per_um * w_read + wf.cj_f_per_um * w_write
            + tech.sn_wire_cap_f)
