"""Synthetic 40 nm technology deck ("syn40").

TSMC N40 SPICE models and design rules are NDA'd (the paper's own repo
withholds them too), so OpenGCRAM-JAX defines an OPEN deck with
public-ballpark constants and calibrates to the paper's reported RATIOS
(cell-area ratios, retention ranges, frequency orderings) rather than
absolute foundry numbers — see DESIGN.md §2 assumption 1.

Everything downstream (cells, bank, layout, timing, power, retention)
reads ONLY from this file, so porting to a different node is: write a new
TechFile (the paper's Fig 1(a) porting flow, step 1).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Tuple

PHI_T = 0.02585  # kT/q at 300 K


@dataclass(frozen=True)
class DeviceFlavor:
    """EKV-style compact-model parameters for one transistor flavor."""
    name: str
    polarity: int          # +1 NMOS, -1 PMOS
    vt0: float             # V
    ss_mv_dec: float       # subthreshold swing
    k_prime: float         # A/V^2 per square (mu*Cox/2 effective)
    lambda_: float         # channel-length modulation 1/V
    cg_f_per_um: float     # gate cap per um width
    cj_f_per_um: float     # junction cap per um width
    i_gate_a_per_um: float # gate leakage
    is_os: bool = False

    @property
    def n_slope(self) -> float:
        return self.ss_mv_dec / (1000.0 * PHI_T * 2.302585)

    def i_off_a_per_um(self, l_um: float, vdd: float) -> float:
        """Analytic off-current (Vgs_on=0, |Vds|=vdd) per um of width."""
        from repro.core.spice.devices import i_off
        return i_off(self, 1.0, l_um, vdd)


@dataclass(frozen=True)
class TechFile:
    name: str = "syn40"
    vdd: float = 1.1
    temp_k: float = 300.0

    # ---- geometry (nm) ----
    cpp: int = 160                 # contacted poly pitch
    m1_pitch: int = 120
    m2_pitch: int = 140
    track: int = 120               # routing track height
    min_l_nm: int = 40

    # ---- wires ----
    r_ohm_per_um: Dict[str, float] = field(default_factory=lambda: {
        "m1": 2.2, "m2": 1.6, "m3": 1.2, "m4": 0.9})
    c_f_per_um: Dict[str, float] = field(default_factory=lambda: {
        "m1": 0.20e-15, "m2": 0.19e-15, "m3": 0.18e-15, "m4": 0.17e-15})

    # ---- bitcell geometry (poly pitches x routing tracks; DRC-margin
    #      constants emerge in layout.py) ----
    cell_geoms: Dict[str, dict] = field(default_factory=lambda: {
        # 6T SRAM with logic design rules (paper Fig 3c)
        "sram6t":   {"poly_pitches": 3.0, "tracks": 8.0, "margin": 0.00},
        # 2T Si-Si gain cell, logic rules: 2 CPP + dummy-WL/GND rail
        # spacing the paper notes could be merged (Fig 3a, 69% of 6T)
        "gc2t_nn":  {"poly_pitches": 2.0, "tracks": 8.0, "margin": 0.035},
        "gc2t_np":  {"poly_pitches": 2.0, "tracks": 8.0, "margin": 0.055},
        # 2T OS-OS: BEOL transistors between tight-pitch metals; FEOL
        # footprint is via landing + rail sharing only (Fig 3b, 11% of 6T)
        "gc2t_osos": {"poly_pitches": 1.0, "tracks": 2.6, "margin": 0.02},
        # 3T gain cell (separate read stack) and hybrid OS-Si
        "gc3t":     {"poly_pitches": 3.0, "tracks": 8.0, "margin": 0.02},
        "gc2t_hyb": {"poly_pitches": 1.6, "tracks": 8.0, "margin": 0.03},
    })

    # ---- storage-node parasitics (F) beyond read-gate cap ----
    sn_wire_cap_f: float = 0.12e-15

    # ---- sensing ----
    v_sense_se: float = 0.10       # single-ended RBL swing needed (V)
    v_sense_diff: float = 0.08     # differential SRAM BL swing
    sa_delay_s: float = 60e-12
    dff_delay_s: float = 70e-12
    stage_delay_s: float = 26e-12  # control delay-chain stage granularity

    # ---- devices ----
    devices: Dict[str, DeviceFlavor] = field(default_factory=lambda: {
        # silicon, three VT flavors (paper Fig 8c modulates write-NMOS VT)
        "nmos_lvt": DeviceFlavor("nmos_lvt", +1, 0.32, 95.0, 3.1e-4, 0.12,
                                 1.00e-15, 0.55e-15, 2.0e-15),
        "nmos_svt": DeviceFlavor("nmos_svt", +1, 0.42, 92.0, 2.9e-4, 0.10,
                                 1.00e-15, 0.55e-15, 1.0e-15),
        "nmos_hvt": DeviceFlavor("nmos_hvt", +1, 0.52, 90.0, 2.6e-4, 0.08,
                                 1.00e-15, 0.55e-15, 0.5e-15),
        "pmos_lvt": DeviceFlavor("pmos_lvt", -1, 0.34, 98.0, 1.5e-4, 0.14,
                                 1.05e-15, 0.60e-15, 1.0e-15),
        "pmos_svt": DeviceFlavor("pmos_svt", -1, 0.44, 95.0, 1.4e-4, 0.12,
                                 1.05e-15, 0.60e-15, 0.6e-15),
        "pmos_hvt": DeviceFlavor("pmos_hvt", -1, 0.54, 92.0, 1.2e-4, 0.10,
                                 1.05e-15, 0.60e-15, 0.3e-15),
        # oxide-semiconductor (ITO-like): low mobility, steep SS, ultra-low
        # leakage; TCAD-calibrated verilog-A analogue (paper §V-D). The
        # default flavor lands ms-range retention (Fig 8e); the hvt flavor
        # is the "VT/material engineering" point with >10 s retention.
        "os_n":     DeviceFlavor("os_n", +1, 0.45, 68.0, 6.0e-6, 0.05,
                                 0.80e-15, 0.25e-15, 1.0e-20, is_os=True),
        "os_n_hvt": DeviceFlavor("os_n_hvt", +1, 0.80, 66.0, 5.0e-6, 0.05,
                                 0.80e-15, 0.25e-15, 1.0e-21, is_os=True),
    })

    def flavor(self, name: str) -> DeviceFlavor:
        return self.devices[name]


SYN40 = TechFile()


# ---------------------------------------------------------------------------
# operating points (paper: retention is tuned "on-the-fly by changing the
# operating voltage")
# ---------------------------------------------------------------------------

# memoized so a given (deck, scale) pair always yields the SAME TechFile
# object: dse_batch.topology_key groups by id(cfg.tech), and session/point
# caches rely on stable identity across calls. Values keep a reference to
# the base deck so its id() cannot be recycled while the entry lives.
_VDD_SCALED: Dict[tuple, Tuple["TechFile", "TechFile"]] = {}


def with_vdd_scale(tech: TechFile, vdd_scale: float) -> TechFile:
    """The deck at a scaled operating voltage: identical devices, wires
    and geometry, `vdd` multiplied by `vdd_scale`. Everything downstream
    (written SN levels, read currents, retention leakage, dynamic CV^2
    energies) follows automatically because it reads only `tech.vdd`;
    voltage-independent periphery constants (sense swings, SA/DFF/stage
    delays) are deliberately left untouched — the VDD axis models the
    ARRAY operating point, not a resized periphery."""
    vdd_scale = float(vdd_scale)
    if vdd_scale == 1.0:
        return tech
    if vdd_scale <= 0.0:
        raise ValueError(f"vdd_scale must be > 0, got {vdd_scale}")
    key = (id(tech), vdd_scale)
    hit = _VDD_SCALED.get(key)
    if hit is None:
        scaled = dataclasses.replace(
            tech, name=f"{tech.name}@{vdd_scale:g}vdd",
            vdd=tech.vdd * vdd_scale)
        _VDD_SCALED[key] = hit = (scaled, tech)
    return hit[0]
