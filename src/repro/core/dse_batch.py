"""Batched (struct-of-arrays) lattice evaluator for design-space sweeps.

`dse.evaluate` is the scalar reference: per config it rebuilds the bank,
re-integrates retention, and issues a dozen single-element jnp dispatches
— fine for one point, slow for a lattice. This module evaluates a whole
lattice at once:

  1. group configs by cell topology (cell, write-VT override, WWLLS,
     WWL boost, tech) so array shapes stay static per group;
  2. compute the group-constant electricals ONCE per group with the SAME
     scalar calls `dse.evaluate` makes (read/leak currents at the
     written SN level, the retention integral, the write SN settle);
  3. `jax.vmap` the per-point analytic timing + power algebra across the
     group's struct-of-arrays (rows, wire RC, word size, ...) in float64
     (jax.experimental.enable_x64), reusing the formula kernels from
     `repro.core.timing`.

Because the group constants come from the identical scalar calls and the
per-point algebra is the identical float64 expression tree, batched
results match `dse.evaluate` to well under 1e-6 relative — asserted in
tests/test_api.py and benchmarks/bench_sweep.py.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core import bank as bank_mod
from repro.core import retention as ret_mod
from repro.core import timing as timing_mod
from repro.core.bank import BankConfig, build_bank
from repro.core.dse import DesignPoint
from repro.core.power import PERIPH_LEAK_W_PER_UM2
from repro.core.spice import devices as dv


def topology_key(cfg: BankConfig) -> tuple:
    """Cell-topology grouping key: configs sharing it have identical cell
    electricals and (for the transient pipeline) identical critical-path
    netlist STRUCTURE — only wire/structural values differ. Shared with
    `repro.core.spice.char_batch`."""
    return (cfg.cell, cfg.write_vt, cfg.wwlls, cfg.wwl_boost, id(cfg.tech))


def group_by_topology(cfgs: Sequence[BankConfig]) -> Dict[tuple, List[int]]:
    """Indices of `cfgs` grouped by topology_key, preserving order."""
    groups: Dict[tuple, List[int]] = {}
    for i, cfg in enumerate(cfgs):
        groups.setdefault(topology_key(cfg), []).append(i)
    return groups


def evaluate_batch(cfgs: Sequence[BankConfig]) -> List[DesignPoint]:
    """Evaluate every config; returns DesignPoints in input order."""
    groups = group_by_topology(cfgs)
    out: List[DesignPoint] = [None] * len(cfgs)
    for idx in groups.values():
        for i, p in zip(idx, _evaluate_group([cfgs[i] for i in idx])):
            out[i] = p
    return out


def _group_constants(cfg0: BankConfig, bank0) -> dict:
    """Electricals that depend only on the cell topology — computed with
    the same scalar calls the reference `dse.evaluate` path makes."""
    tech = cfg0.tech
    cell = bank0.cell
    if bank0.is_gc:
        bit = 0 if cell.read_on_sn_low else 1
        v_sn = cell.v_sn_written(tech, bit, wwlls=cfg0.wwlls,
                                 wwl_boost=cfg0.wwl_boost)
        v_rbl0 = 0.0 if cell.predischarge else tech.vdd
        swing = tech.v_sense_se
        v_rbl_mid = v_rbl0 + (0.5 * swing if cell.predischarge
                              else -0.5 * swing)
        i_cell = cell.i_read(tech, v_sn, v_rbl_mid)
        off_sn = cell.v_sn_written(tech, 1 if cell.read_on_sn_low else 0)
        i_leak1 = cell.i_leak_rbl(tech, off_sn)
        t_ret = ret_mod.analyze(cell, tech, wwlls=cfg0.wwlls,
                                wwl_boost=cfg0.wwl_boost).t_ret_s
        wf = cell.wf(tech)
        v_gate = tech.vdd + (cfg0.wwl_boost if cfg0.wwlls else 0.0)
        i_on = abs(float(dv.channel_current(
            wf, cell.w_write, cell.l_write, v_gate, tech.vdd,
            tech.vdd * 0.45)))
        return dict(i_cell=i_cell, i_leak1=i_leak1, dv_sense=swing,
                    t_ret=t_ret,
                    t_sn=cell.sn_cap(tech) * 0.9 * tech.vdd
                    / max(i_on, 1e-12),
                    cell_leak_per_bit=0.0)
    return dict(i_cell=cell.i_read(tech), i_leak1=0.0,
                dv_sense=tech.v_sense_diff, t_ret=float("inf"), t_sn=0.0,
                cell_leak_per_bit=cell.cell_leakage(tech))


def _evaluate_group(cfgs: List[BankConfig]) -> List[DesignPoint]:
    tech = cfgs[0].tech
    banks = [build_bank(c) for c in cfgs]
    is_gc = banks[0].is_gc
    wwlls = cfgs[0].wwlls
    gc = _group_constants(cfgs[0], banks[0])
    i_cell, i_leak1, dv_sense = gc["i_cell"], gc["i_leak1"], gc["dv_sense"]
    t_ret, t_sn = gc["t_ret"], gc["t_sn"]

    # struct-of-arrays: structural + wire quantities per point
    rows = np.array([b.rows for b in banks], np.float64)
    wl = np.array([bank_mod.wordline_rc(b) for b in banks], np.float64)
    bl = np.array([bank_mod.bitline_rc(b) for b in banks], np.float64)
    t_dec = np.array([timing_mod.decoder_delay(b.rows) for b in banks],
                     np.float64)
    ws = np.array([c.word_size for c in cfgs], np.float64)
    bits = np.array([c.bits for c in cfgs], np.float64)
    periph = np.array([sum(b.modules.values()) for b in banks], np.float64)
    has_mux = np.array([b.has_colmux for b in banks])
    swing_ok = (i_cell > 3.0 * ((rows - 1.0) * i_leak1)) if is_gc \
        else np.full(len(banks), i_cell > 0.0)

    fo4 = timing_mod.FO4_S
    sa_s, dff_s = tech.sa_delay_s, tech.dff_delay_s
    unit0 = tech.stage_delay_s
    vdd = tech.vdd
    margin, cap = timing_mod.CHAIN_MARGIN, float(timing_mod.CHAIN_MAX_STAGES)
    growth = timing_mod.CHAIN_UNIT_GROWTH
    refresh_on = is_gc and t_ret > 0 and np.isfinite(t_ret)

    def point(rows_i, r_wl, c_wl, r_bl, c_bl, t_dec_i, ws_i, bits_i,
              periph_i, mux_i):
        # -- read path (timing.analyze, vectorized)
        t_wl = timing_mod.elmore_delay(timing_mod.WL_DRIVER_R_OHM, r_wl, c_wl)
        c_bl_read = c_bl + timing_mod.SA_INPUT_C_F
        leak = (rows_i - 1.0) * i_leak1
        i_net = jnp.maximum(i_cell - leak, 1e-12)
        t_cell = timing_mod.cell_swing_time(dv_sense, c_bl_read, i_net, r_bl)
        analog = t_wl + t_cell + jnp.where(mux_i, 2 * fo4, 0.0) + sa_s
        if is_gc:
            analog = analog + timing_mod.REF_SETTLE_S
        # delay-chain unit coarsening: unit0 * growth**k, smallest k with
        # analog*margin/unit <= cap (exact while-loop semantics; the log
        # estimate is corrected one step either way for float edges)
        a_m = analog * margin
        k = jnp.maximum(jnp.ceil(jnp.log(a_m / (unit0 * cap))
                                 / jnp.log(growth)), 0.0)
        k = jnp.where(a_m / (unit0 * growth ** k) > cap, k + 1.0, k)
        k = jnp.where((k > 0.0) & (a_m / (unit0 * growth ** (k - 1.0))
                                   <= cap), k - 1.0, k)
        unit = unit0 * growth ** k
        t_chain = jnp.ceil(a_m / unit) * unit
        t_read = dff_s + t_dec_i + t_chain + dff_s
        # -- write path (timing.write_time, vectorized)
        t_bl = timing_mod.elmore_delay(timing_mod.WBL_DRIVER_R_OHM, r_bl,
                                       c_bl)
        t_wr_core = t_wl + t_bl + (t_sn if is_gc else 2 * fo4)
        t_write = dff_s + t_dec_i + jnp.maximum(t_wr_core, t_chain * 0.6)
        f = 1.0 / jnp.maximum(t_read, t_write)
        # -- standby power (power.analyze leakage + refresh, vectorized)
        leakage = bits_i * gc["cell_leak_per_bit"] \
            + periph_i * PERIPH_LEAK_W_PER_UM2
        e_write = (c_wl * vdd ** 2 + ws_i * c_bl * vdd ** 2
                   + ws_i * 6e-15 * vdd ** 2)
        if wwlls:
            e_write = e_write * 1.25
        refresh = bits_i * (e_write / jnp.maximum(ws_i, 1.0)) / t_ret \
            if refresh_on else jnp.zeros_like(e_write)
        return t_read, t_write, f, leakage, refresh

    with enable_x64():
        arrs = [jnp.asarray(a, jnp.float64) for a in
                (rows, wl[:, 0], wl[:, 1], bl[:, 0], bl[:, 1], t_dec, ws,
                 bits, periph)]
        t_read, t_write, f, leakage, refresh = jax.vmap(point)(
            *arrs, jnp.asarray(has_mux))
    t_read, t_write, f, leakage, refresh = (
        np.asarray(a) for a in (t_read, t_write, f, leakage, refresh))

    out = []
    for j, (cfg, b) in enumerate(zip(cfgs, banks)):
        fj, wsz = float(f[j]), cfg.word_size
        if is_gc:
            rbw = wbw = fj * wsz
        else:
            rbw = wbw = fj * wsz / 2
        out.append(DesignPoint(
            cfg, b.area_um2, fj, rbw, wbw, rbw + wbw, float(leakage[j]),
            float(refresh[j]), t_ret, bool(swing_ok[j]), float(t_read[j]),
            float(t_write[j])))
    return out
