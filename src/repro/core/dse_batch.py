"""Batched (struct-of-arrays) lattice evaluator for design-space sweeps,
now with an OPERATING-VOLTAGE axis.

`dse.evaluate` is the scalar reference: per config it rebuilds the bank,
re-integrates retention, and issues a dozen single-element jnp dispatches
— fine for one point, slow for a lattice. This module evaluates a whole
lattice at once:

  1. group configs by cell topology (cell, write-VT override, WWLLS,
     WWL boost, tech) so array shapes stay static per group;
  2. compute the group-constant electricals ONCE per (group, vdd_scale)
     with the SAME scalar calls `dse.evaluate` makes (read/leak currents
     at the written SN level, the retention integral, the write SN
     settle);
  3. `jax.vmap` the per-point analytic timing + power algebra across the
     group's struct-of-arrays (rows, wire RC, word size, ...) in float64
     (jax.experimental.enable_x64), reusing the formula kernels from
     `repro.core.timing` — and vmap AGAIN over the vdd axis, whose
     per-scale constants ride in as mapped operands (geometry and wire
     RC are voltage-independent, so the structural arrays are shared
     across the whole voltage ladder).

Because the group constants come from the identical scalar calls and the
per-point algebra is the identical float64 expression tree, batched
results match `dse.evaluate` bit-for-bit — asserted in
tests/test_api.py, tests/test_codesign.py and benchmarks.

On top of the (vdd x lattice) tables this module vectorizes the
workload-matching layer that `dse.feasible` / `multibank.banks_needed`
define scalarly: `feasible_grid`, `banks_needed_grid` and
`codesign_metrics` evaluate (vdd x lattice x demand) grids in one device
program each — the engine behind `repro.api.CoDesignQuery`.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core import bank as bank_mod
from repro.core import retention as ret_mod
from repro.core import timing as timing_mod
from repro.core.bank import BankConfig, build_bank
from repro.core.dse import DesignPoint
from repro.core.power import PERIPH_LEAK_W_PER_UM2
from repro.core.spice import devices as dv
from repro.core.techfile import with_vdd_scale


def pow2_bucket(n: int, floor: int = 4) -> int:
    """Smallest power-of-two >= n, floored at `floor` — the shared
    batch-bucketing rule: jitted programs specialize on array shapes,
    so batches of varying size land in a handful of buckets and reuse
    the compiled program. Shared with `core.spice.char_batch`."""
    return max(floor, 1 << max(0, n - 1).bit_length())


def pad_bucket(a: np.ndarray, bucket: int) -> np.ndarray:
    """Edge-repeat `a` along axis 0 up to `bucket` rows (no-op when
    already there). Padded rows are dropped by the caller's slice-back,
    so bucketing is value-transparent."""
    n = a.shape[0]
    if bucket <= n:
        return a
    return np.concatenate([a, np.repeat(a[-1:], bucket - n, axis=0)],
                          axis=0)


def topology_key(cfg: BankConfig) -> tuple:
    """Cell-topology grouping key: configs sharing it have identical cell
    electricals and (for the transient pipeline) identical critical-path
    netlist STRUCTURE — only wire/structural values differ. Shared with
    `repro.core.spice.char_batch`."""
    return (cfg.cell, cfg.write_vt, cfg.wwlls, cfg.wwl_boost, id(cfg.tech))


def group_by_topology(cfgs: Sequence[BankConfig]) -> Dict[tuple, List[int]]:
    """Indices of `cfgs` grouped by topology_key, preserving order."""
    groups: Dict[tuple, List[int]] = {}
    for i, cfg in enumerate(cfgs):
        groups.setdefault(topology_key(cfg), []).append(i)
    return groups


def evaluate_batch(cfgs: Sequence[BankConfig],
                   vdd_scale: float = 1.0) -> List[DesignPoint]:
    """Evaluate every config (at one operating voltage); returns
    DesignPoints in input order. Thin wrapper over the one-row
    (vdd x lattice) table so there is a single materialization path."""
    lat = evaluate_vdd_lattice(cfgs, (float(vdd_scale),))
    return [lat.point(0, i) for i in range(len(lat.cfgs))]


def _group_constants(cfg0: BankConfig, bank0, vdd_scale: float = 1.0) -> dict:
    """Electricals that depend only on (cell topology, operating voltage)
    — computed with the same scalar calls the reference `dse.evaluate`
    path makes at that vdd_scale."""
    tech = with_vdd_scale(cfg0.tech, vdd_scale)
    cell = bank0.cell
    if bank0.is_gc:
        bit = 0 if cell.read_on_sn_low else 1
        v_sn = cell.v_sn_written(tech, bit, wwlls=cfg0.wwlls,
                                 wwl_boost=cfg0.wwl_boost)
        v_rbl0 = 0.0 if cell.predischarge else tech.vdd
        swing = tech.v_sense_se
        v_rbl_mid = v_rbl0 + (0.5 * swing if cell.predischarge
                              else -0.5 * swing)
        i_cell = cell.i_read(tech, v_sn, v_rbl_mid)
        off_sn = cell.v_sn_written(tech, 1 if cell.read_on_sn_low else 0)
        i_leak1 = cell.i_leak_rbl(tech, off_sn)
        t_ret = ret_mod.analyze(cell, tech, wwlls=cfg0.wwlls,
                                wwl_boost=cfg0.wwl_boost).t_ret_s
        wf = cell.wf(tech)
        v_gate = tech.vdd + (cfg0.wwl_boost if cfg0.wwlls else 0.0)
        i_on = abs(float(dv.channel_current(
            wf, cell.w_write, cell.l_write, v_gate, tech.vdd,
            tech.vdd * 0.45)))
        return dict(i_cell=i_cell, i_leak1=i_leak1, dv_sense=swing,
                    t_ret=t_ret, vdd=tech.vdd,
                    t_sn=cell.sn_cap(tech) * 0.9 * tech.vdd
                    / max(i_on, 1e-12),
                    cell_leak_per_bit=0.0)
    return dict(i_cell=cell.i_read(tech), i_leak1=0.0,
                dv_sense=tech.v_sense_diff, t_ret=float("inf"), t_sn=0.0,
                vdd=tech.vdd, cell_leak_per_bit=cell.cell_leakage(tech))


# deterministic pure functions of (cell topology, deck, operating
# voltage): safe to memoize process-wide. Values keep the deck alive so
# the id() in the topology key cannot be recycled. This is what makes a
# warm co-design cube cheap — repeated queries over the same cell
# library re-derive NO retention integrals. Scope caveat: keying by
# deck IDENTITY means equal-but-distinct TechFile objects don't share
# entries (and pin their deck for the process lifetime) — reuse one
# TechFile per deck, as Session does, rather than constructing fresh
# ones per query.
_CONSTS_CACHE: Dict[tuple, tuple] = {}


def _group_constants_cached(cfg0: BankConfig, bank0,
                            vdd_scale: float) -> dict:
    key = topology_key(cfg0) + (float(vdd_scale),)
    hit = _CONSTS_CACHE.get(key)
    if hit is None:
        _CONSTS_CACHE[key] = hit = (
            _group_constants(cfg0, bank0, vdd_scale), cfg0.tech)
    return hit[0]


@lru_cache(maxsize=None)
def _group_kernel(is_gc: bool, wwlls: bool, dv_sense: float, sa_s: float,
                  dff_s: float, unit0: float):
    """Jitted nested-vmap timing/power kernel for one (topology-shape,
    periphery-constant) family: outer vmap over the voltage axis (the
    per-voltage electrical constants ride as mapped operands), inner
    vmap over the lattice's structural arrays. Compiled once per
    (family, array shape); must be TRACED under enable_x64 (callers hold
    the context), so python-float constants promote to float64."""
    fo4 = timing_mod.FO4_S
    margin, cap = timing_mod.CHAIN_MARGIN, float(timing_mod.CHAIN_MAX_STAGES)
    growth = timing_mod.CHAIN_UNIT_GROWTH

    def point(vdd, i_cell_v, i_leak1_v, t_ret_v, t_sn_v, clpb_v,
              rows_i, r_wl, c_wl, r_bl, c_bl, t_dec_i, ws_i, bits_i,
              periph_i, mux_i):
        # -- read path (timing.analyze, vectorized)
        t_wl = timing_mod.elmore_delay(timing_mod.WL_DRIVER_R_OHM, r_wl, c_wl)
        c_bl_read = c_bl + timing_mod.SA_INPUT_C_F
        leak = (rows_i - 1.0) * i_leak1_v
        i_net = jnp.maximum(i_cell_v - leak, 1e-12)
        t_cell = timing_mod.cell_swing_time(dv_sense, c_bl_read, i_net, r_bl)
        analog = t_wl + t_cell + jnp.where(mux_i, 2 * fo4, 0.0) + sa_s
        if is_gc:
            analog = analog + timing_mod.REF_SETTLE_S
        # delay-chain unit coarsening: unit0 * growth**k, smallest k with
        # analog*margin/unit <= cap (exact while-loop semantics; the log
        # estimate is corrected one step either way for float edges)
        a_m = analog * margin
        k = jnp.maximum(jnp.ceil(jnp.log(a_m / (unit0 * cap))
                                 / jnp.log(growth)), 0.0)
        k = jnp.where(a_m / (unit0 * growth ** k) > cap, k + 1.0, k)
        k = jnp.where((k > 0.0) & (a_m / (unit0 * growth ** (k - 1.0))
                                   <= cap), k - 1.0, k)
        unit = unit0 * growth ** k
        t_chain = jnp.ceil(a_m / unit) * unit
        t_read = dff_s + t_dec_i + t_chain + dff_s
        # -- write path (timing.write_time, vectorized)
        t_bl = timing_mod.elmore_delay(timing_mod.WBL_DRIVER_R_OHM, r_bl,
                                       c_bl)
        t_wr_core = t_wl + t_bl + (t_sn_v if is_gc else 2 * fo4)
        t_write = dff_s + t_dec_i + jnp.maximum(t_wr_core, t_chain * 0.6)
        f = 1.0 / jnp.maximum(t_read, t_write)
        # -- standby power (power.analyze leakage + refresh, vectorized)
        leakage = bits_i * clpb_v + periph_i * PERIPH_LEAK_W_PER_UM2
        bl_swing = dv_sense * 3 if is_gc else vdd * 0.5
        e_read = (c_wl * vdd ** 2 + ws_i * c_bl * vdd * bl_swing
                  + ws_i * 8e-15 * vdd ** 2)
        e_write = (c_wl * vdd ** 2 + ws_i * c_bl * vdd ** 2
                   + ws_i * 6e-15 * vdd ** 2)
        if wwlls:
            e_write = e_write * 1.25
        if is_gc:
            safe_ret = jnp.where(t_ret_v > 0.0, t_ret_v, 1.0)
            refresh = jnp.where(
                t_ret_v > 0.0,
                bits_i * (e_write / jnp.maximum(ws_i, 1.0)) / safe_ret, 0.0)
        else:
            refresh = jnp.zeros_like(e_write)
        return t_read, t_write, f, leakage, refresh, e_read, e_write

    inner = jax.vmap(point, in_axes=(None,) * 6 + (0,) * 10)  # over points
    outer = jax.vmap(inner, in_axes=(0,) * 6 + (None,) * 10)  # over vdd
    return jax.jit(outer)


def _eval_group_arrays(cfgs: List[BankConfig], banks,
                       vdd_scales: Sequence[float]) -> dict:
    """Core batched algebra for one topology group: (V, P) metric arrays
    from (V,) per-voltage constants x (P,) structural arrays, nested
    jax.vmap, float64."""
    tech = cfgs[0].tech
    is_gc = banks[0].is_gc
    wwlls = cfgs[0].wwlls
    consts = [_group_constants_cached(cfgs[0], banks[0], v)
              for v in vdd_scales]
    dv_sense = consts[0]["dv_sense"]

    # struct-of-arrays: structural + wire quantities per point
    # (voltage-independent, shared across the whole vdd ladder)
    rows = np.array([b.rows for b in banks], np.float64)
    wl = np.array([bank_mod.wordline_rc(b) for b in banks], np.float64)
    bl = np.array([bank_mod.bitline_rc(b) for b in banks], np.float64)
    t_dec = np.array([timing_mod.decoder_delay(b.rows) for b in banks],
                     np.float64)
    ws = np.array([c.word_size for c in cfgs], np.float64)
    bits = np.array([c.bits for c in cfgs], np.float64)
    periph = np.array([sum(b.modules.values()) for b in banks], np.float64)
    has_mux = np.array([b.has_colmux for b in banks])

    # per-voltage scalar constants, mapped over the outer vmap axis
    i_cell = np.array([c["i_cell"] for c in consts], np.float64)
    i_leak1 = np.array([c["i_leak1"] for c in consts], np.float64)
    t_ret = np.array([c["t_ret"] for c in consts], np.float64)
    t_sn = np.array([c["t_sn"] for c in consts], np.float64)
    clpb = np.array([c["cell_leak_per_bit"] for c in consts], np.float64)
    vdd_v = np.array([c["vdd"] for c in consts], np.float64)

    swing_ok = (i_cell[:, None] > 3.0 * ((rows - 1.0) * i_leak1[:, None])) \
        if is_gc else np.broadcast_to(i_cell[:, None] > 0.0,
                                      (len(consts), len(banks))).copy()

    # pad the lattice axis to a power-of-two bucket (edge-repeat) so the
    # jitted kernel is reused across group sizes: vmap shapes are
    # static, and both session sweeps and the coalescing executor
    # (repro.api.executor) hand this path varying-size unions of
    # "missing" configs. Same bucketing pattern as char_batch/engine;
    # the algebra is elementwise per point, so padding (and batch
    # composition generally) cannot perturb any point's value.
    P = len(banks)
    Pp = pow2_bucket(P)
    pad = lambda a: pad_bucket(a, Pp)
    with enable_x64():
        kernel = _group_kernel(is_gc, wwlls, float(dv_sense),
                               tech.sa_delay_s, tech.dff_delay_s,
                               tech.stage_delay_s)
        parrs = [jnp.asarray(pad(a), jnp.float64) for a in
                 (rows, wl[:, 0], wl[:, 1], bl[:, 0], bl[:, 1], t_dec, ws,
                  bits, periph)]
        mux = jnp.asarray(pad(has_mux))
        varrs = [jnp.asarray(a, jnp.float64) for a in
                 (vdd_v, i_cell, i_leak1, t_ret, t_sn, clpb)]
        t_read, t_write, f, leakage, refresh, e_read, e_write = \
            kernel(*varrs, *parrs, mux)
    out = {k: np.asarray(a)[:, :P] for k, a in
           (("t_read", t_read), ("t_write", t_write), ("f", f),
            ("leakage", leakage), ("refresh", refresh),
            ("e_read", e_read), ("e_write", e_write))}
    out.update(swing_ok=swing_ok, t_ret=t_ret,
               area=np.array([b.area_um2 for b in banks], np.float64),
               bits=bits, ws=ws,
               num_words=np.array([c.num_words for c in cfgs], np.float64))
    return out


# ---------------------------------------------------------------------------
# the (vdd x lattice) table — third lattice dimension for co-design
# ---------------------------------------------------------------------------

@dataclass
class VddLattice:
    """Struct-of-arrays metrics over (operating voltage x design lattice).

    All 2-D arrays are shaped (V, P) = (len(vdd_scales), len(cfgs)) and
    row v holds the lattice evaluated at `tech.vdd * vdd_scales[v]`,
    matching `dse.evaluate(cfg, vdd_scale)` bit-for-bit. Units follow
    DesignPoint: Hz, seconds, watts, um^2, bits; `e_read_j`/`e_write_j`
    are dynamic joules PER ACCESS of one word (the CV^2 terms of
    `power.analyze` without the frequency factor)."""
    cfgs: List[BankConfig]
    vdd_scales: Tuple[float, ...]
    f_max_hz: np.ndarray          # (V, P)
    t_read_s: np.ndarray
    t_write_s: np.ndarray
    retention_s: np.ndarray
    swing_ok: np.ndarray          # (V, P) bool
    leakage_w: np.ndarray
    refresh_w: np.ndarray
    e_read_j: np.ndarray
    e_write_j: np.ndarray
    area_um2: np.ndarray          # (P,)
    bits: np.ndarray              # (P,)
    num_words: np.ndarray         # (P,)
    is_gc: np.ndarray             # (P,) bool

    @property
    def shape(self) -> Tuple[int, int]:
        return self.f_max_hz.shape

    @property
    def standby_w(self) -> np.ndarray:
        return self.leakage_w + self.refresh_w

    def point(self, vi: int, pi: int) -> DesignPoint:
        """Materialize one (voltage, config) entry as a DesignPoint."""
        cfg = self.cfgs[pi]
        f, wsz = float(self.f_max_hz[vi, pi]), cfg.word_size
        rbw = wbw = f * wsz if self.is_gc[pi] else f * wsz / 2
        return DesignPoint(
            cfg, float(self.area_um2[pi]), f, rbw, wbw, rbw + wbw,
            float(self.leakage_w[vi, pi]), float(self.refresh_w[vi, pi]),
            float(self.retention_s[vi, pi]), bool(self.swing_ok[vi, pi]),
            float(self.t_read_s[vi, pi]), float(self.t_write_s[vi, pi]),
            float(self.vdd_scales[vi]))


def evaluate_vdd_lattice(cfgs: Sequence[BankConfig],
                         vdd_scales: Sequence[float]) -> VddLattice:
    """Evaluate the whole (vdd_scales x cfgs) grid, one nested-vmap
    program per cell topology; structural arrays are built once and
    shared across the voltage ladder."""
    cfgs = list(cfgs)
    vdd_scales = tuple(float(v) for v in vdd_scales)
    if not vdd_scales:
        raise ValueError("evaluate_vdd_lattice needs >= 1 vdd_scale")
    V, P = len(vdd_scales), len(cfgs)
    z = lambda: np.zeros((V, P), np.float64)
    out = dict(f_max_hz=z(), t_read_s=z(), t_write_s=z(), retention_s=z(),
               swing_ok=np.zeros((V, P), bool), leakage_w=z(),
               refresh_w=z(), e_read_j=z(), e_write_j=z())
    area = np.zeros(P); bits = np.zeros(P); nw = np.zeros(P)
    is_gc = np.zeros(P, bool)
    for idx in group_by_topology(cfgs).values():
        sub = [cfgs[i] for i in idx]
        banks = [build_bank(c) for c in sub]
        a = _eval_group_arrays(sub, banks, vdd_scales)
        cols = np.asarray(idx)
        for dst, src in (("f_max_hz", "f"), ("t_read_s", "t_read"),
                         ("t_write_s", "t_write"), ("leakage_w", "leakage"),
                         ("refresh_w", "refresh"), ("e_read_j", "e_read"),
                         ("e_write_j", "e_write"), ("swing_ok", "swing_ok")):
            out[dst][:, cols] = a[src]
        out["retention_s"][:, cols] = a["t_ret"][:, None]
        area[cols], bits[cols], nw[cols] = a["area"], a["bits"], \
            a["num_words"]
        is_gc[cols] = banks[0].is_gc
    return VddLattice(cfgs, vdd_scales, out["f_max_hz"], out["t_read_s"],
                      out["t_write_s"], out["retention_s"], out["swing_ok"],
                      out["leakage_w"], out["refresh_w"], out["e_read_j"],
                      out["e_write_j"], area, bits, nw, is_gc)


# ---------------------------------------------------------------------------
# vectorized workload matching: (vdd x lattice x demand) in one program
# ---------------------------------------------------------------------------

def feasible_grid(f_max_hz, retention_s, swing_ok, num_words,
                  read_freq_hz, lifetime_s, *,
                  allow_refresh: bool = True) -> np.ndarray:
    """Vectorized `dse.feasible`: lattice metric arrays of any common
    broadcastable shape S (e.g. (P,) or (V, P)) against demand vectors of
    shape (D,) -> boolean mask of shape S + (D,). Same rule, same float64
    comparisons, bit-for-bit with the scalar reference."""
    with enable_x64():
        f = jnp.asarray(f_max_hz, jnp.float64)[..., None]
        ret = jnp.asarray(retention_s, jnp.float64)[..., None]
        ok = jnp.asarray(swing_ok, bool)[..., None]
        nw = jnp.asarray(num_words, jnp.float64)[..., None]
        rf = jnp.asarray(read_freq_hz, jnp.float64)
        lt = jnp.asarray(lifetime_s, jnp.float64)
        meets_f = ok & (f >= rf)
        native = ret >= lt
        if allow_refresh:
            safe = jnp.where(ret > 0.0, ret, 1.0)
            refr = (ret > 0.0) & (nw / safe < 0.1 * f)
            mask = meets_f & (native | refr)
        else:
            mask = meets_f & native
        return np.asarray(mask)


def banks_needed_grid(f_max_hz, retention_s, swing_ok, bits, num_words,
                      read_freq_hz, lifetime_s, capacity_bits=None, *,
                      allow_refresh: bool = True,
                      max_banks: int = 1024) -> np.ndarray:
    """Vectorized `multibank.banks_needed`: smallest interleaved-macro
    bank count per (lattice-entry, demand) covering both the aggregate
    read frequency and the capacity, with `max_banks + 1` as the
    infeasibility sentinel — identical to the scalar reference."""
    with enable_x64():
        f = jnp.asarray(f_max_hz, jnp.float64)[..., None]
        ret = jnp.asarray(retention_s, jnp.float64)[..., None]
        ok = jnp.asarray(swing_ok, bool)[..., None]
        nw = jnp.asarray(num_words, jnp.float64)[..., None]
        bits_ = jnp.asarray(bits, jnp.float64)[..., None]
        rf = jnp.asarray(read_freq_hz, jnp.float64)
        lt = jnp.asarray(lifetime_s, jnp.float64)
        cap = jnp.zeros_like(rf) if capacity_bits is None \
            else jnp.asarray(capacity_bits, jnp.float64)
        alive = ok & (f > 0.0)
        safe_f = jnp.where(f > 0.0, f, 1.0)
        n_freq = jnp.ceil(rf / safe_f)
        n_cap = jnp.where(cap > 0.0, jnp.ceil(cap / bits_), 1.0)
        n = jnp.maximum(1.0, jnp.maximum(n_freq, n_cap))
        # per-bank retention feasibility at the interleaved (clamped)
        # rate: the frequency test passes by construction, so only the
        # native-retention / refresh rule remains
        native = ret >= lt
        if allow_refresh:
            safe_r = jnp.where(ret > 0.0, ret, 1.0)
            perbank = native | ((ret > 0.0) & (nw / safe_r < 0.1 * f))
        else:
            perbank = native
        n = jnp.where(alive & perbank, n, float(max_banks + 1))
        return np.asarray(n).astype(np.int64)


def shmoo_batch(points, demands, *, allow_refresh: bool = True) -> dict:
    """Drop-in replacement for `dse.shmoo` that evaluates the whole
    (points x demands) grid in one device program; same dict layout (and
    same duplicate-key overwrite semantics), python bools."""
    from repro.core.dse import shmoo_key
    mask = feasible_grid(
        np.array([p.f_max_hz for p in points], np.float64),
        np.array([p.retention_s for p in points], np.float64),
        np.array([p.swing_ok for p in points], bool),
        np.array([p.cfg.num_words for p in points], np.float64),
        np.array([d.read_freq_hz for d in demands], np.float64),
        np.array([d.lifetime_s for d in demands], np.float64),
        allow_refresh=allow_refresh)
    grid = {}
    for j, d in enumerate(demands):
        row = {}
        for i, dp in enumerate(points):
            row[shmoo_key(dp.cfg)] = bool(mask[i, j])
        grid[f"{d.level}:{d.name}"] = row
    return grid


def codesign_metrics(lat: VddLattice, demands, step_time_s, *,
                     allow_refresh: bool = True, max_banks: int = 1024):
    """The co-design cube: for every (vdd, config, demand) return

      feas    (V, P, D) bool   — single-bank feasibility (dse.feasible)
      banks   (V, P, D) int    — interleaved-macro size (banks_needed)
      energy  (V, P, D) float  — joules per inference step: dynamic read
              energy for the demanded accesses (read_freq * step_time
              accesses x e_read_j) + the macro's standby (leakage +
              refresh) integrated over the step
      macro_ok (V, P, D) bool  — banks within max_banks AND the per-bank
              retention rule holds

    `demands` is a Demand sequence, `step_time_s` the per-demand
    inference step time (seconds, same length)."""
    rf = np.array([d.read_freq_hz for d in demands], np.float64)
    lt = np.array([d.lifetime_s for d in demands], np.float64)
    cap = np.array([d.capacity_bits for d in demands], np.float64)
    step = np.asarray(step_time_s, np.float64)
    if step.shape != rf.shape:
        raise ValueError(f"step_time_s {step.shape} != demands {rf.shape}")
    feas = feasible_grid(lat.f_max_hz, lat.retention_s, lat.swing_ok,
                         lat.num_words, rf, lt, allow_refresh=allow_refresh)
    banks = banks_needed_grid(lat.f_max_hz, lat.retention_s, lat.swing_ok,
                              lat.bits, lat.num_words, rf, lt, cap,
                              allow_refresh=allow_refresh,
                              max_banks=max_banks)
    macro_ok = banks <= max_banks
    with enable_x64():
        accesses = jnp.asarray(rf * step)                     # (D,)
        e_dyn = accesses * jnp.asarray(lat.e_read_j)[..., None]
        standby = jnp.asarray(lat.standby_w)[..., None]
        energy = e_dyn + jnp.asarray(banks, jnp.float64) * standby \
            * jnp.asarray(step)
        energy = np.asarray(energy)
    return feas, banks, energy, macro_ok
