"""Multibank GCRAM macro generation (paper §VI future work + the Fig 10
discussion: "Analogous to how NVIDIA GPUs organize the L2 SRAM cache, we
can employ a multi-banked GCRAM design to accommodate multiple parallel
read and write requests").

A MultiBank composes N identical banks behind an address-interleaved
crossbar: capacity and bandwidth scale ~N, frequency stays the bank's,
area adds a routing/arbiter overhead per bank.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass

from repro.core import dse
from repro.core.bank import BankConfig

XBAR_OVERHEAD = 0.06     # crossbar/arbiter area per bank (fraction)
XBAR_DELAY_S = 35e-12    # one crossbar hop on the read path


@dataclass
class MultiBankPoint:
    """Composed macro metrics. Units follow DesignPoint: `area_um2`
    um^2, `f_max_hz` Hz, `eff_bw_bps` bits/s, powers watts,
    `retention_s` seconds (per bank — banking does not change it)."""
    n_banks: int
    bank: dse.DesignPoint
    area_um2: float
    f_max_hz: float
    eff_bw_bps: float
    capacity_bits: int
    leakage_w: float
    refresh_w: float
    retention_s: float

    def as_dict(self):
        d = {"n_banks": self.n_banks, **self.bank.as_dict()}
        d.update({"macro_area_um2": self.area_um2,
                  "macro_f_max_hz": self.f_max_hz,
                  "macro_eff_bw_bps": self.eff_bw_bps,
                  "macro_capacity_bits": self.capacity_bits})
        return d


def compose_multibank(dp: dse.DesignPoint, n_banks: int) -> MultiBankPoint:
    """Compose an N-bank interleaved macro around an already-evaluated
    bank (the core implementation; repro.api.Session.multibank caches
    the bank evaluation and calls this)."""
    if dp.t_read_s <= 0 or dp.t_write_s <= 0:
        raise ValueError(
            "compose_multibank needs a DesignPoint with t_read_s/t_write_s "
            "(from dse.evaluate or the batched evaluator); got "
            f"t_read_s={dp.t_read_s}, t_write_s={dp.t_write_s}")
    # crossbar hop slows the read path by one stage-quantized hop
    t_read = dp.t_read_s + XBAR_DELAY_S
    f = 1.0 / max(t_read, dp.t_write_s)
    area = n_banks * dp.area_um2 * (1.0 + XBAR_OVERHEAD)
    return MultiBankPoint(
        n_banks=n_banks, bank=dp, area_um2=area, f_max_hz=f,
        eff_bw_bps=n_banks * dp.eff_bw_bps * (f / dp.f_max_hz),
        capacity_bits=n_banks * dp.cfg.bits,
        leakage_w=n_banks * dp.leakage_w,
        refresh_w=n_banks * dp.refresh_w,
        retention_s=dp.retention_s)


def build_multibank(cfg: BankConfig, n_banks: int) -> MultiBankPoint:
    """DEPRECATED: use repro.api.Session().multibank(cfg, n_banks)."""
    warnings.warn(
        "build_multibank() is deprecated; use repro.api.Session()"
        ".multibank(cfg, n_banks)", DeprecationWarning, stacklevel=2)
    from repro.api import Session
    return Session(cfg.tech).multibank(cfg, n_banks)


def banks_needed(dp: dse.DesignPoint, demand: dse.Demand,
                 capacity_bits: int = 0, max_banks: int = 1024, *,
                 allow_refresh: bool = True) -> int:
    """Smallest bank count whose interleaved macro meets the demand's
    per-bank read frequency is 1 by construction (interleaving divides the
    request stream); what multibanking buys is AGGREGATE frequency and
    capacity — return the count needed so that n * f_bank >= n_requests
    AND n * bits >= capacity.

    Units: `demand.read_freq_hz` Hz, `capacity_bits` bits. Returns
    `max_banks + 1` as the infeasibility sentinel (per-bank retention/
    refresh rule fails, swing fails, or f_max <= 0) — see `dse.feasible`
    for the exact refresh rule. Scalar reference for
    `repro.core.dse_batch.banks_needed_grid`."""
    if not dp.swing_ok or dp.f_max_hz <= 0:
        return max_banks + 1
    n_freq = math.ceil(demand.read_freq_hz / dp.f_max_hz)
    n_cap = math.ceil(capacity_bits / dp.cfg.bits) if capacity_bits else 1
    n = max(1, n_freq, n_cap)
    # retention/refresh feasibility is per bank (unchanged by banking)
    if not dse.feasible(dp, dse.Demand(demand.name, demand.level,
                                       min(demand.read_freq_hz, dp.f_max_hz),
                                       demand.lifetime_s),
                        allow_refresh=allow_refresh):
        return max_banks + 1
    return n
