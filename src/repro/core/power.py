"""Power: leakage (paper Fig 7c) and dynamic (CV^2 f) per bank.

Leakage — the paper's C7 claim: a gain cell has NO static VDD->GND path,
so GCRAM bank leakage is peripheral-only + the (negligible) SN/RBL
subthreshold components, while SRAM leakage scales with the bit count.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core import bank as bank_mod
from repro.core.cells import Sram6T
from repro.core.spice import devices as dv
from repro.core.techfile import TechFile

# peripheral leakage per um2 of module area (decoder/driver/SA transistors)
PERIPH_LEAK_W_PER_UM2 = 1.5e-9
ACTIVITY = 0.5


@dataclass
class Power:
    """All fields in watts (at the analyzed frequency/operating point)."""
    leakage_w: float
    cell_leakage_w: float          # the Fig 7c array comparison
    periph_leakage_w: float
    refresh_w: float               # GC-only standby cost (bits*E_wr/t_ret)
    dynamic_read_w_at_fmax: float
    dynamic_write_w_at_fmax: float

    def as_dict(self):
        return self.__dict__.copy()


def analyze(bank, f_hz: float, *, t_ret_s: float = None,
            vdd_scale: float = 1.0) -> Power:
    from repro.core.timing import bank_at_vdd
    bank = bank_at_vdd(bank, vdd_scale)
    tech = bank.cfg.tech
    n_bits = bank.cfg.bits
    # GC cells: no VDD->GND path (WBL parks low; SN leak is the retention
    # current, pA-scale) -> cell_leak == 0; SRAM: three-path per cell.
    cell_leak = n_bits * bank.cell.cell_leakage(tech)
    periph_area = sum(bank.modules.values())
    periph_leak = periph_area * PERIPH_LEAK_W_PER_UM2
    leakage = cell_leak + periph_leak

    vdd = tech.vdd
    r_wl, c_wl = bank_mod.wordline_rc(bank)
    r_bl, c_bl = bank_mod.bitline_rc(bank)
    # read: one WL + word_size BLs swing (full for precharge, sense swing
    # for the SA-limited single-ended read), SA + DFF + clk tree
    bl_swing = tech.v_sense_se * 3 if bank.is_gc else vdd * 0.5
    e_read = (c_wl * vdd ** 2
              + bank.cfg.word_size * c_bl * vdd * bl_swing
              + bank.cfg.word_size * 8e-15 * vdd ** 2)
    e_write = (c_wl * vdd ** 2
               + bank.cfg.word_size * c_bl * vdd ** 2
               + bank.cfg.word_size * 6e-15 * vdd ** 2)
    if bank.cfg.wwlls:
        e_write *= 1.25  # boosted WWL swing
    refresh = 0.0
    if bank.is_gc and t_ret_s and t_ret_s > 0:
        e_write_bit = e_write / max(bank.cfg.word_size, 1)
        refresh = n_bits * e_write_bit / t_ret_s
    return Power(leakage, cell_leak, periph_leak, refresh,
                 e_read * f_hz * ACTIVITY, e_write * f_hz * ACTIVITY)
