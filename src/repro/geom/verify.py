"""Geometry verification: rule checking + LVS-lite connectivity.

`check_rules` sweeps the generated rectangles against the RuleDeck —
width, spacing (different-net), shorts (different-net overlap), via
enclosure, block-level no-overlap and bank-bounds — vectorized per
layer over struct-of-arrays coordinate columns. The router targets the
same deck, so a clean result guards REFACTORS (a placer or router
change that pinches a pitch fails here, not in silicon-land fiction).

`lvs_read_column` is the connectivity check the paper's LVS step plays:
it re-derives the read-column netlist from GEOMETRY FACTS (the routed
rbl net + its via stack, the placed precharge/predischarge and sense-amp
instances, the read wordline) plus the cell library's device flavors,
then proves it isomorphic to `timing.read_netlist`'s MNA circuit by
Weisfeiler-Lehman color refinement over the union element/node graph —
element types, port roles (g/a/b vs resistor terminals) and source wave
bindings are the initial colors, so a swapped terminal, a missing
ladder segment or a precharge-vs-predischarge mixup all refine apart.

`verify_bank` is the one-call report the `fidelity="layout"` executor
node runs: place + route + DRC + LVS + extract, including the
batched-vs-scalar extraction bit-identity assertion.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.geom import extract as ex
from repro.geom.grid import WIRE_LAYERS, Rect, rects_to_soa
from repro.geom.placer import BankGeometry, place_bank
from repro.geom.router import route_bank

EPS = 1e-6          # float slop on exact-by-construction dimensions
_MAX_REPORT = 20    # violations listed per check before truncating
_CHUNK = 512        # pairwise sweep row-block size


def _pairwise_layer(out: List[str], layer: str, rs: List[Rect],
                    space: float) -> None:
    """Different-net spacing + short sweep over one layer, blocked so the
    (n, n) separation matrix never materializes whole."""
    n = len(rs)
    if n < 2:
        return
    soa = rects_to_soa(rs)
    x0, y0, x1, y1 = soa["x0"], soa["y0"], soa["x1"], soa["y1"]
    nets = soa["net"]
    reported = 0
    for i0 in range(0, n, _CHUNK):
        i1 = min(i0 + _CHUNK, n)
        gx = np.maximum(x0[i0:i1, None] - x1[None, :],
                        x0[None, :] - x1[i0:i1, None])
        gy = np.maximum(y0[i0:i1, None] - y1[None, :],
                        y0[None, :] - y1[i0:i1, None])
        sep = np.maximum(gx, gy)
        diff = nets[i0:i1, None] != nets[None, :]
        upper = np.arange(n)[None, :] > np.arange(i0, i1)[:, None]
        bad = diff & upper & (sep < space - EPS)
        for bi, bj in zip(*np.nonzero(bad)):
            if reported >= _MAX_REPORT:
                out.append(f"{layer}: ... more spacing violations elided")
                return
            i, j = i0 + int(bi), int(bj)
            kind = "short" if sep[bi, bj] < -EPS else "spacing"
            out.append(
                f"{layer} {kind}: {nets[i] or rs[i].name!r} vs "
                f"{nets[j] or rs[j].name!r} sep={sep[bi, bj]:.0f}nm "
                f"< {space:.0f}nm")
            reported += 1


def check_rules(g: BankGeometry) -> List[str]:
    """All rule violations of one placed+routed bank ([] == clean)."""
    out: List[str] = []
    deck = g.deck
    bw, bh = g.bank_w, g.bank_h

    by_layer: Dict[str, List[Rect]] = defaultdict(list)
    for r in g.wires:
        by_layer[r.layer].append(r)

    for layer in WIRE_LAYERS:
        rs = by_layer.get(layer)
        if not rs:
            continue
        soa = rects_to_soa(rs)
        w = soa["x1"] - soa["x0"]
        h = soa["y1"] - soa["y0"]
        mn = np.minimum(w, h)
        for i in np.nonzero(mn < deck.min_width[layer] - EPS)[0][:_MAX_REPORT]:
            out.append(f"{layer} width: {rs[i].net or rs[i].name!r} "
                       f"{mn[i]:.0f}nm < {deck.min_width[layer]:.0f}nm")
        oob = ((soa["x0"] < -EPS) | (soa["y0"] < -EPS)
               | (soa["x1"] > bw + EPS) | (soa["y1"] > bh + EPS))
        for i in np.nonzero(oob)[0][:_MAX_REPORT]:
            out.append(f"{layer} out of bank: {rs[i].net or rs[i].name!r}")
        _pairwise_layer(out, layer, rs, deck.min_space[layer])

    # via cuts enclosed by same-net metal on both joined layers
    pads: Dict[Tuple[str, str], List[Rect]] = defaultdict(list)
    for r in g.wires:
        pads[(r.layer, r.net)].append(r)
    inset = deck.via_enclosure - EPS
    for via in g.vias:
        cut = via.rect
        for side in (via.lo, via.hi):
            if not any(r.contains(cut, inset)
                       for r in pads.get((side, cut.net), ())):
                out.append(f"via enclosure: {cut.name!r} not enclosed "
                           f"on {side}")
                if sum(v.startswith("via enclosure") for v in out) \
                        > _MAX_REPORT:
                    break

    # block-level: top-level "place" blocks and leaf "mod" rects must not
    # overlap within their own layer ("array" is a separate layer so a
    # BEOL array may stack over the packed periphery); ring frames of
    # DIFFERENT nets must not touch (same-net corner overlaps merge)
    place = [b for b in g.blocks if b.layer == "place"]
    for i, a in enumerate(place):
        for b in place[i + 1:]:
            if a.overlaps(b):
                out.append(f"place overlap: {a.name!r} vs {b.name!r}")
    rings = [b for b in g.blocks if b.layer == "ring"]
    for i, a in enumerate(rings):
        for b in rings[i + 1:]:
            if a.net != b.net and a.overlaps(b):
                out.append(f"ring short: {a.name!r} vs {b.name!r}")
    mods = [b for b in g.blocks if b.layer == "mod"]
    if len(mods) > 1:
        soa = rects_to_soa(mods)
        x0, y0, x1, y1 = soa["x0"], soa["y0"], soa["x1"], soa["y1"]
        reported = 0
        for i0 in range(0, len(mods), _CHUNK):
            i1 = min(i0 + _CHUNK, len(mods))
            ox = (x0[i0:i1, None] < x1[None, :] - EPS) & \
                 (x0[None, :] < x1[i0:i1, None] - EPS)
            oy = (y0[i0:i1, None] < y1[None, :] - EPS) & \
                 (y0[None, :] < y1[i0:i1, None] - EPS)
            upper = np.arange(len(mods))[None, :] > \
                np.arange(i0, i1)[:, None]
            for bi, bj in zip(*np.nonzero(ox & oy & upper)):
                if reported >= _MAX_REPORT:
                    out.append("mod: ... more overlaps elided")
                    return out
                out.append(f"mod overlap: {mods[i0 + int(bi)].name!r} vs "
                           f"{mods[int(bj)].name!r}")
                reported += 1
    return out


# ---------------------------------------------------------------------------
# LVS-lite: extracted netlist vs the MNA read-column circuit
# ---------------------------------------------------------------------------

def _circuit_graph(ckt):
    """(initial colors, adjacency) of the element/node multigraph."""
    colors: List[tuple] = [("gnd",) if i == 0 else ("node",)
                           for i in range(len(ckt.names))]
    adj: List[List[tuple]] = [[] for _ in colors]

    def elem(color, ports):
        vid = len(colors)
        colors.append(color)
        adj.append([])
        for lbl, nd in ports:
            adj[vid].append((lbl, nd))
            adj[nd].append((lbl, vid))

    for a, b, _gv in ckt.res:
        elem(("r",), [("t", a), ("t", b)])
    for a, b, _cv in ckt.caps:
        elem(("c",), [("t", a), ("t", b)])
    for d in ckt.devs:
        elem(("dev", d["pol"]),
             [("g", d["g"]), ("a", d["a"]), ("b", d["b"])])
    for nd, wave in ckt.vsrcs:
        elem(("v", int(wave)), [("p", nd)])
    return colors, adj


def _wl_isomorphic(ckt_a, ckt_b) -> bool:
    """Weisfeiler-Lehman color refinement over the DISJOINT UNION of both
    circuit graphs (shared interning arena, so colors are comparable);
    isomorphic-for-our-purposes iff the final color multisets match."""
    ca, aa = _circuit_graph(ckt_a)
    cb, ab = _circuit_graph(ckt_b)
    off = len(ca)
    colors = ca + cb
    adj = [list(e) for e in aa] + \
          [[(lbl, u + off) for lbl, u in e] for e in ab]
    intern: Dict[tuple, int] = {}
    cur = [intern.setdefault(c, len(intern)) for c in colors]
    n_colors = len(intern)
    for _ in range(len(cur)):
        intern = {}
        cur = [intern.setdefault(
            (cur[v], tuple(sorted((lbl, cur[u]) for lbl, u in adj[v]))),
            len(intern)) for v in range(len(cur))]
        if len(intern) == n_colors:
            break
        n_colors = len(intern)
    return sorted(cur[:off]) == sorted(cur[off:])


def lvs_read_column(g: BankGeometry,
                    n_seg: int = 8) -> Tuple[bool, str]:
    """Extract the read-column netlist from geometry facts and prove it
    isomorphic to `timing.read_netlist`. Gain-cell banks only."""
    from repro.core import timing as timing_mod
    from repro.core.spice.mna import Circuit

    bank = g.bank
    if not bank.is_gc:
        raise ValueError("no single-ended read column to LVS "
                         f"(cell {bank.cfg.cell!r})")
    tech, cell = bank.cfg.tech, bank.cell
    problems = []
    rbl = g.nets.get("rbl_0")
    if rbl is None:
        return False, "no routed rbl_0 net"
    if rbl.n_vias != ex.N_BL_VIAS_GC:
        problems.append(f"rbl_0 via stack has {rbl.n_vias} cuts, "
                        f"expected {ex.N_BL_VIAS_GC}")
    if g.nets.get("rwl_0") is None:
        problems.append("no routed rwl_0 net")

    pre_mods = [b for b in g.blocks if b.layer == "mod" and
                b.name.startswith(("precharge", "predischarge"))]
    if not pre_mods:
        return False, "no placed precharge/predischarge instance"
    pre_high = pre_mods[0].name.startswith("precharge")
    if not any(b.layer == "mod" and b.name.startswith(("sa_", "sense_amp"))
               for b in g.blocks):
        problems.append("no placed sense amp")
    # geometric port binding: the column-0 bitline must run through the
    # x-span of a precharge instance (packed banks stack over the full
    # periphery slab instead)
    x_bl = g.col_x(0)
    if not g.packed and not any(b.x0 - EPS <= x_bl <= b.x1 + EPS
                                for b in pre_mods):
        problems.append("rbl_0 misses every precharge instance x-span")

    rc = ex.extract_point(g)
    ckt = Circuit()
    ckt.vsrc("rwl", 0)
    ckt.vsrc("pre_en", 1)
    if pre_high:
        ckt.vsrc("vdd", 3)
        ckt.dev(tech.flavor("pmos_svt"), 1.2, 0.04, "pre_en", "vdd",
                "rbl_0", name="precharge")
    else:
        ckt.dev(tech.flavor("nmos_svt"), 1.2, 0.04, "pre_en", "rbl_0",
                "0", name="predischarge")
    for i in range(n_seg):
        ckt.r(f"rbl_{i}", f"rbl_{i+1}", rc["bl_r_ohm"] / n_seg)
        ckt.c(f"rbl_{i+1}", "0", rc["bl_c_f"] / n_seg)
    ckt.c("rbl_0", "0", timing_mod.SA_INPUT_C_F)
    ckt.vsrc("sn", 2)
    ckt.dev(cell.rf(tech), cell.w_read, cell.l_read, "sn",
            f"rbl_{n_seg}", "rwl", name="read_dev")

    ref, _ = timing_mod.read_netlist(bank, n_seg=n_seg)
    if not _wl_isomorphic(ckt, ref):
        problems.append("extracted netlist not isomorphic to MNA circuit")
    return (not problems), ("; ".join(problems) or "ok")


def verify_bank(bank_or_cfg, n_seg: int = 8) -> dict:
    """Place + route + DRC + LVS-lite + extraction bit-parity for one
    bank; the JSON-able report the layout-tier executor node persists."""
    from repro.core.bank import BankConfig, build_bank
    bank = build_bank(bank_or_cfg) \
        if isinstance(bank_or_cfg, BankConfig) else bank_or_cfg
    g = route_bank(place_bank(bank))
    drc = check_rules(g)
    point = ex.extract_point(g)
    lat = ex.extract_lattice([bank], deck=g.deck)
    bit_identical = all(point[k] == float(lat[k][0]) for k in point)
    if bank.is_gc:
        lvs_ok, lvs_msg = lvs_read_column(g, n_seg=n_seg)
    else:
        lvs_ok, lvs_msg = True, "skipped: differential column (SRAM)"
    return {
        "cell": bank.cfg.cell, "word_size": bank.cfg.word_size,
        "num_words": bank.cfg.num_words, "rows": bank.rows,
        "cols": bank.cols, "packed": g.packed,
        "bank_w_nm": int(round(g.bank_w)),
        "bank_h_nm": int(round(g.bank_h)),
        "n_blocks": len(g.blocks), "n_wires": len(g.wires),
        "n_vias": len(g.vias),
        "drc_clean": not drc, "drc_violations": drc,
        "lvs_ok": lvs_ok, "lvs_msg": lvs_msg,
        "extract": point, "extract_bit_identical": bool(bit_identical),
    }
