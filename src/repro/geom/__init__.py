"""Generated bank geometry: the layout-fidelity tier.

`core.layout` answers "how big is the bank" analytically; this package
generates the geometry itself — track-grid rectangles placed
hierarchically (`placer`), ladder-routed wordlines/bitlines/buses
(`router`), checked against a width/spacing/enclosure rule deck plus an
LVS-lite connectivity pass (`verify`), and batched parasitic extraction
of per-segment wire R/C from the routed lengths (`extract`) that feeds
the transient characterization engine in place of the hand-modeled
bitline ladders (`SweepQuery(fidelity="layout")`).

Everything is host-side numpy over struct-of-arrays rectangle sets;
module footprints come from the same `layout.MODULE_GEOM` deck the
analytic floorplan uses, so the generated bank bounding box reproduces
`layout.floorplan` exactly (asserted in tests).
"""
from repro.geom.grid import Rect, RuleDeck, Via
from repro.geom.placer import BankGeometry, place_bank
from repro.geom.router import route_bank
from repro.geom.extract import (extract_lattice, extract_point,
                                read_column_segments)
from repro.geom.verify import check_rules, lvs_read_column, verify_bank

__all__ = ["Rect", "RuleDeck", "Via", "BankGeometry", "place_bank",
           "route_bank", "extract_lattice", "extract_point",
           "read_column_segments", "check_rules", "lvs_read_column",
           "verify_bank"]
