"""Hierarchical bank placement: `layout.floorplan` strips -> rectangles.

`place_bank(bank)` consumes the SAME floorplan the analytic model emits
(`bank.plan.modules`, in um) for the top-level blocks, so the generated
bank bounding box reproduces `layout.floorplan` exactly, then fills each
strip with leaf module rectangles:

  left strip    per-row write decoder + WL driver (+ WWL level shifter)
  right strip   per-row read decoder + WL driver          (GC dual port)
  top strip     per-column precharge/predischarge (+ read colmux), then
                per-data-bit sense amps and output DFFs, stacked inward
                -> outward
  bottom strip  per-data-bit write drivers (+ write colmux), input DFFs
  corner strip  control FSMs + reference generator + address DFFs (the
                width `floorplan` folds into core_w)
  rings         n_rings supply-pair frames on the dedicated "ring" layer

Leaf footprints come from `layout.MODULE_GEOM`; a module wider than its
row/column pitch is folded AREA-PRESERVING to the pitch (w = pitch,
h = area / w) — the pitch-matching every real compiler does.

Layers: "place" top-level blocks, "mod" leaves, "array" the bitcell
array (its own layer so a BEOL array may legally stack over the packed
periphery), "ring" the power frames. Wires/vias are added by
`repro.geom.router`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core import layout
from repro.core.bank import Bank
from repro.geom.grid import Rect, RuleDeck, Via

NM_PER_UM = 1000.0
RAIL_ROWS_PER = 16       # must match layout.floorplan's rail_rows_per


def _mod_wh(tech, kind: str):
    pp, tr = layout.MODULE_GEOM[kind]
    return pp * tech.cpp, tr * tech.track


@dataclass
class BankGeometry:
    """One placed (and, after `route_bank`, routed) bank."""
    bank: Bank
    deck: RuleDeck
    packed: bool
    blocks: List[Rect] = field(default_factory=list)
    wires: List[Rect] = field(default_factory=list)
    vias: List[Via] = field(default_factory=list)
    nets: Dict[str, object] = field(default_factory=dict)  # router.Net
    # array frame in nm (origin = bank lower-left corner, y up)
    ax0: float = 0.0
    ay0: float = 0.0
    aw: float = 0.0
    ah: float = 0.0
    cw: float = 0.0
    ch: float = 0.0

    @property
    def bank_w(self) -> float:
        return self.bank.plan.bank_w_um * NM_PER_UM

    @property
    def bank_h(self) -> float:
        return self.bank.plan.bank_h_um * NM_PER_UM

    def block(self, name: str) -> Optional[Rect]:
        for r in self.blocks:
            if r.name == name:
                return r
        return None

    def row_y(self, r: int) -> float:
        """Bottom edge of cell row r (rail rows every RAIL_ROWS_PER)."""
        track = self.bank.cfg.tech.track
        return self.ay0 + (r // RAIL_ROWS_PER + 1) * 2 * track + r * self.ch

    def col_x(self, c: int) -> float:
        """Center x of cell column c."""
        return self.ax0 + (c + 0.5) * self.cw

    def manifest(self) -> dict:
        """Compact JSON-able record (int nm) — the golden-file surface:
        top-level block bboxes, ring count, per-layer wire stats, via
        count, and the place-layer no-overlap invariant."""
        place = [b for b in self.blocks if b.layer == "place"]
        top = {b.name: [int(round(v)) for v in
                        (b.x0, b.y0, b.x1, b.y1)] for b in place}
        arr = self.block("bitcell_array")
        if arr is not None:
            top[arr.name] = [int(round(v)) for v in
                             (arr.x0, arr.y0, arr.x1, arr.y1)]
        overlap = any(a.overlaps(b) for i, a in enumerate(place)
                      for b in place[i + 1:])
        layers: Dict[str, dict] = {}
        for w in self.wires:
            d = layers.setdefault(w.layer, {"n": 0, "length_nm": 0})
            d["n"] += 1
            d["length_nm"] += int(round(max(w.w, w.h)))
        return {
            "bank_w_nm": int(round(self.bank_w)),
            "bank_h_nm": int(round(self.bank_h)),
            "rows": self.bank.rows, "cols": self.bank.cols,
            "packed": self.packed,
            "blocks": dict(sorted(top.items())),
            "n_mod_blocks": sum(b.layer == "mod" for b in self.blocks),
            "n_rings": sum(b.layer == "ring" and b.name.endswith(":S")
                           for b in self.blocks) // 2,
            "wires": dict(sorted(layers.items())),
            "n_vias": len(self.vias),
            "no_overlap": not overlap,
        }


def _ring_frames(g: BankGeometry, n_rings: int, wwlls: bool) -> None:
    """Per ring: two concentric supply frames (a vdd/vss pair), each
    40% of RING_W_NM wide, 10% gap — four rects per frame, overlapping
    at the corners (same net, so the checker merges them)."""
    W = layout.RING_W_NM
    bw, bh = g.bank_w, g.bank_h
    for k in range(n_rings):
        nets = ("vdd", "vss") if k == 0 else ("vddh", "vssh")
        for j, net in enumerate(nets):
            off = k * W + (0.05 + 0.55 * j) * W
            t = 0.4 * W
            frame = (("S", off, off, bw - off, off + t),
                     ("N", off, bh - off - t, bw - off, bh - off),
                     ("W", off, off, off + t, bh - off),
                     ("E", bw - off - t, off, bw - off, bh - off))
            for side, x0, y0, x1, y1 in frame:
                g.blocks.append(Rect("ring", x0, y0, x1, y1, net=net,
                                     name=f"ring{k}:{net}:{side}"))


def _fold(native_w: float, native_h: float, pitch: float):
    """Pitch-match: fold a module wider than `pitch` area-preserving."""
    if native_w <= pitch:
        return native_w, native_h
    return pitch, native_w * native_h / pitch


def _col_row(g: BankGeometry, kind: str, y: float, pitch: float,
             n: int, x_of, tag: str) -> float:
    """One row of n pitch-matched module instances; returns row height."""
    tech = g.bank.cfg.tech
    w, h = _fold(*_mod_wh(tech, kind), pitch)
    for i in range(n):
        xc = x_of(i)
        g.blocks.append(Rect("mod", xc - w / 2, y, xc + w / 2, y + h,
                             name=f"{tag}_{i}"))
    return h


def _stack(g: BankGeometry, specs, x0: float, x1: float, y: float,
           up: bool = True) -> None:
    """Stack full-width slabs (name, area_nm2) from y, growing up/down."""
    w = x1 - x0
    for name, area in specs:
        if area <= 0 or w <= 0:
            continue
        h = area / w
        y0, y1 = (y, y + h) if up else (y - h, y)
        g.blocks.append(Rect("mod", x0, y0, x1, y1, name=name))
        y = y1 if up else y0


def _place_standard(g: BankGeometry) -> None:
    bank, tech = g.bank, g.bank.cfg.tech
    m = layout.BLOCK_MARGIN_NM
    left = g.block("left_port_address")
    right = g.block("right_port_address")
    top = g.block("top_port_data")
    bot = g.block("bottom_port_data")

    # -- side strips: per-row decoder/driver chain, driver at the inner
    # edge (it abuts the wordline it drives), decoder outboard
    def side(strip, inner_right: bool, kinds, tag):
        if strip is None or strip.w <= 0:
            return
        for r in range(bank.rows):
            y = g.row_y(r)
            x = strip.x1 if inner_right else strip.x0
            for kind in kinds:
                w, h = _fold(*_mod_wh(tech, kind), strip.w)
                h = min(h, g.ch)
                x0, x1 = (x - w, x) if inner_right else (x, x + w)
                g.blocks.append(Rect("mod", x0, y, x1, y + h,
                                     name=f"{tag}_{kind}_{r}"))
                x = x0 if inner_right else x1

    lkinds = ["wl_driver", "decoder_unit"]
    if bank.is_gc and bank.cfg.wwlls:
        lkinds = ["wwl_ls"] + lkinds
    side(left, True, lkinds, "w" if bank.is_gc else "rw")
    if bank.is_gc:
        side(right, False, ["wl_driver", "decoder_unit"], "r")

    # -- top strip: precharge row (per column), optional colmux, sense
    # amps + out DFFs (per data bit), stacked inner -> outer
    pre = "predischarge" if bank.is_gc and bank.cell.predischarge \
        else "precharge"
    sa = "sense_amp_se" if bank.is_gc else "sense_amp"
    bit_pitch = bank.words_per_row * g.cw
    bit_x = lambda i: g.col_x(i * bank.words_per_row)
    if top is not None and top.w > 0:
        y = top.y0
        y += _col_row(g, pre, y, g.cw, bank.cols, g.col_x, pre)
        if bank.has_colmux:
            y += _col_row(g, "colmux_unit", y, g.cw, bank.cols,
                          g.col_x, "r_colmux")
        y += _col_row(g, sa, y, bit_pitch, bank.cfg.word_size, bit_x, "sa")
        _col_row(g, "dff", y, bit_pitch, bank.cfg.word_size, bit_x,
                 "out_dff")

    # -- bottom strip: write drivers (+ write colmux), in DFFs, stacked
    # inner (top edge) -> outer (downward)
    wd = "write_driver" if bank.is_gc else "write_driver_diff"
    if bot is not None and bot.w > 0:
        y = bot.y1
        w, h = _fold(*_mod_wh(tech, wd), bit_pitch)
        y -= _col_row(g, wd, y - h, bit_pitch, bank.cfg.word_size,
                      bit_x, "wd")
        if bank.is_gc and bank.has_colmux:
            w, h = _fold(*_mod_wh(tech, "colmux_unit"), g.cw)
            y -= _col_row(g, "colmux_unit", y - h, g.cw, bank.cols,
                          g.col_x, "w_colmux")
        w, h = _fold(*_mod_wh(tech, "dff"), bit_pitch)
        _col_row(g, "dff", y - h, bit_pitch, bank.cfg.word_size, bit_x,
                 "in_dff")

    # -- corner strip: floorplan folds its width into core_w to the
    # right of the right strip; reconstruct it and stack control there
    rref = right if right is not None and right.w > 0 else \
        g.block("bitcell_array")
    cx0 = rref.x1 + (m if rref.name == "bitcell_array" else 0.0)
    ring_band = bot.y0 if bot is not None else \
        (left.x0 if left is not None else 0.0)
    cx1 = g.bank_w - ring_band
    if cx1 - cx0 > 1.0:
        y0, y1 = ring_band, g.bank_h - ring_band
        g.blocks.append(Rect("place", cx0, y0, cx1, y1,
                             name="ctrl_corner"))
        um2 = 1.0 / layout.UM2_PER_NM2
        specs = [("ctrl", bank.modules.get("ctrl", 0.0) * um2),
                 ("addr_dff", bank.modules.get("addr_dff", 0.0) * um2)]
        if bank.is_gc:
            specs.insert(0, ("refgen", bank.modules["refgen"] * um2))
        _stack(g, specs, cx0, cx1, y0, up=True)


def _place_packed(g: BankGeometry) -> None:
    """BEOL (OS-OS) floorplan: periphery slabs under the stacked array
    — per-layer no-overlap holds because the array is its own layer."""
    per = g.block("periphery(under array)")
    if per is None:
        return
    um2 = 1.0 / layout.UM2_PER_NM2
    specs = [(k, a * um2) for k, a in sorted(g.bank.modules.items())]
    _stack(g, specs, per.x0, per.x1, per.y0, up=True)


def place_bank(bank: Bank, deck: Optional[RuleDeck] = None
               ) -> BankGeometry:
    """Generate the placed geometry of one bank (no wires yet — see
    `router.route_bank`)."""
    tech = bank.cfg.tech
    deck = deck or RuleDeck.from_tech(tech)
    packed = bank.is_gc and getattr(bank.cell, "is_beol", False)
    g = BankGeometry(bank, deck, packed)
    cw, ch = layout.cell_wh_nm(tech, bank.cell.geom_key)
    g.cw, g.ch = cw, ch

    n_rings = 0
    for mod in bank.plan.modules:
        x0 = mod["x"] * NM_PER_UM
        y0 = mod["y"] * NM_PER_UM
        x1 = x0 + mod["w"] * NM_PER_UM
        y1 = y0 + mod["h"] * NM_PER_UM
        name = mod["name"]
        if name == "power_rings":
            n_rings = mod["rings"]
            g.blocks.append(Rect("outline", x0, y0, x1, y1, name=name))
            continue
        layer = "array" if name.startswith("bitcell_array") else "place"
        if name.startswith("bitcell_array"):
            g.ax0, g.ay0 = x0, y0
            g.aw, g.ah = x1 - x0, y1 - y0
            name = "bitcell_array"
        if x1 - x0 > 0 and y1 - y0 > 0:
            g.blocks.append(Rect(layer, x0, y0, x1, y1, name=name))

    _ring_frames(g, n_rings, bank.cfg.wwlls)
    if packed:
        _place_packed(g)
    else:
        _place_standard(g)
    return g
