"""Batched parasitic extraction: routed lengths -> RC ladders.

Two entry points, ONE arithmetic kernel:

  `extract_point(geom)`    scalar reference — reads the designed segment
                           lengths recorded on the ROUTED nets of one
                           `BankGeometry` (rbl_0 / wl_0 and the read
                           wordline) and runs the kernel on Python
                           floats;
  `extract_lattice(banks)` batched — recomputes the same designed
                           lengths closed-form (no geometry is built)
                           as struct-of-arrays numpy columns over the
                           whole design lattice and runs the SAME
                           kernel elementwise.

Both paths execute the identical sequence of IEEE-double operations, so
they are BIT-identical — asserted per config by `verify.verify_bank`
and `tools/check_geom.py`. That is the contract that lets the query
planner extract thousands of points without placing a single rectangle
while the per-point geometry path stays the auditable reference.

What is charged to the read column (vs the hand model in
`core.bank.bitline_rc`): the extracted bitline includes the rail-row
overhead of the placed array column (`layout.floorplan` inserts a
supply rail every 16 rows), the jog into the sense strip, and the
R/C of the via stack down to the SA input — the hand model stops at
`rows * cell_height`. The gap (a few percent, reported in
`results/bench_layout.json`) is exactly the fidelity the layout tier
adds. The write path (WWL/WBL) stays hand-modeled — see docs/layout.md.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import layout
from repro.core.bank import Bank
from repro.core.cells import Sram6T
from repro.geom.grid import RuleDeck

RAIL_ROWS_PER = 16       # must match layout.floorplan's rail insertion
VIA_TIP_NM = 600.0       # packed (BEOL) bitline tip past the array edge:
#                          room for the via stack + the parity stagger
#                          that keeps landing pads DRC-clean at tight
#                          column pitches (see router._via_stack sites)
N_BL_VIAS_GC = 2         # m3 -> m1 stack at the SA end
N_BL_VIAS_SRAM = 4       # two m3 -> m1 stacks (SA end + write-driver end)


def is_packed(bank: Bank) -> bool:
    """BEOL (OS-OS) banks stack the array over the periphery."""
    return bank.is_gc and getattr(bank.cell, "is_beol", False)


def strip_nm(bank: Bank, name: str, dim: str) -> float:
    """Depth of a floorplan strip in nm, from the PLAN (um * 1000) — not
    from placed rect coordinate differences, so the router's net records
    and the closed-form lattice see the same float."""
    for mod in bank.plan.modules:
        if mod["name"] == name:
            return float(mod[dim]) * 1000.0
    return 0.0


def top_jog_nm(bank: Bank) -> float:
    """Read-bitline jog from the array edge to the sense strip: the
    placement margin + a quarter of the strip depth (pins sit in the
    inner quarter). Packed banks only need the via-stack tip."""
    if is_packed(bank):
        return VIA_TIP_NM
    return layout.BLOCK_MARGIN_NM + strip_nm(bank, "top_port_data", "h") / 4.0


def bot_jog_nm(bank: Bank) -> float:
    if is_packed(bank):
        return VIA_TIP_NM
    return layout.BLOCK_MARGIN_NM + \
        strip_nm(bank, "bottom_port_data", "h") / 4.0


def wwl_jog_nm(bank: Bank) -> float:
    """Write (or SRAM single) wordline jog into the LEFT strip."""
    if is_packed(bank):
        return 0.0
    return layout.BLOCK_MARGIN_NM + \
        strip_nm(bank, "left_port_address", "w") / 4.0


def rwl_jog_nm(bank: Bank) -> float:
    """Read wordline jog — RIGHT strip for dual-port GC, left for SRAM."""
    if is_packed(bank):
        return 0.0
    side = "right_port_address" if bank.is_gc else "left_port_address"
    return layout.BLOCK_MARGIN_NM + strip_nm(bank, side, "w") / 4.0


# -- designed-length closed forms (elementwise: scalars or arrays). The
# router sums its per-net segment records in the SAME association order,
# which is what makes record-sum == closed-form bitwise.

def col_span_nm(rows, ch_nm, track_nm):
    """Bitline span over the placed cell column: rows of cells plus a
    supply-rail row every RAIL_ROWS_PER (layout.floorplan's formula)."""
    return rows * ch_nm + (rows // RAIL_ROWS_PER + 1) * 2.0 * track_nm


def bl_length_nm(rows, ch_nm, track_nm, jog_nm):
    return col_span_nm(rows, ch_nm, track_nm) + jog_nm


def wl_length_nm(cols, cw_nm, jog_nm):
    return cols * cw_nm + jog_nm


def _junction_per_row(bank: Bank) -> float:
    """Per-row drain-junction load on the read bitline (same device
    algebra as core.bank.bitline_rc)."""
    if bank.is_gc:
        rf = bank.cell.rf(bank.cfg.tech)
        return rf.cj_f_per_um * bank.cell.w_read
    return bank.cfg.tech.flavor("nmos_svt").cj_f_per_um * 0.14


def _gate_per_col(bank: Bank) -> float:
    """Per-column gate load on the read wordline."""
    tech = bank.cfg.tech
    if bank.is_gc:
        return bank.cell.rf(tech).cg_f_per_um * bank.cell.w_read
    return tech.flavor("nmos_svt").cg_f_per_um * 0.14


def _column_rc_kernel(rows, cols, l_bl_nm, l_wl_nm, n_vias,
                      r3, c3, r2, c2, cj_row, cg_col, r_via, c_via):
    """The ONE extraction kernel (elementwise; scalar and batched paths
    both run exactly this sequence of IEEE-double ops)."""
    bl_um = l_bl_nm * 1e-3
    wl_um = l_wl_nm * 1e-3
    r_bl = r3 * bl_um + n_vias * r_via
    c_bl = c3 * bl_um + rows * cj_row + n_vias * c_via
    r_wl = r2 * wl_um
    c_wl = c2 * wl_um + cols * cg_col
    return {
        "bl_length_nm": l_bl_nm, "bl_r_ohm": r_bl, "bl_c_f": c_bl,
        "wl_length_nm": l_wl_nm, "wl_r_ohm": r_wl, "wl_c_f": c_wl,
        "n_vias": n_vias,
    }


def extract_lattice(banks: Sequence[Bank],
                    deck: Optional[RuleDeck] = None
                    ) -> Dict[str, np.ndarray]:
    """Batched extraction over a design lattice: struct-of-arrays in,
    struct-of-arrays out. No geometry is placed or routed — the designed
    lengths are recomputed closed-form, bit-identical to the per-point
    `extract_point` reference over routed geometry."""
    banks = list(banks)
    deck = deck or RuleDeck.from_tech(banks[0].cfg.tech)
    n = len(banks)
    rows = np.empty(n, dtype=np.int64)
    cols = np.empty(n, dtype=np.int64)
    n_vias = np.empty(n, dtype=np.int64)
    fcols = {k: np.empty(n) for k in
             ("ch", "cw", "track", "jog_t", "jog_b", "jog_wl",
              "r3", "c3", "r2", "c2", "cj", "cg")}
    for i, b in enumerate(banks):
        tech = b.cfg.tech
        cw, ch = layout.cell_wh_nm(tech, b.cell.geom_key)
        rows[i], cols[i] = b.rows, b.cols
        n_vias[i] = N_BL_VIAS_GC if b.is_gc else N_BL_VIAS_SRAM
        fcols["ch"][i], fcols["cw"][i] = ch, cw
        fcols["track"][i] = tech.track
        fcols["jog_t"][i] = top_jog_nm(b)
        # GC read bitlines terminate at the array edge on the write side;
        # SRAM BL jogs into both strips
        fcols["jog_b"][i] = 0.0 if b.is_gc else bot_jog_nm(b)
        fcols["jog_wl"][i] = rwl_jog_nm(b)
        fcols["r3"][i] = tech.r_ohm_per_um["m3"]
        fcols["c3"][i] = tech.c_f_per_um["m3"]
        fcols["r2"][i] = tech.r_ohm_per_um["m2"]
        fcols["c2"][i] = tech.c_f_per_um["m2"]
        fcols["cj"][i] = _junction_per_row(b)
        fcols["cg"][i] = _gate_per_col(b)
    l_bl = bl_length_nm(rows, fcols["ch"], fcols["track"], fcols["jog_t"])
    l_bl = l_bl + fcols["jog_b"]
    l_wl = wl_length_nm(cols, fcols["cw"], fcols["jog_wl"])
    return _column_rc_kernel(rows, cols, l_bl, l_wl, n_vias,
                             fcols["r3"], fcols["c3"], fcols["r2"],
                             fcols["c2"], fcols["cj"], fcols["cg"],
                             deck.r_via_ohm, deck.c_via_f)


def extract_point(geom) -> Dict[str, float]:
    """Scalar extraction reference over ROUTED geometry: lengths come
    from the per-net designed-segment records the router laid down, not
    from a formula — so this catches a router that draws the wrong
    ladder, while staying bit-comparable to `extract_lattice`."""
    bank = geom.bank
    tech = bank.cfg.tech
    bl = geom.nets["rbl_0" if bank.is_gc else "bl_0"]
    wl = geom.nets["rwl_0" if bank.is_gc else "wl_0"]
    out = _column_rc_kernel(
        bank.rows, bank.cols, bl.length_nm(), wl.length_nm(), bl.n_vias,
        tech.r_ohm_per_um["m3"], tech.c_f_per_um["m3"],
        tech.r_ohm_per_um["m2"], tech.c_f_per_um["m2"],
        _junction_per_row(bank), _gate_per_col(bank),
        geom.deck.r_via_ohm, geom.deck.c_via_f)
    return {k: float(v) for k, v in out.items()}


def read_column_rc(bank: Bank, deck: Optional[RuleDeck] = None
                   ) -> Dict[str, float]:
    """Extracted read-column parasitics of one bank, closed-form (no
    geometry build) — the values `fidelity=\"layout\"` characterization
    and `timing.analyze(parasitics=\"extracted\")` consume."""
    lat = extract_lattice([bank], deck=deck)
    return {k: float(v[0]) for k, v in lat.items()}


def read_column_segments(bank: Bank, n_seg: int = 8,
                         deck: Optional[RuleDeck] = None) -> Dict[str, object]:
    """Uniform n_seg RC ladder of the extracted read bitline (the shape
    `timing.read_netlist` builds), plus the totals."""
    rc = read_column_rc(bank, deck=deck)
    return {
        "r_seg_ohm": np.full(n_seg, rc["bl_r_ohm"] / n_seg),
        "c_seg_f": np.full(n_seg, rc["bl_c_f"] / n_seg),
        **rc,
    }


def ladder_elmore_s(r_segs, c_segs, r_drv: float = 0.0,
                    c_load: float = 0.0) -> float:
    """Elmore delay of an RC ladder driven through r_drv with a lumped
    load at the far end (test/reporting helper)."""
    rs = np.cumsum(np.asarray(r_segs)) + r_drv
    return float(np.sum(rs * np.asarray(c_segs)) + rs[-1] * c_load)
