"""Track-grid rectangle primitives and the rule deck.

All coordinates are NANOMETERS, y-up, bank origin at (0, 0). A `Rect`
is one axis-aligned rectangle on one layer with an optional net label;
module placements live on the "place" layer (and "array" for the
bitcell array block, so a BEOL array may legally stack over the "place"
periphery), wires on "m1".."m4", cut shapes on "via".

The `RuleDeck` derives width/spacing minima from the TechFile pitches
(half-pitch rules) — the same deck `verify.check_rules` enforces and
the router targets, so a clean bank is clean BY CONSTRUCTION and the
checker guards refactors rather than tuning.

`rects_soa` flattens a rect list into struct-of-arrays numpy columns —
the form the vectorized DRC sweeps and batched extraction consume.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.techfile import TechFile

# routing direction convention per layer (ladder routing alternates)
H_LAYERS = ("m2",)            # wordlines, address bus
V_LAYERS = ("m1", "m3", "m4")  # pins/risers, read bitlines, write bitlines
WIRE_LAYERS = ("m1", "m2", "m3", "m4")


@dataclass(frozen=True)
class Rect:
    layer: str
    x0: float
    y0: float
    x1: float
    y1: float
    net: str = ""
    name: str = ""

    @property
    def w(self) -> float:
        return self.x1 - self.x0

    @property
    def h(self) -> float:
        return self.y1 - self.y0

    @property
    def cx(self) -> float:
        return 0.5 * (self.x0 + self.x1)

    @property
    def cy(self) -> float:
        return 0.5 * (self.y0 + self.y1)

    def overlaps(self, o: "Rect") -> bool:
        return (self.x0 < o.x1 and o.x0 < self.x1
                and self.y0 < o.y1 and o.y0 < self.y1)

    def contains(self, o: "Rect", inset: float = 0.0) -> bool:
        return (self.x0 + inset <= o.x0 and o.x1 <= self.x1 - inset
                and self.y0 + inset <= o.y0 and o.y1 <= self.y1 - inset)


@dataclass(frozen=True)
class Via:
    """One cut connecting two wire layers at a point; `lo`/`hi` name the
    layers it joins (a multi-layer stack is emitted as one Via per hop
    so enclosure checks stay per-pair)."""
    rect: Rect
    lo: str
    hi: str


def snap(v: float, pitch: float) -> float:
    """Snap DOWN onto the track grid."""
    return pitch * int(v // pitch)


def snap_up(v: float, pitch: float) -> float:
    return pitch * -int(-v // pitch)


@dataclass(frozen=True)
class RuleDeck:
    """Width / spacing / enclosure minima (nm) per layer, plus the cut
    size and the per-cut parasitics the extractor charges."""
    min_width: Dict[str, float]
    min_space: Dict[str, float]
    via_size: float
    via_enclosure: float
    block_space: float
    r_via_ohm: float = 2.0
    c_via_f: float = 0.05e-15

    @classmethod
    def from_tech(cls, tech: TechFile) -> "RuleDeck":
        # half-pitch width/space on the routing layers; m3/m4 have no
        # pitch entry in the deck, so they inherit the m2 pitch (upper
        # metals in a 40 nm-class BEOL are no tighter than m2)
        pitch = {"m1": float(tech.m1_pitch), "m2": float(tech.m2_pitch),
                 "m3": float(tech.m2_pitch), "m4": float(tech.m2_pitch)}
        return cls(
            min_width={l: p / 2.0 for l, p in pitch.items()},
            min_space={l: p / 2.0 for l, p in pitch.items()},
            via_size=float(tech.m1_pitch) / 2.0,
            via_enclosure=float(tech.min_l_nm) / 2.0,
            block_space=100.0,
        )

    def wire_width(self, layer: str) -> float:
        return self.min_width[layer]


def rects_to_soa(rects: Sequence[Rect]) -> Dict[str, np.ndarray]:
    """Struct-of-arrays view of a rect list (the vectorized-DRC form):
    float64 coordinate columns + object columns for layer/net."""
    return {
        "layer": np.array([r.layer for r in rects], dtype=object),
        "net": np.array([r.net for r in rects], dtype=object),
        "x0": np.array([r.x0 for r in rects], dtype=np.float64),
        "y0": np.array([r.y0 for r in rects], dtype=np.float64),
        "x1": np.array([r.x1 for r in rects], dtype=np.float64),
        "y1": np.array([r.y1 for r in rects], dtype=np.float64),
    }


def bbox(rects: Sequence[Rect]) -> Tuple[float, float, float, float]:
    return (min(r.x0 for r in rects), min(r.y0 for r in rects),
            max(r.x1 for r in rects), max(r.y1 for r in rects))
