"""Ladder-style metal routing over a placed bank.

Layer plan (half-pitch widths from the RuleDeck; two wordline tracks
fit one cell row at every supported row pitch):

  m2  wordlines — GC: WWL at 1/4 row height (driven from the LEFT strip)
      and RWL at 3/4 (driven from the RIGHT strip); SRAM: one WL.
  m3  read bitlines (GC) / BL+BLb (SRAM), one ladder per column, SA end
      at the TOP with a via stack down to the sense-amp input; also the
      address buses (horizontal, bottom strip).
  m4  write bitlines (GC) jogging to the bottom-strip write drivers,
      plus the data-in/out pin stubs at the bank edge.

Every net records its DESIGNED segment lengths explicitly as
(layer, length_nm) pairs — computed from the closed forms in
`repro.geom.extract`, in the same association order the batched
extractor uses — rather than re-deriving them from rect coordinate
differences (floating-point (y0+L)-y0 is not L). `extract_point` sums
these records; `extract_lattice` recomputes the closed forms
vectorized; the two are bit-identical.

Via stacks stagger their landing pads by column parity (and BL/BLb
index for SRAM) so pads stay spacing-clean at column pitches tighter
than pad + min_space. Packed (BEOL) banks route across the stacked
array only, with a VIA_TIP_NM tip past the array edge for the stacks,
and omit the peripheral buses — see docs/layout.md.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.geom import extract as ex
from repro.geom.grid import Rect, Via
from repro.geom.placer import BankGeometry

_ORDER = ("m1", "m2", "m3", "m4")
STAGGER_NM = 300.0     # pad-center offset between adjacent via stacks


@dataclass
class Net:
    """One routed net: designed segment lengths + via count + the
    indices of its wire rects in `geom.wires`."""
    name: str
    kind: str                      # wordline | bitline | bus | stub
    segments: List[Tuple[str, float]] = field(default_factory=list)
    n_vias: int = 0
    wire_ids: List[int] = field(default_factory=list)

    def length_nm(self, layer: Optional[str] = None) -> float:
        return sum(l for lay, l in self.segments
                   if layer is None or lay == layer)


def _wire(g: BankGeometry, net: Net, layer: str, x0, y0, x1, y1) -> None:
    net.wire_ids.append(len(g.wires))
    g.wires.append(Rect(layer, x0, y0, x1, y1, net=net.name))


def _hwire(g, net, layer, x0, x1, yc):
    w = g.deck.wire_width(layer)
    _hw = w / 2
    _wire(g, net, layer, min(x0, x1), yc - _hw, max(x0, x1), yc + _hw)


def _vwire(g, net, layer, xc, y0, y1):
    w = g.deck.wire_width(layer)
    _wire(g, net, layer, xc - w / 2, min(y0, y1), xc + w / 2,
          max(y0, y1))


def _pad_half(g: BankGeometry) -> float:
    return g.deck.via_size / 2 + g.deck.via_enclosure


def _via_stack(g: BankGeometry, net: Net, x: float, y: float,
               lo: str, hi: str) -> None:
    """Stacked cuts from `hi` down to `lo` + landing pads on every
    touched layer (pads are wider than the wire so the enclosure rule
    holds around each cut)."""
    vs, ph = g.deck.via_size, _pad_half(g)
    i0, i1 = _ORDER.index(lo), _ORDER.index(hi)
    for layer in _ORDER[i0:i1 + 1]:
        net.wire_ids.append(len(g.wires))
        g.wires.append(Rect(layer, x - ph, y - ph, x + ph, y + ph,
                            net=net.name, name=f"{net.name}:pad:{layer}"))
    for k in range(i0, i1):
        cut = Rect("via", x - vs / 2, y - vs / 2, x + vs / 2, y + vs / 2,
                   net=net.name, name=f"{net.name}:cut:{k}")
        g.vias.append(Via(cut, _ORDER[k], _ORDER[k + 1]))
        net.n_vias += 1


def _route_wordlines(g: BankGeometry) -> None:
    bank = g.bank
    ax1 = g.ax0 + g.aw
    left = g.block("left_port_address")
    right = g.block("right_port_address")
    aw = bank.cols * g.cw
    jw, jr = ex.wwl_jog_nm(bank), ex.rwl_jog_nm(bank)
    for r in range(bank.rows):
        y = g.row_y(r)
        if bank.is_gc:
            wwl = Net(f"wwl_{r}", "wordline")
            rwl = Net(f"rwl_{r}", "wordline")
            if g.packed:
                _hwire(g, wwl, "m2", g.ax0, ax1, y + g.ch / 4)
                _hwire(g, rwl, "m2", g.ax0, ax1, y + 3 * g.ch / 4)
            else:
                _hwire(g, wwl, "m2", left.x1 - left.w / 4, ax1,
                       y + g.ch / 4)
                _hwire(g, rwl, "m2", g.ax0, right.x0 + right.w / 4,
                       y + 3 * g.ch / 4)
            wwl.segments += [("m2", aw), ("m2", jw)]
            rwl.segments += [("m2", aw), ("m2", jr)]
            g.nets[wwl.name] = wwl
            g.nets[rwl.name] = rwl
        else:
            wl = Net(f"wl_{r}", "wordline")
            _hwire(g, wl, "m2", left.x1 - left.w / 4, ax1, y + g.ch / 2)
            wl.segments += [("m2", aw), ("m2", jr)]
            g.nets[wl.name] = wl


def _route_bitlines(g: BankGeometry) -> None:
    bank, tech = g.bank, g.bank.cfg.tech
    span = ex.col_span_nm(bank.rows, g.ch, tech.track)
    jt = ex.top_jog_nm(bank)
    jb = ex.bot_jog_nm(bank)
    ph = _pad_half(g)
    for c in range(bank.cols):
        x = g.col_x(c)
        stag = (c % 2) * STAGGER_NM
        if bank.is_gc:
            # read bitline: SA end (ladder segment 0) at the top, active
            # cell at the bottom — timing.read_netlist's orientation
            rbl = Net(f"rbl_{c}", "bitline")
            y_top = g.ay0 + span + jt
            _vwire(g, rbl, "m3", x, g.ay0, y_top)
            rbl.segments += [("m3", span), ("m3", jt)]
            _via_stack(g, rbl, x, y_top - ph - stag, "m1", "m3")
            g.nets[rbl.name] = rbl

            wbl = Net(f"wbl_{c}", "bitline")
            y_bot = g.ay0 - jb
            _vwire(g, wbl, "m4", x, y_bot, g.ay0 + span)
            wbl.segments += [("m4", span), ("m4", jb)]
            _via_stack(g, wbl, x, y_bot + ph + stag, "m1", "m4")
            g.nets[wbl.name] = wbl
        else:
            for j, name in ((0, f"bl_{c}"), (1, f"blb_{c}")):
                xj = g.ax0 + (c + (j + 1) / 3.0) * g.cw
                n = Net(name, "bitline")
                y_top, y_bot = g.ay0 + span + jt, g.ay0 - jb
                _vwire(g, n, "m3", xj, y_bot, y_top)
                n.segments += [("m3", span), ("m3", jt), ("m3", jb)]
                _via_stack(g, n, xj, y_top - ph - j * STAGGER_NM,
                           "m1", "m3")
                _via_stack(g, n, xj, y_bot + ph + j * STAGGER_NM,
                           "m1", "m3")
                g.nets[name] = n


def _route_buses(g: BankGeometry) -> None:
    """Address buses (m3, horizontal, lower part of the bottom strip —
    below the write-bitline landing pads at 3/4 depth) and per-data-bit
    pin stubs (m4, vertical, outer strip halves)."""
    bank, tech = g.bank, g.bank.cfg.tech
    bot = g.block("bottom_port_data")
    top = g.block("top_port_data")
    left = g.block("left_port_address")
    right = g.block("right_port_address")
    corner = g.block("ctrl_corner")
    if bot is None or top is None:
        return
    cx = corner.cx if corner is not None else g.ax0 + g.aw / 2
    n_addr = max(1, int(math.log2(max(bank.cfg.num_words, 2))))
    pitch = float(tech.m2_pitch)
    y = bot.y0 + pitch / 2
    spans = [("waddr", left.x0 + left.w / 2 if left is not None else g.ax0,
              cx)]
    if bank.is_gc and right is not None and right.w > 0:
        spans.append(("raddr", right.x0 + right.w / 2, cx))
    for tag, x0, x1 in spans:
        for b in range(n_addr):
            n = Net(f"{tag}_{b}", "bus")
            _hwire(g, n, "m3", x0, x1, y)
            n.segments.append(("m3", abs(x1 - x0)))
            g.nets[n.name] = n
            y += pitch

    ring_band = bot.y0
    for i in range(bank.cfg.word_size):
        x = g.col_x(i * bank.words_per_row)
        dout = Net(f"dout_{i}", "stub")
        y0, y1 = top.y1 - top.h / 4, g.bank_h - ring_band - 2 * pitch
        _vwire(g, dout, "m4", x, y0, y1)
        dout.segments.append(("m4", y1 - y0))
        g.nets[dout.name] = dout
        din = Net(f"din_{i}", "stub")
        y0, y1 = ring_band + 2 * pitch, bot.y0 + bot.h / 4
        _vwire(g, din, "m4", x, y0, y1)
        din.segments.append(("m4", y1 - y0))
        g.nets[din.name] = din


def route_bank(g: BankGeometry) -> BankGeometry:
    """Route wordlines, bitlines and peripheral buses in place; returns
    the same BankGeometry with `wires`/`vias`/`nets` filled."""
    _route_wordlines(g)
    _route_bitlines(g)
    if not g.packed:
        _route_buses(g)
    return g
