"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf]

Modeling notes (DESIGN.md §Arch-applicability): one SHARED attention+MLP
block (single weight set) is applied every 6 Mamba2 layers; Zamba2's
per-application LoRA deltas are omitted.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    conv_kernel=4,
    attn_every=6,
    rope_theta=10000.0,
)
