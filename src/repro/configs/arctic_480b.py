"""arctic-480b [moe] — 128 experts top-2 + dense residual FFN.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2
[hf:Snowflake/snowflake-arctic-base]

Optimizer: Adafactor (factored second moment, bf16 first moment) so
optimizer state fits per-device HBM at 480B scale (see DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    top_k=2,
    moe_dense_ff=4864,           # dense residual path (dense-MoE hybrid)
    capacity_factor=1.0,
    optimizer="adafactor",
    remat="full",
    rope_theta=10000.0,
)
