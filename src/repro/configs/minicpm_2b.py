"""minicpm-2b [dense] — llama-like arch trained with the WSD schedule.

40L d_model=2304 36H (GQA kv=36) d_ff=5760 vocab=122753  [arXiv:2404.06395; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    tie_embeddings=True,
    schedule="wsd",
    rope_theta=10000.0,
)
