"""whisper-large-v3 [audio] — encoder-decoder; conv frontend is a stub.

32L d_model=1280 20H (GQA kv=20) d_ff=5120 vocab=51866  [arXiv:2212.04356]

32 encoder + 32 decoder layers (whisper-large is 32/32). The mel/conv
frontend is a STUB: input_specs() provides precomputed (1500, d_model)
frame embeddings. LayerNorm + GELU, learned absolute positions, cross-attn.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    n_enc_layers=32,
    enc_frames=1500,
    norm="layernorm",
    act="gelu",
)
