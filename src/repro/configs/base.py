"""Model/shape configuration system.

Every assigned architecture is a `ModelConfig`; the four assigned input
shapes are `ShapeConfig`s. `reduced()` derives a CPU-smoke-test-sized config
of the same family (same block structure, tiny dims).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The assigned shape grid (identical for every LM-family arch).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- attention options ---
    qkv_bias: bool = False
    sliding_window: int = 0          # >0 -> SWA (mixtral)
    rope_theta: float = 500000.0
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_dense_ff: int = 0            # arctic: parallel dense residual FFN
    capacity_factor: float = 1.25

    # --- SSM / hybrid (zamba2) ---
    ssm_state: int = 0               # Mamba2 d_state
    ssm_expand: int = 2
    ssm_headdim: int = 64
    conv_kernel: int = 4
    attn_every: int = 0              # zamba2: shared attn+MLP block period

    # --- xLSTM ---
    slstm_every: int = 0             # one sLSTM per group of this many blocks
    mlstm_proj_factor: float = 2.0

    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    enc_frames: int = 0              # stub frontend: precomputed frame embeds

    # --- vlm (internvl2) ---
    n_patches: int = 0               # stub frontend: precomputed patch embeds

    # --- numerics / norm ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu | gelu
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # --- training-time knobs ---
    remat: str = "full"              # none | dots | full
    attn_seqpar: bool = True         # context-parallel flash when heads
                                     # don't divide the model axis (§Perf)
    kv_dtype: str = "bfloat16"       # "int8" -> quantized KV cache with
                                     # per-token-per-head scales (§Perf)
    optimizer: str = "adamw"         # adamw | adafactor
    schedule: str = "cosine"         # cosine | wsd

    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def subquadratic(self) -> bool:
        """Can this arch run long_500k decode with bounded memory?"""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def shapes(self):
        """The live (non-skipped) shape list for this arch."""
        out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
        if self.subquadratic:
            out.append(SHAPES["long_500k"])
        return out

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            n_experts=min(self.n_experts, 4),
            moe_dense_ff=64 if self.moe_dense_ff else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_headdim=16,
            sliding_window=32 if self.sliding_window else 0,
            attn_every=2 if self.attn_every else 0,
            slstm_every=2 if self.slstm_every else 0,
            n_enc_layers=2 if self.n_enc_layers else 0,
            enc_frames=8 if self.enc_frames else 0,
            n_patches=4 if self.n_patches else 0,
            remat="none",
        )

    # ---- parameter counting (used by roofline MODEL_FLOPS) ----
    def param_count(self) -> int:
        from repro.models.model import Model
        return Model(self).param_count()

    def active_param_count(self) -> int:
        from repro.models.model import Model
        return Model(self).param_count(active_only=True)
