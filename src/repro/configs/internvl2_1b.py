"""internvl2-1b [vlm] — InternViT frontend (stub) + InternLM2-ish backbone.

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655  [arXiv:2404.16821; hf]

The vision tower is a STUB: input_specs() provides precomputed
(n_patches=256, d_model) patch embeddings which are prepended to the
text-token embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    tie_embeddings=True,
    n_patches=256,
    rope_theta=1000000.0,
)
