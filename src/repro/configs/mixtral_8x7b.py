"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2
[arXiv:2401.04088; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    top_k=2,
    sliding_window=4096,
    rope_theta=1000000.0,
)
