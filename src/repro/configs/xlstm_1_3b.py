"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks.

48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304  [arXiv:2405.04517]

48 layers = 6 groups x (7 mLSTM + 1 sLSTM). mLSTM is a matrix-memory
gated linear recurrence run in chunkwise-parallel form; sLSTM is a
scalar-memory recurrence run as a sequential scan (inherently serial).
d_ff=0: the mLSTM block carries its own 2x up-projection (proj_factor).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,                # qk head dim at proj_factor=2: inner=4096, hd_v=1024
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,
    mlstm_proj_factor=2.0,
)
