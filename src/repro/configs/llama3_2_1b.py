"""llama3.2-1b [dense] — small llama3.

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256  [hf:meta-llama/Llama-3.2-1B]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    tie_embeddings=True,
    rope_theta=500000.0,
)
