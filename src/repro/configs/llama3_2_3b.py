"""llama3.2-3b [dense] — small llama3.

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256  [hf:meta-llama/Llama-3.2-1B family]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    tie_embeddings=True,
    rope_theta=500000.0,
)
