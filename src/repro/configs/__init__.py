"""Architecture registry: ``get_config("llama3.2-1b")`` / ``--arch`` ids."""
from repro.configs.base import ModelConfig, ShapeConfig, SHAPES

from repro.configs.xlstm_1_3b import CONFIG as XLSTM_1_3B
from repro.configs.zamba2_2_7b import CONFIG as ZAMBA2_2_7B
from repro.configs.whisper_large_v3 import CONFIG as WHISPER_LARGE_V3
from repro.configs.qwen2_0_5b import CONFIG as QWEN2_0_5B
from repro.configs.minicpm_2b import CONFIG as MINICPM_2B
from repro.configs.llama3_2_3b import CONFIG as LLAMA3_2_3B
from repro.configs.llama3_2_1b import CONFIG as LLAMA3_2_1B
from repro.configs.arctic_480b import CONFIG as ARCTIC_480B
from repro.configs.mixtral_8x7b import CONFIG as MIXTRAL_8X7B
from repro.configs.internvl2_1b import CONFIG as INTERNVL2_1B

REGISTRY = {
    c.name: c
    for c in [
        XLSTM_1_3B,
        ZAMBA2_2_7B,
        WHISPER_LARGE_V3,
        QWEN2_0_5B,
        MINICPM_2B,
        LLAMA3_2_3B,
        LLAMA3_2_1B,
        ARCTIC_480B,
        MIXTRAL_8X7B,
        INTERNVL2_1B,
    ]
}

ARCH_IDS = sorted(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    return REGISTRY[name]


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "REGISTRY", "ARCH_IDS", "get_config"]
