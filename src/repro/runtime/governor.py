"""Adaptive operating-voltage governor over a precomputed `VddLattice`.

The paper's flexibility claim — GCRAM retention/power "tuned on-the-fly
by changing the operating voltage" — becomes a runtime policy here: a
deployed KV-cache macro (one lattice config, `n_banks` interleaved
banks) moves along its voltage ladder as MEASURED traffic shifts.

Physics (gc2t_np, the PMOS-read gain cell this repo's benches govern):
dropping vdd LENGTHENS retention — the written level sits farther from
the read margin — so the refresh interval stretches, refresh power
falls, and every access costs fewer CV^2 joules, at the price of f_max.
The governor rides that tradeoff: serve bursts at a rung that meets the
measured read rate, drop to the cheapest admissible rung when traffic
quiets.

Admissibility of rung `vi` for a traffic window mirrors `core.dse.
feasible` exactly: swing_ok, aggregate n_banks x f_max covers the read
rate, and native retention >= the window's OBSERVED data lifetime OR
refresh covers it at <10% bandwidth overhead (num_words / retention_s
< 0.1 x f_max); retention <= 0 never passes. Operating points failing
the retention rule are FORBIDDEN regardless of how fast or cheap they
are.

Energy-accounting rules (shared with bench_runtime's scoreboard and
docs/runtime.md):
  e_dyn     = window accesses x e_read_j[vi]        (per-access CV^2)
  e_leak    = n_banks x leakage_w[vi] x duration
  e_refresh = n_banks x refresh_w[vi] x duration, charged only when
              retention falls short of the observed lifetime (native
              retention needs no refresh)
  a FIXED operating point inadmissible in ANY window scores +inf total
  — pinned there, the deployment would have dropped requests (rate
  shortfall) or lost data (retention shortfall). Fixed points are held
  to the SAME headroom admission margin the governor provisions with,
  so the comparison is like-for-like QoS.

Policy: the first observed window calibrates the starting rung; after
that, up-switches are immediate (capacity emergencies don't wait) and
down-switches are hysteretic — at least `dwell_windows` quiet windows
AND `down_headroom` capacity margin at the lower rung — so traffic
flutter at a capacity boundary cannot flap the rail.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.runtime.telemetry import TelemetryWindow


@dataclasses.dataclass(frozen=True)
class Traffic:
    """One telemetry window's demand on the governed macro.

    `read_hz` is the AGGREGATE word-read rate across the macro's banks
    (window-averaged — idle time dilutes it); `lifetime_s` the longest
    observed data residency the rung's retention must cover;
    `accesses` the window's total reads (rate x duration)."""
    read_hz: float
    lifetime_s: float
    duration_s: float
    accesses: float


def traffic_from_window(win: TelemetryWindow, cfg, *,
                        word_bytes: float = 8.0) -> Traffic:
    """Derive the governed macro's traffic from a telemetry window: the
    macro is the (L2-class) KV-cache store, so its request stream is the
    measured KV byte stream divided into `word_bytes` words. The
    lifetime is the window's LONGEST admit->retire residency (every
    resident datum must survive), falling back to the window duration
    when nothing retired."""
    from repro.runtime.profile import kv_stream_bytes
    total_words = kv_stream_bytes(win, cfg) / word_bytes
    dur = win.duration_s
    life = max(win.kv_lifetimes_s) if win.kv_lifetimes_s else dur
    return Traffic(read_hz=total_words / dur if dur > 0 else 0.0,
                   lifetime_s=life, duration_s=dur, accesses=total_words)


@dataclasses.dataclass(frozen=True)
class GovernorPolicy:
    headroom: float = 1.25        # capacity margin a rung must provision
    down_headroom: float = 1.6    # stricter margin required to step DOWN
    dwell_windows: int = 1        # quiet windows to wait before stepping down


@dataclasses.dataclass(frozen=True)
class Decision:
    """One governed window: the rung chosen, its refresh bookkeeping and
    the window's energy split under the accounting rules above."""
    window: int
    vi: int
    vdd_scale: float
    switched: bool
    admissible: bool              # chosen rung admissible for the window
    refresh_interval_s: float     # retention_s at the rung = max interval
    e_dyn_j: float
    e_leak_j: float
    e_refresh_j: float

    @property
    def energy_j(self) -> float:
        return self.e_dyn_j + self.e_leak_j + self.e_refresh_j


class VddGovernor:
    """Moves one lattice config (`pi`, x `n_banks` interleaved) along the
    lattice's voltage ladder, one `observe(traffic)` call per window."""

    def __init__(self, lattice, pi: int, n_banks: int,
                 policy: Optional[GovernorPolicy] = None,
                 start_vi: Optional[int] = None):
        self.lat = lattice
        self.pi = int(pi)
        self.n_banks = int(n_banks)
        self.policy = policy or GovernorPolicy()
        self.vi: Optional[int] = None if start_vi is None else int(start_vi)
        self._dwell = 0
        self.decisions: List[Decision] = []

    # -- rung properties ------------------------------------------------
    def capacity_hz(self, vi: int) -> float:
        """Aggregate read capacity of the macro at rung vi."""
        return self.n_banks * float(self.lat.f_max_hz[vi, self.pi])

    def refresh_interval_s(self, vi: int) -> float:
        return float(self.lat.retention_s[vi, self.pi])

    def retention_covers(self, vi: int, lifetime_s: float) -> bool:
        """`core.dse.feasible`'s retention/refresh rule at rung vi."""
        ret = float(self.lat.retention_s[vi, self.pi])
        if ret >= lifetime_s:
            return True
        if ret <= 0:
            return False
        refresh_rate = float(self.lat.num_words[self.pi]) / ret
        return refresh_rate < 0.1 * float(self.lat.f_max_hz[vi, self.pi])

    def admissible(self, vi: int, t: Traffic, *, margin: float = 1.0) -> bool:
        return (bool(self.lat.swing_ok[vi, self.pi])
                and self.capacity_hz(vi) >= margin * t.read_hz
                and self.retention_covers(vi, t.lifetime_s))

    def target(self, t: Traffic) -> Optional[int]:
        """Lowest (cheapest) rung admissible with provisioning headroom;
        None when no rung — even the top — can carry the window."""
        for vi in range(len(self.lat.vdd_scales)):
            if self.admissible(vi, t, margin=self.policy.headroom):
                return vi
        return None

    def energy_at(self, vi: int, t: Traffic):
        """(e_dyn, e_leak, e_refresh) joules of serving `t` at rung vi."""
        needs_refresh = float(self.lat.retention_s[vi, self.pi]) \
            < t.lifetime_s
        e_dyn = t.accesses * float(self.lat.e_read_j[vi, self.pi])
        e_leak = self.n_banks * float(self.lat.leakage_w[vi, self.pi]) \
            * t.duration_s
        e_ref = self.n_banks * float(self.lat.refresh_w[vi, self.pi]) \
            * t.duration_s if needs_refresh else 0.0
        return e_dyn, e_leak, e_ref

    # -- the policy -----------------------------------------------------
    def observe(self, t: Traffic) -> Decision:
        tgt = self.target(t)
        switched = False
        if self.vi is None:
            # first window calibrates the boot rung (no history yet);
            # fall back to the fastest swing-ok rung when nothing admits
            self.vi = tgt if tgt is not None else self._fastest_ok()
        elif tgt is None:
            best = self._fastest_ok()
            switched = best != self.vi
            self.vi, self._dwell = best, 0
        elif tgt > self.vi:
            self.vi, self._dwell, switched = tgt, 0, True   # urgent up
        elif tgt < self.vi:
            if (self._dwell >= self.policy.dwell_windows
                    and self.capacity_hz(tgt)
                    >= self.policy.down_headroom * t.read_hz):
                self.vi, self._dwell, switched = tgt, 0, True
            else:
                self._dwell += 1                 # hysteresis: hold rail
        else:
            self._dwell += 1
        e_dyn, e_leak, e_ref = self.energy_at(self.vi, t)
        d = Decision(len(self.decisions), self.vi,
                     float(self.lat.vdd_scales[self.vi]), switched,
                     self.admissible(self.vi, t),
                     self.refresh_interval_s(self.vi), e_dyn, e_leak,
                     e_ref)
        self.decisions.append(d)
        return d

    def _fastest_ok(self) -> int:
        cands = [vi for vi in range(len(self.lat.vdd_scales))
                 if bool(self.lat.swing_ok[vi, self.pi])]
        return max(cands, key=self.capacity_hz) if cands \
            else len(self.lat.vdd_scales) - 1

    @property
    def total_energy_j(self) -> float:
        return sum(d.energy_j for d in self.decisions)


def replay_fixed(lattice, pi: int, n_banks: int,
                 traffics: Sequence[Traffic], vi: int,
                 policy: Optional[GovernorPolicy] = None) -> float:
    """Total energy of a deployment PINNED at rung `vi` across the
    traffic windows, under the same admission margin the governor
    provisions with; +inf when any window is inadmissible there."""
    gov = VddGovernor(lattice, pi, n_banks, policy=policy, start_vi=vi)
    margin = gov.policy.headroom
    total = 0.0
    for t in traffics:
        if not gov.admissible(vi, t, margin=margin):
            return float("inf")
        total += sum(gov.energy_at(vi, t))
    return total
