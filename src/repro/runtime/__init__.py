"""Runtime telemetry + adaptive voltage governor: the loop from the live
serving engine back into co-design.

Three layers: `telemetry` accumulates host-side counters from the
serving/training loops (zero extra device syncs); `profile` converts a
telemetry window into the frozen `workloads.profiler.Profile` schema so
measured workloads feed `CoDesignQuery` unchanged; `governor` moves a
deployed macro along its precomputed `VddLattice` as measured traffic
shifts. `replay` drives deterministic traffic scenarios for benchmarks
and tests.
"""
from repro.runtime.governor import (Decision, GovernorPolicy, Traffic,
                                    VddGovernor, replay_fixed,
                                    traffic_from_window)
from repro.runtime.profile import (DIFF_FIELDS, diff_profiles, kv_row_bytes,
                                   kv_stream_bytes, measured_profile)
from repro.runtime.replay import Phase, Scenario, run_scenario
from repro.runtime.telemetry import (TelemetryCollector, TelemetryWindow,
                                     VirtualClock)

__all__ = [
    "TelemetryCollector", "TelemetryWindow", "VirtualClock",
    "measured_profile", "diff_profiles", "kv_row_bytes", "kv_stream_bytes",
    "DIFF_FIELDS",
    "Traffic", "traffic_from_window", "GovernorPolicy", "Decision",
    "VddGovernor", "replay_fixed",
    "Phase", "Scenario", "run_scenario",
]
