"""Low-overhead runtime telemetry for the serving and training loops.

Design rule (asserted in tests/test_runtime.py): the collector is fed
exclusively from HOST-side values the engine already reconciled — the
np token/live arrays `ServeEngine._reconcile` pulls once per fused
chunk, host-tracked per-slot context lengths, python queue depths — so
attaching it to a `mode="device"` engine adds ZERO device syncs and
leaves greedy token streams bit-identical.

Clocks: with `step_time_s` set the collector runs on a `VirtualClock` —
time is model-steps x step_time_s, advanced by the chunk hooks (and by
`tick()` when a replay drives an idle engine step) — so deterministic
replays produce deterministic windows. Without it, wall time
(time.monotonic).

A `TelemetryWindow` snapshot is a frozen bag of counters; the byte-level
interpretation (KV bytes per row, weight stream, hierarchy split) lives
in `repro.runtime.profile`, which converts windows into the frozen
`repro.workloads.profiler.Profile` schema, and in
`repro.runtime.governor`, which turns windows into macro `Traffic`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple


class VirtualClock:
    """Deterministic model-step clock: now() = steps_seen x step_time_s.

    The serving engine reads it for request timestamps; the collector
    advances it once per observed (or idle-ticked) model step."""

    def __init__(self, step_time_s: float):
        self.step_time_s = float(step_time_s)
        self._t = 0.0

    def __call__(self) -> float:
        return self._t

    def advance(self, n_steps: int = 1) -> None:
        self._t += n_steps * self.step_time_s


@dataclasses.dataclass(frozen=True)
class TelemetryWindow:
    """Counters accumulated between two `snapshot()` calls.

    `decode_steps` counts FUSED model steps (a K-step chunk adds K,
    including steps where some slots sat frozen), so
    `decode_tokens / decode_steps` is the effective live batch.
    `kv_row_steps` integrates resident KV-cache rows over model steps
    (rows sampled at chunk boundaries, capped at the engine window);
    `kv_row_steps / decode_steps` is mean resident rows.
    `kv_lifetimes_s` holds admit->retire residency per retired request
    — the observed data lifetime the governor checks retention against.
    """
    t_start_s: float
    t_end_s: float
    step_time_s: Optional[float]       # virtual-clock step, if configured
    decode_steps: int = 0
    decode_tokens: int = 0
    prefill_tokens: int = 0            # prompt tokens pushed at admission
    n_submitted: int = 0
    n_admitted: int = 0                # each also emits 1 token at prefill
    n_retired: int = 0
    batch_hist: Tuple[Tuple[int, int], ...] = ()  # (live_slots, steps)
    queue_depth_sum: int = 0
    queue_samples: int = 0
    kv_row_steps: float = 0.0
    kv_lifetimes_s: Tuple[float, ...] = ()
    queue_waits_s: Tuple[float, ...] = ()
    train_steps: int = 0
    train_tokens: int = 0
    train_time_s: float = 0.0

    @property
    def duration_s(self) -> float:
        return self.t_end_s - self.t_start_s

    @property
    def mean_batch(self) -> float:
        """Tokens emitted per decode model step (effective live batch)."""
        return self.decode_tokens / self.decode_steps \
            if self.decode_steps else 0.0

    @property
    def mean_kv_rows(self) -> float:
        """Mean resident KV-cache rows across decode steps (all slots)."""
        return self.kv_row_steps / self.decode_steps \
            if self.decode_steps else 0.0

    @property
    def mean_queue_depth(self) -> float:
        return self.queue_depth_sum / self.queue_samples \
            if self.queue_samples else 0.0

    @property
    def tokens_per_s(self) -> float:
        toks = self.decode_tokens + self.n_admitted + self.train_tokens
        return toks / self.duration_s if self.duration_s > 0 else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(duration_s=self.duration_s, mean_batch=self.mean_batch,
                 mean_kv_rows=self.mean_kv_rows,
                 mean_queue_depth=self.mean_queue_depth,
                 tokens_per_s=self.tokens_per_s)
        return d


class TelemetryCollector:
    """Accumulates engine/trainer hooks into TelemetryWindows.

    Attach via `ServeEngine(..., telemetry=collector)` (serving) or
    `TrainConfig(telemetry=collector)` (training); call
    `snapshot(reset=True)` at window boundaries. All hooks are O(live
    slots) python arithmetic on host data — no device interaction."""

    def __init__(self, *, step_time_s: Optional[float] = None, clock=None):
        self.step_time_s = step_time_s
        if clock is not None:
            self.clock = clock
        elif step_time_s is not None:
            self.clock = VirtualClock(step_time_s)
        else:
            self.clock = time.monotonic
        self._reset()

    def _reset(self) -> None:
        self._t0 = self.clock()
        self._decode_steps = 0
        self._decode_tokens = 0
        self._prefill_tokens = 0
        self._n_submitted = 0
        self._n_admitted = 0
        self._n_retired = 0
        self._batch: Dict[int, int] = {}
        self._queue_sum = 0
        self._queue_n = 0
        self._kv_row_steps = 0.0
        self._kv_lifetimes: List[float] = []
        self._queue_waits: List[float] = []
        self._train_steps = 0
        self._train_tokens = 0
        self._train_time = 0.0

    def _advance(self, n_steps: int) -> None:
        if isinstance(self.clock, VirtualClock):
            self.clock.advance(n_steps)

    # ------------------------------------------------------------------
    # serving hooks (called by ServeEngine; host-side data only)
    # ------------------------------------------------------------------
    def on_submit(self, rid: int, prompt_len: int, queue_depth: int) -> None:
        self._n_submitted += 1

    def on_admit(self, n_requests: int, prompt_tokens: int,
                 queue_depth: int) -> None:
        self._n_admitted += n_requests
        self._prefill_tokens += prompt_tokens
        self._queue_sum += queue_depth
        self._queue_n += 1

    def on_chunk(self, n_steps: int, emitted_tokens: int, kv_rows,
                 queue_depth: int) -> None:
        """One reconciled decode chunk: `n_steps` fused model steps,
        `emitted_tokens` tokens folded into streams, `kv_rows` the
        resident cache rows of each live slot at the chunk boundary."""
        self._advance(n_steps)
        self._decode_steps += n_steps
        self._decode_tokens += emitted_tokens
        n_live = len(kv_rows)
        self._batch[n_live] = self._batch.get(n_live, 0) + n_steps
        self._kv_row_steps += float(sum(kv_rows)) * n_steps
        self._queue_sum += queue_depth
        self._queue_n += 1

    def on_retire(self, stats) -> None:
        self._n_retired += 1
        self._kv_lifetimes.append(stats.service_s)
        self._queue_waits.append(stats.queue_wait_s)

    def tick(self, n_steps: int = 1) -> None:
        """Advance the virtual clock across an IDLE engine step (no
        dispatch happened). Idle time dilutes window rates — exactly what
        the governor should see from a quiet macro."""
        self._advance(n_steps)
        self._batch[0] = self._batch.get(0, 0) + n_steps

    # ------------------------------------------------------------------
    # training hook (called by training.loop.Trainer)
    # ------------------------------------------------------------------
    def on_train_step(self, step: int, tokens: int, dt_s: float,
                      loss: Optional[float] = None) -> None:
        self._train_steps += 1
        self._train_tokens += int(tokens)
        self._train_time += float(dt_s)

    # ------------------------------------------------------------------
    def snapshot(self, reset: bool = True) -> TelemetryWindow:
        win = TelemetryWindow(
            t_start_s=self._t0, t_end_s=self.clock(),
            step_time_s=self.step_time_s,
            decode_steps=self._decode_steps,
            decode_tokens=self._decode_tokens,
            prefill_tokens=self._prefill_tokens,
            n_submitted=self._n_submitted, n_admitted=self._n_admitted,
            n_retired=self._n_retired,
            batch_hist=tuple(sorted(self._batch.items())),
            queue_depth_sum=self._queue_sum, queue_samples=self._queue_n,
            kv_row_steps=self._kv_row_steps,
            kv_lifetimes_s=tuple(self._kv_lifetimes),
            queue_waits_s=tuple(self._queue_waits),
            train_steps=self._train_steps, train_tokens=self._train_tokens,
            train_time_s=self._train_time)
        if reset:
            self._reset()
        return win
