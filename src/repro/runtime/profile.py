"""Measured workload profiles: TelemetryWindow -> `workloads.profiler.
Profile`, so runtime observations feed `CoDesignQuery` UNCHANGED and can
be diffed field-by-field against the analytic profiles.

Byte model (mirrors `workloads.profiler._bytes_classes`):
  weights      one stream of active params x 2 bytes/step (x3 training)
  kv           per resident row per layer, (K+V) x n_kv_heads x head_dim
               x itemsize bytes (itemsize 1 for int8 KV, else 2); the
               measured resident rows come from the window's
               `kv_row_steps` integral instead of the analytic
               batch x seq_len assumption
  activations  ~12 materialized tensors/layer x 2 bytes x tokens/step
               x d_model

The hierarchy split (per-instance L1/L2 Hz) is the SAME
`workloads.profiler.hierarchy_split` the analytic path uses, so a
measured-vs-analytic diff isolates genuine traffic differences, not
modeling skew. Lifetimes: KV lifetime is the mean observed
admit->retire residency (the governor uses the max — see
`runtime.governor.traffic_from_window`); activation lifetime is one
layer's slice of the step, as in the analytic profile.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.runtime.telemetry import TelemetryWindow


def kv_row_bytes(cfg) -> float:
    """Bytes one KV-cache row (one token position, one layer) occupies."""
    itemsize = 1.0 if cfg.kv_dtype == "int8" else 2.0
    return 2.0 * cfg.n_kv_heads * cfg.hd() * itemsize


def kv_stream_bytes(win: TelemetryWindow, cfg) -> float:
    """Total KV bytes streamed across the window: every decode step
    re-reads each live slot's resident rows in every layer, so the
    stream is the rows-over-steps integral x per-row bytes x layers."""
    L = max(cfg.n_layers + cfg.n_enc_layers, 1)
    return L * win.kv_row_steps * kv_row_bytes(cfg)


def measured_profile(win: TelemetryWindow, cfg, *,
                     arch: Optional[str] = None, shape: str = "measured",
                     n_devices: int = 1,
                     step_time_s: Optional[float] = None):
    """Convert one telemetry window into a frozen Profile.

    `step_time_s` overrides the per-step time (defaults to the window's
    virtual-clock step, else observed duration / steps — note the
    latter includes idle time). `n_devices` splits the traffic when the
    measured engine stands in for a pod (default 1: profile the device
    that actually ran)."""
    from repro.models.model import Model
    from repro.workloads.profiler import Profile, hierarchy_split

    if win.train_steps and win.decode_steps:
        raise ValueError("telemetry window mixes serving and training "
                         "steps; snapshot them separately")
    kind = "train" if win.train_steps else "decode"
    steps = win.train_steps or win.decode_steps
    if steps == 0:
        raise ValueError("empty telemetry window: no model steps observed")
    if step_time_s is not None:
        step = float(step_time_s)
    elif win.step_time_s is not None:
        step = win.step_time_s
    elif kind == "train":
        step = win.train_time_s / steps
    else:
        step = win.duration_s / steps
    L = max(cfg.n_layers + cfg.n_enc_layers, 1)
    n_active = Model(cfg).param_count(active_only=True)

    if kind == "train":
        toks = win.train_tokens / steps            # tokens per step
        wb = 2.0 * n_active * 3.0                  # fwd + bwd(dgrad+wgrad)
        kvb = 0.0
        kv_life = step
        flops_per_step = 3.0 * 2.0 * n_active * toks
    else:
        toks = win.mean_batch
        wb = 2.0 * n_active
        kvb = L * win.mean_kv_rows * kv_row_bytes(cfg)
        kv_life = sum(win.kv_lifetimes_s) / len(win.kv_lifetimes_s) \
            if win.kv_lifetimes_s else win.duration_s
        flops_per_step = 2.0 * n_active * toks
    act = 2.0 * toks * cfg.d_model * 12
    l1_hz, l2_hz = hierarchy_split(
        flops_per_step / step / n_devices,
        (wb + kvb + act) / n_devices / step)
    return Profile(
        arch or f"measured:{cfg.name}", shape, kind, step, wb, kvb,
        act / L,
        weight_reuse_s=3600.0 * 24,
        kv_lifetime_s=kv_life,
        act_lifetime_s=step / L,
        l1_read_hz=l1_hz,
        l2_read_hz=l2_hz)


DIFF_FIELDS = ("step_time_s", "weights_bytes", "kv_bytes",
               "act_bytes_per_layer", "l1_read_hz", "l2_read_hz")


def diff_profiles(measured, analytic,
                  fields=DIFF_FIELDS) -> Dict[str, float]:
    """Relative deviation per field: (measured - analytic) / analytic
    (exact-zero analytic fields report 0.0 on match, 1.0 on mismatch)."""
    out = {}
    for f in fields:
        a, m = getattr(analytic, f), getattr(measured, f)
        out[f] = (m - a) / a if a else float(m != a)
    return out
