"""Deterministic traffic replay: drive a ServeEngine through scripted
phases and snapshot one TelemetryWindow per phase.

Scenarios are the benchmark's unit of traffic shape (chat burst, batch
offline, long context). Replays are seeded and step-count-driven, so the
same scenario on a plain engine and on a telemetry-instrumented engine
produces bit-identical greedy streams — the parity check in
tests/test_runtime.py and benchmarks/bench_runtime.py.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.serving.engine import Request


@dataclasses.dataclass(frozen=True)
class Phase:
    """One scripted traffic phase: submit `n_requests` identical-shape
    requests, then drive `steps` engine steps (idle steps tick the
    collector's virtual clock so quiet phases dilute window rates)."""
    name: str
    n_requests: int
    prompt_len: int
    max_new_tokens: int
    steps: int


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    phases: Tuple[Phase, ...]


def run_scenario(eng, scenario: Scenario, *, seed: int = 0,
                 collector=None, rid_base: int = 0) -> List:
    """Replay `scenario` on `eng`; returns one collector window per phase
    (empty list when no collector is attached — the plain-engine side of
    a parity comparison).

    The FINAL phase drains the engine before its snapshot, so scenarios
    compose on a reused (compile-warm) engine without leaking live slots
    into the next replay."""
    rng = np.random.default_rng(seed)
    windows = []
    rid = rid_base
    for pi, ph in enumerate(scenario.phases):
        for _ in range(ph.n_requests):
            prompt = rng.integers(
                0, eng.cfg.vocab_size, ph.prompt_len).astype(np.int32)
            eng.submit(Request(rid=rid, prompt=prompt,
                               max_new_tokens=ph.max_new_tokens,
                               temperature=0.0))
            rid += 1
        for _ in range(ph.steps):
            if not eng.step() and collector is not None:
                collector.tick(eng.decode_chunk)
        if pi == len(scenario.phases) - 1:
            while eng.queue or any(r is not None for r in eng.active):
                eng.step()
        if collector is not None:
            windows.append(collector.snapshot(reset=True))
    return windows
