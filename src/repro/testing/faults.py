"""Deterministic, seeded fault injection for the compile fleet.

Chaos testing only proves something if the chaos is REPRODUCIBLE: every
fault decision here is a pure function of `(seed, salt, kind, key)` —
a SHA-256 roll, no RNG state, no wall clock — so a failing run replays
bit-for-bit from its spec string. The injector monkeypatches exactly
the seams the fault-tolerance layer defends:

  * **store put**  — torn writes: the artifact file is truncated AFTER
    the atomic rename, modelling a host crash mid-flush. The store's
    checksum turns this into a miss + self-heal on next read.
  * **store get**  — checksum corruption: a byte inside the entry's
    `data` section is flipped before the real read runs, modelling
    bit-rot. Same self-heal path.
  * **evaluation** — node exceptions and slow nodes on
    `dse_batch.evaluate_batch` / `evaluate_vdd_lattice` /
    `char_batch.characterize`, modelling transient device failures. An
    eval fault fires at most ONCE per (key, process), so a retried
    request eventually lands on an attempt that succeeds — the fleet's
    bounded-retry contract is what's under test, not an unwinnable
    request.
  * **worker liveness** — `die_after_puts=N` hard-kills the process
    (`os._exit(137)`) after its N-th successful artifact publish: a
    worker killed MID-WAVE, with some artifacts published and some
    leases still held. Lease expiry + steal must reclaim the rest.
  * **poison requests** — any request whose id contains the `poison`
    marker raises on every attempt, in every worker: the one failure
    class retry can never fix, which must end in quarantine (a
    structured error response), not a wedged fleet.

`FaultSpec` round-trips through a `k=v,k=v` string so a spec crosses
the subprocess boundary on the worker command line
(`repro.launch.fleet --worker --faults "seed=7,tear_rate=0.3,..."`).
Per-worker `salt` decorrelates decisions between workers (two workers
tearing the SAME key on their first put would starve the heal path).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import time
from collections import Counter
from dataclasses import dataclass
from typing import Optional

__all__ = ["FaultInjector", "FaultSpec", "InjectedFault"]


class InjectedFault(RuntimeError):
    """An error raised (or a corruption planted) by the harness."""


@dataclass(frozen=True)
class FaultSpec:
    seed: int = 0
    salt: str = ""                 # per-worker decorrelation
    tear_rate: float = 0.0         # P(torn write) per put key
    corrupt_rate: float = 0.0      # P(bit flip) per get key
    eval_fail_rate: float = 0.0    # P(exception) per evaluation key
    eval_slow_rate: float = 0.0    # P(stall) per evaluation key
    slow_s: float = 0.05           # stall duration
    die_after_puts: int = 0        # 0 = never; else hard-exit after Nth
    poison: str = ""               # request-id marker that always fails

    _FLOATS = ("tear_rate", "corrupt_rate", "eval_fail_rate",
               "eval_slow_rate", "slow_s")

    @classmethod
    def parse(cls, spec: str) -> "FaultSpec":
        """`"seed=7,salt=w0,tear_rate=0.3"` -> FaultSpec."""
        kw = {}
        for part in (spec or "").split(","):
            part = part.strip()
            if not part:
                continue
            k, _, v = part.partition("=")
            if k in ("seed", "die_after_puts"):
                kw[k] = int(v)
            elif k in cls._FLOATS:
                kw[k] = float(v)
            elif k in ("salt", "poison"):
                kw[k] = v
            else:
                raise ValueError(f"unknown fault field {k!r}")
        return cls(**kw)

    def encode(self) -> str:
        out = []
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if v != f.default:
                out.append(f"{f.name}={v}")
        return ",".join(out)

    def any_faults(self) -> bool:
        return bool(self.tear_rate or self.corrupt_rate
                    or self.eval_fail_rate or self.eval_slow_rate
                    or self.die_after_puts or self.poison)


def _cfg_token(cfg) -> tuple:
    return (cfg.word_size, cfg.num_words, cfg.cell, cfg.write_vt,
            cfg.wwlls, cfg.wwl_boost)


class FaultInjector:
    """Installs the hooks above on a concrete store instance and/or the
    evaluation modules; `uninstall()` (or the context manager) restores
    everything. `counts` records every fault actually fired, so tests
    and the fleet bench can assert the chaos was real."""

    def __init__(self, spec: FaultSpec):
        self.spec = spec if isinstance(spec, FaultSpec) \
            else FaultSpec.parse(spec)
        self.counts: Counter = Counter()
        self._once = set()             # (kind, key) that already fired
        self._puts = 0
        self._restore = []             # (obj, attr, original)

    # ------------------------------------------------------------------
    # deterministic decisions
    # ------------------------------------------------------------------
    def _roll(self, kind: str, key: str) -> float:
        h = hashlib.sha256(
            f"{self.spec.seed}:{self.spec.salt}:{kind}:{key}".encode()
        ).hexdigest()[:12]
        return int(h, 16) / float(16 ** 12)

    def _fire_once(self, kind: str, key: str, rate: float) -> bool:
        """Seeded decision that fires at most once per (kind, key) in
        this process — transient faults, not permanent ones."""
        if rate <= 0.0:
            return False
        token = (kind, key)
        if token in self._once:
            return False
        if self._roll(kind, key) >= rate:
            return False
        self._once.add(token)
        return True

    # ------------------------------------------------------------------
    # install / uninstall
    # ------------------------------------------------------------------
    def install(self, store=None, evals: bool = False) -> "FaultInjector":
        if store is not None:
            self._wrap(store, "put", self._put_hook)
            self._wrap(store, "get", self._get_hook)
            self._store = store
        if evals:
            from repro.core import dse_batch
            from repro.core.spice import char_batch
            self._wrap(dse_batch, "evaluate_batch",
                       self._eval_hook("evaluate_batch"))
            self._wrap(dse_batch, "evaluate_vdd_lattice",
                       self._eval_hook("evaluate_vdd_lattice"))
            self._wrap(char_batch, "characterize",
                       self._eval_hook("characterize"))
        return self

    def _wrap(self, obj, attr: str, factory) -> None:
        """`factory(original) -> replacement`; original restored by
        uninstall()."""
        orig = getattr(obj, attr)
        self._restore.append((obj, attr, orig))
        setattr(obj, attr, factory(orig))

    def uninstall(self) -> None:
        while self._restore:
            obj, attr, orig = self._restore.pop()
            setattr(obj, attr, orig)

    def __enter__(self) -> "FaultInjector":
        return self

    def __exit__(self, *exc) -> None:
        self.uninstall()

    # ------------------------------------------------------------------
    # hooks
    # ------------------------------------------------------------------
    def check_request(self, request: dict) -> None:
        """Raise for poison requests (called by fleet workers before
        submission). Fires on EVERY attempt — poison is the permanent
        failure class that must end in quarantine."""
        marker = self.spec.poison
        if marker and marker in str(request.get("id", "")):
            self.counts["poison_hits"] += 1
            raise InjectedFault(
                f"poison request {request.get('id')!r}")

    def _put_hook(self, orig):
        def put(key, data):
            orig(key, data)
            if self._fire_once("tear", key, self.spec.tear_rate):
                self._tear(key)
            self._puts += 1
            if self.spec.die_after_puts and \
                    self._puts >= self.spec.die_after_puts:
                self.counts["worker_suicides"] += 1
                os._exit(137)      # SIGKILL-equivalent: no cleanup runs
        return put

    def _get_hook(self, orig):
        def get(key):
            if self._fire_once("corrupt", key, self.spec.corrupt_rate):
                self._flip_byte(key)
            return orig(key)
        return get

    def _eval_hook(self, name: str):
        def make(orig):
            def wrapped(cfgs, *args, **kwargs):
                key = name + ":" + hashlib.sha256(
                    repr([_cfg_token(c) for c in cfgs]).encode()
                ).hexdigest()[:16]
                if self._fire_once("slow", key, self.spec.eval_slow_rate):
                    self.counts["slow_evals"] += 1
                    time.sleep(self.spec.slow_s)
                if self._fire_once("fail", key, self.spec.eval_fail_rate):
                    self.counts["eval_faults"] += 1
                    raise InjectedFault(
                        f"injected {name} failure ({key})")
                return orig(cfgs, *args, **kwargs)
            return wrapped
        return make

    # ------------------------------------------------------------------
    # file-level damage
    # ------------------------------------------------------------------
    def _artifact_path(self, key: str) -> Optional[str]:
        store = getattr(self, "_store", None)
        if store is None:
            return None
        path = store._path(key)
        return path if os.path.exists(path) else None

    def _tear(self, key: str) -> None:
        """Truncate the renamed artifact to half its length — the torn
        state a crash between rename and (missing) fsync leaves."""
        path = self._artifact_path(key)
        if path is None:
            return
        size = os.path.getsize(path)
        if size < 4:
            return
        with open(path, "r+b") as f:
            f.truncate(size // 2)
        self.counts["torn_writes"] += 1

    def _flip_byte(self, key: str) -> None:
        """Flip one byte inside the entry's `data` section (the part
        the sha256 covers), modelling bit-rot the checksum must catch."""
        path = self._artifact_path(key)
        if path is None:
            return
        with open(path, "r+b") as f:
            blob = f.read()
            anchor = blob.find(b'"data"')
            if anchor < 0 or len(blob) - anchor < 12:
                return
            pos = anchor + 8 + (len(blob) - anchor - 10) // 2
            f.seek(pos)
            f.write(bytes([blob[pos] ^ 0x01]))
        self.counts["corrupted_reads"] += 1
