"""Deterministic fault-injection harness for chaos tests and the
fleet benchmark (`repro.testing.faults`)."""
from repro.testing.faults import FaultInjector, FaultSpec, InjectedFault

__all__ = ["FaultInjector", "FaultSpec", "InjectedFault"]
