"""File-based lease/claim protocol over a shared artifact-store
directory.

N compile-fleet workers share one content-addressed `ArtifactStore`
(`repro.api.store`). Node keys name results, so the only coordination
the fleet needs is "who computes a missing artifact" — everything else
is the store's atomic-rename publish. This module provides that claim:

  * `try_claim(key)` atomically creates `<root>/_leases/<key>.lease`
    with `O_CREAT | O_EXCL` (the POSIX mutual-exclusion primitive that
    works on a shared directory): exactly one process wins, no matter
    how many race.
  * The claim file holds the owner id; its **mtime is the heartbeat**.
    A background daemon thread re-touches every held lease, so a live
    owner's lease never expires — even while the owner is blocked in a
    long device evaluation.
  * A lease whose mtime is older than `ttl_s` belongs to a DEAD worker.
    Anyone may steal it: take the per-key breaker lock (its own O_EXCL
    file), RE-CHECK expiry under the lock, unlink, then race the normal
    `O_CREAT | O_EXCL` claim. The re-check under mutual exclusion is
    what makes stealing safe — a slow second stealer can never tear
    down the fresh lease a quicker winner just created. A crashed
    worker's in-flight nodes are therefore reclaimed after at most one
    TTL, never lost.
  * `acquire(key, have)` is the waiter's loop: poll `have()` (usually a
    store read) until the owner publishes, or steal the lease once it
    expires. Callers must publish their own claimed work BEFORE waiting
    on foreign keys — that ordering is what makes the protocol
    deadlock-free (no one ever blocks while holding an unpublished
    claim; see `repro.api.executor`).

The manager also keeps an append-only evaluation log
(`_leases/evals.log`, one `key<TAB>reason<TAB>owner` line per fresh
device evaluation, written with `O_APPEND`) so a fleet run can PROVE
"zero duplicate lattice evaluations": every key must appear with reason
`fresh` at most once across all workers; `steal` (reclaimed from a dead
owner) and `heal` (recompute after detected store corruption) are the
sanctioned recovery paths and are reported separately.
"""
from __future__ import annotations

import json
import os
import re
import socket
import threading
import time
import uuid
from collections import Counter
from typing import Callable, Dict, Optional, Tuple

__all__ = ["Lease", "LeaseManager"]

_SAFE = re.compile(r"[^-\w.]")


class Lease:
    """A held claim on one key. Release after publishing the artifact;
    an unreleased lease expires (and is stolen) one TTL after its last
    heartbeat."""

    __slots__ = ("_manager", "key", "path", "stolen")

    def __init__(self, manager: "LeaseManager", key: str, path: str,
                 stolen: bool):
        self._manager = manager
        self.key = key
        self.path = path
        self.stolen = stolen            # claimed by expiring a dead owner

    def heartbeat(self) -> None:
        self._manager._touch_if_owned(self.path)

    def release(self) -> None:
        self._manager._release(self)

    def __repr__(self) -> str:          # pragma: no cover - debug aid
        return f"Lease({self.key!r}, stolen={self.stolen})"


class LeaseManager:
    """Claim/heartbeat/steal coordinator for one store directory.

    Thread-safe; every worker process builds its own manager over the
    SHARED `root` (normally `ArtifactStore.root`). `owner` defaults to
    `host:pid:nonce` and is written into each claim file so stale
    leases are attributable and release/heartbeat can verify ownership
    (a stolen lease is never touched or unlinked by its old owner).
    """

    def __init__(self, root: str, owner: Optional[str] = None,
                 ttl_s: float = 30.0, poll_s: float = 0.02,
                 heartbeat: bool = True):
        self.root = os.path.join(os.fspath(root), "_leases")
        self.owner = owner or (f"{socket.gethostname()}:{os.getpid()}:"
                               f"{uuid.uuid4().hex[:8]}")
        self.ttl_s = float(ttl_s)
        self.poll_s = float(poll_s)
        self._heartbeat = bool(heartbeat)
        self._held: Dict[str, Lease] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._hb_thread: Optional[threading.Thread] = None
        self.counts: Counter = Counter()

    # ------------------------------------------------------------------
    # paths and file helpers
    # ------------------------------------------------------------------
    def _path(self, key: str) -> str:
        return os.path.join(self.root, _SAFE.sub("_", key) + ".lease")

    def _read_owner(self, path: str) -> Optional[str]:
        try:
            with open(path) as f:
                return json.load(f).get("owner")
        except (OSError, ValueError):
            return None

    def _touch_if_owned(self, path: str) -> None:
        """Refresh the heartbeat mtime — but only while the file is
        still OUR claim (never resuscitate a lease someone stole)."""
        if self._read_owner(path) == self.owner:
            try:
                os.utime(path)
            except OSError:
                pass

    def _expired(self, path: str) -> bool:
        try:
            return time.time() - os.stat(path).st_mtime > self.ttl_s
        except OSError:
            return False                 # vanished: claimable, not stale

    def _break(self, path: str) -> bool:
        """Remove an EXPIRED lease so it can be re-claimed. The caller's
        expiry check races: by the time we act, a quicker stealer may
        have broken the old lease AND someone may have re-claimed it
        fresh. So removal happens under a per-key breaker lock (its own
        `O_CREAT | O_EXCL` file) with expiry RE-CHECKED inside — of N
        racing stealers at most one unlinks, and a fresh lease is never
        torn down. A breaker orphaned by a crash mid-break expires like
        a lease (its critical section is microseconds, so an old one is
        always dead) and is cleared for the next pass."""
        brk = path + ".brk"
        try:
            fd = os.open(brk, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            if self._expired(brk):
                try:
                    os.unlink(brk)
                except OSError:
                    pass
            return False
        except OSError:
            return False
        try:
            os.close(fd)
            if not self._expired(path):
                return False           # re-claimed while we raced
            try:
                os.unlink(path)
            except OSError:
                return False
            self.counts["broken"] += 1
            return True
        finally:
            try:
                os.unlink(brk)
            except OSError:
                pass

    # ------------------------------------------------------------------
    # claim / release
    # ------------------------------------------------------------------
    def try_claim(self, key: str) -> Optional[Lease]:
        """Claim `key` if it is unclaimed (or its claim expired).
        Returns the Lease, or None while a LIVE foreign owner holds it.
        Never blocks on a live owner."""
        os.makedirs(self.root, exist_ok=True)
        path = self._path(key)
        stolen = False
        for _ in range(8):               # bounded retries around races
            try:
                fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                if not self._expired(path):
                    return None
                if self._break(path):
                    stolen = True
                continue                 # re-race the O_EXCL create
            except OSError:
                return None
            with os.fdopen(fd, "w") as f:
                json.dump({"owner": self.owner, "key": key}, f)
            lease = Lease(self, key, path, stolen)
            with self._lock:
                self._held[key] = lease
            self.counts["claims"] += 1
            if stolen:
                self.counts["steals"] += 1
            self._ensure_heartbeat()
            return lease
        return None

    def _release(self, lease: Lease) -> None:
        with self._lock:
            self._held.pop(lease.key, None)
        # unlink only our own claim file: if the lease was stolen, the
        # stealer renamed it away (or re-created it as THEIRS)
        if self._read_owner(lease.path) == self.owner:
            try:
                os.unlink(lease.path)
            except OSError:
                pass
        self.counts["releases"] += 1

    def acquire(self, key: str, have: Callable[[], object],
                timeout: Optional[float] = None) -> Tuple[str, object]:
        """Wait-or-claim loop: returns `("have", value)` as soon as
        `have()` yields a value (the owner published), or
        `("own", lease)` once we hold the claim — immediately if the key
        is unclaimed, or after stealing an expired lease (owner died
        without publishing). Raises TimeoutError past `timeout`."""
        deadline = None if timeout is None else time.time() + timeout
        waited = False
        while True:
            val = have()
            if val is not None:
                if waited:
                    self.counts["waits_satisfied"] += 1
                return ("have", val)
            lease = self.try_claim(key)
            if lease is not None:
                return ("own", lease)
            if not waited:
                waited = True
                self.counts["waits"] += 1
            if deadline is not None and time.time() > deadline:
                raise TimeoutError(
                    f"gave up waiting {timeout}s for lease/artifact "
                    f"{key!r}")
            time.sleep(self.poll_s)

    # ------------------------------------------------------------------
    # heartbeats
    # ------------------------------------------------------------------
    def _ensure_heartbeat(self) -> None:
        if not self._heartbeat or self._hb_thread is not None:
            return
        t = threading.Thread(target=self._hb_loop, daemon=True,
                             name="lease-heartbeat")
        self._hb_thread = t
        t.start()

    def _hb_loop(self) -> None:
        # touch every held lease a few times per TTL, so a lease only
        # ever expires when its owner PROCESS is gone
        while not self._stop.wait(max(self.ttl_s / 4.0, 0.01)):
            with self._lock:
                held = list(self._held.values())
            for lease in held:
                self._touch_if_owned(lease.path)

    def close(self) -> None:
        self._stop.set()

    # ------------------------------------------------------------------
    # evaluation accounting (the zero-duplicates proof)
    # ------------------------------------------------------------------
    def log_eval(self, key: str, reason: str) -> None:
        """Record one fresh device evaluation of `key` by this owner.
        `reason` is `fresh` (first computation), `steal` (reclaimed from
        an expired lease) or `heal` (recompute after the store reported
        the artifact corrupt). One O_APPEND write: atomic for lines this
        short on POSIX."""
        os.makedirs(self.root, exist_ok=True)
        line = f"{key}\t{reason}\t{self.owner}\n"
        fd = os.open(os.path.join(self.root, "evals.log"),
                     os.O_CREAT | os.O_WRONLY | os.O_APPEND, 0o644)
        try:
            os.write(fd, line.encode())
        finally:
            os.close(fd)
        self.counts[f"evals_{reason}"] += 1

    @staticmethod
    def read_eval_log(store_root: str) -> Dict[str, Counter]:
        """{key: Counter(reason -> evaluations)} across every worker
        that shared `store_root` (the store directory, not `_leases`)."""
        path = os.path.join(os.fspath(store_root), "_leases", "evals.log")
        out: Dict[str, Counter] = {}
        try:
            with open(path) as f:
                for line in f:
                    parts = line.rstrip("\n").split("\t")
                    if len(parts) >= 2:
                        out.setdefault(parts[0], Counter())[parts[1]] += 1
        except OSError:
            pass
        return out

    @staticmethod
    def duplicate_evals(store_root: str) -> Dict[str, int]:
        """Keys evaluated fresh MORE than once — the fleet invariant is
        that this is empty (steals/heals are sanctioned recoveries and
        excluded)."""
        return {k: c["fresh"] for k, c in
                LeaseManager.read_eval_log(store_root).items()
                if c.get("fresh", 0) > 1}

    def stats(self) -> dict:
        with self._lock:
            held = len(self._held)
        return {"owner": self.owner, "ttl_s": self.ttl_s, "held": held,
                **dict(self.counts)}
