"""Unified OpenGCRAM query API — ONE user-facing entry point.

The paper pitches a *compiler*: a config goes in, circuits and
area/delay/power reports come out, and a DSE layer matches banks to
workload demands (§III, Fig 10). This package is that surface:

    from repro.api import Session, CompileQuery, SweepQuery, MatchQuery

    s = Session()                          # tech + caches
    rep = s.run(CompileQuery(BankConfig(32, 32, cell="gc2t_nn")))
    table = s.run(SweepQuery())            # batched (vmapped) lattice
    match = s.run(MatchQuery(demands=tuple(profile.demands())))
    best = table.pareto().best("eff_bw_bps")

Queries are declarative dataclasses; every result shares the `Result`
interface (`.as_dict()` / `.write(outdir)`). A `Session` memoizes
per-config evaluations and whole sweep tables, and `SweepQuery` runs
through the struct-of-arrays `jax.vmap` evaluator in
`repro.core.dse_batch` (scalar reference: `repro.core.dse.evaluate`).
`SweepQuery(fidelity="transient")` escalates to the HSPICE-class tier:
the batched Newton transient engine (`repro.core.spice.char_batch`)
simulates every gain-cell read column, one compiled program per cell
topology, and the returned `CalibratedTable` reports the
analytic-vs-transient error per point. `SweepQuery(fidelity="layout")`
escalates once more: every bank is placed + routed + DRC/LVS-verified
(`repro.geom`) and the transient engine runs on the layout-EXTRACTED
read-column parasitics, returning a `LayoutTable` that carries the
per-point geometry verification reports.

`CoDesignQuery` closes the loop between the two halves of the repo: it
consumes AI-workload Profiles from `repro.workloads.profiler`, evaluates
the design lattice across an operating-voltage ladder (the paper's
"retention tuned on-the-fly by changing the operating voltage"), and
returns a per-workload heterogeneous memory plan — best L1 bank at its
best voltage + best L2 bank at its (possibly different) one — with the
whole (vdd x lattice x demand) cube batched on device
(`repro.core.dse_batch`).

Execution is PLANNED, not eager: every query lowers to a small DAG of
content-hash-keyed evaluation nodes (`repro.api.plan`), and a
coalescing executor (`repro.api.executor`) runs them — `Session.run`
is a thin wrapper over `submit(query) -> Future` / `run_many(queries)`,
which dedupe identical nodes across concurrently submitted queries and
union distinct lattice evaluations into single padded device batches,
bit-identical to sequential runs. `Session(store=...)` adds the
content-addressed on-disk artifact cache (`repro.api.store`), so
evaluated tables and characterizations survive process restarts;
`repro.launch.compile_service` serves JSON queries from many tenants
through one coalescing session.

The legacy entry points (`GCRAMCompiler`, `dse.sweep`,
`multibank.build_multibank`) remain as thin deprecated shims over this
API.
"""
from repro.api.executor import Executor, QueryFuture
from repro.api.leases import Lease, LeaseManager
from repro.api.queries import (CoDesignQuery, CompileQuery, MatchQuery,
                               OptimizeQuery, Query, SweepQuery)
from repro.api.results import (CalibratedTable, CoDesignReport,
                               CompileResult, DesignTable, LayoutTable,
                               MatchResult, OptimizeResult, Result)
from repro.api.session import Session
from repro.api.store import ArtifactStore

__all__ = [
    "Session", "Query", "CompileQuery", "SweepQuery", "MatchQuery",
    "CoDesignQuery", "OptimizeQuery", "Result", "CompileResult",
    "DesignTable", "CalibratedTable", "LayoutTable", "MatchResult",
    "CoDesignReport", "OptimizeResult", "Executor", "QueryFuture",
    "ArtifactStore", "Lease", "LeaseManager",
]
