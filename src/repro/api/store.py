"""Content-addressed on-disk artifact cache for planned query execution.

Plan nodes (see `repro.api.plan`) are keyed by a content hash of
`(kind, tech hash, lattice-shaping payload)`, so a node's key names its
result as much as its work. This store persists those results —
evaluated lattice points, transient characterizations, (vdd x lattice)
tables — as JSON files keyed by node key, letting tables and
characterizations survive process restarts: many sessions (or a fleet
of compile-service workers sharing a directory) pay each lattice once.

Layout: `<root>/<kind>/<hash>.json`, one artifact per file, each
wrapped as `{"key", "sha256", "data"}`. The sha256 covers the canonical
JSON of `data`; `get()` verifies it and treats any unreadable,
unparsable or checksum-failing entry as a miss (counted in `corrupt`),
so a torn write or bit-rot degrades to recompute, never to a wrong
result. Writes go through a temp file + `os.replace`, so concurrent
readers and writers only ever see whole artifacts. Floats round-trip
exactly through JSON (shortest-repr), so a store hit is bit-identical
to the evaluation it replaced; non-finite values use the Python
`json` extensions (Infinity/NaN), which this module both writes and
reads.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import time

__all__ = ["ArtifactStore"]


def _digest(data) -> str:
    blob = json.dumps(data, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


class ArtifactStore:
    """Directory-backed artifact cache. Thread/process-safe for the
    single-writer-per-key pattern the executor uses (atomic renames);
    hit/miss/corruption counters are per-instance, not persisted."""

    def __init__(self, root: str):
        self.root = str(root)
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.corrupt = 0
        self.pruned = 0
        self.swept = 0

    def _path(self, key: str) -> str:
        kind, _, h = key.partition("-")
        return os.path.join(self.root, kind, (h or "misc") + ".json")

    def has(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def get(self, key: str):
        """The artifact for `key`, or None on miss OR corruption (the
        caller recomputes either way). Corrupt entries are unlinked so
        the recompute's put() repairs the store in place."""
        path = self._path(key)
        if not os.path.exists(path):
            self.misses += 1
            return None
        try:
            with open(path) as f:
                blob = json.load(f)
            data = blob["data"]
            if blob.get("sha256") != _digest(data):
                raise ValueError("artifact checksum mismatch")
        except (OSError, ValueError, KeyError, TypeError):
            self.corrupt += 1
            try:
                os.unlink(path)
            except OSError:
                pass
            return None
        self.hits += 1
        return data

    def put(self, key: str, data) -> None:
        """Persist `data` (JSON-able) under `key`, atomically. The temp
        file is fsync'd BEFORE the rename: a host crash can leave a
        stale `.tmp` (swept by `sweep_tmp`) or the old entry, but never
        a truncated file under the final name."""
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        blob = {"key": key, "sha256": _digest(data), "data": data}
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(blob, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        self.puts += 1

    def sweep_tmp(self, max_age_s: float = 600.0) -> int:
        """Unlink `*.tmp` files older than `max_age_s` — the droppings
        of writers killed between mkstemp and the atomic rename. Safe
        concurrently: an in-flight writer's temp file is younger than
        any sane age bound."""
        cutoff = time.time() - max_age_s
        swept = 0
        for dirpath, _, files in os.walk(self.root):
            for name in files:
                if not name.endswith(".tmp"):
                    continue
                p = os.path.join(dirpath, name)
                try:
                    if os.stat(p).st_mtime <= cutoff:
                        os.unlink(p)
                        swept += 1
                except OSError:
                    pass
        self.swept += swept
        return swept

    def prune(self, max_age_s: float) -> int:
        """Drop artifacts not touched within `max_age_s` (plus stale
        temp files of the same age) — the retention policy for a
        long-lived fleet store. Returns the number of entries removed;
        a pruned entry simply recomputes on next use."""
        cutoff = time.time() - max_age_s
        pruned = 0
        for dirpath, _, files in os.walk(self.root):
            for name in files:
                if not name.endswith(".json"):
                    continue
                p = os.path.join(dirpath, name)
                try:
                    if os.stat(p).st_mtime <= cutoff:
                        os.unlink(p)
                        pruned += 1
                except OSError:
                    pass
        self.pruned += pruned
        self.sweep_tmp(max_age_s)
        return pruned

    def drop(self, key: str) -> None:
        """Remove an entry the caller found unusable (e.g. it decodes
        against a different artifact schema), counting it corrupt so a
        recompute's put() can repair the store in place."""
        self.corrupt += 1
        try:
            os.unlink(self._path(key))
        except OSError:
            pass

    def __len__(self) -> int:
        n = 0
        for dirpath, _, files in os.walk(self.root):
            if os.path.basename(dirpath) == "_leases":
                continue                 # lease/claim files, not artifacts
            n += sum(f.endswith(".json") for f in files)
        return n

    def stats(self) -> dict:
        return {"root": self.root, "entries": len(self),
                "hits": self.hits, "misses": self.misses,
                "puts": self.puts, "corrupt": self.corrupt,
                "pruned": self.pruned, "swept": self.swept}
