"""Uniform Result hierarchy returned by Session queries.

Every result exposes `.as_dict()` (JSON-ready) and `.write(outdir)`
(writes `<outdir>/<filename>`; CompileResult additionally emits its
netlists + floorplan, inherited from the compiler Report).
"""
from __future__ import annotations

import abc
import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core import dse
from repro.core.compiler import Report
from repro.core.dse import Demand, DesignPoint


class Result(abc.ABC):
    filename = "result.json"

    @abc.abstractmethod
    def as_dict(self) -> dict:
        ...

    def write(self, outdir: str) -> str:
        os.makedirs(outdir, exist_ok=True)
        with open(os.path.join(outdir, self.filename), "w") as f:
            json.dump(self.as_dict(), f, indent=1, default=str)
        return outdir


# the compiler Report already implements as_dict()/write(); register it
# so `isinstance(x, Result)` holds across the whole hierarchy
Result.register(Report)
CompileResult = Report


@dataclass
class DesignTable(Result):
    """Evaluated design lattice: a list of DesignPoints + query context."""
    points: List[DesignPoint]
    query: object = None
    filename = "design_table.json"

    def __len__(self):
        return len(self.points)

    def __iter__(self):
        return iter(self.points)

    def __getitem__(self, i):
        return self.points[i]

    def pareto(self, keys=("area_um2", "f_max_hz", "standby_w")):
        return DesignTable(dse.pareto(self.points, keys=keys), self.query)

    def feasible(self, demand: Demand, *, allow_refresh=True):
        return DesignTable(
            [p for p in self.points
             if dse.feasible(p, demand, allow_refresh=allow_refresh)],
            self.query)

    def best(self, key: str = "eff_bw_bps", *, minimize=None
             ) -> Optional[DesignPoint]:
        """Best feasible point by `key`. Direction follows the same
        convention as `pareto()` (dse.PARETO_MAXIMIZE members are
        maximized, everything else — area, power, delays — minimized);
        pass minimize=True/False to override."""
        ok = [p for p in self.points if p.swing_ok]
        if not ok:
            return None
        if minimize is None:
            minimize = key not in dse.PARETO_MAXIMIZE
        return (min if minimize else max)(ok, key=lambda p: getattr(p, key))

    def as_dict(self):
        return {"n_points": len(self.points),
                "rows": [p.as_dict() for p in self.points]}


@dataclass
class CalibratedTable(DesignTable):
    """A DesignTable whose gain-cell points also carry a transient
    (HSPICE-class) characterization of the read column — the result of
    `SweepQuery(fidelity="transient")`.

    `transient[i]` aligns with `points[i]`: a
    `repro.core.spice.char_batch.TransientChar` (simulated sense-swing
    time, analytic estimate, relative deviation) or None for non-gain-cell
    configs. `calibration()` summarizes the analytic-vs-transient error —
    the per-lattice view of the paper's GEMTOO-gap claim."""
    transient: List[Optional[object]] = field(default_factory=list)
    filename = "calibration.json"

    def calibration(self) -> dict:
        devs = [c.rel_dev for c in self.transient
                if c is not None and c.swing_ok]
        return {
            "n_points": len(self.points),
            "n_simulated": sum(c is not None for c in self.transient),
            "n_swing_fail": sum(c is not None and not c.swing_ok
                                for c in self.transient),
            "max_rel_dev": max(devs) if devs else None,
            "mean_rel_dev": sum(devs) / len(devs) if devs else None,
        }

    def as_dict(self):
        rows = []
        for i, p in enumerate(self.points):
            # index (not zip) so a mis-sized transient list can never
            # silently truncate the point rows
            c = self.transient[i] if i < len(self.transient) else None
            row = p.as_dict()
            if c is not None:
                row["transient"] = c.as_dict()
            rows.append(row)
        return {"n_points": len(self.points),
                "calibration": self.calibration(), "rows": rows}


@dataclass
class LayoutTable(CalibratedTable):
    """A CalibratedTable whose transient characterization ran on
    LAYOUT-EXTRACTED parasitics — the result of
    `SweepQuery(fidelity="layout")`.

    `geometry[i]` aligns with `points[i]`: the
    `repro.geom.verify.verify_bank` report of that config's placed +
    routed bank (manifest stats, DRC verdict, LVS-lite connectivity
    verdict, extracted read-column RC, scalar-vs-batched extraction
    bit-parity). `geometry_summary()` rolls the verdicts up — the
    all-clean gate `tools/check_geom.py` enforces in CI."""
    geometry: List[Optional[dict]] = field(default_factory=list)
    filename = "layout_table.json"

    def geometry_summary(self) -> dict:
        gs = [g for g in self.geometry if g is not None]
        return {
            "n_points": len(self.points),
            "n_verified": len(gs),
            "n_drc_clean": sum(bool(g.get("drc_clean")) for g in gs),
            "n_lvs_ok": sum(bool(g.get("lvs_ok")) for g in gs),
            "n_extract_bit_identical": sum(
                bool(g.get("extract_bit_identical")) for g in gs),
            "all_clean": all(
                g.get("drc_clean") and g.get("lvs_ok")
                and g.get("extract_bit_identical") for g in gs),
        }

    def as_dict(self):
        out = super().as_dict()
        for i, row in enumerate(out["rows"]):
            g = self.geometry[i] if i < len(self.geometry) else None
            if g is not None:
                row["geometry"] = g
        out["geometry_summary"] = self.geometry_summary()
        return out


@dataclass
class MatchResult(Result):
    """Shmoo of the lattice against workload demands + multibank sizing."""
    grid: Dict[str, Dict[str, bool]]
    rows: List[dict]                      # one summary row per demand
    banks_needed: Dict[str, int]
    table: DesignTable
    filename = "match.json"

    @property
    def pass_rate(self) -> float:
        cells = [v for row in self.grid.values() for v in row.values()]
        return sum(cells) / len(cells) if cells else 0.0

    def as_dict(self):
        return {"demands": self.rows, "banks_needed": self.banks_needed,
                "pass_rate": self.pass_rate, "grid": self.grid}


@dataclass
class CoDesignReport(Result):
    """Per-workload heterogeneous memory plan from `CoDesignQuery`.

    `plans` has one dict per profiled workload:

      {"workload": "arch:shape", "kind": ..., "step_time_s": ...,
       "feasible": bool,                  # both levels plannable
       "total_area_um2": ..., "total_energy_per_inference_j": ...,
       "levels": {"L1": <entry>, "L2": <entry>}}

    and each level entry carries the chosen bank (`DesignPoint.as_dict`
    including its `vdd_scale`), the operating rail `vdd_v` in volts, the
    interleaved-macro sizing (`banks_needed`, `macro_area_um2`,
    `macro_capacity_bits`, `macro_f_max_hz`), the macro standby watts
    and the joules per inference step — or, when infeasible, the demand
    that could not be met. `lattice` is the underlying
    `repro.core.dse_batch.VddLattice` for further slicing."""
    plans: List[dict]
    query: object = None
    lattice: object = None
    filename = "codesign.json"

    def __iter__(self):
        return iter(self.plans)

    def __getitem__(self, workload: str) -> dict:
        for p in self.plans:
            if p["workload"] == workload:
                return p
        raise KeyError(workload)

    @property
    def all_feasible(self) -> bool:
        return all(p["feasible"] for p in self.plans)

    def as_dict(self):
        n_vdd, n_cfg = self.lattice.shape if self.lattice is not None \
            else (0, 0)
        return {"n_workloads": len(self.plans),
                "n_configs": n_cfg, "n_vdd": n_vdd,
                "vdd_scales": list(getattr(self.lattice, "vdd_scales", ())),
                "all_feasible": self.all_feasible,
                "plans": self.plans}


@dataclass
class OptimizeResult(Result):
    """grad_optimize outcome (optimized design + discrete validation)."""
    raw: dict
    query: object = None
    filename = "optimize.json"

    def __getitem__(self, k):
        return self.raw[k]

    @property
    def met(self) -> bool:
        return bool(self.raw.get("met"))

    def as_dict(self):
        return dict(self.raw)
