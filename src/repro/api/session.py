"""Session: the stateful entry point of the unified query API.

A Session binds a TechFile and memoizes work across queries:

  * per-config DesignPoints (shared between sweeps, matches and
    multibank sizing — a MatchQuery after a SweepQuery re-evaluates
    nothing);
  * whole DesignTables keyed by the (hashable, frozen) SweepQuery;
  * compiled Reports keyed by (config, simulate, solver).

Convenience methods (`compile/sweep/match/optimize/evaluate/multibank`)
mirror the Query objects, so both styles work:

    Session().run(SweepQuery(cells=("gc2t_nn",)))
    Session().sweep(SweepQuery(cells=("gc2t_nn",)))
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.api.queries import (CoDesignQuery, CompileQuery, MatchQuery,
                               OptimizeQuery, Query, SweepQuery)
from repro.api.results import (CalibratedTable, CoDesignReport, CompileResult,
                               DesignTable, MatchResult, OptimizeResult,
                               Result)
from repro.core import compiler as compiler_mod
from repro.core import dse
from repro.core import dse_batch
from repro.core import multibank as mb_mod
from repro.core.bank import BankConfig
from repro.core.dse import Demand, DesignPoint
from repro.core.dse_batch import VddLattice, evaluate_batch, \
    evaluate_vdd_lattice
from repro.core.spice import char_batch
from repro.core.techfile import SYN40, TechFile


class Session:
    def __init__(self, tech: TechFile = SYN40):
        self.tech = tech
        self._points: Dict[tuple, DesignPoint] = {}
        self._tables: Dict[SweepQuery, DesignTable] = {}
        self._reports: Dict[tuple, CompileResult] = {}
        # per-config transient characterizations, keyed by
        # (config key, sim_steps, solver) — shared between overlapping
        # transient-fidelity sweeps exactly like the analytic points
        self._tchars: Dict[tuple, object] = {}
        # (sweep query, vdd_scales) -> VddLattice, and whole co-design
        # reports keyed by the (hashable, frozen) CoDesignQuery
        self._vlattices: Dict[tuple, VddLattice] = {}
        self._codesigns: Dict[CoDesignQuery, CoDesignReport] = {}

    # ------------------------------------------------------------------
    def run(self, query: Query) -> Result:
        """Execute any Query; returns its Result."""
        return query.run(self)

    # ------------------------------------------------------------------
    def _adopt(self, cfg: BankConfig) -> BankConfig:
        """Configs evaluated through a session use the session's tech."""
        if cfg.tech is not self.tech:
            cfg = dataclasses.replace(cfg, tech=self.tech)
        return cfg

    @staticmethod
    def _key(cfg: BankConfig) -> tuple:
        return (cfg.word_size, cfg.num_words, cfg.cell, cfg.write_vt,
                cfg.wwlls, cfg.wwl_boost)

    # ------------------------------------------------------------------
    def compile(self, cfg: Optional[BankConfig] = None, *, simulate=False,
                solver="jnp", **cfg_kw) -> CompileResult:
        """One bank -> Report (netlists + floorplan + all reports).
        Accepts a BankConfig or BankConfig kwargs."""
        cfg = self._adopt(cfg if cfg is not None
                          else BankConfig(tech=self.tech, **cfg_kw))
        key = (self._key(cfg), simulate, solver)
        if key not in self._reports:
            self._reports[key] = compiler_mod.compile_bank(
                cfg, simulate=simulate, solver=solver)
        return self._reports[key]

    def evaluate(self, cfg: BankConfig) -> DesignPoint:
        """Scalar-evaluate (and cache) one config."""
        cfg = self._adopt(cfg)
        k = self._key(cfg)
        if k not in self._points:
            self._points[k] = dse.evaluate(cfg)
        return self._points[k]

    def sweep(self, query: SweepQuery = SweepQuery()) -> DesignTable:
        """Evaluate the config lattice; batched via jax.vmap by default.

        fidelity="analytic" returns a DesignTable; fidelity="transient"
        additionally runs the topology-grouped batched transient engine
        over every gain-cell point and returns a CalibratedTable."""
        if query.fidelity not in ("analytic", "transient"):
            raise ValueError(f"unknown SweepQuery fidelity "
                             f"{query.fidelity!r} (analytic | transient)")
        if query.solver not in ("jnp", "pallas"):
            raise ValueError(f"unknown SweepQuery solver {query.solver!r} "
                             "(jnp | pallas)")
        if query.fidelity == "transient" and query.solver == "pallas":
            # the kernel computes in f32; fine for TPU screening sweeps,
            # but it is NOT the float64 accuracy anchor
            import warnings
            warnings.warn(
                "SweepQuery(fidelity='transient', solver='pallas') solves "
                "in float32 inside the Pallas kernel; calibration numbers "
                "are screening-grade only (use solver='jnp' for the f64 "
                "anchor)", stacklevel=2)
        if query in self._tables:
            return self._tables[query]
        cfgs = query.configs(self.tech)
        keys = [self._key(c) for c in cfgs]
        missing, seen = [], set()
        for c, k in zip(cfgs, keys):
            if k not in self._points and k not in seen:
                missing.append(c)
                seen.add(k)
        if missing:
            pts = evaluate_batch(missing) if query.batched \
                else [dse.evaluate(c) for c in missing]
            for c, p in zip(missing, pts):
                self._points[self._key(c)] = p
        points = [self._points[k] for k in keys]
        if query.fidelity == "transient":
            tkeys = [(k, query.sim_steps, query.solver) for k in keys]
            todo, seen = [], set()
            for c, tk in zip(cfgs, tkeys):
                if tk not in self._tchars and tk not in seen:
                    todo.append(c)
                    seen.add(tk)
            if todo:
                chars = char_batch.characterize(
                    todo, n_steps=query.sim_steps, solver=query.solver)
                for c, ch in zip(todo, chars):
                    self._tchars[(self._key(c), query.sim_steps,
                                  query.solver)] = ch
            table = CalibratedTable(points, query,
                                    [self._tchars[tk] for tk in tkeys])
        else:
            table = DesignTable(points, query)
        self._tables[query] = table
        return table

    def match(self, demands: Iterable[Demand],
              sweep: SweepQuery = SweepQuery(), *, allow_refresh=True,
              max_banks=1024) -> MatchResult:
        """Shmoo the lattice against demands; for every demand also size
        an interleaved multibank macro (paper: multi-banked GCRAM serves
        the aggregate L2 request stream no single bank can)."""
        demands = list(demands)
        dkeys = [f"{d.level}:{d.name}" for d in demands]
        if len(set(dkeys)) != len(dkeys):
            raise ValueError(f"duplicate demand keys in match: {dkeys} "
                             "(grid/banks_needed are keyed by level:name)")
        table = self.sweep(sweep)
        # one device program over the whole (points x demands) grid —
        # bit-for-bit with the scalar dse.shmoo loop it replaced
        grid = dse_batch.shmoo_batch(table.points, demands,
                                     allow_refresh=allow_refresh)
        fastest = table.best("f_max_hz")
        rows, banks = [], {}
        for d in demands:
            key = f"{d.level}:{d.name}"
            feas = table.feasible(d, allow_refresh=allow_refresh)
            # densest single bank if one works, else the fastest bank tiled
            pick = max(feas, key=lambda p: p.cfg.bits / p.area_um2) \
                if len(feas) else fastest
            n = mb_mod.banks_needed(pick, d, capacity_bits=d.capacity_bits,
                                    max_banks=max_banks,
                                    allow_refresh=allow_refresh) \
                if pick is not None else max_banks + 1
            banks[key] = n
            rows.append({
                "demand": key, "read_freq_hz": d.read_freq_hz,
                "lifetime_s": d.lifetime_s,
                "capacity_bits": d.capacity_bits,
                "n_feasible": len(feas),
                # n > max_banks is banks_needed's infeasibility sentinel:
                # even a max_banks-wide macro cannot serve this demand
                "macro_feasible": n <= max_banks,
                "banks_needed": n,
                "bank": pick.as_dict() if pick is not None else None,
            })
        return MatchResult(grid, rows, banks, table)

    def multibank(self, cfg: BankConfig, n_banks: int) -> "mb_mod.MultiBankPoint":
        """Compose an N-bank interleaved macro around a (cached) bank."""
        return mb_mod.compose_multibank(self.evaluate(cfg), n_banks)

    def vdd_lattice(self, sweep: SweepQuery = SweepQuery(),
                    vdd_scales=(0.7, 0.85, 1.0, 1.15)) -> VddLattice:
        """Evaluate (and cache) the sweep lattice across an operating-
        voltage ladder — the third lattice dimension of the co-design
        flow. Analytic tier only: a transient-fidelity sweep is rejected
        rather than silently downgraded."""
        if sweep.fidelity != "analytic":
            raise ValueError(
                f"vdd_lattice/codesign run the analytic tier only; got "
                f"SweepQuery(fidelity={sweep.fidelity!r}). Calibrate a "
                "shortlist separately with SweepQuery(fidelity="
                "'transient').")
        # key on the lattice-shaping fields only, so sweeps differing in
        # evaluation knobs (batched, sim_steps, solver) share the table
        key = (sweep.cells, sweep.word_sizes, sweep.num_words,
               sweep.write_vts, sweep.wwlls,
               tuple(float(v) for v in vdd_scales))
        if key not in self._vlattices:
            self._vlattices[key] = evaluate_vdd_lattice(
                sweep.configs(self.tech), key[-1])
        return self._vlattices[key]

    def codesign(self, query: CoDesignQuery) -> CoDesignReport:
        """Workload -> memory co-design: per profiled workload, pick the
        best (config, operating voltage) for each cache level and size
        its interleaved macro — the whole (vdd x lattice x demand) cube
        is evaluated device-batched (repro.core.dse_batch), never with
        the scalar per-pair loop."""
        if query.objective not in ("energy", "area"):
            raise ValueError(f"unknown CoDesignQuery objective "
                             f"{query.objective!r} (energy | area)")
        if not query.profiles:
            raise ValueError("CoDesignQuery needs >= 1 Profile "
                             "(see repro.workloads.profiler)")
        if query in self._codesigns:
            return self._codesigns[query]
        lat = self.vdd_lattice(query.sweep, query.vdd_scales)
        demands, steps = [], []
        for prof in query.profiles:
            for d in prof.demands():
                demands.append(d)
                steps.append(prof.step_time_s)
        feas, banks, energy, macro_ok = dse_batch.codesign_metrics(
            lat, demands, steps, allow_refresh=query.allow_refresh,
            max_banks=query.max_banks)
        _, P = lat.shape
        plans, j = [], 0
        for prof in query.profiles:
            levels = {}
            for d in prof.demands():
                # a level is plannable if SOME interleaved macro serves it
                # (banks_needed tiles past a single bank's f_max, exactly
                # like MatchQuery's fastest-bank fallback)
                ok = macro_ok[:, :, j]
                entry = {"read_freq_hz": d.read_freq_hz,
                         "lifetime_s": d.lifetime_s,
                         "capacity_bits": d.capacity_bits,
                         "n_feasible": int(feas[:, :, j].sum()),
                         "n_macro_feasible": int(ok.sum()),
                         "feasible": bool(ok.any())}
                if entry["feasible"]:
                    score = energy[:, :, j] if query.objective == "energy" \
                        else banks[:, :, j] * lat.area_um2[None, :]
                    vi, pi = divmod(int(np.argmin(
                        np.where(ok, score, np.inf))), P)
                    n = int(banks[vi, pi, j])
                    dp = lat.point(vi, pi)
                    macro = mb_mod.compose_multibank(dp, n)
                    entry.update(
                        bank=dp.as_dict(),
                        vdd_scale=float(lat.vdd_scales[vi]),
                        vdd_v=self.tech.vdd * float(lat.vdd_scales[vi]),
                        banks_needed=n,
                        macro_area_um2=macro.area_um2,
                        macro_capacity_bits=macro.capacity_bits,
                        macro_f_max_hz=macro.f_max_hz,
                        standby_w=n * dp.standby_w,
                        energy_per_inference_j=float(energy[vi, pi, j]))
                levels[d.level] = entry
                j += 1
            okl = [e for e in levels.values() if e["feasible"]]
            plans.append({
                "workload": f"{prof.arch}:{prof.shape}",
                "kind": prof.kind, "step_time_s": prof.step_time_s,
                "feasible": len(okl) == len(levels),
                "total_area_um2": sum(e["macro_area_um2"] for e in okl),
                "total_energy_per_inference_j":
                    sum(e["energy_per_inference_j"] for e in okl),
                "levels": levels,
            })
        report = CoDesignReport(plans, query, lat)
        self._codesigns[query] = report
        return report

    def optimize(self, query: OptimizeQuery = OptimizeQuery()
                 ) -> OptimizeResult:
        res = dse.grad_optimize(
            query.cell, target_ret_s=query.target_ret_s,
            target_freq_hz=query.target_freq_hz, steps=query.steps,
            lr=query.lr, tech=self.tech)
        return OptimizeResult(res, query)
