"""Session: the stateful entry point of the unified query API.

A Session binds a TechFile and memoizes work across queries:

  * per-config DesignPoints (shared between sweeps, matches and
    multibank sizing — a MatchQuery after a SweepQuery re-evaluates
    nothing);
  * whole DesignTables keyed by the sweep's LATTICE-SHAPING fields
    (cells/word_sizes/num_words/write_vts/wwlls + fidelity tier), so
    sweeps differing only in evaluation knobs (`batched`, an analytic
    sweep's `sim_steps`/`solver`/`precision`) share one cached table;
  * compiled Reports keyed by (config, simulate, solver), match results
    and co-design reports by their own shaping fields.

Execution is PLAN-THEN-EXECUTE (`repro.api.plan` lowers queries to
content-hash-keyed node DAGs, `repro.api.executor` runs them):

    s = Session()
    table = s.run(SweepQuery(...))        # eager surface, planned core
    futs = [s.submit(q) for q in queries] # async: queue...
    s.flush()                             # ...drain one coalesced wave
    results = s.run_many(queries)         # submit + flush + collect

`run` is a thin wrapper over submit/flush, so the eager API and its
memoization semantics are unchanged — but concurrently submitted
queries COALESCE: identical plan nodes execute once, and distinct
lattice-eval nodes union into a single padded device batch. Passing
`store=` (a directory path or `repro.api.store.ArtifactStore`) adds a
content-addressed on-disk cache, so evaluated tables and transient
characterizations survive process restarts and are shared between
sessions.

Convenience methods (`compile/sweep/match/optimize/evaluate/multibank`)
mirror the Query objects, so both styles work:

    Session().run(SweepQuery(cells=("gc2t_nn",)))
    Session().sweep(SweepQuery(cells=("gc2t_nn",)))
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Iterable, List, Optional

from repro.api.executor import Executor, QueryFuture
from repro.api.queries import (CoDesignQuery, CompileQuery, MatchQuery,
                               OptimizeQuery, Query, SweepQuery)
from repro.api.results import (CalibratedTable, CoDesignReport,
                               CompileResult, DesignTable, LayoutTable,
                               MatchResult, Result)
from repro.api.store import ArtifactStore
from repro.api import plan as plan_mod
from repro.core import dse
from repro.core import multibank as mb_mod
from repro.core.bank import BankConfig
from repro.core.dse import Demand, DesignPoint
from repro.core.dse_batch import VddLattice
from repro.core.techfile import SYN40, TechFile


class Session:
    def __init__(self, tech: TechFile = SYN40, store=None, leases=None):
        self.tech = tech
        self.store: Optional[ArtifactStore] = \
            ArtifactStore(os.fspath(store)) \
            if isinstance(store, (str, os.PathLike)) else store
        # lease/claim coordination over the shared store directory so N
        # concurrent worker processes never duplicate a lattice
        # evaluation (repro.api.leases): pass a LeaseManager, or True to
        # build one over the store root. Meaningless without a store.
        if leases is True:
            from repro.api.leases import LeaseManager
            leases = LeaseManager(self.store.root) \
                if self.store is not None else None
        self.leases = leases if self.store is not None else None
        self._points: Dict[tuple, DesignPoint] = {}
        # whole tables keyed by lattice-shaping fields + fidelity tier
        # (see _table_key) — NOT by the full query, so evaluation knobs
        # don't fragment the cache
        self._tables: Dict[tuple, DesignTable] = {}
        self._reports: Dict[tuple, CompileResult] = {}
        # per-config transient characterizations, keyed by
        # (config key, sim_steps, solver, precision, parasitics) —
        # shared between overlapping transient/layout-fidelity sweeps
        # exactly like the analytic points
        self._tchars: Dict[tuple, object] = {}
        # per-config geometry verification reports (layout tier), keyed
        # by (config key, n_seg)
        self._geoms: Dict[tuple, dict] = {}
        # (lattice fields, vdd_scales) -> VddLattice; match results and
        # co-design reports by their shaping fields (_match_key /
        # _codesign_key)
        self._vlattices: Dict[tuple, VddLattice] = {}
        self._matches: Dict[tuple, MatchResult] = {}
        self._codesigns: Dict[tuple, CoDesignReport] = {}
        self._optimizes: Dict[object, "Result"] = {}
        self._executor = Executor(self)

    # ------------------------------------------------------------------
    # planned execution surface
    # ------------------------------------------------------------------
    @property
    def executor(self) -> Executor:
        return self._executor

    def run(self, query: Query) -> Result:
        """Execute any Query; returns its Result. Planned queries go
        plan -> (coalescing) execute -> compose; a Query subclass
        overriding run(session) — even a subclass of a built-in query —
        keeps its legacy eager hook."""
        if type(query).run is not Query.run:
            return query.run(self)         # legacy subclass hook
        if not plan_mod.plannable(query):
            raise TypeError(
                f"cannot plan query of type {type(query).__name__} and "
                "it overrides no run(session) hook")
        return self._executor.run_one(query)

    def submit(self, query: Query) -> QueryFuture:
        """Queue a query; returns a Future. Queued queries drain in one
        coalesced admission wave at the next flush() (or implicitly at
        the first Future.result()). Legacy run()-override queries can't
        coalesce; they execute eagerly and return a resolved future."""
        if type(query).run is not Query.run:
            fut = QueryFuture(self._executor, query)
            try:
                fut._set(result=query.run(self))
            except Exception as e:                       # noqa: BLE001
                fut._set(error=e)
            return fut
        return self._executor.submit(query)

    def run_many(self, queries: Iterable[Query]) -> List[Result]:
        """Submit every query and drain them in ONE coalesced wave;
        results come back in input order, bit-identical to sequential
        run() calls."""
        futs = [self.submit(q) for q in queries]
        self.flush()
        return [f.result() for f in futs]

    def flush(self) -> None:
        self._executor.flush()

    # ------------------------------------------------------------------
    # config keys and adoption
    # ------------------------------------------------------------------
    def _adopt(self, cfg: BankConfig) -> BankConfig:
        """Configs evaluated through a session use the session's tech."""
        if cfg.tech is not self.tech:
            cfg = dataclasses.replace(cfg, tech=self.tech)
        return cfg

    @staticmethod
    def _key(cfg: BankConfig) -> tuple:
        return (cfg.word_size, cfg.num_words, cfg.cell, cfg.write_vt,
                cfg.wwlls, cfg.wwl_boost)

    def _cfg_from_key(self, key: tuple) -> BankConfig:
        ws, nw, cell, write_vt, wwlls, boost = key
        return BankConfig(int(ws), int(nw), cell=cell, write_vt=write_vt,
                          wwlls=bool(wwlls), wwl_boost=float(boost),
                          tech=self.tech)

    # ------------------------------------------------------------------
    # result-level cache (lattice-shaping keys only)
    # ------------------------------------------------------------------
    @staticmethod
    def _lattice_key(sweep: SweepQuery) -> tuple:
        return (sweep.cells, sweep.word_sizes, sweep.num_words,
                sweep.write_vts, sweep.wwlls)

    @classmethod
    def _table_key(cls, sweep: SweepQuery) -> tuple:
        base = cls._lattice_key(sweep)
        if sweep.fidelity in ("transient", "layout"):
            return base + (sweep.fidelity, sweep.sim_steps, sweep.solver,
                           sweep.precision)
        return base + ("analytic",)

    @classmethod
    def _match_key(cls, q: MatchQuery) -> tuple:
        return (q.demands, cls._table_key(q.sweep), q.allow_refresh,
                q.max_banks)

    @classmethod
    def _codesign_key(cls, q: CoDesignQuery) -> tuple:
        return (q.profiles, cls._lattice_key(q.sweep), q.vdd_scales,
                q.allow_refresh, q.max_banks, q.objective)

    @staticmethod
    def _vlattice_key(sweep: SweepQuery, vdd_scales) -> tuple:
        return Session._lattice_key(sweep) + \
            (tuple(float(v) for v in vdd_scales),)

    def _result_cache_get(self, query: Query) -> Optional[Result]:
        if isinstance(query, SweepQuery):
            return self._tables.get(self._table_key(query))
        if isinstance(query, MatchQuery):
            return self._matches.get(self._match_key(query))
        if isinstance(query, CoDesignQuery):
            return self._codesigns.get(self._codesign_key(query))
        if isinstance(query, CompileQuery):
            cfg = self._adopt(query.cfg)
            return self._reports.get(
                (self._key(cfg), query.simulate, query.solver))
        if isinstance(query, OptimizeQuery):
            # frozen + tuple-only fields -> the query is its own key
            return self._optimizes.get(query)
        return None

    def _result_cache_put(self, query: Query, result: Result) -> None:
        if isinstance(query, SweepQuery):
            self._tables.setdefault(self._table_key(query), result)
        elif isinstance(query, MatchQuery):
            self._matches.setdefault(self._match_key(query), result)
        elif isinstance(query, CoDesignQuery):
            self._codesigns.setdefault(self._codesign_key(query), result)
        elif isinstance(query, OptimizeQuery):
            self._optimizes.setdefault(query, result)
        # CompileQuery results land in _reports inside the compile node

    def _table_from_points(self, query: SweepQuery, points,
                           chars=None, geoms=None) -> DesignTable:
        """Build (or return the cached) table for an evaluated lattice —
        the compose step of SweepQuery plans."""
        tkey = self._table_key(query)
        hit = self._tables.get(tkey)
        if hit is not None:
            return hit
        if query.fidelity == "layout":
            table = LayoutTable(list(points), query, list(chars),
                                list(geoms))
        elif query.fidelity == "transient":
            table = CalibratedTable(list(points), query, list(chars))
        else:
            table = DesignTable(list(points), query)
        self._tables[tkey] = table
        return table

    # ------------------------------------------------------------------
    # eager convenience surface (thin wrappers over run())
    # ------------------------------------------------------------------
    def compile(self, cfg: Optional[BankConfig] = None, *, simulate=False,
                solver="jnp", **cfg_kw) -> CompileResult:
        """One bank -> Report (netlists + floorplan + all reports).
        Accepts a BankConfig or BankConfig kwargs."""
        cfg = self._adopt(cfg if cfg is not None
                          else BankConfig(tech=self.tech, **cfg_kw))
        return self._executor.run_one(CompileQuery(cfg, simulate=simulate,
                                                   solver=solver))

    def evaluate(self, cfg: BankConfig) -> DesignPoint:
        """Scalar-evaluate (and cache) one config."""
        cfg = self._adopt(cfg)
        k = self._key(cfg)
        if k not in self._points:
            self._points[k] = dse.evaluate(cfg)
        return self._points[k]

    def sweep(self, query: SweepQuery = SweepQuery()) -> DesignTable:
        """Evaluate the config lattice; batched via jax.vmap by default.

        fidelity="analytic" returns a DesignTable; fidelity="transient"
        additionally runs the topology-grouped batched transient engine
        over every gain-cell point and returns a CalibratedTable;
        fidelity="layout" drives that engine with layout-extracted
        parasitics and returns a LayoutTable that also carries every
        point's geometry verification report (DRC + LVS-lite +
        extraction bit-parity, repro.geom).

        Goes straight to the planned path (NOT through run()'s
        subclass-override dispatch), so a legacy subclass whose run()
        hook delegates here cannot recurse."""
        return self._executor.run_one(query)

    def match(self, demands: Iterable[Demand],
              sweep: SweepQuery = SweepQuery(), *, allow_refresh=True,
              max_banks=1024) -> MatchResult:
        """Shmoo the lattice against demands; for every demand also size
        an interleaved multibank macro (paper: multi-banked GCRAM serves
        the aggregate L2 request stream no single bank can)."""
        return self._executor.run_one(
            MatchQuery(tuple(demands), sweep,
                       allow_refresh=allow_refresh, max_banks=max_banks))

    def multibank(self, cfg: BankConfig, n_banks: int) -> "mb_mod.MultiBankPoint":
        """Compose an N-bank interleaved macro around a (cached) bank."""
        return mb_mod.compose_multibank(self.evaluate(cfg), n_banks)

    def vdd_lattice(self, sweep: SweepQuery = SweepQuery(),
                    vdd_scales=(0.7, 0.85, 1.0, 1.15)) -> VddLattice:
        """Evaluate (and cache) the sweep lattice across an operating-
        voltage ladder — the third lattice dimension of the co-design
        flow. Analytic tier only: a transient-fidelity sweep is rejected
        rather than silently downgraded."""
        if sweep.fidelity != "analytic":
            raise ValueError(
                f"vdd_lattice/codesign run the analytic tier only; got "
                f"SweepQuery(fidelity={sweep.fidelity!r}). Calibrate a "
                "shortlist separately with SweepQuery(fidelity="
                "'transient').")
        # same node execution as a CoDesignQuery plan: keyed on the
        # lattice-shaping fields only (evaluation knobs share the
        # table), consulting and populating the artifact store
        return self._executor.eval_vdd_lattice(
            plan_mod.vdd_lattice_node(self, sweep, vdd_scales))

    def codesign(self, query: CoDesignQuery) -> CoDesignReport:
        """Workload -> memory co-design: per profiled workload, pick the
        best (config, voltage) per L1/L2 demand and size its interleaved
        macro; the whole (vdd x lattice x demand) cube is evaluated
        device-batched (repro.core.dse_batch)."""
        return self._executor.run_one(query)

    def codesign_measured(self, windows, cfg, *,
                          sweep: SweepQuery = SweepQuery(),
                          vdd_scales=(0.7, 0.85, 1.0, 1.15),
                          objective: str = "energy",
                          arch: Optional[str] = None,
                          step_time_s: Optional[float] = None,
                          allow_refresh: bool = True,
                          max_banks: int = 1024) -> CoDesignReport:
        """Co-design directly from MEASURED telemetry windows: each
        window becomes a `repro.runtime.measured_profile` over the model
        config that produced it, and the list feeds an ordinary
        CoDesignQuery — the loop from the live engine back into design-
        space exploration. Passing the plain list (CoDesignQuery
        normalizes profile lists to tuples) keeps the report cacheable."""
        from repro.runtime.profile import measured_profile
        profiles = [measured_profile(w, cfg, arch=arch, shape=f"win{i}",
                                     step_time_s=step_time_s)
                    for i, w in enumerate(windows)]
        return self.run(CoDesignQuery(profiles, sweep=sweep,
                                      vdd_scales=tuple(vdd_scales),
                                      objective=objective,
                                      allow_refresh=allow_refresh,
                                      max_banks=max_banks))

    def optimize(self, query: OptimizeQuery = OptimizeQuery()
                 ) -> "Result":
        return self._executor.run_one(query)
