"""Declarative query objects accepted by `repro.api.Session.run`.

Each query is a frozen dataclass (hashable where possible, so sessions
can memoize whole results). Validation lives in `__post_init__`, so an
invalid query fails AT CONSTRUCTION — before it is submitted, queued,
serialized or shipped to a compile service — not halfway through a
session method. The `run(session)` hook remains as the legacy dispatch
path for user-defined Query subclasses; the built-in queries are
lowered by the planner (`repro.api.plan`) instead.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Optional, Tuple

from repro.core.bank import BankConfig
from repro.core.dse import Demand, lattice_configs


@dataclass(frozen=True)
class Query:
    """Base class. Built-in subclasses are planned (repro.api.plan);
    user-defined subclasses may override run(session) -> Result, which
    Session.run falls back to when it cannot plan a query."""

    def run(self, session):
        return session.run(self)


@dataclass(frozen=True)
class CompileQuery(Query):
    """One bank config -> full compiler report (netlists, floorplan,
    timing/power/retention; optionally transient-simulated)."""
    cfg: BankConfig = BankConfig()
    simulate: bool = False
    solver: str = "jnp"


@dataclass(frozen=True)
class SweepQuery(Query):
    """Config lattice -> DesignTable, evaluated by the batched (vmapped)
    struct-of-arrays evaluator (set batched=False for the scalar loop).

    fidelity picks the model tier:
      "analytic"  (default) — logical-effort + Elmore algebra, the
                  GEMTOO-class fast model; returns a DesignTable.
      "transient" — additionally integrates every gain-cell point's read
                  column with the batched Newton engine (HSPICE-class,
                  one compiled program per cell topology) and returns a
                  CalibratedTable: the analytic DesignTable plus the
                  per-point simulated sense time and analytic-vs-transient
                  error. sim_steps/solver/precision parameterize that
                  engine: solver "pallas" (default) is the fused
                  sparse-Newton engine (prefactored-K Woodbury; Pallas
                  kernel on TPU, bit-identical XLA fallback on CPU),
                  "sparse" the fixed-pattern symbolic-LU engine, "jnp"
                  the dense f64 reference. precision "f64" (default) |
                  "mixed" (f32 carried traces, f64 model + solve — passes
                  the 1% scalar-parity contract) | "f32" (screening only).
      "layout"  — the transient tier driven by LAYOUT-EXTRACTED
                  parasitics instead of the hand-modeled wire RC: every
                  point's bank is placed + routed + DRC/LVS-verified by
                  `repro.geom` (one batched struct-of-arrays extraction
                  per topology group replaces `core.bank.bitline_rc`),
                  and the result is a LayoutTable carrying the per-point
                  geometry verification reports alongside the transient
                  characterization. sim_steps/solver/precision apply as
                  in "transient".
    """
    cells: Tuple[str, ...] = ("gc2t_nn", "gc2t_np", "gc2t_osos")
    word_sizes: Tuple[int, ...] = (16, 32, 64, 128)
    num_words: Tuple[int, ...] = (16, 32, 64, 128)
    write_vts: Tuple[Optional[str], ...] = (None,)
    wwlls: Tuple[bool, ...] = (False, True)
    batched: bool = True
    fidelity: str = "analytic"
    sim_steps: int = 300
    solver: str = "pallas"
    precision: str = "f64"

    def __post_init__(self):
        for f in ("cells", "word_sizes", "num_words", "write_vts",
                  "wwlls"):
            object.__setattr__(self, f, tuple(getattr(self, f)))
        if self.fidelity not in ("analytic", "transient", "layout"):
            raise ValueError(
                f"unknown SweepQuery fidelity {self.fidelity!r} "
                "(analytic | transient | layout)")
        if self.solver not in ("jnp", "pallas", "sparse"):
            raise ValueError(f"unknown SweepQuery solver {self.solver!r} "
                             "(jnp | pallas | sparse)")
        if self.precision not in ("f64", "mixed", "f32"):
            raise ValueError(f"unknown SweepQuery precision "
                             f"{self.precision!r} (f64 | mixed | f32)")
        if self.fidelity in ("transient", "layout") and \
                self.precision == "f32":
            # pure-f32 solves through the cond(J)~1e6 MNA Jacobian are
            # outside the parity contract (docs/fidelity-tiers.md);
            # "mixed" keeps the model + solve in f64 and passes it
            warnings.warn(
                "SweepQuery(precision='f32') solves in float32 "
                "throughout; calibration numbers are screening-grade "
                "only (precision='mixed' keeps the solve f64 and holds "
                "the 1% parity contract)", stacklevel=2)

    def configs(self, tech):
        return lattice_configs(self.cells, self.word_sizes, self.num_words,
                               self.write_vts, self.wwlls, tech=tech)


@dataclass(frozen=True)
class MatchQuery(Query):
    """Lattice x workload demands -> shmoo grid + feasibility + multibank
    sizing (`banks_needed`) per demand (the Fig 10 flow).

    The default sweep runs at TRANSIENT fidelity: the fused sparse-Newton
    engine made the HSPICE-class tier cheap enough to be the shmoo
    default (>=5x over the dense batched baseline at <=1% parity — see
    benchmarks/bench_transient.py), so feasibility verdicts come
    calibrated out of the box. Pass an analytic SweepQuery to screen."""
    demands: Tuple[Demand, ...] = ()
    sweep: SweepQuery = field(
        default_factory=lambda: SweepQuery(fidelity="transient"))
    allow_refresh: bool = True
    max_banks: int = 1024

    def __post_init__(self):
        object.__setattr__(self, "demands", tuple(self.demands))
        dkeys = [f"{d.level}:{d.name}" for d in self.demands]
        if len(set(dkeys)) != len(dkeys):
            raise ValueError(f"duplicate demand keys in match: {dkeys} "
                             "(grid/banks_needed are keyed by level:name)")


@dataclass(frozen=True)
class CoDesignQuery(Query):
    """Workload -> memory co-design over (design lattice x operating
    voltage): consume workload Profiles from `repro.workloads.profiler`,
    evaluate the sweep lattice at every `vdd_scales` operating point
    (one device-batched program per cell topology), and for each
    workload's L1/L2 demand pick the feasible (config, voltage) combo
    minimizing the objective, sized as an interleaved multibank macro.

    The result is a `CoDesignReport`: one heterogeneous per-workload
    plan (best L1 bank at its best operating point + best L2 bank at
    its, possibly different, operating point), memoized in the Session
    like sweep tables.

      profiles      tuple of Profile (frozen/hashable)
      vdd_scales    operating-voltage multipliers of tech.vdd — the
                    paper's "retention tuned on-the-fly by changing the
                    operating voltage" knob
      objective     "energy" -> minimize joules per inference step
                    (dynamic read + macro standby over the step);
                    "area" -> minimize macro area in um^2
      allow_refresh / max_banks follow MatchQuery semantics
    """
    profiles: Tuple["Profile", ...] = ()
    sweep: SweepQuery = field(default_factory=SweepQuery)
    vdd_scales: Tuple[float, ...] = (0.7, 0.85, 1.0, 1.15)
    allow_refresh: bool = True
    max_banks: int = 1024
    objective: str = "energy"

    def __post_init__(self):
        object.__setattr__(self, "profiles", tuple(self.profiles))
        object.__setattr__(self, "vdd_scales",
                           tuple(float(v) for v in self.vdd_scales))
        if self.objective not in ("energy", "area"):
            raise ValueError(f"unknown CoDesignQuery objective "
                             f"{self.objective!r} (energy | area)")
        if not self.profiles:
            raise ValueError("CoDesignQuery needs >= 1 Profile "
                             "(see repro.workloads.profiler)")
        if self.sweep.fidelity != "analytic":
            raise ValueError(
                f"vdd_lattice/codesign run the analytic tier only; got "
                f"SweepQuery(fidelity={self.sweep.fidelity!r}). Calibrate "
                "a shortlist separately with SweepQuery(fidelity="
                "'transient').")


@dataclass(frozen=True)
class OptimizeQuery(Query):
    """Gradient-based continuous design optimization of ONE gain-cell
    bank topology (projected Adam on the differentiable evaluator —
    `repro.optim.dse_opt` over `repro.core.dse_grad`).

    The discrete vdd ladder is demoted to a global SEED (it shares the
    session/store `vdd_lattice` artifacts); the continuous `knobs`
    (operating voltage, device widths, bitline wire width) are then
    refined under the `dse.feasible` demand constraints
    (target_freq_hz, target_ret_s), minimizing `objective`. The result
    is verified with the exact quantized algebra and never regresses
    vs the seed rung (see dse_opt.optimize).

      cell/word_size/num_words/write_vt/wwlls   the frozen topology
      target_freq_hz, target_ret_s   the demand (read Hz, lifetime s)
      objective    any dse_grad output; conventionally one of
                   dse_opt.OBJECTIVES ("standby_w", "t_read_s",
                   "e_read_j", "e_write_j")
      knobs        subset of dse_grad.KNOBS to optimize
      steps, lr    Adam iterations / learning rate
      seed_vdd_scales   the coarse ladder rungs seeding the loop
    """
    cell: str = "gc2t_nn"
    word_size: int = 32
    num_words: int = 64
    write_vt: Optional[str] = None
    wwlls: bool = False
    target_ret_s: float = 1e-4
    target_freq_hz: float = 1e8
    objective: str = "standby_w"
    knobs: Tuple[str, ...] = ("vdd_scale",)
    steps: int = 60
    lr: float = 0.05
    seed_vdd_scales: Tuple[float, ...] = (0.7, 0.85, 1.0, 1.15)
    allow_refresh: bool = True

    def __post_init__(self):
        from repro.core.cells import CELLS, Bitcell
        from repro.core.dse_grad import KNOBS, OUTPUTS
        object.__setattr__(self, "knobs", tuple(self.knobs))
        object.__setattr__(self, "seed_vdd_scales",
                           tuple(float(v) for v in self.seed_vdd_scales))
        if self.cell not in CELLS:
            raise ValueError(f"unknown cell {self.cell!r} "
                             f"(known: {sorted(CELLS)})")
        if not isinstance(CELLS[self.cell], Bitcell):
            raise ValueError(f"OptimizeQuery optimizes gain cells; "
                             f"{self.cell!r} has no retention/width knobs")
        bad = set(self.knobs) - set(KNOBS)
        if bad:
            raise ValueError(f"unknown knobs {sorted(bad)} "
                             f"(allowed: {KNOBS})")
        if not self.knobs:
            raise ValueError("OptimizeQuery needs >= 1 knob")
        if self.objective not in OUTPUTS:
            raise ValueError(f"unknown objective {self.objective!r} "
                             f"(one of {OUTPUTS})")
        if self.steps <= 0 or self.lr <= 0:
            raise ValueError(f"steps/lr must be positive, got "
                             f"steps={self.steps} lr={self.lr}")
        if self.target_ret_s <= 0 or self.target_freq_hz <= 0:
            raise ValueError(
                f"targets must be positive, got target_ret_s="
                f"{self.target_ret_s} target_freq_hz={self.target_freq_hz}")
        if not self.seed_vdd_scales or \
                any(v <= 0 for v in self.seed_vdd_scales):
            raise ValueError(f"seed_vdd_scales must be positive, got "
                             f"{self.seed_vdd_scales}")
        if self.write_vt is not None:
            wf = CELLS[self.cell].write_flavor
            if wf.startswith("os") != self.write_vt.startswith("os"):
                raise ValueError(
                    f"write_vt {self.write_vt!r} is the wrong device "
                    f"family for cell {self.cell!r} (write flavor {wf!r})")
