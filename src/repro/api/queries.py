"""Declarative query objects accepted by `repro.api.Session.run`.

Each query is a frozen dataclass (hashable where possible, so sessions
can memoize whole results) with a `run(session)` hook dispatching to the
session method that implements it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

from repro.core.bank import BankConfig
from repro.core.dse import Demand, lattice_configs


@dataclass(frozen=True)
class Query:
    """Base class; subclasses implement run(session) -> Result."""

    def run(self, session):
        raise NotImplementedError


@dataclass(frozen=True)
class CompileQuery(Query):
    """One bank config -> full compiler report (netlists, floorplan,
    timing/power/retention; optionally transient-simulated)."""
    cfg: BankConfig = BankConfig()
    simulate: bool = False
    solver: str = "jnp"

    def run(self, session):
        return session.compile(self.cfg, simulate=self.simulate,
                               solver=self.solver)


@dataclass(frozen=True)
class SweepQuery(Query):
    """Config lattice -> DesignTable, evaluated by the batched (vmapped)
    struct-of-arrays evaluator (set batched=False for the scalar loop).

    fidelity picks the model tier:
      "analytic"  (default) — logical-effort + Elmore algebra, the
                  GEMTOO-class fast model; returns a DesignTable.
      "transient" — additionally integrates every gain-cell point's read
                  column with the batched Newton engine (HSPICE-class,
                  one compiled program per cell topology) and returns a
                  CalibratedTable: the analytic DesignTable plus the
                  per-point simulated sense time and analytic-vs-transient
                  error. sim_steps/solver parameterize that engine.
    """
    cells: Tuple[str, ...] = ("gc2t_nn", "gc2t_np", "gc2t_osos")
    word_sizes: Tuple[int, ...] = (16, 32, 64, 128)
    num_words: Tuple[int, ...] = (16, 32, 64, 128)
    write_vts: Tuple[Optional[str], ...] = (None,)
    wwlls: Tuple[bool, ...] = (False, True)
    batched: bool = True
    fidelity: str = "analytic"
    sim_steps: int = 300
    solver: str = "jnp"

    def configs(self, tech):
        return lattice_configs(self.cells, self.word_sizes, self.num_words,
                               self.write_vts, self.wwlls, tech=tech)

    def run(self, session):
        return session.sweep(self)


@dataclass(frozen=True)
class MatchQuery(Query):
    """Lattice x workload demands -> shmoo grid + feasibility + multibank
    sizing (`banks_needed`) per demand (the Fig 10 flow)."""
    demands: Tuple[Demand, ...] = ()
    sweep: SweepQuery = field(default_factory=SweepQuery)
    allow_refresh: bool = True
    max_banks: int = 1024

    def run(self, session):
        return session.match(self.demands, self.sweep,
                             allow_refresh=self.allow_refresh,
                             max_banks=self.max_banks)


@dataclass(frozen=True)
class CoDesignQuery(Query):
    """Workload -> memory co-design over (design lattice x operating
    voltage): consume workload Profiles from `repro.workloads.profiler`,
    evaluate the sweep lattice at every `vdd_scales` operating point
    (one device-batched program per cell topology), and for each
    workload's L1/L2 demand pick the feasible (config, voltage) combo
    minimizing the objective, sized as an interleaved multibank macro.

    The result is a `CoDesignReport`: one heterogeneous per-workload
    plan (best L1 bank at its best operating point + best L2 bank at
    its, possibly different, operating point), memoized in the Session
    like sweep tables.

      profiles      tuple of Profile (frozen/hashable)
      vdd_scales    operating-voltage multipliers of tech.vdd — the
                    paper's "retention tuned on-the-fly by changing the
                    operating voltage" knob
      objective     "energy" -> minimize joules per inference step
                    (dynamic read + macro standby over the step);
                    "area" -> minimize macro area in um^2
      allow_refresh / max_banks follow MatchQuery semantics
    """
    profiles: Tuple["Profile", ...] = ()
    sweep: SweepQuery = field(default_factory=SweepQuery)
    vdd_scales: Tuple[float, ...] = (0.7, 0.85, 1.0, 1.15)
    allow_refresh: bool = True
    max_banks: int = 1024
    objective: str = "energy"

    def run(self, session):
        return session.codesign(self)


@dataclass(frozen=True)
class OptimizeQuery(Query):
    """Continuous co-optimization of (write VT, write width, WWL boost)
    for a retention target — wraps dse.grad_optimize."""
    cell: str = "gc2t_nn"
    target_ret_s: float = 1e-4
    target_freq_hz: Optional[float] = None
    steps: int = 300
    lr: float = 0.02

    def run(self, session):
        return session.optimize(self)
