"""Query planning: lower every Query into a DAG of canonical
evaluation nodes.

Eager `Session.run` used to walk straight into evaluation; planned
execution splits every query into two halves:

  * a small DAG of `Node`s naming the device-side work — config-lattice
    evaluation (`points`), transient characterization (`transient`),
    geometry verification for the layout tier (`geom`), the
    (vdd x lattice) table (`vdd_lattice`), the shmoo grid (`shmoo`),
    the co-design cube (`codesign_cube`), one-bank compilation
    (`compile`) and gradient optimization (`optimize`);
  * a pure-host `compose` step that assembles the query's Result from
    the node outputs (select/compose: pick banks, size macros, build
    tables) — byte-for-byte the assembly the eager methods performed.

Node keys are CONTENT HASHES of `(kind, tech hash, lattice-shaping
payload)`: two queries that need the same evaluation produce the same
key no matter which session, process or tenant submitted them. That is
what the coalescing executor (`repro.api.executor`) dedupes on, what
distinct lattice-eval nodes union device batches across, and what the
on-disk artifact store (`repro.api.store`) files results under.
Evaluation knobs that cannot change the result (e.g. `batched`) stay
OUT of the key and ride in `spec` instead.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.api.queries import (CoDesignQuery, CompileQuery, MatchQuery,
                               OptimizeQuery, Query, SweepQuery)
from repro.api.results import (CoDesignReport, MatchResult, OptimizeResult)
from repro.core import multibank as mb_mod
from repro.core.bank import BankConfig
from repro.core.dse import DesignPoint
from repro.core.dse_batch import VddLattice
from repro.core.spice.char_batch import TransientChar

__all__ = ["Node", "Plan", "plan_query", "plannable", "node_key",
           "tech_hash"]


# ---------------------------------------------------------------------------
# content hashing
# ---------------------------------------------------------------------------

# id(tech) -> (tech, hash); the strong reference keeps the deck alive so
# a recycled id can never alias a different TechFile (same caveat and
# fix as dse_batch._CONSTS_CACHE)
_TECH_HASH_CACHE: Dict[int, tuple] = {}


def tech_hash(tech) -> str:
    """Stable content hash of a TechFile deck: equal decks hash equal
    across processes (the property the on-disk store keys rely on)."""
    hit = _TECH_HASH_CACHE.get(id(tech))
    if hit is not None and hit[0] is tech:
        return hit[1]
    blob = json.dumps(dataclasses.asdict(tech), sort_keys=True,
                      default=repr)
    h = hashlib.sha256(blob.encode()).hexdigest()[:16]
    _TECH_HASH_CACHE[id(tech)] = (tech, h)
    return h


def node_key(kind: str, tech, payload) -> str:
    """Content-hash key of one evaluation node. `payload` must hold the
    lattice-shaping fields only — everything that determines the node's
    RESULT, nothing that merely tunes how it is computed."""
    blob = json.dumps([kind, tech_hash(tech), payload], sort_keys=True)
    return f"{kind}-{hashlib.sha256(blob.encode()).hexdigest()[:24]}"


# ---------------------------------------------------------------------------
# nodes and plans
# ---------------------------------------------------------------------------

@dataclass
class Node:
    """One canonical evaluation step. `key` is the content hash (dedupe
    + store identity); `cfgs`/`spec` carry the runtime payload the
    executor needs; `deps` are keys of nodes whose outputs this one
    consumes."""
    kind: str
    key: str
    cfgs: Tuple[BankConfig, ...] = ()
    spec: dict = field(default_factory=dict)
    deps: Tuple[str, ...] = ()


@dataclass
class Plan:
    """A query's node DAG + the host-side compose step. `nodes` is
    ordered dependencies-first, so executing in list order (after
    cross-plan dedupe, which keeps first occurrences) is always valid."""
    query: Query
    nodes: List[Node]
    compose: Callable  # (session, {node key: output}) -> Result


def plannable(query) -> bool:
    return isinstance(query, (CompileQuery, SweepQuery, MatchQuery,
                              CoDesignQuery, OptimizeQuery))


def _cfg_keys(session, cfgs) -> list:
    return [list(session._key(c)) for c in cfgs]


def _demand_payload(demands) -> list:
    return [[d.name, d.level, d.read_freq_hz, d.lifetime_s,
             d.capacity_bits] for d in demands]


def _lattice_payload(sweep: SweepQuery) -> list:
    return [list(sweep.cells), list(sweep.word_sizes),
            list(sweep.num_words), list(sweep.write_vts),
            list(sweep.wwlls)]


def plan_query(session, query: Query) -> Plan:
    """Lower one query into its Plan. Raises TypeError for query types
    the planner does not know (legacy Query subclasses keep working via
    their own `run(session)` hooks — see Session.run)."""
    if isinstance(query, SweepQuery):
        return _plan_sweep(session, query)
    if isinstance(query, MatchQuery):
        return _plan_match(session, query)
    if isinstance(query, CoDesignQuery):
        return _plan_codesign(session, query)
    if isinstance(query, CompileQuery):
        return _plan_compile(session, query)
    if isinstance(query, OptimizeQuery):
        return _plan_optimize(session, query)
    raise TypeError(f"cannot plan query of type {type(query).__name__}")


def _plan_sweep(session, q: SweepQuery) -> Plan:
    cfgs = tuple(q.configs(session.tech))
    pkeys = _cfg_keys(session, cfgs)
    pnode = Node("points", node_key("points", session.tech, pkeys),
                 cfgs=cfgs, spec={"batched": q.batched})
    nodes = [pnode]
    tnode = gnode = None
    if q.fidelity in ("transient", "layout"):
        parasitics = "extracted" if q.fidelity == "layout" else "modeled"
        payload = [pkeys, q.sim_steps, q.solver, q.precision]
        if parasitics != "modeled":
            # appended only for the layout tier, so stored hand-modeled
            # transient artifacts keep their pre-layout keys
            payload.append(parasitics)
        tnode = Node(
            "transient", node_key("transient", session.tech, payload),
            cfgs=cfgs, spec={"sim_steps": q.sim_steps, "solver": q.solver,
                             "precision": q.precision,
                             "parasitics": parasitics})
        nodes.append(tnode)
    if q.fidelity == "layout":
        # geometry build + DRC/LVS + scalar-vs-batched extraction parity,
        # one verification report per config (repro.geom.verify)
        gnode = Node("geom", node_key("geom", session.tech, pkeys),
                     cfgs=cfgs, spec={"n_seg": 8})
        nodes.append(gnode)

    def compose(s, out):
        chars = out[tnode.key] if tnode is not None else None
        if gnode is not None:
            return s._table_from_points(q, out[pnode.key], chars,
                                        geoms=out[gnode.key])
        return s._table_from_points(q, out[pnode.key], chars)

    return Plan(q, nodes, compose)


def _plan_match(session, q: MatchQuery) -> Plan:
    sub = _plan_sweep(session, q.sweep)
    pnode = sub.nodes[0]
    snode = Node(
        "shmoo",
        node_key("shmoo", session.tech,
                 [pnode.key, _demand_payload(q.demands), q.allow_refresh]),
        spec={"demands": q.demands, "allow_refresh": q.allow_refresh},
        deps=(pnode.key,))

    def compose(s, out):
        table = sub.compose(s, out)
        return compose_match(s, q, table, out[snode.key])

    return Plan(q, sub.nodes + [snode], compose)


def vdd_lattice_node(session, sweep: SweepQuery, vdd_scales) -> Node:
    """The (vdd x lattice) evaluation node — shared by CoDesignQuery
    plans and the eager Session.vdd_lattice, so both read and populate
    the same session cache and on-disk artifacts."""
    scales = tuple(float(v) for v in vdd_scales)
    return Node(
        "vdd_lattice",
        node_key("vdd_lattice", session.tech,
                 [_lattice_payload(sweep), list(scales)]),
        spec={"sweep": sweep, "vdd_scales": scales})


def _plan_codesign(session, q: CoDesignQuery) -> Plan:
    vnode = vdd_lattice_node(session, q.sweep, q.vdd_scales)
    demands, steps = [], []
    for prof in q.profiles:
        for d in prof.demands():
            demands.append(d)
            steps.append(prof.step_time_s)
    cnode = Node(
        "codesign_cube",
        node_key("codesign_cube", session.tech,
                 [vnode.key, _demand_payload(demands), list(steps),
                  q.allow_refresh, q.max_banks]),
        spec={"demands": tuple(demands), "steps": tuple(steps),
              "allow_refresh": q.allow_refresh, "max_banks": q.max_banks},
        deps=(vnode.key,))

    def compose(s, out):
        return compose_codesign(s, q, out[vnode.key], out[cnode.key])

    return Plan(q, [vnode, cnode], compose)


def _plan_compile(session, q: CompileQuery) -> Plan:
    cfg = session._adopt(q.cfg)
    node = Node(
        "compile",
        node_key("compile", session.tech,
                 [list(session._key(cfg)), q.simulate, q.solver]),
        cfgs=(cfg,), spec={"simulate": q.simulate, "solver": q.solver})
    return Plan(q, [node], lambda s, out: out[node.key])


def _plan_optimize(session, q: OptimizeQuery) -> Plan:
    # seed ladder as a shared vdd_lattice node: the single-config
    # (vdd x 1) table dedupes/caches/persists exactly like the co-design
    # lattices (same session cache, same on-disk artifacts)
    sweep = SweepQuery(cells=(q.cell,), word_sizes=(q.word_size,),
                       num_words=(q.num_words,), write_vts=(q.write_vt,),
                       wwlls=(q.wwlls,))
    vnode = vdd_lattice_node(session, sweep, q.seed_vdd_scales)
    cfg = session._adopt(BankConfig(q.word_size, q.num_words, cell=q.cell,
                                    write_vt=q.write_vt, wwlls=q.wwlls,
                                    tech=session.tech))
    spec = {"target_ret_s": q.target_ret_s,
            "target_freq_hz": q.target_freq_hz, "objective": q.objective,
            "knobs": q.knobs, "steps": q.steps, "lr": q.lr,
            "seed_vdd_scales": q.seed_vdd_scales,
            "allow_refresh": q.allow_refresh}
    payload = [list(session._key(cfg)),
               sorted((k, list(v) if isinstance(v, tuple) else v)
                      for k, v in spec.items()), vnode.key]
    node = Node("optimize", node_key("optimize", session.tech, payload),
                cfgs=(cfg,), spec=spec, deps=(vnode.key,))
    return Plan(q, [vnode, node],
                lambda s, out: OptimizeResult(out[node.key], q))


# ---------------------------------------------------------------------------
# compose steps (select/compose: pure host logic, no device work)
# ---------------------------------------------------------------------------

def compose_match(session, q: MatchQuery, table, grid) -> MatchResult:
    """Per-demand bank selection + multibank sizing over an evaluated
    table and its shmoo grid (the host half of the old Session.match)."""
    fastest = table.best("f_max_hz")
    rows, banks = [], {}
    for d in q.demands:
        key = f"{d.level}:{d.name}"
        feas = table.feasible(d, allow_refresh=q.allow_refresh)
        # densest single bank if one works, else the fastest bank tiled
        pick = max(feas, key=lambda p: p.cfg.bits / p.area_um2) \
            if len(feas) else fastest
        n = mb_mod.banks_needed(pick, d, capacity_bits=d.capacity_bits,
                                max_banks=q.max_banks,
                                allow_refresh=q.allow_refresh) \
            if pick is not None else q.max_banks + 1
        banks[key] = n
        rows.append({
            "demand": key, "read_freq_hz": d.read_freq_hz,
            "lifetime_s": d.lifetime_s,
            "capacity_bits": d.capacity_bits,
            "n_feasible": len(feas),
            # n > max_banks is banks_needed's infeasibility sentinel:
            # even a max_banks-wide macro cannot serve this demand
            "macro_feasible": n <= q.max_banks,
            "banks_needed": n,
            "bank": pick.as_dict() if pick is not None else None,
        })
    return MatchResult(grid, rows, banks, table)


def compose_codesign(session, q: CoDesignQuery, lat: VddLattice,
                     cube) -> CoDesignReport:
    """Per-workload (config, voltage) selection + macro sizing over the
    evaluated co-design cube (the host half of the old
    Session.codesign)."""
    feas, banks, energy, macro_ok = cube
    _, P = lat.shape
    plans, j = [], 0
    for prof in q.profiles:
        levels = {}
        for d in prof.demands():
            # a level is plannable if SOME interleaved macro serves it
            # (banks_needed tiles past a single bank's f_max, exactly
            # like MatchQuery's fastest-bank fallback)
            ok = macro_ok[:, :, j]
            entry = {"read_freq_hz": d.read_freq_hz,
                     "lifetime_s": d.lifetime_s,
                     "capacity_bits": d.capacity_bits,
                     "n_feasible": int(feas[:, :, j].sum()),
                     "n_macro_feasible": int(ok.sum()),
                     "feasible": bool(ok.any())}
            if entry["feasible"]:
                score = energy[:, :, j] if q.objective == "energy" \
                    else banks[:, :, j] * lat.area_um2[None, :]
                vi, pi = divmod(int(np.argmin(
                    np.where(ok, score, np.inf))), P)
                n = int(banks[vi, pi, j])
                dp = lat.point(vi, pi)
                macro = mb_mod.compose_multibank(dp, n)
                entry.update(
                    bank=dp.as_dict(),
                    vdd_scale=float(lat.vdd_scales[vi]),
                    vdd_v=session.tech.vdd * float(lat.vdd_scales[vi]),
                    banks_needed=n,
                    macro_area_um2=macro.area_um2,
                    macro_capacity_bits=macro.capacity_bits,
                    macro_f_max_hz=macro.f_max_hz,
                    standby_w=n * dp.standby_w,
                    energy_per_inference_j=float(energy[vi, pi, j]))
            levels[d.level] = entry
            j += 1
        okl = [e for e in levels.values() if e["feasible"]]
        plans.append({
            "workload": f"{prof.arch}:{prof.shape}",
            "kind": prof.kind, "step_time_s": prof.step_time_s,
            "feasible": len(okl) == len(levels),
            "total_area_um2": sum(e["macro_area_um2"] for e in okl),
            "total_energy_per_inference_j":
                sum(e["energy_per_inference_j"] for e in okl),
            "levels": levels,
        })
    return CoDesignReport(plans, q, lat)


# ---------------------------------------------------------------------------
# artifact (de)serialization — JSON-able forms for the on-disk store.
# Floats round-trip exactly (shortest repr), so a decoded artifact is
# bit-identical to the evaluation it replaces.
# ---------------------------------------------------------------------------

_POINT_FIELDS = ("area_um2", "f_max_hz", "read_bw_bps", "write_bw_bps",
                 "eff_bw_bps", "leakage_w", "refresh_w", "retention_s",
                 "swing_ok", "t_read_s", "t_write_s", "vdd_scale")


def encode_points(session, points) -> list:
    return [{"cfg": list(session._key(p.cfg)),
             **{f: getattr(p, f) for f in _POINT_FIELDS}}
            for p in points]


def decode_points(session, data) -> List[DesignPoint]:
    return [DesignPoint(session._cfg_from_key(tuple(d["cfg"])),
                        *(d[f] for f in _POINT_FIELDS)) for d in data]


_CHAR_FIELDS = ("t_cell_s", "t_cell_analytic_s", "rel_dev", "swing_ok",
                "t_end_s", "n_steps")


def encode_chars(session, chars) -> list:
    return [None if c is None else
            {"cfg": list(session._key(c.cfg)),
             **{f: getattr(c, f) for f in _CHAR_FIELDS}}
            for c in chars]


def decode_chars(session, data) -> List[Optional[TransientChar]]:
    return [None if d is None else
            TransientChar(session._cfg_from_key(tuple(d["cfg"])),
                          *(d[f] for f in _CHAR_FIELDS)) for d in data]


def encode_geoms(session, geoms) -> list:
    """Geometry verification reports (repro.geom.verify.verify_bank) are
    already JSON-able dicts of ints/floats/bools/strings."""
    return [None if g is None else dict(g) for g in geoms]


def decode_geoms(session, data) -> list:
    return [None if g is None else dict(g) for g in data]


_VLAT_2D = ("f_max_hz", "t_read_s", "t_write_s", "retention_s",
            "swing_ok", "leakage_w", "refresh_w", "e_read_j", "e_write_j")
_VLAT_1D = ("area_um2", "bits", "num_words", "is_gc")


def encode_vdd_lattice(session, lat: VddLattice) -> dict:
    out = {"cfgs": [list(session._key(c)) for c in lat.cfgs],
           "vdd_scales": list(lat.vdd_scales)}
    for f in _VLAT_2D + _VLAT_1D:
        out[f] = np.asarray(getattr(lat, f)).tolist()
    return out


def decode_vdd_lattice(session, data) -> VddLattice:
    cfgs = [session._cfg_from_key(tuple(k)) for k in data["cfgs"]]
    arrs = {}
    for f in _VLAT_2D + _VLAT_1D:
        dt = bool if f in ("swing_ok", "is_gc") else np.float64
        arrs[f] = np.asarray(data[f], dtype=dt)
    return VddLattice(cfgs, tuple(float(v) for v in data["vdd_scales"]),
                      **arrs)
