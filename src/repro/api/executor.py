"""Coalescing plan executor: Session.submit / run_many / run.

Queries submitted to a Session no longer execute eagerly — they queue
as (query, future) pairs and drain in ADMISSION WAVES. One wave:

  1. result-cache check: queries whose whole Result the session already
     memoizes resolve immediately (same objects as before — `run` twice
     still returns the identical table);
  2. plan: every remaining query lowers to its node DAG
     (`repro.api.plan`); nodes dedupe across queries by content-hash
     key, first submission wins — N queries sharing a lattice carry ONE
     `points` node into execution;
  3. coalesce: still-missing configs of ALL `points` nodes union into a
     single padded device batch per evaluation mode (batched nodes
     share one `dse_batch.evaluate_batch` call, riding its topology
     grouping and power-of-two bucketing; scalar nodes loop), walked in
     submission order so shared points are computed exactly as a
     sequential `Session.run` series would compute them. `transient`
     nodes union the same way per (sim_steps, solver, precision,
     parasitics) — the layout tier's extracted-parasitics runs never
     mix batches with hand-modeled ones.
  4. execute: remaining nodes run dependencies-first, consulting the
     session caches and the on-disk artifact store
     (`repro.api.store`) before any device work, persisting fresh
     artifacts after;
  5. compose + resolve: each query's host-side compose step assembles
     its Result from the node outputs; failures (plan, node, or
     compose) resolve ONLY the futures that depend on them — the rest
     of the wave completes.

Results are bit-identical to running the same queries sequentially
through the eager path: node evaluation goes through the same
primitives (`dse_batch.evaluate_batch`, `char_batch.characterize`,
`dse_batch.evaluate_vdd_lattice`, ...) whose per-point algebra is
elementwise, so union batching cannot perturb any point's value —
asserted in tests/test_executor.py.

Single-threaded by design: `flush()` (and therefore `Future.result()`
on a pending future) runs the wave on the calling thread under a lock.
`submit` is safe to call from other threads; the compile service
(`repro.launch.compile_service`) builds its request queue on top.
"""
from __future__ import annotations

import threading
from collections import Counter
from typing import Dict, List, Optional

from repro.api import plan as plan_mod
from repro.api.plan import Node
from repro.core import compiler as compiler_mod
from repro.core import dse
from repro.core import dse_batch
from repro.core.spice import char_batch

__all__ = ["Executor", "QueryFuture"]


class QueryFuture:
    """Handle for one submitted query. `result()` / `exception()` on a
    still-pending future flush the executor's queue first, so a lone
    submit-then-result behaves exactly like an eager run."""

    __slots__ = ("_executor", "query", "_done", "_result", "_error")

    def __init__(self, executor: "Executor", query):
        self._executor = executor
        self.query = query
        self._done = False
        self._result = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done

    def result(self):
        if not self._done:
            self._executor.flush()
        if not self._done:             # belt: flush resolves every
            raise RuntimeError(        # future, even on wave failure
                f"query future for {type(self.query).__name__} was "
                "never resolved")
        if self._error is not None:
            raise self._error
        return self._result

    def exception(self) -> Optional[BaseException]:
        if not self._done:
            self._executor.flush()
        return self._error

    def _set(self, result=None, error=None):
        self._result, self._error, self._done = result, error, True


class Executor:
    def __init__(self, session):
        self.session = session
        self._pending: List[tuple] = []
        self._lock = threading.RLock()
        # keys known present in the store (avoids re-stat + re-put)
        self._persisted = set()
        # keys whose stored artifact this process found corrupt (torn
        # write, bit-rot, schema mismatch): their recompute is a HEAL,
        # not a duplicate evaluation — see LeaseManager.log_eval
        self._healed = set()
        self.stats = Counter()

    # ------------------------------------------------------------------
    # lease plumbing (fleet mode: session.leases is a LeaseManager)
    # ------------------------------------------------------------------
    @property
    def _leases(self):
        s = self.session
        return s.leases if s.store is not None else None

    def _eval_reason(self, lease, key: str) -> str:
        if lease is not None and lease.stolen:
            return "steal"
        return "heal" if key in self._healed else "fresh"

    def _log_eval(self, lease, key: str) -> None:
        leases = self._leases
        if leases is not None:
            leases.log_eval(key, self._eval_reason(lease, key))

    # ------------------------------------------------------------------
    # submission API (surfaced as Session.submit / run_many / run)
    # ------------------------------------------------------------------
    def submit(self, query) -> QueryFuture:
        fut = QueryFuture(self, query)
        with self._lock:
            self._pending.append((query, fut))
        return fut

    def flush(self) -> None:
        """Drain the queue: one admission wave over everything pending.
        A wave can never strand a future: anything that escapes the
        per-query/per-node handling resolves every unresolved future of
        the wave with the error (surfaced through the futures, the
        contract of this API)."""
        with self._lock:
            pending, self._pending = self._pending, []
            if not pending:
                return
            try:
                self._run_wave(pending)
            except Exception as e:                       # noqa: BLE001
                for _, fut in pending:
                    if not fut.done():
                        fut._set(error=e)

    def run_one(self, query):
        """Eagerly execute one PLANNABLE query (submit + flush +
        result). Multi-query submission lives on Session.run_many,
        which also handles legacy run()-override queries — there is
        deliberately no executor-side duplicate of that loop."""
        fut = self.submit(query)
        self.flush()
        return fut.result()

    # ------------------------------------------------------------------
    # wave execution
    # ------------------------------------------------------------------
    def _run_wave(self, pending) -> None:
        s = self.session
        jobs = []
        for query, fut in pending:
            try:
                cached = s._result_cache_get(query)
                if cached is not None:
                    self.stats["result_cache_hits"] += 1
                    fut._set(result=cached)
                    continue
                jobs.append((query, fut, plan_mod.plan_query(s, query)))
            except Exception as e:                       # noqa: BLE001
                fut._set(error=e)
        if not jobs:
            return
        self.stats["waves"] += 1
        self.stats["queries"] += len(jobs)

        # dedupe nodes by content key, preserving submission order
        nodes: Dict[str, Node] = {}
        for _, _, p in jobs:
            for n in p.nodes:
                if n.key in nodes:
                    self.stats["nodes_coalesced"] += 1
                else:
                    nodes[n.key] = n
        self.stats["nodes_executed"] += len(nodes)

        out: Dict[str, object] = {}
        err: Dict[str, BaseException] = {}
        self._coalesce_points([n for n in nodes.values()
                               if n.kind == "points"], err)
        self._coalesce_transient([n for n in nodes.values()
                                  if n.kind == "transient"], err)
        for n in nodes.values():
            if n.key in err:
                continue
            try:
                out[n.key] = self._exec_node(n, out, err)
            except Exception as e:                       # noqa: BLE001
                err[n.key] = e

        for query, fut, p in jobs:
            try:
                # an earlier duplicate in this same wave may have
                # composed already — resolve to the identical object,
                # exactly like the sequential path would
                cached = s._result_cache_get(query)
                if cached is not None:
                    self.stats["result_cache_hits"] += 1
                    fut._set(result=cached)
                    continue
                bad = next((err[n.key] for n in p.nodes if n.key in err),
                           None)
                if bad is not None:
                    raise bad
                res = p.compose(s, out)
                s._result_cache_put(query, res)
                fut._set(result=res)
            except Exception as e:                       # noqa: BLE001
                fut._set(error=e)

    # ------------------------------------------------------------------
    # cross-query coalescing of lattice evaluation
    # ------------------------------------------------------------------
    def _coalesce_points(self, pnodes: List[Node], err: dict) -> None:
        """Union every points node's still-missing configs into one
        device batch per evaluation mode. Submission order decides which
        node CLAIMS a shared config (and with which mode) — the same
        config the same position in the sequential-run order would have
        computed it with.

        With a LeaseManager attached (fleet mode), each node whose
        artifact is missing is first CLAIMED: nodes whose lease a live
        foreign worker holds are deferred, and only waited on AFTER our
        own claims are evaluated and published — no worker ever blocks
        while holding unpublished work, which keeps the lease protocol
        deadlock-free."""
        s = self.session
        leases = self._leases
        claims = {True: [], False: []}      # batched? -> [cfg, ...]
        owners = {True: set(), False: set()}  # batched? -> {node key}
        claim_mode = {}                     # cfg key -> claiming mode
        held = {}                           # node key -> Lease
        waiting = []                        # [(node, missing)] foreign
        for n in pnodes:
            pkeys = [s._key(c) for c in n.cfgs]
            missing = [(c, k) for c, k in zip(n.cfgs, pkeys)
                       if k not in s._points]
            if missing:
                pts = self._store_decode(n.key, plan_mod.decode_points)
                for p in pts or ():
                    k = s._key(p.cfg)
                    if k not in s._points:
                        s._points[k] = p
                if pts:
                    missing = [(c, k) for c, k in missing
                               if k not in s._points]
            if missing and leases is not None:
                lease = leases.try_claim(n.key)
                if lease is None:           # live foreign owner: defer
                    waiting.append((n, missing))
                    continue
                held[n.key] = lease
            mode = bool(n.spec.get("batched", True))
            for c, k in missing:
                if k not in claim_mode:     # dedupe within + across nodes
                    claim_mode[k] = mode
                    claims[mode].append(c)
                # the node depends on WHOEVER claimed the config: if that
                # mode's evaluation fails, this node must carry the real
                # error, not a KeyError at output assembly
                owners[claim_mode[k]].add(n.key)
        if claims[True]:
            self.stats["eval_batch_calls"] += 1
            self.stats["points_evaluated"] += len(claims[True])
            try:
                pts = dse_batch.evaluate_batch(claims[True])
                for c, p in zip(claims[True], pts):
                    s._points[s._key(c)] = p
            except Exception as e:                       # noqa: BLE001
                for k in owners[True]:
                    err[k] = e
        if claims[False]:
            self.stats["points_evaluated"] += len(claims[False])
            try:
                for c in claims[False]:
                    self.stats["scalar_evals"] += 1
                    s._points[s._key(c)] = dse.evaluate(c)
            except Exception as e:                       # noqa: BLE001
                for k in owners[False]:
                    err[k] = e
        if leases is None:
            return
        # publish everything we claimed (artifact first, then release
        # the lease), THEN wait on the foreign-held nodes
        for n in pnodes:
            lease = held.pop(n.key, None)
            if lease is None:
                continue
            try:
                if n.key not in err:
                    pts = [s._points[s._key(c)] for c in n.cfgs]
                    self._store_put(
                        n.key, lambda: plan_mod.encode_points(s, pts))
                    self._log_eval(lease, n.key)
            finally:
                lease.release()
        for n, missing in waiting:
            self._await_points(n, missing, err)

    def _await_points(self, n: Node, missing, err: dict) -> None:
        """A foreign worker holds this points node's lease: wait for its
        artifact, or steal the lease once it expires (the owner died
        mid-flight) and evaluate the node ourselves."""
        s = self.session

        def have():
            pts = self._store_decode(n.key, plan_mod.decode_points)
            if not pts:
                return None
            for p in pts:
                s._points.setdefault(s._key(p.cfg), p)
            return pts

        try:
            status, val = self._leases.acquire(n.key, have)
        except Exception as e:                           # noqa: BLE001
            err[n.key] = e
            return
        if status == "have":
            return
        lease = val
        try:
            cfgs = [c for c, k in missing if k not in s._points]
            if cfgs:
                self.stats["points_evaluated"] += len(cfgs)
                if bool(n.spec.get("batched", True)):
                    self.stats["eval_batch_calls"] += 1
                    pts = dse_batch.evaluate_batch(cfgs)
                else:
                    self.stats["scalar_evals"] += len(cfgs)
                    pts = [dse.evaluate(c) for c in cfgs]
                for c, p in zip(cfgs, pts):
                    s._points[s._key(c)] = p
            allpts = [s._points[s._key(c)] for c in n.cfgs]
            self._store_put(n.key,
                            lambda: plan_mod.encode_points(s, allpts))
            self._log_eval(lease, n.key)
        except Exception as e:                           # noqa: BLE001
            err[n.key] = e
        finally:
            lease.release()

    def _coalesce_transient(self, tnodes: List[Node], err: dict) -> None:
        s = self.session
        leases = self._leases
        # (steps, solver, precision, parasitics) -> [cfg]
        groups: Dict[tuple, list] = {}
        owners: Dict[tuple, set] = {}
        claimed = set()
        held = {}                             # node key -> Lease
        waiting = []                          # [(node, mode)] foreign
        for n in tnodes:
            mode = (n.spec["sim_steps"], n.spec["solver"],
                    n.spec.get("precision", "f64"),
                    n.spec.get("parasitics", "modeled"))
            tkeys = [(s._key(c),) + mode for c in n.cfgs]
            missing = [(c, tk) for c, tk in zip(n.cfgs, tkeys)
                       if tk not in s._tchars]
            if missing:
                chars = self._store_decode(n.key, plan_mod.decode_chars)
                if chars:
                    for c, ch in zip(n.cfgs, chars):
                        tk = (s._key(c),) + mode
                        if tk not in s._tchars:
                            s._tchars[tk] = ch
                    missing = [(c, tk) for c, tk in missing
                               if tk not in s._tchars]
            if missing and leases is not None:
                lease = leases.try_claim(n.key)
                if lease is None:             # live foreign owner: defer
                    waiting.append((n, mode))
                    continue
                held[n.key] = lease
            for c, tk in missing:
                if tk not in claimed:       # dedupe within + across nodes
                    claimed.add(tk)
                    groups.setdefault(mode, []).append(c)
                # transient claims share the node's (steps, solver) mode,
                # so the claiming group IS this mode's group — but the
                # node must still own it to inherit a group failure
                owners.setdefault(mode, set()).add(n.key)
        for mode, cfgs in groups.items():
            self.stats["char_calls"] += 1
            try:
                chars = char_batch.characterize(
                    cfgs, n_steps=mode[0], solver=mode[1],
                    precision=mode[2], parasitics=mode[3])
                for c, ch in zip(cfgs, chars):
                    s._tchars[(s._key(c),) + mode] = ch
            except Exception as e:                       # noqa: BLE001
                for k in owners[mode]:
                    err[k] = e
        if leases is None:
            return
        for n in tnodes:                      # publish, then wait
            lease = held.pop(n.key, None)
            if lease is None:
                continue
            try:
                if n.key not in err:
                    mode = (n.spec["sim_steps"], n.spec["solver"],
                            n.spec.get("precision", "f64"),
                            n.spec.get("parasitics", "modeled"))
                    chars = [s._tchars[(s._key(c),) + mode]
                             for c in n.cfgs]
                    self._store_put(
                        n.key, lambda: plan_mod.encode_chars(s, chars))
                    self._log_eval(lease, n.key)
            finally:
                lease.release()
        for n, mode in waiting:
            self._await_transient(n, mode, err)

    def _await_transient(self, n: Node, mode: tuple, err: dict) -> None:
        s = self.session

        def have():
            chars = self._store_decode(n.key, plan_mod.decode_chars)
            if not chars:
                return None
            for c, ch in zip(n.cfgs, chars):
                s._tchars.setdefault((s._key(c),) + mode, ch)
            return chars

        try:
            status, val = self._leases.acquire(n.key, have)
        except Exception as e:                           # noqa: BLE001
            err[n.key] = e
            return
        if status == "have":
            return
        lease = val
        try:
            cfgs = [c for c in n.cfgs
                    if (s._key(c),) + mode not in s._tchars]
            if cfgs:
                self.stats["char_calls"] += 1
                chars = char_batch.characterize(
                    cfgs, n_steps=mode[0], solver=mode[1],
                    precision=mode[2], parasitics=mode[3])
                for c, ch in zip(cfgs, chars):
                    s._tchars[(s._key(c),) + mode] = ch
            allchars = [s._tchars[(s._key(c),) + mode] for c in n.cfgs]
            self._store_put(n.key,
                            lambda: plan_mod.encode_chars(s, allchars))
            self._log_eval(lease, n.key)
        except Exception as e:                           # noqa: BLE001
            err[n.key] = e
        finally:
            lease.release()

    # ------------------------------------------------------------------
    # per-node execution
    # ------------------------------------------------------------------
    def _exec_node(self, n: Node, out: dict, err: dict):
        for d in n.deps:
            if d in err:
                raise err[d]
        s = self.session
        if n.kind == "points":
            pts = [s._points[s._key(c)] for c in n.cfgs]
            self._store_put(n.key, lambda: plan_mod.encode_points(s, pts))
            return pts
        if n.kind == "transient":
            mode = (n.spec["sim_steps"], n.spec["solver"],
                    n.spec.get("precision", "f64"),
                    n.spec.get("parasitics", "modeled"))
            chars = [s._tchars[(s._key(c),) + mode] for c in n.cfgs]
            self._store_put(n.key, lambda: plan_mod.encode_chars(s, chars))
            return chars
        if n.kind == "geom":
            n_seg = int(n.spec.get("n_seg", 8))
            missing = [c for c in n.cfgs
                       if (s._key(c), n_seg) not in s._geoms]
            if missing:
                reports = self._store_decode(n.key, plan_mod.decode_geoms)
                if reports:
                    for c, g in zip(n.cfgs, reports):
                        s._geoms.setdefault((s._key(c), n_seg), g)
                    missing = [c for c in missing
                               if (s._key(c), n_seg) not in s._geoms]
            if missing:
                from repro.geom import verify as geom_verify
                self.stats["geom_verifies"] += len(missing)
                for c in missing:
                    s._geoms[(s._key(c), n_seg)] = \
                        geom_verify.verify_bank(c, n_seg=n_seg)
            geoms = [s._geoms[(s._key(c), n_seg)] for c in n.cfgs]
            self._store_put(n.key, lambda: plan_mod.encode_geoms(s, geoms))
            return geoms
        if n.kind == "vdd_lattice":
            return self.eval_vdd_lattice(n)
        if n.kind == "shmoo":
            self.stats["shmoo_calls"] += 1
            return dse_batch.shmoo_batch(
                out[n.deps[0]], list(n.spec["demands"]),
                allow_refresh=n.spec["allow_refresh"])
        if n.kind == "codesign_cube":
            self.stats["cube_calls"] += 1
            return dse_batch.codesign_metrics(
                out[n.deps[0]], list(n.spec["demands"]),
                list(n.spec["steps"]),
                allow_refresh=n.spec["allow_refresh"],
                max_banks=n.spec["max_banks"])
        if n.kind == "compile":
            cfg = n.cfgs[0]
            rkey = (s._key(cfg), n.spec["simulate"], n.spec["solver"])
            if rkey not in s._reports:
                self.stats["compile_calls"] += 1
                s._reports[rkey] = compiler_mod.compile_bank(
                    cfg, simulate=n.spec["simulate"],
                    solver=n.spec["solver"])
            return s._reports[rkey]
        if n.kind == "optimize":
            self.stats["optimize_calls"] += 1
            sp = n.spec
            from repro.optim import dse_opt
            r = dse_opt.optimize(
                n.cfgs[0], target_freq_hz=sp["target_freq_hz"],
                target_ret_s=sp["target_ret_s"],
                objective=sp["objective"], knobs=sp["knobs"],
                steps=sp["steps"], lr=sp["lr"],
                seed_vdd_scales=sp["seed_vdd_scales"],
                allow_refresh=sp["allow_refresh"],
                seed_lattice=out[n.deps[0]])
            return r.as_dict()
        raise ValueError(f"unknown node kind {n.kind!r}")

    def eval_vdd_lattice(self, n: Node):
        """Execute one vdd_lattice node (session cache -> store ->
        evaluate, persisting fresh artifacts). Public on purpose: it is
        the sanctioned entry for the eager Session.vdd_lattice as well
        as the in-wave node executor, so both paths share one cache and
        store policy."""
        s = self.session
        sweep, scales = n.spec["sweep"], n.spec["vdd_scales"]
        vkey = s._vlattice_key(sweep, scales)
        lat = s._vlattices.get(vkey)
        lease = None
        if lat is None:
            lat = self._store_decode(n.key,
                                     plan_mod.decode_vdd_lattice)
            if lat is None and self._leases is not None:
                # fleet mode: claim the node (or wait for whoever holds
                # it to publish; steal if that owner died)
                status, val = self._leases.acquire(
                    n.key, lambda: self._store_decode(
                        n.key, plan_mod.decode_vdd_lattice))
                if status == "have":
                    lat = val
                else:
                    lease = val
        try:
            if lat is None:
                self.stats["vdd_evals"] += 1
                lat = dse_batch.evaluate_vdd_lattice(
                    sweep.configs(s.tech), scales)
                s._vlattices[vkey] = lat
                self._store_put(
                    n.key, lambda: plan_mod.encode_vdd_lattice(s, lat))
                if lease is not None:
                    self._log_eval(lease, n.key)
                return lat
            s._vlattices[vkey] = lat
            self._store_put(n.key,
                            lambda: plan_mod.encode_vdd_lattice(s, lat))
            return lat
        finally:
            if lease is not None:
                lease.release()

    # ------------------------------------------------------------------
    # store plumbing
    # ------------------------------------------------------------------
    def _store_get(self, key: str):
        store = self.session.store
        if store is None:
            return None
        before = store.corrupt
        data = store.get(key)
        if data is not None:
            self._persisted.add(key)
            self.stats["store_hits"] += 1
        elif store.corrupt > before:
            # the entry existed but was torn/bit-rotted: the recompute
            # that follows is a store HEAL, not a duplicate evaluation
            self._healed.add(key)
            self.stats["store_heals"] += 1
        return data

    def _store_decode(self, key: str, decode):
        """Fetch + decode one artifact; a checksum-valid entry that no
        longer decodes (e.g. written by a different code version)
        degrades to a miss-and-recompute, never a wave failure."""
        data = self._store_get(key)
        if data is None:
            return None
        s = self.session
        try:
            return decode(s, data)
        except Exception:                                # noqa: BLE001
            self.stats["store_hits"] -= 1
            self.stats["store_decode_errors"] += 1
            self._persisted.discard(key)    # the recompute rewrites it
            self._healed.add(key)           # schema heal, not duplicate
            if s.store is not None:
                s.store.drop(key)
            return None

    def _store_put(self, key: str, make) -> None:
        store = self.session.store
        if store is None or key in self._persisted:
            return
        if not store.has(key):
            store.put(key, make())
        self._persisted.add(key)
