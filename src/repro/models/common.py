"""Shared model building blocks: norms, activations, RoPE, init, sharding ctx.

Parameters are plain nested dicts of jnp arrays. Every init function has a
`*_specs` twin returning the same tree with tuples of LOGICAL axis names
(see launch/sharding.py for the logical->mesh rule table).
"""
from __future__ import annotations

import contextlib
import threading
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Sharding context: model code annotates activations with *logical* axes;
# when a mesh context is active the annotation becomes a
# with_sharding_constraint, otherwise it is a no-op (CPU smoke tests).
# ---------------------------------------------------------------------------

_CTX = threading.local()


@contextlib.contextmanager
def sharding_ctx(mesh, rules):
    """rules: dict logical_axis -> mesh axis name (or tuple, or None)."""
    prev = getattr(_CTX, "val", None)
    _CTX.val = (mesh, rules)
    try:
        yield
    finally:
        _CTX.val = prev


def logical_to_pspec(logical_axes, rules, shape=None, mesh=None):
    """Map a tuple of logical axis names to a PartitionSpec via `rules`.

    Divisibility fallback: if `shape`/`mesh` given and the dim size is not
    divisible by the product of assigned mesh-axis sizes, replicate that dim.
    A mesh axis may be used at most once in the spec (first logical axis wins).
    """
    from jax.sharding import PartitionSpec as P

    used = set()
    out = []
    for i, name in enumerate(logical_axes):
        assign = rules.get(name)
        if assign is None:
            out.append(None)
            continue
        axes = assign if isinstance(assign, tuple) else (assign,)
        axes = tuple(a for a in axes if a is not None and a not in used)
        if not axes:
            out.append(None)
            continue
        if shape is not None and mesh is not None:
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if shape[i] % size != 0:
                out.append(None)
                continue
        used.update(axes)
        out.append(axes[0] if len(axes) == 1 else axes)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def current_mesh():
    """The mesh of the active sharding context (None outside one)."""
    ctx = getattr(_CTX, "val", None)
    return ctx[0] if ctx is not None else None


def shard_act(x, *logical_axes):
    """Annotate activation x with logical axes; no-op without a mesh ctx."""
    ctx = getattr(_CTX, "val", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    from jax.sharding import NamedSharding

    spec = logical_to_pspec(logical_axes, rules, shape=x.shape, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


def rms_norm(x, scale, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm(x, p, cfg):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def norm_init(cfg, d=None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), dtype_of(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype_of(cfg))
    return p


def norm_specs(cfg):
    p = {"scale": ("embed",)}
    if cfg.norm == "layernorm":
        p["bias"] = ("embed",)
    return p


def act_fn(name):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True)}[name]


def dense_init(key, shape, dtype, scale=None):
    """Truncated-normal fan-in init."""
    fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding (computed on the fly from integer positions;
# avoids multi-hundred-MB constant tables at 500k context).
# ---------------------------------------------------------------------------

def rope(x, positions, theta):
    """x: (..., S, H, hd), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def sinusoidal_positions(n, d):
    pos = np.arange(n)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, jnp.float32)


def sinusoid_at(pos, d):
    """Sinusoidal embedding of integer positions. pos: (B,) -> (B, 1, d)."""
    i = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos[:, None].astype(jnp.float32) / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, None, :]


# ---------------------------------------------------------------------------
# Chunked cross-entropy: never materializes (B, S, V) logits in one piece.
# ---------------------------------------------------------------------------

def chunked_softmax_xent(h, w_unembed, labels, chunk=512, ignore_index=-100):
    """h: (B, S, d) final hidden; w_unembed: (d, V); labels: (B, S) int32.

    Returns mean CE over non-ignored positions (fp32). Scans over S chunks so
    peak logits memory is (B, chunk, V).
    """
    B, S, d = h.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def one(hc, lc):
        # remat: the (B, chunk, V) logits block is recomputed in the backward
        # pass instead of being saved per scan iteration (which would cost
        # n_chunks x B x chunk x V x 4 bytes of residuals).
        logits = jnp.einsum("bsd,dv->bsv", hc, w_unembed, preferred_element_type=jnp.float32)
        logits = shard_act(logits, "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        safe = jnp.clip(lc, 0, logits.shape[-1] - 1)
        tgt = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        mask = (lc != ignore_index).astype(jnp.float32)
        return jnp.sum((lse - tgt) * mask), jnp.sum(mask)

    def body(carry, xs):
        hc, lc = xs
        s, c = one(hc, lc)
        return (carry[0] + s, carry[1] + c), None

    hs = h[:, : n * chunk].reshape(B, n, chunk, d).swapaxes(0, 1)
    ls = labels[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (hs, ls))
    if rem:
        s, c = one(h[:, n * chunk :], labels[:, n * chunk :])
        tot, cnt = tot + s, cnt + c
    return tot / jnp.maximum(cnt, 1.0)
