"""Mamba2 (SSD) block: chunkwise-parallel selective state-space layer.

Train/prefill runs the chunked dual form (intra-chunk "attention-like"
matmuls + inter-chunk state scan) with chunk size `CHUNK`; all decays are
log-space cumulative sums with da <= 0, so every exp() factor is <= 1 and
the computation is stable in fp32 without a max-stabilizer.

Decode advances the recurrent state (B, H, P, N) one token at a time with
a depthwise-conv ring cache of the last k-1 inputs.

Used by zamba2-2.7b (54 Mamba2 layers + shared attention, see model.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, dtype_of, rms_norm, shard_act

CHUNK = 256


def dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_headdim
    conv_dim = d_inner + 2 * cfg.ssm_state
    return d_inner, n_heads, conv_dim


def init(key, cfg):
    d = cfg.d_model
    di, nh, cdim = dims(cfg)
    N = cfg.ssm_state
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    # dt_bias init so softplus(dt_bias) ~ U[1e-3, 1e-1] (mamba2 default).
    u = jax.random.uniform(ks[3], (nh,), jnp.float32, 1e-3, 1e-1)
    dt_bias = u + jnp.log(-jnp.expm1(-u))  # inverse softplus
    return {
        "w_in": dense_init(ks[0], (d, 2 * di + 2 * N + nh), dt),
        "conv_w": dense_init(ks[1], (cfg.conv_kernel, cdim), dt, scale=0.5),
        "conv_b": jnp.zeros((cdim,), dt),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_bias,
        "norm": jnp.ones((di,), dt),
        "w_out": dense_init(ks[2], (di, d), dt),
    }


def specs(cfg):
    return {
        "w_in": ("embed", "inner_all"),
        "conv_w": (None, "conv_dim"),
        "conv_b": ("conv_dim",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "norm": ("inner",),
        "w_out": ("inner", "embed"),
    }


def _causal_conv(u, w, b, init_state=None):
    """Depthwise causal conv. u: (B, S, C); w: (k, C). Returns same shape.

    init_state: (B, k-1, C) history (decode prefill continuation) or None.
    """
    k = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    else:
        pad = init_state.astype(u.dtype)
    x = jnp.concatenate([pad, u], axis=1)
    out = sum(x[:, i : i + u.shape[1]] * w[i] for i in range(k))
    return out + b


def _split(cfg, zxbcdt):
    di, nh, _ = dims(cfg)
    N = cfg.ssm_state
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * N]
    dt_raw = zxbcdt[..., 2 * di + 2 * N :]
    return z, xBC, dt_raw


def apply(p, x, cfg, conv_state=None, ssm_state=None, return_state=False):
    """x: (B, S, d_model) -> (B, S, d_model). Chunked SSD.

    If return_state, also returns (conv_state (B,k-1,cdim), ssm_state
    (B,H,P,N) fp32) for seeding subsequent decode.
    """
    B, S, d = x.shape
    di, nh, cdim = dims(cfg)
    N, P = cfg.ssm_state, cfg.ssm_headdim
    Q = min(CHUNK, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xBC, dt_raw = _split(cfg, zxbcdt)
    xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state))
    xc = xBC[..., :di].reshape(B, S, nh, P)
    Bm = xBC[..., di : di + N].astype(jnp.float32)
    Cm = xBC[..., di + N :].astype(jnp.float32)
    xc = shard_act(xc, "batch", "seq", "ssm_heads", None)

    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,) < 0
    da = dtv * A  # (B,S,H) <= 0

    # chunk views: (nc, B, Q, ...)
    def chunked(t):
        return t.reshape(B, nc, Q, *t.shape[2:]).swapaxes(0, 1)

    xcc, Bc, Cc, dtc, dac = map(chunked, (xc.astype(jnp.float32), Bm, Cm, dtv, da))

    def body(state, xs):
        xq, Bq, Cq, dtq, daq = xs  # (B,Q,...)
        cum = jnp.cumsum(daq, axis=1)  # (B,Q,H)
        # intra-chunk: w[b,h,i,j] = (C_i . B_j) exp(cum_i - cum_j) dt_j, j<=i
        cb = jnp.einsum("bqn,bsn->bqs", Cq, Bq)  # (B,Q,Q)
        dec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,Q,Q,H)
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        w = cb[..., None] * dec * dtq[:, None, :, :]
        w = jnp.where(causal[None, :, :, None], w, 0.0)
        y = jnp.einsum("bqsh,bshp->bqhp", w, xq)
        # inter-chunk: contribution of incoming state
        y += jnp.einsum("bqn,bhpn,bqh->bqhp", Cq, state, jnp.exp(cum))
        # state update
        rem = jnp.exp(cum[:, -1:, :] - cum)  # exp(cum_Q - cum_j)
        st = jnp.einsum("bqh,bqn,bqhp->bhpn", rem * dtq, Bq, xq)
        state = state * jnp.exp(cum[:, -1])[:, :, None, None] + st
        return state, y

    state0 = (
        jnp.zeros((B, nh, P, N), jnp.float32) if ssm_state is None else ssm_state
    )
    state, yc = jax.lax.scan(body, state0, (xcc, Bc, Cc, dtc, dac))
    y = yc.swapaxes(0, 1).reshape(B, S, nh, P)
    y = y + p["D"][None, None, :, None] * xc.astype(jnp.float32)
    y = y.reshape(B, S, di)
    y = rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    out = shard_act(out, "batch", "seq", "embed")
    if return_state:
        k = cfg.conv_kernel
        pad = jnp.zeros((B, max(k - 1 - S, 0), cdim), xBC.dtype)
        raw = jnp.einsum("bsd,de->bse", x[:, max(S - (k - 1), 0):], p["w_in"])
        _, hist, _ = _split(cfg, raw)
        conv_state = jnp.concatenate([pad, hist], axis=1)
        return out, conv_state, state
    return out


def decode_step(p, x, conv_state, ssm_state, cfg):
    """x: (B, 1, d). conv_state: (B, k-1, cdim) pre-activation history.
    ssm_state: (B, H, P, N) fp32. Returns (out (B,1,d), conv_state, ssm_state).
    """
    B = x.shape[0]
    di, nh, cdim = dims(cfg)
    N, P = cfg.ssm_state, cfg.ssm_headdim

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xBC_raw, dt_raw = _split(cfg, zxbcdt)
    hist = jnp.concatenate([conv_state, xBC_raw], axis=1)  # (B, k, cdim)
    conv_state = hist[:, 1:]
    xBC = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"])
    xc = xBC[:, :di].reshape(B, nh, P).astype(jnp.float32)
    Bm = xBC[:, di : di + N].astype(jnp.float32)
    Cm = xBC[:, di + N :].astype(jnp.float32)

    dtv = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dtv * A)  # (B,H)
    ssm_state = ssm_state * decay[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dtv, Bm, xc
    )
    y = jnp.einsum("bn,bhpn->bhp", Cm, ssm_state) + p["D"][None, :, None] * xc
    y = y.reshape(B, 1, di)
    y = rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, conv_state, ssm_state
