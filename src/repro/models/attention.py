"""GQA attention: blocked-flash train/prefill path + KV-cache decode path.

The train/prefill path is a pure-JAX flash attention (online softmax over
KV chunks inside a lax.scan, q chunks via lax.map) so that 32k-token
prefill never materializes an (S, S) score matrix and the HLO stays small
(one while body per loop — see launch/hlo_analysis.py for trip-count-aware
costing).

`block_skip=True` enables causal block skipping (lax.cond around fully
masked KV blocks) — a §Perf hillclimb knob; baseline computes all blocks
with masking.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, dtype_of, rope, shard_act

NEG_INF = -1e30


def init(key, cfg, d_model=None, n_heads=None, n_kv_heads=None, cross=False):
    d = d_model or cfg.d_model
    H = n_heads or cfg.n_heads
    K = n_kv_heads or cfg.n_kv_heads
    hd = cfg.hd()
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, hd), dt),
        "wk": dense_init(ks[1], (d, K, hd), dt),
        "wv": dense_init(ks[2], (d, K, hd), dt),
        "wo": dense_init(ks[3], (H, hd, d), dt, scale=1.0 / np.sqrt(H * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((K, hd), dt)
        p["bv"] = jnp.zeros((K, hd), dt)
    return p


def specs(cfg):
    p = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = ("heads", "head_dim")
        p["bk"] = ("kv_heads", "head_dim")
        p["bv"] = ("kv_heads", "head_dim")
    return p


def _qkv(p, x, cfg, positions=None, use_rope=True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if use_rope and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = shard_act(q, "batch", "seq", "heads", "head_dim")
    k = shard_act(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard_act(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    kv_len=None, chunk_q=512, chunk_kv=1024, block_skip=False):
    """q: (B, Sq, H, hd); k, v: (B, Skv, K, hd); H = K * G. Returns (B, Sq, H, hd).

    Online-softmax over KV chunks; fp32 accumulation; GQA via head groups.
    """
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    cq = min(chunk_q, Sq)
    ckv = min(chunk_kv, Skv)
    kv_len = Skv if kv_len is None else kv_len
    # pad non-divisible sequence lengths (e.g. whisper's 1500 frames);
    # padded KV is masked via kv_len, padded q rows are sliced off.
    Sq0 = Sq
    if Sq % cq or Skv % ckv:
        Sqp = -(-Sq // cq) * cq
        Skvp = -(-Skv // ckv) * ckv
        q = jnp.pad(q, ((0, 0), (0, Sqp - Sq), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, Skvp - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skvp - Skv), (0, 0), (0, 0)))
        Sq, Skv = Sqp, Skvp
    nq, nkv = Sq // cq, Skv // ckv
    scale = 1.0 / np.sqrt(hd)

    qg = q.reshape(B, nq, cq, K, G, hd)
    kc = k.reshape(B, nkv, ckv, K, hd)
    vc = v.reshape(B, nkv, ckv, K, hd)

    def q_chunk_body(qi):
        qq = qg[:, qi]  # (B, cq, K, G, hd)
        qpos = q_offset + qi * cq + jnp.arange(cq)

        def kv_body(carry, kj):
            m, l, acc = carry

            # each (q-chunk, kv-chunk) tile is its own remat unit: the
            # backward recomputes s/p per tile (true flash backward) instead
            # of stacking (nq, nkv, B, K, G, cq, ckv) score residuals —
            # measured 14 GiB/device for qwen2 train_4k without this.
            @partial(jax.checkpoint,
                     policy=jax.checkpoint_policies.nothing_saveable)
            def compute(args):
                m, l, acc = args
                kk = jax.lax.dynamic_index_in_dim(kc, kj, 1, keepdims=False)
                vv = jax.lax.dynamic_index_in_dim(vc, kj, 1, keepdims=False)
                kpos = kj * ckv + jnp.arange(ckv)
                s = jnp.einsum("bqkgh,bskh->bkgqs", qq, kk,
                               preferred_element_type=jnp.float32) * scale
                mask = kpos[None, :] <= qpos[:, None] if causal else jnp.ones(
                    (cq, ckv), bool)
                if window:
                    mask &= (qpos[:, None] - kpos[None, :]) < window
                mask &= (kpos < kv_len)[None, :]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                # l in fp32 (sum of exps), but the materialized probability
                # BLOCK is bf16: halves the dominant HBM-traffic term
                # (§Perf hillclimb #1 iter 2); max-normalized exps lose
                # <1e-2 relative which is below bf16 matmul noise anyway.
                p32 = jnp.exp(s - m_new[..., None])
                l_new = l * jnp.exp(m - m_new) + jnp.sum(p32, axis=-1)
                p = p32.astype(vv.dtype)
                corr = jnp.exp(m - m_new)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bkgqs,bskh->bkgqh", p, vv,
                    preferred_element_type=jnp.float32)
                return m_new, l_new, acc_new

            if block_skip:
                needed = kj * ckv <= qpos[-1]
                if window:
                    needed &= (kj + 1) * ckv - 1 > qpos[0] - window
                carry = jax.lax.cond(needed, compute, lambda a: a, (m, l, acc))
            else:
                carry = compute((m, l, acc))
            return carry, None

        m0 = jnp.full((B, K, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, cq), jnp.float32)
        a0 = jnp.zeros((B, K, G, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), jnp.arange(nkv))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4).reshape(B, cq, H, hd)  # (B,cq,H,hd)

    if nq == 1:
        out = q_chunk_body(jnp.int32(0))[:, None]
    else:
        out = jax.lax.map(q_chunk_body, jnp.arange(nq))  # (nq, B, cq, H, hd)
        out = out.transpose(1, 0, 2, 3, 4)
    return out.reshape(B, Sq, H, hd)[:, :Sq0].astype(q.dtype)


def _seqpar_flash(q, k, v, mesh, *, causal, window, block_skip):
    """Context-parallel flash: q's SEQUENCE dim sharded over 'model', k/v
    replicated over 'model' (they already are when the head count doesn't
    divide the axis). Each model rank computes its q slice against the
    full KV — zero collectives inside attention; the (9x-measured) win is
    that per-device score-block HBM traffic drops by the axis size.
    §Perf hillclimb #1 (EXPERIMENTS.md)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    m = mesh.shape["model"]
    B, S, H, hd = q.shape
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    bspec = data_axes if (data_axes and B % max(
        1, int(np.prod([mesh.shape[a] for a in data_axes]))) == 0) else None

    def fn(ql, kl, vl):
        off = jax.lax.axis_index("model") * (S // m)
        return flash_attention(ql, kl, vl, causal=causal, window=window,
                               q_offset=off, block_skip=block_skip)

    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(bspec, "model", None, None), P(bspec, None, None, None),
                  P(bspec, None, None, None)),
        out_specs=P(bspec, "model", None, None),
        check_rep=False,
    )(q, k, v)


def _want_seqpar(cfg, q, k):
    from repro.models.common import current_mesh
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.shape or not cfg.attn_seqpar:
        return None
    m = mesh.shape["model"]
    H, S = q.shape[2], q.shape[1]
    if H % m == 0:          # heads shard fine; TP attention is better
        return None
    if S % m != 0 or S // m < 128:
        return None
    return mesh


def attend_train(p, x, positions, cfg, *, use_rope=True, causal=True,
                 block_skip=False):
    """Full training/prefill attention. Returns (out(B,S,d), k, v)."""
    q, k, v = _qkv(p, x, cfg, positions, use_rope)
    mesh = _want_seqpar(cfg, q, k)
    if mesh is not None:
        o = _seqpar_flash(q, k, v, mesh, causal=causal,
                          window=cfg.sliding_window, block_skip=block_skip)
    else:
        o = flash_attention(q, k, v, causal=causal, window=cfg.sliding_window,
                            block_skip=block_skip)
    o = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return shard_act(o, "batch", "seq", "embed"), k, v


def cross_attend_train(p, x, enc_kv, cfg):
    """Decoder cross-attention against precomputed encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k, v = enc_kv
    o = flash_attention(q, k, v, causal=False)
    o = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return shard_act(o, "batch", "seq", "embed")


def cross_kv(p, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return k, v


# ---------------------------------------------------------------------------
# Decode path (one new token against a KV cache; ring buffer under SWA)
# ---------------------------------------------------------------------------

def quantize_kv(k, axis=-1):
    """Symmetric int8 per-token-per-head quantization.
    k: (..., hd) -> (int8 like k, scale (...,) bf16)."""
    s = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=axis) / 127.0
    s = jnp.maximum(s, 1e-8)
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / s[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.bfloat16)


def decode(p, x, cache_k, cache_v, pos, cfg, *, use_rope=True, ring=False,
           scales=None):
    """x: (B, 1, d); cache_k/v: (B, W, K, hd); pos: (B,) int32 current index.

    Returns (out (B,1,d), new_k_cache, new_v_cache[, new_scales]). If
    `ring`, the cache is a sliding-window ring buffer indexed by pos % W.
    `scales`: (ks, vs) each (B, W, K) for int8 caches (§Perf hillclimb #3:
    halves the decode-dominant cache-read traffic; dequant is folded into
    the score/value einsums so no bf16 cache copy materializes).
    """
    B, _, d = x.shape
    W = cache_k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if use_rope:
        q = rope(q, pos[:, None], cfg.rope_theta)
        k = rope(k, pos[:, None], cfg.rope_theta)

    slot = jnp.mod(pos, W) if ring else jnp.minimum(pos, W - 1)
    bidx = jnp.arange(B)
    if scales is not None:
        ks, vs = scales
        kq, ksc = quantize_kv(k[:, 0])
        vq, vsc = quantize_kv(v[:, 0])
        cache_k = cache_k.at[bidx, slot].set(kq)
        cache_v = cache_v.at[bidx, slot].set(vq)
        ks = ks.at[bidx, slot].set(ksc)
        vs = vs.at[bidx, slot].set(vsc)
    else:
        cache_k = cache_k.at[bidx, slot].set(k[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[bidx, slot].set(v[:, 0].astype(cache_v.dtype))

    H, hd = q.shape[2], q.shape[3]
    K = cache_k.shape[2]
    G = H // K
    qg = q.reshape(B, 1, K, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg,
                   cache_k.astype(qg.dtype),
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    if scales is not None:
        s = s * ks.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, None, :]
    slots = jnp.arange(W)
    if ring:
        valid = (slots[None] <= slot[:, None]) | (pos[:, None] >= W)
    else:
        valid = slots[None] <= slot[:, None]
    s = jnp.where(valid[:, None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    if scales is not None:
        w = w * vs.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, None, :]
    o = jnp.einsum("bkgqs,bskh->bkgqh", w.astype(qg.dtype),
                   cache_v.astype(qg.dtype))
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, 1, H, hd)
    o = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if scales is not None:
        return o, cache_k, cache_v, (ks, vs)
    return o, cache_k, cache_v


def cross_decode(p, x, cross_k, cross_v, kv_len=None):
    """Cross-attention during decode (static encoder cache)."""
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    H, hd = q.shape[2], q.shape[3]
    K = cross_k.shape[2]
    qg = q.reshape(B, 1, K, H // K, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, cross_k,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", w.astype(cross_v.dtype), cross_v)
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, 1, H, hd)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def seed_ring_cache(k, v, window):
    """Convert full prefill K/V (B, S, K, hd) into a ring cache of size W
    positioned such that slot = pos % W, ready for decode at pos = S."""
    B, S, K, hd = k.shape
    W = window
    if S <= W:
        ck = jnp.zeros((B, W, K, hd), k.dtype).at[:, :S].set(k)
        cv = jnp.zeros((B, W, K, hd), v.dtype).at[:, :S].set(v)
        return ck, cv
    idx = np.mod(np.arange(S - W, S), W)
    ck = jnp.zeros((B, W, K, hd), k.dtype).at[:, idx].set(k[:, S - W:])
    cv = jnp.zeros((B, W, K, hd), v.dtype).at[:, idx].set(v[:, S - W:])
    return ck, cv
