"""xLSTM blocks: mLSTM (matrix-memory, chunkwise-parallel) and sLSTM
(scalar-memory, sequential scan).

mLSTM follows the stabilized chunkwise form: per-position stabilizer
m_i = max(b_i + m_prev, max_{j<=i}(b_i - b_j + i~_j)) where b is the
intra-chunk cumulative log-forget and i~ the log input gate; every exp()
is then <= 1. The recurrent state is (C (B,H,Dq,Dv), n (B,H,Dq), m (B,H))
carried across chunks by lax.scan and across decode steps one token at a
time. Correctness of chunked == sequential is asserted in
tests/test_models.py.

Block layout (xLSTM paper, arXiv:2405.04517): mLSTM is a pre-LN residual
block with 2x up-projection, causal conv4 + silu for q/k, per-head gates,
headwise GroupNorm, learnable skip and silu(z) gating. sLSTM is a pre-LN
residual block with a 4-gate recurrent cell (block-diagonal recurrent
matrix over heads) followed by a GeGLU FFN of factor 4/3.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense_init, dtype_of, rms_norm, shard_act

CHUNK = 256


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def m_dims(cfg):
    inner = int(cfg.mlstm_proj_factor * cfg.d_model)
    nh = cfg.n_heads
    hd_v = inner // nh
    hd_qk = cfg.hd()
    return inner, nh, hd_qk, hd_v


def m_init(key, cfg):
    d = cfg.d_model
    inner, nh, hq, hv = m_dims(cfg)
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_up": dense_init(ks[0], (d, inner), dt),
        "w_z": dense_init(ks[1], (d, inner), dt),
        "conv_w": dense_init(ks[2], (4, inner), dt, scale=0.5),
        "conv_b": jnp.zeros((inner,), dt),
        "wq": dense_init(ks[3], (inner, nh, hq), dt),
        "wk": dense_init(ks[4], (inner, nh, hq), dt),
        "w_if": dense_init(ks[5], (inner, nh, 2), jnp.float32, scale=0.01),
        "b_if": jnp.concatenate([jnp.zeros((nh, 1)), jnp.linspace(3.0, 6.0, nh)[:, None]], -1),
        "gn": jnp.ones((nh, hv), dt),
        "skip": jnp.zeros((inner,), dt),
        "w_down": dense_init(ks[6], (inner, d), dt),
    }


def m_specs(cfg):
    return {
        "w_up": ("embed", "inner"),
        "w_z": ("embed", "inner"),
        "conv_w": (None, "inner"),
        "conv_b": ("inner",),
        "wq": ("inner", "heads", "head_dim"),
        "wk": ("inner", "heads", "head_dim"),
        "w_if": ("inner", "heads", None),
        "b_if": ("heads", None),
        "gn": ("heads", None),
        "skip": ("inner",),
        "w_down": ("inner", "embed"),
    }


def _conv4(u, w, b, hist=None):
    k = w.shape[0]
    pad = (
        jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
        if hist is None
        else hist.astype(u.dtype)
    )
    x = jnp.concatenate([pad, u], axis=1)
    return sum(x[:, i : i + u.shape[1]] * w[i] for i in range(k)) + b


def _headnorm(h, gn, eps):
    """Per-head groupnorm on (..., H, Dv)."""
    hf = h.astype(jnp.float32)
    mu = jnp.mean(hf, axis=-1, keepdims=True)
    var = jnp.var(hf, axis=-1, keepdims=True)
    return (hf - mu) * jax.lax.rsqrt(var + eps) * gn.astype(jnp.float32)


def m_apply(p, x, cfg, state=None, return_state=False):
    """x: (B, S, d) -> (B, S, d), chunkwise-parallel stabilized mLSTM."""
    B, S, d = x.shape
    inner, nh, hq, hv = m_dims(cfg)
    Q = min(CHUNK, S)
    assert S % Q == 0
    nc = S // Q
    scale = 1.0 / np.sqrt(hq)

    u = jnp.einsum("bsd,de->bse", x, p["w_up"])
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    c = jax.nn.silu(_conv4(u, p["conv_w"], p["conv_b"]))
    q = jnp.einsum("bse,ehk->bshk", c, p["wq"]) * scale
    k = jnp.einsum("bse,ehk->bshk", c, p["wk"])
    v = u.reshape(B, S, nh, hv)
    gif = jnp.einsum("bse,ehg->bshg", c.astype(jnp.float32), p["w_if"]) + p["b_if"]
    ig = gif[..., 0]                       # (B,S,H) log input gate
    lf = jax.nn.log_sigmoid(gif[..., 1])   # (B,S,H) log forget gate <= 0
    q = shard_act(q, "batch", "seq", "heads", None)
    k = shard_act(k, "batch", "seq", "heads", None)
    v = shard_act(v, "batch", "seq", "heads", None)

    def chunked(t):
        return t.reshape(B, nc, Q, *t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, igc, lfc = map(
        chunked, (q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), ig, lf)
    )

    def body(carry, xs):
        C, n, m = carry  # (B,H,Dq,Dv), (B,H,Dq), (B,H)
        qq, kk, vv, ii, ff = xs
        b = jnp.cumsum(ff, axis=1)  # (B,Q,H) intra-chunk cum log-forget
        # log weights of intra contributions: g[i,j] = b_i - b_j + i~_j (j<=i)
        g = b[:, :, None, :] - b[:, None, :, :] + ii[:, None, :, :]
        causal = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]
        g = jnp.where(causal, g, -jnp.inf)
        m_intra = jnp.max(g, axis=2)  # (B,Q,H)
        m_inter = b + m[:, None, :]  # (B,Q,H)
        mi = jnp.maximum(m_intra, m_inter)
        mi = jnp.maximum(mi, -1e30)  # guard all--inf rows
        w = jnp.exp(g - mi[:, :, None, :])  # (B,Q,Q,H) <= 1
        s = jnp.einsum("bqhk,bshk->bqsh", qq, kk)
        h_intra = jnp.einsum("bqsh,bqsh,bshv->bqhv", s, w, vv)
        dec = jnp.exp(m_inter - mi)  # (B,Q,H)
        h_inter = jnp.einsum("bqhk,bhkv,bqh->bqhv", qq, C, dec)
        h_num = h_intra + h_inter
        n_i = jnp.einsum("bqsh,bshk->bqhk", w, kk) + dec[..., None] * n[:, None]
        qn = jnp.abs(jnp.einsum("bqhk,bqhk->bqh", qq, n_i))
        h = h_num / jnp.maximum(qn, jnp.exp(-mi))[..., None]
        # ---- state update to end of chunk ----
        bQ = b[:, -1]  # (B,H)
        g_st = bQ[:, None, :] - b + ii  # (B,Q,H) weight of each j into state
        m_new = jnp.maximum(jnp.max(g_st, axis=1), bQ + m)
        w_st = jnp.exp(g_st - m_new[:, None, :])
        C = C * jnp.exp(bQ + m - m_new)[..., None, None] + jnp.einsum(
            "bqh,bqhk,bqhv->bhkv", w_st, kk, vv
        )
        n = n * jnp.exp(bQ + m - m_new)[..., None] + jnp.einsum(
            "bqh,bqhk->bhk", w_st, kk
        )
        return (C, n, m_new), h

    if state is None:
        C0 = jnp.zeros((B, nh, hq, hv), jnp.float32)
        n0 = jnp.zeros((B, nh, hq), jnp.float32)
        m0 = jnp.full((B, nh), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state
    (C, n, m), hc = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, igc, lfc))
    h = hc.swapaxes(0, 1).reshape(B, S, nh, hv)
    h = _headnorm(h, p["gn"], cfg.norm_eps).reshape(B, S, inner)
    h = (h + p["skip"].astype(jnp.float32) * c.astype(jnp.float32)) * jax.nn.silu(
        z.astype(jnp.float32)
    )
    out = jnp.einsum("bse,ed->bsd", h.astype(x.dtype), p["w_down"])
    out = shard_act(out, "batch", "seq", "embed")
    if return_state:
        kk = cfg.conv_kernel if cfg.conv_kernel else 4
        hist = u[:, max(S - 3, 0):]
        pad = jnp.zeros((B, max(3 - S, 0), inner), u.dtype)
        return out, (jnp.concatenate([pad, hist], 1), (C, n, m))
    return out


def m_decode(p, x, conv_hist, state, cfg):
    """One-token mLSTM step. x: (B,1,d); conv_hist: (B,3,inner);
    state: (C,n,m). Returns (out, conv_hist, state)."""
    B = x.shape[0]
    inner, nh, hq, hv = m_dims(cfg)
    scale = 1.0 / np.sqrt(hq)

    u = jnp.einsum("bsd,de->bse", x, p["w_up"])
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    hist = jnp.concatenate([conv_hist, u], axis=1)  # (B,4,inner)
    conv_hist = hist[:, 1:]
    c = jax.nn.silu(jnp.einsum("bke,ke->be", hist, p["conv_w"]) + p["conv_b"])
    q = jnp.einsum("be,ehk->bhk", c, p["wq"]).astype(jnp.float32) * scale
    k = jnp.einsum("be,ehk->bhk", c, p["wk"]).astype(jnp.float32)
    v = u[:, 0].reshape(B, nh, hv).astype(jnp.float32)
    gif = jnp.einsum("be,ehg->bhg", c.astype(jnp.float32), p["w_if"]) + p["b_if"]
    ii, ff = gif[..., 0], jax.nn.log_sigmoid(gif[..., 1])

    C, n, m = state
    m_new = jnp.maximum(ff + m, ii)
    fd = jnp.exp(ff + m - m_new)[..., None]
    iw = jnp.exp(ii - m_new)[..., None]
    C = C * fd[..., None] + (iw * k)[..., None] * v[:, :, None, :]
    n = n * fd + iw * k
    h_num = jnp.einsum("bhk,bhkv->bhv", q, C)
    qn = jnp.abs(jnp.einsum("bhk,bhk->bh", q, n))
    h = h_num / jnp.maximum(qn, jnp.exp(-m_new))[..., None]
    h = _headnorm(h, p["gn"], cfg.norm_eps).reshape(B, inner)
    h = (h + p["skip"].astype(jnp.float32) * c.astype(jnp.float32)) * jax.nn.silu(
        z[:, 0].astype(jnp.float32)
    )
    out = jnp.einsum("be,ed->bd", h.astype(x.dtype), p["w_down"])[:, None]
    return out, conv_hist, (C, n, m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def s_dims(cfg):
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    ff = int(round(cfg.d_model * 4 / 3 / 64)) * 64
    return nh, dh, ff


def s_init(key, cfg):
    d = cfg.d_model
    nh, dh, ff = s_dims(cfg)
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    return {
        "w": dense_init(ks[0], (d, 4, d), dt),  # gates z,i,f,o from input
        "r": dense_init(ks[1], (nh, dh, 4, dh), dt, scale=0.01),  # recurrent (blockdiag)
        "b": jnp.zeros((4, d), jnp.float32).at[2].set(
            jnp.tile(jnp.linspace(3.0, 6.0, dh), nh)
        ),
        "gn": jnp.ones((d,), dt),
        "w_ff1": dense_init(ks[2], (d, 2 * ff), dt),
        "w_ff2": dense_init(ks[3], (ff, d), dt),
    }


def s_specs(cfg):
    return {
        "w": ("embed", None, "inner"),
        "r": ("heads", None, None, None),
        "b": (None, "inner"),
        "gn": ("embed",),
        "w_ff1": ("embed", "mlp"),
        "w_ff2": ("mlp", "embed"),
    }


def _s_cell(p, wx_t, state, cfg):
    """One sLSTM timestep. wx_t: (B,4,d) precomputed input contribution."""
    nh, dh, _ = s_dims(cfg)
    h, c, n, m = state  # h,c,n: (B,d); m: (B,d)
    B, d = h.shape
    hh = h.reshape(B, nh, dh)
    rh = jnp.einsum("bhk,hkgl->bhgl", hh.astype(jnp.float32), p["r"].astype(jnp.float32))
    g = wx_t.astype(jnp.float32).reshape(B, 4, nh, dh) + rh.transpose(0, 2, 1, 3)
    g = g.reshape(B, 4, d) + p["b"]
    zt = jnp.tanh(g[:, 0])
    it = g[:, 1]                      # log-space input gate
    ft = jax.nn.log_sigmoid(g[:, 2])  # log-space forget gate
    ot = jax.nn.sigmoid(g[:, 3])
    m_new = jnp.maximum(ft + m, it)
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(ft + m - m_new)
    c_new = f_ * c + i_ * zt
    n_new = f_ * n + i_
    h_new = ot * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return h_new, c_new, n_new, m_new


def s_apply(p, x, cfg, state=None, return_state=False):
    """x: (B, S, d). Sequential scan over S (inherently serial)."""
    B, S, d = x.shape
    wx = jnp.einsum("bsd,dge->bsge", x, p["w"])  # (B,S,4,d)
    if state is None:
        z = jnp.zeros((B, d), jnp.float32)
        state = (z, z, z, jnp.full((B, d), -1e30, jnp.float32))

    def body(st, wx_t):
        h, c, n, m = _s_cell(p, wx_t, st, cfg)
        return (h, c, n, m), h

    state, hs = jax.lax.scan(body, state, wx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1)  # (B,S,d)
    h = rms_norm(h.astype(x.dtype), p["gn"], cfg.norm_eps)
    # GeGLU FFN
    ff = jnp.einsum("bsd,df->bsf", h, p["w_ff1"])
    a, b = jnp.split(ff, 2, axis=-1)
    out = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(a.astype(jnp.float32)).astype(x.dtype) * b, p["w_ff2"])
    out = shard_act(out, "batch", "seq", "embed")
    if return_state:
        return out, state
    return out


def s_decode(p, x, state, cfg):
    out, state = s_apply(p, x, cfg, state=state, return_state=True)
    return out, state
