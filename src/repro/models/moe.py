"""Mixture-of-Experts FFN (GShard-style top-k with capacity, scatter dispatch).

Design (DESIGN.md §5): activations are replicated across the 'model' mesh
axis between blocks (TP layout), so expert parallelism needs NO token
all-to-all: every model-rank sees all local-data-shard tokens, keeps only
assignments routed to ITS experts, computes, and the per-rank partial
outputs are summed by the same all-reduce a dense TP FFN would need.

Two weight layouts, one code path:
  * EP  (E % model_axis == 0, e.g. arctic 128e/16): experts sharded over
    'model'; each rank owns E_loc experts at offset rank*E_loc.
  * TP  (E < model_axis, e.g. mixtral 8e/16): all experts on every rank
    with d_ff sharded over 'model'; partial-ff outputs psum'd.

The (T, E, C) one-hot einsum of the original GShard paper is replaced by a
scatter-add into an (E_loc, C, d) buffer — O(T·k·d) instead of O(T·E·C).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import act_fn, dense_init, dtype_of, shard_act

_MODEL_AXIS = "model"


def init(key, cfg):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "w1": dense_init(ks[1], (E, d, f), dt),
        "w3": dense_init(ks[2], (E, d, f), dt),
        "w2": dense_init(ks[3], (E, f, d), dt, scale=1.0 / np.sqrt(f)),
    }


def specs(cfg):
    return {
        "router": ("embed", None),
        "w1": ("experts", "embed", "expert_mlp"),
        "w3": ("experts", "embed", "expert_mlp"),
        "w2": ("experts", "expert_mlp", "embed"),
    }


def _route(x32, router_w, k):
    """x32: (T, d) fp32. Returns gates (T, k), expert ids (T, k), aux loss."""
    logits = x32 @ router_w  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balancing aux loss.
    E = router_w.shape[-1]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return gates, idx, aux


def _moe_local(x, router_w, w1, w3, w2, cfg, e_offset, axis_name=None,
               mean_axes=None, capacity=None):
    """x: (T, d) tokens local to this device (replicated over model axis).

    e_offset: first global expert id owned by this rank (EP) or 0 (TP).
    w*: local expert weights (E_loc, d, f_loc).
    """
    T, d = x.shape
    E = cfg.n_experts
    E_loc = w1.shape[0]
    k = cfg.top_k
    act = act_fn(cfg.act)

    gates, idx, aux = _route(x.astype(jnp.float32), router_w, k)

    flat_e = idx.reshape(-1)                      # (T*k,) global expert ids
    flat_g = gates.reshape(-1).astype(jnp.float32)
    flat_t = jnp.repeat(jnp.arange(T), k)

    # Slot within each expert: rank of this assignment among same-expert
    # assignments, in token order (consistent across ranks: full router view).
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)       # (T*k, E)
    slot = (jnp.cumsum(onehot, axis=0) - 1)[jnp.arange(T * k), flat_e]
    C = capacity or max(1, int(math.ceil(T * k * cfg.capacity_factor / E)))

    local = (flat_e >= e_offset) & (flat_e < e_offset + E_loc)
    keep = (slot < C) & local
    le = jnp.clip(flat_e - e_offset, 0, E_loc - 1)
    slot_c = jnp.clip(slot, 0, C - 1)

    # Dispatch: scatter tokens into (E_loc, C, d).
    upd = x[flat_t] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((E_loc, C, d), x.dtype).at[le, slot_c].add(
        upd, mode="drop")

    h = act(jnp.einsum("ecd,edf->ecf", buf, w1)) * jnp.einsum(
        "ecd,edf->ecf", buf, w3)
    out_e = jnp.einsum("ecf,efd->ecd", h, w2)     # (E_loc, C, d)

    # Combine: gather expert outputs back to tokens, weighted by gates.
    contrib = out_e[le, slot_c] * (flat_g * keep).astype(out_e.dtype)[:, None]
    y = jnp.zeros((T, d), out_e.dtype).at[flat_t].add(contrib, mode="drop")

    if axis_name is not None:
        y = jax.lax.psum(y, axis_name)
        # aux must come out replicated over the WHOLE mesh (out_spec P()).
        aux = jax.lax.pmean(aux, mean_axes or axis_name)
    return y, aux


import os

# decode-scale token counts take the 2D weight-stationary path; settable
# to 0 (env REPRO_MOE_SMALL_T=0) to reproduce the paper-faithful baseline
SMALL_T = int(os.environ.get("REPRO_MOE_SMALL_T", "4096"))


def _apply_small_t(p, xt, cfg, mesh):
    """Decode path (§Perf hillclimb #2): tokens are tiny (a few thousand),
    expert weights are huge. Replicate the TOKENS over the whole mesh and
    keep the WEIGHTS fully stationary in their 2D (experts@model,
    d_ff@data) shards: each rank computes its expert/f-slice partials for
    all tokens and one psum of (T, d) activations replaces the 58 GB/step
    expert all-gather. Dropless (C = T)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    T = xt.shape[0]
    data_axes = tuple(a for a in mesh.axis_names if a != _MODEL_AXIS)
    all_axes = tuple(mesh.axis_names)
    w13 = P(_MODEL_AXIS, None, data_axes)
    w2s = P(_MODEL_AXIS, data_axes, None)

    def fn(xt, router_w, w1, w3, w2):
        e_off = jax.lax.axis_index(_MODEL_AXIS) * w1.shape[0]
        return _moe_local(xt, router_w, w1, w3, w2, cfg, e_off,
                          axis_name=all_axes, mean_axes=all_axes,
                          capacity=T)

    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(None, None), P(None, None), w13, w13, w2s),
        out_specs=(P(None, None), P()),
        check_rep=False,
    )(xt, p["router"], p["w1"], p["w3"], p["w2"])


def apply(p, x, cfg, mesh=None):
    """x: (B, S, d) -> (B, S, d), aux loss. Uses shard_map when a mesh with a
    'model' axis is active, plain local computation otherwise."""
    B, S, d = x.shape
    xt = x.reshape(B * S, d)

    if mesh is None or _MODEL_AXIS not in mesh.shape:
        y, aux = _moe_local(xt, p["router"], p["w1"], p["w3"], p["w2"], cfg, 0)
        return y.reshape(B, S, d).astype(x.dtype), aux

    small_t = int(os.environ.get("REPRO_MOE_SMALL_T", SMALL_T))
    m_sz = mesh.shape[_MODEL_AXIS]
    n_dat = int(np.prod([s for a, s in mesh.shape.items()
                         if a != _MODEL_AXIS]))
    if (B * S <= small_t and cfg.n_experts % m_sz == 0
            and cfg.n_experts >= m_sz and cfg.d_ff % max(n_dat, 1) == 0):
        y, aux = _apply_small_t(p, xt, cfg, mesh)
        return y.reshape(B, S, d).astype(x.dtype), aux

    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    m = mesh.shape[_MODEL_AXIS]
    ep = cfg.n_experts % m == 0 and cfg.n_experts >= m
    # data axes: everything except 'model' shards the token dim (replicate
    # tokens when too few to split, e.g. batch-1 long-context decode).
    data_axes = tuple(a for a in mesh.axis_names if a != _MODEL_AXIS)
    n_data = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1
    if (B * S) % max(n_data, 1) != 0:
        data_axes = ()
    xs = P(data_axes, None) if data_axes else P(None, None)
    if ep:
        wspec = P(_MODEL_AXIS, None, None)
    else:
        wspec = P(None, None, _MODEL_AXIS)

    def fn(xt, router_w, w1, w3, w2):
        e_off = jax.lax.axis_index(_MODEL_AXIS) * w1.shape[0] if ep else 0
        return _moe_local(xt, router_w, w1, w3, w2, cfg, e_off,
                          axis_name=_MODEL_AXIS,
                          mean_axes=tuple(mesh.axis_names))

    y, aux = shard_map(
        fn, mesh=mesh,
        in_specs=(xs, P(None, None), wspec, wspec,
                  P(None, _MODEL_AXIS, None) if not ep else wspec),
        out_specs=(xs, P()),
        check_rep=False,
    )(xt, p["router"], p["w1"], p["w3"], p["w2"])
    return y.reshape(B, S, d).astype(x.dtype), aux
