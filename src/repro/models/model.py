"""Model facade: one class driving all 10 assigned architectures.

Families:
  dense / vlm   - stacked dense decoder blocks (vlm prepends patch embeds)
  moe           - stacked MoE decoder blocks (arctic adds dense residual)
  hybrid        - zamba2: 54 Mamba2 layers + ONE shared attn+MLP block
                  applied after every `attn_every` Mamba layers
  ssm           - xlstm: groups of (slstm_every-1) mLSTM + 1 sLSTM
  audio         - whisper: encoder (frames stub) + cross-attn decoder

All layer stacks are lax.scan over STACKED params (compile-time constant
HLO size regardless of depth). remat policy per cfg.remat.

API:
  init(key)                                -> params
  param_specs()                            -> logical-axis tree
  loss(params, batch)                      -> (scalar, metrics)
  prefill(params, batch)                   -> (logits_last, cache, pos)
  decode_step(params, cache, token, pos)   -> (logits, cache)
  decode_loop(params, cache, token, pos, emitted, max_new, done, eos,
              sample_fn, keys, n_tokens=K) -> fused K-token decode scan
  init_cache(B, W)                         -> zeroed cache tree
  cache_specs(W)                           -> logical-axis tree for cache
  param_count(active_only=False)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention, moe, ssm, transformer as tfm, xlstm
from repro.models.common import (chunked_softmax_xent, dense_init, dtype_of,
                                 norm, norm_init, norm_specs, shard_act,
                                 sinusoid_at, sinusoidal_positions)

Params = Dict[str, Any]


def _stack_init(fn, key, n, *args):
    return jax.vmap(lambda k: fn(k, *args))(jax.random.split(key, n))


def _add_layer_axis(tree):
    return jax.tree.map(lambda s: ("layers",) + tuple(s),
                        tree, is_leaf=lambda s: isinstance(s, tuple))


def _remat(fn, mode):
    if mode == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


class Model:
    def __init__(self, cfg, mesh=None, block_skip=False):
        self.cfg = cfg
        self.mesh = mesh
        self.block_skip = block_skip

    # ------------------------------------------------------------------
    # init / specs
    # ------------------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        dt = dtype_of(cfg)
        k_emb, k_blocks, k_head, k_extra = jax.random.split(key, 4)
        p: Params = {
            "embed": dense_init(k_emb, (cfg.vocab_size, cfg.d_model), dt, scale=0.02),
            "final_norm": norm_init(cfg),
        }
        if not cfg.tie_embeddings:
            p["unembed"] = dense_init(k_head, (cfg.d_model, cfg.vocab_size), dt)

        fam = cfg.family
        if fam in ("dense", "vlm"):
            p["blocks"] = _stack_init(tfm.dense_block_init, k_blocks, cfg.n_layers, cfg)
        elif fam == "moe":
            p["blocks"] = _stack_init(tfm.moe_block_init, k_blocks, cfg.n_layers, cfg)
        elif fam == "hybrid":
            p["mamba"] = _stack_init(ssm.init, k_blocks, cfg.n_layers, cfg)
            p["shared_attn"] = tfm.dense_block_init(k_extra, cfg)
        elif fam == "ssm":
            n_s = cfg.n_layers // cfg.slstm_every
            n_m = cfg.n_layers - n_s
            p["mlstm"] = _stack_init(xlstm.m_init, k_blocks, n_m, cfg)
            p["slstm"] = _stack_init(xlstm.s_init, k_extra, n_s, cfg)
        elif fam == "audio":
            p["enc"] = _stack_init(tfm.enc_block_init, k_extra, cfg.n_enc_layers, cfg)
            p["enc_norm"] = norm_init(cfg)
            p["dec"] = _stack_init(tfm.xdec_block_init, k_blocks, cfg.n_layers, cfg)
        else:
            raise ValueError(fam)
        return p

    def param_specs(self):
        cfg = self.cfg
        p = {"embed": ("vocab", "embed_fsdp"), "final_norm": norm_specs(cfg)}
        if not cfg.tie_embeddings:
            p["unembed"] = ("embed_fsdp", "vocab")
        fam = cfg.family
        if fam in ("dense", "vlm"):
            p["blocks"] = _add_layer_axis(tfm.dense_block_specs(cfg))
        elif fam == "moe":
            p["blocks"] = _add_layer_axis(tfm.moe_block_specs(cfg))
        elif fam == "hybrid":
            p["mamba"] = _add_layer_axis(ssm.specs(cfg))
            p["shared_attn"] = tfm.dense_block_specs(cfg)
        elif fam == "ssm":
            p["mlstm"] = _add_layer_axis(xlstm.m_specs(cfg))
            p["slstm"] = _add_layer_axis(xlstm.s_specs(cfg))
        elif fam == "audio":
            p["enc"] = _add_layer_axis(tfm.enc_block_specs(cfg))
            p["enc_norm"] = norm_specs(cfg)
            p["dec"] = _add_layer_axis(tfm.xdec_block_specs(cfg))
        return p

    # ------------------------------------------------------------------
    # embedding helpers
    # ------------------------------------------------------------------
    def _embed(self, p, tokens):
        h = jnp.take(p["embed"], tokens, axis=0)
        return shard_act(h, "batch", "seq", None)

    def _unembed_w(self, p):
        return p["embed"].T if self.cfg.tie_embeddings else p["unembed"]

    def _logits_last(self, p, h_last):
        """h_last: (B, d) -> (B, V) fp32."""
        return jnp.einsum("bd,dv->bv", h_last, self._unembed_w(p),
                          preferred_element_type=jnp.float32)

    # ------------------------------------------------------------------
    # backbone: train forward (no caches)
    # ------------------------------------------------------------------
    def _backbone_train(self, p, h, positions):
        cfg, mesh = self.cfg, self.mesh
        fam = cfg.family
        aux = jnp.float32(0.0)

        if fam in ("dense", "vlm"):
            def body(x, bp):
                return tfm.dense_block_apply(bp, x, positions, cfg,
                                             block_skip=self.block_skip), None
            h, _ = jax.lax.scan(_remat(body, cfg.remat), h, p["blocks"])

        elif fam == "moe":
            def body(carry, bp):
                x, a = carry
                x, al = tfm.moe_block_apply(bp, x, positions, cfg, mesh=mesh,
                                            block_skip=self.block_skip)
                return (x, a + al), None
            (h, aux), _ = jax.lax.scan(_remat(body, cfg.remat), (h, aux), p["blocks"])

        elif fam == "hybrid":
            per = cfg.attn_every
            ng = cfg.n_layers // per
            mamba = jax.tree.map(
                lambda a: a.reshape(ng, per, *a.shape[1:]), p["mamba"])

            def inner(x, mp):
                return ssm.apply(mp, x, cfg) + x, None

            def group(x, gp):
                x, _ = jax.lax.scan(_remat(inner, cfg.remat), x, gp)
                x = tfm.dense_block_apply(p["shared_attn"], x, positions, cfg,
                                          block_skip=self.block_skip)
                return x, None
            h, _ = jax.lax.scan(group, h, mamba)

        elif fam == "ssm":
            per = cfg.slstm_every
            ng = cfg.n_layers // per
            ml = jax.tree.map(
                lambda a: a.reshape(ng, per - 1, *a.shape[1:]), p["mlstm"])

            def inner(x, mp):
                return xlstm.m_apply(mp, x, cfg) + x, None

            def group(x, gps):
                gm, gs = gps
                x, _ = jax.lax.scan(_remat(inner, cfg.remat), x, gm)
                x = x + xlstm.s_apply(gs, x, cfg)
                return x, None
            h, _ = jax.lax.scan(group, h, (ml, p["slstm"]))

        elif fam == "audio":
            raise RuntimeError("audio handled by _audio_train")
        return h, aux

    # ------------------------------------------------------------------
    # loss (train step forward)
    # ------------------------------------------------------------------
    def loss(self, p, batch):
        cfg = self.cfg
        fam = cfg.family
        tokens, labels = batch["tokens"], batch["labels"]
        B = tokens.shape[0]

        if fam == "audio":
            frames = batch["frames"].astype(dtype_of(cfg))
            F = frames.shape[1]
            pe = sinusoidal_positions(F, cfg.d_model).astype(frames.dtype)
            e = frames + pe[None]
            e = shard_act(e, "batch", "seq", None)

            def ebody(x, bp):
                return tfm.enc_block_apply(bp, x, cfg), None
            e, _ = jax.lax.scan(_remat(ebody, cfg.remat), e, p["enc"])
            enc_out = norm(e, p["enc_norm"], cfg)

            S = tokens.shape[1]
            h = self._embed(p, tokens)
            h = h + sinusoidal_positions(S, cfg.d_model).astype(h.dtype)[None]
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))

            def dbody(x, bp):
                y, _, _ = tfm.xdec_block_apply(bp, x, enc_out, positions, cfg)
                return y, None
            h, _ = jax.lax.scan(_remat(dbody, cfg.remat), h, p["dec"])
            aux = jnp.float32(0.0)
        else:
            if fam == "vlm":
                patches = batch["patches"].astype(dtype_of(cfg))
                ht = self._embed(p, tokens)
                h = jnp.concatenate([patches, ht], axis=1)
                # loss only on text positions: pad labels with ignore_index
                pad = jnp.full((B, patches.shape[1]), -100, labels.dtype)
                labels = jnp.concatenate([pad, labels], axis=1)
            else:
                h = self._embed(p, tokens)
            S = h.shape[1]
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
            h, aux = self._backbone_train(p, h, positions)

        h = norm(h, p["final_norm"], cfg)
        ce = chunked_softmax_xent(h, self._unembed_w(p), labels)
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def kv_window(self, seq_len):
        cfg = self.cfg
        # ring caches are ALWAYS exactly sliding_window long: the ring
        # index math (slot = pos % W) and the prefill seeding both assume
        # W == cfg.sliding_window.
        return cfg.sliding_window if cfg.sliding_window else seq_len

    def init_cache(self, B, W):
        cfg = self.cfg
        dt = dtype_of(cfg)
        K, hd, L = cfg.n_kv_heads, cfg.hd(), cfg.n_layers
        fam = cfg.family
        if fam in ("dense", "vlm", "moe"):
            W = self.kv_window(W)
            if self._int8_kv():
                return {"k": jnp.zeros((L, B, W, K, hd), jnp.int8),
                        "v": jnp.zeros((L, B, W, K, hd), jnp.int8),
                        "ksc": jnp.zeros((L, B, W, K), jnp.bfloat16),
                        "vsc": jnp.zeros((L, B, W, K), jnp.bfloat16)}
            return {"k": jnp.zeros((L, B, W, K, hd), dt),
                    "v": jnp.zeros((L, B, W, K, hd), dt)}
        if fam == "audio":
            F = cfg.enc_frames
            return {"k": jnp.zeros((L, B, W, K, hd), dt),
                    "v": jnp.zeros((L, B, W, K, hd), dt),
                    "xk": jnp.zeros((L, B, F, K, hd), dt),
                    "xv": jnp.zeros((L, B, F, K, hd), dt)}
        if fam == "hybrid":
            di, nh, cdim = ssm.dims(cfg)
            napp = cfg.n_layers // cfg.attn_every
            return {
                "conv": jnp.zeros((L, B, cfg.conv_kernel - 1, cdim), dt),
                "ssm": jnp.zeros((L, B, nh, cfg.ssm_headdim, cfg.ssm_state),
                                 jnp.float32),
                "k": jnp.zeros((napp, B, W, K, hd), dt),
                "v": jnp.zeros((napp, B, W, K, hd), dt),
            }
        if fam == "ssm":
            inner, nh, hq, hv = xlstm.m_dims(cfg)
            n_s = L // cfg.slstm_every
            n_m = L - n_s
            d = cfg.d_model
            return {
                "mconv": jnp.zeros((n_m, B, 3, inner), dt),
                "mC": jnp.zeros((n_m, B, nh, hq, hv), jnp.float32),
                "mN": jnp.zeros((n_m, B, nh, hq), jnp.float32),
                "mM": jnp.full((n_m, B, nh), -1e30, jnp.float32),
                "sh": jnp.zeros((n_s, B, d), jnp.float32),
                "sc": jnp.zeros((n_s, B, d), jnp.float32),
                "sn": jnp.zeros((n_s, B, d), jnp.float32),
                "sm": jnp.full((n_s, B, d), -1e30, jnp.float32),
            }
        raise ValueError(fam)

    def _int8_kv(self):
        # int8 KV: decode-path quantized cache (§Perf hillclimb #3).
        # ring buffers (SWA) keep bf16 (seeding rotates quantized rows).
        return (self.cfg.kv_dtype == "int8"
                and self.cfg.sliding_window == 0
                and self.cfg.family in ("dense", "vlm", "moe"))

    def cache_specs(self):
        fam = self.cfg.family
        kv = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
        sc = ("layers", "batch", "kv_seq", "kv_heads")
        if fam in ("dense", "vlm", "moe"):
            if self._int8_kv():
                return {"k": kv, "v": kv, "ksc": sc, "vsc": sc}
            return {"k": kv, "v": kv}
        if fam == "audio":
            return {"k": kv, "v": kv, "xk": kv, "xv": kv}
        if fam == "hybrid":
            return {"conv": ("layers", "batch", None, "conv_dim"),
                    "ssm": ("layers", "batch", "ssm_heads", None, None),
                    "k": kv, "v": kv}
        if fam == "ssm":
            return {"mconv": ("layers", "batch", None, "inner"),
                    "mC": ("layers", "batch", "heads", None, None),
                    "mN": ("layers", "batch", "heads", None),
                    "mM": ("layers", "batch", "heads"),
                    "sh": ("layers", "batch", "embed"),
                    "sc": ("layers", "batch", "embed"),
                    "sn": ("layers", "batch", "embed"),
                    "sm": ("layers", "batch", "embed")}
        raise ValueError(fam)

    # ------------------------------------------------------------------
    # prefill: full forward that also builds the cache; returns logits of
    # the last position. W (cache window) == padded cache length.
    # ------------------------------------------------------------------
    def prefill(self, p, batch, W=None):
        cfg, mesh = self.cfg, self.mesh
        fam = cfg.family
        tokens = batch["tokens"]
        B, S = tokens.shape[0], None
        ring = cfg.sliding_window > 0

        def pad_kv(k):
            # k: (L, B, S, K, hd) -> (L, B, W_eff, K, hd)
            Sk = k.shape[2]
            W_eff = self.kv_window(W or Sk)
            if W_eff == Sk:
                return k
            pad = [(0, 0)] * k.ndim
            pad[2] = (0, W_eff - Sk)
            return jnp.pad(k, pad)

        if fam in ("dense", "vlm", "moe"):
            if fam == "vlm":
                patches = batch["patches"].astype(dtype_of(cfg))
                h = jnp.concatenate([patches, self._embed(p, tokens)], axis=1)
            else:
                h = self._embed(p, tokens)
            S = h.shape[1]
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))

            if fam == "moe":
                def body(x, bp):
                    y, (k, v), _ = tfm.moe_block_prefill(bp, x, positions, cfg,
                                                         mesh=mesh)
                    return y, (k, v)
            else:
                def body(x, bp):
                    y, (k, v) = tfm.dense_block_prefill(bp, x, positions, cfg)
                    return y, (k, v)
            h, (ks, vs) = jax.lax.scan(_remat(body, cfg.remat), h, p["blocks"])
            if ring:
                Wr = cfg.sliding_window
                seeded = jax.vmap(
                    lambda a, b: attention.seed_ring_cache(a, b, Wr))(ks, vs)
                cache = {"k": seeded[0], "v": seeded[1]}
            elif self._int8_kv():
                kq, ksc = attention.quantize_kv(pad_kv(ks))
                vq, vsc = attention.quantize_kv(pad_kv(vs))
                cache = {"k": kq, "v": vq, "ksc": ksc, "vsc": vsc}
            else:
                cache = {"k": pad_kv(ks), "v": pad_kv(vs)}

        elif fam == "audio":
            frames = batch["frames"].astype(dtype_of(cfg))
            F = frames.shape[1]
            e = frames + sinusoidal_positions(F, cfg.d_model).astype(frames.dtype)[None]

            def ebody(x, bp):
                return tfm.enc_block_apply(bp, x, cfg), None
            e, _ = jax.lax.scan(_remat(ebody, cfg.remat), e, p["enc"])
            enc_out = norm(e, p["enc_norm"], cfg)

            S = tokens.shape[1]
            h = self._embed(p, tokens)
            h = h + sinusoidal_positions(S, cfg.d_model).astype(h.dtype)[None]
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))

            def dbody(x, bp):
                y, (k, v), (xk, xv) = tfm.xdec_block_apply(bp, x, enc_out,
                                                           positions, cfg)
                return y, (k, v, xk, xv)
            h, (ks, vs, xks, xvs) = jax.lax.scan(_remat(dbody, cfg.remat), h,
                                                 p["dec"])
            cache = {"k": pad_kv(ks), "v": pad_kv(vs), "xk": xks, "xv": xvs}

        elif fam == "hybrid":
            h = self._embed(p, tokens)
            S = h.shape[1]
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
            per = cfg.attn_every
            ng = cfg.n_layers // per
            mamba = jax.tree.map(lambda a: a.reshape(ng, per, *a.shape[1:]),
                                 p["mamba"])

            def inner(x, mp):
                y, cs, st = ssm.apply(mp, x, cfg, return_state=True)
                return x + y, (cs, st)

            def group(x, gp):
                x, (cs, st) = jax.lax.scan(_remat(inner, cfg.remat), x, gp)
                x, (k, v) = tfm.dense_block_prefill(p["shared_attn"], x,
                                                    positions, cfg)
                return x, (cs, st, k, v)
            h, (css, sts, ks, vs) = jax.lax.scan(group, h, mamba)
            cache = {
                "conv": css.reshape(cfg.n_layers, *css.shape[2:]),
                "ssm": sts.reshape(cfg.n_layers, *sts.shape[2:]),
                "k": pad_kv(ks), "v": pad_kv(vs),
            }

        elif fam == "ssm":
            h = self._embed(p, tokens)
            S = h.shape[1]
            per = cfg.slstm_every
            ng = cfg.n_layers // per
            ml = jax.tree.map(lambda a: a.reshape(ng, per - 1, *a.shape[1:]),
                              p["mlstm"])

            def inner(x, mp):
                y, (cs, st) = xlstm.m_apply(mp, x, cfg, return_state=True)
                return x + y, (cs, st)

            def group(x, gps):
                gm, gs = gps
                x, (cs, st) = jax.lax.scan(_remat(inner, cfg.remat), x, gm)
                y, sstate = xlstm.s_apply(gs, x, cfg, return_state=True)
                return x + y, (cs, st, sstate)
            h, (css, sts, sstates) = jax.lax.scan(group, h, (ml, p["slstm"]))
            n_m = cfg.n_layers - ng
            cache = {
                "mconv": css.reshape(n_m, *css.shape[2:]),
                "mC": sts[0].reshape(n_m, *sts[0].shape[2:]),
                "mN": sts[1].reshape(n_m, *sts[1].shape[2:]),
                "mM": sts[2].reshape(n_m, *sts[2].shape[2:]),
                "sh": sstates[0], "sc": sstates[1],
                "sn": sstates[2], "sm": sstates[3],
            }
        else:
            raise ValueError(fam)

        h = norm(h, p["final_norm"], cfg)
        logits = self._logits_last(p, h[:, -1])
        pos = jnp.full((B,), S, jnp.int32)
        return logits, cache, pos

    # ------------------------------------------------------------------
    # decode: one token against the cache
    # ------------------------------------------------------------------
    def decode_step(self, p, cache, token, pos):
        """token: (B, 1) int32; pos: (B,) int32. Returns (logits, cache)."""
        cfg, mesh = self.cfg, self.mesh
        fam = cfg.family
        x = self._embed(p, token)
        ring = cfg.sliding_window > 0

        if fam in ("dense", "vlm", "moe"):
            int8 = self._int8_kv()
            dec = tfm.moe_block_decode if fam == "moe" else \
                tfm.dense_block_decode
            kw = {"mesh": mesh} if fam == "moe" else {}

            if int8:
                def body(x, xs):
                    bp, ck, cv, ksc, vsc = xs
                    y, ck, cv, (ksc, vsc) = dec(bp, x, ck, cv, pos, cfg,
                                                ring=ring,
                                                scales=(ksc, vsc), **kw)
                    return y, (ck, cv, ksc, vsc)
                x, (ks, vs, kss, vss) = jax.lax.scan(
                    body, x, (p["blocks"], cache["k"], cache["v"],
                              cache["ksc"], cache["vsc"]))
                cache = {"k": ks, "v": vs, "ksc": kss, "vsc": vss}
            else:
                def body(x, xs):
                    bp, ck, cv = xs
                    y, ck, cv = dec(bp, x, ck, cv, pos, cfg, ring=ring, **kw)
                    return y, (ck, cv)
                x, (ks, vs) = jax.lax.scan(
                    body, x, (p["blocks"], cache["k"], cache["v"]))
                cache = {"k": ks, "v": vs}

        elif fam == "audio":
            x = x + sinusoid_at(pos, cfg.d_model).astype(x.dtype)

            def body(x, xs):
                bp, ck, cv, xk, xv = xs
                y, ck, cv = tfm.xdec_block_decode(bp, x, ck, cv, xk, xv, pos, cfg)
                return y, (ck, cv)
            x, (ks, vs) = jax.lax.scan(
                body, x, (p["dec"], cache["k"], cache["v"], cache["xk"], cache["xv"]))
            cache = {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"]}

        elif fam == "hybrid":
            per = cfg.attn_every
            ng = cfg.n_layers // per
            r = lambda a: a.reshape(ng, per, *a.shape[1:])
            mamba = jax.tree.map(r, p["mamba"])

            def inner(x, xs):
                mp, cs, st = xs
                y, cs, st = ssm.decode_step(mp, x, cs, st, cfg)
                return x + y, (cs, st)

            def group(x, xs):
                gp, gcs, gst, ck, cv = xs
                x, (cs, st) = jax.lax.scan(inner, x, (gp, gcs, gst))
                x, ck, cv = tfm.dense_block_decode(p["shared_attn"], x, ck, cv,
                                                   pos, cfg)
                return x, (cs, st, ck, cv)
            x, (css, sts, ks, vs) = jax.lax.scan(
                group, x, (mamba, r(cache["conv"]), r(cache["ssm"]),
                           cache["k"], cache["v"]))
            cache = {"conv": css.reshape(cfg.n_layers, *css.shape[2:]),
                     "ssm": sts.reshape(cfg.n_layers, *sts.shape[2:]),
                     "k": ks, "v": vs}

        elif fam == "ssm":
            per = cfg.slstm_every
            ng = cfg.n_layers // per
            rm = lambda a: a.reshape(ng, per - 1, *a.shape[1:])
            ml = jax.tree.map(rm, p["mlstm"])

            def inner(x, xs):
                mp, hist, C, n, m = xs
                y, hist, (C, n, m) = xlstm.m_decode(mp, x, hist, (C, n, m), cfg)
                return x + y, (hist, C, n, m)

            def group(x, xs):
                gm, hist, C, n, m, gs, sh, sc, sn, sm = xs
                x, (hist, C, n, m) = jax.lax.scan(inner, x,
                                                  (gm, hist, C, n, m))
                y, sstate = xlstm.s_decode(gs, x, (sh, sc, sn, sm), cfg)
                return x + y, (hist, C, n, m) + sstate
            x, outs = jax.lax.scan(
                group, x,
                (ml, rm(cache["mconv"]), rm(cache["mC"]), rm(cache["mN"]),
                 rm(cache["mM"]), p["slstm"], cache["sh"], cache["sc"],
                 cache["sn"], cache["sm"]))
            hist, C, n, m, sh, sc, sn, sm = outs
            n_m = cfg.n_layers - ng
            flat = lambda a: a.reshape(n_m, *a.shape[2:])
            cache = {"mconv": flat(hist), "mC": flat(C), "mN": flat(n),
                     "mM": flat(m), "sh": sh, "sc": sc, "sn": sn, "sm": sm}
        else:
            raise ValueError(fam)

        h = norm(x, p["final_norm"], cfg)
        logits = self._logits_last(p, h[:, -1])
        return logits, cache

    # ------------------------------------------------------------------
    # decode loop: K fused decode+sample steps per host dispatch
    # ------------------------------------------------------------------
    def decode_loop(self, p, cache, token, pos, emitted, max_new, done, eos,
                    sample_fn, keys, *, n_tokens):
        """`n_tokens` decode steps fused into one lax.scan.

        token: (B, 1) int32 feedback tokens; pos / emitted / max_new /
        eos: (B,) int32 (eos < 0 means "no stop token"); done: (B,) bool;
        keys: (n_tokens,) PRNG keys; sample_fn(logits, key) -> (B,) int32
        (the engine closes it over per-slot temperature / top-k).

        Per-slot stop state is carried through the scan: finished slots
        freeze — their pos/emitted stop advancing and their feedback
        token is re-fed, so the repeated cache write at the frozen
        position is idempotent for KV families and only perturbs state
        the host will overwrite on re-admission for recurrent families.

        Returns (cache, token, pos, emitted, done, toks, live) with toks
        and live shaped (n_tokens, B): token k belongs to slot b's output
        stream iff live[k, b] (slots freeze monotonically, so the live
        column is a prefix mask).

        The carry signature is donation-safe: every carried array is
        returned with identical shape/dtype, so callers can jit with
        donate_argnums over (cache, token, pos, emitted, done) and the
        KV cache updates in place instead of round-tripping.
        """
        def step(carry, key):
            cache, token, pos, emitted, done = carry
            logits, cache = self.decode_step(p, cache, token, pos)
            tok = sample_fn(logits, key)
            live = ~done
            tok = jnp.where(live, tok, token[:, 0]).astype(jnp.int32)
            inc = live.astype(jnp.int32)
            emitted = emitted + inc
            pos = pos + inc
            done = done | (emitted >= max_new) | (live & (eos >= 0) &
                                                  (tok == eos))
            return (cache, tok[:, None], pos, emitted, done), (tok, live)

        (cache, token, pos, emitted, done), (toks, live) = jax.lax.scan(
            step, (cache, token, pos, emitted, done), keys, length=n_tokens)
        return cache, token, pos, emitted, done, toks, live

    # ------------------------------------------------------------------
    # counting
    # ------------------------------------------------------------------
    def param_count(self, active_only=False) -> int:
        shapes = jax.eval_shape(self.init, jax.random.key(0))
        total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        if active_only and self.cfg.n_experts:
            cfg = self.cfg
            expert = cfg.n_layers * cfg.n_experts * 3 * cfg.d_model * cfg.d_ff
            total = total - expert + expert * cfg.top_k // cfg.n_experts
        return total
