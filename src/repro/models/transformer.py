"""Transformer blocks: dense (attn + gated MLP), MoE, encoder and
decoder-with-cross-attention variants. Residual wiring + norms live here;
attention math in attention.py, MoE math in moe.py.

Every block exposes init / specs / apply(+decode) with params as plain
dicts so model.py can stack them over layers and lax.scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention, moe
from repro.models.common import (act_fn, dense_init, dtype_of, norm,
                                 norm_init, norm_specs, shard_act)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    return {
        "w1": dense_init(ks[0], (d, f), dt),
        "w3": dense_init(ks[1], (d, f), dt),
        "w2": dense_init(ks[2], (f, d), dt, scale=1.0 / np.sqrt(f)),
    }


def mlp_specs(cfg):
    return {"w1": ("embed", "mlp"), "w3": ("embed", "mlp"), "w2": ("mlp", "embed")}


def mlp_apply(p, x, cfg):
    act = act_fn(cfg.act)
    h = act(jnp.einsum("bsd,df->bsf", x, p["w1"])) * jnp.einsum(
        "bsd,df->bsf", x, p["w3"])
    h = shard_act(h, "batch", "seq", "mlp")
    out = jnp.einsum("bsf,fd->bsd", h, p["w2"])
    return shard_act(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Dense decoder block (llama/qwen/minicpm/internvl2 backbone)
# ---------------------------------------------------------------------------

def dense_block_init(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "n1": norm_init(cfg),
        "attn": attention.init(ks[0], cfg),
        "n2": norm_init(cfg),
        "mlp": mlp_init(ks[1], cfg),
    }


def dense_block_specs(cfg):
    return {
        "n1": norm_specs(cfg),
        "attn": attention.specs(cfg),
        "n2": norm_specs(cfg),
        "mlp": mlp_specs(cfg),
    }


def dense_block_apply(p, x, positions, cfg, block_skip=False):
    a, _, _ = attention.attend_train(p["attn"], norm(x, p["n1"], cfg), positions,
                                     cfg, block_skip=block_skip)
    x = x + a
    return x + mlp_apply(p["mlp"], norm(x, p["n2"], cfg), cfg)


def dense_block_prefill(p, x, positions, cfg):
    a, k, v = attention.attend_train(p["attn"], norm(x, p["n1"], cfg), positions, cfg)
    x = x + a
    return x + mlp_apply(p["mlp"], norm(x, p["n2"], cfg), cfg), (k, v)


def dense_block_decode(p, x, ck, cv, pos, cfg, ring=False, scales=None):
    out = attention.decode(p["attn"], norm(x, p["n1"], cfg), ck, cv, pos,
                           cfg, ring=ring, scales=scales)
    a, ck, cv = out[:3]
    x = x + a
    y = x + mlp_apply(p["mlp"], norm(x, p["n2"], cfg), cfg)
    if scales is not None:
        return y, ck, cv, out[3]
    return y, ck, cv


# ---------------------------------------------------------------------------
# MoE decoder block (mixtral / arctic). arctic adds a parallel dense
# residual MLP alongside the MoE FFN (dense-MoE hybrid).
# ---------------------------------------------------------------------------

def moe_block_init(key, cfg):
    ks = jax.random.split(key, 3)
    p = {
        "n1": norm_init(cfg),
        "attn": attention.init(ks[0], cfg),
        "n2": norm_init(cfg),
        "moe": moe.init(ks[1], cfg),
    }
    if cfg.moe_dense_ff:
        p["dense_mlp"] = mlp_init(ks[2], cfg, d_ff=cfg.moe_dense_ff)
    return p


def moe_block_specs(cfg):
    p = {
        "n1": norm_specs(cfg),
        "attn": attention.specs(cfg),
        "n2": norm_specs(cfg),
        "moe": moe.specs(cfg),
    }
    if cfg.moe_dense_ff:
        p["dense_mlp"] = mlp_specs(cfg)
    return p


def _moe_ffn(p, h, cfg, mesh):
    y, aux = moe.apply(p["moe"], h, cfg, mesh=mesh)
    if cfg.moe_dense_ff:
        y = y + mlp_apply(p["dense_mlp"], h, cfg)
    return y, aux


def moe_block_apply(p, x, positions, cfg, mesh=None, block_skip=False):
    a, _, _ = attention.attend_train(p["attn"], norm(x, p["n1"], cfg), positions,
                                     cfg, block_skip=block_skip)
    x = x + a
    y, aux = _moe_ffn(p, norm(x, p["n2"], cfg), cfg, mesh)
    return x + y, aux


def moe_block_prefill(p, x, positions, cfg, mesh=None):
    a, k, v = attention.attend_train(p["attn"], norm(x, p["n1"], cfg), positions, cfg)
    x = x + a
    y, aux = _moe_ffn(p, norm(x, p["n2"], cfg), cfg, mesh)
    return x + y, (k, v), aux


def moe_block_decode(p, x, ck, cv, pos, cfg, mesh=None, ring=False,
                     scales=None):
    out = attention.decode(p["attn"], norm(x, p["n1"], cfg), ck, cv, pos,
                           cfg, ring=ring, scales=scales)
    a, ck, cv = out[:3]
    x = x + a
    y, _ = _moe_ffn(p, norm(x, p["n2"], cfg), cfg, mesh)
    if scales is not None:
        return x + y, ck, cv, out[3]
    return x + y, ck, cv


# ---------------------------------------------------------------------------
# Encoder block (whisper encoder: bidirectional, layernorm+gelu)
# ---------------------------------------------------------------------------

def enc_block_init(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "n1": norm_init(cfg),
        "attn": attention.init(ks[0], cfg),
        "n2": norm_init(cfg),
        "mlp": mlp_init(ks[1], cfg),
    }


enc_block_specs = dense_block_specs


def enc_block_apply(p, x, cfg):
    a, _, _ = attention.attend_train(p["attn"], norm(x, p["n1"], cfg), None, cfg,
                                     use_rope=False, causal=False)
    x = x + a
    return x + mlp_apply(p["mlp"], norm(x, p["n2"], cfg), cfg)


# ---------------------------------------------------------------------------
# Decoder block with cross attention (whisper decoder)
# ---------------------------------------------------------------------------

def xdec_block_init(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "n1": norm_init(cfg),
        "attn": attention.init(ks[0], cfg),
        "n2": norm_init(cfg),
        "xattn": attention.init(ks[1], cfg),
        "n3": norm_init(cfg),
        "mlp": mlp_init(ks[2], cfg),
    }


def xdec_block_specs(cfg):
    return {
        "n1": norm_specs(cfg),
        "attn": attention.specs(cfg),
        "n2": norm_specs(cfg),
        "xattn": attention.specs(cfg),
        "n3": norm_specs(cfg),
        "mlp": mlp_specs(cfg),
    }


def xdec_block_apply(p, x, enc_out, positions, cfg):
    a, k, v = attention.attend_train(p["attn"], norm(x, p["n1"], cfg), positions,
                                     cfg, use_rope=False)
    x = x + a
    xk, xv = attention.cross_kv(p["xattn"], enc_out)
    x = x + attention.cross_attend_train(p["xattn"], norm(x, p["n2"], cfg),
                                         (xk, xv), cfg)
    return x + mlp_apply(p["mlp"], norm(x, p["n3"], cfg), cfg), (k, v), (xk, xv)


def xdec_block_decode(p, x, ck, cv, xk, xv, pos, cfg):
    a, ck, cv = attention.decode(p["attn"], norm(x, p["n1"], cfg), ck, cv, pos,
                                 cfg, use_rope=False)
    x = x + a
    x = x + attention.cross_decode(p["xattn"], norm(x, p["n2"], cfg), xk, xv)
    return x + mlp_apply(p["mlp"], norm(x, p["n3"], cfg), cfg), ck, cv
