"""Layout-geometry CI gate.

Places, routes, and verifies every bank in a cell x shape matrix:

    PYTHONPATH=src python tools/check_geom.py            # full matrix
    PYTHONPATH=src python tools/check_geom.py --smoke    # quick subset

Per bank, `repro.geom.verify.verify_bank` must come back fully clean:

  * DRC       — min width / min spacing / bank-boundary checks on every
                rect the placer + router emitted, zero violations;
  * LVS-lite  — the routed read column connects cell -> bitline ladder
                -> sense strip, the wordline spans all columns, and the
                net inventory matches the bank netlist;
  * bit-parity— `extract_point` over the routed geometry equals the
                closed-form `extract_lattice` entry BITWISE (the
                contract that lets the batched extractor skip building
                geometry per lattice point).

Any unclean bank prints its violation list and fails the job. Exits 0
only when the whole matrix is clean.
"""
from __future__ import annotations

import argparse
import itertools
import sys
import time

FULL_CELLS = ("sram6t", "gc2t_nn", "gc2t_np", "gc2t_osos", "gc2t_hyb",
              "gc3t")
FULL_SHAPES = ((8, 32), (16, 64), (32, 128))
SMOKE_CELLS = ("gc2t_nn", "gc2t_osos", "gc3t")
SMOKE_SHAPES = ((8, 32), (16, 64))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="3 cells x 2 shapes instead of the full matrix")
    ap.add_argument("--n-seg", type=int, default=8)
    args = ap.parse_args()

    from repro.core.bank import BankConfig
    from repro.geom import verify_bank

    cells = SMOKE_CELLS if args.smoke else FULL_CELLS
    shapes = SMOKE_SHAPES if args.smoke else FULL_SHAPES
    t0 = time.time()
    failures = []
    for cell, (ws, nw) in itertools.product(cells, shapes):
        cfg = BankConfig(ws, nw, cell=cell)
        rep = verify_bank(cfg, n_seg=args.n_seg)
        clean = (rep["drc_clean"] and rep["lvs_ok"]
                 and rep["extract_bit_identical"])
        tag = "ok  " if clean else "FAIL"
        print(f"  {tag} {cell:10s} {ws:3d}x{nw:<3d}  "
              f"wires={rep['n_wires']:5d} vias={rep['n_vias']:4d}  "
              f"drc={rep['drc_clean']} lvs={rep['lvs_ok']} "
              f"bit={rep['extract_bit_identical']}")
        if not clean:
            for v in rep.get("drc_violations", []):
                print(f"       drc: {v}")
            if not rep["lvs_ok"]:
                print(f"       lvs: {rep['lvs_msg']}")
            failures.append((cell, ws, nw))
    n = len(cells) * len(shapes)
    print(f"check_geom: {n - len(failures)}/{n} banks clean "
          f"in {time.time() - t0:.1f}s")
    if failures:
        print(f"check_geom: FAILED {failures}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
