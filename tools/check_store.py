"""Persistent artifact-cache CI gate.

Runs the same mixed query set twice against one on-disk artifact store
(`repro.api.store.ArtifactStore`) in two separate processes:

    PYTHONPATH=src python tools/check_store.py --dir /tmp/s --phase populate
    PYTHONPATH=src python tools/check_store.py --dir /tmp/s --phase verify

`populate` runs on a fresh store and asserts artifacts were written.
`verify` runs in a NEW process and asserts the session recomputed
NOTHING device-side (zero lattice evaluations; every plan node was
served from the store) while producing the identical results — the
restart-survival contract of the content-addressed store.

`--prune SECONDS` is the retention tool for long-lived fleet stores:
drop artifacts (and stale `*.tmp` droppings of killed writers) older
than the age bound, then exit:

    PYTHONPATH=src python tools/check_store.py --dir /tmp/s --prune 86400
"""
from __future__ import annotations

import argparse
import json
import sys


def _queries():
    from repro.api import CoDesignQuery, MatchQuery, SweepQuery
    from repro.core.dse import Demand
    from repro.workloads.profiler import profile_arch
    sweep = SweepQuery(cells=("gc2t_nn", "gc2t_osos"),
                       word_sizes=(16, 32), num_words=(16, 32))
    return [
        sweep,
        MatchQuery((Demand("act", "L1", 3.0e8, 2.0e-6),
                    Demand("kv", "L2", 8.0e8, 1.0e-3,
                           capacity_bits=1 << 20)), sweep),
        CoDesignQuery(profiles=(profile_arch("qwen2-0.5b", "decode_32k"),),
                      sweep=sweep, vdd_scales=(0.85, 1.0)),
    ]


def _run(store_dir: str):
    from repro.api import Session
    from repro.core import dse_batch
    calls = {"n": 0}
    orig_eb = dse_batch.evaluate_batch
    orig_vl = dse_batch.evaluate_vdd_lattice

    def count(fn):
        def wrapper(*a, **kw):
            calls["n"] += 1
            return fn(*a, **kw)
        return wrapper

    dse_batch.evaluate_batch = count(orig_eb)
    dse_batch.evaluate_vdd_lattice = count(orig_vl)
    try:
        s = Session(store=store_dir)
        results = s.run_many(_queries())
    finally:
        dse_batch.evaluate_batch = orig_eb
        dse_batch.evaluate_vdd_lattice = orig_vl
    digest = [json.dumps(r.as_dict(), sort_keys=True, default=str)
              for r in results]
    return s, calls["n"], digest


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", required=True)
    ap.add_argument("--phase", choices=("populate", "verify"))
    ap.add_argument("--prune", type=float, default=None, metavar="SECONDS",
                    help="drop artifacts (and stale *.tmp files) older "
                         "than SECONDS, then exit")
    args = ap.parse_args()
    if args.prune is not None:
        from repro.api.store import ArtifactStore
        store = ArtifactStore(args.dir)
        n = store.prune(args.prune)
        print(f"prune: removed {n} artifacts older than {args.prune}s, "
              f"swept {store.swept} stale tmp files; "
              f"{len(store)} entries remain")
        return 0
    if args.phase is None:
        ap.error("--phase is required unless --prune is given")
    s, n_evals, digest = _run(args.dir)
    store = s.store
    print(f"{args.phase}: {n_evals} lattice evaluations, "
          f"store {store.stats()}")
    digest_path = f"{args.dir}/.digest"
    if args.phase == "populate":
        if store.puts == 0 or len(store) == 0:
            print("FAIL: populate wrote no artifacts")
            return 1
        with open(digest_path, "w") as f:
            json.dump(digest, f)
        return 0
    # verify: a fresh process must serve everything from the store
    errors = []
    if n_evals != 0:
        errors.append(f"recomputed {n_evals} lattice evaluations")
    if store.hits == 0:
        errors.append("no store hits")
    if store.corrupt:
        errors.append(f"{store.corrupt} corrupt artifacts")
    try:
        with open(digest_path) as f:
            if json.load(f) != digest:
                errors.append("results differ from populate phase")
    except OSError as e:
        errors.append(f"missing populate digest: {e}")
    if errors:
        print("FAIL: " + "; ".join(errors))
        return 1
    print("persistent cache check passed (bit-identical, zero "
          "recompute)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
