"""Docs CI gate — keeps the guides from rotting.

1. Link check: every relative markdown link in README.md and docs/*.md
   must resolve to an existing file (anchors are stripped; http(s) and
   mailto links are skipped).
2. Snippet execution: every fenced ```python block in each
   EXECUTED_DOCS guide is executed, in order, in ONE shared namespace
   per guide against the installed package — the examples are tests.

    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import re
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)
EXECUTED_DOCS = ["docs/query-api.md", "docs/runtime.md", "docs/fleet.md",
                 "docs/layout.md"]


def check_links() -> list:
    errors = []
    files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    n = 0
    for md in files:
        text = md.read_text()
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path = target.split("#", 1)[0]
            if not path:          # pure in-page anchor
                continue
            n += 1
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
    print(f"link check: {n} relative links across {len(files)} files, "
          f"{len(errors)} broken")
    return errors


def run_snippets(rel: str) -> list:
    md = ROOT / rel
    blocks = FENCE_RE.findall(md.read_text())
    ns: dict = {"__name__": "__docs__"}
    errors = []
    for i, src in enumerate(blocks, 1):
        t0 = time.time()
        try:
            exec(compile(src, f"{rel}#block{i}", "exec"), ns)
            print(f"snippet {i}/{len(blocks)} of {rel}: ok "
                  f"({time.time() - t0:.1f}s)")
        except Exception as e:                      # noqa: BLE001
            errors.append(f"{rel} block {i}: {type(e).__name__}: {e}")
            print(f"snippet {i}/{len(blocks)} of {rel}: FAILED — {e}")
    return errors


def main() -> int:
    errors = check_links()
    for rel in EXECUTED_DOCS:
        errors += run_snippets(rel)
    if errors:
        print("\n".join(["", "DOCS CHECK FAILED:"] + errors))
        return 1
    print("docs check passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
