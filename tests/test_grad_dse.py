"""Finite-difference verification of the differentiable DSE path.

Three layers, matching how the gradients are built:

  1. `dse_grad.evaluate_grad_fn` — the pure-jnp analytic algebra. Every
     differentiable output is checked against central differences for
     every continuous knob, plus a bit-exact parity check of the
     `quantized=True` mode against the scalar `dse.evaluate` reference
     and a second-order `check_grads` spot check.
  2. `char_batch.t_cell_grad_fn` — the transient path, where gradients
     flow through the implicit-function VJP of the fused Newton solve.
  3. The VJP itself — the adjoint of a converged fixed point must not
     depend on how many Newton iterations the forward pass ran.

Central differences use RELATIVE steps of ~1e-4: much smaller steps sit
in the catastrophic-cancellation regime even in f64 (at eps=1e-7 the
apparent "error" is ~1%), much larger ones truncate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64
from jax.test_util import check_grads

from repro.core import dse
from repro.core.bank import BankConfig
from repro.core.dse_grad import KNOBS, OUTPUTS, evaluate_grad_fn
from repro.core.spice.char_batch import characterize, t_cell_grad_fn

EPS_REL = 1e-4      # central-difference relative step
TOL_REL = 1e-4      # acceptance threshold (ISSUE contract)

# off-nominal base point: keeps every knob away from kinks/specials
BASE = {"vdd_scale": 0.95, "w_read_scale": 1.10,
        "w_write_scale": 0.90, "bl_wire_scale": 1.05}


def _rel_err(ad, fd, out_mag, x_mag):
    """|ad - fd| relative to the gradient scale; the floor ties the
    scale to the output magnitude so exact-zero gradients compare
    clean."""
    # central differences carry ~machine_eps*|f|/(2h) ~ 1e-12*|f| of
    # cancellation noise: gradients below 1e-7*|f|/|x| are numerically
    # zero at this step size and compare against the floor instead
    floor = 1e-7 * (abs(out_mag) / max(x_mag, 1e-30) + 1e-300)
    return abs(ad - fd) / max(abs(ad), abs(fd), floor)


# ---------------------------------------------------------------------------
# 1. analytic algebra: every output x every knob
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell,wwlls", [("gc2t_nn", False),
                                        ("gc2t_np", True),
                                        ("gc2t_osos", False)])
def test_analytic_grads_match_central_differences(cell, wwlls):
    with enable_x64():
        cfg = BankConfig(32, 64, cell=cell, wwlls=wwlls)
        fn = evaluate_grad_fn(cfg)

        def vec_fn(x):           # (4,) knob vector -> (n_out,) outputs
            kn = {k: x[i][None] for i, k in enumerate(KNOBS)}
            out = fn(kn)
            return jnp.stack([out[o][0] for o in OUTPUTS])

        x0 = jnp.asarray([BASE[k] for k in KNOBS], dtype=jnp.float64)
        jac = jax.jacfwd(vec_fn)(x0)             # (n_out, 4)
        jac_rev = jax.jacrev(vec_fn)(x0)
        # atol tied to the Jacobian scale: fwd/rev may disagree on
        # whether a dead path is exactly 0.0 or denormal-level noise
        np.testing.assert_allclose(jac, jac_rev, rtol=1e-12,
                                   atol=1e-16 * float(np.abs(jac).max()))

        y0 = vec_fn(x0)
        for j, knob in enumerate(KNOBS):
            h = EPS_REL * float(x0[j])
            yp = vec_fn(x0.at[j].add(+h))
            ym = vec_fn(x0.at[j].add(-h))
            fd = (yp - ym) / (2 * h)
            for i, out in enumerate(OUTPUTS):
                err = _rel_err(float(jac[i, j]), float(fd[i]),
                               float(y0[i]), float(x0[j]))
                assert err < TOL_REL, \
                    f"d({out})/d({knob}): ad={jac[i, j]:.6e} " \
                    f"fd={fd[i]:.6e} rel={err:.3e}"


def test_quantized_mode_matches_scalar_reference_bitwise():
    """quantized=True replicates the scalar staircase algebra exactly;
    both sides run under x64 (the scalar path is f32 otherwise)."""
    with enable_x64():
        for cell, wwlls in [("gc2t_nn", False), ("gc2t_np", False),
                            ("gc2t_osos", True)]:
            cfg = BankConfig(32, 64, cell=cell, wwlls=wwlls)
            fn = evaluate_grad_fn(cfg, quantized=True)
            for vs in (0.8, 1.0, 1.15):
                out = fn({"vdd_scale": jnp.asarray([vs],
                                                   dtype=jnp.float64)})
                ref = dse.evaluate(cfg, vdd_scale=vs)
                for f in ("t_read_s", "t_write_s", "f_max_hz",
                          "retention_s", "leakage_w", "refresh_w",
                          "read_bw_bps", "eff_bw_bps"):
                    a, b = float(out[f][0]), float(getattr(ref, f))
                    assert a == pytest.approx(b, rel=1e-12, abs=0), \
                        f"{cell} vs={vs} {f}: traced={a!r} scalar={b!r}"
                sw = float(out["standby_w"][0])
                assert sw == pytest.approx(ref.standby_w, rel=1e-12)


def test_analytic_second_order_spot_check():
    """check_grads-style: the VJP of the VJP is also correct (order=2)
    for the headline objective along the headline knob."""
    with enable_x64():
        fn = evaluate_grad_fn(BankConfig(32, 64, cell="gc2t_np"))

        def f(vs):
            return fn({"vdd_scale": vs[None]})["standby_w"][0]

        check_grads(f, (jnp.asarray(0.93, dtype=jnp.float64),),
                    order=2, modes=("rev",), eps=1e-4,
                    atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# 2. transient path: implicit-function VJP through the Newton solve
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("solver", ["pallas", "sparse"])
def test_t_cell_transient_grads_match_fd(solver):
    """t_cell gradients w.r.t. device width / vdd / bitline geometry via
    the custom_vjp fixed-point adjoint vs central differences, plus
    nominal parity against the non-differentiable characterize() path.
    One batched forward evaluates the nominal point and all +/-eps
    perturbations in a single compiled program."""
    knob_names = ("vdd_scale", "w_read_scale", "bl_wire_scale")
    base = np.asarray([0.97, 1.05, 0.92])
    with enable_x64():
        cfg = BankConfig(16, 16, cell="gc2t_np")
        fn = t_cell_grad_fn(cfg, solver=solver)

        # batch rows: 0 = nominal-1.0 (parity), 1 = base point,
        # 2..7 = base +/- eps per knob
        h = EPS_REL * base
        rows = [np.ones(3), base]
        for j in range(3):
            for s in (+1, -1):
                p = base.copy()
                p[j] += s * h[j]
                rows.append(p)
        X = np.stack(rows)                      # (8, 3)
        kn = {k: jnp.asarray(X[:, j]) for j, k in enumerate(knob_names)}
        t, valid = fn(kn)
        assert bool(jnp.all(valid))

        ref = characterize([cfg], solver=solver)[0]
        assert float(t[0]) == pytest.approx(ref.t_cell_s, rel=1e-9), \
            "nominal traced t_cell != characterize()"

        def scalar(x):
            k1 = {k: x[j][None] for j, k in enumerate(knob_names)}
            return fn(k1)[0][0]

        grad = jax.grad(scalar)(jnp.asarray(base))
        t0 = float(t[1])
        for j, name in enumerate(knob_names):
            fd = float(t[2 + 2 * j] - t[3 + 2 * j]) / (2 * h[j])
            err = _rel_err(float(grad[j]), fd, t0, base[j])
            assert err < TOL_REL, \
                f"{solver} d(t_cell)/d({name}): ad={float(grad[j]):.6e} " \
                f"fd={fd:.6e} rel={err:.3e}"


# ---------------------------------------------------------------------------
# 3. fixed-point adjoint is iteration-count independent
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("solver", ["pallas", "sparse"])
def test_fixed_point_vjp_independent_of_newton_iters(solver):
    """Past convergence, the implicit-function adjoint depends only on
    the fixed point, never on the forward iteration count — doubling
    the Newton budget must reproduce the gradient bitwise. (An unrolled
    backprop would differ: each extra iteration adds terms.)"""
    from repro.core.spice.transient import Transient
    from tests.test_fused_newton import _lattice_inputs

    with enable_x64():
        system, inp = _lattice_inputs(B=2, cell="gc2t_nn")
        v0 = jnp.full((system.n,), inp["v_pre"])

        def loss(scale, iters):
            tr = Transient(system, solver=solver, iters=iters)
            res = tr.run_lattice(
                inp["wt"], inp["wv"], inp["t_end"], 40,
                over_batches={"G": jnp.asarray(inp["G_b"]) * scale,
                              "C": jnp.asarray(inp["C_b"])},
                v0=v0)
            return jnp.sum(res["all"][:, -1, :] ** 2)

        x = jnp.asarray(1.0, dtype=jnp.float64)
        g30 = jax.grad(lambda s: loss(s, 30))(x)
        g60 = jax.grad(lambda s: loss(s, 60))(x)
        assert float(g30) == float(g60), (float(g30), float(g60))
        assert jnp.isfinite(g30)
