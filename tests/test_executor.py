"""Planned query execution: plan lowering, coalescing executor
(submit/run_many), bit-exactness vs the sequential eager path, future
error propagation, the on-disk artifact store, and the compile
service front end."""
import dataclasses
import glob
import json
import os

import numpy as np
import pytest

from repro.api import (ArtifactStore, CoDesignQuery, CompileQuery,
                       MatchQuery, Session, SweepQuery)
from repro.api import plan as plan_mod
from repro.core import dse_batch
from repro.core.bank import BankConfig
from repro.core.dse import Demand
from repro.core.spice import char_batch
from repro.core.techfile import SYN40
from repro.workloads.profiler import profile_arch

SMALL = SweepQuery(cells=("gc2t_nn", "gc2t_osos"),
                   word_sizes=(16, 32), num_words=(16, 32))
GROWN = dataclasses.replace(SMALL, num_words=(16, 32, 64))
PROF = profile_arch("qwen2-0.5b", "decode_32k")


def _mixed_queries():
    return [
        SMALL,
        GROWN,
        MatchQuery((Demand("act", "L1", 3.0e8, 2.0e-6),
                    Demand("kv", "L2", 8.0e8, 1.0e-3,
                           capacity_bits=1 << 20)), SMALL),
        CoDesignQuery(profiles=(PROF,), sweep=SMALL,
                      vdd_scales=(0.85, 1.0)),
    ]


def _canon(result):
    return json.dumps(result.as_dict(), sort_keys=True, default=str)


def _count_evals(monkeypatch):
    calls = {"batch": 0, "vdd": 0, "char": 0}
    orig_b, orig_v = dse_batch.evaluate_batch, \
        dse_batch.evaluate_vdd_lattice
    orig_c = char_batch.characterize
    monkeypatch.setattr(dse_batch, "evaluate_batch",
                        lambda *a, **k: (calls.__setitem__(
                            "batch", calls["batch"] + 1), orig_b(*a, **k))[1])
    monkeypatch.setattr(dse_batch, "evaluate_vdd_lattice",
                        lambda *a, **k: (calls.__setitem__(
                            "vdd", calls["vdd"] + 1), orig_v(*a, **k))[1])
    monkeypatch.setattr(char_batch, "characterize",
                        lambda *a, **k: (calls.__setitem__(
                            "char", calls["char"] + 1), orig_c(*a, **k))[1])
    return calls


# ---------------------------------------------------------------------------
# tentpole: coalesced run_many == sequential run, with shared work
# executing ONCE
# ---------------------------------------------------------------------------

def test_run_many_bit_identical_to_sequential_on_mixed_batch():
    seq = [Session().run(q) for q in _mixed_queries()]
    coal = Session().run_many(_mixed_queries())
    for a, b in zip(seq, coal):
        assert _canon(a) == _canon(b)
    # point-level floats are EXACTLY equal, not approximately
    for pa, pb in zip(seq[0].points, coal[0].points):
        assert pa.f_max_hz == pb.f_max_hz
        assert pa.leakage_w == pb.leakage_w
        assert pa.t_read_s == pb.t_read_s


def test_concurrent_queries_share_one_lattice_evaluation(monkeypatch):
    calls = _count_evals(monkeypatch)
    s = Session()
    futs = [s.submit(q) for q in
            [SMALL, SMALL, GROWN,
             MatchQuery((Demand("d", "L1", 1e6, 1e-9),), SMALL)]]
    assert not any(f.done() for f in futs)
    s.flush()
    assert all(f.done() for f in futs)
    # SMALL+SMALL dedupe to one node; GROWN's extra configs union into
    # the SAME padded device batch; the match rides the shared node
    assert calls["batch"] == 1
    # dedup extends to the result objects themselves
    assert futs[0].result() is futs[1].result()
    assert futs[3].result().table is futs[0].result()


def test_run_many_matches_eager_call_counts(monkeypatch):
    calls = _count_evals(monkeypatch)
    Session().run_many(_mixed_queries())
    assert calls["batch"] == 1            # one union batch for the wave
    assert calls["vdd"] - calls["batch"] == 1   # one codesign lattice


def test_duplicate_queries_in_one_wave_share_result_objects():
    s = Session()
    m = MatchQuery((Demand("d", "L1", 1e6, 1e-9),), SMALL)
    c = CoDesignQuery(profiles=(PROF,), sweep=SMALL,
                      vdd_scales=(0.85, 1.0))
    rm1, rm2, rc1, rc2 = s.run_many([m, m, c, c])
    assert rm1 is rm2 and rc1 is rc2     # same identity as sequential
    assert s.run(m) is rm1


def test_submit_result_flushes_lazily():
    s = Session()
    fut = s.submit(SMALL)
    assert not fut.done()
    table = fut.result()                  # implicit flush
    assert fut.done() and len(table) == len(SMALL.configs(s.tech))
    assert s.run(SMALL) is table          # result-level memoization


def test_transient_sweeps_coalesce_characterization(monkeypatch):
    calls = _count_evals(monkeypatch)
    tq1 = SweepQuery(cells=("gc2t_nn",), word_sizes=(16,),
                     num_words=(16,), wwlls=(False,),
                     fidelity="transient", sim_steps=120)
    tq2 = dataclasses.replace(tq1, num_words=(16, 32))
    s = Session()
    r1, r2 = s.run_many([tq1, tq2])
    assert calls["char"] == 1             # union of both lattices
    assert r1.transient[0] is r2.transient[0]
    ref = Session().run(tq1)
    assert _canon(ref) == _canon(r1)


# ---------------------------------------------------------------------------
# futures: error propagation stays per-query
# ---------------------------------------------------------------------------

def test_future_error_propagation_is_isolated(monkeypatch):
    s = Session()
    s.run(SMALL)                          # cache SMALL's points
    def boom(cfgs, *a, **k):
        raise RuntimeError("device fell over")
    monkeypatch.setattr(dse_batch, "evaluate_batch", boom)
    fresh = SweepQuery(cells=("gc2t_np",), word_sizes=(16,),
                       num_words=(16, 32))
    ok_match = MatchQuery((Demand("d", "L1", 1e6, 1e-9),), SMALL)
    f_bad, f_ok = s.submit(fresh), s.submit(ok_match)
    s.flush()
    assert isinstance(f_bad.exception(), RuntimeError)
    with pytest.raises(RuntimeError, match="device fell over"):
        f_bad.result()
    # the failing node resolves only its dependents; the rest completes
    assert f_ok.exception() is None
    assert f_ok.result().banks_needed["L1:d"] == 1
    # run_many surfaces the first failure
    with pytest.raises(RuntimeError):
        s.run_many([fresh])


def test_shared_batch_failure_reaches_every_dependent_future(monkeypatch):
    """A query whose configs were claimed by ANOTHER query's failed
    union batch must see the real evaluation error, not a KeyError from
    output assembly."""
    s = Session()
    def boom(cfgs, *a, **k):
        raise RuntimeError("device fell over")
    monkeypatch.setattr(dse_batch, "evaluate_batch", boom)
    f_super, f_sub = s.submit(GROWN), s.submit(SMALL)   # SMALL ⊂ GROWN
    s.flush()
    assert isinstance(f_super.exception(), RuntimeError)
    assert isinstance(f_sub.exception(), RuntimeError)


def test_eager_vdd_lattice_uses_artifact_store(tmp_path, monkeypatch):
    calls = _count_evals(monkeypatch)
    s1 = Session(store=tmp_path)          # pathlib.Path accepted
    lat = s1.vdd_lattice(SMALL, (0.85, 1.0))
    assert calls["vdd"] == 1 and s1.store.puts == 1
    fresh = Session(store=tmp_path)
    lat2 = fresh.vdd_lattice(SMALL, (0.85, 1.0))
    assert calls["vdd"] == 1              # served from disk
    assert np.array_equal(lat.f_max_hz, lat2.f_max_hz)
    assert np.array_equal(lat.retention_s, lat2.retention_s)
    # and a codesign query in yet another process rides the same artifact
    Session(store=tmp_path).run(CoDesignQuery(
        profiles=(PROF,), sweep=SMALL, vdd_scales=(0.85, 1.0)))
    assert calls["vdd"] == 1


def test_node_failure_inside_execution_reaches_future():
    s = Session()
    fut = s.submit(CompileQuery(BankConfig(16, 16, cell="no_such_cell")))
    assert fut.exception() is not None
    assert isinstance(fut.exception(), (KeyError, ValueError))


# ---------------------------------------------------------------------------
# construction-time validation (moved out of Session methods)
# ---------------------------------------------------------------------------

def test_queries_validate_at_construction():
    with pytest.raises(ValueError, match="fidelity"):
        SweepQuery(fidelity="spice")
    with pytest.raises(ValueError, match="solver"):
        SweepQuery(solver="ngspice")
    with pytest.raises(ValueError, match="duplicate demand keys"):
        MatchQuery((Demand("a", "L1", 1e6, 1e-9),
                    Demand("a", "L1", 2e6, 1e-9)))
    with pytest.raises(ValueError, match="objective"):
        CoDesignQuery(profiles=(PROF,), objective="speed")
    with pytest.raises(ValueError, match="Profile"):
        CoDesignQuery(profiles=())
    with pytest.raises(ValueError, match="analytic tier"):
        CoDesignQuery(profiles=(PROF,),
                      sweep=dataclasses.replace(SMALL,
                                                fidelity="transient"))
    # demands normalize to a tuple so the query stays hashable
    q = MatchQuery([Demand("a", "L1", 1e6, 1e-9)])
    assert isinstance(q.demands, tuple) and hash(q)


def test_sweep_query_normalizes_sequence_fields():
    q = SweepQuery(cells=["gc2t_nn"], word_sizes=[16], num_words=[16],
                   wwlls=[False])
    assert isinstance(q.cells, tuple) and hash(q)
    s = Session()
    # list-built queries flow through caches and waves like tuple ones
    t1, t2 = s.run_many([q, SweepQuery(cells=("gc2t_nn",),
                                       word_sizes=(16,), num_words=(16,),
                                       wwlls=(False,))])
    assert t1 is t2 and len(t1) == 1


def test_legacy_run_override_subclass_keeps_its_hook():
    class Custom(SweepQuery):
        def run(self, session):
            return "custom ran"
    s = Session()
    assert s.run(Custom()) == "custom ran"
    fut = s.submit(Custom())
    assert fut.done() and fut.result() == "custom ran"


def test_legacy_run_override_delegating_to_session_method():
    """The pre-planned delegation idiom — run(session) calling the
    session convenience method — must execute, not recurse: the
    convenience methods go straight to the planned path."""
    calls = []

    class Traced(SweepQuery):
        def run(self, session):
            calls.append(type(self).__name__)
            return session.sweep(self)

    s = Session()
    q = Traced(cells=("gc2t_nn",), word_sizes=(16,), num_words=(16,),
               wwlls=(False,))
    table = s.run(q)
    assert len(table) == 1 and calls == ["Traced"]


def test_store_schema_mismatch_degrades_to_recompute(tmp_path,
                                                     monkeypatch):
    ref = Session(store=str(tmp_path)).run(SMALL)
    (victim,) = glob.glob(str(tmp_path / "points" / "*.json"))
    # checksum-VALID artifact whose payload no longer matches the
    # decoder's schema (e.g. written by a different code version)
    from repro.api.store import ArtifactStore
    stale = ArtifactStore(str(tmp_path))
    key = "points-" + os.path.basename(victim)[:-len(".json")]
    stale.drop(key)
    stale.put(key, [{"schema": "from-the-future"}])
    calls = _count_evals(monkeypatch)
    fresh = Session(store=str(tmp_path))
    again = fresh.run(SMALL)
    assert calls["batch"] == 1 and _canon(ref) == _canon(again)
    assert fresh.executor.stats["store_decode_errors"] == 1
    # and the recompute repaired the artifact for the next process
    calls2 = _count_evals(monkeypatch)
    assert _canon(Session(store=str(tmp_path)).run(SMALL)) == _canon(ref)
    assert calls2["batch"] == 0


def test_tables_share_across_evaluation_knobs():
    s = Session()
    t1 = s.sweep(SMALL)
    t2 = s.sweep(dataclasses.replace(SMALL, batched=False))
    assert t1 is t2                       # lattice-shaping key only


# ---------------------------------------------------------------------------
# plan keys
# ---------------------------------------------------------------------------

def test_plan_keys_are_content_addressed():
    s = Session()
    p1 = plan_mod.plan_query(s, SMALL)
    p2 = plan_mod.plan_query(s, dataclasses.replace(SMALL, batched=False))
    assert p1.nodes[0].key == p2.nodes[0].key     # knobs stay out
    p3 = plan_mod.plan_query(s, GROWN)
    assert p3.nodes[0].key != p1.nodes[0].key     # lattice is in
    assert p1.nodes[0].key.startswith("points-")
    assert plan_mod.tech_hash(SYN40) == plan_mod.tech_hash(
        dataclasses.replace(SYN40))


# ---------------------------------------------------------------------------
# on-disk artifact store
# ---------------------------------------------------------------------------

def test_store_round_trip_bit_identical(tmp_path, monkeypatch):
    calls = _count_evals(monkeypatch)
    first = Session(store=str(tmp_path)).run_many(_mixed_queries())
    assert calls["batch"] >= 1 and calls["vdd"] >= 1
    populated = dict(calls)
    fresh = Session(store=str(tmp_path))
    again = fresh.run_many(_mixed_queries())
    # a fresh process recomputes NOTHING device-side...
    assert dict(calls) == populated
    assert fresh.executor.stats["store_hits"] >= 2
    # ...and gets bit-identical results
    for a, b in zip(first, again):
        assert _canon(a) == _canon(b)


def test_store_corrupted_entry_falls_back_to_recompute(tmp_path,
                                                       monkeypatch):
    ref = Session(store=str(tmp_path)).run(SMALL)
    (victim,) = glob.glob(str(tmp_path / "points" / "*.json"))
    with open(victim, "w") as f:
        f.write('{"data": "torn wri')
    calls = _count_evals(monkeypatch)
    fresh = Session(store=str(tmp_path))
    again = fresh.run(SMALL)
    assert calls["batch"] == 1            # recomputed, not trusted
    assert fresh.store.corrupt == 1
    assert _canon(ref) == _canon(again)
    # the recompute repaired the store for the next session
    calls2 = _count_evals(monkeypatch)
    final = Session(store=str(tmp_path)).run(SMALL)
    assert calls2["batch"] == 0 and _canon(final) == _canon(ref)


def test_store_checksum_and_miss_accounting(tmp_path):
    store = ArtifactStore(str(tmp_path))
    assert store.get("points-nope") is None and store.misses == 1
    store.put("points-abc", {"x": [1.5, float("inf")]})
    assert store.get("points-abc") == {"x": [1.5, float("inf")]}
    # checksum tamper -> corrupt, treated as miss
    path = store._path("points-abc")
    blob = json.load(open(path))
    blob["data"]["x"][0] = 2.5
    json.dump(blob, open(path, "w"))
    assert store.get("points-abc") is None and store.corrupt == 1
    # corrupt entries self-heal by unlinking, clearing the way for a put
    assert not store.has("points-abc")
    assert len(store) == 0 and store.stats()["puts"] == 1


# ---------------------------------------------------------------------------
# compile service front end
# ---------------------------------------------------------------------------

def test_compile_service_waves_and_error_isolation():
    from repro.launch.compile_service import CompileService
    svc = CompileService(wave_size=8)
    reqs = [
        {"id": "a", "tenant": "t1",
         "query": {"type": "sweep", "cells": ["gc2t_nn"],
                   "word_sizes": [16, 32], "num_words": [16, 32]}},
        {"id": "b", "tenant": "t2",
         "query": {"type": "match",
                   "demands": [{"name": "d", "level": "L1",
                                "read_freq_hz": 1e6,
                                "lifetime_s": 1e-9}],
                   "sweep": {"cells": ["gc2t_nn"],
                             "word_sizes": [16, 32],
                             "num_words": [16, 32]}}},
        {"id": "c", "tenant": "t2", "query": {"type": "sweep",
                                              "fidelity": "spice"}},
        {"id": "d", "tenant": "t1", "query": {"type": "warp"}},
    ]
    lines = list(svc.serve_lines(json.dumps(r) for r in reqs))
    out = {r["id"]: r for r in map(json.loads, lines)}
    assert out["a"]["ok"] and out["a"]["result"]["n_points"] == 8
    assert out["b"]["ok"] and \
        out["b"]["result"]["banks_needed"]["L1:d"] == 1
    assert not out["c"]["ok"] and "fidelity" in out["c"]["error"]
    assert not out["d"]["ok"] and "unknown query type" in out["d"]["error"]
    assert all(r["wave"] == 0 for r in out.values())
    st = svc.stats()
    assert st["tenants"]["t2"] == {"requests": 2, "errors": 1}
    assert st["executor"]["queries"] == 2   # only the two valid plans


def test_compile_service_stream_drains_partial_waves():
    """A live producer that sends fewer than wave_size requests (and
    keeps the stream open a while) still gets its responses after the
    idle window — no EOF or full wave needed."""
    import time as _time
    from repro.launch.compile_service import CompileService
    svc = CompileService(wave_size=64)
    req = {"id": "slow", "tenant": "t",
           "query": {"type": "sweep", "cells": ["gc2t_nn"],
                     "word_sizes": [16], "num_words": [16]}}

    def producer():
        yield json.dumps(req)
        _time.sleep(0.3)                  # stream stays open, queue idle
        yield json.dumps(dict(req, id="late"))

    got = []
    for line in svc.serve_stream(producer(), max_wait_s=0.02):
        got.append(json.loads(line))
    assert [r["id"] for r in got] == ["slow", "late"]
    assert all(r["ok"] for r in got)
    assert got[0]["wave"] < got[1]["wave"]   # drained as partial waves


def test_compile_service_bad_json_line():
    from repro.launch.compile_service import CompileService
    svc = CompileService(wave_size=4)
    lines = list(svc.serve_lines(["{not json"]))
    (resp,) = map(json.loads, lines)
    assert not resp["ok"] and "bad request line" in resp["error"]
