"""Layout tier tests: generated bank geometry, rule/connectivity
verification, batched parasitic extraction, and the fidelity="layout"
end-to-end plumbing.

The load-bearing contracts:

  * batched `extract_lattice` is BIT-identical to the per-point
    `extract_point` reference over routed geometry (same IEEE-double
    op sequence — see repro/geom/extract.py);
  * every placed+routed bank in the supported matrix is DRC-clean and
    its extracted read column is LVS-isomorphic to the MNA netlist
    `timing.read_netlist` simulates;
  * extracted parasitics stay within documented tolerance of the hand
    models (the gap IS the fidelity the tier adds — it must be small,
    not zero);
  * the floorplan manifest is stable against golden files (int nm, so
    equality is exact).
"""
import json
import math
import os

import numpy as np
import pytest

from repro.core import layout, timing
from repro.core import bank as bank_mod
from repro.core.bank import BankConfig, build_bank
from repro.core.techfile import SYN40
from repro.geom import (extract_lattice, extract_point, place_bank,
                        read_column_segments, route_bank, verify_bank)
from repro.geom import extract as gx
from repro.geom.grid import RuleDeck, Rect
from repro.geom.verify import check_rules, lvs_read_column

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")

MATRIX = [(cell, ws, nw)
          for cell in ("gc2t_nn", "gc2t_np", "gc2t_osos", "gc3t",
                       "gc2t_hyb", "sram6t")
          for ws, nw in ((8, 32), (16, 64))]


def _geom(cfg):
    return route_bank(place_bank(build_bank(cfg)))


# ---------------------------------------------------------------------------
# cell geometry consistency (the satellite fix)
# ---------------------------------------------------------------------------

def test_cell_wh_product_equals_area_exactly():
    """cell_area_um2 is DEFINED as the cell_wh_nm product — bitwise."""
    for key in SYN40.cell_geoms:
        w, h = layout.cell_wh_nm(SYN40, key)
        assert w * h * layout.UM2_PER_NM2 == layout.cell_area_um2(SYN40, key)


def test_cell_wh_margin_is_isotropic():
    """The DRC margin splits evenly: w/h ratio == drawn pitches/tracks
    ratio, and the margined area is (1+margin) x the drawn area."""
    for key, g in SYN40.cell_geoms.items():
        w, h = layout.cell_wh_nm(SYN40, key)
        drawn_w = g["poly_pitches"] * SYN40.cpp
        drawn_h = g["tracks"] * SYN40.track
        assert w / h == pytest.approx(drawn_w / drawn_h, rel=1e-12)
        assert w * h == pytest.approx(
            drawn_w * drawn_h * (1.0 + g["margin"]), rel=1e-12)


# ---------------------------------------------------------------------------
# placement + routing + verification matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell,ws,nw", MATRIX,
                         ids=[f"{c}-{w}x{n}" for c, w, n in MATRIX])
def test_verify_bank_clean(cell, ws, nw):
    r = verify_bank(BankConfig(ws, nw, cell=cell))
    assert r["drc_clean"], r["drc_violations"]
    assert r["lvs_ok"], r["lvs_msg"]
    assert r["extract_bit_identical"]
    assert r["n_vias"] > 0 and r["n_wires"] > 0


def test_drc_catches_planted_violations():
    """The checker is not vacuous: a short, a sliver and an escape each
    trip a distinct rule."""
    g = _geom(BankConfig(8, 32, cell="gc2t_nn"))
    assert check_rules(g) == []
    # different-net overlap (short)
    w0 = g.wires[0]
    g.wires.append(Rect(w0.layer, w0.x0, w0.y0, w0.x1, w0.y1,
                        net="__other__", name="planted_short"))
    assert any("short" in v for v in check_rules(g))
    g.wires.pop()
    # sub-minimum width sliver
    g.wires.append(Rect("m2", 5000.0, 5000.0, 5010.0, 5500.0,
                        net="__sliver__", name="planted_sliver"))
    assert any("width" in v for v in check_rules(g))
    g.wires.pop()
    # out of bank bounds
    g.wires.append(Rect("m3", -500.0, 0.0, -400.0, 400.0,
                        net="__esc__", name="planted_escape"))
    assert any("out of bank" in v for v in check_rules(g))
    g.wires.pop()
    assert check_rules(g) == []


def test_lvs_catches_missing_bitline():
    g = _geom(BankConfig(8, 32, cell="gc2t_nn"))
    ok, _ = lvs_read_column(g)
    assert ok
    rbl = g.nets.pop("rbl_0")
    ok, msg = lvs_read_column(g)
    assert not ok and "rbl_0" in msg
    g.nets["rbl_0"] = rbl


def test_manifest_matches_golden():
    """Floorplan manifests are integer-nm, so equality against the
    checked-in golden files is exact — any placement/routing drift must
    be intentional and regenerate the goldens."""
    for cell, name in (("gc2t_nn", "manifest_gc2t_nn_16x64.json"),
                       ("gc2t_osos", "manifest_gc2t_osos_16x64.json")):
        got = _geom(BankConfig(16, 64, cell=cell)).manifest()
        with open(os.path.join(GOLDEN, name)) as f:
            want = json.load(f)
        assert got == want, f"manifest drift for {cell} (see {name})"


# ---------------------------------------------------------------------------
# extraction: bit-parity, physical sanity, parity with hand models
# ---------------------------------------------------------------------------

def test_extract_lattice_bit_identical_to_point():
    cfgs = [BankConfig(ws, nw, cell=cell) for cell, ws, nw in MATRIX]
    banks = [build_bank(c) for c in cfgs]
    lat = extract_lattice(banks)
    for i, (cfg, bank) in enumerate(zip(cfgs, banks)):
        point = extract_point(_geom(cfg))
        for k, v in point.items():
            assert v == float(lat[k][i]), (cfg.cell, k)


def test_extracted_exceeds_hand_model_by_design():
    """Extraction charges everything the hand model omits (rail rows,
    strip jog, via stack), so extracted >= modeled on every component —
    by a bounded, ROWS-DEPENDENT amount: the via stack + jog are fixed
    overhead, so their relative weight shrinks as the column grows.
    Documented tolerance (docs/layout.md): R <= 2.0x / C <= 1.5x at any
    size, tightening to R <= 1.3x / C <= 1.15x from 64 rows up."""
    for cell, ws, nw in (MATRIX + [("gc2t_nn", 32, 128),
                                   ("gc2t_osos", 32, 128)]):
        bank = build_bank(BankConfig(ws, nw, cell=cell))
        rc = gx.read_column_rc(bank)
        r_hand, c_hand = bank_mod.bitline_rc(bank)
        assert rc["bl_r_ohm"] > r_hand
        assert rc["bl_c_f"] > c_hand
        r_cap, c_cap = (1.3, 1.15) if bank.rows >= 64 else (2.0, 1.5)
        assert rc["bl_r_ohm"] <= r_cap * r_hand, (cell, ws, nw)
        assert rc["bl_c_f"] <= c_cap * c_hand, (cell, ws, nw)
        r_whand, c_whand = bank_mod.wordline_rc(bank)
        # read wordline vs (write-flavored) hand wordline: same wire,
        # different gate loading — lengths agree to the jog
        assert rc["wl_r_ohm"] >= r_whand


def test_elmore_parity_extracted_vs_analytic():
    """Elmore delay of the extracted uniform ladder vs the analytic
    closed form on the SAME totals: the discretized cumulative-sum
    ladder approaches 0.69*(Rd*C + 0.5*R*C)/0.69 structure; with n_seg
    segments the ladder sum is (1/2 + 1/(2 n_seg)) R C + Rd C, so the
    two agree within 1/n_seg relative."""
    from repro.geom import extract as ex
    for cell in ("gc2t_nn", "gc2t_osos", "gc3t"):
        bank = build_bank(BankConfig(16, 64, cell=cell))
        seg = read_column_segments(bank, n_seg=8)
        lad = ex.ladder_elmore_s(seg["r_seg_ohm"], seg["c_seg_f"])
        r, c = seg["bl_r_ohm"], seg["bl_c_f"]
        analytic = 0.5 * r * c
        assert lad == pytest.approx(analytic, rel=1.0 / 8 + 1e-9)


def test_extracted_analytic_t_cell_correction_bounded():
    """Analytic cell_read_time on extracted vs hand-modeled parasitics:
    the layout tier's correction stays a CORRECTION, not a different
    model. Documented tolerance (docs/layout.md): < 20% at 16 rows,
    < 15% at 32, < 10% from 64 rows up — the fixed via/jog overhead
    washes out as the column grows, and the gap shrinks monotonically
    with rows for every cell."""
    for cell in ("gc2t_nn", "gc2t_np", "gc2t_osos", "gc3t",
                 "gc2t_hyb", "sram6t"):
        gaps = []
        for ws, nw in ((8, 32), (16, 64), (32, 128)):
            bank = build_bank(BankConfig(ws, nw, cell=cell))
            t_hand, _ = timing.cell_read_time(bank)
            rc = gx.read_column_rc(bank)
            t_ext, _ = timing.cell_read_time(
                bank, rc=(rc["bl_r_ohm"], rc["bl_c_f"]))
            assert t_ext > t_hand
            gap = (t_ext - t_hand) / t_hand
            cap = 0.10 if bank.rows >= 64 else \
                (0.15 if bank.rows >= 32 else 0.20)
            assert gap < cap, (cell, ws, nw, gap)
            gaps.append(gap)
        assert gaps == sorted(gaps, reverse=True), (cell, gaps)


def test_analyze_extracted_parasitics():
    """timing.analyze(parasitics="extracted") slows the read path and
    can only hold or grow the delay-chain stage count; write timing is
    untouched (the extractor models the read column)."""
    bank = build_bank(BankConfig(16, 64, cell="gc2t_nn"))
    tm = timing.analyze(bank)
    te = timing.analyze(bank, parasitics="extracted")
    assert te.t_cell_s > tm.t_cell_s
    assert te.t_wl_s > tm.t_wl_s
    assert te.delay_stages >= tm.delay_stages
    assert te.f_max_hz <= tm.f_max_hz
    with pytest.raises(ValueError):
        timing.analyze(bank, parasitics="wrong")


def test_read_netlist_rc_override_preserves_structure():
    """The extracted-ladder netlist is element-for-element the modeled
    one with different values — the property that lets layout-tier
    characterization reuse the compiled per-topology pipeline."""
    bank = build_bank(BankConfig(16, 64, cell="gc2t_nn"))
    rc = gx.read_column_rc(bank)
    c0, _ = timing.read_netlist(bank)
    c1, _ = timing.read_netlist(bank, rc=(rc["bl_r_ohm"], rc["bl_c_f"]))
    assert c0.names == c1.names
    assert len(c0.res) == len(c1.res) and len(c0.caps) == len(c1.caps)
    assert [(a, b) for a, b, _ in c0.res] == [(a, b) for a, b, _ in c1.res]
    g_ratio = {g1 / g0 for (_, _, g0), (_, _, g1) in zip(c0.res, c1.res)}
    assert len(g_ratio) == 1          # uniform ladder scaling


# ---------------------------------------------------------------------------
# fidelity="layout" end-to-end (Session plumbing)
# ---------------------------------------------------------------------------

def test_sweep_query_validates_layout_fidelity():
    from repro.api import SweepQuery
    q = SweepQuery(fidelity="layout")
    assert q.fidelity == "layout"
    with pytest.raises(ValueError):
        SweepQuery(fidelity="geometry")


@pytest.mark.slow
def test_layout_fidelity_end_to_end(tmp_path):
    """SweepQuery(fidelity='layout') through a stored Session: the
    LayoutTable carries clean geometry reports, the extracted transient
    t_cell lands within 10% of the hand-modeled tier, and a FRESH
    session replays everything from the artifact store with zero geometry
    rebuilds or transient recomputes."""
    from repro.api import LayoutTable, Session, SweepQuery
    kw = dict(cells=("gc2t_nn", "gc2t_osos", "gc3t"), word_sizes=(16,),
              num_words=(64,), wwlls=(False,), sim_steps=200)
    s = Session(store=str(tmp_path))
    t = s.run(SweepQuery(fidelity="layout", **kw))
    assert isinstance(t, LayoutTable) and len(t) == 3
    gsum = t.geometry_summary()
    assert gsum["all_clean"] and gsum["n_verified"] == 3
    tm = s.run(SweepQuery(fidelity="transient", **kw))
    assert type(tm).__name__ == "CalibratedTable"   # distinct cache entry
    for cl, cm in zip(t.transient, tm.transient):
        assert cl.swing_ok and cm.swing_ok
        assert cl.t_cell_s > cm.t_cell_s            # extraction adds RC
        assert abs(cl.t_cell_s - cm.t_cell_s) / cm.t_cell_s < 0.10
    d = t.as_dict()
    assert d["geometry_summary"]["all_clean"]
    assert all("geometry" in row for row in d["rows"])
    json.dumps(d)                                   # JSON-able artifact

    s2 = Session(store=str(tmp_path))
    t2 = s2.run(SweepQuery(fidelity="layout", **kw))
    assert s2.executor.stats.get("geom_verifies", 0) == 0
    assert s2.executor.stats.get("char_calls", 0) == 0
    assert t2.geometry == t.geometry
    assert [c.t_cell_s for c in t2.transient] == \
        [c.t_cell_s for c in t.transient]
