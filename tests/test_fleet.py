"""Fault-tolerance layer: lease claim/expiry/steal races, store
self-heal after torn writes and checksum corruption, fleet retries /
poison quarantine / degraded mode, and the subprocess fleet surviving a
worker SIGKILLed mid-wave with zero duplicate evaluations."""
import json
import os
import threading
import time

from repro.api import ArtifactStore, Session, SweepQuery
from repro.api.leases import LeaseManager
from repro.launch.compile_service import CompileService
from repro.launch.fleet import Fleet
from repro.testing.faults import FaultInjector, FaultSpec

TINY = SweepQuery(cells=("gc2t_nn",), word_sizes=(8,), num_words=(16,),
                  write_vts=(None,), wwlls=(False,))


def _tiny_spec(num_words=16, ident="r0", tenant="t0"):
    return {"id": ident, "tenant": tenant, "query": {
        "type": "sweep", "cells": ["gc2t_nn"], "word_sizes": [8],
        "num_words": [num_words], "write_vts": [None], "wwlls": [False]}}


# ---------------------------------------------------------------------------
# leases
# ---------------------------------------------------------------------------

def test_lease_single_winner_under_threads(tmp_path):
    mgr = LeaseManager(tmp_path, ttl_s=30.0, heartbeat=False)
    wins, barrier = [], threading.Barrier(16)

    def race():
        barrier.wait()
        lease = mgr.try_claim("points-abc")
        if lease is not None:
            wins.append(lease)

    threads = [threading.Thread(target=race) for _ in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(wins) == 1
    wins[0].release()
    assert mgr.try_claim("points-abc") is not None  # released -> claimable


def test_lease_expiry_allows_steal(tmp_path):
    dead = LeaseManager(tmp_path, owner="dead", ttl_s=0.15,
                        heartbeat=False)
    assert dead.try_claim("points-k") is not None
    thief = LeaseManager(tmp_path, owner="thief", ttl_s=0.15,
                         heartbeat=False)
    assert thief.try_claim("points-k") is None      # still live
    time.sleep(0.3)
    lease = thief.try_claim("points-k")             # expired: steal
    assert lease is not None and lease.stolen
    assert thief.counts["steals"] == 1


def test_steal_race_has_single_winner(tmp_path):
    dead = LeaseManager(tmp_path, owner="dead", ttl_s=0.1,
                        heartbeat=False)
    assert dead.try_claim("points-k") is not None
    time.sleep(0.25)
    wins, barrier = [], threading.Barrier(8)

    def race(i):
        mgr = LeaseManager(tmp_path, owner=f"thief{i}", ttl_s=0.1,
                           heartbeat=False)
        barrier.wait()
        lease = mgr.try_claim("points-k")
        if lease is not None:
            wins.append(lease)

    threads = [threading.Thread(target=race, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(wins) == 1 and wins[0].stolen


def test_heartbeat_keeps_lease_alive(tmp_path):
    owner = LeaseManager(tmp_path, owner="live", ttl_s=0.3, heartbeat=True)
    assert owner.try_claim("points-k") is not None
    thief = LeaseManager(tmp_path, owner="thief", ttl_s=0.3,
                         heartbeat=False)
    time.sleep(0.6)        # two TTLs: heartbeats must have re-touched
    assert thief.try_claim("points-k") is None
    owner.close()


def test_acquire_waits_for_publish(tmp_path):
    owner = LeaseManager(tmp_path, owner="o", ttl_s=30.0, heartbeat=False)
    lease = owner.try_claim("points-k")
    box, got = {}, []

    def waiter():
        got.append(LeaseManager(tmp_path, owner="w", ttl_s=30.0,
                                heartbeat=False)
                   .acquire("points-k", lambda: box.get("v"), timeout=5))

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.1)
    box["v"] = 42          # publish, THEN release — the executor's order
    lease.release()
    t.join()
    assert got == [("have", 42)]


def test_acquire_steals_from_dead_owner(tmp_path):
    dead = LeaseManager(tmp_path, owner="dead", ttl_s=0.15,
                        heartbeat=False)
    assert dead.try_claim("points-k") is not None   # never publishes
    mgr = LeaseManager(tmp_path, owner="w", ttl_s=0.15, heartbeat=False)
    kind, lease = mgr.acquire("points-k", lambda: None, timeout=5)
    assert kind == "own" and lease.stolen


def test_eval_log_and_duplicates(tmp_path):
    a = LeaseManager(tmp_path, owner="a", ttl_s=1.0, heartbeat=False)
    b = LeaseManager(tmp_path, owner="b", ttl_s=1.0, heartbeat=False)
    a.log_eval("points-x", "fresh")
    b.log_eval("points-y", "fresh")
    b.log_eval("points-y", "heal")      # sanctioned recovery, not a dup
    assert LeaseManager.duplicate_evals(tmp_path) == {}
    b.log_eval("points-x", "fresh")     # the forbidden case
    assert LeaseManager.duplicate_evals(tmp_path) == {"points-x": 2}


# ---------------------------------------------------------------------------
# store durability
# ---------------------------------------------------------------------------

def test_store_sweeps_stale_tmp_and_prunes(tmp_path):
    store = ArtifactStore(str(tmp_path))
    store.put("points-abc", {"v": 1})
    stale = tmp_path / "points" / "dead.tmp"
    stale.write_text("torn")
    old = time.time() - 3600
    os.utime(stale, (old, old))
    assert store.sweep_tmp(600.0) == 1 and not stale.exists()
    assert store.get("points-abc") == {"v": 1}      # artifacts untouched
    os.utime(store._path("points-abc"), (old, old))
    assert store.prune(600.0) == 1
    assert store.get("points-abc") is None          # pruned -> recompute
    assert store.stats()["swept"] == 1 and store.stats()["pruned"] == 1


def test_store_detects_torn_write(tmp_path):
    store = ArtifactStore(str(tmp_path))
    with FaultInjector(FaultSpec(tear_rate=1.0)).install(store=store) as inj:
        store.put("points-abc", {"rows": [1.5, 2.5]})
        assert inj.counts["torn_writes"] == 1
    assert store.get("points-abc") is None          # miss, not garbage
    assert store.corrupt == 1
    store.put("points-abc", {"rows": [1.5, 2.5]})   # recompute repairs
    assert store.get("points-abc") == {"rows": [1.5, 2.5]}


def test_store_detects_checksum_corruption(tmp_path):
    store = ArtifactStore(str(tmp_path))
    store.put("points-abc", {"rows": [1.5, 2.5]})
    with FaultInjector(FaultSpec(corrupt_rate=1.0)).install(store=store) \
            as inj:
        assert store.get("points-abc") is None
        assert inj.counts["corrupted_reads"] == 1
    assert store.corrupt == 1
    assert not store.has("points-abc")              # unlinked for repair


def test_leased_sessions_share_one_evaluation(tmp_path):
    d = str(tmp_path)
    t1 = Session(store=d, leases=True).run(TINY)
    t2 = Session(store=d, leases=True).run(TINY)    # pure store hit
    log = LeaseManager.read_eval_log(d)
    assert sum(c.get("fresh", 0) for c in log.values()) == len(log)
    assert LeaseManager.duplicate_evals(d) == {}
    for a, b in zip(t1.points, t2.points):
        assert a.t_read_s == b.t_read_s and a.area_um2 == b.area_um2


def test_executor_heals_torn_artifact(tmp_path):
    d = str(tmp_path)
    store = ArtifactStore(d)
    with FaultInjector(FaultSpec(tear_rate=1.0)).install(store=store):
        t1 = Session(store=store,
                     leases=LeaseManager(d, heartbeat=False)).run(TINY)
    s2 = Session(store=d, leases=True)
    t2 = s2.run(TINY)                   # torn artifact -> heal recompute
    assert s2.store.corrupt == 1
    log = LeaseManager.read_eval_log(d)
    assert sum(c.get("heal", 0) for c in log.values()) == 1
    assert LeaseManager.duplicate_evals(d) == {}
    for a, b in zip(t1.points, t2.points):
        assert a.t_read_s == b.t_read_s and a.area_um2 == b.area_um2


# ---------------------------------------------------------------------------
# compile-service satellites
# ---------------------------------------------------------------------------

def test_drain_isolates_serialization_failure(monkeypatch):
    from repro.api.queries import Query
    from repro.launch import compile_service as cs

    class _BadResult:
        def as_dict(self):
            raise RuntimeError("unserializable result")

    class _BadQuery(Query):
        def run(self, session):
            return _BadResult()

    real_parse = cs.parse_query
    monkeypatch.setattr(
        cs, "parse_query",
        lambda spec, tech: _BadQuery() if spec.get("type") == "boom"
        else real_parse(spec, tech))
    svc = CompileService(wave_size=8)
    svc.submit({"id": "bad", "query": {"type": "boom"}})
    svc.submit(_tiny_spec(ident="good"))
    out = {r["id"]: r for r in svc.drain()}
    assert out["good"]["ok"]                         # wave completed
    assert not out["bad"]["ok"]
    assert "serialization" in out["bad"]["error"]
    assert out["bad"]["retryable"] is False          # deterministic


def test_serve_stream_drains_partial_waves():
    svc = CompileService(wave_size=64)

    def slow_producer():
        yield json.dumps(_tiny_spec(ident="first"))
        time.sleep(0.4)                 # far longer than the idle window
        yield json.dumps(_tiny_spec(ident="second"))

    t0 = time.time()
    lines = list(svc.serve_stream(slow_producer(), max_wait_s=0.05))
    assert [json.loads(l)["id"] for l in lines] == ["first", "second"]
    assert all(json.loads(l)["ok"] for l in lines)
    assert svc.waves == 2               # partial waves, not one big one
    assert time.time() - t0 < 30


# ---------------------------------------------------------------------------
# fleet
# ---------------------------------------------------------------------------

def test_fleet_degrades_to_inline_when_spawn_fails(tmp_path):
    with Fleet(str(tmp_path / "spool"), str(tmp_path / "store"),
               n_workers=2, python="/nonexistent/python",
               max_attempts=2) as fleet:
        assert fleet.degraded
        resp = fleet.run([_tiny_spec(ident="a"), _tiny_spec(ident="b")])
    assert all(r["ok"] for r in resp)
    assert [r["id"] for r in resp] == ["a", "b"]
    assert fleet.counters["spawn_failures"] == 2
    assert LeaseManager.duplicate_evals(str(tmp_path / "store")) == {}


def test_fleet_quarantines_poison_inline(tmp_path):
    with Fleet(str(tmp_path / "spool"), str(tmp_path / "store"),
               n_workers=1, python="/nonexistent/python",
               max_attempts=3, backoff_s=0.01,
               fault_specs={"inline": "poison=POISON"}) as fleet:
        resp = fleet.run([_tiny_spec(ident="POISON-1"),
                          _tiny_spec(ident="fine")])
    poison, fine = resp
    assert not poison["ok"] and poison["quarantined"]
    assert poison["attempts"] == 3
    assert fine["ok"] and "quarantined" not in fine


def test_fleet_rejects_invalid_query_without_retry(tmp_path):
    with Fleet(str(tmp_path / "spool"), str(tmp_path / "store"),
               n_workers=1, python="/nonexistent/python",
               max_attempts=5) as fleet:
        resp = fleet.run([{"id": "bad", "query": {"type": "nonsense"}}])
    assert not resp[0]["ok"] and resp[0]["attempts"] == 1
    assert "quarantined" not in resp[0]  # deterministic error, no retry


def test_fleet_survives_worker_killed_mid_wave(tmp_path):
    spool, store = str(tmp_path / "spool"), str(tmp_path / "store")
    reqs = [_tiny_spec(nw, f"r{i}", f"t{i % 2}")
            for i, nw in enumerate((16, 32, 16, 64, 32))]
    svc = CompileService(wave_size=8)
    lines = svc.serve_lines(json.dumps(r) for r in reqs)
    base = {r["id"]: r for r in map(json.loads, lines)}
    with Fleet(spool, store, n_workers=2, lease_ttl_s=2.0,
               backoff_s=0.2, max_attempts=5, deadline_s=120.0,
               fault_specs={"w0": "die_after_puts=1"}) as fleet:
        resp = fleet.run(reqs, timeout_s=300)
        stats = fleet.stats()
    assert all(r["ok"] for r in resp)
    assert stats["worker_deaths"] == 1
    assert stats["retries"] >= 1
    assert LeaseManager.duplicate_evals(store) == {}
    for r in resp:          # bit-identical to the in-process baseline
        b = base[r["id"]]
        assert json.dumps(r["result"], sort_keys=True) == \
            json.dumps(b["result"], sort_keys=True)
