"""Hypothesis shim: re-export the real library when installed, else a
deterministic fallback so tier-1 collects and runs everywhere.

The fallback implements just the surface our tests use — `given`,
`settings`, `strategies.integers/floats/sampled_from` — and runs each
property test over a fixed-seed sample of the strategy space instead of
hypothesis's adaptive search. Install `hypothesis` (requirements-dev.txt)
for real shrinking/coverage.
"""
try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401
except ModuleNotFoundError:
    import functools
    import inspect
    import random

    _FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, sampler):
            self._sampler = sampler

        def sample(self, rng):
            return self._sampler(rng)

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

    def settings(max_examples=_FALLBACK_EXAMPLES, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            @functools.wraps(fn)
            def run(*args, **kwargs):
                n = getattr(run, "_max_examples",
                            getattr(fn, "_max_examples", _FALLBACK_EXAMPLES))
                rng = random.Random(0)
                for _ in range(n):
                    fn(*args, *(s.sample(rng) for s in strats), **kwargs)
            # hide the sampled parameters from pytest's fixture resolution
            del run.__wrapped__
            run.__signature__ = inspect.Signature()
            return run
        return deco
