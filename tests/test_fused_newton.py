"""Fused sparse-Newton engine: Woodbury/sparse-LU lattice parity vs the
dense reference, Pallas-kernel interpret-vs-XLA parity, the mixed
precision contract, crossing_time edge cases, and the small satellites
(_pad_to round-trip, LU-based modified Newton)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import timing
from repro.core.bank import BankConfig, build_bank
from repro.core.spice.mna import G_BIG, MNASparsity
from repro.core.spice.transient import Transient, crossing_time
from repro.kernels.batched_solve import newton as nwt
from repro.kernels.batched_solve import ops as solve_ops
from repro.kernels.batched_solve import sparse as sps
from repro.kernels.batched_solve.fused import fused_newton
from repro.kernels.batched_solve.kernel import _pad_to


def _lattice_inputs(B=3, cell="gc2t_nn", ws=16, nw=16):
    """One topology's run_lattice inputs with per-lane R/C jitter —
    the char_batch assembly path in miniature."""
    bank = build_bank(BankConfig(ws, nw, cell))
    ckt, meta = timing.read_netlist(bank)
    res_stamps, cap_stamps, src_G = ckt.build_stamps()
    system = ckt.build()
    rng = np.random.default_rng(42)
    g = np.asarray([g for _, _, g in ckt.res])
    c = np.asarray([c for _, _, c in ckt.caps])
    g_b = g[None] * (1 + 0.1 * rng.uniform(-1, 1, (B, len(g))))
    c_b = c[None] * (1 + 0.1 * rng.uniform(-1, 1, (B, len(c))))
    G_b = src_G[None] + np.einsum("br,rij->bij", g_b, res_stamps)
    C_b = np.einsum("bc,cij->bij", c_b, cap_stamps)
    t_an, _ = timing.cell_read_time(bank)
    t_end1 = max(timing.T_END_OVER_ANALYTIC * t_an, timing.T_END_MIN_S)
    t_end = t_end1 * (1 + 0.1 * rng.uniform(-1, 1, B))
    waves, v_pre = timing.read_stimulus(bank.cell, bank.cfg.tech,
                                        meta["v_sn"],
                                        timing.T0_FRACTION * t_end1)
    k = max(len(t) for t, _ in waves)
    wt = np.zeros((B, len(waves), k))
    wv = np.zeros((B, len(waves), k))
    for w, (t, v) in enumerate(waves):
        wt[:, w] = t + [t[-1]] * (k - len(t))
        wv[:, w] = v + [v[-1]] * (k - len(v))
    return system, dict(wt=wt, wv=wv, t_end=t_end, G_b=G_b, C_b=C_b,
                        v_pre=v_pre, bank=bank)


def _run(system, inp, solver, precision="f64", n_steps=60):
    tr = Transient(system, solver=solver, precision=precision)
    v0 = jnp.full((system.n,), inp["v_pre"])
    return tr.run_lattice(inp["wt"], inp["wv"], inp["t_end"], n_steps,
                          over_batches={"G": inp["G_b"], "C": inp["C_b"]},
                          v0=v0)


# ---------------------------------------------------------------------------
# fused engines == dense reference on whole lattice traces
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("solver", ["pallas", "sparse"])
@pytest.mark.parametrize("cell", ["gc2t_nn", "gc2t_np"])
def test_fused_lattice_matches_dense(solver, cell):
    with enable_x64():
        system, inp = _lattice_inputs(cell=cell)
        ref = _run(system, inp, "jnp")
        got = _run(system, inp, solver)
        dev = float(jnp.max(jnp.abs(ref["all"] - got["all"])))
        assert dev <= 1e-6, dev


def test_mixed_precision_holds_parity_contract():
    """mixed = f32 carried traces, f64 model + solve: t_cell within the
    1% contract; pure f32 is NOT asserted (screening only)."""
    with enable_x64():
        system, inp = _lattice_inputs()
        ref = _run(system, inp, "jnp")
        got = _run(system, inp, "pallas", precision="mixed")
        assert got["all"].dtype == jnp.float32
        bank = inp["bank"]
        swing = bank.cfg.tech.v_sense_se
        target = inp["v_pre"] + (swing if bank.cell.predischarge
                                 else -swing)
        for res in (ref, got):
            tc, valid = crossing_time(res["t"], res["rbl_near"], target,
                                      rising=bank.cell.predischarge)
            res["tc"] = np.asarray(tc, np.float64)
            res["valid"] = np.asarray(valid)
        assert ref["valid"].all() and got["valid"].all()
        rel = np.abs(got["tc"] - ref["tc"]) / ref["tc"]
        assert float(np.max(rel)) <= 0.01


def test_fused_rejects_unknown_override_batches():
    """Device-parameter overrides ("w", "vt0", ... — the differentiable
    DSE path) are accepted alongside G/C; anything else still fails
    loudly instead of being silently dropped."""
    with enable_x64():
        system, inp = _lattice_inputs()
        tr = Transient(system, solver="pallas")
        with pytest.raises(ValueError, match="overrides"):
            tr.run_lattice(inp["wt"], inp["wv"], inp["t_end"], 10,
                           over_batches={"G": inp["G_b"],
                                         "bogus": np.ones((3, 4))})


# ---------------------------------------------------------------------------
# Pallas kernel (interpret mode) == XLA fallback, per precision
# ---------------------------------------------------------------------------

def _step_operands(precision):
    """Physically consistent single-timestep operands for the fused
    solve: first backward-Euler step of the read transient."""
    system, inp = _lattice_inputs()
    spec = nwt.build_fused_spec(system, precision)
    sdt, cdt = spec.dtypes
    B = inp["t_end"].shape[0]
    h = jnp.asarray(inp["t_end"] / 60, cdt)
    pre = nwt.precompute(spec, inp["G_b"], inp["C_b"], h)
    src = jnp.zeros((B, system.n), cdt).at[:, np.asarray(system.src_node)] \
        .set(G_BIG * jnp.asarray(inp["wv"], cdt)[
            :, np.asarray(system.src_wave), 0])
    v0 = jnp.full((B, system.n), inp["v_pre"], sdt)
    Krhs = jnp.einsum("bij,bj->bi", pre["KCoh"], v0.astype(cdt)) \
        + jnp.einsum("bij,bj->bi", pre["K"], src)
    params = sps.pack_params(system.dev, B, sdt)
    return spec, pre, Krhs, params, v0


@pytest.mark.parametrize("precision", ["f64", "f32"])
def test_fused_kernel_interpret_matches_xla(precision):
    with enable_x64():
        spec, pre, Krhs, params, v0 = _step_operands(precision)
        v_xla, _ = nwt.newton_solve(spec, pre, Krhs, params, v0, 6, 1e-9)
        v_fix = nwt.newton_solve_fixed(spec, pre, Krhs, params, v0,
                                       6, 1e-9)
        v_ker = fused_newton(spec, pre, Krhs, params, v0, iters=6,
                             tol=1e-9, block_b=4, interpret=True)
        # per-lane freeze: early-exit while_loop == fixed fori_loop
        np.testing.assert_array_equal(np.asarray(v_xla), np.asarray(v_fix))
        np.testing.assert_array_equal(np.asarray(v_ker), np.asarray(v_fix))
        assert v_ker.dtype == v0.dtype


def test_fused_kernel_dispatcher_routes_and_pads():
    """ops.fused_newton_step(force_kernel=True) runs the interpret-mode
    kernel (incl. batch padding: B=3 pads to block_b=8) and matches the
    XLA fallback it would otherwise take on CPU."""
    with enable_x64():
        spec, pre, Krhs, params, v0 = _step_operands("f64")
        v_fb = solve_ops.fused_newton_step(spec, pre, Krhs, params, v0,
                                           iters=6, tol=1e-9)
        v_ker = solve_ops.fused_newton_step(spec, pre, Krhs, params, v0,
                                            iters=6, tol=1e-9,
                                            force_kernel=True)
        np.testing.assert_array_equal(np.asarray(v_ker), np.asarray(v_fb))


# ---------------------------------------------------------------------------
# satellites: _pad_to, LU-based modified Newton, crossing_time edges
# ---------------------------------------------------------------------------

def test_pad_to_round_trip():
    x = jnp.arange(15.0).reshape(3, 5)
    for axis, n in ((0, 8), (1, 7)):
        y = _pad_to(x, n, axis)
        assert y.shape[axis] == n
        pad = [slice(None)] * 2
        pad[axis] = slice(x.shape[axis], None)
        assert float(jnp.abs(y[tuple(pad)]).max()) == 0.0
        sl = [slice(None)] * 2
        sl[axis] = slice(0, x.shape[axis])
        np.testing.assert_array_equal(np.asarray(y[tuple(sl)]),
                                      np.asarray(x))
    assert _pad_to(x, 5, 1) is x          # no-op when already sized
    assert _pad_to(x, 3, 1).shape == x.shape


def test_modified_newton_lu_matches_explicit_inverse():
    """The chord iteration now factors once (LU) and applies triangular
    solves; same math as the old explicit-inverse path."""
    with enable_x64():
        bank = build_bank(BankConfig(16, 16, "gc2t_nn"))
        ckt, meta = timing.read_netlist(bank)
        sys = ckt.build()
        rng = np.random.default_rng(5)
        v = jnp.asarray(rng.uniform(0.0, 1.1, (sys.n,)))
        h = jnp.asarray(1e-11)
        J = sys.jacobian(v, h)
        r = jnp.asarray(rng.standard_normal((sys.n,)) * 1e-3)
        lu_piv = jax.scipy.linalg.lu_factor(J)
        x_lu = jax.scipy.linalg.lu_solve(lu_piv, r)
        x_inv = jnp.linalg.inv(J) @ r
        np.testing.assert_allclose(np.asarray(x_lu), np.asarray(x_inv),
                                   rtol=1e-9, atol=1e-18)

        # and the full trace still agrees with fresh-Jacobian Newton.
        # The chord iteration converges only linearly, so it needs fine
        # steps (contractive h) and a deeper iteration budget.
        t_an, _ = timing.cell_read_time(bank)
        t_end = max(timing.T_END_OVER_ANALYTIC * t_an, timing.T_END_MIN_S)
        waves, v_pre = timing.read_stimulus(bank.cell, bank.cfg.tech,
                                            meta["v_sn"],
                                            timing.T0_FRACTION * t_end)
        v0 = jnp.full((sys.n,), v_pre)
        full = Transient(sys, newton="full", tol=1e-9).run(
            waves, t_end, n_steps=300, v0=v0)
        mod = Transient(sys, newton="modified", iters=25).run(
            waves, t_end, n_steps=300, v0=v0)
        dev = float(jnp.max(jnp.abs(full["all"] - mod["all"])))
        assert dev <= 1e-6, dev


def test_crossing_time_step0_flat_and_never():
    """Edge lanes: already past target at step 0 (invalid, +inf), flat
    trace parked ON the target (invalid, no NaN from dv == 0), flat
    below target, and a normal crossing lane in the same batch."""
    t = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    v = jnp.asarray([
        [0.5, 0.9, 1.0, 1.0],   # step-0 exact hit: target reached at t[0]
        [0.5, 0.5, 0.5, 0.5],   # flat ON target: dv == 0 bracket
        [0.1, 0.2, 0.3, 0.4],   # never reaches
        [0.0, 0.4, 0.8, 0.8],   # normal: crosses 0.5 at t=2.25
    ])
    tc, ok = crossing_time(t, v, 0.5, rising=True)
    tc = np.asarray(tc)
    assert np.asarray(ok).tolist() == [False, False, False, True]
    assert not np.isnan(tc).any()
    assert np.isinf(tc[:3]).all()
    assert tc[3] == pytest.approx(2.25)
    # falling direction, same edges
    tcf, okf = crossing_time(t, 1.0 - v, 0.5, rising=False)
    assert np.asarray(okf).tolist() == [False, False, False, True]
    assert float(tcf[3]) == pytest.approx(2.25)


def test_precision_knob_validation():
    from repro.api import SweepQuery
    with pytest.raises(ValueError, match="precision"):
        SweepQuery(precision="f16")
    with pytest.raises(ValueError, match="solver"):
        SweepQuery(solver="scipy")
    with pytest.raises(ValueError, match="precision"):
        nwt.build_fused_spec(object(), "f16")   # checked before system use
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        SweepQuery(fidelity="transient", precision="f32")
    assert any("screening" in str(x.message) for x in w)
