"""Serving engine integration: continuous batching over slots, greedy
determinism, SWA ring engine, int8-cache engine, chunked device-resident
decode (parity with the per-token host loop, slot lifecycle mid-chunk,
EOS stop, device sampler)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import Model
from repro.serving import ServeEngine
from repro.serving.engine import Request
from repro.serving.sampling import sample_host, sample_tokens


def _engine(arch="llama3.2-1b", **cfg_over):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32",
                              **cfg_over)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, params


def test_engine_serves_more_requests_than_slots():
    cfg, params = _engine()
    eng = ServeEngine(cfg, params, n_slots=2, window=64)
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                           max_new_tokens=4))
    done, steps = eng.run()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 4 for r in done)


def test_greedy_is_deterministic():
    cfg, params = _engine()
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    outs = []
    for _ in range(2):
        eng = ServeEngine(cfg, params, n_slots=1, window=64)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=6,
                           temperature=0.0))
        done, _ = eng.run()
        outs.append(done[0].out_tokens)
    assert outs[0] == outs[1]


def test_engine_matches_manual_decode():
    """Engine greedy continuation == hand-rolled prefill+decode loop."""
    cfg, params = _engine()
    m = Model(cfg)
    prompt = np.arange(5, dtype=np.int32) + 3
    eng = ServeEngine(cfg, params, n_slots=1, window=32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=3))
    done, _ = eng.run()

    import jax.numpy as jnp
    logits, cache, pos = jax.jit(lambda p, b: m.prefill(p, b, W=32))(
        params, {"tokens": jnp.asarray(prompt)[None]})
    toks = [int(np.argmax(np.asarray(logits)[0]))]
    cur = jnp.asarray([[toks[-1]]], jnp.int32)
    for _ in range(2):
        logits, cache = jax.jit(m.decode_step)(params, cache, cur, pos)
        pos = pos + 1
        toks.append(int(np.argmax(np.asarray(logits)[0])))
        cur = jnp.asarray([[toks[-1]]], jnp.int32)
    assert done[0].out_tokens == toks


def test_engine_with_int8_cache():
    cfg, params = _engine(kv_dtype="int8")
    eng = ServeEngine(cfg, params, n_slots=2, window=64)
    assert eng.cache["k"].dtype.name == "int8"
    rng = np.random.default_rng(1)
    for i in range(3):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                           max_new_tokens=3))
    done, _ = eng.run()
    assert len(done) == 3


def test_engine_slot_lifecycle():
    """admit -> decode -> retire, step by step: slots fill FIFO from the
    queue, retire exactly at max_new_tokens, and free slots re-admit."""
    cfg, params = _engine()
    eng = ServeEngine(cfg, params, n_slots=2, window=64)
    assert eng.step() is False                 # idle engine: nothing to do
    rng = np.random.default_rng(2)
    for i in range(3):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 5)
                           .astype(np.int32),
                           max_new_tokens=2))
    assert eng.active == [None, None] and len(eng.queue) == 3
    # step 1: admits rids 0,1 (prefill emits token 1), decode emits token
    # 2 -> both hit max_new_tokens and retire; rid 2 still queued
    assert eng.step() is True
    assert [r.rid for r in eng.done] == [0, 1]
    assert eng.active == [None, None]
    assert [r.rid for r in eng.queue] == [2]
    # step 2: admits rid 2 into a freed slot and finishes it
    assert eng.step() is True
    assert [r.rid for r in eng.done] == [0, 1, 2]
    assert all(len(r.out_tokens) == 2 for r in eng.done)
    # drained: queue empty, all slots free, engine idle again
    assert not eng.queue and eng.active == [None, None]
    assert eng.step() is False


def test_engine_partial_retire_keeps_long_request():
    """Unequal lengths: the short request retires and frees its slot
    while the long one keeps decoding in place (chunk of 2 so the long
    request spans several engine steps)."""
    cfg, params = _engine()
    eng = ServeEngine(cfg, params, n_slots=2, window=64, decode_chunk=2)
    prompt = (np.arange(4, dtype=np.int32) + 1) % cfg.vocab_size
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=1))
    eng.submit(Request(rid=1, prompt=prompt, max_new_tokens=5))
    eng.step()
    assert [r.rid for r in eng.done] == [0]
    assert eng.active[0] is None and eng.active[1].rid == 1
    done, steps = eng.run()
    assert [r.rid for r in done] == [0, 1]
    assert len(done[1].out_tokens) == 5


def test_engine_run_drains_queue_within_step_budget():
    """run() serves queue > slots completely and reports its step count."""
    cfg, params = _engine()
    eng = ServeEngine(cfg, params, n_slots=2, window=64)
    rng = np.random.default_rng(3)
    for i in range(6):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 4)
                           .astype(np.int32),
                           max_new_tokens=3))
    done, steps = eng.run()
    assert len(done) == 6
    # 6 requests x 2 decode steps each over 2 slots, +1 idle-check step
    assert steps <= 6 * 3
    assert sorted(r.rid for r in done) == list(range(6))
    assert eng.active == [None, None] and not eng.queue


def test_chunked_greedy_matches_host_loop():
    """Device-resident chunked decode (K=8) emits the identical greedy
    token stream as the per-token host loop, across mixed prompt-length
    admission groups and slot reuse."""
    cfg, params = _engine()
    prompts = [(np.arange(n, dtype=np.int32) * 3 + i) % cfg.vocab_size
               for i, n in enumerate((5, 9, 5, 7))]
    streams = {}
    for mode in ("device", "host"):
        eng = ServeEngine(cfg, params, n_slots=2, window=64, mode=mode,
                          decode_chunk=8)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
        done, _ = eng.run()
        streams[mode] = {r.rid: r.out_tokens for r in done}
    assert streams["device"] == streams["host"]


def test_chunked_slot_lifecycle_and_readmission():
    """A slot that hits max_new_tokens mid-chunk emits EXACTLY
    max_new_tokens tokens, is retired, and the freed slot is re-admitted
    with a fresh cache row: the re-admitted request's continuation equals
    a standalone prefill+decode loop."""
    cfg, params = _engine()
    m = Model(cfg)
    prompts = [((np.arange(4, dtype=np.int32) + 7 * i) % cfg.vocab_size)
               for i in range(3)]
    eng = ServeEngine(cfg, params, n_slots=1, window=32, decode_chunk=8)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=3))
    done, _ = eng.run()
    assert [r.rid for r in done] == [0, 1, 2]
    assert all(len(r.out_tokens) == 3 for r in done)
    # the single slot was retired and re-admitted twice; the LAST request
    # must decode from a freshly inserted cache row
    logits, cache, pos = jax.jit(lambda pp, b: m.prefill(pp, b, W=32))(
        params, {"tokens": jnp.asarray(prompts[2])[None]})
    toks = [int(np.argmax(np.asarray(logits)[0]))]
    dec = jax.jit(m.decode_step)
    cur = jnp.asarray([[toks[-1]]], jnp.int32)
    for _ in range(2):
        logits, cache = dec(params, cache, cur, pos)
        pos = pos + 1
        toks.append(int(np.argmax(np.asarray(logits)[0])))
        cur = jnp.asarray([[toks[-1]]], jnp.int32)
    assert done[2].out_tokens == toks


def test_max_new_one_emits_exactly_one_token():
    """max_new_tokens=1 retires at prefill with a single token (the old
    per-token loop over-emitted one decode token here)."""
    cfg, params = _engine()
    eng = ServeEngine(cfg, params, n_slots=1, window=32)
    eng.submit(Request(rid=0, prompt=np.arange(5, dtype=np.int32),
                       max_new_tokens=1))
    done, _ = eng.run()
    assert len(done) == 1 and len(done[0].out_tokens) == 1


def test_prefill_finished_wave_does_not_strand_queue():
    """A whole admission wave finishing at prefill (max_new=1) must not
    stall run(): every queued request is still served."""
    cfg, params = _engine()
    eng = ServeEngine(cfg, params, n_slots=1, window=32)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=np.arange(4, dtype=np.int32) + i,
                           max_new_tokens=1))
    done, _ = eng.run()
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert all(len(r.out_tokens) == 1 for r in done)
    assert not eng.queue


def test_eos_stops_mid_chunk():
    """An EOS hit inside a chunk freezes the slot immediately: the
    stream is the no-EOS greedy stream truncated just after the EOS."""
    cfg, params = _engine()
    prompt = (np.arange(6, dtype=np.int32) * 5 + 1) % cfg.vocab_size
    eng = ServeEngine(cfg, params, n_slots=1, window=64, decode_chunk=8)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=6))
    full = eng.run()[0][0].out_tokens
    assert len(full) == 6
    eos = full[2]
    cut = full.index(eos)
    eng2 = ServeEngine(cfg, params, n_slots=1, window=64, decode_chunk=8)
    eng2.submit(Request(rid=0, prompt=prompt, max_new_tokens=6,
                        eos_id=int(eos)))
    out = eng2.run()[0][0].out_tokens
    assert out == full[:cut + 1]


def test_device_sampler_matches_host_support():
    """Device sampler: greedy rows equal argmax; stochastic rows draw
    only from the same top-k support the host reference sampler uses,
    and repeated draws on a fixed key cover more than one candidate."""
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(4, 64)).astype(np.float32) * 3
    temp = jnp.asarray([0.0, 0.8, 0.8, 1.5], jnp.float32)
    topk = jnp.asarray([1, 5, 5, 3], jnp.int32)
    key = jax.random.key(42)
    toks = np.asarray(sample_tokens(jnp.asarray(logits), key, temp, topk,
                                    k_max=32))
    assert toks.shape == (4,) and toks.dtype == np.int32
    assert toks[0] == int(np.argmax(logits[0]))
    for b in (1, 2, 3):
        support = set(np.argsort(logits[b])[-int(topk[b]):].tolist())
        assert int(toks[b]) in support

    support = set(np.argsort(logits[1])[-5:].tolist())
    dev_draws, host_draws = set(), set()
    hrng = np.random.default_rng(1)
    for i in range(64):
        k = jax.random.fold_in(key, i)
        dev_draws.add(int(sample_tokens(jnp.asarray(logits), k, temp, topk,
                                        k_max=32)[1]))
        host_draws.add(sample_host(logits[1], 0.8, 5, hrng))
    assert dev_draws <= support and host_draws <= support
    assert len(dev_draws) > 1


def test_engine_with_swa_ring(arch="mixtral-8x7b"):
    cfg, params = _engine(arch, capacity_factor=8.0)
    eng = ServeEngine(cfg, params, n_slots=1, window=16)  # ring < prompt
    prompt = np.arange(24, dtype=np.int32) % cfg.vocab_size
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
    done, _ = eng.run()
    assert len(done[0].out_tokens) == 4
