"""Serving engine integration: continuous batching over slots, greedy
determinism, SWA ring engine, int8-cache engine."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import Model
from repro.serving import ServeEngine
from repro.serving.engine import Request


def _engine(arch="llama3.2-1b", **cfg_over):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32",
                              **cfg_over)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, params


def test_engine_serves_more_requests_than_slots():
    cfg, params = _engine()
    eng = ServeEngine(cfg, params, n_slots=2, window=64)
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                           max_new_tokens=4))
    done, steps = eng.run()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 4 for r in done)


def test_greedy_is_deterministic():
    cfg, params = _engine()
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    outs = []
    for _ in range(2):
        eng = ServeEngine(cfg, params, n_slots=1, window=64)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=6,
                           temperature=0.0))
        done, _ = eng.run()
        outs.append(done[0].out_tokens)
    assert outs[0] == outs[1]


def test_engine_matches_manual_decode():
    """Engine greedy continuation == hand-rolled prefill+decode loop."""
    cfg, params = _engine()
    m = Model(cfg)
    prompt = np.arange(5, dtype=np.int32) + 3
    eng = ServeEngine(cfg, params, n_slots=1, window=32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=3))
    done, _ = eng.run()

    import jax.numpy as jnp
    logits, cache, pos = jax.jit(lambda p, b: m.prefill(p, b, W=32))(
        params, {"tokens": jnp.asarray(prompt)[None]})
    toks = [int(np.argmax(np.asarray(logits)[0]))]
    cur = jnp.asarray([[toks[-1]]], jnp.int32)
    for _ in range(2):
        logits, cache = jax.jit(m.decode_step)(params, cache, cur, pos)
        pos = pos + 1
        toks.append(int(np.argmax(np.asarray(logits)[0])))
        cur = jnp.asarray([[toks[-1]]], jnp.int32)
    assert done[0].out_tokens == toks


def test_engine_with_int8_cache():
    cfg, params = _engine(kv_dtype="int8")
    eng = ServeEngine(cfg, params, n_slots=2, window=64)
    assert eng.cache["k"].dtype.name == "int8"
    rng = np.random.default_rng(1)
    for i in range(3):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                           max_new_tokens=3))
    done, _ = eng.run()
    assert len(done) == 3


def test_engine_slot_lifecycle():
    """admit -> decode -> retire, step by step: slots fill FIFO from the
    queue, retire exactly at max_new_tokens, and free slots re-admit."""
    cfg, params = _engine()
    eng = ServeEngine(cfg, params, n_slots=2, window=64)
    assert eng.step() is False                 # idle engine: nothing to do
    rng = np.random.default_rng(2)
    for i in range(3):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 5)
                           .astype(np.int32),
                           max_new_tokens=2))
    assert eng.active == [None, None] and len(eng.queue) == 3
    # step 1: admits rids 0,1 (prefill emits token 1), decode emits token
    # 2 -> both hit max_new_tokens and retire; rid 2 still queued
    assert eng.step() is True
    assert [r.rid for r in eng.done] == [0, 1]
    assert eng.active == [None, None]
    assert [r.rid for r in eng.queue] == [2]
    # step 2: admits rid 2 into a freed slot and finishes it
    assert eng.step() is True
    assert [r.rid for r in eng.done] == [0, 1, 2]
    assert all(len(r.out_tokens) == 2 for r in eng.done)
    # drained: queue empty, all slots free, engine idle again
    assert not eng.queue and eng.active == [None, None]
    assert eng.step() is False


def test_engine_partial_retire_keeps_long_request():
    """Unequal lengths: the short request retires and frees its slot
    while the long one keeps decoding in place."""
    cfg, params = _engine()
    eng = ServeEngine(cfg, params, n_slots=2, window=64)
    prompt = (np.arange(4, dtype=np.int32) + 1) % cfg.vocab_size
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=1))
    eng.submit(Request(rid=1, prompt=prompt, max_new_tokens=5))
    eng.step()
    assert [r.rid for r in eng.done] == [0]
    assert eng.active[0] is None and eng.active[1].rid == 1
    done, steps = eng.run()
    assert [r.rid for r in done] == [0, 1]
    assert len(done[1].out_tokens) == 5


def test_engine_run_drains_queue_within_step_budget():
    """run() serves queue > slots completely and reports its step count."""
    cfg, params = _engine()
    eng = ServeEngine(cfg, params, n_slots=2, window=64)
    rng = np.random.default_rng(3)
    for i in range(6):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 4)
                           .astype(np.int32),
                           max_new_tokens=3))
    done, steps = eng.run()
    assert len(done) == 6
    # 6 requests x 2 decode steps each over 2 slots, +1 idle-check step
    assert steps <= 6 * 3
    assert sorted(r.rid for r in done) == list(range(6))
    assert eng.active == [None, None] and not eng.queue


def test_engine_with_swa_ring(arch="mixtral-8x7b"):
    cfg, params = _engine(arch, capacity_factor=8.0)
    eng = ServeEngine(cfg, params, n_slots=1, window=16)  # ring < prompt
    prompt = np.arange(24, dtype=np.int32) % cfg.vocab_size
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
    done, _ = eng.run()
    assert len(done[0].out_tokens) == 4
