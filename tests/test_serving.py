"""Serving engine integration: continuous batching over slots, greedy
determinism, SWA ring engine, int8-cache engine."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import Model
from repro.serving import ServeEngine
from repro.serving.engine import Request


def _engine(arch="llama3.2-1b", **cfg_over):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32",
                              **cfg_over)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, params


def test_engine_serves_more_requests_than_slots():
    cfg, params = _engine()
    eng = ServeEngine(cfg, params, n_slots=2, window=64)
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                           max_new_tokens=4))
    done, steps = eng.run()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 4 for r in done)


def test_greedy_is_deterministic():
    cfg, params = _engine()
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    outs = []
    for _ in range(2):
        eng = ServeEngine(cfg, params, n_slots=1, window=64)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=6,
                           temperature=0.0))
        done, _ = eng.run()
        outs.append(done[0].out_tokens)
    assert outs[0] == outs[1]


def test_engine_matches_manual_decode():
    """Engine greedy continuation == hand-rolled prefill+decode loop."""
    cfg, params = _engine()
    m = Model(cfg)
    prompt = np.arange(5, dtype=np.int32) + 3
    eng = ServeEngine(cfg, params, n_slots=1, window=32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=3))
    done, _ = eng.run()

    import jax.numpy as jnp
    logits, cache, pos = jax.jit(lambda p, b: m.prefill(p, b, W=32))(
        params, {"tokens": jnp.asarray(prompt)[None]})
    toks = [int(np.argmax(np.asarray(logits)[0]))]
    cur = jnp.asarray([[toks[-1]]], jnp.int32)
    for _ in range(2):
        logits, cache = jax.jit(m.decode_step)(params, cache, cur, pos)
        pos = pos + 1
        toks.append(int(np.argmax(np.asarray(logits)[0])))
        cur = jnp.asarray([[toks[-1]]], jnp.int32)
    assert done[0].out_tokens == toks


def test_engine_with_int8_cache():
    cfg, params = _engine(kv_dtype="int8")
    eng = ServeEngine(cfg, params, n_slots=2, window=64)
    assert eng.cache["k"].dtype.name == "int8"
    rng = np.random.default_rng(1)
    for i in range(3):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                           max_new_tokens=3))
    done, _ = eng.run()
    assert len(done) == 3


def test_engine_with_swa_ring(arch="mixtral-8x7b"):
    cfg, params = _engine(arch, capacity_factor=8.0)
    eng = ServeEngine(cfg, params, n_slots=1, window=16)  # ring < prompt
    prompt = np.arange(24, dtype=np.int32) % cfg.vocab_size
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
    done, _ = eng.run()
    assert len(done[0].out_tokens) == 4
