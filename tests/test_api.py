"""Unified repro.api surface: Session/Query dispatch, batched-sweep
parity vs the scalar reference, result hierarchy, caching, deprecated
shims, pareto keys, banks_needed edge cases."""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.api import (CompileQuery, DesignTable, MatchQuery, MatchResult,
                       OptimizeQuery, Result, Session, SweepQuery)
from repro.core import dse
from repro.core.bank import BankConfig
from repro.core.compiler import GCRAMCompiler
from repro.core.dse import Demand
from repro.core.multibank import banks_needed, build_multibank

SMALL = SweepQuery(cells=("gc2t_nn", "gc2t_osos", "sram6t"),
                   word_sizes=(16, 32), num_words=(16, 32),
                   wwlls=(False, True))

PARITY_FIELDS = ("area_um2", "f_max_hz", "read_bw_bps", "write_bw_bps",
                 "eff_bw_bps", "leakage_w", "refresh_w", "retention_s",
                 "t_read_s", "t_write_s")


def _assert_parity(point, ref, rel=1e-6):
    for f in PARITY_FIELDS:
        a, b = getattr(point, f), getattr(ref, f)
        if np.isinf(b):
            assert np.isinf(a), (f, point.cfg)
        else:
            assert a == pytest.approx(b, rel=rel), (f, point.cfg)
    assert point.swing_ok == ref.swing_ok, point.cfg


# ---------------------------------------------------------------------------
# tentpole: batched sweep == scalar reference
# ---------------------------------------------------------------------------

def test_batched_sweep_matches_scalar_on_default_lattice():
    s = Session()
    table = s.run(SweepQuery())
    cfgs = SweepQuery().configs(s.tech)
    assert isinstance(table, DesignTable) and len(table) == len(cfgs)
    for p, cfg in zip(table, cfgs):
        _assert_parity(p, dse.evaluate(cfg))


def test_batched_sweep_covers_sram_and_os_groups():
    s = Session()
    table = s.sweep(SMALL)
    cells = {p.cfg.cell for p in table}
    assert cells == {"gc2t_nn", "gc2t_osos", "sram6t"}
    for p in table:
        _assert_parity(p, dse.evaluate(p.cfg))


def test_scalar_fallback_sweep_matches_batched():
    s = Session()
    q = dataclasses.replace(SMALL, batched=False)
    slow = Session().sweep(q)
    fast = s.sweep(SMALL)
    for a, b in zip(slow, fast):
        _assert_parity(b, a)


# ---------------------------------------------------------------------------
# session caching
# ---------------------------------------------------------------------------

def test_session_caches_points_and_tables(monkeypatch):
    s = Session()
    calls = []
    orig = dse.evaluate
    monkeypatch.setattr(dse, "evaluate",
                        lambda cfg: (calls.append(cfg), orig(cfg))[1])
    cfg = BankConfig(16, 16, "gc2t_nn")
    p1 = s.evaluate(cfg)
    p2 = s.evaluate(BankConfig(16, 16, "gc2t_nn"))
    assert p1 is p2 and len(calls) == 1
    t1 = s.sweep(SMALL)
    t2 = s.sweep(SMALL)
    assert t1 is t2
    # sweep populated the point cache: no further scalar evaluations
    n = len(calls)
    s.evaluate(t1[0].cfg)
    assert len(calls) == n
    # and the pre-sweep scalar point was reused inside the sweep
    assert any(p is p1 for p in t1)


# ---------------------------------------------------------------------------
# CompileQuery + uniform results
# ---------------------------------------------------------------------------

def test_compile_query_matches_deprecated_facade(tmp_path):
    cfg = BankConfig(32, 32, cell="gc2t_nn")
    rep = Session().run(CompileQuery(cfg))
    with pytest.warns(DeprecationWarning):
        legacy = GCRAMCompiler(cfg).compile()
    assert rep.as_dict() == legacy.summary()
    assert isinstance(rep, Result)
    out = rep.write(str(tmp_path / "gc"))
    assert os.path.exists(os.path.join(out, "report.json"))
    assert os.path.exists(os.path.join(out, "read_column.sp"))


def test_results_write_uniformly(tmp_path):
    s = Session()
    table = s.sweep(SMALL)
    table.write(str(tmp_path))
    data = json.load(open(tmp_path / table.filename))
    assert data["n_points"] == len(table)
    m = s.match([Demand("toy", "L1", 1e6, 1e-9)], SMALL)
    m.write(str(tmp_path))
    data = json.load(open(tmp_path / m.filename))
    assert data["banks_needed"]["L1:toy"] == 1
    o = s.run(OptimizeQuery(target_ret_s=1e-6, steps=10))
    o.write(str(tmp_path))
    data = json.load(open(tmp_path / o.filename))
    assert "write_vt" in data
    assert data["objective"] == "standby_w"
    assert "vdd_scale" in data["knobs"]
    # only the requested knob moves; the rest stay at nominal
    assert all(data["knobs"][k] == 1.0 for k in data["knobs"]
               if k != "vdd_scale")
    assert data["met"] is True
    # never-regress contract: final objective <= the grid-seed rung's
    assert data["objective_value"] <= data["seed_objective_value"] * (1 + 1e-12)
    # the whole result is memoized on the frozen query
    assert s.run(OptimizeQuery(target_ret_s=1e-6, steps=10)) is o
    assert all(isinstance(r, Result) for r in (table, m, o))


# ---------------------------------------------------------------------------
# MatchQuery
# ---------------------------------------------------------------------------

def test_match_query_shmoo_and_multibank_sizing():
    s = Session()
    table = s.sweep(SMALL)
    fast = table.best("f_max_hz")
    demands = (Demand("easy", "L1", fast.f_max_hz * 0.5, 1e-9),
               Demand("hard", "L2", fast.f_max_hz * 3.5, 1e-9))
    m = s.run(MatchQuery(demands=demands, sweep=SMALL))
    assert isinstance(m, MatchResult)
    assert m.grid == dse.shmoo(table.points, list(demands))
    assert m.banks_needed["L1:easy"] == 1
    assert m.banks_needed["L2:hard"] == 4          # ceil(3.5) fastest banks
    assert 0.0 < m.pass_rate < 1.0
    hard = [r for r in m.rows if r["demand"] == "L2:hard"][0]
    assert hard["n_feasible"] == 0 and hard["bank"] is not None


def test_match_allow_refresh_threads_into_multibank_sizing():
    """A demand only serviceable via refresh must not get a 'feasible'
    multibank sizing when the query forbids refresh."""
    s = Session()
    table = s.sweep(SMALL)
    # lifetime longer than any gc bank's native retention but within
    # refresh reach: feasible with refresh, infeasible without
    ref = max((p for p in table if p.swing_ok and np.isfinite(p.retention_s)),
              key=lambda p: p.retention_s)
    d = Demand("refreshy", "L2", ref.f_max_hz * 0.1, ref.retention_s * 10)
    q = SweepQuery(cells=("gc2t_nn", "gc2t_osos"), word_sizes=(16, 32),
                   num_words=(16, 32), wwlls=(False, True))
    with_ref = s.match([d], q, allow_refresh=True)
    without = s.match([d], q, allow_refresh=False)
    assert with_ref.rows[0]["macro_feasible"]
    assert not without.rows[0]["macro_feasible"]
    assert without.banks_needed["L2:refreshy"] == 1025  # sentinel
    assert without.rows[0]["n_feasible"] == 0


def test_compose_multibank_rejects_timing_free_points():
    from repro.core.multibank import compose_multibank
    dp = Session().evaluate(BankConfig(16, 16, "gc2t_nn"))
    stale = dataclasses.replace(dp, t_read_s=0.0, t_write_s=0.0)
    with pytest.raises(ValueError):
        compose_multibank(stale, 4)
    assert compose_multibank(dp, 4).n_banks == 4


def test_design_point_as_dict_carries_new_metrics():
    dp = Session().evaluate(BankConfig(16, 16, "gc2t_nn"))
    d = dp.as_dict()
    assert d["t_read_s"] == dp.t_read_s > 0
    assert d["t_write_s"] == dp.t_write_s > 0
    assert d["standby_w"] == dp.leakage_w + dp.refresh_w


def test_match_capacity_driven_sizing():
    s = Session()
    fast = s.sweep(SMALL).best("f_max_hz")
    d = Demand("big", "L2", fast.f_max_hz * 0.25, 1e-9,
               capacity_bits=10 * fast.cfg.bits)
    m = s.match([d], SMALL)
    # some feasible bank exists; the macro must still cover the capacity
    assert m.banks_needed["L2:big"] >= 2


# ---------------------------------------------------------------------------
# pareto: keys respected + sort-based filter equals brute force
# ---------------------------------------------------------------------------

def _brute_pareto(points, keys):
    def metric(dp):
        return tuple(-getattr(dp, k) if k in dse.PARETO_MAXIMIZE
                     else getattr(dp, k) for k in keys)
    pts = [p for p in points if p.swing_ok]
    out = []
    for p in pts:
        m = metric(p)
        dom = any(all(x <= y for x, y in zip(metric(q), m))
                  and any(x < y for x, y in zip(metric(q), m)) for q in pts)
        if not dom:
            out.append(p)
    return out


def test_pareto_respects_keys_and_matches_bruteforce():
    pts = Session().sweep(SMALL).points
    fronts = {}
    for keys in [("area_um2", "f_max_hz"),
                 ("area_um2", "retention_s"),
                 ("area_um2", "f_max_hz", "standby_w")]:
        front = dse.pareto(pts, keys=keys)
        assert {id(p) for p in front} == \
            {id(p) for p in _brute_pareto(pts, keys)}, keys
        fronts[keys] = front
    # single-key fronts = all points achieving the optimum; different keys
    # select different points (so `keys` is demonstrably not ignored)
    area_front = dse.pareto(pts, keys=("area_um2",))
    amin = min(p.area_um2 for p in pts if p.swing_ok)
    assert all(p.area_um2 == amin for p in area_front)
    f_front = dse.pareto(pts, keys=("f_max_hz",))
    fmax = max(p.f_max_hz for p in pts if p.swing_ok)
    assert all(p.f_max_hz == fmax for p in f_front)
    assert {id(p) for p in area_front} != {id(p) for p in f_front}


def test_design_table_pareto_and_best():
    table = Session().sweep(SMALL)
    front = table.pareto()
    assert 0 < len(front) <= len(table)
    assert isinstance(front, DesignTable)
    assert front.best("f_max_hz").f_max_hz == \
        max(p.f_max_hz for p in front if p.swing_ok)


# ---------------------------------------------------------------------------
# deprecated shims stay functional
# ---------------------------------------------------------------------------

def test_deprecated_sweep_shim():
    with pytest.warns(DeprecationWarning):
        pts = dse.sweep(cells=("gc2t_nn",), word_sizes=(16,),
                        num_words=(16, 32), wwlls=(False,))
    assert len(pts) == 2
    _assert_parity(pts[0], dse.evaluate(pts[0].cfg))


def test_deprecated_build_multibank_shim():
    cfg = BankConfig(16, 16, "gc2t_nn")
    with pytest.warns(DeprecationWarning):
        mb = build_multibank(cfg, 4)
    assert mb.n_banks == 4
    assert mb.capacity_bits == 4 * cfg.bits


# ---------------------------------------------------------------------------
# banks_needed edge cases (satellite)
# ---------------------------------------------------------------------------

def test_banks_needed_edge_cases():
    dp = Session().evaluate(BankConfig(32, 32, "gc2t_nn"))
    easy = Demand("e", "L2", dp.f_max_hz * 0.9, 1e-9)
    assert banks_needed(dp, easy) == 1
    # frequency-driven: ceil(3.2x) banks
    assert banks_needed(dp, Demand("f", "L2", dp.f_max_hz * 3.2, 1e-9)) == 4
    # capacity-driven
    assert banks_needed(dp, easy, capacity_bits=10 * dp.cfg.bits) == 10
    # both -> max wins
    assert banks_needed(dp, Demand("f", "L2", dp.f_max_hz * 3.2, 1e-9),
                        capacity_bits=2 * dp.cfg.bits) == 4
    # infeasible points return the max_banks + 1 sentinel
    bad_swing = dataclasses.replace(dp, swing_ok=False)
    assert banks_needed(bad_swing, easy) == 1025
    assert banks_needed(bad_swing, easy, max_banks=16) == 17
    dead = dataclasses.replace(dp, f_max_hz=0.0)
    assert banks_needed(dead, easy) == 1025
    # retention too short for refresh to keep up -> infeasible per bank
    rotten = dataclasses.replace(dp, retention_s=1e-12)
    assert banks_needed(rotten, Demand("l", "L2", dp.f_max_hz * 0.5,
                                       1.0)) == 1025
