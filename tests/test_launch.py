"""Launch-layer integration: build->lower->compile->analyze on a small
mesh, HLO analyzer invariants, sharding rule table, report rendering."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, ShapeConfig
from repro.launch import hlo_analysis, roofline
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_test_mesh, data_axis_names, n_chips
from repro.launch.sharding import make_rules

MINI = {
    "train": ShapeConfig("mini_train", 64, 8, "train"),
    "prefill": ShapeConfig("mini_prefill", 64, 8, "prefill"),
    "decode": ShapeConfig("mini_decode", 64, 8, "decode"),
}


def _mesh():
    n = len(jax.devices())
    if n < 2:
        pytest.skip("needs >= 2 host devices (run under "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    return make_test_mesh(data=2, model=n // 2 if n < 8 else 4)


@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_small_mesh_dryrun_pipeline(kind):
    mesh = _mesh()
    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              name="qwen-mini")
    bundle = steps_mod.build(cfg, mesh, MINI[kind])
    with mesh:
        compiled = bundle.lower().compile()
    an = hlo_analysis.analyze(compiled.as_text(), n_chips(mesh))
    assert an["flops"] > 0
    assert an["mem_bytes"] > 0
    assert an["unknown_trip_counts"] == 0          # all loops resolved
    assert an["collective_count"] > 0              # SPMD really sharded
    rl = roofline.derive(an, n_chips=n_chips(mesh),
                         model_flops=roofline.model_flops_for(cfg, MINI[kind]))
    assert rl.step_time_s > 0 and rl.bottleneck in ("compute", "memory",
                                                    "collective")


def test_rules_divisibility_fallback():
    mesh = _mesh()
    rules = make_rules(mesh, batch_size=8)
    from repro.models.common import logical_to_pspec
    # a dim that doesn't divide the axis must fall back to replication
    m = mesh.shape["model"]
    spec = logical_to_pspec(("heads",), rules, shape=(m + 1,), mesh=mesh)
    assert spec == jax.sharding.PartitionSpec(None) or spec == \
        jax.sharding.PartitionSpec()
    spec2 = logical_to_pspec(("heads",), rules, shape=(m * 4,), mesh=mesh)
    assert spec2[0] == "model"


def test_decode_rules_differ_from_train():
    mesh = _mesh()
    rt = make_rules(mesh, kind="train")
    rd = make_rules(mesh, kind="decode")
    assert rt["expert_mlp"] is None
    assert rd["expert_mlp"] == data_axis_names(mesh)


def test_hlo_analyzer_trip_counts_and_dots():
    """scan-of-matmul: analyzer must multiply by the trip count (XLA's own
    cost_analysis does not)."""
    mesh = _mesh()
    from jax.sharding import NamedSharding, PartitionSpec as P
    L, d = 4, 64
    def step(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y * y)
    f = jax.jit(step, in_shardings=(
        NamedSharding(mesh, P(None, "data", "model")),
        NamedSharding(mesh, P("data", None))))
    lo = f.lower(jax.ShapeDtypeStruct((L, d, d), jnp.float32),
                 jax.ShapeDtypeStruct((8, d), jnp.float32))
    an = hlo_analysis.analyze(lo.compile().as_text(), n_chips(mesh))
    nd = mesh.shape["data"]
    nm = mesh.shape["model"]
    expect = L * 2 * (8 // nd) * d * (d // nm)
    assert an["flops"] == pytest.approx(expect, rel=0.05)
    assert an["dot_count"] == L


def test_report_tables(tmp_path):
    import glob
    import json
    from repro.launch import report
    # synthesize two records
    rec = {"arch": "a", "shape": "s", "mesh": "16x16", "kind": "train",
           "compile_s": 1.0,
           "roofline": {"compute_s": 1, "memory_s": 2, "collective_s": 0.5,
                        "bottleneck": "memory", "model_flops": 1e12,
                        "hlo_flops_global": 2e12, "mfu": 0.25,
                        "step_time_s": 2.0, "roofline_frac": 1.0},
           "hlo_analysis": {"flops": 1, "mem_bytes": 2,
                            "collective_wire_bytes": 3,
                            "collective_by_type": {"all-reduce": 3}},
           "memory_analysis": {"argument_bytes_per_device": 1,
                               "temp_bytes_per_device": 2},
           "peak_bytes_per_device": 3, "fits_16g_hbm": True}
    with open(tmp_path / "a__s__pod256.json", "w") as f:
        json.dump(rec, f)
    recs = report.load(str(tmp_path))
    t = report.roofline_table(recs)
    assert "memory" in t and "| a | s |" in t
    assert "a:s" in report.summary(recs)
