"""Batched transient characterization: analytic Jacobian stamps vs
jacfwd, Newton early-exit, whole-lattice parity vs the scalar
simulate_read reference, and the transient-fidelity SweepQuery."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.api import CalibratedTable, DesignTable, Session, SweepQuery
from repro.core import timing
from repro.core.bank import BankConfig, build_bank
from repro.core.spice.char_batch import characterize
from repro.core.spice.mna import Circuit, channel_current_grads
from repro.core.spice.transient import (Transient, crossing_time,
                                        make_stepper)
from repro.core.techfile import SYN40

TOPOLOGIES = ("gc2t_nn", "gc2t_np", "gc2t_osos")


def _read_system(cell, ws=32, nw=32):
    bank = build_bank(BankConfig(ws, nw, cell))
    ckt, meta = timing.read_netlist(bank)
    return bank, ckt.build(), meta


# ---------------------------------------------------------------------------
# analytic Jacobian stamps == jacfwd
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell", TOPOLOGIES)
def test_device_grads_match_autodiff(cell):
    _, sys, _ = _read_system(cell)
    rng = np.random.default_rng(3)
    for _ in range(3):
        v = jnp.asarray(rng.uniform(0.0, 1.1, (sys.n,)), jnp.float32)
        vg = sys._v_of(v, sys.didx["g"])
        va = sys._v_of(v, sys.didx["a"])
        vb = sys._v_of(v, sys.didx["b"])
        from repro.core.spice.mna import channel_current_raw
        args = (sys.dev["pol"], sys.dev["vt0"], sys.dev["n"], sys.dev["kp"],
                sys.dev["lam"], sys.dev["w"], sys.dev["l"])

        def cur(x, which):
            vs = [vg, va, vb]
            vs[which] = x
            return channel_current_raw(*args, *vs)

        g_an = channel_current_grads(*args, vg, va, vb)
        for which, an in enumerate(g_an):
            ad = jnp.diagonal(jax.jacfwd(lambda x: cur(x, which))(
                [vg, va, vb][which]))
            np.testing.assert_allclose(np.asarray(an), np.asarray(ad),
                                       rtol=1e-5, atol=1e-12)


@pytest.mark.parametrize("cell", TOPOLOGIES)
def test_analytic_jacobian_matches_jacfwd(cell):
    _, sys, _ = _read_system(cell)
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.uniform(0.0, 1.1, (sys.n,)), jnp.float32)
    vp = jnp.asarray(rng.uniform(0.0, 1.1, (sys.n,)), jnp.float32)
    wv = jnp.asarray(rng.uniform(0.0, 1.1, (4,)), jnp.float32)
    h = jnp.float32(1e-11)
    J_ad = jax.jacfwd(lambda vv: sys.residual(vv, vp, h, wv))(v)
    J_an = sys.jacobian(v, h)
    scale = float(jnp.max(jnp.abs(J_ad)))
    assert float(jnp.max(jnp.abs(J_ad - J_an))) <= 1e-6 * scale


def test_analytic_newton_trace_matches_jacfwd_newton():
    """Full integration parity (the acceptance bar): analytic-Jacobian
    Newton vs jacfwd Newton traces agree to 1e-6 in float64 (f32 solve
    noise through the cond~1e6 MNA Jacobian swamps either method)."""
    with enable_x64():
        bank, sys, meta = _read_system("gc2t_nn")
        t_an, _ = timing.cell_read_time(bank)
        t_end = max(timing.T_END_OVER_ANALYTIC * t_an, timing.T_END_MIN_S)
        waves, v_pre = timing.read_stimulus(bank.cell, SYN40, meta["v_sn"],
                                            timing.T0_FRACTION * t_end)
        v0 = jnp.full((sys.n,), v_pre)
        ref = Transient(sys, newton="jacfwd").run(waves, t_end,
                                                  n_steps=200, v0=v0)
        got = Transient(sys, newton="full", tol=1e-9).run(waves, t_end,
                                                          n_steps=200, v0=v0)
        diff = float(jnp.max(jnp.abs(ref["all"] - got["all"])))
        assert diff <= 1e-6, diff


def test_newton_early_exit_converges_and_saves_iterations():
    with enable_x64():
        bank, sys, meta = _read_system("gc2t_nn")
        t_an, _ = timing.cell_read_time(bank)
        t_end = max(6.0 * t_an, 0.5e-9)
        h = jnp.asarray(t_end / 300)
        vdd = SYN40.vdd
        wt = jnp.asarray([[0.0, 1.0]] * 4)
        wv = jnp.asarray([[vdd, vdd], [vdd, vdd],
                          [meta["v_sn"]] * 2, [vdd] * 2])
        v = jnp.full((sys.n,), vdd)
        step_aux = make_stepper(sys, iters=10, tol=1e-8, with_aux=True)
        v_fast, n_it = step_aux(v, h, h, wt, wv, {})
        step_full = make_stepper(sys, iters=10, tol=0.0)
        v_ref = step_full(v, h, h, wt, wv, {})
        # early exit triggered well under the cap, same solution
        assert int(n_it) < 10
        assert float(jnp.max(jnp.abs(v_fast - v_ref))) <= 1e-6


# ---------------------------------------------------------------------------
# batched characterization == scalar simulate_read
# ---------------------------------------------------------------------------

def test_batched_characterization_matches_scalar_3_topologies():
    cfgs = [BankConfig(ws, nw, cell) for cell in TOPOLOGIES
            for (ws, nw) in ((16, 16), (32, 32))]
    chars = characterize(cfgs, n_steps=200)
    assert len(chars) == len(cfgs)
    for cfg, ch in zip(cfgs, chars):
        t_ref, _ = timing.simulate_read(build_bank(cfg), n_steps=200)
        assert ch is not None and ch.cfg is cfg
        if np.isinf(t_ref):
            assert np.isinf(ch.t_cell_s)
        else:
            assert ch.t_cell_s == pytest.approx(t_ref, rel=0.01), cfg
        assert ch.t_cell_analytic_s > 0 and ch.n_steps == 200


def test_characterize_skips_non_gain_cells():
    chars = characterize([BankConfig(16, 16, "sram6t"),
                          BankConfig(16, 16, "gc2t_nn")], n_steps=100)
    assert chars[0] is None and chars[1] is not None


def test_crossing_time_interpolates_and_flags():
    t = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    v = jnp.asarray([[0.0, 0.5, 1.0, 1.0],     # crosses 0.75 at t=2.5
                     [0.0, 0.1, 0.2, 0.3],     # never crosses
                     [1.0, 1.0, 1.0, 1.0]])    # past target at step 0
    tc, ok = crossing_time(t, v, 0.75, rising=True)
    assert np.asarray(ok).tolist() == [True, False, False]
    assert float(tc[0]) == pytest.approx(2.5)
    assert np.isinf(float(tc[1])) and np.isinf(float(tc[2]))
    tc2, ok2 = crossing_time(t, -v, -0.75, rising=False)
    assert bool(ok2[0]) and float(tc2[0]) == pytest.approx(2.5)


def test_circuit_node_interning_dict_backed():
    ckt = Circuit()
    idx = [ckt.node(f"n{i}") for i in range(50)]
    assert idx == list(range(1, 51))
    assert ckt.node("n7") == 8 and ckt.node("0") == 0
    # stamps of a tiny divider: G(g) reproduces build()
    ckt.r("n0", "n1", 100.0)
    ckt.r("n1", "0", 50.0)
    ckt.c("n1", "0", 1e-15)
    ckt.vsrc("n0", 0)
    rst, cst, src_G = ckt.build_stamps()
    sys = ckt.build()
    g = np.array([x[2] for x in ckt.res])
    c = np.array([x[2] for x in ckt.caps])
    np.testing.assert_allclose(
        src_G + np.einsum("r,rij->ij", g, rst),
        np.asarray(sys.G, np.float64), rtol=1e-7)
    np.testing.assert_allclose(np.einsum("c,cij->ij", c, cst),
                               np.asarray(sys.C, np.float64), rtol=1e-7)


# ---------------------------------------------------------------------------
# SweepQuery(fidelity="transient") through the Session
# ---------------------------------------------------------------------------

def test_transient_sweep_query_returns_calibrated_table():
    s = Session()
    q = SweepQuery(cells=("gc2t_nn", "sram6t"), word_sizes=(16,),
                   num_words=(16, 32), wwlls=(False,),
                   fidelity="transient", sim_steps=150)
    table = s.run(q)
    assert isinstance(table, CalibratedTable)
    assert isinstance(table, DesignTable) and len(table) == 4
    assert table is s.run(q)                      # memoized whole-table
    cal = table.calibration()
    assert cal["n_simulated"] == 2                # gc points only
    assert cal["max_rel_dev"] is not None
    # analytic points identical to an analytic sweep of the same lattice
    ta = s.run(dataclasses.replace(q, fidelity="analytic"))
    assert type(ta) is DesignTable
    assert all(a is b for a, b in zip(ta.points, table.points))
    # per-config transient chars are shared with overlapping sweeps
    q2 = SweepQuery(cells=("gc2t_nn",), word_sizes=(16,), num_words=(16,),
                    wwlls=(False,), fidelity="transient", sim_steps=150)
    t2 = s.run(q2)
    assert t2.transient[0] is table.transient[0]
    rows = table.as_dict()["rows"]
    assert sum("transient" in r for r in rows) == 2


def test_transient_sweep_rejects_unknown_fidelity():
    with pytest.raises(ValueError):
        Session().run(SweepQuery(fidelity="spice"))
