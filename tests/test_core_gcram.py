"""Paper-fidelity (C1-C10) + property tests for the GCRAM compiler core."""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import dse, layout, power, retention, timing
from repro.core.bank import BankConfig, build_bank, organize
from repro.core.cells import CELLS, with_write_vt
from repro.core.compiler import GCRAMCompiler
from repro.core.spice import devices as dv
from repro.core.techfile import SYN40


# ---------------------------------------------------------------------------
# C1: cell-area ratios (Fig 3)
# ---------------------------------------------------------------------------

def test_c1_cell_area_ratios():
    a6 = layout.cell_area_um2(SYN40, "sram6t")
    ann = layout.cell_area_um2(SYN40, "gc2t_nn")
    aos = layout.cell_area_um2(SYN40, "gc2t_osos")
    assert 0.66 <= ann / a6 <= 0.72          # paper: 69%
    assert 0.09 <= aos / a6 <= 0.13          # paper: 11%
    # 3T adds area over 2T
    assert layout.cell_area_um2(SYN40, "gc3t") > ann


# ---------------------------------------------------------------------------
# C2/C3: bank vs array area (Fig 6)
# ---------------------------------------------------------------------------

def _ratio(bits, cell):
    ws = int(np.sqrt(bits))
    bs = build_bank(BankConfig(ws, ws, cell="sram6t"))
    bg = build_bank(BankConfig(ws, ws, cell=cell))
    return bg, bs


def test_c2_gc_bank_larger_array_smaller():
    for bits in (1024, 4096, 16384):
        bg, bs = _ratio(bits, "gc2t_nn")
        assert bg.area_um2 > bs.area_um2, bits          # dual-port periphery
        assert bg.array_area_um2 < bs.array_area_um2    # smaller cell
    # crossover at large sizes (paper: extrapolated beyond 256 Kb; our
    # synthetic deck crosses between 16 Kb and 256 Kb — see EXPERIMENTS.md)
    bg, bs = _ratio(262144, "gc2t_nn")
    assert bg.area_um2 < bs.area_um2


def test_c2_array_efficiency_rises_with_size():
    effs = [_ratio(b, "gc2t_nn")[0].plan.array_efficiency
            for b in (1024, 4096, 16384)]
    assert effs[0] < effs[1] < effs[2]


def test_c3_osos_bank_smaller_everywhere():
    for bits in (1024, 4096, 16384):
        bo, bs = _ratio(bits, "gc2t_osos")
        assert bo.area_um2 < bs.area_um2, bits


# ---------------------------------------------------------------------------
# C4/C5: frequency (Fig 7a)
# ---------------------------------------------------------------------------

def test_c4_frequency_ordering():
    for bits in (1024, 4096, 16384):
        ws = int(np.sqrt(bits))
        fs = timing.analyze(build_bank(BankConfig(ws, ws, "sram6t"))).f_max_hz
        fg = timing.analyze(build_bank(BankConfig(ws, ws, "gc2t_nn"))).f_max_hz
        assert fg < fs                       # single-ended read is slower
        # narrow word (forces column mux) is slower than the square config
        bn = build_bank(BankConfig(16, bits // 16, "gc2t_nn"))
        if bn.has_colmux:
            fn = timing.analyze(bn).f_max_hz
            assert fn <= fg
    # frequency decreases with bank size
    f1 = timing.analyze(build_bank(BankConfig(32, 32, "gc2t_nn"))).f_max_hz
    f16 = timing.analyze(build_bank(BankConfig(128, 128, "gc2t_nn"))).f_max_hz
    assert f16 < f1


def test_c4_delay_chain_stages_grow():
    s1 = timing.analyze(build_bank(BankConfig(32, 32, "gc2t_nn"))).delay_stages
    s16 = timing.analyze(build_bank(BankConfig(128, 128, "gc2t_nn"))).delay_stages
    assert s16 > s1


def test_c5_wwlls_speeds_up_and_costs_area():
    b0 = build_bank(BankConfig(64, 64, "gc2t_nn"))
    bl = build_bank(BankConfig(64, 64, "gc2t_nn", wwlls=True))
    t0 = timing.analyze(b0)
    tl = timing.analyze(bl)
    assert tl.t_cell_s < t0.t_cell_s         # boosted SN -> faster read
    assert bl.area_um2 > b0.area_um2         # extra ring + LS column


# ---------------------------------------------------------------------------
# C6: effective bandwidth (Fig 7b)
# ---------------------------------------------------------------------------

def test_c6_dual_port_bandwidth():
    pg = dse.evaluate(BankConfig(64, 64, "gc2t_nn"))
    ps = dse.evaluate(BankConfig(64, 64, "sram6t"))
    # SRAM eff bw is halved (shared port): per-MHz GCRAM moves 2 words
    assert pg.eff_bw_bps / pg.f_max_hz == pytest.approx(2 * 64, rel=1e-6)
    assert ps.eff_bw_bps / ps.f_max_hz == pytest.approx(64, rel=1e-6)


# ---------------------------------------------------------------------------
# C7: leakage (Fig 7c)
# ---------------------------------------------------------------------------

def test_c7_leakage():
    bs = build_bank(BankConfig(128, 128, "sram6t"))
    bg = build_bank(BankConfig(128, 128, "gc2t_nn"))
    ps = power.analyze(bs, 1e9)
    pg = power.analyze(bg, 1e9)
    assert pg.cell_leakage_w == 0.0                    # no VDD->GND path
    assert ps.cell_leakage_w > 100 * max(pg.cell_leakage_w, 1e-12)
    assert pg.leakage_w < ps.leakage_w                 # bank-level too


# ---------------------------------------------------------------------------
# C8/C9: retention (Fig 8)
# ---------------------------------------------------------------------------

def test_c8_si_retention_microseconds():
    r = retention.analyze(CELLS["gc2t_nn"], SYN40)
    assert 1e-7 < r.t_ret_s < 1e-4


def test_c8_retention_rises_with_vt_and_wwlls():
    rl = retention.analyze(with_write_vt(CELLS["gc2t_nn"], "nmos_lvt"), SYN40)
    rs = retention.analyze(with_write_vt(CELLS["gc2t_nn"], "nmos_svt"), SYN40)
    rh = retention.analyze(with_write_vt(CELLS["gc2t_nn"], "nmos_hvt"), SYN40)
    assert rl.t_ret_s < rs.t_ret_s < rh.t_ret_s
    rb = retention.analyze(CELLS["gc2t_nn"], SYN40, wwlls=True)
    assert rb.t_ret_s > rs.t_ret_s


def test_c9_os_retention():
    r = retention.analyze(CELLS["gc2t_osos"], SYN40)
    assert 1e-3 < r.t_ret_s < 1.0                      # ms range
    rh = retention.analyze(with_write_vt(CELLS["gc2t_osos"], "os_n_hvt"),
                           SYN40, wwlls=True)
    assert rh.t_ret_s > 10.0                           # paper: >10 s
    # hybrid sits between Si and OS
    rhyb = retention.analyze(CELLS["gc2t_hyb"], SYN40)
    rsi = retention.analyze(CELLS["gc2t_nn"], SYN40)
    assert rsi.t_ret_s < rhyb.t_ret_s


def test_os_ioff_below_1e18_claim():
    fl = SYN40.flavor("os_n_hvt")
    assert dv.i_off(fl, 1.0, 0.04, 1.1) < 1e-18        # A/um


# ---------------------------------------------------------------------------
# GEMTOO-gap: analytic vs transient <= 15%
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_analytic_vs_transient_within_15pct():
    for cell in ("gc2t_nn", "gc2t_np"):
        rep = GCRAMCompiler(BankConfig(32, 32, cell=cell)).compile(
            simulate=True)
        s = rep.summary()
        assert s["analytic_vs_sim_dev"] <= 0.15, (cell, s["analytic_vs_sim_dev"])


# ---------------------------------------------------------------------------
# properties (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.sampled_from([16, 32, 64, 128]), st.sampled_from([16, 32, 64, 128]),
       st.sampled_from(["gc2t_nn", "gc2t_np", "gc2t_osos", "sram6t"]))
def test_prop_bank_area_positive_monotone(ws, nw, cell):
    b = build_bank(BankConfig(ws, nw, cell))
    assert b.area_um2 > b.array_area_um2 > 0
    # monotone at 4x capacity (2x can legitimately invert on aspect-ratio
    # flips of small banks — hypothesis found (16,32)->(16,64))
    b2 = build_bank(BankConfig(ws, nw * 4, cell))
    assert b2.area_um2 > b.area_um2


@settings(max_examples=20, deadline=None)
@given(st.floats(0.30, 0.65), st.floats(0.08, 0.3))
def test_prop_retention_monotone_in_vt_and_width(vt, w):
    import dataclasses
    c1 = dataclasses.replace(CELLS["gc2t_nn"], w_write=w)
    fn = retention.leak_fn(c1, SYN40)
    import jax.numpy as jnp
    i_lo = float(fn(jnp.float32(0.6), vt0=vt))
    i_hi = float(fn(jnp.float32(0.6), vt0=vt + 0.05))
    assert i_hi < i_lo                      # higher VT -> less leak


@settings(max_examples=15, deadline=None)
@given(st.integers(16, 256), st.integers(16, 256))
def test_prop_organize_squares_the_array(ws, nw):
    wpr = organize(ws, nw)
    assert nw % wpr == 0
    rows, cols = nw // wpr, ws * wpr
    base = max(nw, ws) / min(nw, ws)
    assert max(rows, cols) / min(rows, cols) <= base + 1e-9


def test_device_model_consistency():
    """mna.channel_current_raw must equal devices.channel_current."""
    import jax.numpy as jnp
    from repro.core.spice.mna import channel_current_raw
    fl = SYN40.flavor("nmos_svt")
    for vg, va, vb in [(1.1, 1.1, 0.0), (0.0, 1.1, 0.0), (0.7, 0.2, 0.9)]:
        a = float(dv.channel_current(fl, 0.2, 0.05, vg, va, vb))
        b = float(channel_current_raw(1.0, fl.vt0, fl.n_slope, fl.k_prime,
                                      fl.lambda_, 0.2, 0.05, vg, va, vb))
        assert a == pytest.approx(b, rel=1e-6)


def test_gradient_cooptimization_meets_target():
    res = dse.grad_optimize(target_ret_s=1e-4, steps=150)
    assert res["met"], res
    res2 = dse.grad_optimize(target_ret_s=1e-6, steps=150)
    assert res2["met"], res2
    # harder target should require higher VT or bigger boost or both
    assert (res["write_vt"] >= res2["write_vt"] - 0.05)


def test_compiler_outputs(tmp_path):
    rep = GCRAMCompiler(BankConfig(32, 32, cell="gc2t_nn")).compile()
    out = rep.write(str(tmp_path / "gc32"))
    import os, json
    assert os.path.exists(os.path.join(out, "report.json"))
    assert os.path.exists(os.path.join(out, "floorplan.json"))
    assert os.path.exists(os.path.join(out, "read_column.sp"))
    txt = open(os.path.join(out, "read_column.sp")).read()
    assert txt.startswith("*") and ".end" in txt
    man = json.load(open(os.path.join(out, "floorplan.json")))
    assert man["array_efficiency"] < 1.0


# ---------------------------------------------------------------------------
# multibank macros (paper §VI realized)
# ---------------------------------------------------------------------------

def test_multibank_scaling():
    from repro.core.multibank import build_multibank, banks_needed
    from repro.core.dse import Demand, evaluate
    cfg = BankConfig(32, 32, "gc2t_nn")
    m1 = build_multibank(cfg, 1)
    m8 = build_multibank(cfg, 8)
    assert m8.capacity_bits == 8 * m1.capacity_bits
    assert m8.eff_bw_bps == pytest.approx(8 * m1.eff_bw_bps, rel=1e-6)
    assert m8.area_um2 == pytest.approx(8 * m1.area_um2, rel=1e-6)
    assert m8.f_max_hz < evaluate(cfg).f_max_hz        # crossbar hop
    # an L2-class demand that a single bank cannot serve becomes feasible
    dp = evaluate(cfg)
    d = Demand("l2", "L2", dp.f_max_hz * 5.5, 1e-7)
    n = banks_needed(dp, d)
    assert n == 6
